//! Fig. 4: Thompson-sampling BO regret vs candidate-set size and sampler, on
//! Hartmann-6 and the 12-D lander controller problem.
//!
//! Paper shape: larger candidate sets give lower final regret; CIQ with a
//! large T beats RFF at the same T; Cholesky is restricted to small T.
//!
//! Run: `cargo bench --bench fig4_bo [-- --reps 3 --evals 40 --lander]`

#[path = "common/mod.rs"]
mod common;

use ciq::bo::lander::Lander;
use ciq::bo::testfns::Hartmann6;
use ciq::bo::{run_bo, BoConfig, Problem, Sampler};
use ciq::ciq::CiqOptions;
use ciq::util::cli::Args;

fn main() {
    let args = Args::parse();
    let reps = args.get_or("reps", 2u64);
    let evals = args.get_or("evals", 25usize);
    let t_small = args.get_or("t-small", 500usize);
    let t_large = args.get_or("t-large", 1500usize);

    println!("# Fig. 4: TS-BO mean final objective over {reps} replications, {evals} evals");
    println!("problem\tconfig\tT\tmean_best\tsem");

    let hart = Hartmann6;
    let lander = Lander { episodes: 10 };
    let mut problems: Vec<&dyn Problem> = vec![&hart];
    if args.has("lander") {
        problems.push(&lander);
    }

    let mut summary: Vec<(String, String, f64)> = Vec::new();
    for problem in problems {
        let configs: Vec<(String, Sampler, usize)> = vec![
            (format!("Cholesky-{t_small}"), Sampler::Cholesky, t_small),
            (format!("CIQ-{t_small}"), Sampler::Ciq, t_small),
            (format!("CIQ-{t_large}"), Sampler::Ciq, t_large),
            (format!("RFF-{t_large}"), Sampler::Rff, t_large),
        ];
        for (label, sampler, t) in configs {
            let mut bests = Vec::new();
            for rep in 0..reps {
                let cfg = BoConfig {
                    candidates: t,
                    evaluations: evals,
                    init: 10,
                    batch: 5,
                    sampler,
                    fit_steps: 10,
                    ciq: CiqOptions { tol: 1e-3, max_iters: 80, ..Default::default() },
                    ..Default::default()
                };
                bests.push(run_bo(problem, &cfg, 7000 + rep).expect("bo").best());
            }
            let mean = ciq::util::mean(&bests);
            let sem = ciq::util::std_dev(&bests) / (reps as f64).sqrt();
            println!("{}\t{label}\t{t}\t{mean:.4}\t{sem:.4}", problem.name());
            summary.push((problem.name().to_string(), label, mean));
        }
    }

    // shape: CIQ-large <= CIQ-small + noise margin, on Hartmann
    let get = |label: &str| summary.iter().find(|s| s.0 == "hartmann6" && s.1.starts_with(label)).unwrap().2;
    let margin = 0.25;
    common::shape_check(
        "larger candidate sets help (Fig. 4)",
        get(&format!("CIQ-{t_large}")) <= get(&format!("CIQ-{t_small}")) + margin,
    );
    common::shape_check(
        "CIQ-small ≈ Cholesky-small (same model, rotated sample)",
        (get(&format!("CIQ-{t_small}")) - get(&format!("Cholesky-{t_small}"))).abs() < 0.6,
    );
}

//! Fig. 2 (middle/right): wall-clock speedup of msMINRES-CIQ over Cholesky
//! for forward+backward `K^{-1/2}b`, as a function of N and the number of
//! right-hand sides.
//!
//! Paper shape: CIQ's advantage grows with N (up to 15× on their GPU) and
//! shrinks as RHS count amortizes the Cholesky factorization; the crossover
//! moves right with more RHS but CIQ still wins at large N.
//!
//! Run: `cargo bench --bench fig2_speedup [-- --sizes 500,1000,2000 --rhs 1,16,64]`

#[path = "common/mod.rs"]
mod common;

use ciq::ciq::{Ciq, CiqOptions};
use ciq::linalg::{Cholesky, Matrix};
use ciq::operators::{KernelOp, KernelType, LinearOp};
use ciq::rng::Pcg64;
use ciq::util::cli::Args;

fn main() {
    let args = Args::parse();
    let sizes = args.get_list("sizes", &[500usize, 1000, 2000]);
    let rhs_counts = args.get_list("rhs", &[1usize, 16, 64]);
    let mut rng = Pcg64::seeded(args.get_or("seed", 5u64));

    println!("# Fig. 2 (mid/right): CIQ vs Cholesky, forward+backward K^(-1/2)b");
    println!("N\trhs\tchol_s\tciq_s\tspeedup");
    let mut speedups: Vec<(usize, usize, f64)> = Vec::new();
    for &n in &sizes {
        // Kin40k-like synthetic data (8-D standardized features)
        let x = Matrix::randn(n, 8, &mut rng);
        let op = KernelOp::new(&x, KernelType::Matern52, 1.5, 1.0, 1e-2);
        for &r in &rhs_counts {
            let b = Matrix::randn(n, r, &mut rng);
            // --- Cholesky: factor + whiten each column + backward-ish solve
            let t_chol = common::bench_median(3, || {
                let k = op.to_dense();
                let chol = Cholesky::with_jitter(&k, 1e-8).expect("chol");
                for j in 0..r {
                    let col = b.col(j);
                    let w = chol.whiten_mvm(&col);
                    let _ = chol.solve_lt(&w); // backward-pass triangular solve
                }
            });
            // --- CIQ: blocked forward + backward (second msMINRES call);
            // the backward pass reuses the forward pass's spectral cache, as
            // the coordinator does in production
            let solver = Ciq::new(CiqOptions { q_points: 8, tol: 1e-4, max_iters: 300, ..Default::default() });
            let t_ciq = common::bench_median(3, || {
                let fwd = solver.invsqrt_mvm_block_with_bounds(&op, &b, None).expect("ciq fwd");
                let _bwd = solver
                    .invsqrt_mvm_block_with_bounds(&op, &b, fwd.cache.as_ref())
                    .expect("ciq bwd");
            });
            let speedup = t_chol / t_ciq;
            println!("{n}\t{r}\t{t_chol:.3}\t{t_ciq:.3}\t{speedup:.2}");
            speedups.push((n, r, speedup));
        }
    }
    // shape checks: speedup grows with N at fixed RHS; shrinks with RHS at fixed N
    let n_lo = sizes[0];
    let n_hi = *sizes.last().unwrap();
    let r0 = rhs_counts[0];
    let s_lo = speedups.iter().find(|s| s.0 == n_lo && s.1 == r0).unwrap().2;
    let s_hi = speedups.iter().find(|s| s.0 == n_hi && s.1 == r0).unwrap().2;
    common::shape_check("speedup grows with N (Fig. 2 mid)", s_hi > s_lo);
    let r_hi = *rhs_counts.last().unwrap();
    let s_rlo = speedups.iter().find(|s| s.0 == n_hi && s.1 == r0).unwrap().2;
    let s_rhi = speedups.iter().find(|s| s.0 == n_hi && s.1 == r_hi).unwrap().2;
    common::shape_check("many RHS amortize Cholesky (Fig. 2 right)", s_rhi < s_rlo * 1.5);
}

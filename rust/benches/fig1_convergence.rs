//! Fig. 1 / Fig. S1: msMINRES-CIQ relative error of `K^{1/2}b` as a function
//! of the number of quadrature points Q, across spectrum families
//! (λ_t ∈ {t^{-1/2}, t^{-1}, t^{-2}, e^{-t}}) and Matérn kernel matrices.
//!
//! Paper shape: error decays rapidly with Q, plateaus at the msMINRES
//! tolerance; Q = 8 reaches < 1e-4 for every family and size.
//!
//! Run: `cargo bench --bench fig1_convergence [-- --sizes 512,1024 --tol 1e-5]`

#[path = "common/mod.rs"]
mod common;

use ciq::ciq::{Ciq, CiqOptions};
use ciq::linalg::eigen::spd_sqrt;
use ciq::linalg::Matrix;
use ciq::operators::{DenseOp, KernelOp, KernelType, LinearOp};
use ciq::rng::Pcg64;
use ciq::util::cli::Args;
use ciq::util::rel_err;

fn main() {
    let args = Args::parse();
    let sizes = args.get_list("sizes", &[256usize, 512]);
    let qs = args.get_list("qs", &[2usize, 4, 6, 8, 12]);
    let tol = args.get_or("tol", 1e-5f64);
    let mut rng = Pcg64::seeded(args.get_or("seed", 1u64));

    println!("# Fig. 1 / S1: CIQ relative error of K^(1/2)b vs Q (msMINRES tol {tol})");
    println!("family\tN\tQ\trel_err");
    let mut q8_worst: f64 = 0.0;
    let mut q8_worst_matern: f64 = 0.0;
    for &n in &sizes {
        // spectrum families + a Matérn kernel on random 1-D data
        let mut cases: Vec<(String, Matrix)> = ["invsqrt", "inv", "invsq", "exp"]
            .iter()
            .map(|f| (f.to_string(), common::spd_with_spectrum(&common::spectrum(f, n), &mut rng)))
            .collect();
        let x = Matrix::randn(n, 1, &mut rng);
        cases.push((
            "matern".to_string(),
            KernelOp::new(&x, KernelType::Matern52, 0.8, 1.0, 1e-3).to_dense(),
        ));
        for (family, k) in cases {
            let exact_map = spd_sqrt(&k).expect("eig");
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let exact = exact_map.matvec(&b);
            let op = DenseOp::new(k);
            for &q in &qs {
                let solver = Ciq::new(CiqOptions {
                    q_points: q,
                    tol,
                    max_iters: 400,
                    ..Default::default()
                });
                let approx = solver.sqrt_mvm(&op, &b).expect("ciq");
                let err = rel_err(&approx.solution, &exact);
                println!("{family}\t{n}\t{q}\t{err:.3e}");
                if q == 8 {
                    if family == "matern" {
                        q8_worst_matern = q8_worst_matern.max(err);
                    } else {
                        q8_worst = q8_worst.max(err);
                    }
                }
            }
        }
    }
    println!("# worst Q=8 error: synthetic {q8_worst:.3e}, matern {q8_worst_matern:.3e}");
    common::shape_check("Q=8 achieves <1e-4 on synthetic spectra (Fig. 1)", q8_worst < 1e-4);
    // the Matérn matrices are the paper's ill-conditioned case: the error
    // plateaus at the msMINRES tolerance, not the quadrature error
    common::shape_check("Q=8 within solver tolerance on Matérn (Fig. 1 right)", q8_worst_matern < 1e-3);
}

//! §Perf microbenchmarks: the three L3 hot paths the optimization pass
//! iterates on — (1) the partitioned kernel MVM (tile size, threading),
//! (2) the msMINRES per-iteration recurrence overhead, (3) RHS batching in
//! the coordinator (block-msMINRES vs per-vector solves).
//!
//! Run: `cargo bench --bench perf_hotpath [-- --n 3000]`

#[path = "common/mod.rs"]
mod common;

use ciq::ciq::{Ciq, CiqOptions};
use ciq::krylov::msminres::{msminres, MsMinresOptions};
use ciq::linalg::Matrix;
use ciq::operators::{KernelOp, KernelType, LinearOp};
use ciq::rng::Pcg64;
use ciq::util::cli::Args;

fn main() {
    let args = Args::parse();
    let n = args.get_or("n", 1500usize);
    let mut rng = Pcg64::seeded(args.get_or("seed", 6u64));
    let x = Matrix::randn(n, 4, &mut rng);
    let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    println!("# perf 1: kernel MVM (N={n}, d=4) — tile-size sweep");
    println!("tile\tms\tgflops");
    let flops = 2.0 * (n as f64) * (n as f64) * (4.0 + 1.0);
    let mut best_ms = f64::INFINITY;
    for tile in [32usize, 64, 128, 256, 512] {
        let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 1e-1).with_tile(tile);
        let t = common::bench_median(5, || {
            let _ = op.matvec(&v);
        });
        println!("{tile}\t{:.2}\t{:.2}", t * 1e3, flops / t / 1e9);
        best_ms = best_ms.min(t * 1e3);
    }

    println!("# perf 2: msMINRES recurrence overhead (Q sweep at fixed J)");
    println!("q\tms_total\tms_per_iter");
    let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 1e-1);
    let j = 20;
    for q in [1usize, 4, 8, 16] {
        let shifts: Vec<f64> = (0..q).map(|i| 0.1 * (i + 1) as f64).collect();
        let t = common::bench_median(3, || {
            let _ = msminres(
                &op,
                &v,
                &shifts,
                &MsMinresOptions { max_iters: j, tol: 1e-30, weights: None },
            );
        });
        println!("{q}\t{:.1}\t{:.2}", t * 1e3, t * 1e3 / j as f64);
    }

    println!("# perf 3: RHS batching (block msMINRES vs per-vector) at r=4");
    let r = 4;
    let b = Matrix::randn(n, r, &mut rng);
    let solver = Ciq::new(CiqOptions { q_points: 8, tol: 1e-4, max_iters: 200, ..Default::default() });
    let cache = solver.solver_cache(&op).expect("spectral cache");
    let t_block = common::bench_median(3, || {
        let _ = solver.invsqrt_mvm_block_with_bounds(&op, &b, Some(&cache)).expect("block");
    });
    // the per-vector baseline gets the cache too, so perf 3 isolates RHS
    // batching and perf 4 isolates cache reuse
    let t_loop = common::bench_median(3, || {
        for jcol in 0..r {
            let _ = solver.invsqrt_with_bounds(&op, &b.col(jcol), Some(cache.bounds)).expect("solo");
        }
    });
    println!("block\t{:.1} ms", t_block * 1e3);
    println!("loop\t{:.1} ms", t_loop * 1e3);
    println!("batching_speedup\t{:.2}x", t_loop / t_block);

    println!("# perf 4: spectral-cache reuse (cold Lanczos estimate vs cached bounds)");
    let t_cold = common::bench_median(3, || {
        let _ = solver.invsqrt_mvm_block_with_bounds(&op, &b, None).expect("cold");
    });
    println!("cold\t{:.1} ms", t_cold * 1e3);
    println!("warm\t{:.1} ms", t_block * 1e3);
    println!("cache_speedup\t{:.2}x", t_cold / t_block);

    common::shape_check("MVM under 1 GF/s would signal a regression", flops / (best_ms / 1e3) / 1e9 > 0.5);
}

//! §Perf microbenchmarks: the L3 hot paths the optimization pass iterates
//! on — (0) the panel-GEMM kernel-MVM engine vs the pre-panel per-entry
//! engine (emits `BENCH_kernel_mvm.json`), (1) the partitioned kernel MVM
//! (tile size, threading), (2) the msMINRES per-iteration recurrence
//! overhead, (3) RHS batching in the coordinator (block-msMINRES vs
//! per-vector solves), (5) preconditioned vs plain CIQ on an
//! ill-conditioned kernel (emits `BENCH_ciq_precond.json`), (6) the async
//! dispatcher's enqueue→flush latency at 1/8/64 shards (emits
//! `BENCH_dispatch.json`), (7) allocation pressure of the solve stack —
//! allocs/solve and solves/sec, workspace-warm vs cold, measured through a
//! counting global allocator (emits `BENCH_alloc.json`), (8) the batched
//! dense Newton–Schulz tier vs per-operator Krylov across
//! N ∈ {16, 64, 256, 1024} × batch ∈ {1, 8, 64, 512} — the crossover that
//! sets `BatchedDenseConfig::n_threshold` (emits
//! `BENCH_batched_dense.json`), (9) the runtime-dispatched SIMD
//! micro-kernels vs the forced-scalar fallback — GEMM, kernel MVM, and the
//! lane-parallel ρ panel vs per-element glibc `exp` across
//! N ∈ {1024, 4096, 16384} (emits `BENCH_simd.json`), (10) the
//! observability layer's ns/event — disabled `trace!` vs a plain
//! relaxed-load branch (the cost-contract gate), the enabled recorder
//! write, and the lock-free histogram record vs the retired `Mutex<Vec>`
//! push (emits `BENCH_obs.json`), (11) the mixed-precision MVM engine —
//! f32-storage kernel panels with f64 iterative refinement vs the pure-f64
//! block solve through the same cached-bounds entry point (emits
//! `BENCH_mixed.json`).
//!
//! Run: `cargo bench --bench perf_hotpath [-- --n 3000] [--fast]`
//!
//! `--fast` shrinks section 0 to N=1024, d=4, section 5 to N=400, section 6
//! to 1/8 shards, section 7 to N=256, section 8 to
//! N ∈ {16, 64} × batch ∈ {1, 8}, section 9 to N=1024, section 10 to
//! 200k events/rep, and section 11 to N=512 (the CI smoke configuration);
//! the full sweep covers N ∈ {1024, 4096} × d ∈ {4, 16} × all four kernel
//! types × {matvec, matmat r=8}.

#[path = "common/mod.rs"]
mod common;

use ciq::ciq::{recycle_block_result, Ciq, CiqOptions, PrecondConfig, SolveKind, SolverPolicy};
use ciq::krylov::msminres::{msminres, MsMinresOptions};
use ciq::linalg::{Matrix, Precision, RefineConfig, SolveWorkspace};
use ciq::operators::{KernelOp, KernelType, LinearOp};
use ciq::rng::Pcg64;
use ciq::util::allocs::{thread_allocs, CountingAllocator};
use ciq::util::cli::Args;
use ciq::util::threadpool::{num_threads, pool_spawned_threads};

// §7 measures allocation pressure through this counting allocator; it
// delegates straight to `System`, so the timing sections are unaffected
// beyond one thread-local increment per allocation event.
#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// One before/after measurement for the JSON report.
struct MvmEntry {
    n: usize,
    d: usize,
    kernel: &'static str,
    op: &'static str,
    before_ms: f64,
    after_ms: f64,
    gflops_after: f64,
}

impl MvmEntry {
    fn speedup(&self) -> f64 {
        self.before_ms / self.after_ms.max(1e-12)
    }

    fn to_json(&self) -> String {
        format!(
            "    {{\"n\": {}, \"d\": {}, \"kernel\": \"{}\", \"op\": \"{}\", \
             \"before_ms\": {:.4}, \"after_ms\": {:.4}, \"speedup\": {:.3}, \
             \"gflops_after\": {:.3}}}",
            self.n, self.d, self.kernel, self.op, self.before_ms, self.after_ms,
            self.speedup(), self.gflops_after
        )
    }
}

/// Deferred PASS/FAIL checks: every section *records* its verdicts and main
/// evaluates them after all sections ran, so the JSON artifacts are always
/// written (and uploadable by CI) before any failing check exits the
/// process.
type Checks = Vec<(String, bool)>;

/// §0: panel-GEMM engine vs the pre-panel per-entry engine, before/after in
/// one run on one machine. Writes `BENCH_kernel_mvm.json` into the CWD.
fn bench_kernel_mvm(fast: bool, rng: &mut Pcg64, checks: &mut Checks) {
    let ns: &[usize] = if fast { &[1024] } else { &[1024, 4096] };
    let ds: &[usize] = if fast { &[4] } else { &[4, 16] };
    let reps = if fast { 3 } else { 5 };
    let kinds: [(KernelType, &'static str); 4] = [
        (KernelType::Rbf, "rbf"),
        (KernelType::Matern12, "matern12"),
        (KernelType::Matern32, "matern32"),
        (KernelType::Matern52, "matern52"),
    ];
    println!("# perf 0: panel-GEMM kernel MVM engine (before = per-entry naive, after = panel)");
    println!("n\td\tkernel\top\tbefore_ms\tafter_ms\tspeedup");
    let mut entries: Vec<MvmEntry> = Vec::new();
    let mut max_diff = 0.0f64;
    for &n in ns {
        for &d in ds {
            let x = Matrix::randn(n, d, rng);
            let v = Matrix::randn(n, 1, rng);
            let b = Matrix::randn(n, 8, rng);
            // flops for one matmat: distance panel (2nd + 3) + rho (~10) + contract (2r)
            let gram_flops = |r: usize| {
                (n as f64) * (n as f64) * (2.0 * d as f64 + 13.0 + 2.0 * r as f64)
            };
            for (kind, kname) in kinds {
                let op = KernelOp::new(&x, kind, 1.0, 1.0, 1e-1);
                for (opname, rhs, r) in [("matvec", &v, 1usize), ("matmat_r8", &b, 8)] {
                    let before_s = common::bench_median(reps, || {
                        let _ = op.matmat_naive(rhs);
                    });
                    let after_s = common::bench_median(reps, || {
                        let _ = op.matmat(rhs);
                    });
                    max_diff = max_diff.max(op.matmat(rhs).max_abs_diff(&op.matmat_naive(rhs)));
                    let e = MvmEntry {
                        n,
                        d,
                        kernel: kname,
                        op: opname,
                        before_ms: before_s * 1e3,
                        after_ms: after_s * 1e3,
                        gflops_after: gram_flops(r) / after_s / 1e9,
                    };
                    println!(
                        "{}\t{}\t{}\t{}\t{:.2}\t{:.2}\t{:.2}x",
                        e.n, e.d, e.kernel, e.op, e.before_ms, e.after_ms, e.speedup()
                    );
                    entries.push(e);
                }
            }
        }
    }
    let body: Vec<String> = entries.iter().map(MvmEntry::to_json).collect();
    let json = format!(
        "{{\n  \"schema\": \"ciq.bench.kernel_mvm.v1\",\n  \"config\": {{\"fast\": {}, \
         \"threads\": {}, \"pool_workers\": {}, \"reps\": {}}},\n  \"entries\": [\n{}\n  ]\n}}\n",
        fast,
        num_threads(),
        pool_spawned_threads(),
        reps,
        body.join(",\n")
    );
    std::fs::write("BENCH_kernel_mvm.json", json).expect("write BENCH_kernel_mvm.json");
    println!("wrote BENCH_kernel_mvm.json ({} entries)", entries.len());
    checks.push(("panel engine agrees with naive engine (1e-8)".into(), max_diff < 1e-8));
    let worst = entries
        .iter()
        .map(MvmEntry::speedup)
        .fold(f64::INFINITY, f64::min);
    // soft floor: regression guard, not the ≥2×/1.5× acceptance numbers
    // (those are read off the committed JSON for the target machine)
    checks.push(("panel engine is never slower than 0.8x naive".into(), worst > 0.8));
}

fn main() {
    let args = Args::parse();
    let mut checks: Checks = Vec::new();
    bench_kernel_mvm(args.has("fast"), &mut Pcg64::seeded(0xA11A), &mut checks);
    let n = args.get_or("n", 1500usize);
    let mut rng = Pcg64::seeded(args.get_or("seed", 6u64));
    let x = Matrix::randn(n, 4, &mut rng);
    let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    println!("# perf 1: kernel MVM (N={n}, d=4) — tile-size sweep");
    println!("tile\tms\tgflops");
    let flops = 2.0 * (n as f64) * (n as f64) * (4.0 + 1.0);
    let mut best_ms = f64::INFINITY;
    for tile in [32usize, 64, 128, 256, 512] {
        let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 1e-1).with_tile(tile);
        let t = common::bench_median(5, || {
            let _ = op.matvec(&v);
        });
        println!("{tile}\t{:.2}\t{:.2}", t * 1e3, flops / t / 1e9);
        best_ms = best_ms.min(t * 1e3);
    }

    println!("# perf 2: msMINRES recurrence overhead (Q sweep at fixed J)");
    println!("q\tms_total\tms_per_iter");
    let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 1e-1);
    let j = 20;
    for q in [1usize, 4, 8, 16] {
        let shifts: Vec<f64> = (0..q).map(|i| 0.1 * (i + 1) as f64).collect();
        let t = common::bench_median(3, || {
            let _ = msminres(
                &op,
                &v,
                &shifts,
                &MsMinresOptions { max_iters: j, tol: 1e-30, weights: None },
            );
        });
        println!("{q}\t{:.1}\t{:.2}", t * 1e3, t * 1e3 / j as f64);
    }

    println!("# perf 3: RHS batching (block msMINRES vs per-vector) at r=4");
    let r = 4;
    let b = Matrix::randn(n, r, &mut rng);
    let solver = Ciq::new(CiqOptions { q_points: 8, tol: 1e-4, max_iters: 200, ..Default::default() });
    let cache = solver.solver_cache(&op).expect("spectral cache");
    let t_block = common::bench_median(3, || {
        let _ = solver.invsqrt_mvm_block_with_bounds(&op, &b, Some(&cache)).expect("block");
    });
    // the per-vector baseline gets the cache too, so perf 3 isolates RHS
    // batching and perf 4 isolates cache reuse
    let t_loop = common::bench_median(3, || {
        for jcol in 0..r {
            let _ = solver.invsqrt_with_bounds(&op, &b.col(jcol), Some(cache.bounds)).expect("solo");
        }
    });
    println!("block\t{:.1} ms", t_block * 1e3);
    println!("loop\t{:.1} ms", t_loop * 1e3);
    println!("batching_speedup\t{:.2}x", t_loop / t_block);

    println!("# perf 4: spectral-cache reuse (cold Lanczos estimate vs cached bounds)");
    let t_cold = common::bench_median(3, || {
        let _ = solver.invsqrt_mvm_block_with_bounds(&op, &b, None).expect("cold");
    });
    println!("cold\t{:.1} ms", t_cold * 1e3);
    println!("warm\t{:.1} ms", t_block * 1e3);
    println!("cache_speedup\t{:.2}x", t_cold / t_block);

    checks.push((
        "MVM under 1 GF/s would signal a regression".into(),
        flops / (best_ms / 1e3) / 1e9 > 0.5,
    ));

    bench_ciq_precond(args.has("fast"), &mut rng, &mut checks);

    bench_dispatch(args.has("fast"), &mut checks);

    bench_alloc(args.has("fast"), &mut rng, &mut checks);

    bench_batched_dense(args.has("fast"), &mut rng, &mut checks);

    bench_simd(args.has("fast"), &mut rng, &mut checks);

    bench_obs(args.has("fast"), &mut checks);

    bench_mixed(args.has("fast"), &mut rng, &mut checks);

    // evaluate every recorded verdict only now — all eight JSON artifacts
    // exist on disk whatever happens below
    for (label, ok) in &checks {
        common::shape_check(label, *ok);
    }
}

/// §7: allocation pressure of the solve stack — the zero-allocation
/// steady-state acceptance numbers. A cold solve (fresh workspace per call)
/// pays the first-touch growth; a warm solve on a pooled workspace must pay
/// **zero** allocations on the solving thread (the counting global allocator
/// above is thread-local; all solver-side allocations happen on the
/// submitting thread — pool workers only run allocation-free GEMM bodies).
/// Writes `BENCH_alloc.json` into the CWD.
fn bench_alloc(fast: bool, rng: &mut Pcg64, checks: &mut Checks) {
    use ciq::operators::DenseOp;

    let n = if fast { 256 } else { 1024 };
    let r = 8;
    let reps = if fast { 10 } else { 30 };
    println!("# perf 7: alloc pressure (N={n}, r={r}, counting global allocator)");
    let a = Matrix::randn(n, n, rng);
    let mut k = a.matmul(&a.transpose());
    for i in 0..n {
        k[(i, i)] += n as f64 * 0.5;
    }
    let op = DenseOp::new(k);
    let b = Matrix::randn(n, r, rng);
    let solver = Ciq::new(CiqOptions { tol: 1e-6, ..Default::default() });
    let ctx = solver.build_context(&op, &SolverPolicy::CachedBounds).expect("ctx");

    // cold: a fresh workspace per solve — every buffer is a first touch
    let mut cold_allocs = 0u64;
    let t_cold = common::bench_median(3, || {
        let mut ws = SolveWorkspace::new();
        let a0 = thread_allocs();
        let res = solver.solve_block_in(&mut ws, &op, &b, SolveKind::InvSqrt, &ctx).expect("cold");
        cold_allocs = thread_allocs() - a0;
        recycle_block_result(&mut ws, res);
    });

    // warm: one pooled workspace, measured over `reps` steady-state solves
    let mut ws = SolveWorkspace::new();
    for _ in 0..2 {
        let res = solver.solve_block_in(&mut ws, &op, &b, SolveKind::InvSqrt, &ctx).expect("warm-up");
        recycle_block_result(&mut ws, res);
    }
    let a0 = thread_allocs();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let res = solver.solve_block_in(&mut ws, &op, &b, SolveKind::InvSqrt, &ctx).expect("warm");
        recycle_block_result(&mut ws, res);
    }
    let warm_secs = t0.elapsed().as_secs_f64() / reps as f64;
    let warm_allocs = (thread_allocs() - a0) as f64 / reps as f64;
    let solves_per_sec = 1.0 / warm_secs.max(1e-12);

    println!("mode\tallocs_per_solve\tms_per_solve");
    println!("cold\t{cold_allocs}\t{:.2}", t_cold * 1e3);
    println!("warm\t{warm_allocs:.2}\t{:.2}", warm_secs * 1e3);
    println!("warm solves/sec: {solves_per_sec:.1}");
    let json = format!(
        "{{\n  \"schema\": \"ciq.bench.alloc.v1\",\n  \"config\": {{\"fast\": {fast}, \
         \"n\": {n}, \"rhs\": {r}, \"reps\": {reps}, \"threads\": {}, \
         \"counter\": \"thread-local, submitting thread\"}},\n  \"entries\": [\n    \
         {{\"mode\": \"cold\", \"allocs_per_solve\": {cold_allocs}, \"ms_per_solve\": {:.4}}},\n    \
         {{\"mode\": \"warm\", \"allocs_per_solve\": {warm_allocs:.2}, \"ms_per_solve\": {:.4}, \
         \"solves_per_sec\": {solves_per_sec:.1}}}\n  ]\n}}\n",
        num_threads(),
        t_cold * 1e3,
        warm_secs * 1e3,
    );
    std::fs::write("BENCH_alloc.json", json).expect("write BENCH_alloc.json");
    println!("wrote BENCH_alloc.json");
    checks.push(("cold solve allocates (sanity: the counter is live)".into(), cold_allocs > 0));
    checks.push(("warm-path allocs/solve == 0 (zero-allocation steady state)".into(), warm_allocs == 0.0));
}

/// §6: the async dispatcher's enqueue→flush latency on the deadline path,
/// at 1/8/64 shards. Every wave submits one sub-ceiling request per shard,
/// so each must wait out its armed flush deadline: the measured latency is
/// `max_wait` plus pure dispatcher overhead (one timer-wheel fire per
/// shard). Writes `BENCH_dispatch.json` into the CWD (uploaded by the CI
/// bench-smoke job next to the other JSONs). The threaded baseline this
/// section used to race is retired — compare against the committed history
/// for the before-side.
fn bench_dispatch(fast: bool, checks: &mut Checks) {
    use ciq::coordinator::{ReqKind, SamplingService, ServiceConfig, SharedOp};
    use ciq::operators::DenseOp;
    use std::collections::HashMap;
    use std::sync::atomic::Ordering;
    use std::sync::Arc;
    use std::time::Duration;

    let n = 8;
    let shard_counts: &[usize] = if fast { &[1, 8] } else { &[1, 8, 64] };
    let waves = if fast { 20 } else { 50 };
    let max_wait = Duration::from_millis(2);
    println!("# perf 6: async dispatcher (deadline path, {waves} waves, max_wait 2 ms)");
    println!("shards\tp50_us\tp99_us\twakeups\ttimer_fires");
    let mut entries: Vec<String> = Vec::new();
    let mut async_event_driven = true;
    for &shards in shard_counts {
        // identity operators: the solve is trivial, so latency beyond
        // max_wait is dispatcher overhead
        let mut map: HashMap<String, SharedOp> = HashMap::new();
        for s in 0..shards {
            map.insert(format!("op{s}"), Arc::new(DenseOp::new(Matrix::eye(n))));
        }
        let svc = SamplingService::start(
            ServiceConfig {
                max_batch: 1024,
                max_wait,
                workers: 2,
                ciq: CiqOptions::default(),
                warm_on_register: false,
                ..Default::default()
            },
            map,
        );
        for _ in 0..waves {
            let tickets: Vec<_> = (0..shards)
                .map(|s| svc.submit(&format!("op{s}"), ReqKind::Whiten, vec![1.0; n]))
                .collect();
            for t in tickets {
                t.wait().expect("dispatch bench request failed");
            }
        }
        let m = svc.metrics();
        let (p50, p99) = (m.latency_percentile_us(50.0), m.latency_percentile_us(99.0));
        let wakeups = m.dispatcher_wakeups.load(Ordering::Relaxed);
        let fires = m.timer_fires.load(Ordering::Relaxed);
        println!("{shards}\t{p50}\t{p99}\t{wakeups}\t{fires}");
        entries.push(format!(
            "    {{\"backend\": \"Async\", \"shards\": {shards}, \"p50_us\": {p50}, \
             \"p99_us\": {p99}, \"wakeups\": {wakeups}, \"timer_fires\": {fires}}}"
        ));
        // Strictly event/deadline-driven, checked behaviorally (not just by
        // re-counting submissions): every wakeup is an arrival, and every
        // wave's per-shard batch flushed via its own armed deadline — a
        // reintroduced poll loop that flushed shards early would starve the
        // deadline tasks of fires, a double-fire would overshoot. (The
        // idle-window guarantee itself is pinned by the integration test on
        // ExecStats.)
        let expected = (waves * shards) as u64;
        async_event_driven &= wakeups == expected && fires == expected;
        svc.shutdown();
    }
    let json = format!(
        "{{\n  \"schema\": \"ciq.bench.dispatch.v1\",\n  \"config\": {{\"fast\": {fast}, \
         \"waves\": {waves}, \"n\": {n}, \"max_wait_ms\": 2, \"workers\": 2, \
         \"threads\": {}}},\n  \"entries\": [\n{}\n  ]\n}}\n",
        num_threads(),
        entries.join(",\n")
    );
    std::fs::write("BENCH_dispatch.json", json).expect("write BENCH_dispatch.json");
    println!("wrote BENCH_dispatch.json ({} entries)", entries.len());
    checks.push((
        "async dispatcher: wakeups == arrivals and every wave flushed by its armed deadline"
            .into(),
        async_event_driven,
    ));
}

/// §5: preconditioned vs plain CIQ on an ill-conditioned RBF kernel — the
/// serving pipeline's precond-on/off numbers. Writes
/// `BENCH_ciq_precond.json` into the CWD (uploaded by the CI bench-smoke
/// job next to `BENCH_kernel_mvm.json`).
fn bench_ciq_precond(fast: bool, rng: &mut Pcg64, checks: &mut Checks) {
    let n = if fast { 400 } else { 1000 };
    let rank = if fast { 24 } else { 48 };
    let reps = if fast { 2 } else { 3 };
    let noise = 1e-4;
    let r = 4;
    println!("# perf 5: preconditioned CIQ (N={n}, rank={rank}, noise={noise:.0e}, r={r})");
    let x = Matrix::randn(n, 1, rng);
    let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, noise);
    let b = Matrix::randn(n, r, rng);
    let solver =
        Ciq::new(CiqOptions { tol: 1e-5, max_iters: 4000, ..Default::default() });
    let ctx_plain = solver.build_context(&op, &SolverPolicy::CachedBounds).expect("plain ctx");
    let cfg = PrecondConfig { rank, sigma2: Some(noise), build_tol: 1e-14 };
    let t_build = common::bench_median(reps, || {
        let _ = solver.build_context(&op, &SolverPolicy::Preconditioned(cfg.clone())).expect("ctx");
    });
    let ctx_pre = solver.build_context(&op, &SolverPolicy::Preconditioned(cfg)).expect("pre ctx");
    let mut iters = (0usize, 0usize); // (plain, precond)
    let t_plain = common::bench_median(reps, || {
        let res = solver.solve_block(&op, &b, SolveKind::InvSqrt, &ctx_plain).expect("plain");
        iters.0 = res.col_iterations.iter().copied().max().unwrap_or(0);
    });
    let t_pre = common::bench_median(reps, || {
        let res = solver.solve_block(&op, &b, SolveKind::InvSqrt, &ctx_pre).expect("precond");
        iters.1 = res.col_iterations.iter().copied().max().unwrap_or(0);
    });
    println!("mode\tms\titers");
    println!("plain\t{:.1}\t{}", t_plain * 1e3, iters.0);
    println!("precond\t{:.1}\t{}", t_pre * 1e3, iters.1);
    println!("precond_build\t{:.1} ms (amortized across every batch on the operator)", t_build * 1e3);
    println!("precond_speedup\t{:.2}x ({} → {} iters)", t_plain / t_pre.max(1e-12), iters.0, iters.1);
    let json = format!(
        "{{\n  \"schema\": \"ciq.bench.ciq_precond.v1\",\n  \"config\": {{\"fast\": {fast}, \
         \"n\": {n}, \"rank\": {rank}, \"noise\": {noise}, \"rhs\": {r}, \"tol\": 1e-5, \
         \"threads\": {}, \"reps\": {reps}}},\n  \"entries\": [\n    \
         {{\"mode\": \"plain\", \"ms\": {:.4}, \"iters\": {}}},\n    \
         {{\"mode\": \"precond\", \"ms\": {:.4}, \"iters\": {}, \"build_ms\": {:.4}}}\n  ],\n  \
         \"speedup\": {:.3}\n}}\n",
        num_threads(),
        t_plain * 1e3,
        iters.0,
        t_pre * 1e3,
        iters.1,
        t_build * 1e3,
        t_plain / t_pre.max(1e-12),
    );
    std::fs::write("BENCH_ciq_precond.json", json).expect("write BENCH_ciq_precond.json");
    println!("wrote BENCH_ciq_precond.json");
    checks.push((
        "preconditioned CIQ uses fewer msMINRES iterations than plain".into(),
        iters.1 < iters.0,
    ));
}

/// §8: the batched-dense Newton–Schulz tier vs per-operator Krylov — the
/// crossover measurement behind `BatchedDenseConfig::n_threshold`. For each
/// `N × batch` cell: `build_ms` is the one-per-operator-version coupled
/// Newton–Schulz factorization of the whole stack, `apply_ms` the
/// steady-state batched GEMV serving one request per operator, and
/// `krylov_ms` the per-operator cached-bounds CIQ solve (warm workspace, so
/// both sides are steady-state). Stack buffers are capped at ~32 MiB: big
/// cells measure a subset of the batch and extrapolate linearly (both tiers
/// are linear in batch — `"sample"` in the JSON records the measured
/// subset). Writes `BENCH_batched_dense.json` into the CWD (uploaded by the
/// CI bench-smoke job next to the other JSONs).
fn bench_batched_dense(fast: bool, rng: &mut Pcg64, checks: &mut Checks) {
    use ciq::ciq::dense_sqrt::{newton_schulz_stack_in, DenseFactorStack, DenseSqrtOptions};
    use ciq::linalg::batched::gemv_nn_batched;
    use ciq::linalg::eigen;
    use ciq::operators::DenseOp;

    let ns: &[usize] = if fast { &[16, 64] } else { &[16, 64, 256, 1024] };
    let batches: &[usize] = if fast { &[1, 8] } else { &[1, 8, 64, 512] };
    let opts = DenseSqrtOptions::default();
    println!("# perf 8: batched dense Newton–Schulz tier vs per-operator Krylov");
    println!("n\tbatch\tbuild_ms\tapply_ms\tkrylov_ms\tdense_speedup");
    let mut entries: Vec<String> = Vec::new();
    let mut ns_accuracy = 0.0f64;
    let mut crossover_n = 0usize;
    let solver = Ciq::new(CiqOptions { tol: 1e-10, ..Default::default() });
    for &n in ns {
        let nn = n * n;
        let cap = ((1usize << 22) / nn).max(1);
        let reps = if n >= 256 { 1 } else { 3 };
        let sample_max = batches.iter().copied().max().unwrap_or(1).min(cap);
        // one SPD ensemble per N, reused across batch sizes
        let mut a_stack = vec![0.0; sample_max * nn];
        for i in 0..sample_max {
            let a = Matrix::randn(n, n, rng);
            let mut k = a.matmul(&a.transpose());
            for d in 0..n {
                k[(d, d)] += n as f64 * 0.5;
            }
            a_stack[i * nn..(i + 1) * nn].copy_from_slice(k.as_slice());
        }
        let xs: Vec<f64> = (0..sample_max * n).map(|_| rng.normal()).collect();
        // per-operator Krylov reference: cached-bounds context, warm
        // workspace, one single-RHS solve per request
        let op = DenseOp::new(Matrix::from_vec(n, n, a_stack[..nn].to_vec()));
        let ctx = solver.build_context(&op, &SolverPolicy::CachedBounds).expect("ctx");
        let mut kws = SolveWorkspace::new();
        let b = &xs[..n];
        for _ in 0..2 {
            let res = solver.solve_in(&mut kws, &op, b, SolveKind::InvSqrt, &ctx).expect("warm");
            kws.give_vec(res.solution);
        }
        let t_krylov_req = common::bench_median(reps, || {
            let res = solver.solve_in(&mut kws, &op, b, SolveKind::InvSqrt, &ctx).expect("solve");
            kws.give_vec(res.solution);
        });
        for &batch in batches {
            let sample = batch.min(cap);
            let scale = batch as f64 / sample as f64;
            let mut stack = DenseFactorStack::new(n, sample);
            let mut ws = SolveWorkspace::new();
            let t_build = common::bench_median(reps, || {
                newton_schulz_stack_in(&mut ws, n, sample, &a_stack[..sample * nn], &opts, &mut stack);
            });
            assert!(stack.all_converged(), "bench ensemble must converge (N={n})");
            let mut ys = vec![0.0; sample * n];
            let t_apply = common::bench_median(reps, || {
                ys.fill(0.0);
                gemv_nn_batched(sample, n, &stack.invsqrt[..sample * nn], &xs[..sample * n], &mut ys);
            });
            let build_ms = t_build * scale * 1e3;
            let apply_ms = t_apply * scale * 1e3;
            let krylov_ms = t_krylov_req * batch as f64 * 1e3;
            let speedup = krylov_ms / apply_ms.max(1e-9);
            println!(
                "{n}\t{batch}\t{build_ms:.3}\t{apply_ms:.4}\t{krylov_ms:.3}\t{speedup:.1}x"
            );
            entries.push(format!(
                "    {{\"n\": {n}, \"batch\": {batch}, \"sample\": {sample}, \
                 \"build_ms\": {build_ms:.4}, \"apply_ms\": {apply_ms:.5}, \
                 \"krylov_ms\": {krylov_ms:.4}, \"dense_speedup\": {speedup:.2}}}"
            ));
            // the routing threshold: largest N whose steady-state apply
            // still beats the Krylov path at the widest batch in the sweep
            if batches.last() == Some(&batch) && apply_ms < krylov_ms && n > crossover_n {
                crossover_n = n;
            }
            // oracle check on one element per cell (cheap sizes only):
            // factors must match the exact eigendecomposition square root
            if n <= 256 {
                let m = Matrix::from_vec(n, n, a_stack[..nn].to_vec());
                let exact = eigen::spd_sqrt(&m).expect("oracle");
                let got = stack.sqrt_mat(0);
                let (mut num, mut den) = (0.0f64, 0.0f64);
                for (g, e) in got.iter().zip(exact.as_slice()) {
                    num += (g - e) * (g - e);
                    den += e * e;
                }
                ns_accuracy = ns_accuracy.max((num / den.max(1e-300)).sqrt());
            }
        }
    }
    let json = format!(
        "{{\n  \"schema\": \"ciq.bench.batched_dense.v1\",\n  \"config\": {{\"fast\": {fast}, \
         \"threads\": {}, \"ns\": {ns:?}, \"batches\": {batches:?}, \"tol\": {:.0e}}},\n  \
         \"entries\": [\n{}\n  ],\n  \"crossover_n\": {crossover_n}\n}}\n",
        num_threads(),
        opts.tol,
        entries.join(",\n")
    );
    std::fs::write("BENCH_batched_dense.json", json).expect("write BENCH_batched_dense.json");
    println!("wrote BENCH_batched_dense.json ({} entries, crossover_n = {crossover_n})", entries.len());
    checks.push((
        "batched Newton–Schulz matches the eigen K^{1/2} oracle (1e-8)".into(),
        ns_accuracy < 1e-8,
    ));
    checks.push((
        "dense tier beats per-operator Krylov at the smallest N".into(),
        crossover_n >= 16,
    ));
}

/// §10: the observability layer's hot-path cost, in ns/event — the numbers
/// behind the `obs/` cost contract (DESIGN.md §8):
///
/// - `branch_baseline` — a plain relaxed `AtomicBool` load + branch, the
///   target the disabled path is gated against;
/// - `trace_disabled` — a `trace!` site with recording off (the contract:
///   one relaxed load, no timestamp, no TLS, no payload evaluation);
/// - `trace_enabled` — a full recorder write: clock read + seqlock publish
///   into the thread's pre-registered ring;
/// - `hist_record` — one lock-free histogram record (4 relaxed RMWs), the
///   completion path's per-request telemetry cost;
/// - `mutex_vec_push` — the retired `Mutex<Vec<u64>>` latency storage this
///   PR replaced (lock + push per event, pre-grown so realloc is excluded —
///   the comparison is against its *best* case).
///
/// Writes `BENCH_obs.json` into the CWD (uploaded by the CI bench-smoke
/// job next to the other JSONs). The gating check is the cost contract:
/// disabled `trace!` within noise of the plain branch.
fn bench_obs(fast: bool, checks: &mut Checks) {
    use ciq::obs::hist::AtomicHistogram;
    use ciq::obs::trace::{self, EventKind};
    use std::hint::black_box;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Mutex;

    let events: usize = if fast { 200_000 } else { 2_000_000 };
    let reps = if fast { 3 } else { 5 };
    println!("# perf 10: observability hot path ({events} events/rep)");
    println!("op\tns_per_event");
    let per_ns = |t: f64| t / events as f64 * 1e9;

    // the contract target: one relaxed atomic load + branch, same loop shape
    // as the trace! sites below (black_box pins the loop counter in both)
    static FLAG: AtomicBool = AtomicBool::new(false);
    let t_branch = common::bench_median(reps, || {
        for i in 0..events {
            if black_box(&FLAG).load(Ordering::Relaxed) {
                black_box(i);
            }
            black_box(i);
        }
    });

    trace::set_enabled(false);
    let t_disabled = common::bench_median(reps, || {
        for i in 0..events {
            ciq::trace!(EventKind::Enqueue, i, 0u64);
            black_box(i);
        }
    });

    trace::set_enabled(true);
    ciq::trace!(EventKind::Enqueue, 0u64, 0u64); // register this thread's ring
    let t_enabled = common::bench_median(reps, || {
        for i in 0..events {
            ciq::trace!(EventKind::Enqueue, i, 1u64);
            black_box(i);
        }
    });
    trace::set_enabled(false);

    let hist = AtomicHistogram::new();
    let t_hist = common::bench_median(reps, || {
        for i in 0..events {
            hist.record(black_box((i & 0xFFFF) as u64));
        }
    });

    // the retired storage, best case: pre-grown Vec, uncontended lock
    let vec: Mutex<Vec<u64>> = Mutex::new(Vec::with_capacity(events));
    let t_mutex_vec = common::bench_median(reps, || {
        vec.lock().unwrap().clear();
        for i in 0..events {
            vec.lock().unwrap().push(black_box((i & 0xFFFF) as u64));
        }
    });

    let rows = [
        ("branch_baseline", t_branch),
        ("trace_disabled", t_disabled),
        ("trace_enabled", t_enabled),
        ("hist_record", t_hist),
        ("mutex_vec_push", t_mutex_vec),
    ];
    let mut entries: Vec<String> = Vec::new();
    for (op, t) in rows {
        println!("{op}\t{:.2}", per_ns(t));
        entries.push(format!("    {{\"op\": \"{op}\", \"ns_per_event\": {:.3}}}", per_ns(t)));
    }
    let json = format!(
        "{{\n  \"schema\": \"ciq.bench.obs.v1\",\n  \"config\": {{\"fast\": {fast}, \
         \"events_per_rep\": {events}, \"reps\": {reps}, \"threads\": {}}},\n  \
         \"entries\": [\n{}\n  ]\n}}\n",
        num_threads(),
        entries.join(",\n")
    );
    std::fs::write("BENCH_obs.json", json).expect("write BENCH_obs.json");
    println!("wrote BENCH_obs.json ({} entries)", entries.len());
    // the cost contract: a disabled trace! site is the relaxed-load branch —
    // allow 2x + 1 ns/event for timing noise at sub-ns magnitudes
    checks.push((
        "disabled trace! within noise of a plain relaxed-load branch".into(),
        per_ns(t_disabled) <= 2.0 * per_ns(t_branch) + 1.0,
    ));
    checks.push((
        "enabled trace! stays under 1 us/event".into(),
        per_ns(t_enabled) < 1_000.0,
    ));
    checks.push((
        "lock-free histogram record stays under 1 us/event".into(),
        per_ns(t_hist) < 1_000.0,
    ));
}

/// §9: the runtime-dispatched SIMD micro-kernel engine vs the forced-scalar
/// fallback, measured through the *public* entry points so the dispatch
/// overhead (one fn-pointer load per call) is part of the number. Three ops
/// per size: `gemm_nn` on the coordinator's panel shape (`m=N, k=256, n=8`),
/// the kernel operator's full matvec (distance panel + ρ + contraction), and
/// the ρ panel evaluator alone — lane-parallel polynomial `exp` vs the
/// per-element glibc path (`rho_row_scalar`), reported per element. Writes
/// `BENCH_simd.json` into the CWD (uploaded by the CI bench-smoke job next
/// to the other JSONs). The forced-scalar side doubles as the bit-exactness
/// regression surface: `CIQ_SIMD=scalar` runs the verbatim pre-dispatch
/// kernels.
fn bench_simd(fast: bool, rng: &mut Pcg64, checks: &mut Checks) {
    use ciq::linalg::gemm;
    use ciq::linalg::simd::{self, Backend, RhoFamily};

    let best = simd::best_available();
    let ns: &[usize] = if fast { &[1024] } else { &[1024, 4096, 16384] };
    let reps = if fast { 3 } else { 5 };
    println!(
        "# perf 9: SIMD dispatch (scalar vs {}, detected backends: {})",
        best.name(),
        Backend::all()
            .iter()
            .filter(|b| b.available())
            .map(|b| b.name())
            .collect::<Vec<_>>()
            .join("/")
    );
    println!("n\top\tscalar_ms\tsimd_ms\tspeedup");
    let mut entries: Vec<String> = Vec::new();
    let mut max_rel = 0.0f64;
    let mut gemm_speedup_4096 = f64::NAN;
    let mut rho_speedup_4096 = f64::NAN;
    let mut worst_speedup = f64::INFINITY;
    for &n in ns {
        // — gemm_nn on the panel shape the solve stack actually runs —
        let (kdim, r) = (256usize, 8usize);
        let a: Vec<f64> = (0..n * kdim).map(|_| rng.normal()).collect();
        let bm: Vec<f64> = (0..kdim * r).map(|_| rng.normal()).collect();
        let mut c = vec![0.0; n * r];
        simd::set_backend(Backend::Scalar).expect("scalar always available");
        let t_scalar = common::bench_median(reps, || {
            c.fill(0.0);
            gemm::gemm_nn(n, kdim, r, &a, &bm, &mut c);
        });
        let c_ref = c.clone();
        simd::set_backend(best).expect("best_available must be available");
        let t_simd = common::bench_median(reps, || {
            c.fill(0.0);
            gemm::gemm_nn(n, kdim, r, &a, &bm, &mut c);
        });
        for (got, want) in c.iter().zip(&c_ref) {
            max_rel = max_rel.max((got - want).abs() / (1.0 + want.abs()));
        }
        let mut push = |op: &str, t_s: f64, t_v: f64, extra: String| {
            let speedup = t_s / t_v.max(1e-12);
            println!("{n}\t{op}\t{:.3}\t{:.3}\t{speedup:.2}x", t_s * 1e3, t_v * 1e3);
            entries.push(format!(
                "    {{\"n\": {n}, \"op\": \"{op}\", \"scalar_ms\": {:.4}, \
                 \"simd_ms\": {:.4}, \"speedup\": {speedup:.3}{extra}}}",
                t_s * 1e3,
                t_v * 1e3
            ));
            speedup
        };
        let s = push("gemm_nn_m_n_k256_r8", t_scalar, t_simd, String::new());
        worst_speedup = worst_speedup.min(s);
        if n == 4096 {
            gemm_speedup_4096 = s;
        }

        // — full kernel matvec: distance GEMM + ρ panel + contraction —
        let x = Matrix::randn(n, 4, rng);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 1e-1);
        let mvm_reps = if n >= 16384 { 2 } else { reps };
        simd::set_backend(Backend::Scalar).expect("scalar always available");
        let t_scalar = common::bench_median(mvm_reps, || {
            let _ = op.matvec(&v);
        });
        let y_ref = op.matvec(&v);
        simd::set_backend(best).expect("best_available must be available");
        let t_simd = common::bench_median(mvm_reps, || {
            let _ = op.matvec(&v);
        });
        let y = op.matvec(&v);
        for (got, want) in y.iter().zip(&y_ref) {
            max_rel = max_rel.max((got - want).abs() / (1.0 + want.abs()));
        }
        let s = push("kernel_matvec_d4_rbf", t_scalar, t_simd, String::new());
        worst_speedup = worst_speedup.min(s);

        // — ρ panel alone: lane-parallel exp vs per-element glibc exp —
        // `row` holds the dot products the distance GEMM would produce;
        // zeros make d2 = sq[j] exactly, spanning [0, ~4] like a unit-ℓ RBF.
        let sq: Vec<f64> = (0..n).map(|_| rng.normal().powi(2)).collect();
        let mut row = vec![0.0; n];
        let inner = ((1usize << 22) / n).max(1);
        let t_glibc = common::bench_median(reps, || {
            for _ in 0..inner {
                row.fill(0.0);
                simd::rho_row_scalar(RhoFamily::Rbf, 1.0, 0.0, &sq, &mut row);
            }
        });
        let row_ref = row.clone();
        let t_lane = common::bench_median(reps, || {
            for _ in 0..inner {
                row.fill(0.0);
                if let Some(t) = simd::table_for(best) {
                    (t.rho_row)(RhoFamily::Rbf, 1.0, 0.0, &sq, &mut row);
                } else {
                    simd::rho_row_scalar(RhoFamily::Rbf, 1.0, 0.0, &sq, &mut row);
                }
            }
        });
        for (got, want) in row.iter().zip(&row_ref) {
            max_rel = max_rel.max((got - want).abs() / (1.0 + want.abs()));
        }
        let per_elem = format!(
            ", \"glibc_ns_per_elem\": {:.2}, \"simd_ns_per_elem\": {:.2}",
            t_glibc / (inner * n) as f64 * 1e9,
            t_lane / (inner * n) as f64 * 1e9
        );
        let s = push("rho_panel_rbf", t_glibc, t_lane, per_elem);
        worst_speedup = worst_speedup.min(s);
        if n == 4096 {
            rho_speedup_4096 = s;
        }
    }
    simd::clear_backend_override();
    let json = format!(
        "{{\n  \"schema\": \"ciq.bench.simd.v1\",\n  \"config\": {{\"fast\": {fast}, \
         \"backend\": \"{}\", \"threads\": {}, \"reps\": {reps}, \
         \"gemm_shape\": \"m=N, k=256, n=8\"}},\n  \"entries\": [\n{}\n  ]\n}}\n",
        best.name(),
        num_threads(),
        entries.join(",\n")
    );
    std::fs::write("BENCH_simd.json", json).expect("write BENCH_simd.json");
    println!("wrote BENCH_simd.json ({} entries, backend = {})", entries.len(), best.name());
    checks.push((
        "dispatched kernels agree with forced-scalar (rel 1e-10)".into(),
        max_rel < 1e-10,
    ));
    if best == Backend::Scalar {
        println!("no SIMD backend detected — speedup gates skipped (scalar == scalar)");
        return;
    }
    // soft floor on every cell: dispatch must never cost real throughput
    checks.push((
        "dispatched kernels are never slower than 0.8x scalar".into(),
        worst_speedup > 0.8,
    ));
    if !fast {
        // the ISSUE acceptance numbers, measured at N=4096 in full mode
        checks.push((
            "dispatched gemm_nn >= 1.5x scalar at N=4096".into(),
            gemm_speedup_4096 >= 1.5,
        ));
        checks.push((
            "rho panel >= 2x glibc exp per element at N=4096".into(),
            rho_speedup_4096 >= 2.0,
        ));
    }
}

/// §11: the mixed-precision MVM engine — f32-storage kernel panels with f64
/// iterative refinement vs the pure-f64 solve, through the same
/// cached-bounds [`Ciq::solve_block_in`] entry point both policies serve
/// from (warm workspace on both sides, so the numbers are steady-state).
/// Per `N × kernel` cell the JSON records the two medians, the refinement
/// sweeps the mixed side spent, whether it fell back to f64, and the hybrid
/// rel error between the two solutions. The gates are correctness-only:
/// agreement, no fallback, and at least one sweep (proof the mixed path
/// actually ran) — the speedup itself is read off the committed JSON for
/// the target machine, because on hardware without wide-f32 SIMD lanes the
/// mixed tier's win is bandwidth, not a guaranteed ratio. Writes
/// `BENCH_mixed.json` into the CWD (uploaded by the CI bench-smoke job next
/// to the other JSONs).
fn bench_mixed(fast: bool, rng: &mut Pcg64, checks: &mut Checks) {
    let ns: &[usize] = if fast { &[512] } else { &[512, 2048] };
    let r = 8;
    let reps = if fast { 2 } else { 3 };
    let tol = 1e-6;
    let kinds: [(KernelType, &'static str); 2] =
        [(KernelType::Rbf, "rbf"), (KernelType::Matern32, "matern32")];
    println!("# perf 11: mixed-precision MVM engine (f32 storage + f64 refinement vs pure f64)");
    println!("n\tkernel\tf64_ms\tmixed_ms\tspeedup\tsweeps\trel_err");
    let f64_solver = Ciq::new(CiqOptions { tol, ..Default::default() });
    let mixed_solver = Ciq::new(CiqOptions {
        tol,
        precision: Precision::Mixed(RefineConfig::default()),
        ..Default::default()
    });
    let mut entries: Vec<String> = Vec::new();
    let mut max_rel = 0.0f64;
    let mut any_fallback = false;
    let mut min_sweeps = usize::MAX;
    for &n in ns {
        let x = Matrix::randn(n, 4, rng);
        let b = Matrix::randn(n, r, rng);
        for (kind, kname) in kinds {
            let op = KernelOp::new(&x, kind, 1.0, 1.0, 1e-1);
            let ctx64 =
                f64_solver.build_context(&op, &SolverPolicy::CachedBounds).expect("f64 ctx");
            let ctx32 =
                mixed_solver.build_context(&op, &SolverPolicy::CachedBounds).expect("mixed ctx");
            let mut ws = SolveWorkspace::new();
            // harvest pass: agreement + telemetry, doubling as the warm-up
            let res64 = f64_solver
                .solve_block_in(&mut ws, &op, &b, SolveKind::InvSqrt, &ctx64)
                .expect("f64 solve");
            let resmx = mixed_solver
                .solve_block_in(&mut ws, &op, &b, SolveKind::InvSqrt, &ctx32)
                .expect("mixed solve");
            let mut rel = 0.0f64;
            for j in 0..r {
                for i in 0..n {
                    let (g, w) = (resmx.solution[(i, j)], res64.solution[(i, j)]);
                    rel = rel.max((g - w).abs() / (1.0 + w.abs()));
                }
            }
            max_rel = max_rel.max(rel);
            let fallback = resmx.precision_fallback;
            any_fallback |= fallback;
            min_sweeps = min_sweeps.min(resmx.refine_sweeps);
            let sweeps = resmx.refine_sweeps;
            recycle_block_result(&mut ws, res64);
            recycle_block_result(&mut ws, resmx);
            let t64 = common::bench_median(reps, || {
                let res = f64_solver
                    .solve_block_in(&mut ws, &op, &b, SolveKind::InvSqrt, &ctx64)
                    .expect("f64 solve");
                recycle_block_result(&mut ws, res);
            });
            let tmx = common::bench_median(reps, || {
                let res = mixed_solver
                    .solve_block_in(&mut ws, &op, &b, SolveKind::InvSqrt, &ctx32)
                    .expect("mixed solve");
                recycle_block_result(&mut ws, res);
            });
            let speedup = t64 / tmx.max(1e-12);
            println!(
                "{n}\t{kname}\t{:.2}\t{:.2}\t{speedup:.2}x\t{sweeps}\t{rel:.2e}",
                t64 * 1e3,
                tmx * 1e3
            );
            entries.push(format!(
                "    {{\"n\": {n}, \"kernel\": \"{kname}\", \"f64_ms\": {:.4}, \
                 \"mixed_ms\": {:.4}, \"speedup\": {speedup:.3}, \
                 \"refine_sweeps\": {sweeps}, \"fallback\": {fallback}, \
                 \"rel_err\": {rel:.3e}}}",
                t64 * 1e3,
                tmx * 1e3
            ));
        }
    }
    let json = format!(
        "{{\n  \"schema\": \"ciq.bench.mixed.v1\",\n  \"config\": {{\"fast\": {fast}, \
         \"threads\": {}, \"reps\": {reps}, \"rhs\": {r}, \"tol\": {tol:.0e}}},\n  \
         \"entries\": [\n{}\n  ]\n}}\n",
        num_threads(),
        entries.join(",\n")
    );
    std::fs::write("BENCH_mixed.json", json).expect("write BENCH_mixed.json");
    println!("wrote BENCH_mixed.json ({} entries)", entries.len());
    checks.push((
        "mixed solve agrees with the f64 solve (hybrid 1e-4 at tol 1e-6)".into(),
        max_rel < 1e-4,
    ));
    checks.push((
        "mixed tier never fell back on the well-conditioned bench kernels".into(),
        !any_fallback,
    ));
    checks.push((
        "mixed tier refined (>= 1 sweep per solve, proof the f32 path ran)".into(),
        min_sweeps >= 1,
    ));
}

//! Fig. 5 / Sec. 5.3: Gibbs-sampling image super-resolution. Reports
//! reconstruction error and sampler throughput, with an *estimated* Cholesky
//! throughput for comparison (the paper estimates 0.05 samples/s vs CIQ's
//! 0.61 at 25,600 dims — Cholesky on the dense precision is infeasible to
//! run outright, which is the point).
//!
//! Run: `cargo bench --bench fig5_gibbs [-- --n 48 --samples 40]`
//! Paper scale: `--n 160` reproduces the 25,600-dimensional setting.

#[path = "common/mod.rs"]
mod common;

use ciq::gibbs::{reconstruct, GibbsConfig};
use ciq::linalg::{Cholesky, Matrix};
use ciq::rng::Pcg64;
use ciq::util::cli::Args;

fn main() {
    let args = Args::parse();
    let n = args.get_or("n", 48usize);
    let samples = args.get_or("samples", 40usize);
    let burn_in = args.get_or("burn-in", 15usize);

    let cfg = GibbsConfig { n, samples, burn_in, ..Default::default() };
    let dim = n * n;
    println!("# Fig. 5: Gibbs super-resolution, latent dim {dim}");
    let res = reconstruct(&cfg, args.get_or("seed", 1u64)).expect("gibbs");
    let ciq_rate = 1.0 / res.seconds_per_sample.max(1e-12);

    // estimate dense-Cholesky throughput: time an n0³ factorization and
    // extrapolate cubically to dim³ (+ the dense matrix build, ignored —
    // generous to Cholesky)
    let n0 = 600usize.min(dim);
    let mut rng = Pcg64::seeded(9);
    let a = Matrix::randn(n0, 12, &mut rng);
    let mut k0 = a.matmul(&a.transpose());
    for i in 0..n0 {
        k0[(i, i)] += n0 as f64;
    }
    let t_chol0 = common::bench_median(3, || {
        let _ = Cholesky::with_jitter(&k0, 0.0).expect("chol");
    });
    let t_chol_est = t_chol0 * (dim as f64 / n0 as f64).powi(3);
    let chol_rate_est = 1.0 / t_chol_est;

    println!("method\tsamples_per_s\trmse\tmean_ciq_iters");
    println!("CIQ\t{ciq_rate:.3}\t{:.4}\t{:.0}", res.rmse, res.mean_ciq_iters);
    println!("Cholesky(est)\t{chol_rate_est:.4}\t-\t-");
    println!(
        "# speedup over estimated Cholesky: {:.1}x (paper: ~12x at 25.6k dims)",
        ciq_rate / chol_rate_est
    );
    let tail = &res.gamma_obs_trace[burn_in..];
    println!(
        "# posterior gamma_obs mean {:.0} (generative truth {})",
        ciq::util::mean(tail),
        cfg.gamma_obs_true
    );

    common::shape_check("CIQ sampler faster than estimated Cholesky (Fig. 5)", ciq_rate > chol_rate_est);
    common::shape_check("reconstruction is usable (rmse < 0.3)", res.rmse < 0.3);
}

//! Fig. S3: msMINRES iterations needed for a 1e-4 residual vs matrix size,
//! for pivoted-Cholesky preconditioner ranks {0, low, high}, on random RBF
//! and Matérn-5/2 kernels.
//!
//! Paper shape: iterations grow with N without preconditioning; rank-100 /
//! rank-400 preconditioners cut them by ~2x / ~4x.
//!
//! Run: `cargo bench --bench figs3_precond_iters [-- --sizes 400,800,1600 --ranks 0,40,120]`

#[path = "common/mod.rs"]
mod common;

use ciq::ciq::precond::WhitenedOp;
use ciq::ciq::{Ciq, CiqOptions};
use ciq::krylov::msminres::{msminres, MsMinresOptions};
use ciq::linalg::Matrix;
use ciq::operators::{KernelOp, KernelType};
use ciq::precond::PivotedCholesky;
use ciq::rng::Pcg64;
use ciq::util::cli::Args;

fn main() {
    let args = Args::parse();
    let sizes = args.get_list("sizes", &[400usize, 800, 1200]);
    let ranks = args.get_list("ranks", &[0usize, 40, 120]);
    let noise = args.get_or("noise", 1e-3f64);
    let mut rng = Pcg64::seeded(args.get_or("seed", 4u64));

    println!("# Fig. S3: msMINRES iterations to 1e-4 residual");
    println!("kernel\tN\trank\titers");
    let mut iter_table: Vec<(String, usize, usize, usize)> = Vec::new();
    for kind in [KernelType::Rbf, KernelType::Matern52] {
        let kname = format!("{kind:?}").to_lowercase();
        for &n in &sizes {
            let x = Matrix::randn(n, 1, &mut rng);
            let op = KernelOp::new(&x, kind, 1.0, 1.0, noise);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let solver = Ciq::new(CiqOptions { q_points: 8, tol: 1e-4, max_iters: 1500, ..Default::default() });
            for &rank in &ranks {
                let iters = if rank == 0 {
                    let (rule, _) = solver.rule(&op, None).expect("rule");
                    msminres(&op, &b, &rule.shifts, &MsMinresOptions { max_iters: 1500, tol: 1e-4, weights: None })
                        .iterations
                } else {
                    let pc = PivotedCholesky::new(&op, rank, noise, 1e-14).expect("pc");
                    let m = WhitenedOp::new(&op, &pc);
                    let (rule, _) = solver.rule(&m, None).expect("rule");
                    msminres(&m, &b, &rule.shifts, &MsMinresOptions { max_iters: 1500, tol: 1e-4, weights: None })
                        .iterations
                };
                println!("{kname}\t{n}\t{rank}\t{iters}");
                iter_table.push((kname.clone(), n, rank, iters));
            }
        }
    }
    // shape: at the largest N, preconditioning reduces iterations monotonically
    let n_hi = *sizes.last().unwrap();
    let ok = [KernelType::Rbf, KernelType::Matern52].iter().all(|kind| {
        let kname = format!("{kind:?}").to_lowercase();
        let mut prev = usize::MAX;
        ranks.iter().all(|&r| {
            let it = iter_table
                .iter()
                .find(|row| row.0 == kname && row.1 == n_hi && row.2 == r)
                .unwrap()
                .3;
            let ok = it <= prev.saturating_add(5);
            prev = it;
            ok
        })
    });
    common::shape_check("higher rank => fewer iterations (Fig. S3)", ok);
}

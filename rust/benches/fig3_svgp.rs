//! Fig. 3 / S5 / S6: SVGP accuracy and speed vs inducing-point count, CIQ vs
//! Cholesky backends, on the three dataset/likelihood pairs (Gaussian,
//! Student-T, Bernoulli).
//!
//! Paper shape: NLL and error improve with M; the two backends match in
//! accuracy; CIQ's per-step time scales better at large M; the Student-T
//! noise estimate shrinks as M grows (Fig. S6).
//!
//! Run: `cargo bench --bench fig3_svgp [-- --n 2000 --ms 32,64,128 --steps 40]`

#[path = "common/mod.rs"]
mod common;

use ciq::ciq::CiqOptions;
use ciq::data;
use ciq::operators::KernelType;
use ciq::rng::Pcg64;
use ciq::svgp::{evaluate, train, Backend, Bernoulli, Gaussian, Likelihood, StudentT, Svgp, SvgpHyper};
use ciq::util::cli::Args;

fn main() {
    let args = Args::parse();
    let n = args.get_or("n", 1500usize);
    let ms = args.get_list("ms", &[32usize, 64, 128]);
    let steps = args.get_or("steps", 30usize);
    let batch = args.get_or("batch", 128usize);

    println!("# Fig. 3 / S5 / S6: SVGP across M, CIQ vs Cholesky");
    println!("dataset\tbackend\tM\tNLL\terror\tms_per_step\tlik_params");
    let mut rows: Vec<(String, String, usize, f64, f64, f64)> = Vec::new();
    let mut student_noise: Vec<(usize, f64)> = Vec::new();

    let datasets: Vec<(data::Dataset, &str)> = vec![
        (data::gaussian_regression(n, 2, 0.1, 11), "gaussian"),
        (data::student_t_regression(n, 3, 0.2, 4.0, 12), "student_t"),
        (data::binary_classification(n, 4, 0.08, 13), "bernoulli"),
    ];
    for (ds, likname) in &datasets {
        let mut rng = Pcg64::seeded(17);
        let (train_set, test_set) = ds.split(0.8, &mut rng);
        for &m in &ms {
            for backend_name in ["cholesky", "ciq"] {
                let backend = if backend_name == "cholesky" {
                    Backend::Cholesky
                } else {
                    Backend::Ciq(CiqOptions { tol: 1e-4, max_iters: 200, ..Default::default() })
                };
                let lik: Box<dyn Likelihood> = match *likname {
                    "gaussian" => Box::new(Gaussian { noise: 0.1 }),
                    "student_t" => Box::new(StudentT { nu: 5.0, scale2: 0.1 }),
                    _ => Box::new(Bernoulli),
                };
                let mut rng_run = Pcg64::seeded(23);
                let z = train_set.kmeans_centers(m, 5, &mut rng_run);
                let mut model = Svgp::new(
                    z,
                    KernelType::Rbf,
                    SvgpHyper { lengthscale: 0.2, outputscale: 1.0, jitter: 1e-4 },
                    lik,
                    backend,
                );
                let stats =
                    train(&mut model, &train_set, steps, batch, 0.5, 0.02, &mut rng_run).expect("train");
                let metrics = evaluate(&mut model, &test_set).expect("eval");
                let ms_step = 1000.0 * stats.seconds / steps as f64;
                let lik_params: Vec<String> =
                    model.lik.log_params().iter().map(|p| format!("{:.3}", p.exp())).collect();
                println!(
                    "{likname}\t{backend_name}\t{m}\t{:.4}\t{:.4}\t{ms_step:.1}\t[{}]",
                    metrics.nll,
                    metrics.error,
                    lik_params.join(",")
                );
                rows.push((likname.to_string(), backend_name.to_string(), m, metrics.nll, metrics.error, ms_step));
                if *likname == "student_t" && backend_name == "ciq" {
                    if let Some(p0) = model.lik.log_params().first() {
                        let _ = p0;
                    }
                    if model.lik.log_params().len() == 2 {
                        student_noise.push((m, model.lik.log_params()[1].exp()));
                    }
                }
            }
        }
    }

    // shape checks
    let nll_at = |lik: &str, be: &str, m: usize| {
        rows.iter().find(|r| r.0 == lik && r.1 == be && r.2 == m).map(|r| r.3).unwrap()
    };
    let (m_lo, m_hi) = (ms[0], *ms.last().unwrap());
    // Student-T gets a wider margin: at this abbreviated step budget larger-M
    // models are undertrained and the heavy-tailed NLL is noisy (the paper
    // trains 20 epochs; both backends show the identical drift, so it is a
    // budget artifact, not a CIQ-vs-Cholesky difference).
    let margin = |lik: &str| if lik == "student_t" { 0.3 } else { 0.05 };
    let improves = ["gaussian", "student_t", "bernoulli"]
        .iter()
        .all(|lik| nll_at(lik, "ciq", m_hi) <= nll_at(lik, "ciq", m_lo) + margin(lik));
    common::shape_check("NLL improves (or holds) with M (Fig. 3)", improves);
    let agree = ["gaussian", "student_t", "bernoulli"].iter().all(|lik| {
        (nll_at(lik, "ciq", m_hi) - nll_at(lik, "cholesky", m_hi)).abs() < 0.3
    });
    common::shape_check("CIQ matches Cholesky accuracy (Fig. 3)", agree);
}

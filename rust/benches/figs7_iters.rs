//! Fig. S7: histogram of msMINRES iterations needed during SVGP training.
//!
//! Paper shape: almost all calls converge in < 100 iterations (M = 5,000
//! there); the shifted systems are better conditioned than K_ZZ itself.
//!
//! Run: `cargo bench --bench figs7_iters [-- --n 2000 --m 128 --steps 30]`

#[path = "common/mod.rs"]
mod common;

use ciq::ciq::CiqOptions;
use ciq::data::gaussian_regression;
use ciq::operators::KernelType;
use ciq::rng::Pcg64;
use ciq::svgp::{train, Backend, Gaussian, Svgp, SvgpHyper};
use ciq::util::cli::Args;

fn main() {
    let args = Args::parse();
    let n = args.get_or("n", 1500usize);
    let m = args.get_or("m", 128usize);
    let steps = args.get_or("steps", 30usize);

    let ds = gaussian_regression(n, 2, 0.1, 21);
    let mut rng = Pcg64::seeded(22);
    let z = ds.kmeans_centers(m, 5, &mut rng);
    let mut model = Svgp::new(
        z,
        KernelType::Rbf,
        SvgpHyper { lengthscale: 0.2, outputscale: 1.0, jitter: 1e-4 },
        Box::new(Gaussian { noise: 0.1 }),
        Backend::Ciq(CiqOptions { tol: 1e-3, max_iters: 200, ..Default::default() }),
    );
    train(&mut model, &ds, steps, 128, 0.5, 0.02, &mut rng).expect("train");

    let iters = &model.iteration_log;
    println!("# Fig. S7: msMINRES iterations during SVGP training (M={m}, {} calls)", iters.len());
    println!("bucket\tcount");
    let bucket = 10usize;
    let mut hist = std::collections::BTreeMap::<usize, usize>::new();
    for &it in iters {
        *hist.entry(it / bucket * bucket).or_default() += 1;
    }
    for (b, c) in &hist {
        println!("{b}-{}\t{c}", b + bucket - 1);
    }
    let mean = ciq::util::mean(&iters.iter().map(|&v| v as f64).collect::<Vec<_>>());
    let frac_small = iters.iter().filter(|&&v| v < 150).count() as f64 / iters.len() as f64;
    println!("# mean iterations {mean:.1}; fraction <150: {frac_small:.3}");
    common::shape_check("most calls converge quickly (Fig. S7)", frac_small > 0.9);
    common::shape_check("telemetry populated", !iters.is_empty());
}

//! Shared helpers for the self-timed bench harnesses (criterion is not
//! available offline; each bench prints the paper's rows as TSV plus a
//! PASS/FAIL shape check and exits non-zero on FAIL).

use ciq::baselines::rsvd::orthonormalize;
use ciq::linalg::Matrix;
use ciq::rng::Pcg64;

/// Random SPD matrix with the prescribed spectrum (orthogonal conjugation).
pub fn spd_with_spectrum(evals: &[f64], rng: &mut Pcg64) -> Matrix {
    let n = evals.len();
    let a = Matrix::randn(n, n, rng);
    let q = orthonormalize(&a);
    let mut scaled = q.clone();
    for j in 0..n {
        for i in 0..n {
            scaled[(i, j)] *= evals[j];
        }
    }
    scaled.matmul(&q.transpose())
}

/// The paper's Fig. 1 / S1 spectrum families.
pub fn spectrum(name: &str, n: usize) -> Vec<f64> {
    match name {
        "invsqrt" => (1..=n).map(|t| 1.0 / (t as f64).sqrt()).collect(),
        "inv" => (1..=n).map(|t| 1.0 / t as f64).collect(),
        "invsq" => (1..=n).map(|t| 1.0 / (t as f64).powi(2)).collect(),
        "exp" => (1..=n).map(|t| (-(t as f64) / (n as f64 / 8.0)).exp()).collect(),
        other => panic!("unknown spectrum {other}"),
    }
}

/// Report a PASS/FAIL shape check; exit non-zero on failure.
pub fn shape_check(label: &str, ok: bool) {
    if ok {
        println!("SHAPE CHECK [{label}]: PASS");
    } else {
        println!("SHAPE CHECK [{label}]: FAIL");
        std::process::exit(1);
    }
}

/// Median wall-clock seconds of `reps` runs of `f`.
pub fn bench_median(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    ciq::util::median(&times)
}

//! Fig. 2 (left): effect of pivoted-Cholesky preconditioning on msMINRES-CIQ
//! convergence, on an ill-conditioned GP posterior covariance from Bayesian
//! optimization of Hartmann-6.
//!
//! Paper shape: without preconditioning the residual stalls; higher-rank
//! preconditioners both accelerate convergence and lower the final residual.
//!
//! Run: `cargo bench --bench fig2_precond [-- --t 2000 --ranks 0,50,100]`

#[path = "common/mod.rs"]
mod common;

use ciq::bo::testfns::Hartmann6;
use ciq::bo::Problem;
use ciq::ciq::precond::WhitenedOp;
use ciq::ciq::{Ciq, CiqOptions};
use ciq::gp::{ExactGp, GpHyper};
use ciq::krylov::msminres::{msminres, MsMinresOptions};
use ciq::linalg::Matrix;
use ciq::operators::{KernelType, LinearOp, SubtractLowRankOp};
use ciq::precond::PivotedCholesky;
use ciq::rng::{Pcg64, Sobol};
use ciq::util::cli::Args;

fn main() {
    let args = Args::parse();
    let t = args.get_or("t", 1500usize);
    let ranks = args.get_list("ranks", &[0usize, 50, 100]);
    let n_train = args.get_or("train", 60usize);
    let mut rng = Pcg64::seeded(args.get_or("seed", 3u64));

    // exact-GP surrogate over Hartmann-6 evaluations (Sec. 5.2 setup)
    let problem = Hartmann6;
    let mut x = Matrix::zeros(n_train, 6);
    let mut y = Vec::new();
    let mut sobol = Sobol::new(6);
    for (i, p) in sobol.sample(n_train).into_iter().enumerate() {
        for j in 0..6 {
            x[(i, j)] = p[j];
        }
        y.push(problem.eval(&p));
    }
    let ym = ciq::util::mean(&y);
    let ys = ciq::util::std_dev(&y).max(1e-12);
    let y_std: Vec<f64> = y.iter().map(|v| (v - ym) / ys).collect();
    let mut gp = ExactGp::new(
        x,
        y_std,
        KernelType::Matern52,
        GpHyper { lengthscale: 0.3, outputscale: 1.0, noise: 1e-4 },
    );
    gp.fit_hypers(15, 0.1).expect("fit");

    // the N = t posterior covariance (paper: 50k; default scaled for CPU)
    let mut cands = Matrix::zeros(t, 6);
    let mut sob = Sobol::new(6);
    for (i, p) in sob.sample(t).into_iter().enumerate() {
        for j in 0..6 {
            cands[(i, j)] = p[j];
        }
    }
    let (kss, w) = gp.posterior_cov_parts(&cands, 1e-4).expect("cov");
    let cov = SubtractLowRankOp::new(&kss, w);
    let b: Vec<f64> = (0..t).map(|_| rng.normal()).collect();

    let solver = Ciq::new(CiqOptions { q_points: 8, tol: 1e-10, max_iters: 200, ..Default::default() });
    println!("# Fig. 2 (left): residual vs iteration, N={t} Hartmann-6 posterior covariance");
    println!("rank\titer\tresidual");
    let mut final_res: Vec<(usize, f64)> = Vec::new();
    for &rank in &ranks {
        let history = if rank == 0 {
            let (rule, _) = solver.rule(&cov, None).expect("rule");
            let ms = msminres(
                &cov,
                &b,
                &rule.shifts,
                &MsMinresOptions { max_iters: 200, tol: 1e-10, weights: None },
            );
            ms.residual_history
        } else {
            let pc = PivotedCholesky::new(&cov, rank, 1e-4, 1e-14).expect("precond");
            let m = WhitenedOp::new(&cov, &pc);
            let (rule, _) = solver.rule(&m, None).expect("rule");
            let ms = msminres(
                &m,
                &b,
                &rule.shifts,
                &MsMinresOptions { max_iters: 200, tol: 1e-10, weights: None },
            );
            ms.residual_history
        };
        for (i, r) in history.iter().enumerate().step_by(10) {
            println!("{rank}\t{i}\t{r:.3e}");
        }
        final_res.push((rank, *history.last().unwrap_or(&1.0)));
        println!("{rank}\tfinal\t{:.3e}", final_res.last().unwrap().1);
    }
    // shape: preconditioning lowers the final residual monotonically in rank
    let ok = final_res.windows(2).all(|w| w[1].1 <= w[0].1 * 1.5);
    common::shape_check("preconditioning lowers final residual (Fig. 2 left)", ok);
    let big_gain = final_res.last().unwrap().1 < final_res[0].1;
    common::shape_check("highest rank strictly better than none", big_gain);
}

//! Fig. S4: empirical-covariance error of sampling methods — Cholesky,
//! msMINRES-CIQ, and 1,000-feature RFF — on RBF kernel matrices built from
//! Protein/Kin40k-like synthetic feature data.
//!
//! Paper shape: CIQ and Cholesky have nearly identical empirical-covariance
//! error (pure Monte-Carlo error); RFF incurs up to ~2x more.
//!
//! Run: `cargo bench --bench figs4_cov_error [-- --n 256 --samples 500]`

#[path = "common/mod.rs"]
mod common;

use ciq::baselines::RandomFourierFeatures;
use ciq::ciq::{Ciq, CiqOptions};
use ciq::linalg::{Cholesky, Matrix};
use ciq::operators::{KernelOp, KernelType, LinearOp};
use ciq::rng::Pcg64;
use ciq::util::cli::Args;

fn empirical_cov_err(samples: &[Vec<f64>], k: &Matrix) -> f64 {
    let n = k.rows();
    let mut acc = Matrix::zeros(n, n);
    let reps = samples.len() as f64;
    for s in samples {
        for i in 0..n {
            for j in 0..n {
                acc[(i, j)] += s[i] * s[j] / reps;
            }
        }
    }
    (&acc - k).fro_norm() / k.fro_norm()
}

fn main() {
    let args = Args::parse();
    let n = args.get_or("n", 256usize);
    let reps = args.get_or("samples", 500usize);
    let d = args.get_or("d", 6usize);
    let mut rng = Pcg64::seeded(args.get_or("seed", 8u64));

    println!("# Fig. S4: empirical covariance error from {reps} samples (N={n})");
    println!("dataset\tmethod\trel_err");
    let mut results: Vec<(String, f64)> = Vec::new();
    for (dsname, ell) in [("protein-like", 2.0), ("kin40k-like", 1.2)] {
        let x = Matrix::randn(n, d, &mut rng);
        let op = KernelOp::new(&x, KernelType::Rbf, ell, 1.0, 1e-2);
        let k = op.to_dense();

        // Cholesky samples
        let chol = Cholesky::with_jitter(&k, 1e-10).expect("chol");
        let chol_samples: Vec<Vec<f64>> =
            (0..reps).map(|_| chol.sample_mvm(&rng.normal_vec(n))).collect();
        let e_chol = empirical_cov_err(&chol_samples, &k);

        // CIQ samples (bounds reused across draws)
        let solver = Ciq::new(CiqOptions { q_points: 8, tol: 1e-5, max_iters: 400, ..Default::default() });
        let bounds = solver.bounds(&op).expect("bounds");
        let ciq_samples: Vec<Vec<f64>> = (0..reps)
            .map(|_| solver.sqrt_with_bounds(&op, &rng.normal_vec(n), Some(bounds)).expect("ciq").solution)
            .collect();
        let e_ciq = empirical_cov_err(&ciq_samples, &k);

        // RFF samples (1,000 features, as in the paper)
        let rff = RandomFourierFeatures::new(d, 1000, ell, 1.0, &mut rng);
        let rff_samples: Vec<Vec<f64>> = (0..reps).map(|_| rff.prior_sample(&x, &mut rng)).collect();
        let e_rff = empirical_cov_err(&rff_samples, &k);

        for (m, e) in [("cholesky", e_chol), ("ciq", e_ciq), ("rff", e_rff)] {
            println!("{dsname}\t{m}\t{e:.4}");
            results.push((format!("{dsname}/{m}"), e));
        }
    }
    let get = |s: &str| results.iter().filter(|r| r.0.ends_with(s)).map(|r| r.1).fold(0.0, f64::max);
    common::shape_check(
        "CIQ ≈ Cholesky empirical covariance (Fig. S4)",
        (get("/ciq") - get("/cholesky")).abs() < 0.35 * get("/cholesky"),
    );
    common::shape_check("RFF strictly worse (Fig. S4)", get("/rff") > get("/ciq"));
}

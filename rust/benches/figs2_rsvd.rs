//! Fig. S2: randomized-SVD relative error at computing `K^{1/2}b` vs rank,
//! on the same spectrum families as Fig. 1 — contrasted with CIQ at Q=8.
//!
//! Paper shape: randomized SVD plateaus around 0.25 relative error on
//! slowly-decaying spectra even at rank 1024, while CIQ reaches ~1e-4.
//!
//! Run: `cargo bench --bench figs2_rsvd [-- --n 512 --ranks 16,64,256]`

#[path = "common/mod.rs"]
mod common;

use ciq::baselines::RandomizedSvdSqrt;
use ciq::ciq::{Ciq, CiqOptions};
use ciq::linalg::eigen::spd_sqrt;
use ciq::operators::{DenseOp, LinearOp};
use ciq::rng::Pcg64;
use ciq::util::cli::Args;
use ciq::util::rel_err;

fn main() {
    let args = Args::parse();
    let n = args.get_or("n", 512usize);
    let ranks = args.get_list("ranks", &[16usize, 64, 256]);
    let mut rng = Pcg64::seeded(args.get_or("seed", 2u64));

    println!("# Fig. S2: randomized SVD error vs rank (CIQ Q=8 shown for contrast)");
    println!("family\tmethod\trank\trel_err");
    let mut slow_decay_best = f64::INFINITY;
    let mut ciq_slow = f64::INFINITY;
    for family in ["invsqrt", "inv", "invsq", "exp"] {
        let k = common::spd_with_spectrum(&common::spectrum(family, n), &mut rng);
        let exact_map = spd_sqrt(&k).expect("eig");
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = exact_map.matvec(&b);
        let op = DenseOp::new(k);
        for &rank in &ranks {
            let rs = RandomizedSvdSqrt::new(&op, rank, 2, &mut rng).expect("rsvd");
            let err = rel_err(&rs.sqrt_mvm(&b), &exact);
            println!("{family}\trsvd\t{rank}\t{err:.3e}");
            if family == "invsqrt" {
                slow_decay_best = slow_decay_best.min(err);
            }
        }
        let solver = Ciq::new(CiqOptions { q_points: 8, tol: 1e-6, ..Default::default() });
        let err = rel_err(&solver.sqrt_mvm(&op, &b).expect("ciq").solution, &exact);
        println!("{family}\tciq\tQ=8\t{err:.3e}");
        if family == "invsqrt" {
            ciq_slow = err;
        }
    }
    common::shape_check(
        "rsvd plateaus on slow decay (>5e-2, paper ~0.25)",
        slow_decay_best > 5e-2,
    );
    common::shape_check(
        "CIQ beats rsvd by >=100x on slow decay (Fig. S2 vs Fig. 1)",
        ciq_slow * 100.0 < slow_decay_best,
    );
}

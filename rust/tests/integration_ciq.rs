//! Integration tests for the full CIQ pipeline: statistical correctness of
//! sampling/whitening, preconditioning (including the unified
//! `SolverPolicy`/`SolverContext` path), and the backward pass — on kernel
//! operators (never materialized) rather than toy dense matrices.

use ciq::ciq::precond::WhitenedOp;
use ciq::ciq::{Ciq, CiqOptions, PrecondConfig, SolveKind, SolverPolicy};
use ciq::linalg::eigen::{spd_inv_sqrt, spd_sqrt};
use ciq::linalg::{Cholesky, Matrix};
use ciq::operators::{KernelOp, KernelType, LinearOp};
use ciq::precond::PivotedCholesky;
use ciq::prop_assert;
use ciq::rng::Pcg64;
use ciq::util::proptest::{check, Config};
use ciq::util::rel_err;

#[test]
fn property_ciq_matches_eigen_oracle_on_kernels() {
    check(Config { cases: 8, seed: 1 }, "CIQ vs eigendecomposition", |rng, case| {
        let n = 40 + rng.below(30);
        let d = 1 + case % 3;
        let x = Matrix::randn(n, d, rng);
        let kinds = [KernelType::Rbf, KernelType::Matern32, KernelType::Matern52];
        let op = KernelOp::new(&x, kinds[case % 3], 0.8, 1.2, 0.3);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { tol: 1e-8, q_points: 10, ..Default::default() });
        let dense = op.to_dense();
        let sq = solver.sqrt_mvm(&op, &b).unwrap();
        let exact = spd_sqrt(&dense).unwrap().matvec(&b);
        let e1 = rel_err(&sq.solution, &exact);
        prop_assert!(e1 < 1e-4, "sqrt err {e1}");
        let inv = solver.invsqrt_mvm(&op, &b).unwrap();
        let exact_i = spd_inv_sqrt(&dense).unwrap().matvec(&b);
        let e2 = rel_err(&inv.solution, &exact_i);
        prop_assert!(e2 < 1e-4, "invsqrt err {e2}");
        Ok(())
    });
}

#[test]
fn sample_covariance_converges_to_k() {
    // Empirical covariance of CIQ samples ≈ K (the Fig. S4 statistic).
    let mut rng = Pcg64::seeded(2);
    let n = 32;
    let x = Matrix::randn(n, 2, &mut rng);
    let op = KernelOp::new(&x, KernelType::Rbf, 0.8, 1.0, 0.1);
    let k = op.to_dense();
    let solver = Ciq::new(CiqOptions { tol: 1e-6, ..Default::default() });
    let bounds = solver.bounds(&op).unwrap();
    let reps = 600;
    let mut acc = Matrix::zeros(n, n);
    for _ in 0..reps {
        let eps: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let s = solver.sqrt_with_bounds(&op, &eps, Some(bounds)).unwrap().solution;
        for i in 0..n {
            for j in 0..n {
                acc[(i, j)] += s[i] * s[j] / reps as f64;
            }
        }
    }
    let err = (&acc - &k).fro_norm() / k.fro_norm();
    assert!(err < 0.25, "empirical covariance rel err {err}");
}

#[test]
fn whitened_vectors_are_white() {
    // Cov(K^{-1/2} eps) = K^{-1} ... instead check: whiten(K^{1/2} eps) has
    // identity covariance.
    let mut rng = Pcg64::seeded(3);
    let n = 24;
    let x = Matrix::randn(n, 2, &mut rng);
    let op = KernelOp::new(&x, KernelType::Matern52, 0.7, 1.0, 0.2);
    let solver = Ciq::new(CiqOptions { tol: 1e-7, ..Default::default() });
    let bounds = solver.bounds(&op).unwrap();
    let reps = 600;
    let mut acc = Matrix::zeros(n, n);
    for _ in 0..reps {
        let eps: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let s = solver.sqrt_with_bounds(&op, &eps, Some(bounds)).unwrap().solution;
        let w = solver.invsqrt_with_bounds(&op, &s, Some(bounds)).unwrap().solution;
        for i in 0..n {
            for j in 0..n {
                acc[(i, j)] += w[i] * w[j] / reps as f64;
            }
        }
    }
    let err = (&acc - &Matrix::eye(n)).fro_norm() / (n as f64).sqrt();
    assert!(err < 0.25, "whitened covariance deviates from I: {err}");
}

#[test]
fn property_preconditioned_and_plain_ciq_agree_in_distribution() {
    // The preconditioned maps are rotations of the plain ones (Eqs. S12/S13):
    // R = K P^{-1/2} M^{-1/2} satisfies R Rᵀ = K and R' = P^{-1/2} M^{-1/2}
    // satisfies R' R'ᵀ = K^{-1}, which is exactly "agrees in distribution"
    // for Gaussian sampling/whitening. Rather than materialize R, probe the
    // identities: every factor is symmetric, so Rᵀ x = M^{-1/2} P^{-1/2} K x
    // and R'ᵀ x = M^{-1/2} P^{-1/2} x are one whitened CIQ solve each, and
    // the outer R·/R'· application is the unified preconditioned solve.
    check(Config { cases: 6, seed: 31 }, "precond RRᵀx == Kx across kernels/ranks", |rng, case| {
        let n = 22 + rng.below(14);
        let kinds = [KernelType::Rbf, KernelType::Matern32, KernelType::Matern52];
        let noise = 1e-2;
        let x = Matrix::randn(n, 1 + case % 2, rng);
        let op = KernelOp::new(&x, kinds[case % 3], 0.8, 1.0, noise);
        let rank = 4 + 4 * (case % 3); // sweep preconditioner ranks 4/8/12
        let solver = Ciq::new(CiqOptions { tol: 1e-10, q_points: 12, ..Default::default() });
        let cfg = PrecondConfig { rank, sigma2: Some(noise), build_tol: 1e-14 };
        let err_str = |e: ciq::Error| format!("{e}");
        let ctx = solver
            .build_context(&op, &SolverPolicy::Preconditioned(cfg))
            .map_err(err_str)?;
        let pc = ctx.precond.as_ref().expect("preconditioned context").clone();
        let probe: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

        // sampling: R Rᵀ x must equal K x
        let kx = op.matvec(&probe);
        let m = WhitenedOp::new(&op, pc.as_ref());
        let rt_x = {
            let p_kx = pc.invsqrt_mvm(&kx);
            solver.invsqrt_with_bounds(&m, &p_kx, Some(ctx.cache.bounds)).map_err(err_str)?.solution
        };
        let rrt_x = solver.solve(&op, &rt_x, SolveKind::Sqrt, &ctx).map_err(err_str)?.solution;
        let e_sample = rel_err(&rrt_x, &kx);
        prop_assert!(e_sample < 5e-3, "R Rᵀx vs Kx rel err {e_sample} (rank {rank})");

        // whitening: R' R'ᵀ x must equal K^{-1} x
        let kinv_x = Cholesky::new(&op.to_dense()).map_err(err_str)?.solve(&probe);
        let rpt_x = {
            let p_x = pc.invsqrt_mvm(&probe);
            solver.invsqrt_with_bounds(&m, &p_x, Some(ctx.cache.bounds)).map_err(err_str)?.solution
        };
        let rprpt_x = solver.solve(&op, &rpt_x, SolveKind::InvSqrt, &ctx).map_err(err_str)?.solution;
        let e_whiten = rel_err(&rprpt_x, &kinv_x);
        prop_assert!(e_whiten < 5e-3, "R'R'ᵀx vs K⁻¹x rel err {e_whiten} (rank {rank})");
        Ok(())
    });
}

#[test]
fn preconditioned_ciq_cuts_iterations_on_ill_conditioned_kernel() {
    let mut rng = Pcg64::seeded(4);
    let n = 300;
    let x = Matrix::randn(n, 1, &mut rng);
    let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 1e-5);
    let solver = Ciq::new(CiqOptions { tol: 1e-4, max_iters: 2000, ..Default::default() });
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let plain = solver.invsqrt_mvm(&op, &b).unwrap();
    for rank in [25, 100] {
        let pc = PivotedCholesky::new(&op, rank, 1e-5, 1e-14).unwrap();
        let pre = solver.invsqrt_mvm_preconditioned(&op, &pc, &b).unwrap();
        assert!(
            pre.iterations <= plain.iterations,
            "rank {rank}: precond {} vs plain {}",
            pre.iterations,
            plain.iterations
        );
    }
    // higher rank should not be slower than lower rank (allow slack of 1.2x)
    let lo = solver
        .invsqrt_mvm_preconditioned(&op, &PivotedCholesky::new(&op, 25, 1e-5, 1e-14).unwrap(), &b)
        .unwrap();
    let hi = solver
        .invsqrt_mvm_preconditioned(&op, &PivotedCholesky::new(&op, 100, 1e-5, 1e-14).unwrap(), &b)
        .unwrap();
    assert!(
        (hi.iterations as f64) <= 1.2 * lo.iterations as f64 + 5.0,
        "rank-100 ({}) should beat rank-25 ({})",
        hi.iterations,
        lo.iterations
    );
}

#[test]
fn backward_pass_kernel_hyper_gradient_matches_fd() {
    // The paper's Eq. 3 gradient contracted against dK/d(log ell) must match
    // finite differences of f = vᵀ K^{-1/2} b through the exact map.
    let mut rng = Pcg64::seeded(5);
    let n = 16;
    let x = Matrix::randn(n, 2, &mut rng);
    let (ell, s2, noise) = (0.9, 1.1, 0.4);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let solver = Ciq::new(CiqOptions { tol: 1e-11, q_points: 14, ..Default::default() });

    let op = KernelOp::new(&x, KernelType::Rbf, ell, s2, noise);
    let fwd = solver.invsqrt_mvm(&op, &b).unwrap();
    let bwd = solver.backward(&op, &fwd, &v).unwrap();
    // analytic: sum_q -w_q l_qᵀ (dK/dlogell) r_q via the fused contraction
    let mut analytic = 0.0;
    for (w, l, r) in &bwd.terms {
        let noise_free = KernelOp::new(&x, KernelType::Rbf, ell, s2, 0.0);
        let (g_ell, _g_s2) = noise_free.grad_contract(l, r);
        analytic += -w * g_ell;
    }
    // FD through exact eigendecomposition
    let f = |ell: f64| -> f64 {
        let o = KernelOp::new(&x, KernelType::Rbf, ell, s2, noise);
        let m = spd_inv_sqrt(&o.to_dense()).unwrap();
        ciq::util::dot(&v, &m.matvec(&b))
    };
    let h: f64 = 1e-4;
    let fd = (f(ell * h.exp()) - f(ell * (-h).exp())) / (2.0 * h);
    assert!(
        (analytic - fd).abs() < 2e-3 * (1.0 + fd.abs()),
        "hyper gradient: analytic {analytic} vs fd {fd}"
    );
}

#[test]
fn q_sweep_error_profile_matches_fig1() {
    // Fig. 1's qualitative claim: error decays with Q and plateaus at the
    // msMINRES tolerance; Q=8 reaches <1e-4 with tol 1e-5.
    let mut rng = Pcg64::seeded(6);
    let n = 80;
    let x = Matrix::randn(n, 1, &mut rng);
    let op = KernelOp::new(&x, KernelType::Matern52, 0.6, 1.0, 0.1);
    let dense = op.to_dense();
    let exact_map = spd_sqrt(&dense).unwrap();
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let exact = exact_map.matvec(&b);
    let mut prev = f64::INFINITY;
    for q in [2usize, 4, 6, 8] {
        let solver = Ciq::new(CiqOptions { q_points: q, tol: 1e-6, max_iters: 1000, ..Default::default() });
        let approx = solver.sqrt_mvm(&op, &b).unwrap();
        let err = rel_err(&approx.solution, &exact);
        assert!(err <= prev * 1.5 + 1e-12, "error not decaying at Q={q}: {err} (prev {prev})");
        if q == 8 {
            assert!(err < 1e-4, "Q=8 error {err} (paper: <1e-4)");
        }
        prev = prev.min(err);
    }
}

//! Coordinator invariants under concurrency (property-style): every request
//! answered exactly once, batched results identical to solo solves, routing
//! by operator name, metrics accounting, the preconditioned serving
//! pipeline (policy-driven solves + background warming), and the async
//! dispatcher: no flush starvation under a steady trickle, zero wakeups at
//! idle, and bounded-concurrency warming. (The threaded dispatcher is
//! retired; the async executor backend is the only one.)

use ciq::ciq::{CiqOptions, PrecondConfig, SolverPolicy};
use ciq::coordinator::{ReqKind, SamplingService, ServiceConfig, SharedOp};
use ciq::linalg::eigen::spd_inv_sqrt;
use ciq::linalg::Matrix;
use ciq::obs::solvetrace;
use ciq::obs::trace::{self, EventKind};
use ciq::operators::{DenseOp, KernelOp, KernelType, LinearOp};
use ciq::rng::Pcg64;
use ciq::util::rel_err;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn spd(n: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::seeded(seed);
    let a = Matrix::randn(n, n, &mut rng);
    let mut k = a.matmul(&a.transpose());
    for i in 0..n {
        k[(i, i)] += n as f64 * 0.5;
    }
    k
}

fn service(ops: Vec<(&str, Matrix)>, max_batch: usize) -> SamplingService {
    let mut map: HashMap<String, SharedOp> = HashMap::new();
    for (name, k) in ops {
        map.insert(name.to_string(), Arc::new(DenseOp::new(k)));
    }
    SamplingService::start(
        ServiceConfig {
            max_batch,
            max_wait: Duration::from_millis(3),
            workers: 3,
            ciq: CiqOptions { tol: 1e-9, ..Default::default() },
            ..Default::default()
        },
        map,
    )
}

#[test]
fn property_batched_equals_solo_across_random_traffic() {
    let n = 18;
    let k1 = spd(n, 1);
    let k2 = spd(n, 2);
    let inv1 = spd_inv_sqrt(&k1).unwrap();
    let inv2 = spd_inv_sqrt(&k2).unwrap();
    let svc = service(vec![("a", k1.clone()), ("b", k2.clone())], 8);

    // random interleaved traffic targeting both operators
    let mut rng = Pcg64::seeded(3);
    let mut expected = Vec::new();
    let mut tickets = Vec::new();
    for i in 0..40 {
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (name, inv) = if i % 3 == 0 { ("b", &inv2) } else { ("a", &inv1) };
        expected.push(inv.matvec(&b));
        tickets.push(svc.submit(name, ReqKind::Whiten, b));
    }
    for (t, e) in tickets.into_iter().zip(&expected) {
        let got = t.wait().unwrap();
        assert!(rel_err(&got, e) < 1e-5, "batched result differs from solo");
    }
    // accounting: all submitted requests completed, none failed
    let m = svc.metrics();
    assert_eq!(m.submitted.load(Ordering::Relaxed), 40);
    assert_eq!(m.completed.load(Ordering::Relaxed), 40);
    assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    svc.shutdown();
}

#[test]
fn batches_never_exceed_max_batch() {
    let n = 12;
    let svc = service(vec![("a", spd(n, 4))], 4);
    let mut rng = Pcg64::seeded(5);
    let tickets: Vec<_> = (0..30)
        .map(|_| {
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            svc.submit("a", ReqKind::Sample, b)
        })
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    assert!(svc.metrics().max_batch_size() <= 4, "batch cap violated");
    svc.shutdown();
}

#[test]
fn sample_and_whiten_are_kept_in_separate_batches() {
    // A whiten result must never be a sample result: roundtrip consistency
    // under mixed traffic proves no cross-contamination.
    let n = 14;
    let k = spd(n, 6);
    let svc = service(vec![("a", k.clone())], 16);
    let mut rng = Pcg64::seeded(7);
    for _ in 0..10 {
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let w = svc.submit("a", ReqKind::Whiten, b.clone());
        let s = svc.submit("a", ReqKind::Sample, b.clone());
        let w = w.wait().unwrap();
        let s = s.wait().unwrap();
        // K^{1/2}w == b and K^{-1/2}s == b
        let round_w = svc.submit("a", ReqKind::Sample, w).wait().unwrap();
        let round_s = svc.submit("a", ReqKind::Whiten, s).wait().unwrap();
        assert!(rel_err(&round_w, &b) < 1e-4);
        assert!(rel_err(&round_s, &b) < 1e-4);
    }
    svc.shutdown();
}

#[test]
fn graceful_shutdown_drains_inflight() {
    let n = 16;
    let svc = service(vec![("a", spd(n, 8))], 32);
    let mut rng = Pcg64::seeded(9);
    let tickets: Vec<_> = (0..12)
        .map(|_| {
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            svc.submit("a", ReqKind::Whiten, b)
        })
        .collect();
    svc.shutdown(); // must flush the pending queue before exiting
    for t in tickets {
        assert!(t.wait().is_ok(), "in-flight request dropped on shutdown");
    }
}

// Regression for the dispatcher flush-starvation bug (PR 1): deadlines used
// to be checked only on the recv_timeout Timeout branch, so a steady
// trickle of requests arriving faster than max_wait kept the loop on its Ok
// path and a sub-max_batch shard was never flushed until the trickle
// stopped.
//
// 30 requests at ~5 ms spacing with max_wait = 15 ms and max_batch = 1000:
// the starving dispatcher's first flush happened only after the full ~150 ms
// trickle (p50 latency ≈ 90 ms, one giant batch); the deadline-correct
// dispatcher (per-shard timer armed at oldest.enqueued + max_wait) flushes
// every ~15 ms regardless of arrivals.
#[test]
fn starvation_steady_trickle_flushed_within_deadline() {
    let n = 8;
    let mut map: HashMap<String, SharedOp> = HashMap::new();
    map.insert("a".to_string(), Arc::new(DenseOp::new(Matrix::eye(n))));
    let svc = SamplingService::start(
        ServiceConfig {
            max_batch: 1000,
            max_wait: Duration::from_millis(15),
            workers: 1,
            ciq: CiqOptions::default(),
            ..Default::default()
        },
        map,
    );
    let mut rng = Pcg64::seeded(77);
    let mut tickets = Vec::new();
    let t0 = Instant::now();
    for _ in 0..30 {
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        tickets.push(svc.submit("a", ReqKind::Whiten, b));
        std::thread::sleep(Duration::from_millis(5));
    }
    let trickle_us = t0.elapsed().as_micros() as u64;
    for t in tickets {
        t.wait().unwrap();
    }
    // Self-scaling bound so scheduler jitter can't flake the test: the old
    // dispatcher's p50 is ~half the (measured) trickle duration, the fixed
    // one's is ~max_wait regardless of it.
    let bound_us = (trickle_us / 3).max(60_000);
    let p50 = svc.metrics().latency_percentile_us(50.0);
    assert!(
        p50 < bound_us,
        "p50 latency {p50}us (bound {bound_us}us) — steady trickle starved the shard of flushes"
    );
    assert!(
        svc.metrics().max_batch_size() < 30,
        "all requests collapsed into one post-trickle flush (batch {})",
        svc.metrics().max_batch_size()
    );
    // every deadline flush goes through the timer wheel
    assert!(
        svc.metrics().timer_fires.load(Ordering::Relaxed) >= 2,
        "trickle flushes must be deadline-driven"
    );
    svc.shutdown();
}

#[test]
fn async_backend_performs_zero_wakeups_while_idle() {
    // The acceptance test for the exec port: a single dispatcher thread
    // multiplexes all shards, and while the service sits idle *nothing*
    // moves — no poll interval exists to tick. The timer only fires while a
    // shard holds a pending flush deadline.
    let n = 8;
    let mut map: HashMap<String, SharedOp> = HashMap::new();
    map.insert("a".to_string(), Arc::new(DenseOp::new(Matrix::eye(n))));
    let svc = SamplingService::start(
        ServiceConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            workers: 1,
            ciq: CiqOptions::default(),
            // keep the startup warm job out of the books: this test pins
            // exact wakeup counts
            warm_on_register: false,
            ..Default::default()
        },
        map,
    );
    // liveness probe: one sub-ceiling request must flush via exactly one
    // armed deadline (one arrival wakeup + one timer fire)
    svc.submit("a", ReqKind::Whiten, vec![1.0; n]).wait().unwrap();
    let m = svc.metrics();
    assert_eq!(m.dispatcher_wakeups.load(Ordering::Relaxed), 1);
    assert_eq!(
        m.timer_fires.load(Ordering::Relaxed),
        1,
        "a single sub-ceiling request must flush by its armed deadline"
    );
    // idle window: no arrivals, no shard with a pending deadline. Pin the
    // property at the *executor* layer too — the coordinator counters above
    // only count coordinator events, and could not catch a reintroduced
    // internal poll interval; task polls can.
    let exec_stats = m.exec_stats().expect("async backend must expose executor stats");
    std::thread::sleep(Duration::from_millis(20)); // let the executor re-park
    let polls_before = exec_stats.polls.load(Ordering::Relaxed);
    let wakeups_before = exec_stats.wakeups.load(Ordering::Relaxed);
    std::thread::sleep(Duration::from_millis(200));
    assert_eq!(
        m.dispatcher_wakeups.load(Ordering::Relaxed),
        1,
        "idle service woke the dispatcher"
    );
    assert_eq!(
        m.timer_fires.load(Ordering::Relaxed),
        1,
        "timer fired with no pending flush deadline"
    );
    assert_eq!(
        exec_stats.polls.load(Ordering::Relaxed),
        polls_before,
        "executor polled tasks while the service was idle"
    );
    assert!(
        exec_stats.wakeups.load(Ordering::Relaxed) <= wakeups_before + 1,
        "executor woke repeatedly while idle (poll-interval regression)"
    );
    svc.shutdown();
}

/// An operator whose MVMs are artificially slow, tracking how many run
/// concurrently — the probe for warm-pool parallelism.
struct SlowOp {
    inner: DenseOp,
    delay: Duration,
    active: Arc<AtomicUsize>,
    peak: Arc<AtomicUsize>,
}

impl LinearOp for SlowOp {
    fn size(&self) -> usize {
        self.inner.size()
    }
    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let now = self.active.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak.fetch_max(now, Ordering::SeqCst);
        std::thread::sleep(self.delay);
        let y = self.inner.matvec(x);
        self.active.fetch_sub(1, Ordering::SeqCst);
        y
    }
}

#[test]
fn warm_pool_builds_contexts_concurrently_under_registration_burst() {
    // Regression for single-threaded warming: N slow-to-warm operators
    // registered together must overlap their context builds (bounded by
    // warm_concurrency) instead of serializing behind one build. The old
    // one-warmer-thread design pins peak observed concurrency at exactly 1.
    let n = 16;
    let nops = 4;
    let active = Arc::new(AtomicUsize::new(0));
    let peak = Arc::new(AtomicUsize::new(0));
    let mut rng = Pcg64::seeded(80);
    let mut map: HashMap<String, SharedOp> = HashMap::new();
    for i in 0..nops {
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for j in 0..n {
            k[(j, j)] += n as f64 * 0.5;
        }
        map.insert(
            format!("op{i}"),
            Arc::new(SlowOp {
                inner: DenseOp::new(k),
                delay: Duration::from_millis(2),
                active: active.clone(),
                peak: peak.clone(),
            }),
        );
    }
    let svc = SamplingService::start(
        ServiceConfig {
            workers: 1,
            warm_concurrency: nops,
            ciq: CiqOptions { tol: 1e-8, ..Default::default() },
            ..Default::default() // warm_on_register: true
        },
        map,
    );
    let t0 = Instant::now();
    while (svc.metrics().warmed_operators.load(Ordering::Relaxed) as usize) < nops {
        assert!(t0.elapsed() < Duration::from_secs(30), "warm pool never finished");
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(
        peak.load(Ordering::SeqCst) >= 2,
        "a registration burst must warm concurrently (peak concurrent MVMs = {})",
        peak.load(Ordering::SeqCst)
    );
    // every warmed operator serves its first batch with zero inline work
    let mut rng = Pcg64::seeded(81);
    for i in 0..nops {
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        svc.submit(&format!("op{i}"), ReqKind::Whiten, b).wait().unwrap();
    }
    assert_eq!(svc.metrics().cache_misses.load(Ordering::Relaxed), 0);
    svc.shutdown();
}

#[test]
fn shard_queue_depth_telemetry_tracks_traffic() {
    let n = 12;
    let k1 = spd(n, 31);
    let k2 = spd(n, 32);
    let svc = service(vec![("a", k1), ("b", k2)], 8);
    let mut rng = Pcg64::seeded(33);
    let mut tickets = Vec::new();
    for i in 0..16 {
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let name = if i % 2 == 0 { "a" } else { "b" };
        let kind = if i % 4 < 2 { ReqKind::Sample } else { ReqKind::Whiten };
        tickets.push(svc.submit(name, kind, b));
    }
    for t in tickets {
        t.wait().unwrap();
    }
    let depths = svc.metrics().shard_depths();
    assert!(!depths.is_empty(), "shard telemetry never recorded");
    // every shard drained back to zero, and at least one saw real queueing
    assert!(depths.iter().all(|&(_, cur, _)| cur == 0), "shard left non-empty: {depths:?}");
    assert!(depths.iter().any(|&(_, _, max)| max >= 1));
    svc.shutdown();
}

/// The acceptance test for the preconditioned serving pipeline: a service
/// running `SolverPolicy::Preconditioned` on an ill-conditioned kernel
/// operator must (a) serve a sampling map whose square reproduces `K`
/// (correctness up to the Eqs. S12/S13 rotation) and (b) spend measurably
/// fewer msMINRES iterations per RHS than the plain policy, as read from
/// `Metrics` iteration counts.
#[test]
fn preconditioned_policy_serves_correctly_with_fewer_iterations_than_plain() {
    let n = 96;
    let mut rng = Pcg64::seeded(90);
    // smooth 1-D RBF data with small noise: the ill-conditioned regime where
    // pivoted-Cholesky preconditioning shines (Appx. D / Fig. S3)
    let x = Matrix::randn(n, 1, &mut rng);
    let noise = 1e-3;
    let run = |policy: SolverPolicy| -> (f64, Matrix) {
        let op: SharedOp = Arc::new(KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, noise));
        let mut map: HashMap<String, SharedOp> = HashMap::new();
        map.insert("k".to_string(), op);
        let svc = SamplingService::start(
            ServiceConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(2),
                workers: 2,
                ciq: CiqOptions { tol: 1e-8, q_points: 10, max_iters: 3000, ..Default::default() },
                policy,
                ..Default::default()
            },
            map,
        );
        // build the full sampling map column by column: R e_j (or K^{1/2} e_j)
        let tickets: Vec<_> = (0..n)
            .map(|j| {
                let mut e = vec![0.0; n];
                e[j] = 1.0;
                svc.submit("k", ReqKind::Sample, e)
            })
            .collect();
        let mut r_mat = Matrix::zeros(n, n);
        for (j, t) in tickets.into_iter().enumerate() {
            let col = t.wait().unwrap();
            for i in 0..n {
                r_mat[(i, j)] = col[i];
            }
        }
        let mean_iters = svc.metrics().mean_iterations();
        assert!(mean_iters > 0.0, "no iteration telemetry recorded");
        svc.shutdown();
        (mean_iters, r_mat)
    };

    let (plain_iters, plain_r) = run(SolverPolicy::Plain);
    let (pre_iters, pre_r) = run(SolverPolicy::Preconditioned(PrecondConfig {
        rank: 32,
        sigma2: Some(noise),
        build_tol: 1e-14,
    }));

    // correctness: both maps square to K (the preconditioned one only up to
    // the orthonormal rotation, which R Rᵀ is invariant to). A wrong rotation
    // or a stale/mixed context shows up at O(1) here; the tight numerical
    // bound lives in the integration_ciq distribution property test.
    let k = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, noise).to_dense();
    let e_plain = (&plain_r.matmul(&plain_r.transpose()) - &k).fro_norm() / k.fro_norm();
    let e_pre = (&pre_r.matmul(&pre_r.transpose()) - &k).fro_norm() / k.fro_norm();
    assert!(e_plain < 2e-2, "plain policy sampling map drifted: {e_plain}");
    assert!(e_pre < 2e-2, "preconditioned sampling map drifted: {e_pre}");

    // the acceptance number: measurably fewer msMINRES iterations per RHS
    assert!(
        pre_iters < 0.8 * plain_iters,
        "preconditioning not measurably faster: {pre_iters:.1} vs plain {plain_iters:.1} mean iters"
    );
}

/// The flight-recorder acceptance test: drained trace spans must
/// reconstruct each request's timeline — enqueue → (queue wait) → solve →
/// respond — and the trace-derived end-to-end time must agree with the
/// latency the coordinator recorded at the response site.
///
/// The recorder is process-global, so the snapshot may also hold events from
/// tests running in parallel; every invariant asserted here is universal
/// (it holds for *any* complete request), and attribution only needs the
/// request-id bracket taken around our own submissions.
#[test]
fn flight_recorder_reconstructs_request_timeline_within_latency_tolerance() {
    let n = 18;
    let svc = service(vec![("t", spd(n, 41))], 8);
    trace::set_enabled(true);
    let lo = trace::next_request_id();
    let mut rng = Pcg64::seeded(42);
    for _ in 0..4 {
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        svc.submit("t", ReqKind::Whiten, b).wait().unwrap();
    }
    let hi = trace::next_request_id();
    trace::set_enabled(false);
    let snap = trace::snapshot();

    let mut checked = 0;
    for enq in snap.of_kind(EventKind::Enqueue) {
        if !(lo < enq.a && enq.a < hi) {
            continue;
        }
        // a still-in-flight foreign request may miss its Respond — skip it
        let Some(rsp) = snap.of_kind(EventKind::Respond).find(|e| e.a == enq.a) else {
            continue;
        };
        // trace-derived e2e vs the µs latency recorded at the response site
        let trace_us = rsp.t_ns.saturating_sub(enq.t_ns) / 1000;
        let recorded_us = rsp.b;
        let tol_us = 2_000 + recorded_us / 4;
        assert!(
            trace_us.abs_diff(recorded_us) <= tol_us,
            "trace e2e {trace_us}us disagrees with recorded latency {recorded_us}us \
             (request {})",
            enq.a
        );
        // the responding worker's solve span must nest inside the request
        // window: enqueue ≤ solve start ≤ solve end ≤ respond, so queue
        // wait + solve never exceeds the end-to-end time
        let start = snap
            .of_kind(EventKind::SolveStart)
            .find(|e| e.tid == rsp.tid && enq.t_ns <= e.t_ns && e.t_ns <= rsp.t_ns);
        let end = snap
            .of_kind(EventKind::SolveEnd)
            .find(|e| e.tid == rsp.tid && enq.t_ns <= e.t_ns && e.t_ns <= rsp.t_ns);
        let (Some(start), Some(end)) = (start, end) else {
            panic!("request {} has no solve span on its responding worker", enq.a);
        };
        assert!(start.t_ns <= end.t_ns, "solve span inverted");
        let queue_wait_plus_solve = end.t_ns.saturating_sub(enq.t_ns);
        assert!(
            queue_wait_plus_solve <= rsp.t_ns.saturating_sub(enq.t_ns),
            "queue wait + solve exceeds the request's end-to-end window"
        );
        checked += 1;
    }
    assert!(checked >= 4, "only {checked} of our 4 requests left complete trace pairs");
    // the exported form is loadable Chrome trace JSON with async request
    // spans and complete solve spans
    let json = snap.to_chrome_json();
    assert!(json.contains("\"ph\":\"b\"") && json.contains("\"ph\":\"e\""));
    assert!(json.contains("\"name\":\"solve\"") && json.contains("\"ph\":\"X\""));
    svc.shutdown();
}

/// The residual-trajectory acceptance test: with 1-in-1 sampling on, served
/// solves publish monotone, terminating residual histories (the Fig. 2
/// curve shape) — and a well-conditioned operator converges below its own
/// tolerance in well under 100 MVMs.
#[test]
fn sampled_residual_trajectories_are_monotone_and_terminate() {
    let n = 18;
    let svc = service(vec![("r", spd(n, 51))], 8);
    solvetrace::configure(1);
    let mut rng = Pcg64::seeded(52);
    for _ in 0..3 {
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        svc.submit("r", ReqKind::Whiten, b).wait().unwrap();
    }
    solvetrace::configure(0);
    let trajs = solvetrace::drain();
    assert!(!trajs.is_empty(), "sampling at 1-in-1 published no trajectory");
    // universal invariant (sampling is process-global, other tests' solves
    // may be in the drain too): msMINRES residual estimates are monotone
    // non-increasing — φ_{k+1} = φ_k·|s_k| with |s_k| ≤ 1 per shift, and a
    // max over per-column monotone sequences on a shrinking active set
    for t in &trajs {
        assert!(!t.residuals.is_empty() && t.iters > 0 && t.cols > 0);
        for w in t.residuals.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-9),
                "residual trajectory not monotone: {:?}",
                t.residuals
            );
        }
    }
    // existential: at least one sampled solve (ours are n=18, tol 1e-9)
    // terminates below its own tolerance in < 100 MVMs
    assert!(
        trajs.iter().any(|t| t.iters < 100 && *t.residuals.last().unwrap() <= t.tol),
        "no sampled solve terminated below tolerance within 100 MVMs"
    );
    svc.shutdown();
}

#[test]
fn latency_metrics_populated() {
    let n = 10;
    let svc = service(vec![("a", spd(n, 10))], 4);
    let mut rng = Pcg64::seeded(11);
    for _ in 0..8 {
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        svc.submit("a", ReqKind::Sample, b).wait().unwrap();
    }
    assert!(svc.metrics().latency_percentile_us(50.0) > 0);
    assert!(
        svc.metrics().latency_percentile_us(99.0) >= svc.metrics().latency_percentile_us(50.0)
    );
    svc.shutdown();
}

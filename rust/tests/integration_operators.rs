//! Operator-algebra integration: composed operators used by the
//! applications behave like their dense counterparts, and partitioned MVMs
//! are exact.

use ciq::linalg::{Cholesky, Matrix};
use ciq::operators::image::{Conv2d, Downsample, PrecisionOp};
use ciq::operators::{
    cross_kernel, DenseOp, DiagOp, KernelOp, KernelType, LinearOp, LowRankPlusDiagOp, ScaledOp,
    ShiftedOp, SubtractLowRankOp, SumOp,
};
use ciq::prop_assert;
use ciq::rng::Pcg64;
use ciq::util::proptest::{check, Config};
use ciq::util::{dot, rel_err};

#[test]
fn property_kernel_mvm_invariant_to_tile_size() {
    check(Config { cases: 10, seed: 1 }, "tile invariance", |rng, case| {
        let n = 30 + rng.below(50);
        let x = Matrix::randn(n, 1 + case % 4, rng);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let base = KernelOp::new(&x, KernelType::Rbf, 0.7, 1.0, 0.05).with_tile(8);
        let y0 = base.matvec(&v);
        for tile in [16, 64, 1024] {
            let op = KernelOp::new(&x, KernelType::Rbf, 0.7, 1.0, 0.05).with_tile(tile);
            let y = op.matvec(&v);
            let e = rel_err(&y, &y0);
            prop_assert!(e < 1e-12, "tile {tile}: {e}");
        }
        Ok(())
    });
}

#[test]
fn property_composed_operators_match_dense_algebra() {
    check(Config { cases: 10, seed: 2 }, "composed ops", |rng, _| {
        let n = 12 + rng.below(10);
        let mut a = Matrix::randn(n, n, rng);
        a.symmetrize();
        let mut b = Matrix::randn(n, n, rng);
        b.symmetrize();
        let w = Matrix::randn(n, 3, rng);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (oa, ob) = (DenseOp::new(a.clone()), DenseOp::new(b.clone()));
        let t = rng.uniform() * 5.0;

        // ((2A + 3B) + tI) v scaled by -1, minus WWᵀ v
        let sum = SumOp::new(&oa, 2.0, &ob, 3.0);
        let shifted = ShiftedOp::new(&sum, t);
        let scaled = ScaledOp::new(&shifted, -1.0);
        let final_op = SubtractLowRankOp::new(&scaled, w.clone());

        let dense = {
            let mut m = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    m[(i, j)] = -(2.0 * a[(i, j)] + 3.0 * b[(i, j)] + if i == j { t } else { 0.0 });
                }
            }
            &m - &w.matmul(&w.transpose())
        };
        let e = rel_err(&final_op.matvec(&v), &dense.matvec(&v));
        prop_assert!(e < 1e-10, "composed mvm err {e}");
        // diagonal consistency
        let d_op = final_op.diagonal();
        for i in 0..n {
            prop_assert!((d_op[i] - dense[(i, i)]).abs() < 1e-10, "diag {i}");
        }
        Ok(())
    });
}

#[test]
fn gp_posterior_covariance_operator_equals_dense_formula() {
    // Cov = K** − K*n (Knn+σ²I)^{-1} Kn* (via the W = K*n L^{-T} factor)
    let mut rng = Pcg64::seeded(3);
    let (n, t, d) = (30, 20, 2);
    let xn = Matrix::randn(n, d, &mut rng);
    let xt = Matrix::randn(t, d, &mut rng);
    let (ell, s2, noise) = (0.8, 1.0, 0.1);
    let ells = vec![ell; d];
    let knn = KernelOp::new(&xn, KernelType::Rbf, ell, s2, noise).to_dense();
    let chol = Cholesky::with_jitter(&knn, 0.0).unwrap();
    let ktn = cross_kernel(&xt, &xn, KernelType::Rbf, &ells, s2);
    let mut w = Matrix::zeros(t, n);
    for i in 0..t {
        let sol = chol.solve_l(&ktn.row(i).to_vec());
        for j in 0..n {
            w[(i, j)] = sol[j];
        }
    }
    let ktt = KernelOp::new(&xt, KernelType::Rbf, ell, s2, 0.0);
    let cov_op = SubtractLowRankOp::new(&ktt, w);
    // dense formula
    let kinv_knt = chol.solve_mat(&ktn.transpose());
    let dense_cov = &ktt.to_dense() - &ktn.matmul(&kinv_knt);
    assert!(cov_op.to_dense().max_abs_diff(&dense_cov) < 1e-8);
}

#[test]
fn image_forward_model_composes() {
    // A = D∘B: adjoint identity on the composition, PSD of Λ, and the
    // precision quadratic form equals γobs·R‖Ax‖² + γprior‖Lx‖².
    let n = 12;
    let prec = PrecisionOp::new(n, 2, 3, 2.0, 0.7);
    let mut rng = Pcg64::seeded(4);
    let x: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
    let ax = prec.forward(&x);
    let lap = Conv2d::laplacian(n);
    let lx = lap.apply(&x);
    let quad_direct = 2.0 * 3.0 * dot(&ax, &ax) + 0.7 * dot(&lx, &lx);
    let quad_op = dot(&x, &prec.matvec(&x));
    assert!(
        (quad_direct - quad_op).abs() < 1e-8 * quad_direct.abs().max(1.0),
        "{quad_direct} vs {quad_op}"
    );
    // downsample of constant image is constant
    let ds = Downsample::new(n, 2);
    let c = vec![3.5; n * n];
    assert!(ds.apply(&c).iter().all(|&v| (v - 3.5).abs() < 1e-12));
}

#[test]
fn lowrank_and_diag_ops_in_krylov_context() {
    // LowRankPlusDiagOp should be solvable by msMINRES and match Woodbury.
    let mut rng = Pcg64::seeded(5);
    let n = 40;
    let l = Matrix::randn(n, 4, &mut rng);
    let op = LowRankPlusDiagOp::new(l.clone(), 0.9);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let (x, _, _) = ciq::krylov::minres(&op, &b, 300, 1e-12);
    let dense = op.to_dense();
    let exact = Cholesky::with_jitter(&dense, 0.0).unwrap().solve(&b);
    assert!(rel_err(&x, &exact) < 1e-7);
    // minres on a pure diagonal is exact in 1 iteration for scaled identity
    let dop = DiagOp::new(vec![2.0; 10]);
    let (y, _, iters) = ciq::krylov::minres(&dop, &vec![1.0; 10], 10, 1e-12);
    assert!(iters <= 2);
    assert!(y.iter().all(|&v| (v - 0.5).abs() < 1e-10));
}

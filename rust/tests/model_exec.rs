//! Model-checked concurrency tests for the executor stack: the channel's
//! send-vs-close protocol, the executor's ready-queue dedup flag, the
//! chunk pool's park/unpark epoch handoff, the task pool's
//! drain-on-shutdown handshake, and the flight recorder's seqlock ring
//! publish — explored under the deterministic interleaving checker in
//! `ciq::util::model` instead of wall-clock racing.
//!
//! Compiled only under `RUSTFLAGS="--cfg ciq_model"` (the `[[test]]` target
//! is otherwise an empty crate): the cfg routes `crate::util::sync` through
//! the model scheduler, so every `Mutex`/`Condvar`/atomic the production
//! code touches becomes a scheduling point the checker controls. Run with:
//!
//! ```text
//! RUSTFLAGS="--cfg ciq_model" cargo test --test model_exec
//! ```
//!
//! The checker is sequentially-consistent: it explores *interleavings*, not
//! weak-memory reorderings (that is Miri/TSan territory — see the nightly CI
//! lanes and `rust/DESIGN.md` §5).
//!
//! # Mutation validation
//!
//! Each test below is validated by a deliberately-weakened mutation that the
//! checker must catch. The mutations are **reverted** in the committed tree;
//! the patches are kept here (see the `MUTATIONS` section at the bottom of
//! this file) so a reviewer can re-apply any of them locally and watch the
//! corresponding test print a failing interleaving trace.

#![cfg(ciq_model)]

use ciq::exec::channel::channel;
use ciq::exec::Executor;
use ciq::obs::trace::{EventKind, ThreadRing};
use ciq::util::model;
use ciq::util::model::ModelConfig;
use ciq::util::sync::{AtomicUsize, Condvar, Mutex, Ordering};
use ciq::util::threadpool::{ChunkPool, TaskOrder, TaskPool};
use std::cell::Cell;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::sync::Arc;
use std::task::{Context, Poll, Wake, Waker};

/// A minimal parker: a waker that sets a flag under the (shim) mutex and
/// notifies, and a `park` that waits for the flag. This is the executor's
/// park/unpark protocol reduced to its essentials, so the channel tests can
/// explore waker registration races without the full run loop.
struct Parker {
    woken: Mutex<bool>,
    cv: Condvar,
}

impl Parker {
    fn new() -> Arc<Parker> {
        Arc::new(Parker { woken: Mutex::new(false), cv: Condvar::new() })
    }

    /// Block until `wake` has been called since the last `park` returned.
    fn park(&self) {
        let mut woken = self.woken.lock().unwrap();
        while !*woken {
            woken = self.cv.wait(woken).unwrap();
        }
        *woken = false;
    }
}

impl Wake for Parker {
    fn wake(self: Arc<Self>) {
        self.wake_by_ref();
    }
    fn wake_by_ref(self: &Arc<Self>) {
        *self.woken.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

/// Family 1 — **send vs close**: a receiver that registers its waker and
/// parks must always be woken again, whether the next event is a value or
/// the last sender dropping. Mutation M1 (drop the close-wake in
/// `Sender::drop`) strands a receiver that parked between `send` and the
/// drop; the checker reports that interleaving as a deadlock.
#[test]
fn channel_close_vs_send_never_strands_receiver() {
    model::check(move || {
        let (tx, mut rx) = channel::<u32>();
        let sender = model::spawn(move || {
            tx.send(7).unwrap();
            // tx drops here: the close must wake a parked receiver.
        });
        let parker = Parker::new();
        let waker = Waker::from(parker.clone());
        let mut cx = Context::from_waker(&waker);
        let mut got = Vec::new();
        loop {
            let mut fut = rx.recv();
            match Pin::new(&mut fut).poll(&mut cx) {
                Poll::Ready(Some(v)) => got.push(v),
                Poll::Ready(None) => break,
                Poll::Pending => parker.park(),
            }
        }
        assert_eq!(got, vec![7], "receiver must observe the value exactly once");
        sender.join();
    });
}

/// Family 2 — **ready-queue dedup flag**: the executor clears a task's
/// `queued` flag *before* polling it, so a wake that lands mid-poll
/// re-queues the task. Mutation M2 (clear the flag *after* the poll) opens
/// the classic lost-wake window: the mid-poll wake sees `queued == true`,
/// skips the push, the flag is then cleared, and the task sleeps forever —
/// the checker finds the executor parked with a live task and reports a
/// deadlock.
///
/// The sender leaks its `Sender` (`mem::forget`) so the close-wake cannot
/// mask the lost value-wake.
#[test]
fn exec_queued_flag_dedup() {
    model::check(move || {
        let (tx, mut rx) = channel::<u32>();
        let sender = model::spawn(move || {
            tx.send(9).unwrap();
            // Leak the sender: no close-wake may rescue a lost value-wake.
            std::mem::forget(tx);
        });
        let exec = Executor::new();
        let got: Rc<Cell<u32>> = Rc::new(Cell::new(0));
        let got2 = got.clone();
        exec.handle().spawn(async move {
            if let Some(v) = rx.recv().await {
                got2.set(v);
            }
        });
        exec.run();
        assert_eq!(got.get(), 9, "task must complete with the sent value");
        sender.join();
    });
}

/// Family 3 — **worker park/unpark epoch handoff**: `ChunkPool::run` bumps
/// the epoch under the state lock, workers wake on `work_cv` and claim
/// chunks, and the submitter waits on `done_cv` until `active == 0` before
/// retiring the task. Two back-to-back jobs exercise a recycled worker
/// observing a second epoch bump. Mutation M3 (skip the `active > 0` wait)
/// lets `run` return while a worker still owes work; the checker finds an
/// interleaving where the post-`run` sum assertion fails.
#[test]
fn chunk_pool_epoch_handoff_completes_work() {
    model::check(move || {
        let pool = ChunkPool::new(1);
        let mut workers = Vec::new();
        pool.spawn_workers_with(|w| workers.push(model::spawn(w)));
        let sum = Arc::new(AtomicUsize::new(0));
        for round in 1..=2usize {
            let s = sum.clone();
            pool.run(2, 1, &move |a, b| {
                s.fetch_add(b - a, Ordering::SeqCst);
            });
            assert_eq!(
                sum.load(Ordering::SeqCst),
                2 * round,
                "run() returned before every chunk of epoch {round} was executed"
            );
        }
        pool.shutdown();
        for w in workers {
            w.join();
        }
    });
}

/// Family 4 — **task-pool drain on shutdown**: [`TaskPool`] workers honor
/// `stop` only after a pop comes up empty, so every job accepted before
/// `shutdown` still runs — even when the stop notify reaches a worker that
/// parked before the jobs were submitted. Mutation M5 (check `stop` before
/// popping) lets that worker exit with the queue non-empty; the checker
/// finds the interleaving where the drain counter comes up short.
#[test]
fn task_pool_drains_every_accepted_job_on_shutdown() {
    model::check(move || {
        let mut workers = Vec::new();
        let pool =
            TaskPool::with_spawner(1, TaskOrder::Fifo, |w| workers.push(model::spawn(w)));
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..2 {
            let d = done.clone();
            pool.submit(move || {
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        for w in workers {
            w.join();
        }
        assert_eq!(
            done.load(Ordering::SeqCst),
            2,
            "shutdown abandoned jobs accepted before it"
        );
    });
}

/// Family 5 — **flight-recorder ring writer vs snapshot drain**: the per-slot
/// seqlock in `obs::trace::ThreadRing` must never surface a torn event. The
/// writer wraps a tiny (2-slot) ring while a concurrent drain runs, so the
/// checker explores every overlap of overwrite and read. Each pushed event
/// carries a self-describing payload (`t = 10·i`, `a = i`, `b = i + 1`, slot
/// generation encodes `i`), so a drained event whose payload disagrees with
/// its own generation is *proof* of a torn read. Mutation M6 (publish the
/// even generation before the payload stores) lets the drain accept a slot
/// whose payload is still the previous write's; the checker finds the
/// interleaving where `a != seq` and reports the assertion failure.
///
/// After the writer joins, a quiescent drain must recover the last `cap`
/// events exactly — the overwrite path loses only the oldest data.
#[test]
fn trace_ring_drain_never_surfaces_torn_events() {
    model::check_with(ModelConfig::dfs(2), move || {
        let ring = Arc::new(ThreadRing::new(0, 2));
        let w = ring.clone();
        let writer = model::spawn(move || {
            for i in 0..3u64 {
                w.push(10 * i, EventKind::Enqueue as u64, i, i + 1);
            }
        });
        let mut out = Vec::new();
        ring.snapshot_into(&mut out);
        for e in &out {
            assert_eq!(e.a, e.seq, "payload `a` torn against the slot generation");
            assert_eq!(e.b, e.seq + 1, "payload `b` torn against the slot generation");
            assert_eq!(e.t_ns, 10 * e.seq, "timestamp torn against the slot generation");
            assert_eq!(e.kind, EventKind::Enqueue);
        }
        writer.join();
        out.clear();
        ring.snapshot_into(&mut out);
        out.sort_by_key(|e| e.seq);
        let seqs: Vec<u64> = out.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![1, 2], "quiescent drain must recover the last cap events");
    });
}

// ============================================================================
// MUTATIONS — deliberately-weakened variants the checker must catch.
//
// Each patch below was applied locally during development, the corresponding
// test observed to fail with a printed interleaving trace, and the patch then
// reverted. To re-validate, apply one patch, run
//
//     RUSTFLAGS="--cfg ciq_model" cargo test --test model_exec <test_name>
//
// and expect the named failure shape. Re-run a printed failing schedule
// deterministically by switching the test to
// `model::check_with(ModelConfig::random(<seed>, 1), ...)` with the seed from
// the trace (DFS traces replay by construction on the next run).
//
// ----------------------------------------------------------------------------
// M1 — channel close-wake dropped (caught by
//      `channel_close_vs_send_never_strands_receiver` as a DEADLOCK)
//
// --- rust/src/exec/channel.rs  (impl<T> Drop for Sender<T>)
//             if st.senders == 0 {
// -               st.waker.take()
// +               None // MUTATION M1: close no longer wakes the receiver
//             } else {
//                 None
//             }
//
// ----------------------------------------------------------------------------
// M2 — queued flag cleared after the poll instead of before (caught by
//      `exec_queued_flag_dedup` as a DEADLOCK: executor parked, task live)
//
// --- rust/src/exec/mod.rs  (Executor::run, step 1 drain loop)
// -               task.waker.queued.store(false, Ordering::Release);
//                 let waker = Waker::from(task.waker.clone());
//                 let mut cx = Context::from_waker(&waker);
//                 inner.shared.stats.polls.fetch_add(1, Ordering::Relaxed);
//                 match task.fut.as_mut().poll(&mut cx) {
// +               task.waker.queued.store(false, Ordering::Release);
//                   ^ MUTATION M2: a wake landing mid-poll is lost
//
// ----------------------------------------------------------------------------
// M3 — submitter no longer waits for workers before retiring the task
//      (caught by `chunk_pool_epoch_handoff_completes_work` as an ASSERTION
//      failure: sum too small after `run` returns)
//
// --- rust/src/util/threadpool.rs  (ChunkPool::run, step 4)
//         {
//             let mut guard = self.state.lock().unwrap();
// -           while guard.active > 0 {
// -               guard = self.done_cv.wait(guard).unwrap();
// -           }
// +           // MUTATION M3: retire the task while workers may still run it
//             guard.task = None;
//         }
//
// ----------------------------------------------------------------------------
// M4 — timer fire/cancel "first outcome wins" guard removed (caught *without*
//      the model by `exec::tests::cancel_racing_fire_at_same_tick_first_
//      outcome_wins`, and under the model by
//      `exec::model_tests::timer_fire_vs_cancel_outcome_is_sticky`)
//
// --- rust/src/exec/mod.rs  (SleepShared::finish)
//             let mut st = self.inner.lock().unwrap();
// -           if st.done.is_some() {
// -               return; // fire/cancel race: first outcome wins
// -           }
// +           // MUTATION M4: a later cancel/fire overwrites the outcome
//             st.done = Some(fired);
//
// ----------------------------------------------------------------------------
// M5 — task-pool worker honors `stop` before draining the queue (caught by
//      `task_pool_drains_every_accepted_job_on_shutdown` as an ASSERTION
//      failure: done == 0 after join — the worker parked before the jobs
//      arrived, woke on the shutdown notify, and exited with both jobs
//      still queued)
//
// --- rust/src/util/threadpool.rs  (task_pool_worker)
//             let mut st = shared.state.lock().unwrap();
//             loop {
// +               if st.stop {
// +                   break None; // MUTATION M5: exit before draining
// +               }
//                 let popped = match order {
//                     TaskOrder::Fifo => st.queue.pop_front(),
//                     TaskOrder::Lifo => st.queue.pop_back(),
//                 };
//
// ----------------------------------------------------------------------------
// M6 — ring slot published before the payload is written (caught by
//      `trace_ring_drain_never_surfaces_torn_events` as an ASSERTION
//      failure: a drained event's payload disagrees with its own slot
//      generation — e.g. `a != seq` — because the drain accepted a slot
//      whose even generation was visible while the payload still held the
//      previous write). This is an *algorithmic* reorder of the seqlock
//      publish, so the sequentially-consistent checker sees it directly; the
//      equivalent weak-memory bug (demoting the final store to `Relaxed`) is
//      Miri/TSan territory, same as the rest of this file.
//
// --- rust/src/obs/trace.rs  (ThreadRing::push)
//         let slot = &self.slots[(i as usize) & self.mask];
//         slot.seq.store(2 * i + 1, Ordering::Relaxed);
//         fence(Ordering::Release);
// +       slot.seq.store(2 * i + 2, Ordering::Release);
// +         ^ MUTATION M6: slot reads as cleanly published from here on
//         slot.t.store(t_ns, Ordering::Relaxed);
//         slot.kd.store(kind, Ordering::Relaxed);
//         slot.a.store(a, Ordering::Relaxed);
//         slot.b.store(b, Ordering::Relaxed);
// -       slot.seq.store(2 * i + 2, Ordering::Release);
// ============================================================================

//! Application-level smoke + correctness integration: SVGP, BO, Gibbs —
//! the three systems of Sec. 5 running on their real (synthetic) workloads.

use ciq::bo::testfns::Branin2;
use ciq::bo::{run_bo, BoConfig, Sampler};
use ciq::ciq::CiqOptions;
use ciq::data;
use ciq::gibbs::{reconstruct, GibbsConfig};
use ciq::operators::KernelType;
use ciq::rng::Pcg64;
use ciq::svgp::{evaluate, train, Backend, Bernoulli, Gaussian, StudentT, Svgp, SvgpHyper};

#[test]
fn svgp_all_three_likelihoods_train() {
    let mut rng = Pcg64::seeded(1);
    // (dataset, likelihood) triples mirroring Fig. 3
    let cases: Vec<(data::Dataset, Box<dyn ciq::svgp::Likelihood>)> = vec![
        (data::gaussian_regression(250, 2, 0.1, 1), Box::new(Gaussian { noise: 0.05 })),
        (data::student_t_regression(250, 2, 0.2, 4.0, 2), Box::new(StudentT { nu: 4.0, scale2: 0.05 })),
        (data::binary_classification(250, 2, 0.05, 3), Box::new(Bernoulli)),
    ];
    for (ds, lik) in cases {
        let z = ds.kmeans_centers(16, 4, &mut rng);
        let mut model = Svgp::new(
            z,
            KernelType::Rbf,
            SvgpHyper { lengthscale: 0.2, outputscale: 1.0, jitter: 1e-4 },
            lik,
            Backend::Ciq(CiqOptions { tol: 1e-4, max_iters: 150, ..Default::default() }),
        );
        let stats = train(&mut model, &ds, 20, 64, 0.4, 0.0, &mut rng).unwrap();
        let first = stats.ll_trace[0];
        let last = *stats.ll_trace.last().unwrap();
        assert!(
            last > first,
            "{}: LL should improve ({first} -> {last})",
            model.lik.name()
        );
        let m = evaluate(&mut model, &ds).unwrap();
        assert!(m.nll.is_finite(), "{} NLL not finite", model.lik.name());
    }
}

#[test]
fn svgp_more_inducing_points_fit_no_worse() {
    // Fig. 3's qualitative claim: NLL improves (or at least does not
    // degrade) with larger M.
    let ds = data::gaussian_regression(500, 2, 0.1, 5);
    let mut nlls = Vec::new();
    for m in [8usize, 48] {
        let mut rng = Pcg64::seeded(6);
        let z = ds.kmeans_centers(m, 5, &mut rng);
        let mut model = Svgp::new(
            z,
            KernelType::Rbf,
            SvgpHyper { lengthscale: 0.15, outputscale: 1.0, jitter: 1e-4 },
            Box::new(Gaussian { noise: 0.05 }),
            Backend::Cholesky,
        );
        train(&mut model, &ds, 40, 64, 0.5, 0.0, &mut rng).unwrap();
        nlls.push(evaluate(&mut model, &ds).unwrap().nll);
    }
    assert!(
        nlls[1] < nlls[0] + 0.05,
        "M=48 NLL {} should be <= M=8 NLL {}",
        nlls[1],
        nlls[0]
    );
}

#[test]
fn bo_larger_candidate_sets_no_worse() {
    // Fig. 4's qualitative claim over a few replications on Branin.
    let problem = Branin2;
    let mut small = Vec::new();
    let mut large = Vec::new();
    for rep in 0..2u64 {
        for (t, out) in [(32usize, &mut small), (384, &mut large)] {
            let cfg = BoConfig {
                candidates: t,
                evaluations: 20,
                init: 6,
                batch: 3,
                sampler: Sampler::Ciq,
                fit_steps: 6,
                ciq: ciq::ciq::CiqOptions { tol: 1e-3, max_iters: 120, ..Default::default() },
                ..Default::default()
            };
            out.push(run_bo(&problem, &cfg, 40 + rep).unwrap().best());
        }
    }
    let (ms, ml) = (ciq::util::mean(&small), ciq::util::mean(&large));
    assert!(ml <= ms + 0.5, "T=512 ({ml}) should be ≈≤ T=32 ({ms})");
}

#[test]
fn gibbs_posterior_mean_stable_across_seeds() {
    let cfg = GibbsConfig { n: 20, samples: 20, burn_in: 8, ..Default::default() };
    let r1 = reconstruct(&cfg, 1).unwrap();
    let r2 = reconstruct(&cfg, 2).unwrap();
    // different chains, same posterior: reconstructions should agree broadly
    let diff = ciq::util::rel_err(&r1.reconstruction, &r2.reconstruction);
    assert!(diff < 0.15, "chains disagree: {diff}");
    assert!(r1.mean_ciq_iters > 0.0);
}

#[test]
fn exact_gp_surrogate_pipeline() {
    // end-to-end surrogate: fit on Branin evals, posterior sampling sane
    use ciq::gp::{ExactGp, GpHyper};
    use ciq::linalg::Matrix;
    let problem = Branin2;
    let mut rng = Pcg64::seeded(8);
    let n = 25;
    let mut x = Matrix::zeros(n, 2);
    let mut y = Vec::new();
    for i in 0..n {
        let p = [rng.uniform(), rng.uniform()];
        x[(i, 0)] = p[0];
        x[(i, 1)] = p[1];
        y.push(ciq::bo::Problem::eval(&problem, &p));
    }
    let ym = ciq::util::mean(&y);
    let ys = ciq::util::std_dev(&y).max(1e-9);
    let y_std: Vec<f64> = y.iter().map(|v| (v - ym) / ys).collect();
    let mut gp = ExactGp::new(x, y_std, KernelType::Matern52, GpHyper::default());
    gp.fit_hypers(15, 0.1).unwrap();
    let cands = Matrix::randn(200, 2, &mut rng);
    let s = gp
        .sample_posterior_ciq(&cands, &CiqOptions { tol: 1e-5, ..Default::default() }, &mut rng)
        .unwrap();
    assert_eq!(s.len(), 200);
    assert!(s.iter().all(|v| v.is_finite()));
}

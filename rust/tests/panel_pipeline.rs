//! Integration tests for the panel-GEMM MVM engine and the persistent
//! worker pool: the panel pipeline must be bit-for-bit compatible with the
//! dense oracle through the whole CIQ stack, and the pool must spawn its
//! threads once per process, never per MVM.

use ciq::ciq::{Ciq, CiqOptions};
use ciq::linalg::Matrix;
use ciq::operators::{KernelOp, KernelType, LinearOp};
use ciq::rng::Pcg64;
use ciq::util::threadpool::{num_threads, pool_spawned_threads};
use ciq::util::rel_err;

fn data(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::seeded(seed);
    Matrix::randn(n, d, &mut rng)
}

#[test]
fn panel_matmat_matches_dense_oracle_all_kernels() {
    // N deliberately not divisible by any tile size in play
    let n = 101;
    let x = data(n, 3, 1);
    let mut rng = Pcg64::seeded(2);
    let b = Matrix::randn(n, 7, &mut rng);
    for kind in
        [KernelType::Rbf, KernelType::Matern12, KernelType::Matern32, KernelType::Matern52]
    {
        for tile in [8, 16, 33, 128] {
            let op = KernelOp::new(&x, kind, 0.6, 1.4, 0.02).with_tile(tile);
            let dense = op.to_dense();
            let got = op.matmat(&b);
            let want = dense.matmul(&b);
            assert!(
                got.max_abs_diff(&want) < 1e-10,
                "{kind:?} tile={tile} diff={}",
                got.max_abs_diff(&want)
            );
        }
    }
}

#[test]
fn ciq_whiten_sample_roundtrip_on_panel_engine() {
    let n = 120;
    let x = data(n, 4, 3);
    let op = KernelOp::new(&x, KernelType::Matern32, 0.9, 1.0, 0.5);
    let mut rng = Pcg64::seeded(4);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let solver = Ciq::new(CiqOptions { tol: 1e-8, ..Default::default() });
    let w = solver.invsqrt_mvm(&op, &b).expect("whiten").solution;
    let s = solver.sqrt_mvm(&op, &w).expect("sample").solution;
    assert!(rel_err(&s, &b) < 1e-4, "K^{{1/2}}·K^{{-1/2}}·b must round-trip");
}

#[test]
fn pool_spawns_once_across_many_mvms() {
    let n = 257;
    let x = data(n, 4, 5);
    let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 0.1).with_tile(32);
    let mut rng = Pcg64::seeded(6);
    let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    // warm up: first parallel call may lazily construct the pool
    let _ = op.matvec(&v);
    let after_first = pool_spawned_threads();
    let a = Matrix::randn(n, 40, &mut rng);
    for _ in 0..50 {
        let _ = op.matvec(&v);
        let _ = a.matmul(&a.transpose());
    }
    assert_eq!(
        pool_spawned_threads(),
        after_first,
        "~100 MVMs must not spawn a single new thread"
    );
    assert!(
        pool_spawned_threads() <= num_threads().saturating_sub(1),
        "pool size is bounded by num_threads() - 1 (the submitter participates)"
    );
}

#[test]
fn serial_override_matches_parallel_engine() {
    let n = 90;
    let x = data(n, 5, 7);
    let mut rng = Pcg64::seeded(8);
    let b = Matrix::randn(n, 4, &mut rng);
    for kind in [KernelType::Rbf, KernelType::Matern52] {
        let serial = KernelOp::new(&x, kind, 0.7, 1.1, 0.01).with_threads(1);
        let threaded = KernelOp::new(&x, kind, 0.7, 1.1, 0.01).with_threads(8);
        let diff = serial.matmat(&b).max_abs_diff(&threaded.matmat(&b));
        assert!(diff < 1e-12, "{kind:?}: serial and threaded engines must agree, diff={diff:e}");
    }
}

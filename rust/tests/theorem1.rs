//! Empirical verification of the paper's theory: Lemma 1 (quadrature error)
//! and Theorem 1 (total msMINRES-CIQ error bound).

use ciq::ciq::{Ciq, CiqOptions};
use ciq::linalg::eigen::spd_sqrt;
use ciq::linalg::Matrix;
use ciq::operators::DenseOp;
use ciq::prop_assert;
use ciq::quadrature::ciq_quadrature;
use ciq::rng::Pcg64;
use ciq::util::proptest::{check, Config};
use ciq::util::{norm2, rel_err};

/// Random SPD matrix with a prescribed spectrum (orthogonal conjugation).
fn spd_with_spectrum(evals: &[f64], rng: &mut Pcg64) -> Matrix {
    let n = evals.len();
    let a = Matrix::randn(n, n, rng);
    let q = ciq::baselines::rsvd::orthonormalize(&a);
    let mut scaled = q.clone();
    for j in 0..n {
        for i in 0..n {
            scaled[(i, j)] *= evals[j];
        }
    }
    scaled.matmul(&q.transpose())
}

#[test]
fn lemma1_quadrature_error_bound_holds_scalarwise() {
    // For scalars x ∈ [λmin, λmax]: |x Σ w/(t+x) − √x| ≤ C·exp(−2Qπ²/(log κ + 3))
    // with a modest constant C. Check C ≤ 10 over a sweep of κ and Q.
    for &kappa in &[10.0, 1e3, 1e6] {
        let (lo, hi) = (1.0 / kappa, 1.0);
        for q in [3usize, 5, 8, 12] {
            let rule = ciq_quadrature(q, lo, hi).unwrap();
            let bound = (-2.0 * q as f64 * std::f64::consts::PI.powi(2) / (kappa.ln() + 3.0)).exp();
            let mut worst: f64 = 0.0;
            for i in 0..=60 {
                let x = lo * (hi / lo as f64).powf(i as f64 / 60.0);
                let approx = x * rule.eval_inv_sqrt(x);
                worst = worst.max((approx - x.sqrt()).abs());
            }
            assert!(
                worst <= 10.0 * bound + 1e-14,
                "kappa={kappa} Q={q}: err {worst} vs bound {bound}"
            );
        }
    }
}

#[test]
fn theorem1_total_error_bounded() {
    // ‖a_J − K^{1/2}b‖ ≤ quadrature term + msMINRES term (Thm. 1).
    check(Config { cases: 6, seed: 42 }, "theorem 1", |rng, case| {
        let n = 30;
        // spectra of varying decay (the Fig. 1 families)
        let evals: Vec<f64> = match case % 3 {
            0 => (1..=n).map(|t| 1.0 / (t as f64).sqrt()).collect(),
            1 => (1..=n).map(|t| 1.0 / (t as f64).powi(2)).collect(),
            _ => (1..=n).map(|t| (-(t as f64) / 6.0).exp()).collect(),
        };
        let k = spd_with_spectrum(&evals, rng);
        let lam_max: f64 = evals[0];
        let lam_min: f64 = *evals.last().unwrap();
        let kappa = lam_max / lam_min;
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let op = DenseOp::new(k.clone());
        let exact = spd_sqrt(&k).unwrap().matvec(&b);

        for j in [5usize, 15, 40] {
            let q = 8;
            let solver = Ciq::new(CiqOptions {
                q_points: q,
                max_iters: j,
                tol: 1e-30,
                ..Default::default()
            });
            let approx = solver.sqrt_mvm(&op, &b).unwrap();
            let err = norm2(
                &approx
                    .solution
                    .iter()
                    .zip(&exact)
                    .map(|(a, e)| a - e)
                    .collect::<Vec<_>>(),
            );
            // Theorem 1 terms (constants included generously)
            let quad_term = (-2.0 * q as f64 * std::f64::consts::PI.powi(2) / (kappa.ln() + 3.0)).exp();
            let rho = (kappa.sqrt() - 1.0) / (kappa.sqrt() + 1.0);
            let minres_term = 2.0 * q as f64 * (5.0 * kappa.sqrt()).ln() * kappa * lam_min.sqrt()
                / std::f64::consts::PI
                * rho.powi(j as i32 - 1)
                * norm2(&b);
            let bound = 10.0 * (quad_term + minres_term) + 1e-9;
            prop_assert!(
                err <= bound,
                "J={j} kappa={kappa:.1}: err {err:.3e} > bound {bound:.3e}"
            );
        }
        Ok(())
    });
}

#[test]
fn error_decreases_exponentially_in_j() {
    // The msMINRES term dominates: error should drop geometrically with J.
    let mut rng = Pcg64::seeded(7);
    let n = 40;
    let evals: Vec<f64> = (1..=n).map(|t| 1.0 / t as f64).collect();
    let k = spd_with_spectrum(&evals, &mut rng);
    let op = DenseOp::new(k.clone());
    let exact_map = spd_sqrt(&k).unwrap();
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let exact = exact_map.matvec(&b);
    let errs: Vec<f64> = [4usize, 8, 16, 32]
        .iter()
        .map(|&j| {
            let solver = Ciq::new(CiqOptions {
                q_points: 10,
                max_iters: j,
                tol: 1e-30,
                ..Default::default()
            });
            rel_err(&solver.sqrt_mvm(&op, &b).unwrap().solution, &exact)
        })
        .collect();
    assert!(errs[1] < errs[0] && errs[2] < errs[1] && errs[3] < errs[2], "errors: {errs:?}");
    assert!(errs[3] < 1e-6, "final error {}", errs[3]);
}

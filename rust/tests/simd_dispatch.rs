//! End-to-end forced-backend property tests for the runtime SIMD dispatch
//! layer (`rust/DESIGN.md` §7).
//!
//! The unit tests inside `linalg::simd` compare each backend's function
//! pointers against the scalar oracles *directly* (no global state). This
//! binary covers the other half of the contract: with the process-wide
//! override forced to each detected backend via
//! [`ciq::linalg::simd::set_backend`], the **whole public surface** — dense
//! `Matrix` products and the kernel operator's panel MVM / gradient
//! contraction — must agree with the per-entry scalar oracles.
//!
//! The override is process-global, so every test here funnels through
//! [`forced_backends`], which serializes on a `Mutex` and always restores
//! auto dispatch, even across the harness's parallel test threads.

use ciq::linalg::simd::{self, Backend};
use ciq::linalg::{Matrix, SolveWorkspace};
use ciq::operators::{KernelOp, KernelType, LinearOp};
use ciq::rng::Pcg64;
use std::sync::Mutex;

/// One guard for the process-global backend override.
static BACKEND_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` once per *available* backend (scalar always included), with the
/// global override forced to that backend for the duration, then restore
/// auto dispatch.
fn forced_backends(mut f: impl FnMut(Backend)) {
    let _g = BACKEND_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for b in Backend::all() {
        if !b.available() {
            // Forcing an unavailable backend must fail cleanly and must not
            // disturb whatever override is currently in place.
            assert!(simd::set_backend(b).is_err(), "{b:?} unavailable yet accepted");
            continue;
        }
        simd::set_backend(b).expect("available backend must be accepted");
        assert_eq!(simd::backend(), b, "override did not take effect");
        f(b);
    }
    simd::clear_backend_override();
}

fn data(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::seeded(seed);
    Matrix::randn(n, d, &mut rng)
}

const KINDS: [KernelType; 4] =
    [KernelType::Rbf, KernelType::Matern12, KernelType::Matern32, KernelType::Matern52];

#[test]
fn kernel_matmat_matches_naive_oracle_under_every_forced_backend() {
    // Sizes straddle the panel tile and the SIMD lane widths (2/4/8) so both
    // full lanes and scalar remainder tails run on every backend.
    forced_backends(|backend| {
        for &(n, d, r) in &[(1usize, 1usize, 1usize), (13, 3, 2), (34, 4, 5), (61, 2, 7)] {
            let x = data(n, d, 21);
            let mut rng = Pcg64::seeded(22);
            let b = Matrix::randn(n, r, &mut rng);
            for kind in KINDS {
                let op = KernelOp::new(&x, kind, 0.7, 1.3, 1e-2).with_tile(16);
                let got = op.matmat(&b);
                let want = op.matmat_naive(&b);
                let diff = got.max_abs_diff(&want);
                assert!(
                    diff < 1e-10,
                    "{backend:?} kind={kind:?} n={n} d={d} r={r} diff={diff:e}"
                );
            }
        }
    });
}

#[test]
fn kernel_grad_contract_matches_naive_oracle_under_every_forced_backend() {
    forced_backends(|backend| {
        for &(n, d) in &[(1usize, 1usize), (17, 2), (45, 3)] {
            let x = data(n, d, 31);
            let mut rng = Pcg64::seeded(32);
            let l: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let r: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            for kind in KINDS {
                let op = KernelOp::new(&x, kind, 0.6, 1.1, 1e-3).with_tile(16);
                let (ge, gs) = op.grad_contract(&l, &r);
                let (ne, ns) = op.grad_contract_naive(&l, &r);
                assert!(
                    (ge - ne).abs() < 1e-10 * (1.0 + ne.abs()),
                    "{backend:?} kind={kind:?} n={n} ell grad {ge} vs {ne}"
                );
                assert!(
                    (gs - ns).abs() < 1e-10 * (1.0 + ns.abs()),
                    "{backend:?} kind={kind:?} n={n} s2 grad {gs} vs {ns}"
                );
            }
        }
    });
}

#[test]
fn kernel_mixed_matmat_stays_within_f32_forward_error_under_every_forced_backend() {
    // The precision axis of the dispatch matrix: the mixed pipeline stores
    // panels in f32 and accumulates in f64, so its documented per-entry
    // bound against the f64 oracle is O(ε₃₂) of the row scale — the hybrid
    // 5e-4 tolerance mirrors linalg::mixed's own backend equivalence tests.
    forced_backends(|backend| {
        let mut ws = SolveWorkspace::new();
        for &(n, d, r) in &[(13usize, 3usize, 2usize), (34, 4, 5), (61, 2, 7)] {
            let x = data(n, d, 51);
            let mut rng = Pcg64::seeded(52);
            let b = Matrix::randn(n, r, &mut rng);
            for kind in KINDS {
                let op = KernelOp::new(&x, kind, 0.7, 1.3, 1e-2).with_tile(16);
                assert!(op.supports_mixed(), "kernel operator must expose the mixed path");
                let want = op.matmat(&b);
                let mut got = Matrix::zeros(n, r);
                op.matmat_mixed_in(&mut ws, &b, &mut got);
                for j in 0..r {
                    for i in 0..n {
                        let (g, w) = (got[(i, j)], want[(i, j)]);
                        assert!(
                            (g - w).abs() <= 5e-4 * (1.0 + w.abs()),
                            "{backend:?} kind={kind:?} n={n} d={d} r={r} ({i},{j}): {g} vs {w}"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn kernel_mixed_grad_contract_stays_within_f32_forward_error_under_every_forced_backend() {
    forced_backends(|backend| {
        for &(n, d) in &[(17usize, 2usize), (45, 3)] {
            let x = data(n, d, 61);
            let mut rng = Pcg64::seeded(62);
            let l: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let r: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            for kind in KINDS {
                let op = KernelOp::new(&x, kind, 0.6, 1.1, 1e-3).with_tile(16);
                let (ge, gs) = op.grad_contract_mixed(&l, &r);
                let (we, ws_) = op.grad_contract(&l, &r);
                // f32 distance panel, f64 contraction sums: same hybrid
                // forward-error budget as the mixed matmat above
                assert!(
                    (ge - we).abs() <= 5e-4 * (1.0 + we.abs()),
                    "{backend:?} kind={kind:?} n={n} ell grad {ge} vs {we}"
                );
                assert!(
                    (gs - ws_).abs() <= 5e-4 * (1.0 + ws_.abs()),
                    "{backend:?} kind={kind:?} n={n} s2 grad {gs} vs {ws_}"
                );
            }
        }
    });
}

#[test]
fn matrix_products_agree_with_forced_scalar_reference() {
    // Reference results computed with the scalar kernels forced; every other
    // available backend must match them to accumulation-order tolerance.
    let mut rng = Pcg64::seeded(41);
    let a = Matrix::randn(23, 17, &mut rng);
    let b = Matrix::randn(17, 11, &mut rng);
    let v: Vec<f64> = (0..17).map(|_| rng.normal()).collect();
    let vt: Vec<f64> = (0..23).map(|_| rng.normal()).collect();
    let mut scalar_mm: Option<Matrix> = None;
    let mut scalar_mv: Option<Vec<f64>> = None;
    let mut scalar_mvt: Option<Vec<f64>> = None;
    forced_backends(|backend| {
        let mm = a.matmul(&b);
        let mv = a.matvec(&v);
        let mvt = a.matvec_t(&vt);
        if backend == Backend::Scalar {
            // Backend::all() lists scalar first, so the reference fills
            // before any SIMD backend is compared against it.
            scalar_mm = Some(mm);
            scalar_mv = Some(mv);
            scalar_mvt = Some(mvt);
            return;
        }
        let diff = mm.max_abs_diff(scalar_mm.as_ref().expect("scalar ran first"));
        assert!(diff < 1e-12, "{backend:?} matmul drift {diff:e}");
        for (got, want) in mv.iter().zip(scalar_mv.as_ref().unwrap()) {
            assert!((got - want).abs() < 1e-12, "{backend:?} matvec drift");
        }
        for (got, want) in mvt.iter().zip(scalar_mvt.as_ref().unwrap()) {
            assert!((got - want).abs() < 1e-12, "{backend:?} matvec_t drift");
        }
    });
}

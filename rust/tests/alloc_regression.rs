//! Allocation-pressure regression tests: with a counting global allocator
//! installed, a **warmed** workspace-backed solve in the krylov/ciq layers
//! must perform **zero** heap allocations — the steady-state contract the
//! coordinator's per-flush workspace pool relies on.
//!
//! Every test pins `CIQ_THREADS=1` *before* the first parallel call so the
//! whole solve executes on the measuring thread (the allocator's counter is
//! thread-local; with worker threads parked out of existence, "no
//! allocations observed" really means "no allocations anywhere in the
//! solve"). The env var is read once per process, so all tests in this
//! binary run serial — which is exactly what an allocation census wants.
//!
//! The headline proofs run under **both** the forced-scalar kernels and the
//! best detected SIMD backend ([`with_backends`]): the dispatch layer's
//! promise is a resolved function-pointer table, so flipping backends must
//! not reintroduce per-call heap traffic anywhere in the solve stack.

use ciq::ciq::dense_sqrt::{newton_schulz_stack_in, DenseFactorStack, DenseSqrtOptions};
use ciq::ciq::{recycle_block_result, Ciq, CiqOptions, SolveKind, SolverPolicy};
use ciq::coordinator::Metrics;
use ciq::krylov::msminres::{msminres_block_in, msminres_in, MsMinresOptions};
use ciq::linalg::batched::{gemm_nn_batched, gemv_nn_batched};
use ciq::linalg::{gemm, simd, Matrix, Precision, RefineConfig, SolveWorkspace};
use ciq::obs::trace::EventKind;
use ciq::obs::{solvetrace, trace};
use ciq::operators::DenseOp;
use ciq::rng::Pcg64;
use ciq::util::allocs::{thread_allocs, CountingAllocator};
use std::sync::Mutex;
use std::time::Duration;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Force the solve stack fully serial so the thread-local allocation
/// counter sees every allocation the solve performs.
fn serial_mode() {
    std::env::set_var("CIQ_THREADS", "1");
}

/// Serializes process-global observability state (backend override, flight
/// recorder, trajectory sampler) across this binary's test threads: a census
/// must never observe another test's recorder flipping mid-measurement (an
/// unregistered thread ring or a fresh history checkout would show up as an
/// allocation in the wrong test).
static BACKEND_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` once with the scalar kernels forced and once with the best
/// detected SIMD backend, then restore auto dispatch. The zero-alloc
/// contract must hold identically on both sides.
fn with_backends(mut f: impl FnMut(simd::Backend)) {
    let _g = BACKEND_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for b in [simd::Backend::Scalar, simd::best_available()] {
        simd::set_backend(b).expect("backend reported available");
        f(b);
    }
    simd::clear_backend_override();
}

fn random_spd(n: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::seeded(seed);
    let a = Matrix::randn(n, n, &mut rng);
    let mut k = a.matmul(&a.transpose());
    for i in 0..n {
        k[(i, i)] += n as f64 * 0.5;
    }
    k
}

#[test]
fn counting_allocator_counts_this_thread() {
    serial_mode();
    let before = thread_allocs();
    let v: Vec<u64> = Vec::with_capacity(1024);
    assert!(thread_allocs() > before, "allocator failed to count an allocation");
    drop(v);
}

#[test]
fn warmed_msminres_in_performs_zero_heap_allocations() {
    serial_mode();
    let n = 48;
    let k = random_spd(n, 1);
    let op = DenseOp::new(k);
    let mut rng = Pcg64::seeded(2);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let shifts = [0.1, 1.0, 10.0];
    let opts = MsMinresOptions { max_iters: 200, tol: 1e-9, weights: None };
    let mut ws = SolveWorkspace::new();
    with_backends(|backend| {
        // warm-up: first touch grows the pool
        for _ in 0..2 {
            msminres_in(&mut ws, &op, &b, &shifts, &opts).recycle(&mut ws);
        }
        let grows = ws.grows();
        let allocs_before = thread_allocs();
        for _ in 0..3 {
            let sol = msminres_in(&mut ws, &op, &b, &shifts, &opts);
            assert!(sol.converged);
            sol.recycle(&mut ws);
        }
        assert_eq!(
            thread_allocs() - allocs_before,
            0,
            "warmed msminres_in touched the heap under {backend:?}"
        );
        assert_eq!(ws.grows(), grows);
    });
}

#[test]
fn warmed_ciq_solve_block_in_performs_zero_heap_allocations() {
    serial_mode();
    let n = 40;
    let r = 4;
    let k = random_spd(n, 3);
    let op = DenseOp::new(k);
    let mut rng = Pcg64::seeded(4);
    let b = Matrix::randn(n, r, &mut rng);
    let solver = Ciq::new(CiqOptions { tol: 1e-8, ..Default::default() });
    let ctx = solver.build_context(&op, &SolverPolicy::CachedBounds).unwrap();
    let mut ws = SolveWorkspace::new();
    with_backends(|backend| {
        for kind in [SolveKind::InvSqrt, SolveKind::Sqrt] {
            // warm-up for this solve shape
            for _ in 0..2 {
                let res = solver.solve_block_in(&mut ws, &op, &b, kind, &ctx).unwrap();
                recycle_block_result(&mut ws, res);
            }
            // the acceptance measurement: the whole krylov→ciq block solve,
            // steady state, zero allocations
            let allocs_before = thread_allocs();
            for _ in 0..3 {
                let res = solver.solve_block_in(&mut ws, &op, &b, kind, &ctx).unwrap();
                recycle_block_result(&mut ws, res);
            }
            assert_eq!(
                thread_allocs() - allocs_before,
                0,
                "warmed solve_block_in ({kind:?}) touched the heap under {backend:?}"
            );
        }
    });
}

#[test]
fn warmed_mixed_precision_solve_block_in_performs_zero_heap_allocations() {
    // The mixed-precision tier's steady-state contract: the f32 panel slabs,
    // the f64 residual carriers, and the refinement sweeps' Krylov scratch
    // are all drawn from the same workspace pool — once warm, a refined
    // solve is exactly as alloc-free as the pure-f64 one it wraps.
    serial_mode();
    let n = 40;
    let r = 4;
    let k = random_spd(n, 11);
    let op = DenseOp::new(k);
    let mut rng = Pcg64::seeded(12);
    let b = Matrix::randn(n, r, &mut rng);
    let solver = Ciq::new(CiqOptions {
        tol: 1e-8,
        precision: Precision::Mixed(RefineConfig::default()),
        ..Default::default()
    });
    let ctx = solver.build_context(&op, &SolverPolicy::CachedBounds).unwrap();
    assert!(ctx.precision.is_mixed(), "cached-bounds context must carry the mixed policy");
    let mut ws = SolveWorkspace::new();
    with_backends(|backend| {
        for kind in [SolveKind::InvSqrt, SolveKind::Sqrt] {
            // warm-up: grows the f64 pool *and* the f32 slab pool
            for _ in 0..2 {
                let res = solver.solve_block_in(&mut ws, &op, &b, kind, &ctx).unwrap();
                assert!(!res.precision_fallback, "well-conditioned solve must not fall back");
                recycle_block_result(&mut ws, res);
            }
            let allocs_before = thread_allocs();
            for _ in 0..3 {
                let res = solver.solve_block_in(&mut ws, &op, &b, kind, &ctx).unwrap();
                recycle_block_result(&mut ws, res);
            }
            assert_eq!(
                thread_allocs() - allocs_before,
                0,
                "warmed mixed solve_block_in ({kind:?}) touched the heap under {backend:?}"
            );
        }
    });
}

#[test]
fn warmed_single_vector_solve_in_performs_zero_heap_allocations() {
    serial_mode();
    let _g = BACKEND_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let n = 32;
    let k = random_spd(n, 5);
    let op = DenseOp::new(k);
    let mut rng = Pcg64::seeded(6);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let solver = Ciq::new(CiqOptions { tol: 1e-8, ..Default::default() });
    let ctx = solver.build_context(&op, &SolverPolicy::CachedBounds).unwrap();
    let mut ws = SolveWorkspace::new();
    for _ in 0..2 {
        let res = solver.solve_in(&mut ws, &op, &b, SolveKind::InvSqrt, &ctx).unwrap();
        ws.give_vec(res.solution);
    }
    let allocs_before = thread_allocs();
    for _ in 0..3 {
        let res = solver.solve_in(&mut ws, &op, &b, SolveKind::InvSqrt, &ctx).unwrap();
        ws.give_vec(res.solution);
    }
    assert_eq!(thread_allocs() - allocs_before, 0, "warmed solve_in touched the heap");
}

#[test]
fn warmed_block_engine_is_alloc_free_even_with_compaction() {
    // Heterogeneous columns: compaction shrinks the panel mid-solve, which
    // swaps panels through the pool — still zero allocations once warm.
    serial_mode();
    let _g = BACKEND_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let n = 36;
    let mut k = Matrix::zeros(n, n);
    for i in 0..n {
        k[(i, i)] = 1.0 + i as f64;
    }
    let op = DenseOp::new(k);
    let mut rng = Pcg64::seeded(7);
    let mut b = Matrix::zeros(n, 4);
    b[(0, 0)] = 1.0; // eigenvector: converges on iteration 1 → early retire
    for j in 1..4 {
        for i in 0..n {
            b[(i, j)] = rng.normal();
        }
    }
    let shifts = [0.1, 1.0];
    let opts = MsMinresOptions { max_iters: 200, tol: 1e-10, weights: None };
    let mut ws = SolveWorkspace::new();
    for _ in 0..2 {
        msminres_block_in(&mut ws, &op, &b, &shifts, &opts).recycle(&mut ws);
    }
    let allocs_before = thread_allocs();
    let sol = msminres_block_in(&mut ws, &op, &b, &shifts, &opts);
    assert!(sol.column_work > 0);
    sol.recycle(&mut ws);
    assert_eq!(
        thread_allocs() - allocs_before,
        0,
        "compacting block solve touched the heap when warm"
    );
}

#[test]
fn warmed_batched_dense_solve_performs_zero_heap_allocations() {
    // The batched-dense tier's steady state: a coupled Newton–Schulz
    // factorization over a whole stack of small operators plus the batched
    // GEMV apply, all scratch drawn from the workspace and the factor stack
    // reused across solves — zero heap allocations once warm.
    serial_mode();
    let n = 24;
    let batch = 6;
    let nn = n * n;
    let mut a_stack = vec![0.0; batch * nn];
    for i in 0..batch {
        a_stack[i * nn..(i + 1) * nn].copy_from_slice(random_spd(n, 10 + i as u64).as_slice());
    }
    let mut rng = Pcg64::seeded(8);
    let xs_src: Vec<f64> = (0..batch * n).map(|_| rng.normal()).collect();
    let opts = DenseSqrtOptions::default();
    // the factor stack is the once-per-operator-version allocation
    let mut stack = DenseFactorStack::new(n, batch);
    let mut ws = SolveWorkspace::new();
    let mut solve_and_apply = |ws: &mut SolveWorkspace, stack: &mut DenseFactorStack| {
        newton_schulz_stack_in(ws, n, batch, &a_stack, &opts, stack);
        assert!(stack.all_converged(), "well-conditioned stack must converge");
        let mut xs = ws.take_vec(batch * n);
        let mut ys = ws.take_vec(batch * n);
        xs.copy_from_slice(&xs_src);
        gemv_nn_batched(batch, n, &stack.invsqrt, &xs, &mut ys);
        ws.give_vec(ys);
        ws.give_vec(xs);
    };
    with_backends(|backend| {
        for _ in 0..2 {
            solve_and_apply(&mut ws, &mut stack);
        }
        let grows = ws.grows();
        let allocs_before = thread_allocs();
        for _ in 0..3 {
            solve_and_apply(&mut ws, &mut stack);
        }
        assert_eq!(
            thread_allocs() - allocs_before,
            0,
            "warmed batched Newton–Schulz solve + apply touched the heap under {backend:?}"
        );
        assert_eq!(ws.grows(), grows, "steady-state batched solve grew the workspace");
    });
}

#[test]
fn batched_pack_scratch_growth_is_bounded_across_size_classes() {
    // The batched tier reuses each worker thread's B-panel pack across every
    // element it claims; the scratch must grow to the *running max* `k·NR`
    // seen so far and never beyond — no per-class or per-element churn. With
    // `CIQ_THREADS=1` the only worker is this thread, so `thread_pack_len`
    // observes exactly the scratch the batched path uses.
    serial_mode();
    let batch = 4;
    // deliberately non-monotone size classes: growth must track the max only
    let classes = [8usize, 32, 16, 64, 24, 64, 8];
    let mut max_k = 0usize;
    for &k in &classes {
        max_k = max_k.max(k);
        let (m, n) = (k, k); // n = k ≥ NR, so every class exercises packing
        let a = vec![0.5; batch * m * k];
        let b = vec![0.25; batch * k * n];
        let mut c = vec![0.0; batch * m * n];
        gemm_nn_batched(batch, m, k, n, &a, &b, &mut c);
        assert_eq!(
            gemm::thread_pack_len(),
            max_k * gemm::NR,
            "pack scratch after size class k={k}"
        );
    }
    // steady state: re-running an already-seen class allocates nothing and
    // leaves the scratch exactly at the high-water mark
    let k = 32;
    let a = vec![0.5; batch * k * k];
    let b = vec![0.25; batch * k * k];
    let mut c = vec![0.0; batch * k * k];
    let allocs_before = thread_allocs();
    for _ in 0..3 {
        gemm_nn_batched(batch, k, k, k, &a, &b, &mut c);
    }
    assert_eq!(
        thread_allocs() - allocs_before,
        0,
        "warmed batched GEMM re-packed through the heap"
    );
    assert_eq!(gemm::thread_pack_len(), max_k * gemm::NR, "pack left the high-water mark");
}

#[test]
fn fully_instrumented_completion_path_performs_zero_heap_allocations() {
    // The observability layer's headline contract: with the flight recorder
    // ON and residual-trajectory sampling at 1-in-1, the completion path —
    // histogram records, trace! events, percentile reads, and a sampled
    // block solve — still performs zero heap allocations once warm.
    serial_mode();
    let _g = BACKEND_LOCK.lock().unwrap_or_else(|e| e.into_inner());

    // Satellite regression: the histogram-backed percentile distinguishes
    // "no data" (None) and is an O(buckets) walk, not a clone-and-sort.
    let m = Metrics::default();
    assert_eq!(m.latency_percentile(50.0), None, "empty histogram must report None");

    let n = 36;
    let k = random_spd(n, 9);
    let op = DenseOp::new(k);
    let mut rng = Pcg64::seeded(10);
    let b = Matrix::randn(n, 3, &mut rng);
    let shifts = [0.1, 1.0];
    let opts = MsMinresOptions { max_iters: 200, tol: 1e-9, weights: None };
    let mut ws = SolveWorkspace::new();

    trace::set_enabled(true);
    solvetrace::configure(1); // sample every solve; allocates the slab here
    // Warm-up: registers this thread's event ring (the one-time allocation),
    // pools the block solver's history scratch, grows the solve pool.
    ciq::trace!(EventKind::Enqueue, 0u64, 0u64);
    for _ in 0..2 {
        msminres_block_in(&mut ws, &op, &b, &shifts, &opts).recycle(&mut ws);
    }

    let allocs_before = thread_allocs();
    for i in 0..3u64 {
        // coordinator completion-path telemetry: wait-free histogram records
        m.record_latency(Duration::from_micros(100 + i));
        m.record_batch(8);
        m.record_iters(&[21, 34]);
        // flight recorder, enabled: atomics into the pre-registered ring
        ciq::trace!(EventKind::Enqueue, i, 1u64);
        ciq::trace!(EventKind::Respond, i, 104u64);
        // a sampled solve: history from the workspace pool, trajectory
        // published into the pre-allocated slab
        msminres_block_in(&mut ws, &op, &b, &shifts, &opts).recycle(&mut ws);
        assert!(m.latency_percentile(99.0).is_some());
    }
    assert_eq!(
        thread_allocs() - allocs_before,
        0,
        "instrumented completion path (histograms + trace! + sampled solve) touched the heap"
    );

    solvetrace::configure(0);
    trace::set_enabled(false);
    // The census is over; draining (which allocates) must see the samples.
    let trajs = solvetrace::drain();
    assert!(trajs.len() >= 3, "sampled solves published {} trajectories", trajs.len());
    assert_eq!(m.latency_percentile(50.0).map(|v| v >= 100), Some(true));
}

//! Cross-module Krylov invariants, incl. property tests on the msMINRES
//! recurrence and Lanczos shift invariance (Obs. 1 of the paper).

use ciq::krylov::lanczos::lanczos_tridiag;
use ciq::krylov::msminres::{msminres, MsMinresOptions};
use ciq::krylov::{minres, pcg, CgOptions};
use ciq::linalg::{Cholesky, Matrix};
use ciq::operators::{DenseOp, KernelOp, KernelType, LinearOp, ShiftedOp};
use ciq::prop_assert;
use ciq::rng::Pcg64;
use ciq::util::proptest::{check, Config};
use ciq::util::rel_err;

fn random_spd(n: usize, ridge: f64, rng: &mut Pcg64) -> Matrix {
    let a = Matrix::randn(n, n, rng);
    let mut k = a.matmul(&a.transpose());
    for i in 0..n {
        k[(i, i)] += ridge;
    }
    k
}

#[test]
fn property_shift_invariance_of_lanczos() {
    // Obs. 1: Lanczos on K+tI yields the same basis, T shifted by tI.
    check(Config { cases: 16, seed: 10 }, "lanczos shift invariance", |rng, _| {
        let n = 15 + rng.below(10);
        let k = random_spd(n, n as f64, rng);
        let t = 1.0 + rng.uniform() * 10.0;
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let op = DenseOp::new(k.clone());
        let shifted = ShiftedOp::new(&op, t);
        let (a1, b1) = lanczos_tridiag(&op, &b, 8, true);
        let (a2, b2) = lanczos_tridiag(&shifted, &b, 8, true);
        for (x, y) in a1.iter().zip(&a2) {
            prop_assert!((x + t - y).abs() < 1e-8, "alpha mismatch {x}+{t} vs {y}");
        }
        for (x, y) in b1.iter().zip(&b2) {
            prop_assert!((x - y).abs() < 1e-8, "beta mismatch {x} vs {y}");
        }
        Ok(())
    });
}

#[test]
fn property_residual_monotone_nonincreasing_iterations() {
    // More iterations never increase the tracked msMINRES residual.
    check(Config { cases: 12, seed: 20 }, "residual monotonicity", |rng, _| {
        let n = 25;
        let k = random_spd(n, 2.0, rng);
        let op = DenseOp::new(k);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let shifts = [0.1, 5.0];
        let mut prev = f64::INFINITY;
        for iters in [3, 6, 12, 24] {
            let res = msminres(
                &op,
                &b,
                &shifts,
                &MsMinresOptions { max_iters: iters, tol: 1e-30, weights: None },
            );
            let r = res.residuals[0];
            prop_assert!(r <= prev + 1e-9, "residual grew: {prev} -> {r} at {iters}");
            prev = r;
        }
        Ok(())
    });
}

#[test]
fn property_solutions_live_in_krylov_space() {
    // After J iterations the solution must be expressible in the span of
    // {b, Kb, ..., K^{J-1}b}; verify via orthogonal projection.
    check(Config { cases: 8, seed: 30 }, "solution in Krylov space", |rng, _| {
        let n = 20;
        let j = 6;
        let k = random_spd(n, n as f64, rng);
        let op = DenseOp::new(k.clone());
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let res = msminres(
            &op,
            &b,
            &[0.7],
            &MsMinresOptions { max_iters: j, tol: 1e-30, weights: None },
        );
        // build Krylov basis (orthonormalized)
        let mut basis: Vec<Vec<f64>> = Vec::new();
        let mut v = b.clone();
        for _ in 0..j {
            let mut w = v.clone();
            for q in &basis {
                let c = ciq::util::dot(q, &w);
                ciq::util::axpy(-c, q, &mut w);
            }
            let nw = ciq::util::norm2(&w);
            if nw < 1e-12 {
                break;
            }
            basis.push(w.iter().map(|x| x / nw).collect());
            v = k.matvec(&v);
        }
        // project solution onto basis; projection must reproduce it
        let x = &res.solutions[0];
        let mut proj = vec![0.0; n];
        for q in &basis {
            let c = ciq::util::dot(q, x);
            ciq::util::axpy(c, q, &mut proj);
        }
        let err = rel_err(&proj, x);
        prop_assert!(err < 1e-6, "solution leaves Krylov space: {err}");
        Ok(())
    });
}

#[test]
fn minres_cg_msminres_agree_on_spd() {
    let mut rng = Pcg64::seeded(40);
    let n = 60;
    let x = Matrix::randn(n, 2, &mut rng);
    let op = KernelOp::new(&x, KernelType::Matern32, 0.7, 1.0, 0.5);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let (x1, _, _) = minres(&op, &b, 400, 1e-10);
    let (x2, _, _) = pcg(&op, &b, None, &CgOptions { max_iters: 400, tol: 1e-12 });
    let ms = msminres(&op, &b, &[0.0], &MsMinresOptions { max_iters: 400, tol: 1e-10, weights: None });
    let exact = Cholesky::with_jitter(&op.to_dense(), 0.0).unwrap().solve(&b);
    assert!(rel_err(&x1, &exact) < 1e-6);
    assert!(rel_err(&x2, &exact) < 1e-6);
    assert!(rel_err(&ms.solutions[0], &exact) < 1e-6);
}

#[test]
fn iteration_count_scales_with_condition_number() {
    // well-conditioned (big noise) converges much faster than ill-conditioned
    let mut rng = Pcg64::seeded(50);
    let n = 200;
    let x = Matrix::randn(n, 1, &mut rng);
    let well = KernelOp::new(&x, KernelType::Rbf, 0.5, 1.0, 1.0);
    let ill = KernelOp::new(&x, KernelType::Rbf, 0.5, 1.0, 1e-4);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let opts = MsMinresOptions { max_iters: 1000, tol: 1e-6, weights: None };
    let r_well = msminres(&well, &b, &[0.0], &opts);
    let r_ill = msminres(&ill, &b, &[0.0], &opts);
    assert!(
        r_well.iterations < r_ill.iterations,
        "well {} vs ill {}",
        r_well.iterations,
        r_ill.iterations
    );
}

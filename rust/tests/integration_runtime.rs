//! Integration: PJRT runtime executes the AOT artifacts and matches the
//! native Rust implementations. Skips (with a notice) if `make artifacts`
//! has not been run.

use ciq::ciq::{Ciq, CiqOptions};
use ciq::linalg::Matrix;
use ciq::operators::{KernelOp, KernelType, LinearOp};
use ciq::rng::Pcg64;
use ciq::runtime::{artifacts_dir, discover_artifacts, Runtime, XlaCiq, XlaKernelMvm};
use ciq::util::rel_err;

fn artifacts_available() -> bool {
    !discover_artifacts(&artifacts_dir()).is_empty()
}

#[test]
fn xla_kernel_mvm_matches_native() {
    if !artifacts_available() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    }
    let metas = discover_artifacts(&artifacts_dir());
    let meta = metas
        .iter()
        .find(|m| m.kind == "kernel_mvm" && m.kernel == "rbf")
        .expect("rbf kernel_mvm artifact");
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(meta).unwrap();

    let mut rng = Pcg64::seeded(1);
    let x = Matrix::randn(meta.n, meta.d, &mut rng);
    let (ell, s2, noise) = (0.8, 1.3, 0.05);
    let xla_op = XlaKernelMvm::new(&rt, exe, &x, ell, s2, noise).unwrap();
    let native = KernelOp::new(&x, KernelType::Rbf, ell, s2, noise);

    // single vector
    let v: Vec<f64> = (0..meta.n).map(|_| rng.normal()).collect();
    let y_xla = xla_op.matvec(&v);
    let y_native = native.matvec(&v);
    let err = rel_err(&y_xla, &y_native);
    assert!(err < 1e-4, "xla vs native MVM rel err {err}");

    // batch wider than the artifact's r (exercises padding & chunking)
    let b = Matrix::randn(meta.n, meta.r + 3, &mut rng);
    let y_xla = xla_op.matmat(&b);
    let y_native = native.matmat(&b);
    let mut max_err = 0.0f64;
    for j in 0..b.cols() {
        max_err = max_err.max(rel_err(&y_xla.col(j), &y_native.col(j)));
    }
    assert!(max_err < 1e-4, "batched rel err {max_err}");
}

#[test]
fn xla_ciq_pipeline_matches_native_ciq() {
    if !artifacts_available() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    }
    let metas = discover_artifacts(&artifacts_dir());
    let meta = metas.iter().find(|m| m.kind == "ciq_sqrt").expect("ciq artifact");
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(meta).unwrap();
    let xla_ciq = XlaCiq::new(&rt, exe).unwrap();

    let mut rng = Pcg64::seeded(2);
    let x = Matrix::randn(meta.n, meta.d, &mut rng);
    let (ell, s2, noise) = (0.8, 1.0, 0.5);
    let native = KernelOp::new(&x, KernelType::Rbf, ell, s2, noise);
    let b: Vec<f64> = (0..meta.n).map(|_| rng.normal()).collect();

    // quadrature from the Rust side (Lanczos + elliptic functions)
    let solver = Ciq::new(CiqOptions { q_points: meta.q, tol: 1e-7, ..Default::default() });
    let (rule, _bounds) = solver.rule(&native, None).unwrap();

    let out = xla_ciq
        .run(&x, ell, s2, noise, &b, &rule.shifts, &rule.weights)
        .unwrap();

    let native_sqrt = solver.sqrt_mvm(&native, &b).unwrap().solution;
    let native_inv = solver.invsqrt_mvm(&native, &b).unwrap().solution;
    let es = rel_err(&out.sqrt, &native_sqrt);
    let ei = rel_err(&out.inv_sqrt, &native_inv);
    assert!(es < 5e-3, "sqrt: xla vs native rel err {es}");
    assert!(ei < 5e-3, "invsqrt: xla vs native rel err {ei}");
    assert!(out.residual < 1e-2, "xla residual {}", out.residual);
}

#[test]
fn runtime_reports_platform() {
    // the dependency-free build stubs the PJRT bindings; Runtime::cpu()
    // failing fast with the unlinked-extension notice is the expected path
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP: {e}");
            return;
        }
    };
    let p = rt.platform().to_lowercase();
    assert!(p.contains("cpu") || p.contains("host"), "platform={p}");
}

//! `structlint` — a dependency-free structural lint for the crate's
//! concurrency-correctness conventions. Runs in tier-1 CI (`cargo run
//! --release --bin structlint`) and fails the build on:
//!
//! 1. **Unjustified `unsafe`** — any `unsafe` keyword (block, fn, impl)
//!    without a `// SAFETY:` comment (or a `/// # Safety` doc section) on the
//!    same line or within the 12 preceding lines.
//! 2. **Unjustified weak orderings** — any `Ordering::Relaxed` /
//!    `Ordering::Acquire` / `Ordering::Release` / `Ordering::AcqRel` without
//!    an `// ordering:` justification comment on the same line or within the
//!    10 preceding lines (justifications are often multi-line). `SeqCst`
//!    needs no justification: it is the safe default, weakening it is the
//!    decision that must be argued.
//! 3. **Shim bypass** — direct `std::sync::{Mutex, MutexGuard, Condvar}`,
//!    `std::sync::atomic::*`, or `std::thread::park*` usage inside the
//!    modules that are model-checked through `crate::util::sync`
//!    (`exec/mod.rs`, `exec/channel.rs`, `util/threadpool.rs`). A direct std
//!    primitive there is invisible to the deterministic scheduler, silently
//!    shrinking the interleavings the model checker explores. `Arc`,
//!    `OnceLock`, `mpsc`, and `Weak` stay allowed — they are not scheduling
//!    points the checker needs to own.
//! 4. **Arch escape** — `core::arch` / `std::arch` paths or
//!    `#[target_feature]` attributes anywhere but `linalg/simd.rs` and
//!    `linalg/mixed.rs`. All intrinsics live behind the two dispatch layers
//!    whose `table_for` availability checks discharge their feature
//!    contracts; an intrinsic elsewhere would be a third, unaudited unsafe
//!    surface.
//! 5. **Feature-blind SAFETY** — a `#[target_feature(enable = "…")]` fn
//!    whose preceding `SAFETY:` comment does not name every enabled
//!    feature. The comment is the contract ("caller must ensure avx2 and
//!    fma…"); if it names the wrong feature, the `Backend::available` gate
//!    and the kernel can silently disagree.
//! 6. **Unjustified clock reads** — `Instant::now()` / `SystemTime::now()`
//!    outside `obs/` (the shared monotonic time base) and `exec/timer.rs`
//!    (the wheel's origin) without a `// clock:` justification comment on
//!    the same line or within the 6 preceding lines. Ad-hoc clock reads are
//!    how timing becomes unauditable and unmockable; each one must say why
//!    it cannot go through `obs::clock`.
//! 7. **Unjustified precision narrowing** — an `as f32` cast anywhere but
//!    `linalg/mixed.rs` without a `// precision:` justification comment on
//!    the same line or within the 6 preceding lines. The mixed-precision
//!    kernel layer owns the crate's forward-error analysis; a narrowing
//!    cast elsewhere silently moves data out from under that analysis, so
//!    each one must argue why the rounding is benign.
//!
//! Test regions are exempt: scanning stops at the first `#[cfg(test)]` line
//! (by crate convention test modules sit at the bottom of each file). Scope
//! is `src/` only — integration tests and benches may use std primitives
//! freely.
//!
//! The scanner understands line comments, nested block comments, string /
//! raw-string / byte-string literals, and char-vs-lifetime `'`, so tokens
//! inside strings or comments never count as code.
//!
//! `structlint --self-test` lints embedded fixtures (one violating fixture
//! per rule plus clean ones) and exits nonzero unless every fixture produces
//! exactly the expected findings — the proof that the lint can actually
//! fail, demanded by CI before the tree scan is trusted.

use std::env;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// How far above an `unsafe` keyword a `SAFETY:` comment may sit.
const SAFETY_WINDOW: usize = 12;
/// How far above a weak `Ordering::` an `// ordering:` comment may sit.
const ORDERING_WINDOW: usize = 10;

/// Files routed through `crate::util::sync` whose primitives must stay
/// model-checkable (rule 3). Matched as path suffixes.
const SHIMMED: &[&str] =
    &["exec/mod.rs", "exec/channel.rs", "util/threadpool.rs", "obs/trace.rs"];

/// How far above an `Instant::now()`/`SystemTime::now()` a `// clock:`
/// comment may sit (rule 6).
const CLOCK_WINDOW: usize = 6;

/// The files allowed to contain `core::arch`/`std::arch` paths and
/// `#[target_feature]` fns (rule 4): the SIMD dispatch layer and the
/// mixed-precision kernel layer built on the same availability gates.
/// Matched as path suffixes.
const ARCH_HOMES: &[&str] = &["linalg/simd.rs", "linalg/mixed.rs"];

/// The single module allowed to narrow to `f32` freely (rule 7): the
/// mixed-precision kernel layer, whose module-level forward-error analysis
/// is the standing justification. Matched as a path suffix.
const PRECISION_HOME: &str = "linalg/mixed.rs";
/// How far above an `as f32` cast a `// precision:` comment may sit (rule 7).
const PRECISION_WINDOW: usize = 6;

#[derive(Debug, PartialEq, Eq)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// One physical source line, split into its code text (string-literal
/// contents blanked) and its comment text.
struct Line {
    code: String,
    comment: String,
}

/// Split source into per-line (code, comment) pairs with a small lexer:
/// line comments, nested block comments, plain/raw/byte strings, and char
/// literals (distinguished from lifetimes) are recognized so their contents
/// never leak into the code text.
fn split_lines(src: &str) -> Vec<Line> {
    #[derive(Clone, Copy, PartialEq)]
    enum State {
        Code,
        Block(u32),
    }
    let b: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Code;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            lines.push(Line { code: std::mem::take(&mut code), comment: std::mem::take(&mut comment) });
            i += 1;
            continue;
        }
        match state {
            State::Block(depth) => {
                if c == '/' && b.get(i + 1) == Some(&'*') {
                    state = State::Block(depth + 1);
                    i += 2;
                } else if c == '*' && b.get(i + 1) == Some(&'/') {
                    state = if depth == 1 { State::Code } else { State::Block(depth - 1) };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Code => {
                if c == '/' && b.get(i + 1) == Some(&'/') {
                    // Line comment: take the rest of the physical line.
                    while i < b.len() && b[i] != '\n' {
                        comment.push(b[i]);
                        i += 1;
                    }
                } else if c == '/' && b.get(i + 1) == Some(&'*') {
                    state = State::Block(1);
                    i += 2;
                } else if c == '"' {
                    // Plain string literal: consume to the closing quote.
                    code.push('"');
                    i += 1;
                    while i < b.len() {
                        match b[i] {
                            '\\' => i += 2,
                            '"' => {
                                i += 1;
                                break;
                            }
                            '\n' => {
                                lines.push(Line {
                                    code: std::mem::take(&mut code),
                                    comment: std::mem::take(&mut comment),
                                });
                                i += 1;
                            }
                            _ => i += 1,
                        }
                    }
                    code.push('"');
                } else if (c == 'r' || c == 'b') && is_raw_string_start(&b, i) {
                    // Raw (or raw-byte) string: r#..#"..."#..#
                    let mut j = i;
                    if b[j] == 'b' {
                        j += 1;
                    }
                    j += 1; // past the 'r'
                    let mut hashes = 0;
                    while b.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    // b[j] is the opening quote.
                    j += 1;
                    code.push('"');
                    loop {
                        match b.get(j) {
                            None => break,
                            Some('\n') => {
                                lines.push(Line {
                                    code: std::mem::take(&mut code),
                                    comment: std::mem::take(&mut comment),
                                });
                                j += 1;
                            }
                            Some('"') => {
                                let mut k = 0;
                                while k < hashes && b.get(j + 1 + k) == Some(&'#') {
                                    k += 1;
                                }
                                if k == hashes {
                                    j += 1 + hashes;
                                    break;
                                }
                                j += 1;
                            }
                            Some(_) => j += 1,
                        }
                    }
                    code.push('"');
                    i = j;
                } else if c == '\'' {
                    // Char literal vs lifetime.
                    if b.get(i + 1) == Some(&'\\') {
                        // Escaped char: consume to the closing quote.
                        code.push_str("' '");
                        i += 2;
                        while i < b.len() && b[i] != '\'' && b[i] != '\n' {
                            i += 1;
                        }
                        i += 1;
                    } else if b.get(i + 2) == Some(&'\'') {
                        code.push_str("' '");
                        i += 3;
                    } else {
                        // Lifetime: keep as code.
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(Line { code, comment });
    }
    lines
}

fn is_raw_string_start(b: &[char], i: usize) -> bool {
    // Preceding char must not be part of an identifier (e.g. `attr` in
    // `attr"..."` is impossible, but `var` ending in r could precede `"`).
    if i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_') {
        return false;
    }
    let mut j = i;
    if b[j] == 'b' {
        j += 1;
        if b.get(j) != Some(&'r') {
            // b"..." plain byte string: let the '"' branch handle it next.
            return false;
        }
    }
    if b[j] != 'r' {
        return false;
    }
    j += 1;
    while b.get(j) == Some(&'#') {
        j += 1;
    }
    b.get(j) == Some(&'"')
}

/// Find a whole-word occurrence of `word` in `code` at or after `from`.
fn find_word(code: &str, word: &str, from: usize) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut start = from;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok =
            at == 0 || !(bytes[at - 1].is_ascii_alphanumeric() || bytes[at - 1] == b'_');
        let end = at + word.len();
        let after_ok = end >= bytes.len()
            || !(bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_');
        if before_ok && after_ok {
            return Some(at);
        }
        start = at + word.len();
    }
    None
}

/// Does `code` contain an `as f32` cast (whole-word match on both tokens,
/// any amount of whitespace between them)?
fn has_as_f32(code: &str) -> bool {
    let mut from = 0;
    while let Some(at) = find_word(code, "f32", from) {
        let pre = code[..at].trim_end();
        if pre.ends_with("as") {
            let stem = &pre[..pre.len() - 2];
            let boundary = stem
                .chars()
                .next_back()
                .is_none_or(|c| !(c.is_alphanumeric() || c == '_'));
            if boundary && pre.len() < at {
                return true;
            }
        }
        from = at + "f32".len();
    }
    false
}

/// Does any comment in `lines[lo..=hi]` contain one of `needles`
/// (case-insensitively)?
fn comment_in_window(lines: &[Line], lo: usize, hi: usize, needles: &[&str]) -> bool {
    lines[lo..=hi].iter().any(|l| {
        let lc = l.comment.to_lowercase();
        needles.iter().any(|n| lc.contains(&n.to_lowercase()))
    })
}

/// Identifiers banned from shimmed modules when reached through
/// `std::sync::` (rule 3).
fn banned_sync_item(ident: &str) -> bool {
    ident.starts_with("atomic")
        || ident.starts_with("Atomic")
        || matches!(ident, "Mutex" | "MutexGuard" | "Condvar")
}

/// Extract the item identifiers reached by a `std::sync::` path occurrence
/// starting right after the second `::` — handles both `std::sync::Mutex`
/// and `use std::sync::{Arc, Mutex, atomic::AtomicU64}`.
fn sync_items_after(code: &str, after: usize) -> Vec<String> {
    let rest: Vec<char> = code[after..].chars().collect();
    let mut items = Vec::new();
    if rest.first() == Some(&'{') {
        let mut cur = String::new();
        for &c in &rest[1..] {
            match c {
                '}' | ',' => {
                    let first_seg: String = cur
                        .trim()
                        .chars()
                        .take_while(|c| c.is_alphanumeric() || *c == '_')
                        .collect();
                    if !first_seg.is_empty() {
                        items.push(first_seg);
                    }
                    cur.clear();
                    if c == '}' {
                        break;
                    }
                }
                _ => cur.push(c),
            }
        }
        let first_seg: String =
            cur.trim().chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
        if !first_seg.is_empty() {
            items.push(first_seg);
        }
    } else {
        let ident: String =
            rest.iter().take_while(|c| c.is_alphanumeric() || **c == '_').collect();
        if !ident.is_empty() {
            items.push(ident);
        }
    }
    items
}

/// Parse the feature list out of a raw `#[target_feature(enable = "…")]`
/// source line (rule 5 must read the *raw* line: the lexer blanks string
/// contents out of the code text). Returns the lowercased features, or
/// `None` when the line holds no complete single-line enable list.
fn enable_features(raw_line: &str) -> Option<Vec<String>> {
    let at = raw_line.find("target_feature")?;
    let rest = &raw_line[at..];
    let en = rest.find("enable")?;
    let rest = &rest[en..];
    let open = rest.find('"')?;
    let rest = &rest[open + 1..];
    let close = rest.find('"')?;
    let feats: Vec<String> = rest[..close]
        .split(',')
        .map(|f| f.trim().to_ascii_lowercase())
        .filter(|f| !f.is_empty())
        .collect();
    if feats.is_empty() {
        None
    } else {
        Some(feats)
    }
}

/// Lint one file's source. `relpath` is the display path (also used for the
/// shimmed-module suffix match).
fn lint_file(relpath: &str, src: &str) -> Vec<Violation> {
    let shimmed = SHIMMED.iter().any(|s| relpath.ends_with(s));
    let arch_home = ARCH_HOMES.iter().any(|s| relpath.ends_with(s));
    let precision_home = relpath.ends_with(PRECISION_HOME);
    // Rule 6 exemptions: obs/ owns the shared time base, the timer wheel
    // reads its own origin.
    let clock_home = relpath.contains("/obs/")
        || relpath.starts_with("obs/")
        || relpath.ends_with("exec/timer.rs");
    let raw: Vec<&str> = src.lines().collect();
    let lines = split_lines(src);
    // Test regions are exempt: by convention the `#[cfg(test)]` module sits
    // at the bottom of each file.
    let test_start = lines
        .iter()
        .position(|l| l.code.trim_start().starts_with("#[cfg(test)]"))
        .unwrap_or(lines.len());
    let mut out = Vec::new();
    for (idx, line) in lines.iter().take(test_start).enumerate() {
        let lineno = idx + 1;
        // Rule 1: unsafe needs SAFETY.
        if find_word(&line.code, "unsafe", 0).is_some() {
            let lo = idx.saturating_sub(SAFETY_WINDOW);
            if !comment_in_window(&lines, lo, idx, &["SAFETY:", "# Safety"]) {
                out.push(Violation {
                    file: relpath.to_string(),
                    line: lineno,
                    rule: "unsafe-needs-safety-comment",
                    msg: format!(
                        "`unsafe` without a `// SAFETY:` comment on the same line or \
                         within the {SAFETY_WINDOW} preceding lines"
                    ),
                });
            }
        }
        // Rule 2: weak orderings need justification.
        let mut from = 0;
        while let Some(pos) = line.code[from..].find("Ordering::") {
            let at = from + pos;
            let after = at + "Ordering::".len();
            let ident: String = line.code[after..]
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if matches!(ident.as_str(), "Relaxed" | "Acquire" | "Release" | "AcqRel") {
                let lo = idx.saturating_sub(ORDERING_WINDOW);
                if !comment_in_window(&lines, lo, idx, &["ordering:"]) {
                    out.push(Violation {
                        file: relpath.to_string(),
                        line: lineno,
                        rule: "weak-ordering-needs-justification",
                        msg: format!(
                            "`Ordering::{ident}` without an `// ordering:` comment on the \
                             same line or within the {ORDERING_WINDOW} preceding lines"
                        ),
                    });
                }
            }
            from = after;
        }
        // Rule 3: shimmed modules must not reach std primitives directly.
        if shimmed {
            if line.code.contains("std::thread::park") {
                out.push(Violation {
                    file: relpath.to_string(),
                    line: lineno,
                    rule: "shim-bypass",
                    msg: "direct `std::thread::park` in a model-checked module; park/unpark \
                          must go through a `crate::util::sync` Condvar"
                        .to_string(),
                });
            }
            let mut from = 0;
            while let Some(pos) = line.code[from..].find("std::sync::") {
                let at = from + pos;
                let after = at + "std::sync::".len();
                for item in sync_items_after(&line.code, after) {
                    if banned_sync_item(&item) {
                        out.push(Violation {
                            file: relpath.to_string(),
                            line: lineno,
                            rule: "shim-bypass",
                            msg: format!(
                                "direct `std::sync::{item}` in a model-checked module; use \
                                 `crate::util::sync::{item}` so the model checker can \
                                 schedule it"
                            ),
                        });
                    }
                }
                from = after;
            }
        }
        // Rule 4: intrinsics and feature-gated fns are confined to the SIMD
        // dispatch layer.
        if !arch_home {
            if line.code.contains("core::arch") || line.code.contains("std::arch") {
                let homes = ARCH_HOMES.join(", ");
                out.push(Violation {
                    file: relpath.to_string(),
                    line: lineno,
                    rule: "arch-outside-simd",
                    msg: format!(
                        "`core::arch`/`std::arch` outside {homes}; intrinsics live \
                         behind the dispatch layers whose availability checks discharge \
                         their feature contracts"
                    ),
                });
            }
            if line.code.contains("#[target_feature") {
                let homes = ARCH_HOMES.join(", ");
                out.push(Violation {
                    file: relpath.to_string(),
                    line: lineno,
                    rule: "arch-outside-simd",
                    msg: format!(
                        "`#[target_feature]` outside {homes}; feature-gated kernels \
                         belong in the dispatch layers"
                    ),
                });
            }
        }
        // Rule 6: raw clock reads outside the clock-owning modules need a
        // `// clock:` justification.
        if !clock_home {
            for probe in ["Instant::now", "SystemTime::now"] {
                if find_word(&line.code, probe, 0).is_some() {
                    let lo = idx.saturating_sub(CLOCK_WINDOW);
                    if !comment_in_window(&lines, lo, idx, &["clock:"]) {
                        out.push(Violation {
                            file: relpath.to_string(),
                            line: lineno,
                            rule: "clock-read-needs-justification",
                            msg: format!(
                                "`{probe}()` outside obs/ and exec/timer.rs without a \
                                 `// clock:` comment on the same line or within the \
                                 {CLOCK_WINDOW} preceding lines"
                            ),
                        });
                    }
                }
            }
        }
        // Rule 7: f32 narrowing outside the mixed-precision kernel layer
        // needs a `// precision:` justification.
        if !precision_home && has_as_f32(&line.code) {
            let lo = idx.saturating_sub(PRECISION_WINDOW);
            if !comment_in_window(&lines, lo, idx, &["precision:"]) {
                out.push(Violation {
                    file: relpath.to_string(),
                    line: lineno,
                    rule: "f32-cast-needs-justification",
                    msg: format!(
                        "`as f32` outside {PRECISION_HOME} without a `// precision:` \
                         comment on the same line or within the {PRECISION_WINDOW} \
                         preceding lines"
                    ),
                });
            }
        }
        // Rule 5: a target_feature fn's SAFETY comment must name every
        // enabled feature (parsed from the raw line — the lexer blanks the
        // string out of the code text).
        if line.code.contains("#[target_feature") {
            if let Some(feats) = raw.get(idx).and_then(|r| enable_features(r)) {
                let lo = idx.saturating_sub(SAFETY_WINDOW);
                let window: String = lines[lo..idx]
                    .iter()
                    .map(|l| l.comment.to_lowercase())
                    .collect::<Vec<_>>()
                    .join("\n");
                let missing: Vec<&String> =
                    feats.iter().filter(|f| !window.contains(f.as_str())).collect();
                if !window.contains("safety:") || !missing.is_empty() {
                    out.push(Violation {
                        file: relpath.to_string(),
                        line: lineno,
                        rule: "target-feature-safety-names-feature",
                        msg: format!(
                            "`#[target_feature(enable = …)]` whose preceding `SAFETY:` \
                             comment does not name the detected feature(s) {feats:?} \
                             within the {SAFETY_WINDOW} preceding lines"
                        ),
                    });
                }
            }
        }
    }
    out
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> =
        fs::read_dir(dir)?.collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn lint_tree(root: &Path) -> Result<Vec<Violation>, String> {
    let mut files = Vec::new();
    walk(root, &mut files).map_err(|e| format!("walking {}: {e}", root.display()))?;
    if files.is_empty() {
        return Err(format!("no .rs files under {}", root.display()));
    }
    let mut violations = Vec::new();
    for path in &files {
        let src =
            fs::read_to_string(path).map_err(|e| format!("reading {}: {e}", path.display()))?;
        violations.extend(lint_file(&path.display().to_string(), &src));
    }
    Ok(violations)
}

// ---------------------------------------------------------------------------
// Self-test fixtures: each violating fixture must produce exactly the listed
// rules; the clean fixtures must produce none. CI runs `structlint
// --self-test` before trusting the tree scan — a lint that cannot fail proves
// nothing by passing.
// ---------------------------------------------------------------------------

const FIX_UNSAFE_BAD: &str = r#"
fn f(p: *mut u8) {
    unsafe { *p = 0 };
}
"#;

const FIX_UNSAFE_GOOD: &str = r#"
fn f(p: *mut u8) {
    // SAFETY: p is valid for writes by this function's contract.
    unsafe { *p = 0 };
}
"#;

const FIX_ORDERING_BAD: &str = r#"
use std::sync::atomic::{AtomicU64, Ordering};
fn f(a: &AtomicU64) -> u64 {
    a.load(Ordering::Relaxed)
}
"#;

const FIX_ORDERING_GOOD: &str = r#"
use std::sync::atomic::{AtomicU64, Ordering};
fn f(a: &AtomicU64) -> u64 {
    // ordering: Relaxed — telemetry counter, no synchronization implied.
    a.load(Ordering::Relaxed)
}
fn g(a: &AtomicU64) -> u64 {
    a.load(Ordering::SeqCst)
}
"#;

const FIX_SHIM_BAD: &str = r#"
use std::sync::{Arc, Mutex};
use std::sync::atomic::AtomicBool;
fn f() {
    std::thread::park();
}
"#;

const FIX_SHIM_GOOD: &str = r#"
use crate::util::sync::{AtomicBool, Condvar, Mutex, Ordering};
use std::sync::{mpsc, Arc, OnceLock, Weak};
use std::thread;
"#;

const FIX_ARCH_BAD: &str = r#"
use core::arch::x86_64::*;
#[target_feature(enable = "avx2")]
fn f() {}
"#;

const FIX_TF_GOOD: &str = r#"
use core::arch::x86_64::*;
// SAFETY: caller must ensure avx2 and fma are available on the executing CPU.
#[target_feature(enable = "avx2,fma")]
unsafe fn f() {}
"#;

const FIX_TF_BAD: &str = r#"
// SAFETY: pointers are valid for the whole panel.
#[target_feature(enable = "avx512f")]
unsafe fn f() {}
"#;

const FIX_CLOCK_BAD: &str = r#"
use std::time::Instant;
fn f() -> Instant {
    Instant::now()
}
"#;

const FIX_CLOCK_GOOD: &str = r#"
use std::time::Instant;
fn f() -> Instant {
    // clock: request arrival timestamp — latency is measured from here.
    Instant::now()
}
fn g() -> std::time::SystemTime {
    std::time::SystemTime::now() // clock: wall time for the export filename
}
"#;

const FIX_PRECISION_BAD: &str = r#"
fn f(x: f64) -> f32 {
    x as f32
}
"#;

const FIX_PRECISION_GOOD: &str = r#"
fn f(x: f64) -> f32 {
    // precision: display-only narrowing; the value never feeds a solve.
    x as f32
}
fn g(x: f64) -> f32 {
    x as f32 // precision: same-line justification also counts.
}
fn h(x: f64) -> u32 {
    x as u32
}
"#;

const FIX_FALSE_POSITIVES: &str = r####"
//! Docs may say unsafe and Ordering::Relaxed and std::sync::Mutex freely.
fn f() -> &'static str {
    // A comment may too: unsafe, Ordering::Relaxed, std::thread::park.
    let s = "unsafe Ordering::Relaxed std::sync::Mutex std::thread::park";
    let r = r##"unsafe { Ordering::Relaxed } "quoted" std::sync::Mutex"##;
    let _ = (s, r, 'x', '\n');
    /* block comments too: unsafe /* nested */ std::thread::park */
    "ok"
}

#[cfg(test)]
mod tests {
    fn test_region_is_exempt(p: *mut u8) {
        unsafe { *p = 0 };
        let _ = std::sync::atomic::AtomicU64::new(0).load(std::sync::atomic::Ordering::Relaxed);
    }
}
"####;

fn self_test() -> Result<(), String> {
    let expect = |src: &str, file: &str, rules: &[&str]| -> Result<(), String> {
        let got = lint_file(file, src);
        let got_rules: Vec<&str> = got.iter().map(|v| v.rule).collect();
        if got_rules != rules {
            return Err(format!(
                "fixture {file}: expected rules {rules:?}, got {got_rules:?} ({got:#?})"
            ));
        }
        Ok(())
    };
    expect(FIX_UNSAFE_BAD, "fix/unsafe_bad.rs", &["unsafe-needs-safety-comment"])?;
    expect(FIX_UNSAFE_GOOD, "fix/unsafe_good.rs", &[])?;
    expect(FIX_ORDERING_BAD, "fix/ordering_bad.rs", &["weak-ordering-needs-justification"])?;
    expect(FIX_ORDERING_GOOD, "fix/ordering_good.rs", &[])?;
    // The shim fixture is only a violation inside a shimmed module...
    expect(
        FIX_SHIM_BAD,
        "src/exec/mod.rs",
        &["shim-bypass", "shim-bypass", "shim-bypass"],
    )?;
    // ...the same source elsewhere is fine.
    expect(FIX_SHIM_BAD, "src/operators/mod.rs", &[])?;
    expect(FIX_SHIM_GOOD, "src/exec/channel.rs", &[])?;
    expect(FIX_FALSE_POSITIVES, "src/util/threadpool.rs", &[])?;
    // Arch escape: intrinsic imports and feature-gated fns outside the
    // dispatch layer (the bare attribute also trips the SAFETY-names-feature
    // rule — there is no SAFETY comment at all)...
    expect(
        FIX_ARCH_BAD,
        "src/operators/kernel.rs",
        &["arch-outside-simd", "arch-outside-simd", "target-feature-safety-names-feature"],
    )?;
    // ...a properly annotated kernel is clean inside either arch home...
    expect(FIX_TF_GOOD, "src/linalg/simd.rs", &[])?;
    expect(FIX_TF_GOOD, "src/linalg/mixed.rs", &[])?;
    // ...but the identical source anywhere else is confined...
    expect(FIX_TF_GOOD, "src/util/fastmath.rs", &["arch-outside-simd", "arch-outside-simd"])?;
    // ...and a SAFETY comment that names no feature fails rule 5 even
    // though it satisfies the plain unsafe rule.
    expect(FIX_TF_BAD, "src/linalg/simd.rs", &["target-feature-safety-names-feature"])?;
    // Clock reads: unjustified outside the clock-owning modules...
    expect(FIX_CLOCK_BAD, "src/coordinator/mod.rs", &["clock-read-needs-justification"])?;
    // ...exempt inside them...
    expect(FIX_CLOCK_BAD, "src/obs/clock.rs", &[])?;
    expect(FIX_CLOCK_BAD, "src/exec/timer.rs", &[])?;
    // ...and justified reads pass anywhere.
    expect(FIX_CLOCK_GOOD, "src/svgp/mod.rs", &[])?;
    // f32 narrowing: unjustified outside the mixed-precision home...
    expect(FIX_PRECISION_BAD, "src/operators/kernel.rs", &["f32-cast-needs-justification"])?;
    // ...exempt inside it (the module doc carries the error analysis)...
    expect(FIX_PRECISION_BAD, "src/linalg/mixed.rs", &[])?;
    // ...and justified casts (widening ones too) pass anywhere.
    expect(FIX_PRECISION_GOOD, "src/coordinator/mod.rs", &[])?;
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = env::args().skip(1).collect();
    if args.iter().any(|a| a == "--self-test") {
        return match self_test() {
            Ok(()) => {
                println!("structlint: self-test passed (20 fixtures)");
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("structlint: SELF-TEST FAILED: {e}");
                ExitCode::from(2)
            }
        };
    }
    let root = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            let manifest =
                env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".to_string());
            // Non-standard layout: the crate's manifest sits at the repo root
            // with sources under rust/src (see Cargo.toml).
            let nested = Path::new(&manifest).join("rust").join("src");
            if nested.is_dir() { nested } else { Path::new(&manifest).join("src") }
        });
    match lint_tree(&root) {
        Ok(violations) if violations.is_empty() => {
            println!("structlint: OK ({})", root.display());
            ExitCode::SUCCESS
        }
        Ok(violations) => {
            for v in &violations {
                eprintln!("{v}");
            }
            eprintln!("structlint: {} violation(s)", violations.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("structlint: error: {e}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_test_fixtures_behave() {
        self_test().unwrap();
    }

    #[test]
    fn lexer_blanks_strings_and_comments() {
        let lines = split_lines(FIX_FALSE_POSITIVES);
        for l in &lines {
            assert!(!l.code.contains("unsafe"), "string leaked into code: {:?}", l.code);
        }
    }

    #[test]
    fn nested_block_comments_stay_comments() {
        let src = "/* outer /* inner unsafe */ still comment */ fn f() {}\n";
        let lines = split_lines(src);
        assert!(!lines[0].code.contains("unsafe"));
        assert!(lines[0].code.contains("fn f"));
        assert!(lines[0].comment.contains("unsafe"));
    }

    #[test]
    fn ordering_window_is_ten_lines() {
        let near =
            format!("// ordering: fine\n{}let _ = a.load(Ordering::Relaxed);\n", "\n".repeat(9));
        assert!(lint_file("x.rs", &near).is_empty());
        let far =
            format!("// ordering: too far\n{}let _ = a.load(Ordering::Relaxed);\n", "\n".repeat(10));
        assert_eq!(lint_file("x.rs", &far).len(), 1);
    }

    #[test]
    fn as_f32_detector_matches_casts_only() {
        assert!(has_as_f32("let y = x as f32;"));
        assert!(has_as_f32("(a + b) as f32"));
        assert!(!has_as_f32("let y = x as f64;"));
        assert!(!has_as_f32("fn f(x: f32) -> f32 { x }"));
        assert!(!has_as_f32("alias f32"));
    }

    #[test]
    fn safety_doc_section_counts() {
        let src = "/// # Safety\n/// caller must uphold X\nunsafe fn f() {}\n";
        assert!(lint_file("x.rs", src).is_empty());
    }

    #[test]
    fn enable_features_parses_raw_lines() {
        assert_eq!(
            enable_features(r#"    #[target_feature(enable = "avx2,fma")]"#),
            Some(vec!["avx2".to_string(), "fma".to_string()])
        );
        assert_eq!(
            enable_features(r#"#[target_feature(enable = "neon")]"#),
            Some(vec!["neon".to_string()])
        );
        assert_eq!(enable_features("fn no_attr_here() {}"), None);
        assert_eq!(enable_features(r#"#[target_feature(enable = "")]"#), None);
    }

    #[test]
    fn target_feature_safety_window_excludes_the_attribute_line() {
        // the feature name inside the attribute's own string must not
        // satisfy the rule — only a comment above it can
        let src = "#[target_feature(enable = \"avx2\")]\nunsafe fn f() {}\n";
        let v = lint_file("src/linalg/simd.rs", src);
        assert!(v.iter().any(|v| v.rule == "target-feature-safety-names-feature"), "{v:#?}");
    }

    #[test]
    fn grouped_sync_import_is_parsed() {
        let src = "use std::sync::{mpsc, Arc, Mutex};\n";
        let v = lint_file("src/exec/mod.rs", src);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("Mutex"));
        assert!(lint_file("src/linalg/mod.rs", src).is_empty());
    }
}

//! Fast transcendental approximations for the kernel-MVM hot loop (§Perf).
//!
//! Profiling the partitioned kernel MVM shows `exp()` dominating: an RBF MVM
//! performs N² kernel evaluations, each one `exp` plus a handful of flops,
//! so libm's ~20 ns `exp` caps the MVM near 1 GF/s while the Cholesky
//! baseline streams pure fused multiply-adds. `fast_exp` below is the
//! classic bit-twiddled `2^n · 2^f` scheme with a degree-5 minimax
//! polynomial on `f ∈ [-0.5, 0.5]`: max relative error < 1e-8 over the
//! range kernels use (`x ≤ 0`), at ~3–4× the throughput of libm.

// Shared with the lane-parallel SIMD exp in `linalg::simd`, which uses the
// same `2^n · 2^f` scheme and hi/lo ln2 split (at degree 11, for the solver's
// tighter tolerance) — one set of range-reduction constants for both paths.
pub(crate) const LOG2_E: f64 = std::f64::consts::LOG2_E;
pub(crate) const LN_2_HI: f64 = 6.931_471_803_691_238e-1;
pub(crate) const LN_2_LO: f64 = 1.908_214_929_270_587_7e-10;

/// Fast `e^x` (<1e-8 relative error for |x| ≤ 700; clamps to 0/inf outside).
#[inline(always)]
pub fn fast_exp(x: f64) -> f64 {
    if x < -708.0 {
        return 0.0;
    }
    if x > 708.0 {
        return f64::INFINITY;
    }
    // x = n·ln2 + r,  |r| ≤ ln2/2
    let n = (x * LOG2_E).round();
    let r = (x - n * LN_2_HI) - n * LN_2_LO;
    // e^r via degree-6 Taylor/minimax (|r| ≤ 0.3466 ⇒ err < 1e-10)
    let r2 = r * r;
    let p = 1.0
        + r
        + r2 * (0.5
            + r * (1.0 / 6.0
                + r * (1.0 / 24.0 + r * (1.0 / 120.0 + r * (1.0 / 720.0 + r / 5040.0)))));
    // scale by 2^n through the exponent bits
    let bits = ((n as i64) + 1023) << 52;
    let scale = f64::from_bits(bits as u64);
    p * scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_libm_over_kernel_range() {
        // kernels evaluate exp on (-inf, 0]
        let mut worst = 0.0f64;
        let mut x = -60.0;
        while x <= 0.0 {
            let a = fast_exp(x);
            let b = x.exp();
            let rel = if b > 0.0 { (a - b).abs() / b } else { a.abs() };
            worst = worst.max(rel);
            x += 0.001;
        }
        assert!(worst < 2e-8, "worst rel err {worst}");
    }

    #[test]
    fn matches_libm_positive_and_extremes() {
        for &x in &[0.0, 1.0, 10.0, 100.0, -100.0, 700.0, -700.0] {
            let a = fast_exp(x);
            let b = x.exp();
            let rel = (a - b).abs() / b.max(1e-300);
            assert!(rel < 1e-8, "x={x}: {a} vs {b}");
        }
        assert_eq!(fast_exp(-800.0), 0.0);
        assert!(fast_exp(800.0).is_infinite());
    }
}

//! Data parallelism over index ranges on a **persistent worker pool**
//! (no rayon offline — parked `std::thread` workers plus an atomic work
//! queue).
//!
//! The kernel-matrix MVMs (the hot path of the whole system) split their row
//! range into chunks and let a fixed set of worker threads steal chunks from
//! a shared counter. Results are written into disjoint slices of the output,
//! so no locking is needed on the data itself.
//!
//! ## Why a persistent pool
//!
//! A CIQ solve performs ~100 sequential MVMs (`J` msMINRES iterations plus
//! Lanczos estimation), and the original implementation spawned fresh OS
//! threads via `std::thread::scope` inside *every* MVM — paying thread
//! creation latency ~100× per solve. The pool here is created lazily on the
//! first parallel call and parks its workers on a condvar between jobs, so
//! steady-state dispatch is a mutex + notify instead of `clone(2)`.
//! [`pool_spawned_threads`] exposes the process-lifetime spawn counter so
//! tests can *prove* threads are created once, not per call.
//!
//! ## Scheduling contract
//!
//! One job runs at a time (concurrent submitters serialize on a submit
//! lock; the pool is shared process-wide). The submitting thread always
//! participates in its own job, so `CIQ_THREADS=1` — or a pool with zero
//! workers — degenerates to a fully serial loop on the caller with the pool
//! never even constructed. Nested parallel calls from inside a parallel
//! region run serially on the calling worker (no deadlock, no
//! oversubscription).
//!
//! ## Epoch protocol & ordering audit
//!
//! The park/unpark handoff is deliberately **mutex-based, not atomic-based**:
//! `epoch`, `task`, and `active` only ever change under `ChunkPool::state`,
//! so their visibility is carried by the lock and no `Ordering` subtleties
//! apply to them at all. The protocol:
//!
//! 1. submitter (under `state`): `epoch += 1`, `task = Some(..)`, notify;
//! 2. worker (under `state`): sees `epoch != seen` with a task present →
//!    records `seen = epoch`, `active += 1`, *then* releases the lock and
//!    runs chunks (registration-before-work: the submitter's step 4 check
//!    cannot miss a worker that will still touch the task);
//! 3. worker (under `state`): `active -= 1`, notify `done_cv` at zero;
//! 4. submitter (under `state`): waits `active == 0`, then `task = None` —
//!    only after this can its stack frame (which the task borrows) unwind.
//!
//! The *only* atomic in the hot protocol is the chunk-claim counter, which
//! is safe at `Relaxed` (see the comment at its use). This file goes through
//! [`crate::util::sync`] so the whole protocol runs under the deterministic
//! model checker (`--cfg ciq_model`, see `rust/tests/model_exec.rs`), which
//! explores the park/unpark interleavings directly.
//!
//! Alongside the chunk pool lives [`TaskPool`]: a small independent-job
//! pool (FIFO or LIFO queue, condvar-parked workers, drain-on-drop) that
//! the coordinator uses for batch execution and background warming — the
//! compute half of the `exec` split, where the async executor owns the
//! waiting and these worker threads own the CPU-bound jobs. Its park/drain
//! handshake also runs on the [`crate::util::sync`] shim and is explored
//! under the model checker via [`TaskPool::with_spawner`] (mutation M5 in
//! `rust/tests/model_exec.rs` documents the interleaving that the
//! drain-before-stop pop order exists to prevent).

use crate::util::sync::{AtomicUsize, Condvar, Mutex, Ordering};
use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::{Arc, OnceLock};

/// First panic payload captured from a job's body, re-raised verbatim on the
/// submitting thread once the job completes.
type PanicSlot = Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>;

/// Number of worker threads to use (cached; `CIQ_THREADS` env overrides).
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(s) = std::env::var("CIQ_THREADS") {
            if let Ok(n) = s.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    })
}

/// Total worker threads ever spawned by the persistent pool: `0` until the
/// first parallel call, then `num_threads() - 1` for the life of the
/// process. Tests assert this stays constant across thousands of parallel
/// calls — the "no per-MVM thread spawning" guarantee.
pub fn pool_spawned_threads() -> usize {
    // ordering: Relaxed — monotonic telemetry counter read for tests; no
    // other state is inferred from it. (Was SeqCst; nothing synchronizes
    // through it.)
    SPAWNED.load(Ordering::Relaxed)
}

static SPAWNED: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    // True on pool workers (always) and on a submitter while it executes its
    // own job; parallel entry points check it to run nested calls serially.
    static IN_PARALLEL: Cell<bool> = const { Cell::new(false) };
}

fn in_parallel_region() -> bool {
    IN_PARALLEL.with(|f| f.get())
}

/// One job: call `func(s, e)` for chunk ranges popped off `counter` until
/// `nchunks` is exhausted. The `'static` references are lifetime-erased
/// borrows of the submitter's stack frame — valid because the submitter
/// blocks until every registered worker has finished (see
/// [`ChunkPool::run`]).
#[derive(Clone, Copy)]
struct Task {
    func: &'static (dyn Fn(usize, usize) + Sync),
    counter: &'static AtomicUsize,
    panicked: &'static PanicSlot,
    n: usize,
    chunk: usize,
    nchunks: usize,
}

struct PoolState {
    /// Bumped once per job so sleeping workers can tell a new job from the
    /// one they already completed.
    epoch: u64,
    task: Option<Task>,
    /// Workers currently registered on the task (registration happens under
    /// the state lock, so the submitter's `active == 0` check cannot race a
    /// late take).
    active: usize,
    /// Asks workers to exit (only ever set by [`ChunkPool::shutdown`];
    /// the process-wide pool never stops).
    stop: bool,
}

/// The data-parallel chunk pool: one job at a time, every worker (plus the
/// submitter) stealing chunks off a shared counter. Public so the model
/// checker (`rust/tests/model_exec.rs`) can build a private instance whose
/// workers are *model* threads; production code uses the process-wide
/// instance behind [`parallel_for_chunks`] and friends.
pub struct ChunkPool {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Serializes whole jobs from concurrent submitters.
    submit: Mutex<()>,
    workers: usize,
}

impl ChunkPool {
    /// A pool expecting `workers` worker threads (spawn them with
    /// [`ChunkPool::spawn_workers_with`]). `workers == 0` makes
    /// [`ChunkPool::run`] fully serial on the caller.
    pub fn new(workers: usize) -> Arc<ChunkPool> {
        Arc::new(ChunkPool {
            state: Mutex::new(PoolState { epoch: 0, task: None, active: 0, stop: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            submit: Mutex::new(()),
            workers,
        })
    }

    /// Hand `workers` worker-loop closures to `spawn`. Injectable so the
    /// global pool spawns real OS threads while model tests spawn model
    /// threads — same worker code either way.
    pub fn spawn_workers_with(self: &Arc<Self>, mut spawn: impl FnMut(Box<dyn FnOnce() + Send + 'static>)) {
        for _ in 0..self.workers {
            let pool = self.clone();
            spawn(Box::new(move || pool.worker_loop()));
        }
    }

    /// Ask every worker to exit once idle (they finish a claimed job
    /// first). Used by model tests; the global pool lives forever.
    pub fn shutdown(&self) {
        self.state.lock().unwrap().stop = true;
        self.work_cv.notify_all();
    }

    fn worker_loop(&self) {
        IN_PARALLEL.with(|f| f.set(true));
        let mut seen = 0u64;
        loop {
            let task = {
                let mut guard = self.state.lock().unwrap();
                loop {
                    if guard.stop {
                        return;
                    }
                    if guard.epoch != seen {
                        if let Some(task) = guard.task {
                            seen = guard.epoch;
                            guard.active += 1;
                            break task;
                        }
                        // Epoch moved but the task is already cleared: we
                        // slept through that whole job. Remember the epoch so
                        // we do not spin, and wait for the next one.
                        seen = guard.epoch;
                    }
                    guard = self.work_cv.wait(guard).unwrap();
                }
            };
            run_chunks(&task);
            let mut guard = self.state.lock().unwrap();
            guard.active -= 1;
            if guard.active == 0 {
                self.done_cv.notify_all();
            }
        }
    }

    /// Run one chunked job to completion: publish the task, work the
    /// submitter's share, then wait out every registered worker before the
    /// borrowed stack frame may unwind. See the module-level protocol docs;
    /// weakening step 4 (mutation M3 in `rust/tests/model_exec.rs`) lets a
    /// worker touch a dead frame and is caught by the model checker.
    pub fn run(&self, n: usize, chunk: usize, body: &(dyn Fn(usize, usize) + Sync)) {
        let chunk = chunk.max(1);
        if n == 0 {
            return;
        }
        if self.workers == 0 {
            run_serial(n, chunk, body);
            return;
        }
        let nchunks = n.div_ceil(chunk);
        let counter = AtomicUsize::new(0);
        let panicked: PanicSlot = Mutex::new(None);
        // SAFETY: the erased borrows (`body`, `counter`, `panicked`) live on
        // this stack frame, and this function does not return (nor unwind —
        // the panic slot defers re-raising) until step 4 below has observed
        // `active == 0` under the state lock with the task retired, after
        // which no worker can reach them.
        let task = unsafe {
            Task {
                func: erase_body(body),
                counter: erase_counter(&counter),
                panicked: erase_slot(&panicked),
                n,
                chunk,
                nchunks,
            }
        };
        // One job at a time; competing submitters queue here.
        let submit_guard = self.submit.lock().unwrap();
        {
            let mut guard = self.state.lock().unwrap();
            guard.epoch = guard.epoch.wrapping_add(1);
            guard.task = Some(task);
            self.work_cv.notify_all();
        }
        // The submitting thread works its share too (and is marked
        // in-parallel so any nested parallel call from the body degrades to
        // serial).
        IN_PARALLEL.with(|f| f.set(true));
        run_chunks(&task);
        IN_PARALLEL.with(|f| f.set(false));
        // Wait for every registered worker to finish, then retire the task
        // so a late-waking worker can never touch this (about to die) stack
        // frame.
        {
            let mut guard = self.state.lock().unwrap();
            while guard.active > 0 {
                guard = self.done_cv.wait(guard).unwrap();
            }
            guard.task = None;
        }
        drop(submit_guard);
        if let Some(payload) = panicked.into_inner().unwrap() {
            std::panic::resume_unwind(payload);
        }
    }
}

/// The process-wide pool, created (with real OS worker threads) on first
/// use.
fn pool() -> &'static Arc<ChunkPool> {
    static POOL: OnceLock<Arc<ChunkPool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let p = ChunkPool::new(num_threads().saturating_sub(1));
        p.spawn_workers_with(|worker| {
            // ordering: Relaxed — spawn telemetry only (see
            // `pool_spawned_threads`); thread startup itself synchronizes.
            SPAWNED.fetch_add(1, Ordering::Relaxed);
            std::thread::Builder::new()
                .name("ciq-pool".into())
                .spawn(worker)
                .expect("failed to spawn pool worker");
        });
        p
    })
}

fn run_chunks(task: &Task) {
    loop {
        // ordering: Relaxed — the counter only *claims* chunk indices;
        // fetch_add's atomicity alone guarantees each index is claimed once.
        // All data written by chunk bodies is published to the submitter by
        // the state-lock release/acquire in the active==0 handshake, never
        // through this counter.
        let c = task.counter.fetch_add(1, Ordering::Relaxed);
        if c >= task.nchunks {
            break;
        }
        let s = c * task.chunk;
        let e = (s + task.chunk).min(task.n);
        // A panicking body must not kill a pool worker (the next job would
        // deadlock waiting on it); capture the first payload and re-raise it
        // verbatim on the submitter.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (task.func)(s, e)));
        if let Err(payload) = result {
            let mut slot = task.panicked.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
    }
}

/// Lifetime-erase a job body for the worker-visible [`Task`].
///
/// # Safety
///
/// The caller must guarantee the borrow outlives every worker access — i.e.
/// it must follow the registration/retire protocol of [`ChunkPool::run`].
unsafe fn erase_body<'a>(
    f: &'a (dyn Fn(usize, usize) + Sync),
) -> &'static (dyn Fn(usize, usize) + Sync) {
    // SAFETY: pure lifetime transmute (same type, same layout); validity is
    // the caller's contract above.
    unsafe { std::mem::transmute(f) }
}

/// Lifetime-erase the chunk counter; same contract as [`erase_body`].
///
/// # Safety
///
/// See [`erase_body`].
unsafe fn erase_counter(c: &AtomicUsize) -> &'static AtomicUsize {
    // SAFETY: pure lifetime transmute; validity is the caller's contract.
    unsafe { std::mem::transmute(c) }
}

/// Lifetime-erase the panic slot; same contract as [`erase_body`].
///
/// # Safety
///
/// See [`erase_body`].
unsafe fn erase_slot(s: &PanicSlot) -> &'static PanicSlot {
    // SAFETY: pure lifetime transmute; validity is the caller's contract.
    unsafe { std::mem::transmute(s) }
}

fn run_serial(n: usize, chunk: usize, body: &dyn Fn(usize, usize)) {
    let mut s = 0;
    while s < n {
        let e = (s + chunk).min(n);
        body(s, e);
        s = e;
    }
}

/// Run `body(start, end)` over chunked sub-ranges of `0..n` in parallel.
///
/// `body` must be safe to call concurrently on disjoint ranges. Chunks are
/// `chunk`-sized except possibly the last. Falls back to a serial loop when
/// the range is small or only one thread is available.
pub fn parallel_for_chunks<F>(n: usize, chunk: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    parallel_for_chunks_threads(n, chunk, num_threads(), body);
}

/// [`parallel_for_chunks`] with an explicit thread count: `nthreads <= 1`
/// runs fully serially on the calling thread (the pool is not even
/// constructed); larger values enable the shared pool, whose size is fixed
/// at `num_threads() - 1` workers regardless of the request.
pub fn parallel_for_chunks_threads<F>(n: usize, chunk: usize, nthreads: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let chunk = chunk.max(1);
    if n == 0 {
        return;
    }
    let nchunks = n.div_ceil(chunk);
    if nthreads <= 1 || nchunks <= 1 || in_parallel_region() {
        run_serial(n, chunk, &body);
        return;
    }
    pool().run(n, chunk, &body);
}

/// Parallel map over `0..n`, collecting results in order. Work is
/// distributed in contiguous chunks written disjointly — no per-element
/// locking.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_threads(n, num_threads(), f)
}

/// [`parallel_map`] with an explicit thread count (see
/// [`parallel_for_chunks_threads`]).
pub fn parallel_map_threads<T, F>(n: usize, nthreads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    if n == 0 {
        return out;
    }
    let chunk = n.div_ceil(4 * nthreads.max(1)).max(1);
    parallel_fill_threads(&mut out, chunk, nthreads, |start, block| {
        for (k, slot) in block.iter_mut().enumerate() {
            *slot = f(start + k);
        }
    });
    out
}

/// Write-disjoint parallel fill: partitions `out` into `chunk`-row blocks
/// and calls `body(block_start, block_slice)` concurrently.
pub fn parallel_fill<T, F>(out: &mut [T], chunk: usize, body: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    parallel_fill_threads(out, chunk, num_threads(), body);
}

/// [`parallel_fill`] with an explicit thread count (see
/// [`parallel_for_chunks_threads`]).
pub fn parallel_fill_threads<T, F>(out: &mut [T], chunk: usize, nthreads: usize, body: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    let chunk = chunk.max(1);
    if n == 0 {
        return;
    }
    if nthreads <= 1 || n <= chunk || in_parallel_region() {
        for (ci, block) in out.chunks_mut(chunk).enumerate() {
            body(ci * chunk, block);
        }
        return;
    }
    let base = SendPtr(out.as_mut_ptr());
    parallel_for_chunks_threads(n, chunk, nthreads, move |s, e| {
        // SAFETY: the scheduler hands out disjoint in-bounds ranges, so the
        // reconstructed `&mut` blocks never alias, and `out` outlives the
        // call (the job completes before `parallel_for_chunks_threads`
        // returns).
        let block = unsafe { std::slice::from_raw_parts_mut(base.0.add(s), e - s) };
        body(s, block);
    });
}

#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
// SAFETY: the pointer is only ever used to carve out disjoint `&mut [T]`
// blocks across threads, which is sound exactly when `T: Send`.
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: as above — shared references to the wrapper only copy the pointer.
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Queue discipline for a [`TaskPool`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskOrder {
    /// First submitted, first run (batch execution: fairness).
    Fifo,
    /// Last submitted, first run (the warmer: a burst of re-registrations
    /// should warm the *newest* operator version first — older queued jobs
    /// are likely already stale).
    Lifo,
}

type PoolJob = Box<dyn FnOnce() + Send + 'static>;

struct TaskPoolState {
    queue: VecDeque<PoolJob>,
    stop: bool,
}

struct TaskPoolShared {
    state: Mutex<TaskPoolState>,
    cv: Condvar,
}

/// A small general-purpose **task** pool: independent `FnOnce` jobs on a
/// fixed set of parked worker threads, with a configurable queue order.
///
/// This is deliberately separate from the data-parallel chunk pool above:
/// that one runs *one* job's chunks across every worker (and the submitter)
/// with a barrier; this one runs *many* unrelated jobs concurrently with no
/// barrier. The coordinator uses two of them — a FIFO pool for batch
/// execution and a LIFO pool for background context warming — so neither
/// path ever polls: workers park on a condvar until a job arrives.
///
/// Dropping the pool **drains the queue**: workers finish every job
/// submitted before the drop, then exit. (Shutdown must not abandon
/// accepted work — an in-flight batch's clients are waiting on it.) Like
/// the chunk pool, the whole handshake runs on the [`crate::util::sync`]
/// primitives, and [`TaskPool::with_spawner`] lets the model checker run
/// the workers as model threads and explore the park/drain interleavings.
pub struct TaskPool {
    shared: Arc<TaskPoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl TaskPool {
    /// A pool of `workers.max(1)` named threads with the given queue order.
    pub fn new(name: &str, workers: usize, order: TaskOrder) -> TaskPool {
        let shared = Self::fresh_shared();
        let handles = (0..workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(name.to_string())
                    .spawn(move || task_pool_worker(&shared, order))
                    .expect("failed to spawn task pool worker")
            })
            .collect();
        TaskPool { shared, handles }
    }

    /// Injectable-spawner constructor (the [`ChunkPool::spawn_workers_with`]
    /// pattern): hands `workers.max(1)` worker-loop closures to `spawn`
    /// instead of spawning OS threads, so the model checker
    /// (`rust/tests/model_exec.rs`) can drive the pool's park/drain
    /// handshake on *model* threads — same worker code either way. The
    /// caller owns the workers' lifecycles: call [`TaskPool::shutdown`] and
    /// join what it spawned; drop only re-signals stop (no handles to join).
    pub fn with_spawner(
        workers: usize,
        order: TaskOrder,
        mut spawn: impl FnMut(Box<dyn FnOnce() + Send + 'static>),
    ) -> TaskPool {
        let shared = Self::fresh_shared();
        for _ in 0..workers.max(1) {
            let shared = shared.clone();
            spawn(Box::new(move || task_pool_worker(&shared, order)));
        }
        TaskPool { shared, handles: Vec::new() }
    }

    fn fresh_shared() -> Arc<TaskPoolShared> {
        Arc::new(TaskPoolShared {
            state: Mutex::new(TaskPoolState { queue: VecDeque::new(), stop: false }),
            cv: Condvar::new(),
        })
    }

    /// Ask the workers to exit once the queue is drained (`stop` is honored
    /// only after a pop comes up empty, so every job accepted before this
    /// call still runs). Idempotent; [`Drop`] calls it too.
    pub fn shutdown(&self) {
        self.shared.state.lock().unwrap().stop = true;
        self.shared.cv.notify_all();
    }

    /// Enqueue a job and wake a worker.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let mut st = self.shared.state.lock().unwrap();
        st.queue.push_back(Box::new(job));
        drop(st);
        self.shared.cv.notify_one();
    }

    /// Jobs queued but not yet started.
    pub fn pending(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// Worker thread count.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for TaskPool {
    fn drop(&mut self) {
        self.shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn task_pool_worker(shared: &TaskPoolShared, order: TaskOrder) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                let popped = match order {
                    TaskOrder::Fifo => st.queue.pop_front(),
                    TaskOrder::Lifo => st.queue.pop_back(),
                };
                if let Some(j) = popped {
                    break Some(j);
                }
                if st.stop {
                    break None;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        match job {
            // a panicking job must not kill the worker: later jobs (and the
            // drop-time drain) still need it
            Some(j) => {
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(j));
            }
            None => return,
        }
    }
}

/// Pre-pool reference implementation: spawns fresh scoped threads on every
/// call. Kept (not routed anywhere hot) as the *before* side of the
/// `BENCH_kernel_mvm.json` comparison and as a correctness oracle in tests.
pub fn parallel_fill_scoped<T, F>(out: &mut [T], chunk: usize, body: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    let chunk = chunk.max(1);
    let nthreads = num_threads();
    if nthreads == 1 || n <= chunk {
        for (ci, block) in out.chunks_mut(chunk).enumerate() {
            body(ci * chunk, block);
        }
        return;
    }
    let blocks: Vec<(usize, &mut [T])> = {
        let mut v = Vec::new();
        let mut rest = out;
        let mut start = 0;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            v.push((start, head));
            start += take;
            rest = tail;
        }
        v
    };
    let counter = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<(usize, &mut [T])>>> =
        blocks.into_iter().map(|b| Mutex::new(Some(b))).collect();
    std::thread::scope(|scope| {
        for _ in 0..nthreads.min(slots.len()) {
            scope.spawn(|| loop {
                // ordering: Relaxed — claim counter; the scope join is the
                // publication barrier for the written blocks.
                let c = counter.fetch_add(1, Ordering::Relaxed);
                if c >= slots.len() {
                    break;
                }
                if let Some((start, block)) = slots[c].lock().unwrap().take() {
                    body(start, block);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let n = 1003;
        let sum = AtomicU64::new(0);
        parallel_for_chunks(n, 64, |s, e| {
            let local: u64 = (s..e).map(|i| i as u64).sum();
            sum.fetch_add(local, std::sync::atomic::Ordering::Relaxed);
        });
        let expect: u64 = (0..n as u64).sum();
        assert_eq!(sum.load(std::sync::atomic::Ordering::Relaxed), expect);
    }

    #[test]
    fn parallel_fill_writes_all() {
        let mut v = vec![0usize; 777];
        parallel_fill(&mut v, 50, |start, block| {
            for (k, x) in block.iter_mut().enumerate() {
                *x = start + k;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn parallel_fill_scoped_matches_pool() {
        let mut a = vec![0usize; 513];
        let mut b = vec![0usize; 513];
        parallel_fill(&mut a, 32, |start, block| {
            for (k, x) in block.iter_mut().enumerate() {
                *x = (start + k) * 3;
            }
        });
        parallel_fill_scoped(&mut b, 32, |start, block| {
            for (k, x) in block.iter_mut().enumerate() {
                *x = (start + k) * 3;
            }
        });
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_map_in_order() {
        let v = parallel_map(100, |i| i * i);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn empty_range_ok() {
        parallel_for_chunks(0, 8, |_, _| panic!("must not be called"));
        let mut v: Vec<u8> = vec![];
        parallel_fill(&mut v, 8, |_, _| panic!("must not be called"));
    }

    #[test]
    fn pool_threads_spawn_once_per_process() {
        let fill = |v: &mut [u64]| {
            parallel_fill_threads(v, 64, 8, |s, block| {
                for (k, x) in block.iter_mut().enumerate() {
                    *x = (s + k) as u64;
                }
            });
        };
        let mut v = vec![0u64; 4096];
        fill(&mut v);
        let after_first = pool_spawned_threads();
        for _ in 0..64 {
            fill(&mut v);
            parallel_for_chunks_threads(4096, 64, 8, |_s, _e| {});
        }
        assert_eq!(
            pool_spawned_threads(),
            after_first,
            "pool must not respawn threads per call"
        );
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u64);
        }
    }

    #[test]
    fn private_chunk_pool_with_injected_spawner_runs_and_shuts_down() {
        // The model checker's entry path, exercised here with real threads:
        // a private ChunkPool whose workers come from an injected spawner.
        let pool = ChunkPool::new(2);
        let mut handles = Vec::new();
        pool.spawn_workers_with(|w| handles.push(std::thread::spawn(w)));
        let sum = AtomicUsize::new(0);
        for _ in 0..3 {
            sum.store(0, std::sync::atomic::Ordering::SeqCst);
            pool.run(100, 10, &|s, e| {
                sum.fetch_add(e - s, std::sync::atomic::Ordering::SeqCst);
            });
            assert_eq!(sum.load(std::sync::atomic::Ordering::SeqCst), 100);
        }
        pool.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn one_thread_runs_fully_serial_on_calling_thread() {
        let me = std::thread::current().id();
        let ids = Mutex::new(Vec::new());
        parallel_for_chunks_threads(100, 7, 1, |_s, _e| {
            ids.lock().unwrap().push(std::thread::current().id());
        });
        let mut v = vec![0u8; 100];
        parallel_fill_threads(&mut v, 7, 1, |_s, block| {
            ids.lock().unwrap().push(std::thread::current().id());
            for x in block.iter_mut() {
                *x = 1;
            }
        });
        let ids = ids.into_inner().unwrap();
        assert!(!ids.is_empty());
        assert!(
            ids.iter().all(|&id| id == me),
            "nthreads=1 must never leave the calling thread"
        );
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn nested_parallel_calls_run_serially_without_deadlock() {
        let total = std::sync::atomic::AtomicUsize::new(0);
        parallel_for_chunks_threads(8, 1, 4, |_s, _e| {
            parallel_for_chunks_threads(10, 3, 4, |a, b| {
                total.fetch_add(b - a, std::sync::atomic::Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(std::sync::atomic::Ordering::Relaxed), 80);
    }

    #[test]
    fn task_pool_runs_all_jobs_and_drains_on_drop() {
        let done = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let pool = TaskPool::new("tp-test", 3, TaskOrder::Fifo);
        for _ in 0..50 {
            let done = done.clone();
            pool.submit(move || {
                done.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            });
        }
        drop(pool); // must finish every accepted job before joining
        assert_eq!(done.load(std::sync::atomic::Ordering::SeqCst), 50);
    }

    #[test]
    fn task_pool_lifo_runs_newest_first() {
        // one worker, jobs gated so the queue builds up before any pops
        let gate = Arc::new(Mutex::new(()));
        let order = Arc::new(Mutex::new(Vec::new()));
        let pool = TaskPool::new("tp-lifo", 1, TaskOrder::Lifo);
        let g = gate.lock().unwrap();
        for i in 0..4 {
            let (gate, order) = (gate.clone(), order.clone());
            pool.submit(move || {
                drop(gate.lock().unwrap());
                order.lock().unwrap().push(i);
            });
        }
        // job 0 may already be claimed by the (blocked) worker; the rest
        // must pop newest-first
        drop(g);
        drop(pool);
        let order = order.lock().unwrap().clone();
        assert_eq!(order.len(), 4);
        let tail: Vec<usize> = order.iter().copied().filter(|&i| i != order[0]).collect();
        let mut sorted_desc = tail.clone();
        sorted_desc.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(tail, sorted_desc, "LIFO pool ran queued jobs oldest-first: {order:?}");
    }

    #[test]
    fn task_pool_survives_panicking_job() {
        let done = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let pool = TaskPool::new("tp-panic", 1, TaskOrder::Fifo);
        pool.submit(|| panic!("job panic must not kill the worker"));
        let d = done.clone();
        pool.submit(move || {
            d.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        drop(pool);
        assert_eq!(done.load(std::sync::atomic::Ordering::SeqCst), 1);
    }

    #[test]
    fn concurrent_submitters_serialize_safely() {
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..10 {
                        let sum = std::sync::atomic::AtomicUsize::new(0);
                        parallel_for_chunks_threads(1000, 16, 4, |a, b| {
                            sum.fetch_add(b - a, std::sync::atomic::Ordering::Relaxed);
                        });
                        assert_eq!(sum.load(std::sync::atomic::Ordering::Relaxed), 1000);
                    }
                });
            }
        });
    }
}

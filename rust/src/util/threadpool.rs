//! Scoped data parallelism over index ranges (no rayon offline — built on
//! `std::thread::scope` with an atomic work queue).
//!
//! The kernel-matrix MVMs (the hot path of the whole system) split their row
//! range into chunks and let a fixed set of worker threads steal chunks from
//! a shared counter. Results are written into disjoint slices of the output,
//! so no locking is needed on the data itself.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (cached; `CIQ_THREADS` env overrides).
pub fn num_threads() -> usize {
    use std::sync::OnceLock;
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(s) = std::env::var("CIQ_THREADS") {
            if let Ok(n) = s.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    })
}

/// Run `body(start, end)` over chunked sub-ranges of `0..n` in parallel.
///
/// `body` must be safe to call concurrently on disjoint ranges. Chunks are
/// `chunk`-sized except possibly the last. Falls back to a serial loop when
/// the range is small or only one thread is available.
pub fn parallel_for_chunks<F>(n: usize, chunk: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let chunk = chunk.max(1);
    let nthreads = num_threads();
    let nchunks = n.div_ceil(chunk);
    if nthreads == 1 || nchunks <= 1 {
        let mut s = 0;
        while s < n {
            let e = (s + chunk).min(n);
            body(s, e);
            s = e;
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    let workers = nthreads.min(nchunks);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let c = counter.fetch_add(1, Ordering::Relaxed);
                if c >= nchunks {
                    break;
                }
                let s = c * chunk;
                let e = (s + chunk).min(n);
                body(s, e);
            });
        }
    });
}

/// Parallel map over `0..n`, collecting results in order.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for_chunks(n, 1, |s, e| {
            for i in s..e {
                **slots[i].lock().unwrap() = f(i);
            }
        });
    }
    out
}

/// Write-disjoint parallel fill: partitions `out` into `chunk`-row blocks and
/// calls `body(block_start, block_slice)` concurrently.
pub fn parallel_fill<T, F>(out: &mut [T], chunk: usize, body: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    let chunk = chunk.max(1);
    let nthreads = num_threads();
    if nthreads == 1 || n <= chunk {
        for (ci, block) in out.chunks_mut(chunk).enumerate() {
            body(ci * chunk, block);
        }
        return;
    }
    let blocks: Vec<(usize, &mut [T])> = {
        let mut v = Vec::new();
        let mut rest = out;
        let mut start = 0;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            v.push((start, head));
            start += take;
            rest = tail;
        }
        v
    };
    let counter = AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<Option<(usize, &mut [T])>>> =
        blocks.into_iter().map(|b| std::sync::Mutex::new(Some(b))).collect();
    std::thread::scope(|scope| {
        for _ in 0..nthreads.min(slots.len()) {
            scope.spawn(|| loop {
                let c = counter.fetch_add(1, Ordering::Relaxed);
                if c >= slots.len() {
                    break;
                }
                if let Some((start, block)) = slots[c].lock().unwrap().take() {
                    body(start, block);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let n = 1003;
        let sum = AtomicU64::new(0);
        parallel_for_chunks(n, 64, |s, e| {
            let local: u64 = (s..e).map(|i| i as u64).sum();
            sum.fetch_add(local, Ordering::Relaxed);
        });
        let expect: u64 = (0..n as u64).sum();
        assert_eq!(sum.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn parallel_fill_writes_all() {
        let mut v = vec![0usize; 777];
        parallel_fill(&mut v, 50, |start, block| {
            for (k, x) in block.iter_mut().enumerate() {
                *x = start + k;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn parallel_map_in_order() {
        let v = parallel_map(100, |i| i * i);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn empty_range_ok() {
        parallel_for_chunks(0, 8, |_, _| panic!("must not be called"));
        let mut v: Vec<u8> = vec![];
        parallel_fill(&mut v, 8, |_, _| panic!("must not be called"));
    }
}

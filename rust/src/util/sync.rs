//! Sync facade for the shimmed concurrency modules (`exec/`,
//! `util/threadpool.rs`, `obs/trace.rs`).
//!
//! Normally this is a zero-cost re-export of the `std::sync` types, so the
//! production build is byte-for-byte the std code. Under `--cfg ciq_model`
//! (`RUSTFLAGS="--cfg ciq_model" cargo test --test model_exec`) the same
//! names resolve to [`crate::util::model::shim`], whose operations become
//! scheduling points of the deterministic interleaving checker in
//! [`crate::util::model`].
//!
//! Rules for shimmed modules (enforced by `tools/structlint.rs`):
//!
//! - import `Mutex`/`Condvar`/`Atomic*`/`Ordering`/`fence` from here, never
//!   from `std::sync` directly;
//! - `Arc`, `OnceLock`, and `mpsc` are *not* shimmed (they carry no
//!   interesting interleavings of their own) and stay on `std::sync`;
//! - no `std::thread::park` — parking must go through a shimmed `Condvar`
//!   so the model scheduler can see it.

#[cfg(not(ciq_model))]
pub use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};
#[cfg(not(ciq_model))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(ciq_model)]
pub use crate::util::model::shim::{
    fence, AtomicBool, AtomicU64, AtomicUsize, Condvar, Mutex, MutexGuard, Ordering,
};

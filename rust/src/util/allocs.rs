//! A counting global allocator for allocation-pressure regression tests and
//! the `perf_hotpath` §7 alloc bench.
//!
//! [`CountingAllocator`] wraps [`std::alloc::System`] and bumps a
//! **thread-local** counter on every `alloc`/`alloc_zeroed`/`realloc`. It is
//! *defined* here but *registered* only by the binaries that measure
//! allocation pressure (`rust/tests/alloc_regression.rs`,
//! `rust/benches/perf_hotpath.rs`) via `#[global_allocator]` — the library
//! itself never changes the process allocator.
//!
//! The counter is thread-local so a measurement brackets exactly the work
//! the measuring thread performs: the zero-allocation steady-state claim for
//! the solve stack is that the *submitting* thread performs no allocations
//! inside a warmed `krylov`/`ciq` solve (pool workers only run
//! allocation-free GEMM bodies; the regression tests additionally pin
//! `CIQ_THREADS=1` so every instruction of the solve runs on the counted
//! thread).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    // const-init: no lazy TLS initialization (which could itself allocate)
    // inside the allocator.
    static ALLOC_COUNT: Cell<u64> = const { Cell::new(0) };
}

/// Allocations (`alloc`/`alloc_zeroed`/`realloc`) performed by the current
/// thread since it started, when [`CountingAllocator`] is the registered
/// global allocator. Always 0 otherwise.
pub fn thread_allocs() -> u64 {
    ALLOC_COUNT.try_with(|c| c.get()).unwrap_or(0)
}

#[inline]
fn bump() {
    // try_with: the allocator can be called during TLS setup/teardown.
    let _ = ALLOC_COUNT.try_with(|c| c.set(c.get() + 1));
}

/// A [`System`]-backed allocator that counts per-thread allocation events.
pub struct CountingAllocator;

// SAFETY: pure delegation to `System`; the counter never influences the
// returned pointers or layouts.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        // SAFETY: caller upholds `GlobalAlloc::alloc`'s contract; forwarded
        // unchanged to `System`.
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        // SAFETY: caller upholds `GlobalAlloc::alloc_zeroed`'s contract.
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        // SAFETY: caller upholds `GlobalAlloc::realloc`'s contract (`ptr`
        // from this allocator with `layout`).
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: caller upholds `GlobalAlloc::dealloc`'s contract.
        unsafe { System.dealloc(ptr, layout) }
    }
}

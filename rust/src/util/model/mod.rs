//! Deterministic interleaving checker ("loom-lite") behind the
//! [`crate::util::sync`] facade.
//!
//! The checker runs a test closure many times, each time forcing a different
//! thread interleaving, and reports the first schedule under which the
//! closure panics, asserts, or deadlocks. It is the model-side backend of
//! `util/sync`: when the crate is compiled with `--cfg ciq_model`, every
//! `sync::Mutex` / `sync::Condvar` / `sync::Atomic*` operation performed by a
//! thread spawned through [`spawn`] becomes a *scheduling point* routed
//! through the [`Sched`] token-passing scheduler below.
//!
//! # Execution model
//!
//! Threads are real OS threads, but exactly **one** is runnable at a time: a
//! single token (`SchedState::running`) is handed from thread to thread at
//! scheduling points, so every execution is a deterministic serialization
//! chosen by the [`Explorer`]. This checks *interleavings* under sequential
//! consistency — all shim atomics execute as `SeqCst` regardless of the
//! `Ordering` the caller passed. Protocol bugs (lost wakeups, missed
//! rendezvous, use-of-stale-state windows, deadlocks) are in scope;
//! weak-memory reorderings are not — that is what the Miri/TSan CI lanes are
//! for (see DESIGN.md §5).
//!
//! # Exploration
//!
//! Each run is a path through a schedule tree whose branch points are the
//! `choose(n)` calls the scheduler makes when more than one thread could run
//! next. Two modes:
//!
//! - [`ModelConfig::dfs`]: iterative depth-first enumeration of the tree
//!   with a CHESS-style *preemption bound*: context switches at blocking
//!   points (lock contention, condvar wait, join, thread exit) are always
//!   explored for free, but involuntary switches at non-blocking points
//!   (atomic ops, lock release) are limited to `max_preemptions` per
//!   execution. Most real protocol bugs need only 1–2 preemptions, which
//!   keeps the tree tractable while still falsifying the scary windows.
//! - [`ModelConfig::random`]: seeded pseudo-random walks. The seed fully
//!   determines every schedule, so re-running with a printed seed replays a
//!   failure exactly.
//!
//! On failure the checker panics with the first error plus the schedule
//! trace (the sequence of branch choices) that produced it.

pub mod shim;

use crate::rng::Pcg64;
use std::cell::RefCell;
use std::collections::HashSet;
use std::panic::AssertUnwindSafe;
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard};

// ---------------------------------------------------------------------------
// Exploration
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
enum Mode {
    Dfs { max_preemptions: usize },
    Random { seed: u64 },
}

#[derive(Clone, Copy)]
struct Choice {
    chosen: usize,
    num: usize,
}

/// Persistent (across iterations) schedule-tree cursor.
struct Explorer {
    mode: Mode,
    /// Path through the schedule tree: replayed prefix + fresh suffix.
    stack: Vec<Choice>,
    /// Replay cursor within the current iteration.
    depth: usize,
    /// Involuntary context switches taken this iteration (DFS budget).
    preemptions: usize,
    rng: Pcg64,
}

impl Explorer {
    fn new(mode: Mode) -> Self {
        let seed = match mode {
            Mode::Random { seed } => seed,
            Mode::Dfs { .. } => 0,
        };
        Explorer { mode, stack: Vec::new(), depth: 0, preemptions: 0, rng: Pcg64::seeded(seed) }
    }

    fn begin_iteration(&mut self, iter: u64) {
        self.depth = 0;
        self.preemptions = 0;
        if let Mode::Random { seed } = self.mode {
            // Distinct deterministic stream per iteration.
            self.rng = Pcg64::seeded(seed ^ iter.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            self.stack.clear();
        }
    }

    /// Resolve one branch point with `n` options; replays the recorded
    /// prefix, then extends depth-first (option 0) or randomly.
    fn choose(&mut self, n: usize) -> usize {
        debug_assert!(n >= 2);
        if self.depth < self.stack.len() {
            if self.stack[self.depth].num == n {
                let c = self.stack[self.depth].chosen;
                self.depth += 1;
                return c;
            }
            // Divergence from the recorded path (only possible if the test
            // body itself is nondeterministic); drop the stale suffix.
            self.stack.truncate(self.depth);
        }
        let pick = match self.mode {
            Mode::Dfs { .. } => 0,
            Mode::Random { .. } => self.rng.below(n),
        };
        self.stack.push(Choice { chosen: pick, num: n });
        self.depth += 1;
        pick
    }

    /// Move to the next schedule. Returns `false` when the tree is exhausted
    /// (DFS only; random walks never exhaust).
    fn advance(&mut self) -> bool {
        match self.mode {
            Mode::Random { .. } => true,
            Mode::Dfs { .. } => {
                while let Some(c) = self.stack.pop() {
                    if c.chosen + 1 < c.num {
                        self.stack.push(Choice { chosen: c.chosen + 1, num: c.num });
                        return true;
                    }
                }
                false
            }
        }
    }

    fn trace(&self) -> Vec<usize> {
        self.stack[..self.depth.min(self.stack.len())].iter().map(|c| c.chosen).collect()
    }
}

// ---------------------------------------------------------------------------
// Scheduler
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Status {
    /// Eligible to receive the token.
    Runnable,
    /// Blocked acquiring the model mutex at this address.
    LockWait(usize),
    /// Parked on the condvar at `cv`. `timeout` waits are always eligible
    /// (the scheduler may "fire the timeout" at any point); `notified` marks
    /// a wakeup that has been delivered but not yet scheduled.
    CvWait { cv: usize, timeout: bool, notified: bool },
    /// Blocked in `JoinHandle::join` on the given model thread id.
    JoinWait(usize),
    Finished,
}

struct SchedState {
    status: Vec<Status>,
    /// Model tid currently holding the execution token.
    running: usize,
    /// Addresses of model mutexes currently held.
    locked: HashSet<usize>,
    /// Scheduling events this iteration (runaway-schedule bound).
    steps: usize,
    live: usize,
    abort: bool,
    error: Option<String>,
}

/// Sentinel panic payload used to unwind model threads after an abort; never
/// reported as a failure itself.
struct ModelAbort;

fn abort_panic() -> ! {
    std::panic::panic_any(ModelAbort);
}

fn payload_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "model thread panicked (non-string payload)".to_string()
    }
}

pub(crate) struct Sched {
    state: StdMutex<SchedState>,
    cv: StdCondvar,
    explorer: Arc<StdMutex<Explorer>>,
    handles: StdMutex<Vec<std::thread::JoinHandle<()>>>,
    max_steps: usize,
}

thread_local! {
    static CTX: RefCell<Option<(Arc<Sched>, usize)>> = const { RefCell::new(None) };
}

/// The scheduler + tid of the calling thread, if it is a model thread.
/// Shim primitives use this to decide between model routing and plain std.
pub(crate) fn current() -> Option<(Arc<Sched>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

impl Sched {
    fn new(explorer: Arc<StdMutex<Explorer>>, max_steps: usize) -> Self {
        Sched {
            state: StdMutex::new(SchedState {
                status: Vec::new(),
                running: 0,
                locked: HashSet::new(),
                steps: 0,
                live: 0,
                abort: false,
                error: None,
            }),
            cv: StdCondvar::new(),
            explorer,
            handles: StdMutex::new(Vec::new()),
            max_steps,
        }
    }

    /// Poison-tolerant state lock: model threads unwind (panic) while holding
    /// it during aborts, and every other thread must still make progress.
    fn guard(&self) -> StdMutexGuard<'_, SchedState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Tids eligible to receive the token, in ascending-tid order.
    fn enabled(&self, st: &SchedState, exclude: Option<usize>) -> Vec<usize> {
        st.status
            .iter()
            .enumerate()
            .filter(|&(t, s)| {
                Some(t) != exclude
                    && matches!(
                        s,
                        Status::Runnable
                            | Status::CvWait { notified: true, .. }
                            | Status::CvWait { timeout: true, .. }
                    )
            })
            .map(|(t, _)| t)
            .collect()
    }

    fn choose(&self, n: usize) -> usize {
        if n <= 1 {
            return 0;
        }
        self.explorer.lock().unwrap_or_else(|e| e.into_inner()).choose(n)
    }

    /// Park until this thread holds the token (or the run is aborting).
    fn wait_for_token<'a>(
        &'a self,
        mut st: StdMutexGuard<'a, SchedState>,
        me: usize,
    ) -> StdMutexGuard<'a, SchedState> {
        loop {
            if st.abort {
                drop(st);
                abort_panic();
            }
            if st.running == me {
                return st;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Hand the token to some enabled thread; `me` is no longer eligible
    /// (its status was already changed). Detects deadlock: live threads
    /// remain but none is enabled.
    fn reschedule_from(&self, st: &mut SchedState, me: usize) {
        let en = self.enabled(st, None);
        if en.is_empty() {
            if st.live > 0 {
                let snapshot: Vec<(usize, Status)> =
                    st.status.iter().copied().enumerate().collect();
                st.abort = true;
                if st.error.is_none() {
                    st.error = Some(format!(
                        "deadlock: no runnable thread (thread {me} blocked last); states: {snapshot:?}"
                    ));
                }
            }
            self.cv.notify_all();
            return;
        }
        let k = self.choose(en.len());
        st.running = en[k];
        self.cv.notify_all();
    }

    fn bump_steps(&self, st: &mut SchedState) {
        st.steps += 1;
        if st.steps > self.max_steps {
            st.abort = true;
            if st.error.is_none() {
                st.error = Some(format!(
                    "schedule exceeded {} steps (livelock or unbounded loop under the model)",
                    self.max_steps
                ));
            }
            self.cv.notify_all();
        }
    }

    /// Non-blocking scheduling point: optionally hand the token to another
    /// enabled thread (an involuntary preemption, budgeted under DFS) and
    /// wait to get it back.
    pub(crate) fn preempt(&self, me: usize) {
        let mut st = self.guard();
        if st.abort {
            drop(st);
            abort_panic();
        }
        self.bump_steps(&mut st);
        if st.abort {
            drop(st);
            abort_panic();
        }
        let others = self.enabled(&st, Some(me));
        if others.is_empty() {
            return;
        }
        let may_preempt = {
            let ex = self.explorer.lock().unwrap_or_else(|e| e.into_inner());
            match ex.mode {
                Mode::Dfs { max_preemptions } => ex.preemptions < max_preemptions,
                Mode::Random { .. } => true,
            }
        };
        if !may_preempt {
            return;
        }
        let k = self.choose(1 + others.len());
        if k == 0 {
            return;
        }
        self.explorer.lock().unwrap_or_else(|e| e.into_inner()).preemptions += 1;
        st.running = others[k - 1];
        self.cv.notify_all();
        let st = self.wait_for_token(st, me);
        drop(st);
    }

    fn do_unlock(&self, st: &mut SchedState, addr: usize) {
        st.locked.remove(&addr);
        for s in st.status.iter_mut() {
            if *s == Status::LockWait(addr) {
                *s = Status::Runnable;
            }
        }
    }

    pub(crate) fn lock_acquire(&self, me: usize, addr: usize) {
        self.preempt(me);
        let mut st = self.guard();
        loop {
            if st.abort {
                drop(st);
                abort_panic();
            }
            if !st.locked.contains(&addr) {
                st.locked.insert(addr);
                return;
            }
            st.status[me] = Status::LockWait(addr);
            self.reschedule_from(&mut st, me);
            st = self.wait_for_token(st, me);
            st.status[me] = Status::Runnable;
        }
    }

    pub(crate) fn lock_release(&self, me: usize, addr: usize) {
        {
            let mut st = self.guard();
            self.do_unlock(&mut st, addr);
        }
        // Releasing a lock is a visible event: let the checker hand the
        // token to a thread that was spinning on this lock.
        self.preempt(me);
    }

    /// Condvar wait: atomically (w.r.t. the scheduler) release the model
    /// mutex and register as a waiter, then block until notified (or, for
    /// `timeout` waits, until the scheduler nondeterministically fires the
    /// timeout). Returns `true` if the wakeup was a notification.
    pub(crate) fn cv_wait(&self, me: usize, cv: usize, mx: usize, timeout: bool) -> bool {
        let mut st = self.guard();
        if st.abort {
            drop(st);
            abort_panic();
        }
        self.bump_steps(&mut st);
        self.do_unlock(&mut st, mx);
        st.status[me] = Status::CvWait { cv, timeout, notified: false };
        self.reschedule_from(&mut st, me);
        st = self.wait_for_token(st, me);
        let notified = match st.status[me] {
            Status::CvWait { notified, .. } => notified,
            _ => true,
        };
        st.status[me] = Status::Runnable;
        drop(st);
        notified
    }

    /// Deliver a notification to the longest-parked waiter(s) on `cv`
    /// (deterministically: ascending tid order). Waiters become eligible but
    /// do not run until scheduled.
    pub(crate) fn cv_notify(&self, me: usize, cv: usize, all: bool) {
        self.preempt(me);
        let mut st = self.guard();
        for s in st.status.iter_mut() {
            if let Status::CvWait { cv: c, timeout, notified: false } = *s {
                if c == cv {
                    *s = Status::CvWait { cv: c, timeout, notified: true };
                    if !all {
                        break;
                    }
                }
            }
        }
    }

    pub(crate) fn join_wait(&self, me: usize, target: usize) {
        self.preempt(me);
        let mut st = self.guard();
        if st.abort {
            drop(st);
            abort_panic();
        }
        if st.status[target] == Status::Finished {
            return;
        }
        st.status[me] = Status::JoinWait(target);
        self.reschedule_from(&mut st, me);
        st = self.wait_for_token(st, me);
        st.status[me] = Status::Runnable;
    }

    fn thread_finish(&self, me: usize) {
        let mut st = self.guard();
        st.status[me] = Status::Finished;
        st.live -= 1;
        for s in st.status.iter_mut() {
            if *s == Status::JoinWait(me) {
                *s = Status::Runnable;
            }
        }
        if st.live == 0 {
            self.cv.notify_all();
            return;
        }
        self.reschedule_from(&mut st, me);
    }

    fn record_panic(&self, payload: &(dyn std::any::Any + Send)) {
        if payload.downcast_ref::<ModelAbort>().is_some() {
            return;
        }
        let msg = payload_msg(payload);
        let mut st = self.guard();
        if st.error.is_none() {
            st.error = Some(msg);
        }
        st.abort = true;
        self.cv.notify_all();
    }

    /// Register a new model thread and start its OS thread. The thread parks
    /// until first scheduled.
    fn start_thread(
        self: &Arc<Self>,
        f: Box<dyn FnOnce() + Send + 'static>,
        root: bool,
    ) -> usize {
        let tid = {
            let mut st = self.guard();
            st.status.push(Status::Runnable);
            st.live += 1;
            if root {
                st.running = 0;
            }
            st.status.len() - 1
        };
        let sched = self.clone();
        let h = std::thread::Builder::new()
            .name(format!("ciq-model-{tid}"))
            .spawn(move || {
                CTX.with(|c| *c.borrow_mut() = Some((sched.clone(), tid)));
                {
                    let st = sched.guard();
                    // A freshly-aborted run can finish before we are ever
                    // scheduled; swallow the unwind sentinel in that case.
                    let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
                        let st = sched.wait_for_token(st, tid);
                        drop(st);
                    }));
                    if r.is_err() {
                        sched.thread_finish(tid);
                        CTX.with(|c| *c.borrow_mut() = None);
                        return;
                    }
                }
                let r = std::panic::catch_unwind(AssertUnwindSafe(f));
                if let Err(p) = r {
                    sched.record_panic(&*p);
                }
                sched.thread_finish(tid);
                CTX.with(|c| *c.borrow_mut() = None);
            })
            .expect("spawn model thread");
        self.handles.lock().unwrap_or_else(|e| e.into_inner()).push(h);
        tid
    }

    /// Driver side: block until every model thread has finished, then reap
    /// the OS threads.
    fn wait_all(&self) {
        let mut st = self.guard();
        while st.live > 0 {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        drop(st);
        let handles: Vec<_> =
            self.handles.lock().unwrap_or_else(|e| e.into_inner()).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Public API
// ---------------------------------------------------------------------------

/// Handle to a model thread spawned with [`spawn`].
pub struct JoinHandle {
    sched: Arc<Sched>,
    tid: usize,
}

impl JoinHandle {
    /// Block (as a model scheduling point) until the thread finishes.
    pub fn join(self) {
        let (sched, me) = current().expect("JoinHandle::join outside a model thread");
        debug_assert!(Arc::ptr_eq(&sched, &self.sched));
        sched.join_wait(me, self.tid);
    }
}

/// Spawn a model thread. Must be called from inside a [`check`] closure (or
/// a thread transitively spawned by one).
pub fn spawn<F: FnOnce() + Send + 'static>(f: F) -> JoinHandle {
    let (sched, me) = current().expect("model::spawn outside a model run");
    let tid = sched.start_thread(Box::new(f), false);
    // Spawning is a visible event: the child may run before we continue.
    sched.preempt(me);
    JoinHandle { sched, tid }
}

/// Exploration configuration for [`check_with`].
pub struct ModelConfig {
    /// Stop after this many executions even if DFS has not exhausted the
    /// schedule tree (a coverage bound, not a correctness bound).
    pub max_iterations: usize,
    /// Per-execution scheduling-event bound; exceeding it fails the check
    /// (livelock / unbounded loop detector).
    pub max_steps: usize,
    mode: Mode,
}

impl ModelConfig {
    /// Bounded-DFS enumeration with at most `max_preemptions` involuntary
    /// context switches per execution (switches at blocking points are
    /// always free).
    pub fn dfs(max_preemptions: usize) -> Self {
        ModelConfig { max_iterations: 4096, max_steps: 100_000, mode: Mode::Dfs { max_preemptions } }
    }

    /// Seeded random-walk mode: `iterations` schedules drawn from a PRNG
    /// stream fully determined by `seed` (replay = same seed).
    pub fn random(seed: u64, iterations: usize) -> Self {
        ModelConfig { max_iterations: iterations, max_steps: 100_000, mode: Mode::Random { seed } }
    }

    /// Override the iteration bound.
    pub fn iterations(mut self, n: usize) -> Self {
        self.max_iterations = n;
        self
    }
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig::dfs(2)
    }
}

/// Outcome of a passing [`check_with`] run.
#[derive(Debug, Clone, Copy)]
pub struct Report {
    /// Executions explored.
    pub iterations: usize,
    /// DFS exhausted the (preemption-bounded) schedule tree.
    pub exhausted: bool,
}

/// [`check_with`] under [`ModelConfig::default`] (DFS, 2 preemptions).
pub fn check<F: Fn() + Send + Sync + 'static>(f: F) -> Report {
    check_with(ModelConfig::default(), f)
}

/// Run `f` under every explored schedule. Panics — with the failing schedule
/// trace — on the first execution that panics, asserts, or deadlocks.
pub fn check_with<F: Fn() + Send + Sync + 'static>(cfg: ModelConfig, f: F) -> Report {
    let f: Arc<dyn Fn() + Send + Sync> = Arc::new(f);
    let explorer = Arc::new(StdMutex::new(Explorer::new(cfg.mode)));
    let mut iterations = 0;
    let mut exhausted = false;
    for iter in 0..cfg.max_iterations {
        iterations = iter + 1;
        explorer.lock().unwrap_or_else(|e| e.into_inner()).begin_iteration(iter as u64);
        let sched = Arc::new(Sched::new(explorer.clone(), cfg.max_steps));
        let body = f.clone();
        sched.start_thread(Box::new(move || body()), true);
        sched.wait_all();
        let (error, trace) = {
            let st = sched.guard();
            let ex = explorer.lock().unwrap_or_else(|e| e.into_inner());
            (st.error.clone(), ex.trace())
        };
        if let Some(msg) = error {
            let seed_note = match cfg.mode {
                Mode::Random { seed } => format!(" (random mode, seed {seed})"),
                Mode::Dfs { max_preemptions } => {
                    format!(" (dfs mode, preemption bound {max_preemptions})")
                }
            };
            panic!(
                "model check failed on execution {iterations}{seed_note}: {msg}\n  schedule trace: {trace:?}"
            );
        }
        if !explorer.lock().unwrap_or_else(|e| e.into_inner()).advance() {
            exhausted = true;
            break;
        }
    }
    Report { iterations, exhausted }
}

// ---------------------------------------------------------------------------
// Meta-tests: the checker must catch planted bugs. Always compiled, so the
// tier-1 lane validates the checker itself without `--cfg ciq_model`.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::shim::{AtomicUsize, Condvar, Mutex, Ordering};
    use super::*;
    use std::sync::atomic::{AtomicBool as StdAtomicBool, Ordering as StdOrdering};

    #[test]
    fn explores_multiple_schedules_and_finds_lost_update() {
        // Two threads each do a non-atomic read-modify-write through shim
        // atomics. Under some interleaving both read 0 and the final value
        // is 1 — the checker must reach that schedule.
        let saw_lost = Arc::new(StdAtomicBool::new(false));
        let saw = saw_lost.clone();
        let report = check_with(ModelConfig::dfs(2), move || {
            let v = Arc::new(AtomicUsize::new(0));
            let (a, b) = (v.clone(), v.clone());
            let t1 = spawn(move || {
                let x = a.load(Ordering::Relaxed);
                a.store(x + 1, Ordering::Relaxed);
            });
            let t2 = spawn(move || {
                let x = b.load(Ordering::Relaxed);
                b.store(x + 1, Ordering::Relaxed);
            });
            t1.join();
            t2.join();
            if v.load(Ordering::Relaxed) == 1 {
                saw.store(true, StdOrdering::SeqCst);
            }
        });
        assert!(report.iterations > 1, "expected multiple schedules, got {report:?}");
        assert!(saw_lost.load(StdOrdering::SeqCst), "lost-update interleaving never explored");
    }

    #[test]
    fn reports_assertion_under_racy_schedule() {
        // Same lost update, but asserted against: the check must FAIL.
        let r = std::panic::catch_unwind(|| {
            check_with(ModelConfig::dfs(2), || {
                let v = Arc::new(AtomicUsize::new(0));
                let (a, b) = (v.clone(), v.clone());
                let t1 = spawn(move || {
                    let x = a.load(Ordering::Relaxed);
                    a.store(x + 1, Ordering::Relaxed);
                });
                let t2 = spawn(move || {
                    let x = b.load(Ordering::Relaxed);
                    b.store(x + 1, Ordering::Relaxed);
                });
                t1.join();
                t2.join();
                assert_eq!(v.load(Ordering::Relaxed), 2, "lost update");
            });
        });
        let msg = payload_msg(&*r.expect_err("racy assertion must be caught"));
        assert!(msg.contains("model check failed"), "unexpected failure message: {msg}");
        assert!(msg.contains("lost update"), "original assertion lost: {msg}");
    }

    #[test]
    fn detects_abba_deadlock() {
        let r = std::panic::catch_unwind(|| {
            check_with(ModelConfig::dfs(1), || {
                let a = Arc::new(Mutex::new(0u32));
                let b = Arc::new(Mutex::new(0u32));
                let (a1, b1) = (a.clone(), b.clone());
                let (a2, b2) = (a.clone(), b.clone());
                let t1 = spawn(move || {
                    let _ga = a1.lock().unwrap();
                    let _gb = b1.lock().unwrap();
                });
                let t2 = spawn(move || {
                    let _gb = b2.lock().unwrap();
                    let _ga = a2.lock().unwrap();
                });
                t1.join();
                t2.join();
            });
        });
        let msg = payload_msg(&*r.expect_err("ABBA deadlock must be caught"));
        assert!(msg.contains("deadlock"), "expected deadlock report, got: {msg}");
    }

    #[test]
    fn mutex_gives_mutual_exclusion() {
        // With a real lock around the read-modify-write, every schedule must
        // see the full count.
        let report = check_with(ModelConfig::dfs(2).iterations(2000), || {
            let v = Arc::new(Mutex::new(0u64));
            let hs: Vec<_> = (0..2)
                .map(|_| {
                    let v = v.clone();
                    spawn(move || {
                        for _ in 0..2 {
                            let mut g = v.lock().unwrap();
                            *g += 1;
                        }
                    })
                })
                .collect();
            for h in hs {
                h.join();
            }
            assert_eq!(*v.lock().unwrap(), 4);
        });
        assert!(report.iterations >= 1);
    }

    #[test]
    fn condvar_handoff_never_loses_wakeup() {
        // Classic flag + condvar rendezvous; correct in every interleaving
        // because the flag is checked under the lock.
        check_with(ModelConfig::dfs(2), || {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = pair.clone();
            let producer = spawn(move || {
                let (mx, cv) = &*p2;
                *mx.lock().unwrap() = true;
                cv.notify_one();
            });
            let (mx, cv) = &*pair;
            let mut g = mx.lock().unwrap();
            while !*g {
                g = cv.wait(g).unwrap();
            }
            drop(g);
            producer.join();
        });
    }

    #[test]
    fn random_mode_is_deterministic_per_seed() {
        // Two runs with the same seed must explore the same schedules: drive
        // a racy (but assert-free) body and compare observed outcomes.
        let run = |seed: u64| {
            let outcomes = Arc::new(StdMutex::new(Vec::new()));
            let o = outcomes.clone();
            check_with(ModelConfig::random(seed, 40), move || {
                let v = Arc::new(AtomicUsize::new(0));
                let (a, b) = (v.clone(), v.clone());
                let t1 = spawn(move || {
                    let x = a.load(Ordering::Relaxed);
                    a.store(x + 1, Ordering::Relaxed);
                });
                let t2 = spawn(move || {
                    let x = b.load(Ordering::Relaxed);
                    b.store(x + 1, Ordering::Relaxed);
                });
                t1.join();
                t2.join();
                o.lock().unwrap().push(v.load(Ordering::Relaxed));
            });
            let g = outcomes.lock().unwrap();
            g.clone()
        };
        assert_eq!(run(42), run(42));
    }

    #[test]
    fn join_observes_side_effects() {
        check_with(ModelConfig::dfs(1), || {
            let v = Arc::new(AtomicUsize::new(0));
            let v2 = v.clone();
            let t = spawn(move || {
                v2.store(7, Ordering::Release);
            });
            t.join();
            assert_eq!(v.load(Ordering::Acquire), 7);
        });
    }
}

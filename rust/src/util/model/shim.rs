//! Model-aware drop-in replacements for the `std::sync` primitives used by
//! the shimmed modules (`exec/`, `util/threadpool.rs`).
//!
//! Each type wraps its std counterpart and consults
//! [`super::current`]: on a **model thread** (spawned via
//! [`super::spawn`] inside a [`super::check`] run) every operation becomes a
//! scheduling point routed through the deterministic scheduler; on any other
//! thread it degrades to the plain std operation, so code under test behaves
//! identically when constructed outside a model run.
//!
//! Two deliberate semantic simplifications, both *stricter* than std:
//!
//! - All atomics execute `SeqCst` under the model regardless of the caller's
//!   `Ordering` (the checker explores interleavings under sequential
//!   consistency; weak-memory effects are Miri/TSan territory).
//! - Model locks never report poisoning (a panicking schedule aborts the
//!   whole execution anyway), but the API still returns `LockResult` so call
//!   sites written against std (`.lock().unwrap()`) compile unchanged.
//!
//! Timed condvar waits are modeled as *nondeterministic* timeouts: the
//! scheduler may wake a `wait_timeout` at any point, so code must be correct
//! whether the timeout fires early or never-before-notify — exactly the
//! property a real racing timer demands.

use std::ops::{Deref, DerefMut};
use std::sync::{Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard};
use std::time::Duration;

pub use std::sync::atomic::Ordering;
pub type LockResult<T> = Result<T, std::sync::PoisonError<T>>;

use super::current;

// ---------------------------------------------------------------------------
// Mutex
// ---------------------------------------------------------------------------

/// Model-aware mutex; see the module docs for semantics.
pub struct Mutex<T> {
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    pub const fn new(t: T) -> Self {
        Mutex { inner: StdMutex::new(t) }
    }

    fn addr(&self) -> usize {
        self as *const Self as *const () as usize
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if let Some((sched, me)) = current() {
            sched.lock_acquire(me, self.addr());
            // The model lock serializes model threads, so the inner std lock
            // is uncontended here; it still guards the data for real.
            let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            Ok(MutexGuard { inner: Some(g), mx: self })
        } else {
            let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            Ok(MutexGuard { inner: Some(g), mx: self })
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        Ok(self.inner.into_inner().unwrap_or_else(|e| e.into_inner()))
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        Ok(self.inner.get_mut().unwrap_or_else(|e| e.into_inner()))
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Mutex::new(T::default())
    }
}

/// Guard for [`Mutex`]; dropping it releases the model lock (a scheduling
/// point) after the inner std guard.
pub struct MutexGuard<'a, T> {
    inner: Option<StdMutexGuard<'a, T>>,
    mx: &'a Mutex<T>,
}

impl<'a, T> MutexGuard<'a, T> {
    /// Release the std guard and disarm `Drop`, returning the mutex for
    /// re-acquisition. Used by `Condvar::wait*` which must not run the
    /// model-unlock in `Drop` (the scheduler releases-and-registers
    /// atomically instead).
    fn dissolve(mut self) -> &'a Mutex<T> {
        let mx = self.mx;
        self.inner.take();
        std::mem::forget(self);
        mx
    }

    /// Like `dissolve`, but keeps the std guard alive (non-model condvar
    /// path hands it straight to `StdCondvar::wait`).
    fn take_std(mut self) -> (StdMutexGuard<'a, T>, &'a Mutex<T>) {
        let g = self.inner.take().expect("guard already dissolved");
        let mx = self.mx;
        std::mem::forget(self);
        (g, mx)
    }
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard dissolved")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard dissolved")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Drop the std guard first so the data lock is free before any other
        // model thread is scheduled by `lock_release`.
        self.inner.take();
        if let Some((sched, me)) = current() {
            sched.lock_release(me, self.mx.addr());
        }
    }
}

// ---------------------------------------------------------------------------
// Condvar
// ---------------------------------------------------------------------------

/// Result of [`Condvar::wait_timeout`]; mirrors
/// `std::sync::WaitTimeoutResult`.
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

/// Model-aware condition variable.
pub struct Condvar {
    inner: StdCondvar,
}

impl Condvar {
    pub const fn new() -> Self {
        Condvar { inner: StdCondvar::new() }
    }

    fn addr(&self) -> usize {
        self as *const Self as *const () as usize
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        if let Some((sched, me)) = current() {
            let mx = guard.dissolve();
            sched.cv_wait(me, self.addr(), mx.addr(), false);
            sched.lock_acquire(me, mx.addr());
            let g = mx.inner.lock().unwrap_or_else(|e| e.into_inner());
            Ok(MutexGuard { inner: Some(g), mx })
        } else {
            let (g, mx) = guard.take_std();
            let g = self.inner.wait(g).unwrap_or_else(|e| e.into_inner());
            Ok(MutexGuard { inner: Some(g), mx })
        }
    }

    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        if let Some((sched, me)) = current() {
            let mx = guard.dissolve();
            // Timeout length is irrelevant under the model: the scheduler
            // may fire the timeout at any point (see module docs).
            let notified = sched.cv_wait(me, self.addr(), mx.addr(), true);
            sched.lock_acquire(me, mx.addr());
            let g = mx.inner.lock().unwrap_or_else(|e| e.into_inner());
            Ok((MutexGuard { inner: Some(g), mx }, WaitTimeoutResult(!notified)))
        } else {
            let (g, mx) = guard.take_std();
            let (g, res) = self.inner.wait_timeout(g, dur).unwrap_or_else(|e| e.into_inner());
            Ok((MutexGuard { inner: Some(g), mx }, WaitTimeoutResult(res.timed_out())))
        }
    }

    pub fn notify_one(&self) {
        if let Some((sched, me)) = current() {
            sched.cv_notify(me, self.addr(), false);
        }
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        if let Some((sched, me)) = current() {
            sched.cv_notify(me, self.addr(), true);
        }
        self.inner.notify_all();
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

// ---------------------------------------------------------------------------
// Atomics
// ---------------------------------------------------------------------------

fn model_event() {
    if let Some((sched, me)) = current() {
        sched.preempt(me);
    }
}

/// Model-aware memory fence: a scheduling point under the model (where every
/// atomic already runs `SeqCst`, making the fence itself redundant), the real
/// `std::sync::atomic::fence` otherwise.
pub fn fence(order: Ordering) {
    model_event();
    std::sync::atomic::fence(order);
}

macro_rules! model_atomic {
    ($name:ident, $std:ty, $val:ty) => {
        /// Model-aware atomic: each op is a scheduling point and executes
        /// `SeqCst` under the model (caller's ordering recorded but ignored).
        pub struct $name {
            inner: $std,
        }

        impl $name {
            pub const fn new(v: $val) -> Self {
                $name { inner: <$std>::new(v) }
            }

            pub fn load(&self, _order: Ordering) -> $val {
                model_event();
                self.inner.load(Ordering::SeqCst)
            }

            pub fn store(&self, v: $val, _order: Ordering) {
                model_event();
                self.inner.store(v, Ordering::SeqCst)
            }

            pub fn swap(&self, v: $val, _order: Ordering) -> $val {
                model_event();
                self.inner.swap(v, Ordering::SeqCst)
            }

            pub fn compare_exchange(
                &self,
                cur: $val,
                new: $val,
                _ok: Ordering,
                _err: Ordering,
            ) -> Result<$val, $val> {
                model_event();
                self.inner.compare_exchange(cur, new, Ordering::SeqCst, Ordering::SeqCst)
            }
        }

        impl Default for $name {
            fn default() -> Self {
                $name::new(Default::default())
            }
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                self.inner.fmt(f)
            }
        }
    };
}

model_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);
model_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
model_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

macro_rules! model_atomic_arith {
    ($name:ident, $val:ty) => {
        impl $name {
            pub fn fetch_add(&self, v: $val, _order: Ordering) -> $val {
                model_event();
                self.inner.fetch_add(v, Ordering::SeqCst)
            }

            pub fn fetch_sub(&self, v: $val, _order: Ordering) -> $val {
                model_event();
                self.inner.fetch_sub(v, Ordering::SeqCst)
            }

            pub fn fetch_max(&self, v: $val, _order: Ordering) -> $val {
                model_event();
                self.inner.fetch_max(v, Ordering::SeqCst)
            }
        }
    };
}

model_atomic_arith!(AtomicU64, u64);
model_atomic_arith!(AtomicUsize, usize);

#[cfg(test)]
mod tests {
    use super::*;

    // Off-model fallback: shim types behave like std when no scheduler is
    // registered on the current thread.
    #[test]
    fn fallback_mutex_and_condvar() {
        let mx = Mutex::new(1u32);
        {
            let mut g = mx.lock().unwrap();
            *g += 1;
        }
        assert_eq!(*mx.lock().unwrap(), 2);
        assert_eq!(mx.into_inner().unwrap(), 2);

        let cv = Condvar::new();
        let mx = Mutex::new(false);
        let g = mx.lock().unwrap();
        let (_g, res) = cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
        assert!(res.timed_out());
    }

    #[test]
    fn fallback_atomics() {
        let b = AtomicBool::new(false);
        assert!(!b.swap(true, Ordering::AcqRel));
        assert!(b.load(Ordering::Acquire));
        let u = AtomicU64::new(5);
        assert_eq!(u.fetch_add(2, Ordering::Relaxed), 5);
        assert_eq!(u.load(Ordering::Relaxed), 7);
        let z = AtomicUsize::new(0);
        assert_eq!(z.compare_exchange(0, 9, Ordering::SeqCst, Ordering::SeqCst), Ok(0));
        assert_eq!(z.load(Ordering::SeqCst), 9);
    }
}

//! Minimal command-line flag parser (no `clap` available offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and positional
//! arguments. Used by the `ciq` binary, the examples, and the bench drivers.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// Flag map (keys without leading dashes).
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(stripped.to_string(), v);
                } else {
                    args.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment (skips argv[0]; also skips the
    /// `--bench` token cargo passes to bench binaries).
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"))
    }

    /// Get a flag as a string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Typed flag with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.flags.get(key) {
            Some(s) => s.parse().unwrap_or(default),
            None => default,
        }
    }

    /// Boolean flag (present and not "false").
    pub fn has(&self, key: &str) -> bool {
        matches!(self.flags.get(key), Some(v) if v != "false")
    }

    /// Comma-separated list of typed values, with default.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.flags.get(key) {
            Some(s) => s
                .split(',')
                .filter_map(|tok| tok.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_key_value_forms() {
        let a = parse(&["run", "--n", "100", "--q=8", "--fast", "--name", "x"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get_or("n", 0usize), 100);
        assert_eq!(a.get_or("q", 0usize), 8);
        assert!(a.has("fast"));
        assert_eq!(a.get("name"), Some("x"));
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse(&["--verbose"]);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("n", 7usize), 7);
        assert_eq!(a.get_or("tol", 0.5f64), 0.5);
    }

    #[test]
    fn list_flag() {
        let a = parse(&["--sizes", "10, 20,30"]);
        assert_eq!(a.get_list("sizes", &[1usize]), vec![10, 20, 30]);
        assert_eq!(a.get_list("other", &[1usize, 2]), vec![1, 2]);
    }
}

//! Small infrastructure: scoped parallelism, CLI parsing, a mini
//! property-testing harness, timing helpers, and the concurrency-checking
//! layer (`sync` facade + `model` deterministic interleaving checker).

pub mod threadpool;
pub mod cli;
pub mod proptest;
pub mod fastmath;
pub mod allocs;
pub mod model;
pub mod sync;

use std::time::Instant;

/// Time a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    // clock: generic stopwatch helper — callers own the interpretation.
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Median of a slice (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 { v[n / 2] } else { 0.5 * (v[n / 2 - 1] + v[n / 2]) }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Euclidean norm.
#[inline]
pub fn norm2(xs: &[f64]) -> f64 {
    xs.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Relative L2 error `‖a − b‖ / ‖b‖`.
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
    let den = norm2(b).max(1e-300);
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_helpers() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-15);
        assert!((std_dev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn vec_helpers() {
        let a = vec![3.0, 4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-15);
        assert!((dot(&a, &a) - 25.0).abs() < 1e-15);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        assert!(rel_err(&a, &a) < 1e-15);
    }
}

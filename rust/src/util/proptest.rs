//! Mini property-testing harness (the `proptest` crate is unavailable
//! offline). Runs a property over many seeded random cases and reports the
//! first failing seed so failures are reproducible.

use crate::rng::Pcg64;

/// Configuration for [`check`].
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 32, seed: 0xC1A0 }
    }
}

/// Run `prop(rng, case_index)` for `cfg.cases` seeded cases; panic with the
/// failing seed on the first `Err`.
pub fn check<F>(cfg: Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Pcg64, usize) -> Result<(), String>,
{
    for i in 0..cfg.cases {
        let seed = cfg.seed + i as u64;
        let mut rng = Pcg64::seeded(seed);
        if let Err(msg) = prop(&mut rng, i) {
            panic!("property '{name}' failed at case {i} (seed {seed}): {msg}");
        }
    }
}

/// Convenience: run with the default config.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Pcg64, usize) -> Result<(), String>,
{
    check(Config::default(), name, prop);
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        check(Config { cases: 10, seed: 1 }, "count", |_rng, _i| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
        check_default("uniform in range", |rng, _| {
            let u = rng.uniform();
            prop_assert!((0.0..1.0).contains(&u), "u={u}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check(Config { cases: 3, seed: 9 }, "always fails", |_, _| {
            Err("nope".to_string())
        });
    }
}

//! Synthetic datasets standing in for the paper's UCI/climate data
//! (3DRoad, Precipitation, CovType — see DESIGN.md §Substitutions).
//!
//! Each generator draws a smooth random field (a sum of random RBF bumps —
//! a draw from an approximate GP prior) over `[0,1]^d` and observes it with
//! the noise model matching the paper's likelihood choice:
//! Gaussian (3droad-like), Student-T (precipitation-like: heavy-tailed),
//! Bernoulli (covtype-like: thresholded field).

use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// A regression / classification dataset.
pub struct Dataset {
    /// inputs, `n × d`, standardized
    pub x: Matrix,
    /// targets (standardized for regression; ±1 for classification)
    pub y: Vec<f64>,
    /// human-readable name
    pub name: String,
}

/// Latent smooth field: `f(x) = Σ_k a_k exp(-‖x−c_k‖²/2ℓ²)`.
pub struct SmoothField {
    centers: Matrix,
    amps: Vec<f64>,
    ell: f64,
}

impl SmoothField {
    /// Random field with `k` bumps in `d` dims.
    pub fn random(d: usize, k: usize, ell: f64, rng: &mut Pcg64) -> SmoothField {
        let mut centers = Matrix::zeros(k, d);
        for i in 0..k {
            for j in 0..d {
                centers[(i, j)] = rng.uniform();
            }
        }
        let amps: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        SmoothField { centers, amps, ell }
    }

    /// Evaluate at one point.
    pub fn eval(&self, x: &[f64]) -> f64 {
        let mut acc = 0.0;
        for k in 0..self.centers.rows() {
            let c = self.centers.row(k);
            let d2: f64 = c.iter().zip(x).map(|(a, b)| (a - b) * (a - b)).sum();
            acc += self.amps[k] * (-0.5 * d2 / (self.ell * self.ell)).exp();
        }
        acc
    }
}

fn random_inputs(n: usize, d: usize, rng: &mut Pcg64) -> Matrix {
    let mut x = Matrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            x[(i, j)] = rng.uniform();
        }
    }
    x
}

fn standardize(y: &mut [f64]) {
    let m = crate::util::mean(y);
    let s = crate::util::std_dev(y).max(1e-12);
    for v in y {
        *v = (*v - m) / s;
    }
}

/// Gaussian-noise regression (3droad substitute, D=2 spatial).
pub fn gaussian_regression(n: usize, d: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Pcg64::seeded(seed);
    let field = SmoothField::random(d, 60, 0.12, &mut rng);
    let x = random_inputs(n, d, &mut rng);
    let mut y: Vec<f64> = (0..n).map(|i| field.eval(x.row(i))).collect();
    standardize(&mut y);
    for v in &mut y {
        *v += noise * rng.normal();
    }
    Dataset { x, y, name: format!("synth-gaussian-{d}d") }
}

/// Heavy-tailed (Student-T) regression (precipitation substitute, D=3).
pub fn student_t_regression(n: usize, d: usize, scale: f64, dof: f64, seed: u64) -> Dataset {
    let mut rng = Pcg64::seeded(seed);
    let field = SmoothField::random(d, 60, 0.15, &mut rng);
    let x = random_inputs(n, d, &mut rng);
    let mut y: Vec<f64> = (0..n).map(|i| field.eval(x.row(i))).collect();
    standardize(&mut y);
    for v in &mut y {
        // Student-T noise: normal / sqrt(gamma)
        let g = rng.gamma(dof / 2.0, dof / 2.0);
        *v += scale * rng.normal() / g.sqrt();
    }
    Dataset { x, y, name: format!("synth-student-{d}d") }
}

/// Binary classification from a thresholded field (covtype substitute).
pub fn binary_classification(n: usize, d: usize, flip_prob: f64, seed: u64) -> Dataset {
    let mut rng = Pcg64::seeded(seed);
    let field = SmoothField::random(d, 80, 0.18, &mut rng);
    let x = random_inputs(n, d, &mut rng);
    let y: Vec<f64> = (0..n)
        .map(|i| {
            let f = field.eval(x.row(i));
            let label = if f > 0.0 { 1.0 } else { -1.0 };
            if rng.uniform() < flip_prob {
                -label
            } else {
                label
            }
        })
        .collect();
    Dataset { x, y, name: format!("synth-binary-{d}d") }
}

impl Dataset {
    /// Size.
    pub fn len(&self) -> usize {
        self.x.rows()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deterministic train/test split.
    pub fn split(&self, train_frac: f64, rng: &mut Pcg64) -> (Dataset, Dataset) {
        let n = self.len();
        let n_train = ((n as f64) * train_frac).round() as usize;
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let take = |ids: &[usize]| -> Dataset {
            let mut x = Matrix::zeros(ids.len(), self.x.cols());
            let mut y = Vec::with_capacity(ids.len());
            for (r, &i) in ids.iter().enumerate() {
                for j in 0..self.x.cols() {
                    x[(r, j)] = self.x[(i, j)];
                }
                y.push(self.y[i]);
            }
            Dataset { x, y, name: self.name.clone() }
        };
        (take(&idx[..n_train]), take(&idx[n_train..]))
    }

    /// K-means(-ish) inducing point selection: `m` centers via a few Lloyd
    /// iterations from a random subset init.
    pub fn kmeans_centers(&self, m: usize, iters: usize, rng: &mut Pcg64) -> Matrix {
        let n = self.len();
        let d = self.x.cols();
        let m = m.min(n);
        let init = rng.sample_indices(n, m);
        let mut centers = Matrix::zeros(m, d);
        for (c, &i) in init.iter().enumerate() {
            for j in 0..d {
                centers[(c, j)] = self.x[(i, j)];
            }
        }
        let mut assign = vec![0usize; n];
        for _ in 0..iters {
            // assignment
            for i in 0..n {
                let xi = self.x.row(i);
                let mut best = (f64::INFINITY, 0usize);
                for c in 0..m {
                    let cc = centers.row(c);
                    let d2: f64 = xi.iter().zip(cc).map(|(a, b)| (a - b) * (a - b)).sum();
                    if d2 < best.0 {
                        best = (d2, c);
                    }
                }
                assign[i] = best.1;
            }
            // update
            let mut sums = Matrix::zeros(m, d);
            let mut counts = vec![0usize; m];
            for i in 0..n {
                let c = assign[i];
                counts[c] += 1;
                for j in 0..d {
                    sums[(c, j)] += self.x[(i, j)];
                }
            }
            for c in 0..m {
                if counts[c] > 0 {
                    for j in 0..d {
                        centers[(c, j)] = sums[(c, j)] / counts[c] as f64;
                    }
                }
            }
        }
        centers
    }

    /// Random minibatch indices.
    pub fn minibatch(&self, size: usize, rng: &mut Pcg64) -> Vec<usize> {
        rng.sample_indices(self.len(), size.min(self.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regression_is_standardized_and_smooth() {
        let ds = gaussian_regression(500, 2, 0.1, 1);
        assert_eq!(ds.len(), 500);
        let m = crate::util::mean(&ds.y);
        assert!(m.abs() < 0.2, "mean {m}");
        // smoothness: nearby points have correlated targets
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..100 {
            for j in (i + 1)..100 {
                let d2: f64 = ds
                    .x
                    .row(i)
                    .iter()
                    .zip(ds.x.row(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d2 < 0.001 {
                    num += (ds.y[i] - ds.y[j]).abs();
                    den += 1.0;
                }
            }
        }
        if den > 0.0 {
            assert!(num / den < 1.0, "nearby targets differ too much");
        }
    }

    #[test]
    fn classification_labels_valid() {
        let ds = binary_classification(300, 3, 0.1, 2);
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
        let pos = ds.y.iter().filter(|&&v| v == 1.0).count();
        assert!(pos > 30 && pos < 270, "degenerate class balance: {pos}");
    }

    #[test]
    fn student_t_has_heavier_tails() {
        let g = gaussian_regression(4000, 2, 0.3, 3);
        let t = student_t_regression(4000, 2, 0.3, 3.0, 3);
        let kurt = |y: &[f64]| {
            let m = crate::util::mean(y);
            let s2 = y.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / y.len() as f64;
            y.iter().map(|v| (v - m).powi(4)).sum::<f64>() / y.len() as f64 / (s2 * s2)
        };
        assert!(kurt(&t.y) > kurt(&g.y), "student-t should be heavier tailed");
    }

    #[test]
    fn split_and_kmeans() {
        let ds = gaussian_regression(200, 2, 0.1, 4);
        let mut rng = Pcg64::seeded(5);
        let (tr, te) = ds.split(0.75, &mut rng);
        assert_eq!(tr.len(), 150);
        assert_eq!(te.len(), 50);
        let z = ds.kmeans_centers(16, 5, &mut rng);
        assert_eq!(z.rows(), 16);
        // all centers within the unit cube
        for i in 0..16 {
            for j in 0..2 {
                assert!((0.0..=1.0).contains(&z[(i, j)]));
            }
        }
    }
}

//! Special functions implemented from scratch: complete elliptic integrals
//! (AGM), Jacobi elliptic functions (descending Landen / Gauss
//! transformation), `erf`, and `ln Γ`.
//!
//! These drive the Hale–Higham–Trefethen quadrature rule (Appx. B of the
//! paper): the quadrature nodes/weights are built from `K'(k)` and
//! `sn/cn/dn(u K'(k) | k')`.

/// Complete elliptic integral of the first kind `K(k)` as a function of the
/// **modulus** `k` (not the parameter `m = k²`), via the arithmetic–geometric
/// mean: `K(k) = π / (2 AGM(1, k'))` with `k' = sqrt(1 − k²)`.
pub fn ellipk_modulus(k: f64) -> f64 {
    assert!((0.0..1.0).contains(&k), "ellipk needs 0 <= k < 1, got {k}");
    let kp = (1.0 - k * k).sqrt();
    std::f64::consts::PI / (2.0 * agm(1.0, kp))
}

/// Complete elliptic integral of the first kind as a function of the
/// **parameter** `m = k²` (SciPy's `ellipk` convention).
pub fn ellipk(m: f64) -> f64 {
    assert!((0.0..1.0).contains(&m), "ellipk needs 0 <= m < 1, got {m}");
    std::f64::consts::PI / (2.0 * agm(1.0, (1.0 - m).sqrt()))
}

/// Arithmetic–geometric mean of `a ≥ b > 0`.
pub fn agm(mut a: f64, mut b: f64) -> f64 {
    assert!(a > 0.0 && b >= 0.0);
    if b == 0.0 {
        // AGM(a, 0) = 0 → K diverges; callers guard against k = 1.
        return 0.0;
    }
    for _ in 0..64 {
        let an = 0.5 * (a + b);
        let bn = (a * b).sqrt();
        if (a - b).abs() <= 1e-16 * a.abs() {
            break;
        }
        a = an;
        b = bn;
    }
    0.5 * (a + b)
}

/// Jacobi elliptic functions `(sn, cn, dn)` of real argument `u` with
/// **parameter** `m = k²` (SciPy `ellipj` convention).
///
/// Implemented with the descending Gauss/Landen AGM scheme (Abramowitz &
/// Stegun 16.4 / Numerical Recipes `sncndn`).
pub fn ellipj(u: f64, m: f64) -> (f64, f64, f64) {
    assert!((0.0..=1.0).contains(&m), "ellipj needs 0 <= m <= 1, got {m}");
    const CA: f64 = 1e-14;
    let mc = 1.0 - m;
    if mc.abs() < CA {
        // m → 1: sn = tanh u, cn = dn = sech u
        let c = 1.0 / u.cosh();
        return (u.tanh(), c, c);
    }
    if m.abs() < CA {
        // m → 0: circular limit
        return (u.sin(), u.cos(), 1.0);
    }
    // AGM scheme (Abramowitz & Stegun 16.4): build a_i, c_i ladders until
    // c_N is negligible, set φ_N = 2^N a_N u, then descend
    // φ_{n-1} = (φ_n + arcsin((c_n/a_n) sin φ_n)) / 2.
    let mut a_lad = [0.0f64; 64];
    let mut c_lad = [0.0f64; 64];
    let (mut a, mut b) = (1.0f64, mc.sqrt());
    a_lad[0] = a;
    c_lad[0] = (1.0 - mc).sqrt(); // c_0 = k
    let mut n = 0usize;
    while n < 62 {
        let c_next = 0.5 * (a - b);
        let a_next = 0.5 * (a + b);
        let b_next = (a * b).sqrt();
        n += 1;
        a_lad[n] = a_next;
        c_lad[n] = c_next;
        a = a_next;
        b = b_next;
        if (c_next / a_next).abs() <= CA {
            break;
        }
    }
    let mut phi = (1u64 << n) as f64 * a_lad[n] * u;
    for i in (1..=n).rev() {
        let t = (c_lad[i] / a_lad[i]) * phi.sin();
        phi = 0.5 * (phi + t.asin());
    }
    let sn = phi.sin();
    let cn = phi.cos();
    // dn is pinned by the identity dn² = 1 − m sn² and dn > 0 on the real axis.
    let dn = (1.0 - m * sn * sn).max(0.0).sqrt();
    (sn, cn, dn)
}

/// Error function `erf(x)` (Abramowitz & Stegun 7.1.26-style rational
/// approximation refined with one continued-fraction correction; |err| < 1.2e-7
/// from the base formula, adequate for likelihood computations; we instead use
/// the higher-precision W. J. Cody rational approximation below, |err| < 1e-15).
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Complementary error function (Cody-style, double precision).
pub fn erfc(x: f64) -> f64 {
    // Numerical-Recipes erfc via incomplete gamma–like Chebyshev fit.
    let z = x.abs();
    let t = 2.0 / (2.0 + z);
    let ty = 4.0 * t - 2.0;
    const COF: [f64; 28] = [
        -1.3026537197817094,
        6.4196979235649026e-1,
        1.9476473204185836e-2,
        -9.561514786808631e-3,
        -9.46595344482036e-4,
        3.66839497852761e-4,
        4.2523324806907e-5,
        -2.0278578112534e-5,
        -1.624290004647e-6,
        1.303655835580e-6,
        1.5626441722e-8,
        -8.5238095915e-8,
        6.529054439e-9,
        5.059343495e-9,
        -9.91364156e-10,
        -2.27365122e-10,
        9.6467911e-11,
        2.394038e-12,
        -6.886027e-12,
        8.94487e-13,
        3.13092e-13,
        -1.12708e-13,
        3.81e-16,
        7.106e-15,
        -1.523e-15,
        -9.4e-17,
        1.21e-16,
        -2.8e-17,
    ];
    let mut d = 0.0f64;
    let mut dd = 0.0f64;
    for &c in COF.iter().rev().take(COF.len() - 1) {
        let tmp = d;
        d = ty * d - dd + c;
        dd = tmp;
    }
    let ans = t * (-z * z + 0.5 * (COF[0] + ty * d) - dd).exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Standard normal CDF.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Standard normal log-pdf.
pub fn norm_logpdf(x: f64) -> f64 {
    -0.5 * x * x - 0.5 * (2.0 * std::f64::consts::PI).ln()
}

/// `ln Γ(x)` for `x > 0` (Lanczos approximation, g = 7, n = 9).
pub fn ln_gamma(x: f64) -> f64 {
    assert!(x > 0.0, "ln_gamma needs x > 0");
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Gauss–Hermite quadrature nodes/weights (physicists' convention,
/// `∫ e^{-x²} f(x) dx ≈ Σ w_i f(x_i)`), computed by Newton iteration on the
/// Hermite recurrence. Used for SVGP expected log-likelihoods.
pub fn gauss_hermite(n: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(n >= 1);
    let mut nodes = vec![0.0; n];
    let mut weights = vec![0.0; n];
    let m = n.div_ceil(2);
    let mut z = 0.0f64;
    for i in 0..m {
        // initial guesses (Numerical Recipes gauher)
        z = match i {
            0 => (2.0 * n as f64 + 1.0).sqrt() - 1.85575 * (2.0 * n as f64 + 1.0).powf(-1.0 / 6.0),
            1 => z - 1.14 * (n as f64).powf(0.426) / z,
            2 => 1.86 * z - 0.86 * nodes[0],
            3 => 1.91 * z - 0.91 * nodes[1],
            _ => 2.0 * z - nodes[i - 2],
        };
        let mut pp = 0.0;
        for _ in 0..100 {
            // evaluate H_n via recurrence (orthonormal scaling)
            let mut p1 = std::f64::consts::PI.powf(-0.25);
            let mut p2 = 0.0;
            for j in 0..n {
                let p3 = p2;
                p2 = p1;
                p1 = z * (2.0 / (j as f64 + 1.0)).sqrt() * p2
                    - ((j as f64) / (j as f64 + 1.0)).sqrt() * p3;
            }
            pp = (2.0 * n as f64).sqrt() * p2;
            let z1 = z;
            z = z1 - p1 / pp;
            if (z - z1).abs() < 1e-14 {
                break;
            }
        }
        nodes[i] = z;
        nodes[n - 1 - i] = -z;
        weights[i] = 2.0 / (pp * pp);
        weights[n - 1 - i] = weights[i];
    }
    // ascending nodes
    nodes.reverse();
    weights.reverse();
    (nodes, weights)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ellipk_known_values() {
        // K(m=0) = pi/2
        assert!((ellipk(0.0) - std::f64::consts::FRAC_PI_2).abs() < 1e-14);
        // K(m=0.5) = 1.85407467730137 (Abramowitz & Stegun)
        assert!((ellipk(0.5) - 1.854_074_677_301_372).abs() < 1e-12);
        // K(m=0.81): reference from scipy.special.ellipk(0.81) = 2.2805491384227703
        assert!((ellipk(0.81) - 2.280_549_138_422_770).abs() < 1e-11);
    }

    #[test]
    fn ellipj_reduces_to_trig_and_hyperbolic() {
        for &u in &[0.1, 0.5, 1.2, 2.0] {
            let (sn, cn, dn) = ellipj(u, 0.0);
            assert!((sn - u.sin()).abs() < 1e-12);
            assert!((cn - u.cos()).abs() < 1e-12);
            assert!((dn - 1.0).abs() < 1e-12);
            let (sn1, cn1, dn1) = ellipj(u, 1.0 - 1e-16);
            assert!((sn1 - u.tanh()).abs() < 1e-7);
            assert!((cn1 - 1.0 / u.cosh()).abs() < 1e-7);
            assert!((dn1 - 1.0 / u.cosh()).abs() < 1e-7);
        }
    }

    #[test]
    fn ellipj_identities() {
        // sn² + cn² = 1 and dn² + m sn² = 1 for all u, m
        for &m in &[0.1, 0.3, 0.7, 0.95] {
            for &u in &[0.2, 0.9, 1.7, 3.1] {
                let (sn, cn, dn) = ellipj(u, m);
                assert!((sn * sn + cn * cn - 1.0).abs() < 1e-10, "m={m} u={u}");
                assert!((dn * dn + m * sn * sn - 1.0).abs() < 1e-10, "m={m} u={u}");
            }
        }
    }

    #[test]
    fn ellipj_quarter_period() {
        // sn(K(m), m) = 1, cn(K(m), m) = 0, dn(K(m), m) = sqrt(1-m)
        for &m in &[0.2, 0.5, 0.9] {
            let kk = ellipk(m);
            let (sn, cn, dn) = ellipj(kk, m);
            assert!((sn - 1.0).abs() < 1e-9, "m={m} sn={sn}");
            assert!(cn.abs() < 1e-7, "m={m} cn={cn}");
            assert!((dn - (1.0 - m).sqrt()).abs() < 1e-9, "m={m}");
        }
    }

    #[test]
    fn erf_known_values() {
        assert!(erf(0.0).abs() < 1e-15);
        assert!((erf(1.0) - 0.842_700_792_949_715).abs() < 1e-9);
        assert!((erf(-1.0) + 0.842_700_792_949_715).abs() < 1e-9);
        assert!((erf(2.0) - 0.995_322_265_018_953).abs() < 1e-9);
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-12);
        assert!((norm_cdf(1.96) - 0.975_002_104_851_780).abs() < 1e-7);
    }

    #[test]
    fn ln_gamma_known_values() {
        assert!(ln_gamma(1.0).abs() < 1e-12);
        assert!(ln_gamma(2.0).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24.0f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-10);
    }

    #[test]
    fn gauss_hermite_integrates_polynomials() {
        let (x, w) = gauss_hermite(10);
        // ∫ e^{-x²} dx = sqrt(pi)
        let s0: f64 = w.iter().sum();
        assert!((s0 - std::f64::consts::PI.sqrt()).abs() < 1e-10);
        // ∫ x² e^{-x²} dx = sqrt(pi)/2
        let s2: f64 = x.iter().zip(&w).map(|(xi, wi)| wi * xi * xi).sum();
        assert!((s2 - std::f64::consts::PI.sqrt() / 2.0).abs() < 1e-10);
        // ∫ x⁴ e^{-x²} dx = 3 sqrt(pi)/4
        let s4: f64 = x.iter().zip(&w).map(|(xi, wi)| wi * xi.powi(4)).sum();
        assert!((s4 - 0.75 * std::f64::consts::PI.sqrt()).abs() < 1e-9);
    }
}

//! Gibbs sampling for image super-resolution (Sec. 5.3 / Fig. 5).
//!
//! Model (Eq. 6): `R` low-resolution images `y_r = A x + ε`, `A = D B`
//! (blur + decimate), smoothness prior `p(x) ∝ γ_prior^{(N²−1)/2}
//! exp(−½ γ_prior ‖L x‖²)`, Jeffreys hyperpriors on `(γ_obs, γ_prior)`.
//!
//! The Gibbs sweep alternates:
//! * `x | y, γ ~ N(m, Λ^{-1})` with `Λ = γ_obs R AᵀA + γ_prior LᵀL`:
//!   the mean solves `Λ m = γ_obs Σ_r Aᵀ y_r` (Jacobi-CG) and the
//!   fluctuation is `Λ^{-1/2} ε` — **the CIQ whitening operation on the
//!   precision operator**, where Cholesky would need the dense `N²×N²` Λ;
//! * gamma conditionals for `γ_obs`, `γ_prior` (Eq. S27).

use crate::ciq::{Ciq, CiqOptions};
use crate::krylov::cg::{pcg, CgOptions};
use crate::operators::image::PrecisionOp;
use crate::operators::LinearOp;
use crate::rng::Pcg64;
use crate::Result;

/// A procedurally generated grayscale test image in `[0,1]` (substitute for
/// the paper's photograph — DESIGN.md §Substitutions).
pub fn test_image(n: usize) -> Vec<f64> {
    let mut img = vec![0.0; n * n];
    let nf = n as f64;
    for i in 0..n {
        for j in 0..n {
            let (y, x) = (i as f64 / nf, j as f64 / nf);
            // background gradient
            let mut v = 0.25 + 0.3 * x + 0.15 * y;
            // bright disc
            let d1 = ((x - 0.33) * (x - 0.33) + (y - 0.3) * (y - 0.3)).sqrt();
            if d1 < 0.16 {
                v = 0.9 - 1.5 * d1;
            }
            // dark square
            if (0.55..0.85).contains(&x) && (0.5..0.8).contains(&y) {
                v = 0.12;
            }
            // thin diagonal stripe (high-frequency detail)
            if ((x - y) * 8.0).rem_euclid(1.0) < 0.08 {
                v = (v + 0.55).min(1.0);
            }
            img[i * n + j] = v.clamp(0.0, 1.0);
        }
    }
    img
}

/// Configuration for the Gibbs sampler.
#[derive(Clone, Debug)]
pub struct GibbsConfig {
    /// latent image side length N (dimension is N²)
    pub n: usize,
    /// decimation factor (low-res side = N / factor)
    pub factor: usize,
    /// number of low-res observations R
    pub r: usize,
    /// true observation precision used to synthesize data
    pub gamma_obs_true: f64,
    /// samples to draw
    pub samples: usize,
    /// burn-in discarded
    pub burn_in: usize,
    /// CIQ options for the fluctuation draws
    pub ciq: CiqOptions,
    /// CG tolerance for the mean solves
    pub cg_tol: f64,
}

impl Default for GibbsConfig {
    fn default() -> Self {
        GibbsConfig {
            n: 48,
            factor: 2,
            r: 4,
            gamma_obs_true: 400.0,
            samples: 60,
            burn_in: 20,
            ciq: CiqOptions { tol: 1e-3, max_iters: 400, q_points: 8, ..Default::default() },
            cg_tol: 1e-3,
        }
    }
}

/// Result of a reconstruction run.
pub struct GibbsResult {
    /// posterior-mean reconstruction (N² pixels)
    pub reconstruction: Vec<f64>,
    /// per-sample wall-clock seconds (post burn-in average)
    pub seconds_per_sample: f64,
    /// trace of γ_obs draws
    pub gamma_obs_trace: Vec<f64>,
    /// trace of γ_prior draws
    pub gamma_prior_trace: Vec<f64>,
    /// RMSE against the ground-truth image
    pub rmse: f64,
    /// number of CIQ iterations per sample (mean)
    pub mean_ciq_iters: f64,
}

/// Synthesize `R` low-res observations from a ground-truth image.
pub fn synthesize_observations(
    truth: &[f64],
    op: &PrecisionOp,
    r: usize,
    gamma_obs: f64,
    rng: &mut Pcg64,
) -> Vec<Vec<f64>> {
    let noise_std = 1.0 / gamma_obs.sqrt();
    (0..r)
        .map(|_| {
            let mut y = op.forward(truth);
            for v in &mut y {
                *v += noise_std * rng.normal();
            }
            y
        })
        .collect()
}

/// Run the Gibbs sampler for the super-resolution posterior.
pub fn reconstruct(cfg: &GibbsConfig, seed: u64) -> Result<GibbsResult> {
    let mut rng = Pcg64::seeded(seed);
    let n = cfg.n;
    let dim = n * n;
    let truth = test_image(n);

    // forward model (hyper-independent pieces); Λ's γ's are updated in place
    let mut prec = PrecisionOp::new(n, cfg.factor, cfg.r, 1.0, 1.0);
    let ys = synthesize_observations(&truth, &prec, cfg.r, cfg.gamma_obs_true, &mut rng);
    // Σ_r Aᵀ y_r (fixed across sweeps)
    let mut aty = vec![0.0; dim];
    for y in &ys {
        let a = prec.adjoint(y);
        for (s, v) in aty.iter_mut().zip(&a) {
            *s += v;
        }
    }

    let m_low = (n / cfg.factor) * (n / cfg.factor);
    let mut gamma_obs = 100.0;
    let mut gamma_prior = 10.0;
    let mut x = vec![0.5; dim];
    let mut mean_acc = vec![0.0; dim];
    let mut kept = 0usize;
    let mut gamma_obs_trace = Vec::new();
    let mut gamma_prior_trace = Vec::new();
    let mut sample_secs = Vec::new();
    let mut ciq_iters = Vec::new();

    let solver = Ciq::new(cfg.ciq.clone());
    for s in 0..cfg.samples {
        // clock: per-sample wall-time reported in `GibbsResult::sample_secs`.
        let t0 = std::time::Instant::now();
        prec.gamma_obs = gamma_obs;
        prec.gamma_prior = gamma_prior;

        // --- x | y, γ ---
        // mean: Λ m = γ_obs Σ Aᵀ y
        let rhs: Vec<f64> = aty.iter().map(|v| gamma_obs * v).collect();
        let diag_prec = {
            let d = prec.diagonal();
            move |r: &[f64]| -> Vec<f64> { r.iter().zip(&d).map(|(ri, di)| ri / di.max(1e-12)).collect() }
        };
        let (mean, _res, _it) =
            pcg(&prec, &rhs, Some(&diag_prec), &CgOptions { max_iters: 800, tol: cfg.cg_tol });
        // fluctuation: Λ^{-1/2} ε  (CIQ whitening on the precision operator)
        let eps: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let fluct = solver.invsqrt_mvm(&prec, &eps)?;
        ciq_iters.push(fluct.iterations);
        x = mean.iter().zip(&fluct.solution).map(|(m, f)| m + f).collect();

        // --- γ | x, y (Eq. S27) ---
        let mut resid2 = 0.0;
        for y in &ys {
            let ax = prec.forward(&x);
            resid2 += ax.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum::<f64>();
        }
        let alpha_obs = 1.0 + (cfg.r * m_low) as f64 / 2.0;
        gamma_obs = rng.gamma(alpha_obs, resid2.max(1e-12) / 2.0);
        let lx2 = prec.prior_quad(&x);
        let alpha_pr = 1.0 + (dim as f64 - 1.0) / 2.0;
        gamma_prior = rng.gamma(alpha_pr, lx2.max(1e-12) / 2.0);

        gamma_obs_trace.push(gamma_obs);
        gamma_prior_trace.push(gamma_prior);
        let dt = t0.elapsed().as_secs_f64();
        if s >= cfg.burn_in {
            kept += 1;
            for (acc, v) in mean_acc.iter_mut().zip(&x) {
                *acc += v;
            }
            sample_secs.push(dt);
        }
    }

    let recon: Vec<f64> = mean_acc.iter().map(|v| v / kept.max(1) as f64).collect();
    let rmse = (recon
        .iter()
        .zip(&truth)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        / dim as f64)
        .sqrt();
    Ok(GibbsResult {
        reconstruction: recon,
        seconds_per_sample: crate::util::mean(&sample_secs),
        gamma_obs_trace,
        gamma_prior_trace,
        rmse,
        mean_ciq_iters: crate::util::mean(&ciq_iters.iter().map(|&v| v as f64).collect::<Vec<_>>()),
    })
}

/// Render a grayscale image to a PGM file (for eyeballing Fig. 5).
pub fn write_pgm(path: &std::path::Path, img: &[f64], n: usize) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "P2\n{n} {n}\n255")?;
    for i in 0..n {
        let row: Vec<String> = (0..n)
            .map(|j| format!("{}", (img[i * n + j].clamp(0.0, 1.0) * 255.0) as u8))
            .collect();
        writeln!(f, "{}", row.join(" "))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_image_in_range_with_structure() {
        let img = test_image(32);
        assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let mean = crate::util::mean(&img);
        let sd = crate::util::std_dev(&img);
        assert!(mean > 0.1 && mean < 0.9);
        assert!(sd > 0.1, "image should have contrast, sd={sd}");
    }

    #[test]
    fn reconstruction_beats_upsampled_observation() {
        let cfg = GibbsConfig {
            n: 24,
            factor: 2,
            r: 4,
            samples: 25,
            burn_in: 10,
            ..Default::default()
        };
        let res = reconstruct(&cfg, 1).unwrap();
        // baseline: nearest-neighbour upsampling of the first observation
        let truth = test_image(cfg.n);
        let prec = PrecisionOp::new(cfg.n, cfg.factor, cfg.r, 1.0, 1.0);
        let mut rng = Pcg64::seeded(1);
        let ys = synthesize_observations(&truth, &prec, cfg.r, cfg.gamma_obs_true, &mut rng);
        let m = cfg.n / cfg.factor;
        let mut upsampled = vec![0.0; cfg.n * cfg.n];
        for i in 0..cfg.n {
            for j in 0..cfg.n {
                upsampled[i * cfg.n + j] = ys[0][(i / cfg.factor) * m + j / cfg.factor];
            }
        }
        let base_rmse = (upsampled
            .iter()
            .zip(&truth)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / truth.len() as f64)
            .sqrt();
        assert!(
            res.rmse < base_rmse,
            "gibbs rmse {} should beat naive upsampling {}",
            res.rmse,
            base_rmse
        );
        // the σ=2.5 truncated blur destroys the stripe detail entirely, so
        // the achievable floor sits near 0.2 at this resolution
        assert!(res.rmse < 0.3, "absolute rmse too high: {}", res.rmse);
    }

    #[test]
    fn gamma_chains_concentrate_near_truth() {
        let cfg = GibbsConfig {
            n: 24,
            factor: 2,
            r: 4,
            gamma_obs_true: 400.0,
            samples: 30,
            burn_in: 15,
            ..Default::default()
        };
        let res = reconstruct(&cfg, 2).unwrap();
        let tail = &res.gamma_obs_trace[15..];
        let mean_obs = crate::util::mean(tail);
        // within a factor of ~4 of the generating precision
        assert!(
            mean_obs > 100.0 && mean_obs < 1600.0,
            "gamma_obs posterior mean {mean_obs} vs truth 400"
        );
    }

    #[test]
    fn pgm_writer_works() {
        let dir = std::env::temp_dir().join("ciq_test_pgm");
        let _ = std::fs::create_dir_all(&dir);
        let p = dir.join("img.pgm");
        write_pgm(&p, &test_image(16), 16).unwrap();
        let content = std::fs::read_to_string(&p).unwrap();
        assert!(content.starts_with("P2"));
    }
}

//! Random Fourier Features (Rahimi & Recht [63]) — the approximate sampling
//! baseline of Figs. 4 and S4.
//!
//! For the RBF kernel `k(x,y) = s² exp(-‖x−y‖²/2ℓ²)`, Bochner's theorem
//! gives `k(x,y) ≈ φ(x)ᵀφ(y)` with `φ_d(x) = sqrt(2s²/D) cos(ω_dᵀx + b_d)`,
//! `ω ~ N(0, ℓ^{-2}I)`, `b ~ U[0, 2π)`. Sampling `f = Φ w`, `w ~ N(0, I)`
//! draws from an approximate GP prior; posterior samples come from Bayesian
//! linear regression in feature space.

use crate::linalg::{Cholesky, Matrix};
use crate::rng::Pcg64;
use crate::Result;

/// RFF feature map for an RBF kernel.
pub struct RandomFourierFeatures {
    /// frequencies, `D × d`
    omega: Matrix,
    /// phases, length `D`
    phase: Vec<f64>,
    /// per-feature amplitude `sqrt(2 s² / D)`
    amp: f64,
}

impl RandomFourierFeatures {
    /// Sample a `num_features`-dimensional RFF map for the RBF kernel with
    /// isotropic `lengthscale` and variance `outputscale`.
    pub fn new(dim: usize, num_features: usize, lengthscale: f64, outputscale: f64, rng: &mut Pcg64) -> Self {
        let mut omega = Matrix::zeros(num_features, dim);
        for i in 0..num_features {
            for j in 0..dim {
                omega[(i, j)] = rng.normal() / lengthscale;
            }
        }
        let phase: Vec<f64> = (0..num_features)
            .map(|_| rng.uniform() * 2.0 * std::f64::consts::PI)
            .collect();
        RandomFourierFeatures { omega, phase, amp: (2.0 * outputscale / num_features as f64).sqrt() }
    }

    /// Number of features `D`.
    pub fn num_features(&self) -> usize {
        self.omega.rows()
    }

    /// Feature map `Φ` for inputs `x` (`n × d`) → `n × D`.
    pub fn features(&self, x: &Matrix) -> Matrix {
        let n = x.rows();
        let d_feat = self.num_features();
        let mut phi = Matrix::zeros(n, d_feat);
        for i in 0..n {
            let xi = x.row(i);
            for f in 0..d_feat {
                let w = self.omega.row(f);
                let mut arg = self.phase[f];
                for (wv, xv) in w.iter().zip(xi) {
                    arg += wv * xv;
                }
                phi[(i, f)] = self.amp * arg.cos();
            }
        }
        phi
    }

    /// Approximate prior sample at inputs `x`: `f = Φ w`, `w ~ N(0, I)`.
    pub fn prior_sample(&self, x: &Matrix, rng: &mut Pcg64) -> Vec<f64> {
        let phi = self.features(x);
        let w: Vec<f64> = (0..self.num_features()).map(|_| rng.normal()).collect();
        phi.matvec(&w)
    }

    /// Approximate *posterior* sample: condition the Bayesian linear model
    /// `y = Φ w + ε`, `ε ~ N(0, σ²)` on training data `(x_train, y)`, then
    /// draw `f* = Φ* w_post` at `x_test`.
    ///
    /// `O(n D² + D³)` — independent of the test-set size beyond the feature
    /// evaluation, which is why RFF was previously the only way to use huge
    /// Thompson-sampling candidate sets.
    pub fn posterior_sample(
        &self,
        x_train: &Matrix,
        y: &[f64],
        sigma2: f64,
        x_test: &Matrix,
        rng: &mut Pcg64,
    ) -> Result<Vec<f64>> {
        let phi = self.features(x_train); // n × D
        let d_feat = self.num_features();
        // posterior precision A = ΦᵀΦ/σ² + I
        let mut a = phi.t_matmul(&phi);
        a.scale(1.0 / sigma2);
        for i in 0..d_feat {
            a[(i, i)] += 1.0;
        }
        let chol = Cholesky::new(&a)?;
        // posterior mean m = A^{-1} Φᵀ y / σ²
        let phit_y: Vec<f64> = phi.matvec_t(y).iter().map(|v| v / sigma2).collect();
        let mean = chol.solve(&phit_y);
        // sample w = m + A^{-1/2} ε  via  w = m + L^{-T} ε
        let eps: Vec<f64> = (0..d_feat).map(|_| rng.normal()).collect();
        let dev = chol.solve_lt(&eps);
        let w: Vec<f64> = mean.iter().zip(&dev).map(|(m, d)| m + d).collect();
        let phi_test = self.features(x_test);
        Ok(phi_test.matvec(&w))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::{KernelOp, KernelType, LinearOp};

    #[test]
    fn feature_gram_approximates_kernel() {
        let mut rng = Pcg64::seeded(1);
        let n = 30;
        let x = Matrix::randn(n, 2, &mut rng);
        let (ell, s2) = (1.0, 1.5);
        let rff = RandomFourierFeatures::new(2, 4000, ell, s2, &mut rng);
        let phi = rff.features(&x);
        let gram = phi.matmul(&phi.transpose());
        let k = KernelOp::new(&x, KernelType::Rbf, ell, s2, 0.0).to_dense();
        let err = gram.max_abs_diff(&k);
        assert!(err < 0.15, "RFF gram error {err}");
    }

    #[test]
    fn prior_samples_have_right_scale() {
        let mut rng = Pcg64::seeded(2);
        let x = Matrix::randn(20, 2, &mut rng);
        let rff = RandomFourierFeatures::new(2, 1000, 1.0, 2.0, &mut rng);
        let mut acc = 0.0;
        let reps = 300;
        for _ in 0..reps {
            let f = rff.prior_sample(&x, &mut rng);
            acc += f.iter().map(|v| v * v).sum::<f64>() / 20.0;
        }
        let var = acc / reps as f64;
        assert!((var - 2.0).abs() < 0.4, "marginal variance {var} should be ≈ 2");
    }

    #[test]
    fn posterior_sample_interpolates_data() {
        // with tiny noise, posterior samples should pass near training points
        let mut rng = Pcg64::seeded(3);
        let n = 15;
        let x = Matrix::randn(n, 1, &mut rng);
        let y: Vec<f64> = (0..n).map(|i| (x[(i, 0)] * 2.0).sin()).collect();
        let rff = RandomFourierFeatures::new(1, 800, 0.8, 1.0, &mut rng);
        let f = rff.posterior_sample(&x, &y, 1e-4, &x, &mut rng).unwrap();
        let rmse = (f
            .iter()
            .zip(&y)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / n as f64)
            .sqrt();
        assert!(rmse < 0.15, "posterior sample rmse {rmse}");
    }
}

//! Randomized-SVD square-root baseline (Halko et al. [36]) — Fig. S2.
//!
//! Range-find `Q ≈ range(K)` with a Gaussian sketch + power iterations,
//! project `B = QᵀKQ`, eigendecompose, and use
//! `K^{1/2} b ≈ (QV) Λ^{1/2} (QV)ᵀ b`. Works only when `K` is numerically
//! low-rank — the paper shows it plateaus around 0.25 relative error on
//! slowly-decaying spectra, unlike CIQ.

use crate::linalg::eigen::sym_eig;
use crate::linalg::Matrix;
use crate::operators::LinearOp;
use crate::rng::Pcg64;
use crate::util::{axpy, dot, norm2};
use crate::Result;

/// Rank-`r` randomized approximation of `K^{±1/2}`.
pub struct RandomizedSvdSqrt {
    /// `n × r` basis `QV`
    basis: Matrix,
    /// approximate eigenvalues (descending-ish, ≥ 0)
    evals: Vec<f64>,
}

/// Modified Gram–Schmidt orthonormalization of the columns of `a`.
pub fn orthonormalize(a: &Matrix) -> Matrix {
    let (n, r) = (a.rows(), a.cols());
    let mut q = Matrix::zeros(n, r);
    let mut kept = 0;
    for j in 0..r {
        let mut v = a.col(j);
        for p in 0..kept {
            let qp = q.col(p);
            let c = dot(&qp, &v);
            axpy(-c, &qp, &mut v);
        }
        let nv = norm2(&v);
        if nv > 1e-12 {
            for i in 0..n {
                q[(i, kept)] = v[i] / nv;
            }
            kept += 1;
        }
    }
    if kept < r {
        // return only the kept columns
        let mut qq = Matrix::zeros(n, kept);
        for j in 0..kept {
            for i in 0..n {
                qq[(i, j)] = q[(i, j)];
            }
        }
        qq
    } else {
        q
    }
}

impl RandomizedSvdSqrt {
    /// Build a rank-`rank` approximation with `power` subspace iterations
    /// (paper setup: `power = 2`, oversampling 8).
    pub fn new(op: &dyn LinearOp, rank: usize, power: usize, rng: &mut Pcg64) -> Result<RandomizedSvdSqrt> {
        let n = op.size();
        let sketch = rank + 8.min(n.saturating_sub(rank));
        let omega = Matrix::randn(n, sketch.min(n), rng);
        let mut y = op.matmat(&omega);
        let mut q = orthonormalize(&y);
        for _ in 0..power {
            y = op.matmat(&q);
            q = orthonormalize(&y);
        }
        // project: B = Qᵀ K Q
        let kq = op.matmat(&q);
        let b = q.t_matmul(&kq);
        let eig = sym_eig(&b)?;
        // keep top `rank` eigenpairs
        let total = eig.values.len();
        let keep = rank.min(total);
        let mut basis = Matrix::zeros(n, keep);
        let mut evals = vec![0.0; keep];
        for jj in 0..keep {
            let src = total - 1 - jj; // descending
            evals[jj] = eig.values[src].max(0.0);
            let vj = eig.vectors.col(src);
            let col = q.matvec(&vj);
            for i in 0..n {
                basis[(i, jj)] = col[i];
            }
        }
        Ok(RandomizedSvdSqrt { basis, evals })
    }

    /// `K^{1/2} b ≈ (QV) Λ^{1/2} (QV)ᵀ b`.
    pub fn sqrt_mvm(&self, b: &[f64]) -> Vec<f64> {
        let mut c = self.basis.matvec_t(b);
        for (ci, ev) in c.iter_mut().zip(&self.evals) {
            *ci *= ev.sqrt();
        }
        self.basis.matvec(&c)
    }

    /// `K^{-1/2} b` on the captured subspace (pseudo-inverse square root).
    pub fn invsqrt_mvm(&self, b: &[f64]) -> Vec<f64> {
        let mut c = self.basis.matvec_t(b);
        for (ci, ev) in c.iter_mut().zip(&self.evals) {
            *ci *= if *ev > 1e-12 { 1.0 / ev.sqrt() } else { 0.0 };
        }
        self.basis.matvec(&c)
    }

    /// Approximate eigenvalues.
    pub fn eigenvalues(&self) -> &[f64] {
        &self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigen::spd_sqrt;
    use crate::operators::DenseOp;
    use crate::util::rel_err;

    fn spd_with_decay(n: usize, decay: impl Fn(usize) -> f64, rng: &mut Pcg64) -> Matrix {
        let a = Matrix::randn(n, n, rng);
        let q = orthonormalize(&a);
        let mut scaled = q.clone();
        for j in 0..n {
            let ev = decay(j + 1);
            for i in 0..n {
                scaled[(i, j)] *= ev;
            }
        }
        scaled.matmul(&q.transpose())
    }

    #[test]
    fn exact_on_truly_low_rank() {
        let mut rng = Pcg64::seeded(1);
        let n = 40;
        // rank-5 + tiny ridge
        let k = spd_with_decay(n, |t| if t <= 5 { 10.0 / t as f64 } else { 1e-9 }, &mut rng);
        let op = DenseOp::new(k.clone());
        let rs = RandomizedSvdSqrt::new(&op, 8, 2, &mut rng).unwrap();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let approx = rs.sqrt_mvm(&b);
        let exact = spd_sqrt(&k).unwrap().matvec(&b);
        assert!(rel_err(&approx, &exact) < 1e-3);
    }

    #[test]
    fn plateaus_on_slow_decay() {
        // Fig. S2's message: for λ_t = 1/√t, randomized SVD stalls around
        // 20-30% error even at moderate rank.
        let mut rng = Pcg64::seeded(2);
        let n = 120;
        let k = spd_with_decay(n, |t| 1.0 / (t as f64).sqrt(), &mut rng);
        let op = DenseOp::new(k.clone());
        let rs = RandomizedSvdSqrt::new(&op, 32, 2, &mut rng).unwrap();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let approx = rs.sqrt_mvm(&b);
        let exact = spd_sqrt(&k).unwrap().matvec(&b);
        let err = rel_err(&approx, &exact);
        assert!(err > 0.05, "rsvd should NOT be accurate here, err={err}");
    }

    #[test]
    fn orthonormalize_produces_orthonormal_columns() {
        let mut rng = Pcg64::seeded(3);
        let a = Matrix::randn(25, 6, &mut rng);
        let q = orthonormalize(&a);
        let qtq = q.t_matmul(&q);
        assert!(qtq.max_abs_diff(&Matrix::eye(6)) < 1e-10);
    }
}

//! Baselines the paper compares against: Cholesky sampling/whitening
//! (in [`crate::linalg::chol`]), Random Fourier Features ([`rff`]) and
//! randomized SVD ([`rsvd`]).

pub mod rff;
pub mod rsvd;

pub use rff::RandomFourierFeatures;
pub use rsvd::RandomizedSvdSqrt;

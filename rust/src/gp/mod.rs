//! Exact Gaussian-process regression — the Bayesian-optimization surrogate
//! (Sec. 5.2). Training data stays small (≤ a few hundred BO evaluations),
//! so hyperparameters are fit with dense marginal-likelihood gradients; the
//! expensive object is the *posterior covariance at `T` candidate points*,
//! which is exposed as a [`LinearOp`] (`K** − W Wᵀ`) so CIQ can sample from
//! it with `O(T²)` time / `O(T)` extra memory.

use crate::ciq::{Ciq, CiqOptions};
use crate::linalg::{Cholesky, Matrix};
use crate::operators::kernel::cross_kernel;
use crate::operators::{KernelOp, KernelType, LinearOp, SubtractLowRankOp};
use crate::rng::Pcg64;
use crate::{Error, Result};

/// GP hyperparameters (isotropic lengthscale).
#[derive(Clone, Copy, Debug)]
pub struct GpHyper {
    /// lengthscale ℓ
    pub lengthscale: f64,
    /// kernel variance s²
    pub outputscale: f64,
    /// observation noise σ²
    pub noise: f64,
}

impl Default for GpHyper {
    fn default() -> Self {
        GpHyper { lengthscale: 0.3, outputscale: 1.0, noise: 1e-2 }
    }
}

/// Exact GP with RBF/Matérn kernel.
pub struct ExactGp {
    /// training inputs `n × d`
    pub x: Matrix,
    /// training targets
    pub y: Vec<f64>,
    /// kernel family
    pub kind: KernelType,
    /// hyperparameters
    pub hyper: GpHyper,
    chol: Option<Cholesky>,
    alpha: Vec<f64>,
}

impl ExactGp {
    /// Create (call [`ExactGp::refit`] or [`ExactGp::fit_hypers`] before predicting).
    pub fn new(x: Matrix, y: Vec<f64>, kind: KernelType, hyper: GpHyper) -> ExactGp {
        ExactGp { x, y, kind, hyper, chol: None, alpha: vec![] }
    }

    fn ell_vec(&self) -> Vec<f64> {
        vec![self.hyper.lengthscale; self.x.cols()]
    }

    /// Recompute the Cholesky factor and `α = (K+σ²I)^{-1} y`.
    pub fn refit(&mut self) -> Result<()> {
        let op = KernelOp::new(&self.x, self.kind, self.hyper.lengthscale, self.hyper.outputscale, self.hyper.noise);
        let k = op.to_dense();
        let chol = Cholesky::with_jitter(&k, 1e-8)?;
        self.alpha = chol.solve(&self.y);
        self.chol = Some(chol);
        Ok(())
    }

    /// Log marginal likelihood (requires refit).
    pub fn log_marginal(&self) -> Result<f64> {
        let chol = self.chol.as_ref().ok_or_else(|| Error::Invalid("call refit() first".into()))?;
        let n = self.y.len() as f64;
        Ok(-0.5 * crate::util::dot(&self.y, &self.alpha)
            - 0.5 * chol.logdet()
            - 0.5 * n * (2.0 * std::f64::consts::PI).ln())
    }

    /// Fit hyperparameters by Adam on the log marginal likelihood
    /// (analytic gradients via `tr((ααᵀ − K^{-1}) ∂K/∂θ)/2`).
    pub fn fit_hypers(&mut self, steps: usize, lr: f64) -> Result<f64> {
        let n = self.x.rows();
        // log-parameters
        let mut log_p = [
            self.hyper.lengthscale.ln(),
            self.hyper.outputscale.ln(),
            self.hyper.noise.ln(),
        ];
        let mut m = [0.0; 3];
        let mut v = [0.0; 3];
        let (b1, b2, eps) = (0.9, 0.999, 1e-8);
        let mut last_lml = f64::NEG_INFINITY;
        for t in 1..=steps {
            self.hyper = GpHyper {
                lengthscale: log_p[0].exp(),
                outputscale: log_p[1].exp(),
                noise: log_p[2].exp().max(1e-8),
            };
            self.refit()?;
            last_lml = self.log_marginal()?;
            let chol = self.chol.as_ref().unwrap();
            // K^{-1} via solves on identity columns (n is small for BO)
            let mut kinv = Matrix::zeros(n, n);
            for j in 0..n {
                let mut e = vec![0.0; n];
                e[j] = 1.0;
                let col = chol.solve(&e);
                for i in 0..n {
                    kinv[(i, j)] = col[i];
                }
            }
            // dK/dθ matrices
            let op = KernelOp::new(&self.x, self.kind, self.hyper.lengthscale, self.hyper.outputscale, 0.0);
            let kmat = op.to_dense();
            let ell = self.ell_vec();
            let mut grad = [0.0f64; 3];
            // grad = 0.5 tr((ααᵀ - K^{-1}) dK/dθ)
            for i in 0..n {
                for j in 0..n {
                    let aij = self.alpha[i] * self.alpha[j] - kinv[(i, j)];
                    // dK/d log s2 = K (noise-free part)
                    grad[1] += 0.5 * aij * kmat[(i, j)];
                    // dK/d log ell
                    let d2: f64 = self
                        .x
                        .row(i)
                        .iter()
                        .zip(self.x.row(j))
                        .zip(&ell)
                        .map(|((a, b), l)| {
                            let t = (a - b) / l;
                            t * t
                        })
                        .sum();
                    let r = d2.sqrt();
                    grad[0] += 0.5 * aij * self.hyper.outputscale * self.kind.drho_dlog_ell(r);
                    if i == j {
                        // dK/d log noise = σ² I
                        grad[2] += 0.5 * aij * self.hyper.noise;
                    }
                }
            }
            // Adam ascent
            for p in 0..3 {
                m[p] = b1 * m[p] + (1.0 - b1) * grad[p];
                v[p] = b2 * v[p] + (1.0 - b2) * grad[p] * grad[p];
                let mh = m[p] / (1.0 - b1.powi(t as i32));
                let vh = v[p] / (1.0 - b2.powi(t as i32));
                log_p[p] += lr * mh / (vh.sqrt() + eps);
            }
            // clamp to sane ranges (paper's BO bounds, Appx. F)
            log_p[0] = log_p[0].clamp((0.01f64).ln(), (2.0f64).ln());
            log_p[1] = log_p[1].clamp((0.05f64).ln(), (50.0f64).ln());
            log_p[2] = log_p[2].clamp((1e-6f64).ln(), (1e-2f64).ln());
        }
        self.hyper = GpHyper {
            lengthscale: log_p[0].exp(),
            outputscale: log_p[1].exp(),
            noise: log_p[2].exp().max(1e-8),
        };
        self.refit()?;
        Ok(last_lml)
    }

    /// Posterior mean at test points.
    pub fn posterior_mean(&self, x_star: &Matrix) -> Result<Vec<f64>> {
        if self.chol.is_none() {
            return Err(Error::Invalid("call refit() first".into()));
        }
        let kxs = cross_kernel(x_star, &self.x, self.kind, &self.ell_vec(), self.hyper.outputscale);
        Ok(kxs.matvec(&self.alpha))
    }

    /// Posterior-covariance pieces at `T` test points: the kernel operator
    /// `K**` (with tiny jitter for SPD safety) and the low-rank correction
    /// factor `W = K*n L^{-T}` such that `Cov = K** − W Wᵀ`.
    pub fn posterior_cov_parts(&self, x_star: &Matrix, jitter: f64) -> Result<(KernelOp, Matrix)> {
        let chol = self.chol.as_ref().ok_or_else(|| Error::Invalid("call refit() first".into()))?;
        let t = x_star.rows();
        let n = self.x.rows();
        let kxs = cross_kernel(x_star, &self.x, self.kind, &self.ell_vec(), self.hyper.outputscale); // T×n
        // W = K*n L^{-T}: rows w_i solve L w_i = k_i  (so W Wᵀ = K*n K^{-1} Kn*)
        let mut w = Matrix::zeros(t, n);
        for i in 0..t {
            let ki = kxs.row(i).to_vec();
            let wi = chol.solve_l(&ki);
            for j in 0..n {
                w[(i, j)] = wi[j];
            }
        }
        let kss = KernelOp::new(x_star, self.kind, self.hyper.lengthscale, self.hyper.outputscale, jitter);
        Ok((kss, w))
    }

    /// Draw one posterior sample at `x_star` with CIQ (O(T²) time, O(T) mem).
    pub fn sample_posterior_ciq(
        &self,
        x_star: &Matrix,
        opts: &CiqOptions,
        rng: &mut Pcg64,
    ) -> Result<Vec<f64>> {
        let mean = self.posterior_mean(x_star)?;
        let (kss, w) = self.posterior_cov_parts(x_star, 1e-4)?;
        // the jitter-free posterior covariance is a Schur complement (PSD),
        // so λ_min ≥ jitter — certify it for the CIQ quadrature
        let cov = SubtractLowRankOp::new(&kss, w).with_lambda_min_bound(1e-4);
        let eps: Vec<f64> = (0..x_star.rows()).map(|_| rng.normal()).collect();
        let solver = Ciq::new(opts.clone());
        let dev = solver.sqrt_mvm(&cov, &eps)?.solution;
        Ok(mean.iter().zip(&dev).map(|(m, d)| m + d).collect())
    }

    /// Draw one posterior sample with dense Cholesky (O(T³) / O(T²) —
    /// the baseline).
    pub fn sample_posterior_cholesky(&self, x_star: &Matrix, rng: &mut Pcg64) -> Result<Vec<f64>> {
        let mean = self.posterior_mean(x_star)?;
        let (kss, w) = self.posterior_cov_parts(x_star, 1e-4)?;
        let cov_op = SubtractLowRankOp::new(&kss, w);
        let cov = cov_op.to_dense();
        let chol = Cholesky::with_jitter(&cov, 1e-8)?;
        let eps: Vec<f64> = (0..x_star.rows()).map(|_| rng.normal()).collect();
        let dev = chol.sample_mvm(&eps);
        Ok(mean.iter().zip(&dev).map(|(m, d)| m + d).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_gp(n: usize, seed: u64) -> ExactGp {
        let mut rng = Pcg64::seeded(seed);
        let mut x = Matrix::zeros(n, 1);
        for i in 0..n {
            x[(i, 0)] = rng.uniform();
        }
        let y: Vec<f64> = (0..n).map(|i| (6.0 * x[(i, 0)]).sin() + 0.05 * rng.normal()).collect();
        ExactGp::new(x, y, KernelType::Matern52, GpHyper { lengthscale: 0.2, outputscale: 1.0, noise: 1e-3 })
    }

    #[test]
    fn posterior_interpolates_training_data() {
        let mut gp = toy_gp(30, 1);
        gp.refit().unwrap();
        let mean = gp.posterior_mean(&gp.x.clone()).unwrap();
        let rmse = (mean
            .iter()
            .zip(&gp.y)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / 30.0)
            .sqrt();
        assert!(rmse < 0.1, "rmse {rmse}");
    }

    #[test]
    fn fit_improves_marginal_likelihood() {
        let mut gp = toy_gp(40, 2);
        gp.hyper = GpHyper { lengthscale: 1.5, outputscale: 0.1, noise: 5e-3 };
        gp.refit().unwrap();
        let before = gp.log_marginal().unwrap();
        let after = gp.fit_hypers(30, 0.1).unwrap();
        assert!(after > before, "lml {before} -> {after}");
    }

    #[test]
    fn ciq_and_cholesky_samples_share_moments() {
        let mut gp = toy_gp(25, 3);
        gp.refit().unwrap();
        let mut rng = Pcg64::seeded(4);
        let mut xs = Matrix::zeros(40, 1);
        for i in 0..40 {
            xs[(i, 0)] = i as f64 / 39.0;
        }
        let opts = CiqOptions { tol: 1e-7, ..Default::default() };
        let reps = 60;
        let mut mean_c = vec![0.0; 40];
        let mut mean_q = vec![0.0; 40];
        for _ in 0..reps {
            let sc = gp.sample_posterior_cholesky(&xs, &mut rng).unwrap();
            let sq = gp.sample_posterior_ciq(&xs, &opts, &mut rng).unwrap();
            for i in 0..40 {
                mean_c[i] += sc[i] / reps as f64;
                mean_q[i] += sq[i] / reps as f64;
            }
        }
        let pm = gp.posterior_mean(&xs).unwrap();
        for i in 0..40 {
            assert!((mean_c[i] - pm[i]).abs() < 0.5, "chol mean off at {i}");
            assert!((mean_q[i] - pm[i]).abs() < 0.5, "ciq mean off at {i}");
        }
    }

    #[test]
    fn posterior_cov_is_psd_operator() {
        let mut gp = toy_gp(20, 5);
        gp.refit().unwrap();
        let mut rng = Pcg64::seeded(6);
        let xs = Matrix::randn(30, 1, &mut rng);
        let (kss, w) = gp.posterior_cov_parts(&xs, 1e-6).unwrap();
        let cov = SubtractLowRankOp::new(&kss, w);
        for _ in 0..10 {
            let v: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
            let q = crate::util::dot(&v, &cov.matvec(&v));
            assert!(q > -1e-8, "posterior covariance not PSD: {q}");
        }
    }
}

//! Multi-shift MINRES (Alg. 4 of the paper).
//!
//! Solves all `Q` shifted systems `(K + t_q I) c_q = b` simultaneously from a
//! *single* Krylov subspace: one MVM per iteration regardless of `Q`,
//! exploiting the shift invariance `K_J(K, b) = K_J(K + tI, b)` (Obs. 1).
//! Per shift, the tridiagonal QR is updated with Givens rotations and the
//! solution advances through a three-term "search direction" recurrence, so
//! total extra storage is `O(QN)` (Property 1).

use crate::linalg::Matrix;
use crate::operators::LinearOp;
use crate::util::{axpy, dot, norm2};

/// Options for [`msminres`].
#[derive(Clone, Debug)]
pub struct MsMinresOptions {
    /// Maximum iterations `J`.
    pub max_iters: usize,
    /// Relative-residual stopping tolerance (per shift).
    pub tol: f64,
    /// Optional CIQ weights: when set, stop on the *weighted* residual
    /// `Σ_q |w_q|·res_q / Σ_q |w_q|` instead of the max over shifts.
    pub weights: Option<Vec<f64>>,
}

impl Default for MsMinresOptions {
    fn default() -> Self {
        MsMinresOptions { max_iters: 400, tol: 1e-4, weights: None }
    }
}

/// Result of a (multi-shift) MINRES run.
#[derive(Clone, Debug)]
pub struct MsMinresResult {
    /// One solution vector per shift: `c_q ≈ (K + t_q I)^{-1} b`.
    pub solutions: Vec<Vec<f64>>,
    /// Relative residuals per shift at exit.
    pub residuals: Vec<f64>,
    /// Iterations executed (= MVMs performed).
    pub iterations: usize,
    /// Whether the stopping tolerance was reached.
    pub converged: bool,
    /// Max-over-shifts relative residual after each iteration (Fig. 2 left).
    pub residual_history: Vec<f64>,
}

/// Per-shift recurrence state.
struct ShiftState {
    /// previous two Givens rotations
    c1: f64,
    s1: f64,
    c2: f64,
    s2: f64,
    /// running rhs component; |phi_bar| is the absolute residual
    phi_bar: f64,
    /// search directions d_{k-1}, d_{k-2}
    d_prev: Vec<f64>,
    d_prev2: Vec<f64>,
    /// current solution
    x: Vec<f64>,
    /// frozen once converged
    done: bool,
}

impl ShiftState {
    fn new(n: usize, beta1: f64) -> ShiftState {
        ShiftState {
            c1: 1.0,
            s1: 0.0,
            c2: 1.0,
            s2: 0.0,
            phi_bar: beta1,
            d_prev: vec![0.0; n],
            d_prev2: vec![0.0; n],
            x: vec![0.0; n],
            done: false,
        }
    }

    /// Advance one MINRES step given this iteration's Lanczos scalars and
    /// vector. `beta_k` couples v_{k-1},v_k (0 at k=1); `beta_next` is the
    /// new subdiagonal.
    #[inline]
    fn step(&mut self, shift: f64, alpha: f64, beta_k: f64, beta_next: f64, v: &[f64]) {
        let eps = self.s2 * beta_k;
        let delta_bar = self.c2 * beta_k;
        let a = alpha + shift;
        let delta = self.c1 * delta_bar + self.s1 * a;
        let gamma_bar = -self.s1 * delta_bar + self.c1 * a;
        let gamma = (gamma_bar * gamma_bar + beta_next * beta_next).sqrt();
        // Givens zeroing beta_next; guard breakdown (gamma == 0 happens only
        // for exactly-singular shifted systems, impossible for t > 0 SPD).
        let (c, s) = if gamma > 0.0 { (gamma_bar / gamma, beta_next / gamma) } else { (1.0, 0.0) };
        let tau = c * self.phi_bar;
        self.phi_bar = -s * self.phi_bar;
        // d_k = (v_k - delta d_{k-1} - eps d_{k-2}) / gamma
        // then x += tau d_k. Reuse d_prev2's buffer as the new direction.
        let inv_gamma = if gamma > 0.0 { 1.0 / gamma } else { 0.0 };
        for i in 0..v.len() {
            let d_new = (v[i] - delta * self.d_prev[i] - eps * self.d_prev2[i]) * inv_gamma;
            self.d_prev2[i] = d_new; // temporarily stash
            self.x[i] += tau * d_new;
        }
        std::mem::swap(&mut self.d_prev, &mut self.d_prev2);
        // after swap: d_prev = d_new, d_prev2 = old d_prev  ✓
        self.c2 = self.c1;
        self.s2 = self.s1;
        self.c1 = c;
        self.s1 = s;
    }
}

/// Run msMINRES: returns `c_q ≈ (K + t_q I)^{-1} b` for every shift `t_q`.
///
/// `shifts` must be ≥ 0 (SPD + nonnegative shifts keeps every system SPD,
/// which is what the CIQ quadrature produces — Eq. S5).
pub fn msminres(
    op: &dyn LinearOp,
    b: &[f64],
    shifts: &[f64],
    opts: &MsMinresOptions,
) -> MsMinresResult {
    let n = op.size();
    assert_eq!(b.len(), n);
    assert!(!shifts.is_empty());
    let beta1 = norm2(b);
    if beta1 == 0.0 {
        return MsMinresResult {
            solutions: vec![vec![0.0; n]; shifts.len()],
            residuals: vec![0.0; shifts.len()],
            iterations: 0,
            converged: true,
            residual_history: vec![],
        };
    }
    let mut states: Vec<ShiftState> = shifts.iter().map(|_| ShiftState::new(n, beta1)).collect();

    // Lanczos state
    let mut v: Vec<f64> = b.iter().map(|x| x / beta1).collect();
    let mut v_prev = vec![0.0; n];
    let mut beta_k = 0.0f64; // couples v_prev and v
    let mut iters = 0;
    let mut converged = false;
    let mut residual_history = Vec::new();

    for _k in 1..=opts.max_iters {
        iters += 1;
        // Lanczos expansion
        let mut w = op.matvec(&v);
        if beta_k != 0.0 {
            axpy(-beta_k, &v_prev, &mut w);
        }
        let alpha = dot(&v, &w);
        axpy(-alpha, &v, &mut w);
        let beta_next = norm2(&w);

        // advance every (unconverged) shift
        for (q, st) in states.iter_mut().enumerate() {
            if !st.done {
                st.step(shifts[q], alpha, beta_k, beta_next, &v);
                if (st.phi_bar.abs() / beta1) < opts.tol {
                    st.done = true;
                }
            }
        }

        residual_history
            .push(states.iter().map(|st| st.phi_bar.abs() / beta1).fold(0.0, f64::max));

        // stopping criterion
        let stop = match &opts.weights {
            Some(ws) => {
                let wsum: f64 = ws.iter().map(|w| w.abs()).sum();
                let r: f64 = states
                    .iter()
                    .zip(ws)
                    .map(|(st, w)| w.abs() * (st.phi_bar.abs() / beta1))
                    .sum::<f64>()
                    / wsum.max(1e-300);
                r < opts.tol
            }
            None => states.iter().all(|st| st.done),
        };
        if stop {
            converged = true;
            break;
        }
        if beta_next < 1e-13 * alpha.abs().max(1.0) {
            // Krylov space exhausted: solution is exact in the subspace.
            converged = true;
            break;
        }

        // rotate Lanczos vectors
        for i in 0..n {
            let next = w[i] / beta_next;
            v_prev[i] = v[i];
            v[i] = next;
        }
        beta_k = beta_next;
    }

    MsMinresResult {
        residuals: states.iter().map(|st| st.phi_bar.abs() / beta1).collect(),
        solutions: states.into_iter().map(|st| st.x).collect(),
        iterations: iters,
        converged,
        residual_history,
    }
}

/// Block msMINRES: independent recurrences for each column of `b_mat`,
/// sharing each iteration's MVMs as a single `matmat` (the batching the
/// coordinator exploits — Fig. 2 mid/right varies this RHS count).
///
/// Returns `solutions[q]` as an `n × r` matrix of per-column solves, plus
/// per-column iteration counts.
pub fn msminres_block(
    op: &dyn LinearOp,
    b_mat: &Matrix,
    shifts: &[f64],
    opts: &MsMinresOptions,
) -> (Vec<Matrix>, Vec<usize>, Vec<f64>) {
    let n = op.size();
    let r = b_mat.cols();
    assert_eq!(b_mat.rows(), n);
    // per-column Lanczos state
    let mut beta1 = vec![0.0; r];
    let mut v = Matrix::zeros(n, r);
    let mut v_prev = Matrix::zeros(n, r);
    let mut beta_k = vec![0.0; r];
    let mut col_done = vec![false; r];
    let mut col_iters = vec![0usize; r];
    for j in 0..r {
        let col = b_mat.col(j);
        beta1[j] = norm2(&col);
        if beta1[j] == 0.0 {
            col_done[j] = true;
            continue;
        }
        for i in 0..n {
            v[(i, j)] = col[i] / beta1[j];
        }
    }
    let mut states: Vec<Vec<ShiftState>> = (0..shifts.len())
        .map(|_| (0..r).map(|j| ShiftState::new(n, beta1[j])).collect())
        .collect();

    let mut scratch_v = vec![0.0; n];
    for _k in 1..=opts.max_iters {
        if col_done.iter().all(|&d| d) {
            break;
        }
        let mut w = op.matmat(&v);
        for j in 0..r {
            if col_done[j] {
                continue;
            }
            col_iters[j] += 1;
            // per-column Lanczos update
            let mut alpha = 0.0;
            for i in 0..n {
                let wij = w[(i, j)] - beta_k[j] * v_prev[(i, j)];
                w[(i, j)] = wij;
                alpha += v[(i, j)] * wij;
            }
            let mut bn2 = 0.0;
            for i in 0..n {
                let wij = w[(i, j)] - alpha * v[(i, j)];
                w[(i, j)] = wij;
                bn2 += wij * wij;
            }
            let beta_next = bn2.sqrt();
            for i in 0..n {
                scratch_v[i] = v[(i, j)];
            }
            let mut all_done = true;
            for (q, per_shift) in states.iter_mut().enumerate() {
                let st = &mut per_shift[j];
                if !st.done {
                    st.step(shifts[q], alpha, beta_k[j], beta_next, &scratch_v);
                    if (st.phi_bar.abs() / beta1[j]) < opts.tol {
                        st.done = true;
                    }
                }
                all_done &= st.done;
            }
            if all_done || beta_next < 1e-13 * alpha.abs().max(1.0) {
                col_done[j] = true;
                continue;
            }
            for i in 0..n {
                v_prev[(i, j)] = v[(i, j)];
                v[(i, j)] = w[(i, j)] / beta_next;
            }
            beta_k[j] = beta_next;
        }
    }

    let mut max_res = 0.0f64;
    for per_shift in &states {
        for (j, st) in per_shift.iter().enumerate() {
            if beta1[j] > 0.0 {
                max_res = max_res.max(st.phi_bar.abs() / beta1[j]);
            }
        }
    }
    let residuals = vec![max_res; shifts.len()];
    let solutions: Vec<Matrix> = states
        .into_iter()
        .map(|per_shift| {
            let mut m = Matrix::zeros(n, r);
            for (j, st) in per_shift.into_iter().enumerate() {
                for i in 0..n {
                    m[(i, j)] = st.x[i];
                }
            }
            m
        })
        .collect();
    (solutions, col_iters, residuals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Cholesky, Matrix};
    use crate::operators::DenseOp;
    use crate::rng::Pcg64;
    use crate::util::rel_err;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64 * 0.1;
        }
        k
    }

    #[test]
    fn solves_all_shifts() {
        let n = 50;
        let k = random_spd(n, 1);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(2);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let shifts = [0.0, 0.1, 1.0, 10.0, 100.0];
        let opts = MsMinresOptions { max_iters: 200, tol: 1e-10, weights: None };
        let res = msminres(&op, &b, &shifts, &opts);
        assert!(res.converged);
        for (q, &t) in shifts.iter().enumerate() {
            let mut kt = k.clone();
            for i in 0..n {
                kt[(i, i)] += t;
            }
            let exact = Cholesky::new(&kt).unwrap().solve(&b);
            let err = rel_err(&res.solutions[q], &exact);
            assert!(err < 1e-7, "shift {t}: rel err {err}");
        }
    }

    #[test]
    fn one_mvm_per_iteration_counts() {
        // iteration count should be far below N for well-conditioned K
        let n = 120;
        let mut k = Matrix::eye(n);
        for i in 0..n {
            k[(i, i)] = 1.0 + 0.1 * (i as f64 / n as f64); // kappa ≈ 1.1
        }
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(3);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let res = msminres(&op, &b, &[0.0, 1.0], &MsMinresOptions::default());
        assert!(res.converged);
        assert!(res.iterations < 25, "iterations {}", res.iterations);
    }

    #[test]
    fn higher_shifts_converge_faster() {
        let n = 60;
        let k = random_spd(n, 4);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(5);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = MsMinresOptions { max_iters: 30, tol: 1e-14, weights: None };
        let res = msminres(&op, &b, &[0.0, 50.0], &opts);
        assert!(
            res.residuals[1] <= res.residuals[0] + 1e-12,
            "shifted residual {} should be <= unshifted {}",
            res.residuals[1],
            res.residuals[0]
        );
    }

    #[test]
    fn residual_tracker_matches_true_residual() {
        let n = 40;
        let k = random_spd(n, 6);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(7);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = MsMinresOptions { max_iters: 17, tol: 1e-30, weights: None };
        let res = msminres(&op, &b, &[0.5], &opts);
        let mut kt = k.clone();
        for i in 0..n {
            kt[(i, i)] += 0.5;
        }
        let r_true = {
            let kx = kt.matvec(&res.solutions[0]);
            let diff: Vec<f64> = kx.iter().zip(&b).map(|(a, c)| a - c).collect();
            crate::util::norm2(&diff) / crate::util::norm2(&b)
        };
        assert!(
            (res.residuals[0] - r_true).abs() < 1e-8 * (1.0 + r_true),
            "tracked {} vs true {r_true}",
            res.residuals[0]
        );
    }

    #[test]
    fn block_version_matches_single() {
        let n = 35;
        let k = random_spd(n, 8);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(9);
        let b = Matrix::randn(n, 3, &mut rng);
        let shifts = [0.1, 2.0];
        let opts = MsMinresOptions { max_iters: 150, tol: 1e-10, weights: None };
        let (sols, iters, _res) = msminres_block(&op, &b, &shifts, &opts);
        for j in 0..3 {
            let col = b.col(j);
            let single = msminres(&op, &col, &shifts, &opts);
            for q in 0..2 {
                let blocked = sols[q].col(j);
                let err = rel_err(&blocked, &single.solutions[q]);
                assert!(err < 1e-8, "col {j} shift {q}: {err}");
            }
        }
        assert!(iters.iter().all(|&it| it > 0));
    }

    #[test]
    fn zero_rhs_short_circuits() {
        let op = DenseOp::new(Matrix::eye(10));
        let res = msminres(&op, &vec![0.0; 10], &[0.0, 1.0], &MsMinresOptions::default());
        assert!(res.converged);
        assert_eq!(res.iterations, 0);
        assert!(res.solutions[0].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn property_msminres_equals_minres_per_shift() {
        crate::util::proptest::check_default("msminres == per-shift solves", |rng, _| {
            let n = 12 + rng.below(10);
            let a = Matrix::randn(n, n, rng);
            let mut k = a.matmul(&a.transpose());
            for i in 0..n {
                k[(i, i)] += n as f64;
            }
            let op = DenseOp::new(k.clone());
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let shifts = [rng.uniform() * 5.0, 10.0 + rng.uniform() * 50.0];
            let opts = MsMinresOptions { max_iters: 300, tol: 1e-11, weights: None };
            let multi = msminres(&op, &b, &shifts, &opts);
            for (q, &t) in shifts.iter().enumerate() {
                let mut kt = k.clone();
                for i in 0..n {
                    kt[(i, i)] += t;
                }
                let exact = Cholesky::new(&kt).unwrap().solve(&b);
                let err = rel_err(&multi.solutions[q], &exact);
                crate::prop_assert!(err < 1e-6, "shift {t}: err {err}");
            }
            Ok(())
        });
    }
}

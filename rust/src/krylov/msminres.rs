//! Multi-shift MINRES (Alg. 4 of the paper).
//!
//! Solves all `Q` shifted systems `(K + t_q I) c_q = b` simultaneously from a
//! *single* Krylov subspace: one MVM per iteration regardless of `Q`,
//! exploiting the shift invariance `K_J(K, b) = K_J(K + tI, b)` (Obs. 1).
//! Per shift, the tridiagonal QR is updated with Givens rotations and the
//! solution advances through a three-term "search direction" recurrence, so
//! total extra storage is `O(QN)` (Property 1).

use crate::linalg::Matrix;
use crate::operators::LinearOp;
use crate::util::{axpy, dot, norm2};

/// Options for [`msminres`].
#[derive(Clone, Debug)]
pub struct MsMinresOptions {
    /// Maximum iterations `J`.
    pub max_iters: usize,
    /// Relative-residual stopping tolerance (per shift).
    pub tol: f64,
    /// Optional CIQ weights: when set, stop on the *weighted* residual
    /// `Σ_q |w_q|·res_q / Σ_q |w_q|` instead of the max over shifts.
    pub weights: Option<Vec<f64>>,
}

impl Default for MsMinresOptions {
    fn default() -> Self {
        MsMinresOptions { max_iters: 400, tol: 1e-4, weights: None }
    }
}

/// Result of a (multi-shift) MINRES run.
#[derive(Clone, Debug)]
pub struct MsMinresResult {
    /// One solution vector per shift: `c_q ≈ (K + t_q I)^{-1} b`.
    pub solutions: Vec<Vec<f64>>,
    /// Relative residuals per shift at exit.
    pub residuals: Vec<f64>,
    /// Iterations executed (= MVMs performed).
    pub iterations: usize,
    /// Whether the stopping tolerance was reached.
    pub converged: bool,
    /// Max-over-shifts relative residual after each iteration (Fig. 2 left).
    pub residual_history: Vec<f64>,
    /// Σ over iterations of the *active* (unconverged) shift count — the
    /// per-shift recurrence work actually performed. Without freezing this
    /// would be `iterations × Q`; the single-vector analogue of the block
    /// solver's `column_work`.
    pub shift_work: usize,
}

/// Per-shift recurrence state.
struct ShiftState {
    /// previous two Givens rotations
    c1: f64,
    s1: f64,
    c2: f64,
    s2: f64,
    /// running rhs component; |phi_bar| is the absolute residual
    phi_bar: f64,
    /// search directions d_{k-1}, d_{k-2}
    d_prev: Vec<f64>,
    d_prev2: Vec<f64>,
    /// current solution
    x: Vec<f64>,
    /// frozen once converged
    done: bool,
}

impl ShiftState {
    fn new(n: usize, beta1: f64) -> ShiftState {
        ShiftState {
            c1: 1.0,
            s1: 0.0,
            c2: 1.0,
            s2: 0.0,
            phi_bar: beta1,
            d_prev: vec![0.0; n],
            d_prev2: vec![0.0; n],
            x: vec![0.0; n],
            done: false,
        }
    }

    /// Advance one MINRES step given this iteration's Lanczos scalars and
    /// vector. `beta_k` couples v_{k-1},v_k (0 at k=1); `beta_next` is the
    /// new subdiagonal.
    #[inline]
    fn step(&mut self, shift: f64, alpha: f64, beta_k: f64, beta_next: f64, v: &[f64]) {
        let eps = self.s2 * beta_k;
        let delta_bar = self.c2 * beta_k;
        let a = alpha + shift;
        let delta = self.c1 * delta_bar + self.s1 * a;
        let gamma_bar = -self.s1 * delta_bar + self.c1 * a;
        let gamma = (gamma_bar * gamma_bar + beta_next * beta_next).sqrt();
        // Givens zeroing beta_next; guard breakdown (gamma == 0 happens only
        // for exactly-singular shifted systems, impossible for t > 0 SPD).
        let (c, s) = if gamma > 0.0 { (gamma_bar / gamma, beta_next / gamma) } else { (1.0, 0.0) };
        let tau = c * self.phi_bar;
        self.phi_bar = -s * self.phi_bar;
        // d_k = (v_k - delta d_{k-1} - eps d_{k-2}) / gamma
        // then x += tau d_k. Reuse d_prev2's buffer as the new direction.
        let inv_gamma = if gamma > 0.0 { 1.0 / gamma } else { 0.0 };
        for i in 0..v.len() {
            let d_new = (v[i] - delta * self.d_prev[i] - eps * self.d_prev2[i]) * inv_gamma;
            self.d_prev2[i] = d_new; // temporarily stash
            self.x[i] += tau * d_new;
        }
        std::mem::swap(&mut self.d_prev, &mut self.d_prev2);
        // after swap: d_prev = d_new, d_prev2 = old d_prev  ✓
        self.c2 = self.c1;
        self.s2 = self.s1;
        self.c1 = c;
        self.s1 = s;
    }

    /// Retire a converged shift: mark it done and release its two `O(N)`
    /// search-direction buffers. `x` (the answer) and `phi_bar` (the frozen
    /// residual) survive; the recurrence never advances again — the
    /// single-vector analogue of the block solver retiring a column from the
    /// matmat.
    fn freeze(&mut self) {
        self.done = true;
        self.d_prev = Vec::new();
        self.d_prev2 = Vec::new();
    }
}

/// Weighted CIQ stopping rule shared by [`msminres`] and [`msminres_block`]:
/// stop when the `|w|`-weighted average relative residual falls below `tol`.
fn weighted_converged(states: &[ShiftState], ws: &[f64], beta1: f64, tol: f64) -> bool {
    let wsum: f64 = ws.iter().map(|w| w.abs()).sum();
    let wres: f64 = states
        .iter()
        .zip(ws)
        .map(|(st, w)| w.abs() * (st.phi_bar.abs() / beta1))
        .sum::<f64>()
        / wsum.max(1e-300);
    wres < tol
}

/// Run msMINRES: returns `c_q ≈ (K + t_q I)^{-1} b` for every shift `t_q`.
///
/// `shifts` must be ≥ 0 (SPD + nonnegative shifts keeps every system SPD,
/// which is what the CIQ quadrature produces — Eq. S5).
pub fn msminres(
    op: &dyn LinearOp,
    b: &[f64],
    shifts: &[f64],
    opts: &MsMinresOptions,
) -> MsMinresResult {
    let n = op.size();
    assert_eq!(b.len(), n);
    assert!(!shifts.is_empty());
    let beta1 = norm2(b);
    if beta1 == 0.0 {
        return MsMinresResult {
            solutions: vec![vec![0.0; n]; shifts.len()],
            residuals: vec![0.0; shifts.len()],
            iterations: 0,
            converged: true,
            residual_history: vec![],
            shift_work: 0,
        };
    }
    let mut states: Vec<ShiftState> = shifts.iter().map(|_| ShiftState::new(n, beta1)).collect();

    // Lanczos state
    let mut v: Vec<f64> = b.iter().map(|x| x / beta1).collect();
    let mut v_prev = vec![0.0; n];
    let mut beta_k = 0.0f64; // couples v_prev and v
    let mut iters = 0;
    let mut converged = false;
    let mut residual_history = Vec::new();
    let mut shift_work = 0usize;

    for _k in 1..=opts.max_iters {
        iters += 1;
        // Lanczos expansion
        let mut w = op.matvec(&v);
        if beta_k != 0.0 {
            axpy(-beta_k, &v_prev, &mut w);
        }
        let alpha = dot(&v, &w);
        axpy(-alpha, &v, &mut w);
        let beta_next = norm2(&w);

        // advance only the active shifts; a converged shift is frozen —
        // buffers released, recurrence never touched again
        for (q, st) in states.iter_mut().enumerate() {
            if !st.done {
                shift_work += 1;
                st.step(shifts[q], alpha, beta_k, beta_next, &v);
                if (st.phi_bar.abs() / beta1) < opts.tol {
                    st.freeze();
                }
            }
        }

        residual_history
            .push(states.iter().map(|st| st.phi_bar.abs() / beta1).fold(0.0, f64::max));

        // stopping criterion
        let stop = match &opts.weights {
            Some(ws) => weighted_converged(&states, ws, beta1, opts.tol),
            None => states.iter().all(|st| st.done),
        };
        if stop {
            converged = true;
            break;
        }
        if beta_next < 1e-13 * alpha.abs().max(1.0) {
            // Krylov space exhausted: solution is exact in the subspace.
            converged = true;
            break;
        }

        // rotate Lanczos vectors
        for i in 0..n {
            let next = w[i] / beta_next;
            v_prev[i] = v[i];
            v[i] = next;
        }
        beta_k = beta_next;
    }

    MsMinresResult {
        residuals: states.iter().map(|st| st.phi_bar.abs() / beta1).collect(),
        solutions: states.into_iter().map(|st| st.x).collect(),
        iterations: iters,
        converged,
        residual_history,
        shift_work,
    }
}

/// Result of a blocked msMINRES run ([`msminres_block`]).
#[derive(Clone, Debug)]
pub struct MsMinresBlockResult {
    /// One `n × r` matrix per shift: column `j` is `c_q ≈ (K + t_q I)^{-1} b_j`.
    pub solutions: Vec<Matrix>,
    /// Iterations executed per column (== block MVMs that column rode).
    pub col_iterations: Vec<usize>,
    /// Per-shift relative residuals at exit (max over columns), consistent
    /// with [`msminres`]'s `residuals`.
    pub residuals: Vec<f64>,
    /// Total matmat column-work: Σ over iterations of the active (unconverged)
    /// width. Without active-column compaction this would be
    /// `max(col_iterations) × r`.
    pub column_work: usize,
}

/// All per-column state of one right-hand side in the blocked solve, so a
/// converged column can be retired from the matmat in one move.
struct BlockColumn {
    /// Original column index in `b_mat`.
    index: usize,
    beta1: f64,
    v: Vec<f64>,
    v_prev: Vec<f64>,
    beta_k: f64,
    iters: usize,
    /// One recurrence per shift.
    states: Vec<ShiftState>,
    done: bool,
}

/// Block msMINRES: independent recurrences for each column of `b_mat`,
/// sharing each iteration's MVMs as a single `matmat` (the batching the
/// coordinator exploits — Fig. 2 mid/right varies this RHS count).
///
/// **Active-column compaction:** once every shift of a column converges, the
/// column is retired and the next iteration's matmat runs only over the
/// remaining unconverged columns, so per-iteration work shrinks with
/// convergence instead of staying at full width. `column_work` records the
/// matmat columns actually paid for.
pub fn msminres_block(
    op: &dyn LinearOp,
    b_mat: &Matrix,
    shifts: &[f64],
    opts: &MsMinresOptions,
) -> MsMinresBlockResult {
    let n = op.size();
    let r = b_mat.cols();
    assert_eq!(b_mat.rows(), n);
    assert!(!shifts.is_empty());

    let mut active: Vec<BlockColumn> = Vec::with_capacity(r);
    let mut finished: Vec<BlockColumn> = Vec::new();
    for j in 0..r {
        let col = b_mat.col(j);
        let beta1 = norm2(&col);
        let mut bc = BlockColumn {
            index: j,
            beta1,
            v: vec![0.0; n],
            v_prev: vec![0.0; n],
            beta_k: 0.0,
            iters: 0,
            states: shifts.iter().map(|_| ShiftState::new(n, beta1)).collect(),
            done: beta1 == 0.0,
        };
        if bc.done {
            finished.push(bc);
        } else {
            for i in 0..n {
                bc.v[i] = col[i] / beta1;
            }
            active.push(bc);
        }
    }

    let mut column_work = 0usize;
    let mut wcol = vec![0.0; n];
    // reused across iterations; re-allocated only when compaction shrinks it
    let mut vmat = Matrix::zeros(n, active.len().max(1));
    for _k in 1..=opts.max_iters {
        if active.is_empty() {
            break;
        }
        // compacted matmat: only unconverged columns ride the block MVM
        let width = active.len();
        if vmat.cols() != width {
            vmat = Matrix::zeros(n, width);
        }
        for (c, col) in active.iter().enumerate() {
            for i in 0..n {
                vmat[(i, c)] = col.v[i];
            }
        }
        let w = op.matmat(&vmat);
        column_work += width;

        for (c, col) in active.iter_mut().enumerate() {
            col.iters += 1;
            // per-column Lanczos update
            let mut alpha = 0.0;
            for i in 0..n {
                let wi = w[(i, c)] - col.beta_k * col.v_prev[i];
                wcol[i] = wi;
                alpha += col.v[i] * wi;
            }
            let mut bn2 = 0.0;
            for i in 0..n {
                let wi = wcol[i] - alpha * col.v[i];
                wcol[i] = wi;
                bn2 += wi * wi;
            }
            let beta_next = bn2.sqrt();
            let mut all_done = true;
            for (q, st) in col.states.iter_mut().enumerate() {
                if !st.done {
                    st.step(shifts[q], alpha, col.beta_k, beta_next, &col.v);
                    if (st.phi_bar.abs() / col.beta1) < opts.tol {
                        // same freeze as the single-vector path: drop the
                        // shift's direction buffers the moment it converges
                        st.freeze();
                    }
                }
                all_done &= st.done;
            }
            // same stopping criterion as `msminres`: weighted residual when
            // CIQ weights are supplied, all-shifts-done otherwise
            let stop = match &opts.weights {
                Some(ws) => weighted_converged(&col.states, ws, col.beta1, opts.tol),
                None => all_done,
            };
            if stop || beta_next < 1e-13 * alpha.abs().max(1.0) {
                col.done = true;
                continue;
            }
            for i in 0..n {
                col.v_prev[i] = col.v[i];
                col.v[i] = wcol[i] / beta_next;
            }
            col.beta_k = beta_next;
        }

        // retire converged columns so the next matmat shrinks
        if active.iter().any(|c| c.done) {
            let mut still = Vec::with_capacity(active.len());
            for col in active {
                if col.done {
                    finished.push(col);
                } else {
                    still.push(col);
                }
            }
            active = still;
        }
    }
    finished.append(&mut active);

    let mut solutions: Vec<Matrix> = (0..shifts.len()).map(|_| Matrix::zeros(n, r)).collect();
    let mut residuals = vec![0.0f64; shifts.len()];
    let mut col_iterations = vec![0usize; r];
    for col in &finished {
        col_iterations[col.index] = col.iters;
        for (q, st) in col.states.iter().enumerate() {
            for i in 0..n {
                solutions[q][(i, col.index)] = st.x[i];
            }
            if col.beta1 > 0.0 {
                residuals[q] = residuals[q].max(st.phi_bar.abs() / col.beta1);
            }
        }
    }
    MsMinresBlockResult { solutions, col_iterations, residuals, column_work }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Cholesky, Matrix};
    use crate::operators::DenseOp;
    use crate::rng::Pcg64;
    use crate::util::rel_err;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64 * 0.1;
        }
        k
    }

    #[test]
    fn solves_all_shifts() {
        let n = 50;
        let k = random_spd(n, 1);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(2);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let shifts = [0.0, 0.1, 1.0, 10.0, 100.0];
        let opts = MsMinresOptions { max_iters: 200, tol: 1e-10, weights: None };
        let res = msminres(&op, &b, &shifts, &opts);
        assert!(res.converged);
        for (q, &t) in shifts.iter().enumerate() {
            let mut kt = k.clone();
            for i in 0..n {
                kt[(i, i)] += t;
            }
            let exact = Cholesky::new(&kt).unwrap().solve(&b);
            let err = rel_err(&res.solutions[q], &exact);
            assert!(err < 1e-7, "shift {t}: rel err {err}");
        }
    }

    #[test]
    fn one_mvm_per_iteration_counts() {
        // iteration count should be far below N for well-conditioned K
        let n = 120;
        let mut k = Matrix::eye(n);
        for i in 0..n {
            k[(i, i)] = 1.0 + 0.1 * (i as f64 / n as f64); // kappa ≈ 1.1
        }
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(3);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let res = msminres(&op, &b, &[0.0, 1.0], &MsMinresOptions::default());
        assert!(res.converged);
        assert!(res.iterations < 25, "iterations {}", res.iterations);
    }

    #[test]
    fn higher_shifts_converge_faster() {
        let n = 60;
        let k = random_spd(n, 4);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(5);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = MsMinresOptions { max_iters: 30, tol: 1e-14, weights: None };
        let res = msminres(&op, &b, &[0.0, 50.0], &opts);
        assert!(
            res.residuals[1] <= res.residuals[0] + 1e-12,
            "shifted residual {} should be <= unshifted {}",
            res.residuals[1],
            res.residuals[0]
        );
    }

    #[test]
    fn converged_shifts_freeze_without_extra_mvms() {
        // Heavily-shifted systems converge in a handful of iterations while
        // the unshifted one grinds on; freezing must (a) keep the MVM count
        // at exactly one per iteration (CountingOp), (b) spend strictly less
        // per-shift recurrence work than iterations × Q, and (c) leave every
        // solution as accurate as the Cholesky oracle.
        let n = 60;
        let k = random_spd(n, 40);
        let op = crate::operators::CountingOp::new(DenseOp::new(k.clone()));
        let mut rng = Pcg64::seeded(41);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let shifts = [0.0, 1e3, 1e5];
        let opts = MsMinresOptions { max_iters: 300, tol: 1e-10, weights: None };
        let res = msminres(&op, &b, &shifts, &opts);
        assert!(res.converged);
        assert_eq!(
            op.matvec_count(),
            res.iterations as u64,
            "freezing must not change the one-MVM-per-iteration property"
        );
        assert!(
            res.shift_work < res.iterations * shifts.len(),
            "no shift was ever frozen: shift_work {} vs full {}",
            res.shift_work,
            res.iterations * shifts.len()
        );
        for (q, &t) in shifts.iter().enumerate() {
            let mut kt = k.clone();
            for i in 0..n {
                kt[(i, i)] += t;
            }
            let exact = Cholesky::new(&kt).unwrap().solve(&b);
            let err = rel_err(&res.solutions[q], &exact);
            assert!(err < 1e-7, "shift {t}: frozen solution drifted, rel err {err}");
        }
    }

    #[test]
    fn residual_tracker_matches_true_residual() {
        let n = 40;
        let k = random_spd(n, 6);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(7);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = MsMinresOptions { max_iters: 17, tol: 1e-30, weights: None };
        let res = msminres(&op, &b, &[0.5], &opts);
        let mut kt = k.clone();
        for i in 0..n {
            kt[(i, i)] += 0.5;
        }
        let r_true = {
            let kx = kt.matvec(&res.solutions[0]);
            let diff: Vec<f64> = kx.iter().zip(&b).map(|(a, c)| a - c).collect();
            crate::util::norm2(&diff) / crate::util::norm2(&b)
        };
        assert!(
            (res.residuals[0] - r_true).abs() < 1e-8 * (1.0 + r_true),
            "tracked {} vs true {r_true}",
            res.residuals[0]
        );
    }

    #[test]
    fn block_version_matches_single() {
        let n = 35;
        let k = random_spd(n, 8);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(9);
        let b = Matrix::randn(n, 3, &mut rng);
        let shifts = [0.1, 2.0];
        let opts = MsMinresOptions { max_iters: 150, tol: 1e-10, weights: None };
        let res = msminres_block(&op, &b, &shifts, &opts);
        for j in 0..3 {
            let col = b.col(j);
            let single = msminres(&op, &col, &shifts, &opts);
            for q in 0..2 {
                let blocked = res.solutions[q].col(j);
                let err = rel_err(&blocked, &single.solutions[q]);
                assert!(err < 1e-8, "col {j} shift {q}: {err}");
            }
        }
        assert!(res.col_iterations.iter().all(|&it| it > 0));
    }

    #[test]
    fn block_residuals_are_per_shift() {
        // Regression: the block solver used to collapse residuals to a single
        // max over all shifts; they must be per-shift (max over columns),
        // consistent with `msminres`.
        let n = 50;
        let k = random_spd(n, 21);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(22);
        let b = Matrix::randn(n, 2, &mut rng);
        let shifts = [0.0, 50.0];
        // stop well before convergence so residuals are distinguishable
        let opts = MsMinresOptions { max_iters: 8, tol: 1e-30, weights: None };
        let res = msminres_block(&op, &b, &shifts, &opts);
        let mut expect = vec![0.0f64; shifts.len()];
        for j in 0..2 {
            let single = msminres(&op, &b.col(j), &shifts, &opts);
            for q in 0..shifts.len() {
                expect[q] = expect[q].max(single.residuals[q]);
            }
        }
        for q in 0..shifts.len() {
            let d = (res.residuals[q] - expect[q]).abs();
            assert!(d < 1e-6 * (1.0 + expect[q]), "shift {q}: block {} vs single {}", res.residuals[q], expect[q]);
        }
        assert!(
            res.residuals[1] < res.residuals[0],
            "heavily shifted system must show the smaller residual ({} vs {}) — collapsed max?",
            res.residuals[1],
            res.residuals[0]
        );
    }

    #[test]
    fn compaction_shrinks_column_work_on_heterogeneous_batch() {
        // Column 0 is an eigenvector (its Krylov space is 1-dimensional, so it
        // converges on the first iteration); columns 1–3 are random and need
        // tens of iterations. Compaction must retire column 0 from the matmat
        // immediately, keeping total column-work strictly below
        // `max_iterations × columns`.
        let n = 40;
        let mut k = Matrix::zeros(n, n);
        for i in 0..n {
            k[(i, i)] = 1.0 + i as f64;
        }
        // assert on the matmat columns the operator *actually served*, not
        // the solver's own (derivable) counter
        let op = crate::operators::CountingOp::new(DenseOp::new(k));
        let mut rng = Pcg64::seeded(11);
        let mut b = Matrix::zeros(n, 4);
        b[(0, 0)] = 1.0;
        for j in 1..4 {
            for i in 0..n {
                b[(i, j)] = rng.normal();
            }
        }
        let opts = MsMinresOptions { max_iters: 200, tol: 1e-10, weights: None };
        let res = msminres_block(&op, &b, &[0.1, 1.0], &opts);
        let max_iters = *res.col_iterations.iter().max().unwrap();
        assert_eq!(res.col_iterations[0], 1, "eigenvector column should converge immediately");
        assert!(max_iters > 1, "random columns should need several iterations");
        let served = op.matmat_col_count() as usize;
        assert!(
            served < max_iters * 4,
            "matmat width never shrank: operator served {served} columns vs uncompacted {}",
            max_iters * 4
        );
        assert_eq!(served, res.column_work, "column_work must report the served matmat columns");
    }

    #[test]
    fn property_block_compacted_matches_single_columns() {
        crate::util::proptest::check_default("block msminres == per-column msminres", |rng, _| {
            let n = 10 + rng.below(12);
            let r = 1 + rng.below(4);
            let a = Matrix::randn(n, n, rng);
            let mut k = a.matmul(&a.transpose());
            for i in 0..n {
                k[(i, i)] += n as f64;
            }
            let op = DenseOp::new(k);
            let b = Matrix::randn(n, r, rng);
            let shifts = [0.05 + rng.uniform(), 5.0 + rng.uniform() * 20.0];
            let opts = MsMinresOptions { max_iters: 300, tol: 1e-11, weights: None };
            let blk = msminres_block(&op, &b, &shifts, &opts);
            for j in 0..r {
                let single = msminres(&op, &b.col(j), &shifts, &opts);
                for q in 0..shifts.len() {
                    let err = rel_err(&blk.solutions[q].col(j), &single.solutions[q]);
                    crate::prop_assert!(err < 1e-6, "col {j} shift {q}: err {err}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn block_weighted_stop_terminates_no_later_than_per_shift() {
        // With CIQ weights the block solver must use the same weighted-average
        // stopping rule as `msminres`, which fires no later than (and usually
        // before) the all-shifts-done rule when shifts converge at different
        // rates.
        let n = 50;
        let k = random_spd(n, 25);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(26);
        let b = Matrix::randn(n, 2, &mut rng);
        let shifts = [0.01, 100.0];
        let opts_w = MsMinresOptions { max_iters: 400, tol: 1e-8, weights: Some(vec![1.0, 1.0]) };
        let opts_u = MsMinresOptions { max_iters: 400, tol: 1e-8, weights: None };
        let rw = msminres_block(&op, &b, &shifts, &opts_w);
        let ru = msminres_block(&op, &b, &shifts, &opts_u);
        for j in 0..2 {
            assert!(
                rw.col_iterations[j] <= ru.col_iterations[j],
                "col {j}: weighted {} > unweighted {}",
                rw.col_iterations[j],
                ru.col_iterations[j]
            );
        }
        assert!(
            rw.col_iterations.iter().zip(&ru.col_iterations).any(|(a, b)| a < b),
            "weighted stop never engaged: {:?} vs {:?}",
            rw.col_iterations,
            ru.col_iterations
        );
    }

    #[test]
    fn block_zero_column_short_circuits() {
        let n = 20;
        let k = random_spd(n, 30);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(31);
        let mut b = Matrix::zeros(n, 2);
        for i in 0..n {
            b[(i, 1)] = rng.normal();
        }
        let opts = MsMinresOptions { max_iters: 100, tol: 1e-9, weights: None };
        let res = msminres_block(&op, &b, &[0.0, 1.0], &opts);
        assert_eq!(res.col_iterations[0], 0);
        assert!(res.col_iterations[1] > 0);
        assert!(res.solutions[0].col(0).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn zero_rhs_short_circuits() {
        let op = DenseOp::new(Matrix::eye(10));
        let res = msminres(&op, &vec![0.0; 10], &[0.0, 1.0], &MsMinresOptions::default());
        assert!(res.converged);
        assert_eq!(res.iterations, 0);
        assert!(res.solutions[0].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn property_msminres_equals_minres_per_shift() {
        crate::util::proptest::check_default("msminres == per-shift solves", |rng, _| {
            let n = 12 + rng.below(10);
            let a = Matrix::randn(n, n, rng);
            let mut k = a.matmul(&a.transpose());
            for i in 0..n {
                k[(i, i)] += n as f64;
            }
            let op = DenseOp::new(k.clone());
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let shifts = [rng.uniform() * 5.0, 10.0 + rng.uniform() * 50.0];
            let opts = MsMinresOptions { max_iters: 300, tol: 1e-11, weights: None };
            let multi = msminres(&op, &b, &shifts, &opts);
            for (q, &t) in shifts.iter().enumerate() {
                let mut kt = k.clone();
                for i in 0..n {
                    kt[(i, i)] += t;
                }
                let exact = Cholesky::new(&kt).unwrap().solve(&b);
                let err = rel_err(&multi.solutions[q], &exact);
                crate::prop_assert!(err < 1e-6, "shift {t}: err {err}");
            }
            Ok(())
        });
    }
}

//! Multi-shift MINRES (Alg. 4 of the paper).
//!
//! Solves all `Q` shifted systems `(K + t_q I) c_q = b` simultaneously from a
//! *single* Krylov subspace: one MVM per iteration regardless of `Q`,
//! exploiting the shift invariance `K_J(K, b) = K_J(K + tI, b)` (Obs. 1).
//! Per shift, the tridiagonal QR is updated with Givens rotations and the
//! solution advances through a three-term "search direction" recurrence, so
//! total extra storage is `O(QN)` (Property 1).
//!
//! ## Workspace entry points
//!
//! The engines are [`msminres_in`] / [`msminres_block_in`]: every O(N) and
//! O(N·r) buffer — the `Q` shift recurrences, the Lanczos vectors, the
//! compacted block panels, even the returned solutions — is a slab drawn
//! from a caller-supplied [`SolveWorkspace`], and the per-iteration MVMs run
//! through [`LinearOp::matvec_in`] / [`LinearOp::matmat_in`]. A warmed
//! workspace therefore makes the steady-state solve **allocation-free**
//! (pinned by the `alloc_regression` integration tests with a counting
//! global allocator). [`msminres`] / [`msminres_block`] keep their original
//! signatures as thin wrappers that own a transient workspace, so no caller
//! breaks and results are bit-for-bit those of the `_in` engines.

use crate::linalg::mixed::RefineConfig;
use crate::linalg::{Matrix, SolveWorkspace};
use crate::obs::trace::EventKind;
use crate::operators::{LinearOp, MixedOp};
use crate::util::{axpy, dot, norm2};

/// Options for [`msminres`].
#[derive(Clone, Debug)]
pub struct MsMinresOptions {
    /// Maximum iterations `J`.
    pub max_iters: usize,
    /// Relative-residual stopping tolerance (per shift).
    pub tol: f64,
    /// Optional CIQ weights: when set, stop on the *weighted* residual
    /// `Σ_q |w_q|·res_q / Σ_q |w_q|` instead of the max over shifts.
    pub weights: Option<Vec<f64>>,
}

impl Default for MsMinresOptions {
    fn default() -> Self {
        MsMinresOptions { max_iters: 400, tol: 1e-4, weights: None }
    }
}

/// Result of a (multi-shift) MINRES run.
#[derive(Clone, Debug)]
pub struct MsMinresResult {
    /// One solution vector per shift: `c_q ≈ (K + t_q I)^{-1} b`.
    pub solutions: Vec<Vec<f64>>,
    /// Relative residuals per shift at exit.
    pub residuals: Vec<f64>,
    /// Iterations executed (= MVMs performed).
    pub iterations: usize,
    /// Whether the stopping tolerance was reached.
    pub converged: bool,
    /// Max-over-shifts relative residual after each iteration (Fig. 2 left).
    pub residual_history: Vec<f64>,
    /// Σ over iterations of the *active* (unconverged) shift count — the
    /// per-shift recurrence work actually performed. Without freezing this
    /// would be `iterations × Q`; the single-vector analogue of the block
    /// solver's `column_work`.
    pub shift_work: usize,
}

/// Workspace-backed result of [`msminres_in`]: every buffer came from the
/// caller's [`SolveWorkspace`] — hand them back with
/// [`MsMinresSolve::recycle`] once consumed so the next solve stays
/// allocation-free.
#[derive(Debug)]
pub struct MsMinresSolve {
    /// `Q × n` row-major matrix whose row `q` is the contiguous solution
    /// `c_q ≈ (K + t_q I)^{-1} b`.
    pub solutions: Matrix,
    /// Relative residuals per shift at exit (len `Q`).
    pub residuals: Vec<f64>,
    /// Iterations executed (= MVMs performed).
    pub iterations: usize,
    /// Whether the stopping tolerance was reached.
    pub converged: bool,
    /// Max-over-shifts relative residual after each iteration.
    pub residual_history: Vec<f64>,
    /// Active-shift recurrence work (see [`MsMinresResult::shift_work`]).
    pub shift_work: usize,
}

impl MsMinresSolve {
    /// Return every buffer to the workspace.
    pub fn recycle(self, ws: &mut SolveWorkspace) {
        ws.give_mat(self.solutions);
        ws.give_vec(self.residuals);
        ws.give_vec(self.residual_history);
    }
}

/// Per-(column,shift) recurrence scalars, stored `SC` to a slab row:
/// the two previous Givens rotations, the running rhs component (|phi| is
/// the absolute residual), a done flag, and the parity selecting which half
/// of the direction slab currently holds `d_{k-1}`.
const SC: usize = 8;
const SC_C1: usize = 0;
const SC_S1: usize = 1;
const SC_C2: usize = 2;
const SC_S2: usize = 3;
const SC_PHI: usize = 4;
const SC_DONE: usize = 5;
const SC_PAR: usize = 6;

#[inline]
fn sc_init(sc: &mut [f64], beta1: f64) {
    sc[SC_C1] = 1.0;
    sc[SC_S1] = 0.0;
    sc[SC_C2] = 1.0;
    sc[SC_S2] = 0.0;
    sc[SC_PHI] = beta1;
    sc[SC_DONE] = 0.0;
    sc[SC_PAR] = 0.0;
    sc[7] = 0.0;
}

/// Advance one shift's MINRES step given this iteration's Lanczos scalars
/// and vector. `beta_k` couples v_{k-1},v_k (0 at k=1); `beta_next` is the
/// new subdiagonal. `dirs` holds the shift's two `O(N)` search directions as
/// halves of one `2n` slab; `SC_PAR` selects which half is `d_{k-1}`, the
/// new direction overwrites `d_{k-2}`'s half, and parity flips — the slab
/// equivalent of the old owned-buffer swap, byte-for-byte the same numerics.
#[inline]
fn shift_step(
    sc: &mut [f64],
    shift: f64,
    alpha: f64,
    beta_k: f64,
    beta_next: f64,
    v: &[f64],
    dirs: &mut [f64],
    x: &mut [f64],
) {
    let n = v.len();
    let eps = sc[SC_S2] * beta_k;
    let delta_bar = sc[SC_C2] * beta_k;
    let a = alpha + shift;
    let delta = sc[SC_C1] * delta_bar + sc[SC_S1] * a;
    let gamma_bar = -sc[SC_S1] * delta_bar + sc[SC_C1] * a;
    let gamma = (gamma_bar * gamma_bar + beta_next * beta_next).sqrt();
    // Givens zeroing beta_next; guard breakdown (gamma == 0 happens only
    // for exactly-singular shifted systems, impossible for t > 0 SPD).
    let (c, s) = if gamma > 0.0 { (gamma_bar / gamma, beta_next / gamma) } else { (1.0, 0.0) };
    let tau = c * sc[SC_PHI];
    sc[SC_PHI] = -s * sc[SC_PHI];
    // d_k = (v_k - delta d_{k-1} - eps d_{k-2}) / gamma, then x += tau d_k.
    let inv_gamma = if gamma > 0.0 { 1.0 / gamma } else { 0.0 };
    let (half_a, half_b) = dirs.split_at_mut(n);
    let (d_prev, d_new_buf) =
        if sc[SC_PAR] == 0.0 { (half_a, half_b) } else { (half_b, half_a) };
    for i in 0..n {
        let d_new = (v[i] - delta * d_prev[i] - eps * d_new_buf[i]) * inv_gamma;
        d_new_buf[i] = d_new;
        x[i] += tau * d_new;
    }
    sc[SC_PAR] = 1.0 - sc[SC_PAR];
    sc[SC_C2] = sc[SC_C1];
    sc[SC_S2] = sc[SC_S1];
    sc[SC_C1] = c;
    sc[SC_S1] = s;
}

/// Weighted CIQ stopping rule shared by [`msminres_in`] and
/// [`msminres_block_in`]: stop when the `|w|`-weighted average relative
/// residual over one column's `nq` shift records falls below `tol`.
fn weighted_converged(sc: &[f64], nq: usize, weights: &[f64], beta1: f64, tol: f64) -> bool {
    let wsum: f64 = weights.iter().map(|w| w.abs()).sum();
    let wres: f64 = (0..nq)
        .map(|q| weights[q].abs() * (sc[q * SC + SC_PHI].abs() / beta1))
        .sum::<f64>()
        / wsum.max(1e-300);
    wres < tol
}

/// Run msMINRES: returns `c_q ≈ (K + t_q I)^{-1} b` for every shift `t_q`.
///
/// `shifts` must be ≥ 0 (SPD + nonnegative shifts keeps every system SPD,
/// which is what the CIQ quadrature produces — Eq. S5).
///
/// Thin wrapper over [`msminres_in`] with a transient workspace; results are
/// bit-for-bit those of the workspace engine.
pub fn msminres(
    op: &dyn LinearOp,
    b: &[f64],
    shifts: &[f64],
    opts: &MsMinresOptions,
) -> MsMinresResult {
    let mut ws = SolveWorkspace::new();
    let sol = msminres_in(&mut ws, op, b, shifts, opts);
    let solutions = (0..shifts.len()).map(|q| sol.solutions.row(q).to_vec()).collect();
    MsMinresResult {
        solutions,
        residuals: sol.residuals,
        iterations: sol.iterations,
        converged: sol.converged,
        residual_history: sol.residual_history,
        shift_work: sol.shift_work,
    }
}

/// Workspace engine behind [`msminres`]: all state lives in slabs drawn from
/// `ws`, MVMs run through [`LinearOp::matvec_in`], and a warmed workspace
/// makes the whole solve allocation-free. The returned buffers belong to
/// `ws` — recycle them ([`MsMinresSolve::recycle`]) when done.
pub fn msminres_in(
    ws: &mut SolveWorkspace,
    op: &dyn LinearOp,
    b: &[f64],
    shifts: &[f64],
    opts: &MsMinresOptions,
) -> MsMinresSolve {
    let n = op.size();
    assert_eq!(b.len(), n);
    assert!(!shifts.is_empty());
    let nq = shifts.len();
    if let Some(w) = &opts.weights {
        assert_eq!(w.len(), nq, "msminres: weights must match the shift count");
    }
    let cp = ws.checkpoint();
    let beta1 = norm2(b);
    if beta1 == 0.0 {
        return MsMinresSolve {
            solutions: ws.take_mat(nq, n),
            residuals: ws.take_vec(nq),
            iterations: 0,
            converged: true,
            residual_history: ws.take_vec(0),
            shift_work: 0,
        };
    }
    // 1-in-N residual-trajectory sampling (`obs/solvetrace`): the decision
    // is one relaxed load when sampling is off, and the history below is
    // computed regardless — a sampled solve costs one strided copy at exit.
    let sampled = crate::obs::solvetrace::should_sample();

    // state slabs (all zeroed by the workspace)
    let mut sc = ws.take_vec(nq * SC);
    for q in 0..nq {
        sc_init(&mut sc[q * SC..(q + 1) * SC], beta1);
    }
    let mut dirs = ws.take_vec(nq * 2 * n);
    let mut xs = ws.take_mat(nq, n); // row q = solution for shift q
    let mut v = ws.take_vec(n);
    for i in 0..n {
        v[i] = b[i] / beta1;
    }
    let mut v_prev = ws.take_vec(n);
    let mut w = ws.take_vec(n);
    let mut history = ws.take_vec(opts.max_iters);

    let mut beta_k = 0.0f64; // couples v_prev and v
    let mut iters = 0usize;
    let mut converged = false;
    let mut shift_work = 0usize;

    for _k in 1..=opts.max_iters {
        iters += 1;
        // Lanczos expansion
        op.matvec_in(ws, &v, &mut w);
        if beta_k != 0.0 {
            axpy(-beta_k, &v_prev, &mut w);
        }
        let alpha = dot(&v, &w);
        axpy(-alpha, &v, &mut w);
        let beta_next = norm2(&w);

        // advance only the active shifts; a converged shift is frozen —
        // its recurrence is never touched again
        for q in 0..nq {
            let base = q * SC;
            if sc[base + SC_DONE] == 0.0 {
                shift_work += 1;
                shift_step(
                    &mut sc[base..base + SC],
                    shifts[q],
                    alpha,
                    beta_k,
                    beta_next,
                    &v,
                    &mut dirs[q * 2 * n..(q + 1) * 2 * n],
                    xs.row_mut(q),
                );
                if (sc[base + SC_PHI].abs() / beta1) < opts.tol {
                    sc[base + SC_DONE] = 1.0;
                }
            }
        }

        history[iters - 1] =
            (0..nq).map(|q| sc[q * SC + SC_PHI].abs() / beta1).fold(0.0, f64::max);

        // stopping criterion
        let stop = match &opts.weights {
            Some(wq) => weighted_converged(&sc, nq, wq, beta1, opts.tol),
            None => (0..nq).all(|q| sc[q * SC + SC_DONE] != 0.0),
        };
        if stop {
            converged = true;
            break;
        }
        if beta_next < 1e-13 * alpha.abs().max(1.0) {
            // Krylov space exhausted: solution is exact in the subspace.
            converged = true;
            break;
        }

        // rotate Lanczos vectors
        for i in 0..n {
            let next = w[i] / beta_next;
            v_prev[i] = v[i];
            v[i] = next;
        }
        beta_k = beta_next;
    }

    history.truncate(iters);
    if sampled {
        crate::obs::solvetrace::submit(&history, iters, 1, opts.tol);
    }
    let mut residuals = ws.take_vec(nq);
    for q in 0..nq {
        residuals[q] = sc[q * SC + SC_PHI].abs() / beta1;
    }
    ws.give_vec(sc);
    ws.give_vec(dirs);
    ws.give_vec(v);
    ws.give_vec(v_prev);
    ws.give_vec(w);
    debug_assert_eq!(
        ws.leaked_since(&cp),
        3,
        "msminres_in must keep exactly solutions + residuals + history checked out"
    );
    MsMinresSolve {
        solutions: xs,
        residuals,
        iterations: iters,
        converged,
        residual_history: history,
        shift_work,
    }
}

/// Result of a blocked msMINRES run ([`msminres_block`]).
#[derive(Clone, Debug)]
pub struct MsMinresBlockResult {
    /// One `n × r` matrix per shift: column `j` is `c_q ≈ (K + t_q I)^{-1} b_j`.
    pub solutions: Vec<Matrix>,
    /// Iterations executed per column (== block MVMs that column rode).
    pub col_iterations: Vec<usize>,
    /// Per-shift relative residuals at exit (max over columns), consistent
    /// with [`msminres`]'s `residuals`.
    pub residuals: Vec<f64>,
    /// Total matmat column-work: Σ over iterations of the active (unconverged)
    /// width. Without active-column compaction this would be
    /// `max(col_iterations) × r`.
    pub column_work: usize,
}

/// Workspace-backed result of [`msminres_block_in`] — recycle via
/// [`MsMinresBlockSolve::recycle`] once consumed.
#[derive(Debug)]
pub struct MsMinresBlockSolve {
    /// `(r·Q) × n` row-major matrix: row `j·Q + q` is the contiguous
    /// solution for RHS column `j` under shift `q`.
    pub solutions: Matrix,
    /// Iterations executed per original column.
    pub col_iterations: Vec<usize>,
    /// Per-shift relative residuals (max over columns).
    pub residuals: Vec<f64>,
    /// Matmat column-work actually paid (see
    /// [`MsMinresBlockResult::column_work`]).
    pub column_work: usize,
}

impl MsMinresBlockSolve {
    /// Return every buffer to the workspace.
    pub fn recycle(self, ws: &mut SolveWorkspace) {
        ws.give_mat(self.solutions);
        ws.give_usize(self.col_iterations);
        ws.give_vec(self.residuals);
    }
}

/// Block msMINRES: independent recurrences for each column of `b_mat`,
/// sharing each iteration's MVMs as a single `matmat` (the batching the
/// coordinator exploits — Fig. 2 mid/right varies this RHS count).
///
/// **Active-column compaction:** once every shift of a column converges, the
/// column is retired and the next iteration's matmat runs only over the
/// remaining unconverged columns, so per-iteration work shrinks with
/// convergence instead of staying at full width. `column_work` records the
/// matmat columns actually paid for.
///
/// Thin wrapper over [`msminres_block_in`] with a transient workspace.
pub fn msminres_block(
    op: &dyn LinearOp,
    b_mat: &Matrix,
    shifts: &[f64],
    opts: &MsMinresOptions,
) -> MsMinresBlockResult {
    let mut ws = SolveWorkspace::new();
    let blk = msminres_block_in(&mut ws, op, b_mat, shifts, opts);
    let (n, r, nq) = (op.size(), b_mat.cols(), shifts.len());
    let MsMinresBlockSolve { solutions: sols, col_iterations, residuals, column_work } = blk;
    let mut solutions: Vec<Matrix> = (0..nq).map(|_| Matrix::zeros(n, r)).collect();
    for j in 0..r {
        for (q, sol) in solutions.iter_mut().enumerate() {
            let row = sols.row(j * nq + q);
            for i in 0..n {
                sol[(i, j)] = row[i];
            }
        }
    }
    MsMinresBlockResult { solutions, col_iterations, residuals, column_work }
}

/// Workspace engine behind [`msminres_block`]: per-column Lanczos vectors,
/// the `r × Q` shift recurrences, the compacted MVM panels, and the returned
/// solutions all live in `ws` slabs; the shared per-iteration MVM runs
/// through [`LinearOp::matmat_in`]. Warmed workspace ⇒ zero heap
/// allocations for the whole solve.
pub fn msminres_block_in(
    ws: &mut SolveWorkspace,
    op: &dyn LinearOp,
    b_mat: &Matrix,
    shifts: &[f64],
    opts: &MsMinresOptions,
) -> MsMinresBlockSolve {
    let n = op.size();
    let r = b_mat.cols();
    assert_eq!(b_mat.rows(), n);
    assert!(!shifts.is_empty());
    let nq = shifts.len();
    if let Some(w) = &opts.weights {
        assert_eq!(w.len(), nq, "msminres_block: weights must match the shift count");
    }
    let cp = ws.checkpoint();

    // per-(column,shift) recurrence state + per-column Lanczos state
    let mut sc = ws.take_vec(r * nq * SC);
    let mut dirs = ws.take_vec(r * nq * 2 * n);
    let mut xs = ws.take_mat(r * nq, n); // row j*nq+q = solution (j, q)
    let mut lanc = ws.take_vec(r * 2 * n); // per column: [v | v_prev]
    let mut beta1s = ws.take_vec(r);
    let mut beta_ks = ws.take_vec(r);
    let mut iters = ws.take_usize(r);
    let mut cdone = ws.take_usize(r); // 1 once a column retired
    let mut active = ws.take_usize(r); // active original-column indices
    let mut wcol = ws.take_vec(n);

    let mut nactive = 0usize;
    for j in 0..r {
        let mut sum = 0.0;
        for i in 0..n {
            let x = b_mat[(i, j)];
            sum += x * x;
        }
        let beta1 = sum.sqrt();
        beta1s[j] = beta1;
        for q in 0..nq {
            sc_init(&mut sc[(j * nq + q) * SC..(j * nq + q + 1) * SC], beta1);
        }
        if beta1 > 0.0 {
            let vcol = &mut lanc[j * 2 * n..j * 2 * n + n];
            for i in 0..n {
                vcol[i] = b_mat[(i, j)] / beta1;
            }
            active[nactive] = j;
            nactive += 1;
        } else {
            cdone[j] = 1; // zero RHS short-circuits with iters = 0
        }
    }
    active.truncate(nactive);

    let mut column_work = 0usize;
    // 1-in-N residual-trajectory sampling (`obs/solvetrace`): the block path
    // tracks no history normally, so the slab is pooled workspace scratch
    // taken only on sampled solves and returned before exit — the zero-alloc
    // steady state and the bit-for-bit owned/_in equivalence are unchanged.
    let sampled = nactive > 0 && crate::obs::solvetrace::should_sample();
    let mut hist = if sampled { Some(ws.take_vec(opts.max_iters)) } else { None };
    let mut hist_len = 0usize;
    // reused across iterations; swapped for narrower pooled panels when
    // compaction shrinks the active width
    let mut vmat = ws.take_mat(n, nactive.max(1));
    let mut wmat = ws.take_mat(n, nactive.max(1));

    for _k in 1..=opts.max_iters {
        if active.is_empty() {
            break;
        }
        // compacted matmat: only unconverged columns ride the block MVM
        let width = active.len();
        if vmat.cols() != width {
            ws.give_mat(vmat);
            ws.give_mat(wmat);
            vmat = ws.take_mat(n, width);
            wmat = ws.take_mat(n, width);
        }
        for (c, &j) in active.iter().enumerate() {
            let vcol = &lanc[j * 2 * n..j * 2 * n + n];
            for i in 0..n {
                vmat[(i, c)] = vcol[i];
            }
        }
        op.matmat_in(ws, &vmat, &mut wmat);
        column_work += width;

        let mut any_done = false;
        for pos in 0..width {
            let j = active[pos];
            iters[j] += 1;
            let beta1 = beta1s[j];
            let beta_k = beta_ks[j];
            // per-column Lanczos update
            let (vcol, vprev) = lanc[j * 2 * n..(j + 1) * 2 * n].split_at_mut(n);
            let mut alpha = 0.0;
            for i in 0..n {
                let wi = wmat[(i, pos)] - beta_k * vprev[i];
                wcol[i] = wi;
                alpha += vcol[i] * wi;
            }
            let mut bn2 = 0.0;
            for i in 0..n {
                let wi = wcol[i] - alpha * vcol[i];
                wcol[i] = wi;
                bn2 += wi * wi;
            }
            let beta_next = bn2.sqrt();
            let mut all_done = true;
            for q in 0..nq {
                let base = (j * nq + q) * SC;
                if sc[base + SC_DONE] == 0.0 {
                    shift_step(
                        &mut sc[base..base + SC],
                        shifts[q],
                        alpha,
                        beta_k,
                        beta_next,
                        vcol,
                        &mut dirs[(j * nq + q) * 2 * n..(j * nq + q + 1) * 2 * n],
                        xs.row_mut(j * nq + q),
                    );
                    if (sc[base + SC_PHI].abs() / beta1) < opts.tol {
                        // same freeze as the single-vector path: the shift's
                        // recurrence is never advanced again
                        sc[base + SC_DONE] = 1.0;
                    }
                }
                all_done &= sc[base + SC_DONE] != 0.0;
            }
            // same stopping criterion as `msminres`: weighted residual when
            // CIQ weights are supplied, all-shifts-done otherwise
            let stop = match &opts.weights {
                Some(wq) => {
                    weighted_converged(&sc[j * nq * SC..(j + 1) * nq * SC], nq, wq, beta1, opts.tol)
                }
                None => all_done,
            };
            if stop || beta_next < 1e-13 * alpha.abs().max(1.0) {
                cdone[j] = 1;
                any_done = true;
                continue;
            }
            for i in 0..n {
                vprev[i] = vcol[i];
                vcol[i] = wcol[i] / beta_next;
            }
            beta_ks[j] = beta_next;
        }

        if let Some(h) = hist.as_mut() {
            // Fig. 2 curve point: max over this iteration's active columns of
            // the per-column max-over-shifts relative residual. Computed
            // before the retire pass so a column's sub-tol terminal value
            // still lands in the trajectory.
            let mut mx = 0.0f64;
            for &j in active.iter() {
                for q in 0..nq {
                    let rr = sc[(j * nq + q) * SC + SC_PHI].abs() / beta1s[j];
                    if rr > mx {
                        mx = rr;
                    }
                }
            }
            h[hist_len] = mx;
            hist_len += 1;
        }

        // retire converged columns (stable order) so the next matmat shrinks
        if any_done {
            active.retain(|&j| cdone[j] == 0);
        }
    }

    if let Some(h) = hist.take() {
        crate::obs::solvetrace::submit(&h[..hist_len], hist_len, r, opts.tol);
        ws.give_vec(h);
    }

    // per-shift residuals: max over columns with a nonzero RHS
    let mut residuals = ws.take_vec(nq);
    for j in 0..r {
        if beta1s[j] > 0.0 {
            for (q, res) in residuals.iter_mut().enumerate() {
                let rr = sc[(j * nq + q) * SC + SC_PHI].abs() / beta1s[j];
                if rr > *res {
                    *res = rr;
                }
            }
        }
    }

    ws.give_vec(sc);
    ws.give_vec(dirs);
    ws.give_vec(lanc);
    ws.give_vec(beta1s);
    ws.give_vec(beta_ks);
    ws.give_usize(cdone);
    ws.give_usize(active);
    ws.give_vec(wcol);
    ws.give_mat(vmat);
    ws.give_mat(wmat);
    debug_assert_eq!(
        ws.leaked_since(&cp),
        3,
        "msminres_block_in must keep exactly solutions + col_iterations + residuals checked out"
    );
    MsMinresBlockSolve { solutions: xs, col_iterations: iters, residuals, column_work }
}

/// Mixed-precision block solve with f64 iterative refinement
/// (`rust/DESIGN.md` §9). Returns `(solve, refine_sweeps, precision_fallback)`.
///
/// The inner Krylov recurrence runs against the operator's f32-storage MVM
/// ([`MixedOp`]) to a tolerance floored at `cfg.inner_tol_floor` (below
/// that, the f32 forward error dominates and extra inner iterations buy
/// nothing). An outer loop then measures the **true f64 residual**
/// `r_jq = b_j − t_q·x_jq − K_{f64}·x_jq` — one stacked f64 matmat per
/// sweep, never trusting the low-precision recurrence — and re-solves the
/// corrections against the mixed operator, one single-shift block solve per
/// quadrature node (corrections break shift invariance: each shift's
/// residual is a different RHS).
///
/// Exit contract:
/// - converged: the returned `residuals` are the *true f64* per-shift
///   maxima, all `≤ opts.tol` — the same bound the pure-f64 path certifies;
/// - stagnation (the worst residual fails to shrink by `cfg.stall_ratio`)
///   or the `cfg.max_sweeps` cap: the mixed progress is discarded and the
///   whole system is re-solved in pure f64 (`precision_fallback = true`),
///   so callers never observe worse-than-f64 results.
///
/// All scratch (solution stack, residual stack, per-shift RHS) comes from
/// `ws`; a warmed workspace keeps the refined solve allocation-free.
pub fn msminres_block_refined_in(
    ws: &mut SolveWorkspace,
    op: &dyn LinearOp,
    b_mat: &Matrix,
    shifts: &[f64],
    opts: &MsMinresOptions,
    cfg: &RefineConfig,
) -> (MsMinresBlockSolve, usize, bool) {
    if !op.supports_mixed() {
        // No f32 path behind this operator: the "mixed" policy is a no-op,
        // not an error — serve the exact solve.
        return (msminres_block_in(ws, op, b_mat, shifts, opts), 0, false);
    }
    let n = op.size();
    let r = b_mat.cols();
    assert_eq!(b_mat.rows(), n);
    let nq = shifts.len();
    let mop = MixedOp(op);
    let inner_opts = MsMinresOptions {
        max_iters: opts.max_iters,
        tol: opts.tol.max(cfg.inner_tol_floor),
        weights: opts.weights.clone(),
    };
    let mut blk = msminres_block_in(ws, &mop, b_mat, shifts, &inner_opts);

    let mut beta1s = ws.take_vec(r);
    for j in 0..r {
        let mut sum = 0.0;
        for i in 0..n {
            let x = b_mat[(i, j)];
            sum += x * x;
        }
        beta1s[j] = sum.sqrt();
    }
    let mut xstack = ws.take_mat(n, r * nq);
    let mut rstack = ws.take_mat(n, r * nq);
    let mut rq = ws.take_mat(n, r);
    let mut worst_prev = f64::INFINITY;
    let mut sweeps = 0usize;
    let fallback = loop {
        // True residual check: one stacked f64 matmat over every (column,
        // shift) solution, then r ← b − t·x − Kx in place.
        for c in 0..r * nq {
            let row = blk.solutions.row(c);
            for i in 0..n {
                xstack[(i, c)] = row[i];
            }
        }
        op.matmat_in(ws, &xstack, &mut rstack);
        let mut worst = 0.0f64;
        for v in blk.residuals.iter_mut() {
            *v = 0.0;
        }
        for j in 0..r {
            for q in 0..nq {
                let c = j * nq + q;
                let mut sum = 0.0;
                for i in 0..n {
                    let ri = b_mat[(i, j)] - shifts[q] * xstack[(i, c)] - rstack[(i, c)];
                    rstack[(i, c)] = ri;
                    sum += ri * ri;
                }
                let rel = if beta1s[j] > 0.0 { sum.sqrt() / beta1s[j] } else { 0.0 };
                if rel > blk.residuals[q] {
                    blk.residuals[q] = rel;
                }
                if rel > worst {
                    worst = rel;
                }
            }
        }
        crate::trace!(EventKind::RefineSweep, sweeps, worst.to_bits());
        if worst <= opts.tol {
            break false;
        }
        if sweeps >= cfg.max_sweeps || worst > cfg.stall_ratio * worst_prev {
            // Sweep cap or stagnation (the f32 floor, or an
            // ill-conditioned system amplifying the f32 forward error
            // beyond what refinement can contract): give up on mixed.
            break true;
        }
        worst_prev = worst;
        sweeps += 1;
        // Correction sweep: per shift, re-solve (K + t_q)d = r against the
        // mixed operator and fold the corrections into the solutions.
        let corr_opts =
            MsMinresOptions { max_iters: opts.max_iters, tol: inner_opts.tol, weights: None };
        for (q, &t) in shifts.iter().enumerate() {
            for j in 0..r {
                for i in 0..n {
                    rq[(i, j)] = rstack[(i, j * nq + q)];
                }
            }
            let corr = msminres_block_in(ws, &mop, &rq, &[t], &corr_opts);
            for j in 0..r {
                axpy(1.0, corr.solutions.row(j), blk.solutions.row_mut(j * nq + q));
                blk.col_iterations[j] += corr.col_iterations[j];
            }
            blk.column_work += corr.column_work;
            corr.recycle(ws);
        }
    };
    ws.give_vec(beta1s);
    ws.give_mat(xstack);
    ws.give_mat(rstack);
    ws.give_mat(rq);
    if !fallback {
        return (blk, sweeps, false);
    }
    blk.recycle(ws);
    let blk64 = msminres_block_in(ws, op, b_mat, shifts, opts);
    (blk64, sweeps, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Cholesky, Matrix};
    use crate::operators::DenseOp;
    use crate::rng::Pcg64;
    use crate::util::rel_err;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64 * 0.1;
        }
        k
    }

    #[test]
    fn solves_all_shifts() {
        let n = 50;
        let k = random_spd(n, 1);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(2);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let shifts = [0.0, 0.1, 1.0, 10.0, 100.0];
        let opts = MsMinresOptions { max_iters: 200, tol: 1e-10, weights: None };
        let res = msminres(&op, &b, &shifts, &opts);
        assert!(res.converged);
        for (q, &t) in shifts.iter().enumerate() {
            let mut kt = k.clone();
            for i in 0..n {
                kt[(i, i)] += t;
            }
            let exact = Cholesky::new(&kt).unwrap().solve(&b);
            let err = rel_err(&res.solutions[q], &exact);
            assert!(err < 1e-7, "shift {t}: rel err {err}");
        }
    }

    #[test]
    fn one_mvm_per_iteration_counts() {
        // iteration count should be far below N for well-conditioned K
        let n = 120;
        let mut k = Matrix::eye(n);
        for i in 0..n {
            k[(i, i)] = 1.0 + 0.1 * (i as f64 / n as f64); // kappa ≈ 1.1
        }
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(3);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let res = msminres(&op, &b, &[0.0, 1.0], &MsMinresOptions::default());
        assert!(res.converged);
        assert!(res.iterations < 25, "iterations {}", res.iterations);
    }

    #[test]
    fn higher_shifts_converge_faster() {
        let n = 60;
        let k = random_spd(n, 4);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(5);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = MsMinresOptions { max_iters: 30, tol: 1e-14, weights: None };
        let res = msminres(&op, &b, &[0.0, 50.0], &opts);
        assert!(
            res.residuals[1] <= res.residuals[0] + 1e-12,
            "shifted residual {} should be <= unshifted {}",
            res.residuals[1],
            res.residuals[0]
        );
    }

    #[test]
    fn converged_shifts_freeze_without_extra_mvms() {
        // Heavily-shifted systems converge in a handful of iterations while
        // the unshifted one grinds on; freezing must (a) keep the MVM count
        // at exactly one per iteration (CountingOp), (b) spend strictly less
        // per-shift recurrence work than iterations × Q, and (c) leave every
        // solution as accurate as the Cholesky oracle.
        let n = 60;
        let k = random_spd(n, 40);
        let op = crate::operators::CountingOp::new(DenseOp::new(k.clone()));
        let mut rng = Pcg64::seeded(41);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let shifts = [0.0, 1e3, 1e5];
        let opts = MsMinresOptions { max_iters: 300, tol: 1e-10, weights: None };
        let res = msminres(&op, &b, &shifts, &opts);
        assert!(res.converged);
        assert_eq!(
            op.matvec_count(),
            res.iterations as u64,
            "freezing must not change the one-MVM-per-iteration property"
        );
        assert!(
            res.shift_work < res.iterations * shifts.len(),
            "no shift was ever frozen: shift_work {} vs full {}",
            res.shift_work,
            res.iterations * shifts.len()
        );
        for (q, &t) in shifts.iter().enumerate() {
            let mut kt = k.clone();
            for i in 0..n {
                kt[(i, i)] += t;
            }
            let exact = Cholesky::new(&kt).unwrap().solve(&b);
            let err = rel_err(&res.solutions[q], &exact);
            assert!(err < 1e-7, "shift {t}: frozen solution drifted, rel err {err}");
        }
    }

    #[test]
    fn residual_tracker_matches_true_residual() {
        let n = 40;
        let k = random_spd(n, 6);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(7);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = MsMinresOptions { max_iters: 17, tol: 1e-30, weights: None };
        let res = msminres(&op, &b, &[0.5], &opts);
        let mut kt = k.clone();
        for i in 0..n {
            kt[(i, i)] += 0.5;
        }
        let r_true = {
            let kx = kt.matvec(&res.solutions[0]);
            let diff: Vec<f64> = kx.iter().zip(&b).map(|(a, c)| a - c).collect();
            crate::util::norm2(&diff) / crate::util::norm2(&b)
        };
        assert!(
            (res.residuals[0] - r_true).abs() < 1e-8 * (1.0 + r_true),
            "tracked {} vs true {r_true}",
            res.residuals[0]
        );
    }

    #[test]
    fn block_version_matches_single() {
        let n = 35;
        let k = random_spd(n, 8);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(9);
        let b = Matrix::randn(n, 3, &mut rng);
        let shifts = [0.1, 2.0];
        let opts = MsMinresOptions { max_iters: 150, tol: 1e-10, weights: None };
        let res = msminres_block(&op, &b, &shifts, &opts);
        for j in 0..3 {
            let col = b.col(j);
            let single = msminres(&op, &col, &shifts, &opts);
            for q in 0..2 {
                let blocked = res.solutions[q].col(j);
                let err = rel_err(&blocked, &single.solutions[q]);
                assert!(err < 1e-8, "col {j} shift {q}: {err}");
            }
        }
        assert!(res.col_iterations.iter().all(|&it| it > 0));
    }

    #[test]
    fn block_residuals_are_per_shift() {
        // Regression: the block solver used to collapse residuals to a single
        // max over all shifts; they must be per-shift (max over columns),
        // consistent with `msminres`.
        let n = 50;
        let k = random_spd(n, 21);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(22);
        let b = Matrix::randn(n, 2, &mut rng);
        let shifts = [0.0, 50.0];
        // stop well before convergence so residuals are distinguishable
        let opts = MsMinresOptions { max_iters: 8, tol: 1e-30, weights: None };
        let res = msminres_block(&op, &b, &shifts, &opts);
        let mut expect = vec![0.0f64; shifts.len()];
        for j in 0..2 {
            let single = msminres(&op, &b.col(j), &shifts, &opts);
            for q in 0..shifts.len() {
                expect[q] = expect[q].max(single.residuals[q]);
            }
        }
        for q in 0..shifts.len() {
            let d = (res.residuals[q] - expect[q]).abs();
            assert!(d < 1e-6 * (1.0 + expect[q]), "shift {q}: block {} vs single {}", res.residuals[q], expect[q]);
        }
        assert!(
            res.residuals[1] < res.residuals[0],
            "heavily shifted system must show the smaller residual ({} vs {}) — collapsed max?",
            res.residuals[1],
            res.residuals[0]
        );
    }

    #[test]
    fn compaction_shrinks_column_work_on_heterogeneous_batch() {
        // Column 0 is an eigenvector (its Krylov space is 1-dimensional, so it
        // converges on the first iteration); columns 1–3 are random and need
        // tens of iterations. Compaction must retire column 0 from the matmat
        // immediately, keeping total column-work strictly below
        // `max_iterations × columns`.
        let n = 40;
        let mut k = Matrix::zeros(n, n);
        for i in 0..n {
            k[(i, i)] = 1.0 + i as f64;
        }
        // assert on the matmat columns the operator *actually served*, not
        // the solver's own (derivable) counter
        let op = crate::operators::CountingOp::new(DenseOp::new(k));
        let mut rng = Pcg64::seeded(11);
        let mut b = Matrix::zeros(n, 4);
        b[(0, 0)] = 1.0;
        for j in 1..4 {
            for i in 0..n {
                b[(i, j)] = rng.normal();
            }
        }
        let opts = MsMinresOptions { max_iters: 200, tol: 1e-10, weights: None };
        let res = msminres_block(&op, &b, &[0.1, 1.0], &opts);
        let max_iters = *res.col_iterations.iter().max().unwrap();
        assert_eq!(res.col_iterations[0], 1, "eigenvector column should converge immediately");
        assert!(max_iters > 1, "random columns should need several iterations");
        let served = op.matmat_col_count() as usize;
        assert!(
            served < max_iters * 4,
            "matmat width never shrank: operator served {served} columns vs uncompacted {}",
            max_iters * 4
        );
        assert_eq!(served, res.column_work, "column_work must report the served matmat columns");
    }

    #[test]
    fn property_block_compacted_matches_single_columns() {
        crate::util::proptest::check_default("block msminres == per-column msminres", |rng, _| {
            let n = 10 + rng.below(12);
            let r = 1 + rng.below(4);
            let a = Matrix::randn(n, n, rng);
            let mut k = a.matmul(&a.transpose());
            for i in 0..n {
                k[(i, i)] += n as f64;
            }
            let op = DenseOp::new(k);
            let b = Matrix::randn(n, r, rng);
            let shifts = [0.05 + rng.uniform(), 5.0 + rng.uniform() * 20.0];
            let opts = MsMinresOptions { max_iters: 300, tol: 1e-11, weights: None };
            let blk = msminres_block(&op, &b, &shifts, &opts);
            for j in 0..r {
                let single = msminres(&op, &b.col(j), &shifts, &opts);
                for q in 0..shifts.len() {
                    let err = rel_err(&blk.solutions[q].col(j), &single.solutions[q]);
                    crate::prop_assert!(err < 1e-6, "col {j} shift {q}: err {err}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_workspace_engines_match_owned_api_bit_for_bit() {
        // The `_in` engines against a *reused, dirty* workspace must produce
        // exactly (bit-for-bit) the owned API's results across kernels,
        // shifts, and widths — stale pooled state can never leak into a
        // solve.
        let mut ws = SolveWorkspace::new();
        crate::util::proptest::check_default("*_in == owned API bit-for-bit", move |rng, _| {
            let n = 8 + rng.below(20);
            let r = 1 + rng.below(4);
            let a = Matrix::randn(n, n, rng);
            let mut k = a.matmul(&a.transpose());
            for i in 0..n {
                k[(i, i)] += n as f64 * (0.2 + rng.uniform());
            }
            let op = DenseOp::new(k);
            let nq = 1 + rng.below(3);
            let shifts: Vec<f64> = (0..nq).map(|_| rng.uniform() * 30.0).collect();
            let weights = if rng.uniform() < 0.3 {
                Some((0..nq).map(|_| rng.normal()).collect())
            } else {
                None
            };
            let opts = MsMinresOptions {
                max_iters: 40 + rng.below(100),
                tol: 1e-9,
                weights,
            };
            // single-vector
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let owned = msminres(&op, &b, &shifts, &opts);
            let sol = msminres_in(&mut ws, &op, &b, &shifts, &opts);
            crate::prop_assert!(sol.iterations == owned.iterations, "iteration mismatch");
            crate::prop_assert!(sol.converged == owned.converged, "convergence mismatch");
            crate::prop_assert!(sol.shift_work == owned.shift_work, "shift_work mismatch");
            crate::prop_assert!(sol.residuals == owned.residuals, "residual mismatch");
            crate::prop_assert!(
                sol.residual_history == owned.residual_history,
                "history mismatch"
            );
            for q in 0..nq {
                crate::prop_assert!(
                    sol.solutions.row(q) == owned.solutions[q].as_slice(),
                    "shift {q} solution mismatch"
                );
            }
            sol.recycle(&mut ws);
            // blocked
            let bm = Matrix::randn(n, r, rng);
            let owned_blk = msminres_block(&op, &bm, &shifts, &opts);
            let blk = msminres_block_in(&mut ws, &op, &bm, &shifts, &opts);
            crate::prop_assert!(
                blk.col_iterations == owned_blk.col_iterations,
                "block col_iterations mismatch"
            );
            crate::prop_assert!(blk.residuals == owned_blk.residuals, "block residual mismatch");
            crate::prop_assert!(
                blk.column_work == owned_blk.column_work,
                "block column_work mismatch"
            );
            for j in 0..r {
                for q in 0..nq {
                    let row = blk.solutions.row(j * nq + q);
                    let col = owned_blk.solutions[q].col(j);
                    crate::prop_assert!(row == col.as_slice(), "block ({j},{q}) mismatch");
                }
            }
            blk.recycle(&mut ws);
            Ok(())
        });
    }

    #[test]
    fn warmed_workspace_solves_without_growing() {
        // Identical repeated solves on one workspace must stop allocating
        // after the first (the steady-state contract the coordinator's pool
        // relies on; the allocator-level proof lives in the alloc_regression
        // integration test).
        let n = 30;
        let k = random_spd(n, 55);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(56);
        let b = Matrix::randn(n, 3, &mut rng);
        let bv: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let shifts = [0.1, 1.0, 10.0];
        let opts = MsMinresOptions { max_iters: 200, tol: 1e-9, weights: None };
        let mut ws = SolveWorkspace::new();
        for _ in 0..2 {
            msminres_block_in(&mut ws, &op, &b, &shifts, &opts).recycle(&mut ws);
            msminres_in(&mut ws, &op, &bv, &shifts, &opts).recycle(&mut ws);
        }
        let grows = ws.grows();
        for _ in 0..3 {
            msminres_block_in(&mut ws, &op, &b, &shifts, &opts).recycle(&mut ws);
            msminres_in(&mut ws, &op, &bv, &shifts, &opts).recycle(&mut ws);
        }
        assert_eq!(ws.grows(), grows, "warmed msMINRES workspace must not re-allocate");
        assert!(ws.checkouts() > 0);
    }

    #[test]
    fn block_weighted_stop_terminates_no_later_than_per_shift() {
        // With CIQ weights the block solver must use the same weighted-average
        // stopping rule as `msminres`, which fires no later than (and usually
        // before) the all-shifts-done rule when shifts converge at different
        // rates.
        let n = 50;
        let k = random_spd(n, 25);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(26);
        let b = Matrix::randn(n, 2, &mut rng);
        let shifts = [0.01, 100.0];
        let opts_w = MsMinresOptions { max_iters: 400, tol: 1e-8, weights: Some(vec![1.0, 1.0]) };
        let opts_u = MsMinresOptions { max_iters: 400, tol: 1e-8, weights: None };
        let rw = msminres_block(&op, &b, &shifts, &opts_w);
        let ru = msminres_block(&op, &b, &shifts, &opts_u);
        for j in 0..2 {
            assert!(
                rw.col_iterations[j] <= ru.col_iterations[j],
                "col {j}: weighted {} > unweighted {}",
                rw.col_iterations[j],
                ru.col_iterations[j]
            );
        }
        assert!(
            rw.col_iterations.iter().zip(&ru.col_iterations).any(|(a, b)| a < b),
            "weighted stop never engaged: {:?} vs {:?}",
            rw.col_iterations,
            ru.col_iterations
        );
    }

    #[test]
    fn block_zero_column_short_circuits() {
        let n = 20;
        let k = random_spd(n, 30);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(31);
        let mut b = Matrix::zeros(n, 2);
        for i in 0..n {
            b[(i, 1)] = rng.normal();
        }
        let opts = MsMinresOptions { max_iters: 100, tol: 1e-9, weights: None };
        let res = msminres_block(&op, &b, &[0.0, 1.0], &opts);
        assert_eq!(res.col_iterations[0], 0);
        assert!(res.col_iterations[1] > 0);
        assert!(res.solutions[0].col(0).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn zero_rhs_short_circuits() {
        let op = DenseOp::new(Matrix::eye(10));
        let res = msminres(&op, &vec![0.0; 10], &[0.0, 1.0], &MsMinresOptions::default());
        assert!(res.converged);
        assert_eq!(res.iterations, 0);
        assert!(res.solutions[0].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn refined_solve_meets_f64_tolerance_with_bounded_sweeps() {
        // Well-conditioned system: the mixed inner solve plus refinement
        // must reach the SAME tolerance the f64 path would certify, without
        // falling back, in at most `max_sweeps` sweeps — and the returned
        // residuals are true f64 residuals, not recurrence estimates.
        let n = 50;
        let k = random_spd(n, 71);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(72);
        let b = Matrix::randn(n, 3, &mut rng);
        let shifts = [0.1, 1.0, 10.0];
        let opts = MsMinresOptions { max_iters: 300, tol: 1e-8, weights: None };
        let cfg = RefineConfig::default();
        let mut ws = SolveWorkspace::new();
        let (blk, sweeps, fellback) =
            msminres_block_refined_in(&mut ws, &op, &b, &shifts, &opts, &cfg);
        assert!(!fellback, "well-conditioned solve must not fall back");
        assert!(sweeps >= 1, "tol 1e-8 is below the inner floor: refinement must engage");
        assert!(sweeps <= cfg.max_sweeps);
        for q in 0..shifts.len() {
            assert!(blk.residuals[q] <= opts.tol, "shift {q}: {}", blk.residuals[q]);
        }
        for (q, &t) in shifts.iter().enumerate() {
            let mut kt = k.clone();
            for i in 0..n {
                kt[(i, i)] += t;
            }
            let ch = Cholesky::new(&kt).unwrap();
            for j in 0..3 {
                let exact = ch.solve(&b.col(j));
                let err = rel_err(blk.solutions.row(j * shifts.len() + q), &exact);
                assert!(err < 1e-6, "shift {t} col {j}: err {err}");
            }
        }
        blk.recycle(&mut ws);
    }

    #[test]
    fn ill_conditioned_refinement_falls_back_or_meets_tol() {
        // κ = 1e8: the f32 forward error (κ·ε₃₂ ≈ 6) can swamp refinement.
        // The contract is not "mixed always works" — it is "the caller never
        // observes worse than f64": either the true residual meets the
        // tolerance, or the engine re-solves in pure f64 (bit-identical to
        // the direct f64 path) within a bounded number of sweeps.
        let n = 60;
        let mut k = Matrix::eye(n);
        for i in 0..n {
            let fr = i as f64 / (n - 1) as f64;
            k[(i, i)] = 1e-8_f64.powf(1.0 - fr); // log-spaced spectrum 1e-8..1
        }
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(73);
        let b = Matrix::randn(n, 2, &mut rng);
        let shifts = [0.0];
        let opts = MsMinresOptions { max_iters: 500, tol: 1e-9, weights: None };
        let cfg = RefineConfig::default();
        let mut ws = SolveWorkspace::new();
        let (blk, sweeps, fellback) =
            msminres_block_refined_in(&mut ws, &op, &b, &shifts, &opts, &cfg);
        assert!(sweeps <= cfg.max_sweeps, "sweep count must be bounded: {sweeps}");
        let worst = blk.residuals.iter().cloned().fold(0.0, f64::max);
        assert!(
            fellback || worst <= opts.tol,
            "neither met tol ({worst:e}) nor fell back after {sweeps} sweeps"
        );
        if fellback {
            // the fallback is the plain f64 engine on the original inputs —
            // its outputs must be bit-identical to calling it directly
            let direct = msminres_block_in(&mut ws, &op, &b, &shifts, &opts);
            assert_eq!(blk.residuals, direct.residuals);
            assert_eq!(blk.col_iterations, direct.col_iterations);
            for c in 0..2 {
                assert_eq!(blk.solutions.row(c), direct.solutions.row(c));
            }
            direct.recycle(&mut ws);
        }
        blk.recycle(&mut ws);
    }

    #[test]
    fn refined_solve_reuses_warmed_workspace() {
        // Same steady-state contract as the f64 engines: repeated refined
        // solves on one workspace stop growing the pool after warmup
        // (allocator-level proof lives in tests/alloc_regression.rs).
        let n = 40;
        let k = random_spd(n, 81);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(82);
        let b = Matrix::randn(n, 2, &mut rng);
        let shifts = [0.5, 5.0];
        let opts = MsMinresOptions { max_iters: 200, tol: 1e-8, weights: None };
        let cfg = RefineConfig::default();
        let mut ws = SolveWorkspace::new();
        for _ in 0..2 {
            let (blk, _, _) = msminres_block_refined_in(&mut ws, &op, &b, &shifts, &opts, &cfg);
            blk.recycle(&mut ws);
        }
        let grows = ws.grows();
        for _ in 0..3 {
            let (blk, _, _) = msminres_block_refined_in(&mut ws, &op, &b, &shifts, &opts, &cfg);
            blk.recycle(&mut ws);
        }
        assert_eq!(ws.grows(), grows, "warmed refined workspace must not re-allocate");
    }

    #[test]
    fn property_msminres_equals_minres_per_shift() {
        crate::util::proptest::check_default("msminres == per-shift solves", |rng, _| {
            let n = 12 + rng.below(10);
            let a = Matrix::randn(n, n, rng);
            let mut k = a.matmul(&a.transpose());
            for i in 0..n {
                k[(i, i)] += n as f64;
            }
            let op = DenseOp::new(k.clone());
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let shifts = [rng.uniform() * 5.0, 10.0 + rng.uniform() * 50.0];
            let opts = MsMinresOptions { max_iters: 300, tol: 1e-11, weights: None };
            let multi = msminres(&op, &b, &shifts, &opts);
            for (q, &t) in shifts.iter().enumerate() {
                let mut kt = k.clone();
                for i in 0..n {
                    kt[(i, i)] += t;
                }
                let exact = Cholesky::new(&kt).unwrap().solve(&b);
                let err = rel_err(&multi.solutions[q], &exact);
                crate::prop_assert!(err < 1e-6, "shift {t}: err {err}");
            }
            Ok(())
        });
    }
}

//! Stochastic Lanczos Quadrature (SLQ) — `tr(f(K))` estimators
//! (Ubaru–Chen–Saad [76]; Dong et al. [20]).
//!
//! Appx. E of the paper notes that the whitened-KL *forward* pass can be
//! computed in `O(M²)` with "stochastic trace estimation for the trace term
//! [and] stochastic Lanczos quadrature for the log determinant". This module
//! supplies both: Hutchinson probes `zᵀ f(K) z` evaluated through the
//! Gauss quadrature induced by the Lanczos tridiagonal matrix — each probe
//! costs `J` MVMs, so `tr log K` and `tr K^{-1}` come out in
//! `O(probes · J · ξ(K))` without ever factorizing `K`.

use crate::linalg::eigen::sym_eig;
use crate::linalg::{Matrix, SolveWorkspace};
use crate::operators::LinearOp;
use crate::rng::Pcg64;
use crate::util::norm2;
use crate::{Error, Result};

/// Options for the SLQ estimators.
#[derive(Clone, Debug)]
pub struct SlqOptions {
    /// Hutchinson probe vectors (Rademacher).
    pub probes: usize,
    /// Lanczos steps per probe.
    pub lanczos_iters: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SlqOptions {
    fn default() -> Self {
        SlqOptions { probes: 16, lanczos_iters: 25, seed: 0x51A9 }
    }
}

/// One probe's Gauss-quadrature value of `zᵀ f(K) z`:
/// run Lanczos from `z`, eigendecompose the small tridiagonal `T = V Θ Vᵀ`,
/// and return `‖z‖² Σ_k (V_{1k})² f(θ_k)`.
fn probe_quadrature(
    ws: &mut SolveWorkspace,
    op: &dyn LinearOp,
    z: &[f64],
    iters: usize,
    f: &dyn Fn(f64) -> f64,
) -> Result<f64> {
    let nz = norm2(z);
    if nz == 0.0 {
        return Ok(0.0);
    }
    // full reorthogonalization: J is small and Ritz accuracy matters for log
    let (alphas, betas) = crate::krylov::lanczos_tridiag_in(ws, op, z, iters, true);
    // tridiagonal eigen-pairs (need first-row eigenvector weights); the
    // J×J eigensolve below still allocates — it is O(J²) dense work on a
    // tiny matrix, off the O(N) steady-state path the workspace covers.
    let m = alphas.len();
    let mut t = Matrix::zeros(m, m);
    for i in 0..m {
        t[(i, i)] = alphas[i];
    }
    for i in 0..m - 1 {
        t[(i, i + 1)] = betas[i];
        t[(i + 1, i)] = betas[i];
    }
    ws.give_vec(alphas);
    ws.give_vec(betas);
    let eig = sym_eig(&t)?;
    let mut acc = 0.0;
    for k in 0..m {
        let theta = eig.values[k];
        if !theta.is_finite() {
            return Err(Error::Numerical("non-finite Ritz value in SLQ".into()));
        }
        let w1 = eig.vectors[(0, k)];
        acc += w1 * w1 * f(theta.max(1e-300));
    }
    Ok(nz * nz * acc)
}

/// Estimate `tr(f(K))` with Hutchinson + Lanczos quadrature.
pub fn trace_of_function(
    op: &dyn LinearOp,
    f: impl Fn(f64) -> f64,
    opts: &SlqOptions,
) -> Result<f64> {
    let mut ws = SolveWorkspace::new();
    trace_of_function_in(&mut ws, op, f, opts)
}

/// Workspace engine behind [`trace_of_function`]: probe vectors and every
/// O(N) Lanczos buffer come from `ws` (the per-probe `J×J` tridiagonal
/// eigensolve still allocates — tiny dense work off the O(N) path).
pub fn trace_of_function_in(
    ws: &mut SolveWorkspace,
    op: &dyn LinearOp,
    f: impl Fn(f64) -> f64,
    opts: &SlqOptions,
) -> Result<f64> {
    let n = op.size();
    let mut rng = Pcg64::seeded(opts.seed);
    let mut acc = 0.0;
    let mut z = ws.take_vec(n);
    for _ in 0..opts.probes {
        // Rademacher probe
        for zi in z.iter_mut() {
            *zi = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        }
        let probe = probe_quadrature(ws, op, &z, opts.lanczos_iters, &f);
        match probe {
            Ok(p) => acc += p,
            Err(e) => {
                ws.give_vec(z);
                return Err(e);
            }
        }
    }
    ws.give_vec(z);
    Ok(acc / opts.probes as f64)
}

/// `log |K|` estimate (`tr log K`).
pub fn logdet(op: &dyn LinearOp, opts: &SlqOptions) -> Result<f64> {
    trace_of_function(op, |x| x.ln(), opts)
}

/// `tr(K^{-1})` estimate.
pub fn trace_inverse(op: &dyn LinearOp, opts: &SlqOptions) -> Result<f64> {
    trace_of_function(op, |x| 1.0 / x, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;
    use crate::operators::{DenseOp, KernelOp, KernelType};

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64 * 0.3;
        }
        k
    }

    #[test]
    fn logdet_matches_cholesky() {
        let n = 60;
        let k = spd(n, 1);
        let exact = Cholesky::new(&k).unwrap().logdet();
        let op = DenseOp::new(k);
        let est = logdet(&op, &SlqOptions { probes: 40, lanczos_iters: 30, seed: 2 }).unwrap();
        let rel = (est - exact).abs() / exact.abs();
        assert!(rel < 0.05, "SLQ logdet {est} vs exact {exact} (rel {rel})");
    }

    #[test]
    fn trace_inverse_matches_direct() {
        let n = 40;
        let k = spd(n, 3);
        let chol = Cholesky::new(&k).unwrap();
        let mut exact = 0.0;
        for i in 0..n {
            let mut e = vec![0.0; n];
            e[i] = 1.0;
            exact += chol.solve(&e)[i];
        }
        let op = DenseOp::new(k);
        let est = trace_inverse(&op, &SlqOptions { probes: 60, lanczos_iters: 30, seed: 4 }).unwrap();
        let rel = (est - exact).abs() / exact;
        assert!(rel < 0.1, "SLQ tr(K^-1) {est} vs exact {exact} (rel {rel})");
    }

    #[test]
    fn trace_of_identity_function_is_trace() {
        // f(x) = x  =>  tr(K), which Hutchinson estimates unbiasedly
        let n = 50;
        let k = spd(n, 5);
        let exact: f64 = (0..n).map(|i| k[(i, i)]).sum();
        let op = DenseOp::new(k);
        let est =
            trace_of_function(&op, |x| x, &SlqOptions { probes: 60, lanczos_iters: 20, seed: 6 })
                .unwrap();
        assert!((est - exact).abs() / exact < 0.1, "{est} vs {exact}");
    }

    #[test]
    fn works_on_kernel_operators_without_materialization() {
        let mut rng = Pcg64::seeded(7);
        let n = 120;
        let x = Matrix::randn(n, 2, &mut rng);
        let op = KernelOp::new(&x, KernelType::Rbf, 0.7, 1.0, 0.5);
        let exact = Cholesky::with_jitter(&op.to_dense(), 0.0).unwrap().logdet();
        let est = logdet(&op, &SlqOptions { probes: 30, lanczos_iters: 30, seed: 8 }).unwrap();
        assert!(
            (est - exact).abs() / exact.abs().max(1.0) < 0.1,
            "kernel logdet {est} vs {exact}"
        );
    }
}

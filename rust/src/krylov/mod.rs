//! Krylov-subspace methods: Lanczos extreme-eigenvalue estimation,
//! MINRES, multi-shift MINRES (msMINRES — Alg. 4 of the paper), and
//! preconditioned conjugate gradients.

pub mod lanczos;
pub mod minres;
pub mod msminres;
pub mod cg;
pub mod slq;

pub use lanczos::{estimate_extreme_eigenvalues, lanczos_tridiag, EigenBounds};
pub use minres::minres;
pub use msminres::{msminres, msminres_block, MsMinresBlockResult, MsMinresOptions, MsMinresResult};
pub use cg::{pcg, CgOptions};

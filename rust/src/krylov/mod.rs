//! Krylov-subspace methods: Lanczos extreme-eigenvalue estimation,
//! MINRES, multi-shift MINRES (msMINRES — Alg. 4 of the paper), and
//! preconditioned conjugate gradients.
//!
//! Every solver exposes a `*_in` entry point taking a
//! [`crate::linalg::SolveWorkspace`] whose O(N) state comes from pooled
//! slabs — the zero-allocation steady-state path — with the original owned
//! signatures kept as thin wrappers over a transient workspace.

pub mod lanczos;
pub mod minres;
pub mod msminres;
pub mod cg;
pub mod slq;

pub use lanczos::{estimate_extreme_eigenvalues, lanczos_tridiag, lanczos_tridiag_in, EigenBounds};
pub use minres::minres;
pub use msminres::{
    msminres, msminres_block, msminres_block_in, msminres_in, MsMinresBlockResult,
    MsMinresBlockSolve, MsMinresOptions, MsMinresResult, MsMinresSolve,
};
pub use cg::{pcg, pcg_in, CgOptions};

//! Standard MINRES (Alg. 3 of the paper) — implemented as the single-shift
//! special case of [`super::msminres`]: identical recurrence, `t = 0`.

use super::msminres::{msminres, MsMinresOptions};
use crate::operators::LinearOp;

/// Solve `K c = b` with MINRES. Returns `(solution, relative_residual,
/// iterations)`.
pub fn minres(op: &dyn LinearOp, b: &[f64], max_iters: usize, tol: f64) -> (Vec<f64>, f64, usize) {
    let opts = MsMinresOptions { max_iters, tol, weights: None };
    let mut res = msminres(op, b, &[0.0], &opts);
    (res.solutions.swap_remove(0), res.residuals[0], res.iterations)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Cholesky, Matrix};
    use crate::operators::DenseOp;
    use crate::rng::Pcg64;
    use crate::util::rel_err;

    #[test]
    fn matches_direct_solve() {
        let mut rng = Pcg64::seeded(1);
        let n = 45;
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64 * 0.2;
        }
        let op = DenseOp::new(k.clone());
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (x, res, iters) = minres(&op, &b, 300, 1e-10);
        let exact = Cholesky::new(&k).unwrap().solve(&b);
        assert!(rel_err(&x, &exact) < 1e-7);
        assert!(res < 1e-10);
        assert!(iters <= 300);
    }

    #[test]
    fn works_on_indefinite_systems() {
        // MINRES handles symmetric indefinite K (unlike CG).
        let n = 20;
        let mut k = Matrix::eye(n);
        for i in 0..n {
            k[(i, i)] = if i % 2 == 0 { 2.0 } else { -3.0 };
        }
        let mut rng = Pcg64::seeded(2);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let op = DenseOp::new(k.clone());
        let (x, res, _) = minres(&op, &b, 100, 1e-12);
        let kx = k.matvec(&x);
        assert!(rel_err(&kx, &b) < 1e-8, "res={res}");
    }
}

//! Lanczos tridiagonalization and extreme-eigenvalue estimation (Appx. B.2).

use crate::linalg::eigen::tridiag_eigenvalues;
use crate::linalg::SolveWorkspace;
use crate::operators::LinearOp;
use crate::rng::Pcg64;
use crate::util::{axpy, dot, norm2};
use crate::{Error, Result};

/// Estimated spectral bounds of an operator.
#[derive(Clone, Copy, Debug)]
pub struct EigenBounds {
    /// Lower bound estimate (slightly deflated — Lanczos overestimates λ_min).
    pub lambda_min: f64,
    /// Upper bound estimate (slightly inflated — Lanczos underestimates λ_max).
    pub lambda_max: f64,
}

impl EigenBounds {
    /// Condition number estimate.
    pub fn kappa(&self) -> f64 {
        self.lambda_max / self.lambda_min
    }
}

/// Run `iters` Lanczos steps from starting vector `b`, returning the
/// tridiagonal coefficients `(alphas, betas)` where `betas[j]` couples
/// basis vectors `j` and `j+1`. Performs full re-orthogonalization when
/// `reorth` is set (only used for the small eigenvalue-estimation runs,
/// where it costs O(J²N) but makes the Ritz values reliable).
pub fn lanczos_tridiag(
    op: &dyn LinearOp,
    b: &[f64],
    iters: usize,
    reorth: bool,
) -> (Vec<f64>, Vec<f64>) {
    let mut ws = SolveWorkspace::new();
    lanczos_tridiag_in(&mut ws, op, b, iters, reorth)
}

/// Workspace engine behind [`lanczos_tridiag`]: the Krylov vectors and the
/// reorthogonalization basis are slabs from `ws`, and each MVM runs through
/// [`LinearOp::matvec_in`] — a warmed workspace runs O(N)-allocation-free.
/// The returned `(alphas, betas)` are workspace-backed; give them back with
/// [`SolveWorkspace::give_vec`] when reusing the workspace.
pub fn lanczos_tridiag_in(
    ws: &mut SolveWorkspace,
    op: &dyn LinearOp,
    b: &[f64],
    iters: usize,
    reorth: bool,
) -> (Vec<f64>, Vec<f64>) {
    let n = op.size();
    assert_eq!(b.len(), n);
    let jmax = iters.min(n);
    let mut alphas = ws.take_vec(iters.max(1));
    alphas.clear();
    let mut betas = ws.take_vec(iters.max(1));
    betas.clear();
    let nb = norm2(b);
    if nb == 0.0 {
        alphas.push(0.0);
        return (alphas, betas);
    }
    let mut q = ws.take_vec(n);
    for i in 0..n {
        q[i] = b[i] / nb;
    }
    let mut q_prev = ws.take_vec(n);
    let mut w = ws.take_vec(n);
    let mut basis = ws.take_vec(if reorth { jmax * n } else { 0 });
    let mut nbasis = 0usize;
    let mut beta_prev = 0.0;
    for j in 0..jmax {
        if reorth {
            basis[nbasis * n..(nbasis + 1) * n].copy_from_slice(&q);
            nbasis += 1;
        }
        op.matvec_in(ws, &q, &mut w);
        if beta_prev != 0.0 {
            axpy(-beta_prev, &q_prev, &mut w);
        }
        let alpha = dot(&q, &w);
        axpy(-alpha, &q, &mut w);
        if reorth {
            // full Gram–Schmidt against all previous basis vectors
            for t in 0..nbasis {
                let v = &basis[t * n..(t + 1) * n];
                let c = dot(v, &w);
                axpy(-c, v, &mut w);
            }
        }
        alphas.push(alpha);
        let beta = norm2(&w);
        if j + 1 < jmax {
            if beta < 1e-13 * alpha.abs().max(1.0) {
                break; // invariant subspace found
            }
            betas.push(beta);
            for i in 0..n {
                q_prev[i] = q[i];
                q[i] = w[i] / beta;
            }
            beta_prev = beta;
        }
    }
    ws.give_vec(q);
    ws.give_vec(q_prev);
    ws.give_vec(w);
    ws.give_vec(basis);
    (alphas, betas)
}

/// Estimate `(λ_min, λ_max)` of an SPD operator with ~`iters` Lanczos steps
/// (Alg. 2 of the paper uses ≈10). The returned bounds are widened slightly
/// because the quadrature rule is insensitive to over-estimating the
/// condition number (Lemma 1) but breaks if an eigenvalue escapes the range.
pub fn estimate_extreme_eigenvalues(
    op: &dyn LinearOp,
    iters: usize,
    rng: &mut Pcg64,
) -> Result<EigenBounds> {
    let n = op.size();
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let (alphas, betas) = lanczos_tridiag(op, &b, iters.min(n), true);
    let evals = tridiag_eigenvalues(&alphas, &betas)?;
    let lo = *evals.first().ok_or_else(|| Error::Numerical("empty Lanczos spectrum".into()))?;
    let hi = *evals.last().unwrap();
    if !lo.is_finite() || !hi.is_finite() {
        return Err(Error::Numerical("non-finite Ritz values".into()));
    }
    // Widen: Ritz values are interior to the true spectrum. The max side
    // converges fast; the min side can be badly over-estimated on clustered
    // spectra, so prefer a structural lower bound when the operator has one
    // (e.g. kernel matrices: λ_min ≥ σ²_noise) — Lemma 1 makes an
    // over-estimated κ nearly free, while an under-covered spectrum bottom
    // corrupts the quadrature.
    let lambda_max = hi * 1.01 + 1e-12;
    let mut lambda_min = match op.lambda_min_bound() {
        Some(bound) if bound > 0.0 => bound,
        _ => lo * 0.25,
    };
    if lambda_min <= 0.0 {
        // SPD contract violated numerically; clamp relative to λ_max.
        lambda_min = lambda_max * 1e-7;
    }
    Ok(EigenBounds { lambda_min, lambda_max })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::operators::DenseOp;

    fn spd_with_spectrum(evals: &[f64], rng: &mut Pcg64) -> Matrix {
        // Random orthogonal via QR-free trick: Householder from random vectors.
        let n = evals.len();
        let a = Matrix::randn(n, n, rng);
        // Gram-Schmidt
        let mut q = Matrix::zeros(n, n);
        for j in 0..n {
            let mut v = a.col(j);
            for p in 0..j {
                let qp = q.col(p);
                let c = dot(&qp, &v);
                axpy(-c, &qp, &mut v);
            }
            let nv = norm2(&v);
            for i in 0..n {
                q[(i, j)] = v[i] / nv;
            }
        }
        // K = Q diag Qᵀ
        let mut scaled = q.clone();
        for j in 0..n {
            for i in 0..n {
                scaled[(i, j)] *= evals[j];
            }
        }
        scaled.matmul(&q.transpose())
    }

    #[test]
    fn recovers_extreme_eigenvalues() {
        let mut rng = Pcg64::seeded(1);
        let evals: Vec<f64> = (1..=40).map(|t| 1.0 / (t as f64)).collect();
        let k = spd_with_spectrum(&evals, &mut rng);
        let op = DenseOp::new(k);
        let b = estimate_extreme_eigenvalues(&op, 25, &mut rng).unwrap();
        assert!(b.lambda_max >= 1.0 && b.lambda_max < 1.1, "max {}", b.lambda_max);
        assert!(b.lambda_min <= 1.0 / 40.0, "min {}", b.lambda_min);
        assert!(b.lambda_min > 0.0);
    }

    #[test]
    fn tridiag_exact_for_small_matrix() {
        // For n=3 and 3 Lanczos steps, Ritz values equal true eigenvalues.
        let mut rng = Pcg64::seeded(2);
        let k = spd_with_spectrum(&[1.0, 2.0, 5.0], &mut rng);
        let op = DenseOp::new(k);
        let b: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
        let (alphas, betas) = lanczos_tridiag(&op, &b, 3, true);
        let evals = tridiag_eigenvalues(&alphas, &betas).unwrap();
        let expect = [1.0, 2.0, 5.0];
        for (e, t) in evals.iter().zip(expect.iter()) {
            assert!((e - t).abs() < 1e-8, "{e} vs {t}");
        }
    }

    #[test]
    fn identity_operator() {
        let op = DenseOp::new(Matrix::eye(10));
        let mut rng = Pcg64::seeded(3);
        let b = estimate_extreme_eigenvalues(&op, 8, &mut rng).unwrap();
        assert!((b.lambda_max - 1.01).abs() < 0.02);
        assert!(b.lambda_min <= 1.0);
    }
}

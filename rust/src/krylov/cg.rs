//! Preconditioned conjugate gradients — used for the `O(M²)` natural-gradient
//! solves with `S'` (Appx. E, footnote: Jacobi preconditioner) and for the
//! Gibbs-sampler posterior means.

use crate::linalg::SolveWorkspace;
use crate::operators::LinearOp;
use crate::util::{axpy, dot, norm2};

/// Options for [`pcg`].
#[derive(Clone, Debug)]
pub struct CgOptions {
    /// Maximum iterations.
    pub max_iters: usize,
    /// Relative-residual tolerance.
    pub tol: f64,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions { max_iters: 500, tol: 1e-8 }
    }
}

/// Preconditioned CG: solve `K x = b` for SPD `K`, with an optional
/// preconditioner given as a *solve* closure `z = P^{-1} r`.
/// Returns `(x, relative_residual, iterations)`.
pub fn pcg(
    op: &dyn LinearOp,
    b: &[f64],
    precond: Option<&dyn Fn(&[f64]) -> Vec<f64>>,
    opts: &CgOptions,
) -> (Vec<f64>, f64, usize) {
    let mut ws = SolveWorkspace::new();
    pcg_in(&mut ws, op, b, precond, opts)
}

/// Workspace engine behind [`pcg`]: the iterate, residual, search direction,
/// and `K·p` buffers are slabs from `ws` and each MVM runs through
/// [`LinearOp::matvec_in`], so the unpreconditioned warmed path is
/// allocation-free (a `precond` closure still allocates its own return —
/// that contract is the caller's). The returned solution is
/// workspace-backed.
pub fn pcg_in(
    ws: &mut SolveWorkspace,
    op: &dyn LinearOp,
    b: &[f64],
    precond: Option<&dyn Fn(&[f64]) -> Vec<f64>>,
    opts: &CgOptions,
) -> (Vec<f64>, f64, usize) {
    let n = op.size();
    assert_eq!(b.len(), n);
    let bnorm = norm2(b);
    if bnorm == 0.0 {
        return (ws.take_vec(n), 0.0, 0);
    }
    let mut x = ws.take_vec(n);
    let mut r = ws.take_vec(n);
    r.copy_from_slice(b);
    let mut z = ws.take_vec(n);
    match precond {
        Some(pre) => z.copy_from_slice(&pre(&r)),
        None => z.copy_from_slice(&r),
    }
    let mut p = ws.take_vec(n);
    p.copy_from_slice(&z);
    let mut kp = ws.take_vec(n);
    let mut rz = dot(&r, &z);
    let mut iters = 0;
    let mut res = 1.0;
    for _ in 0..opts.max_iters {
        iters += 1;
        op.matvec_in(ws, &p, &mut kp);
        let pkp = dot(&p, &kp);
        if pkp <= 0.0 || !pkp.is_finite() {
            break; // loss of positive definiteness; return best iterate
        }
        let alpha = rz / pkp;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &kp, &mut r);
        res = norm2(&r) / bnorm;
        if res < opts.tol {
            break;
        }
        match precond {
            Some(pre) => z.copy_from_slice(&pre(&r)),
            None => z.copy_from_slice(&r),
        }
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    ws.give_vec(r);
    ws.give_vec(z);
    ws.give_vec(p);
    ws.give_vec(kp);
    (x, res, iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Cholesky, Matrix};
    use crate::operators::{DenseOp, LinearOp};
    use crate::rng::Pcg64;
    use crate::util::rel_err;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64 * 0.3;
        }
        k
    }

    #[test]
    fn matches_direct() {
        let n = 40;
        let k = spd(n, 1);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(2);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (x, res, _) = pcg(&op, &b, None, &CgOptions { max_iters: 300, tol: 1e-12 });
        let exact = Cholesky::new(&k).unwrap().solve(&b);
        assert!(rel_err(&x, &exact) < 1e-8, "res={res}");
    }

    #[test]
    fn jacobi_preconditioner_reduces_iterations() {
        // strongly scaled diagonal => Jacobi helps a lot
        let n = 80;
        let mut k = spd(n, 3);
        for i in 0..n {
            let s = 1.0 + 100.0 * (i as f64 / n as f64);
            for j in 0..n {
                k[(i, j)] *= s.sqrt();
                k[(j, i)] *= s.sqrt();
            }
        }
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(4);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = CgOptions { max_iters: 500, tol: 1e-9 };
        let (_, _, it_plain) = pcg(&op, &b, None, &opts);
        let diag = op.diagonal();
        let pre = move |r: &[f64]| -> Vec<f64> { r.iter().zip(&diag).map(|(ri, di)| ri / di).collect() };
        let (x, _, it_pre) = pcg(&op, &b, Some(&pre), &opts);
        let exact = Cholesky::new(&k).unwrap().solve(&b);
        assert!(rel_err(&x, &exact) < 1e-6);
        assert!(it_pre <= it_plain, "precond {it_pre} vs plain {it_plain}");
    }
}

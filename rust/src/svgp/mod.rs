//! Whitened Stochastic Variational Gaussian Processes (Sec. 5.1).
//!
//! The variational posterior is `q(u') = N(m', S')` over *whitened* inducing
//! values `u' = K_ZZ^{-1/2} u`. The model holds the **natural parameters**
//! `θ = S'^{-1} m'` and `Θ = −½ S'^{-1}` and trains them with the `O(M²)`
//! natural-gradient update of Appx. E: every quantity the gradient needs is
//! reachable through
//!
//! * `a_i = K_ZZ^{-1/2} k_{Z,x_i}` — the paper's headline whitening
//!   operation, computed by msMINRES-CIQ (or Cholesky for the baseline), and
//! * solves with `(−2Θ)` — preconditioned CG (Jacobi), never an `O(M³)`
//!   inversion.
//!
//! Kernel/likelihood hyperparameters are trained with Adam on the minibatch
//! expected log-likelihood (the whitened KL is hyperparameter-free); the
//! gradients use central finite differences over the ≤4 scalar
//! hyperparameters — see DESIGN.md (the CIQ *backward pass*, Eq. 3, is
//! implemented and validated in [`crate::ciq`]; FD here trades a constant
//! factor for robustness).

pub mod likelihood;

pub use likelihood::{Bernoulli, Gaussian, Likelihood, StudentT};

use crate::ciq::{Ciq, CiqOptions};
use crate::krylov::cg::{pcg, CgOptions};
use crate::linalg::{Cholesky, Matrix};
use crate::operators::kernel::cross_kernel;
use crate::operators::{DenseOp, KernelOp, KernelType, LinearOp};
use crate::rng::Pcg64;
use crate::special::gauss_hermite;
use crate::{Error, Result};

/// Which backend computes `K_ZZ^{-1/2} k_Zx`.
#[derive(Clone, Debug)]
pub enum Backend {
    /// dense Cholesky (`O(M³)` factor + `O(M²)` per vector) — baseline
    Cholesky,
    /// msMINRES-CIQ (`O(J M²)` total, `O(M)` extra memory) — this paper
    Ciq(CiqOptions),
}

/// SVGP kernel hyperparameters (isotropic).
#[derive(Clone, Copy, Debug)]
pub struct SvgpHyper {
    /// lengthscale ℓ
    pub lengthscale: f64,
    /// outputscale s²
    pub outputscale: f64,
    /// jitter added to K_ZZ for SPD safety
    pub jitter: f64,
}

impl Default for SvgpHyper {
    fn default() -> Self {
        SvgpHyper { lengthscale: 0.2, outputscale: 1.0, jitter: 1e-4 }
    }
}

/// Whitened SVGP model.
pub struct Svgp {
    /// inducing locations `M × d`
    pub z: Matrix,
    /// kernel family
    pub kind: KernelType,
    /// kernel hyperparameters
    pub hyper: SvgpHyper,
    /// observation likelihood
    pub lik: Box<dyn Likelihood>,
    /// backend for the whitening solves
    pub backend: Backend,
    /// natural parameter θ = S'⁻¹ m'
    theta: Vec<f64>,
    /// natural parameter Θ = −½ S'⁻¹ (dense `M × M`)
    big_theta: Matrix,
    /// Gauss–Hermite nodes/weights
    gh: (Vec<f64>, Vec<f64>),
    /// msMINRES iteration telemetry (Fig. S7)
    pub iteration_log: Vec<usize>,
}

/// Per-point variational predictive `q(f(x)) = N(mu, var)`.
#[derive(Clone, Copy, Debug)]
pub struct Predictive {
    /// mean
    pub mu: f64,
    /// variance (≥ tiny)
    pub var: f64,
}

impl Svgp {
    /// Create with `q(u') = N(0, I)` (the whitened prior).
    pub fn new(z: Matrix, kind: KernelType, hyper: SvgpHyper, lik: Box<dyn Likelihood>, backend: Backend) -> Svgp {
        let m = z.rows();
        let mut big_theta = Matrix::zeros(m, m);
        for i in 0..m {
            big_theta[(i, i)] = -0.5;
        }
        Svgp {
            z,
            kind,
            hyper,
            lik,
            backend,
            theta: vec![0.0; m],
            big_theta,
            gh: gauss_hermite(20),
            iteration_log: Vec::new(),
        }
    }

    /// Number of inducing points.
    pub fn m(&self) -> usize {
        self.z.rows()
    }

    fn kzz_op(&self) -> KernelOp {
        KernelOp::new(&self.z, self.kind, self.hyper.lengthscale, self.hyper.outputscale, self.hyper.jitter)
    }

    /// `A = K_ZZ^{-1/2} K_Zx` for a batch of points (columns of the result).
    /// This is *the* whitening operation the paper accelerates.
    fn whiten_cross(&mut self, x_batch: &Matrix, hyper: SvgpHyper) -> Result<Matrix> {
        let ell = vec![hyper.lengthscale; self.z.cols()];
        let kzx = cross_kernel(&self.z, x_batch, self.kind, &ell, hyper.outputscale); // M × B
        let kzz = KernelOp::new(&self.z, self.kind, hyper.lengthscale, hyper.outputscale, hyper.jitter);
        match &self.backend {
            Backend::Cholesky => {
                let k = kzz.to_dense();
                let chol = Cholesky::with_jitter(&k, 0.0)?;
                let mut a = Matrix::zeros(self.m(), x_batch.rows());
                for j in 0..x_batch.rows() {
                    let col = kzx.col(j);
                    let w = chol.solve_l(&col);
                    for i in 0..self.m() {
                        a[(i, j)] = w[i];
                    }
                }
                Ok(a)
            }
            Backend::Ciq(opts) => {
                let solver = Ciq::new(opts.clone());
                let (a, iters) = solver.invsqrt_mvm_block(&kzz, &kzx)?;
                self.iteration_log.extend(iters);
                Ok(a)
            }
        }
    }

    /// Solve `(−2Θ) X = B` column-wise with Jacobi-preconditioned CG
    /// (`O(M²)` per solve; Appx. E footnote).
    fn s_prime_solve(&self, b: &Matrix) -> Matrix {
        let m = self.m();
        let mut neg2 = self.big_theta.clone();
        neg2.scale(-2.0);
        let op = DenseOp::new(neg2);
        let diag = op.diagonal();
        let pre = move |r: &[f64]| -> Vec<f64> {
            r.iter().zip(&diag).map(|(ri, di)| ri / di.max(1e-12)).collect()
        };
        let opts = CgOptions { max_iters: 4 * m, tol: 1e-8 };
        let mut out = Matrix::zeros(m, b.cols());
        for j in 0..b.cols() {
            let col = b.col(j);
            let (x, _res, _it) = pcg(&op, &col, Some(&pre), &opts);
            for i in 0..m {
                out[(i, j)] = x[i];
            }
        }
        out
    }

    /// Current `m' = S' θ`.
    pub fn m_prime(&self) -> Vec<f64> {
        let b = Matrix::from_vec(self.m(), 1, self.theta.clone());
        self.s_prime_solve(&b).col(0)
    }

    /// Predictive `q(f)` for a batch given precomputed whitened cross `A`.
    fn predictive_from_a(&self, a: &Matrix, hyper: SvgpHyper) -> Vec<Predictive> {
        let b = a.cols();
        let m_prime = self.m_prime();
        let u = self.s_prime_solve(a); // S' a_i per column
        let kxx = hyper.outputscale + hyper.jitter;
        let mut out = Vec::with_capacity(b);
        for j in 0..b {
            let aj = a.col(j);
            let mu = crate::util::dot(&aj, &m_prime);
            let ata = crate::util::dot(&aj, &aj);
            let asa = crate::util::dot(&aj, &u.col(j));
            let var = (kxx - ata + asa).max(1e-9);
            out.push(Predictive { mu, var });
        }
        out
    }

    /// Predict `q(f)` at arbitrary points.
    pub fn predict(&mut self, x: &Matrix) -> Result<Vec<Predictive>> {
        let hyper = self.hyper;
        let a = self.whiten_cross(x, hyper)?;
        Ok(self.predictive_from_a(&a, hyper))
    }

    /// Expected log-likelihood of one point under `q(f) = N(mu, var)`
    /// (Gauss–Hermite).
    fn expected_ll(&self, y: f64, p: Predictive) -> f64 {
        let (nodes, weights) = (&self.gh.0, &self.gh.1);
        let c = (2.0 * p.var).sqrt();
        let norm = std::f64::consts::PI.sqrt();
        nodes
            .iter()
            .zip(weights)
            .map(|(x, w)| w / norm * self.lik.log_prob(y, p.mu + c * x))
            .sum()
    }

    /// `(E[log p], dE/dmu, dE/dvar)` for one point.
    fn expected_ll_grads(&self, y: f64, p: Predictive) -> (f64, f64, f64) {
        let (nodes, weights) = (&self.gh.0, &self.gh.1);
        let c = (2.0 * p.var).sqrt();
        let norm = std::f64::consts::PI.sqrt();
        let mut e = 0.0;
        let mut dmu = 0.0;
        let mut dvar = 0.0;
        for (x, w) in nodes.iter().zip(weights) {
            let f = p.mu + c * x;
            let lw = w / norm;
            e += lw * self.lik.log_prob(y, f);
            let g = self.lik.dlogp_df(y, f);
            dmu += lw * g;
            dvar += lw * g * x / c.max(1e-12);
        }
        (e, dmu, dvar)
    }

    /// KL[q(u')‖p(u')] (Eq. S22) — `O(M³)` diagnostics only, not used by NGD.
    pub fn kl(&self) -> Result<f64> {
        let m = self.m();
        let mut neg2 = self.big_theta.clone();
        neg2.scale(-2.0);
        let chol = Cholesky::with_jitter(&neg2, 0.0)
            .map_err(|_| Error::Numerical("Θ lost negative-definiteness".into()))?;
        // S' = (−2Θ)^{-1}: Tr(S') via solves, log|S'| = −log|−2Θ|
        let mut tr = 0.0;
        for i in 0..m {
            let mut e = vec![0.0; m];
            e[i] = 1.0;
            tr += chol.solve(&e)[i];
        }
        let mp = self.m_prime();
        let mtm = crate::util::dot(&mp, &mp);
        Ok(0.5 * (mtm + tr + chol.logdet() - m as f64))
    }

    /// `O(M²)` stochastic KL (Appx. E): Hutchinson trace estimation for
    /// `Tr(S')` and stochastic Lanczos quadrature for `log|S'|`, both
    /// through MVMs with `(−2Θ)` only — the forward-pass costing the paper
    /// prescribes when `M` is too large for dense factorization.
    pub fn kl_stochastic(&self, probes: usize, seed: u64) -> Result<f64> {
        let m = self.m();
        let mut neg2 = self.big_theta.clone();
        neg2.scale(-2.0);
        let op = DenseOp::new(neg2);
        let opts = crate::krylov::slq::SlqOptions {
            probes,
            lanczos_iters: 30.min(m),
            seed,
        };
        // Tr(S') = tr((−2Θ)^{-1}); log|S'| = −log|−2Θ|
        let tr_s = crate::krylov::slq::trace_inverse(&op, &opts)?;
        let logdet_neg2 = crate::krylov::slq::logdet(&op, &opts)?;
        let mp = self.m_prime();
        let mtm = crate::util::dot(&mp, &mp);
        Ok(0.5 * (mtm + tr_s + logdet_neg2 - m as f64))
    }

    /// Minibatch ELBO estimate (diagnostics; Appx. E notes NGD needs only
    /// gradients, so the training loop never calls this).
    pub fn elbo(&mut self, x: &Matrix, y: &[f64], n_total: usize) -> Result<f64> {
        let preds = self.predict(x)?;
        let scale = n_total as f64 / x.rows() as f64;
        let ll: f64 = preds.iter().zip(y).map(|(p, &yy)| self.expected_ll(yy, *p)).sum();
        Ok(scale * ll - self.kl()?)
    }

    /// One natural-gradient step on `(θ, Θ)` (Appx. E) for a minibatch.
    /// Returns the minibatch expected log-likelihood (pre-update).
    pub fn ngd_step(&mut self, x: &Matrix, y: &[f64], n_total: usize, lr: f64) -> Result<f64> {
        let hyper = self.hyper;
        let a = self.whiten_cross(x, hyper)?; // M × B
        let preds = self.predictive_from_a(&a, hyper);
        let scale = n_total as f64 / x.rows() as f64;
        let m = self.m();
        let b = x.rows();

        // gradient wrt expectation params (η, H)
        let mut g_eta = vec![0.0; m];
        let mut g_h = Matrix::zeros(m, m);
        let mut ll_acc = 0.0;
        for j in 0..b {
            let (e, dmu, dvar) = self.expected_ll_grads(y[j], preds[j]);
            ll_acc += e;
            let aj = a.col(j);
            let coef_eta = scale * (dmu - 2.0 * dvar * preds[j].mu);
            for i in 0..m {
                g_eta[i] += coef_eta * aj[i];
            }
            let ch = scale * dvar;
            // g_h += ch * a_j a_jᵀ
            for i in 0..m {
                let ai = ch * aj[i];
                if ai != 0.0 {
                    let row = g_h.row_mut(i);
                    for (rk, ak) in row.iter_mut().zip(&aj) {
                        *rk += ai * ak;
                    }
                }
            }
        }
        // KL gradients: dKL/dη = θ, dKL/dH = ½I + Θ
        for i in 0..m {
            g_eta[i] -= self.theta[i];
        }
        for i in 0..m {
            for j2 in 0..m {
                let kl_term = if i == j2 { 0.5 } else { 0.0 } + self.big_theta[(i, j2)];
                g_h[(i, j2)] -= kl_term;
            }
        }
        // natural-gradient ascent: natural params += lr * expectation-grads
        for i in 0..m {
            self.theta[i] += lr * g_eta[i];
        }
        for i in 0..m {
            for j2 in 0..m {
                self.big_theta[(i, j2)] += lr * g_h[(i, j2)];
            }
        }
        Ok(ll_acc / b as f64)
    }

    /// Minibatch expected log-likelihood under given hypers (for FD hyper
    /// gradients; the whitened KL does not depend on the hypers).
    fn batch_ll(&mut self, x: &Matrix, y: &[f64], hyper: SvgpHyper) -> Result<f64> {
        let a = self.whiten_cross(x, hyper)?;
        let preds = self.predictive_from_a(&a, hyper);
        Ok(preds.iter().zip(y).map(|(p, &yy)| self.expected_ll(yy, *p)).sum::<f64>() / x.rows() as f64)
    }

    /// Adam state for hyperparameters.
    fn hyper_logs(&self) -> Vec<f64> {
        let mut v = vec![self.hyper.lengthscale.ln(), self.hyper.outputscale.ln()];
        v.extend(self.lik.log_params());
        v
    }

    fn set_hyper_logs(&mut self, logs: &[f64]) {
        self.hyper.lengthscale = logs[0].exp().clamp(1e-3, 10.0);
        self.hyper.outputscale = logs[1].exp().clamp(1e-3, 100.0);
        self.lik.set_log_params(&logs[2..]);
    }

    /// One Adam step on kernel + likelihood hyperparameters via central
    /// finite differences of the minibatch expected log-likelihood.
    pub fn hyper_step(&mut self, x: &Matrix, y: &[f64], state: &mut AdamState, lr: f64) -> Result<()> {
        let logs = self.hyper_logs();
        let mut grad = vec![0.0; logs.len()];
        let h = 1e-3;
        for p in 0..logs.len() {
            let mut lp = logs.clone();
            lp[p] += h;
            self.set_hyper_logs(&lp);
            let hyper_p = self.hyper;
            let up = self.batch_ll(x, y, hyper_p)?;
            lp[p] -= 2.0 * h;
            self.set_hyper_logs(&lp);
            let hyper_m = self.hyper;
            let um = self.batch_ll(x, y, hyper_m)?;
            grad[p] = (up - um) / (2.0 * h);
            self.set_hyper_logs(&logs);
        }
        let new_logs = state.step(&logs, &grad, lr);
        self.set_hyper_logs(&new_logs);
        Ok(())
    }
}

/// Minimal Adam optimizer state (ascent).
pub struct AdamState {
    m: Vec<f64>,
    v: Vec<f64>,
    t: i32,
}

impl AdamState {
    /// For `n` parameters.
    pub fn new(n: usize) -> AdamState {
        AdamState { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// One ascent step; returns updated parameters.
    pub fn step(&mut self, params: &[f64], grad: &[f64], lr: f64) -> Vec<f64> {
        let (b1, b2, eps) = (0.9, 0.999, 1e-8);
        self.t += 1;
        let mut out = params.to_vec();
        for i in 0..params.len() {
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * grad[i];
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * grad[i] * grad[i];
            let mh = self.m[i] / (1.0 - b1.powi(self.t));
            let vh = self.v[i] / (1.0 - b2.powi(self.t));
            out[i] += lr * mh / (vh.sqrt() + eps);
        }
        out
    }
}

/// Training statistics.
pub struct TrainStats {
    /// per-step minibatch mean expected log-likelihood
    pub ll_trace: Vec<f64>,
    /// wall-clock seconds
    pub seconds: f64,
}

/// Train an SVGP with alternating NGD (variational) and Adam (hypers).
pub fn train(
    model: &mut Svgp,
    data: &crate::data::Dataset,
    steps: usize,
    batch: usize,
    lr_ngd: f64,
    lr_hyper: f64,
    rng: &mut Pcg64,
) -> Result<TrainStats> {
    let mut adam = AdamState::new(model.hyper_logs().len());
    let n = data.len();
    let mut ll_trace = Vec::with_capacity(steps);
    // clock: wall-time for the reported training throughput (steps/sec).
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let idx = data.minibatch(batch, rng);
        let mut xb = Matrix::zeros(idx.len(), data.x.cols());
        let mut yb = Vec::with_capacity(idx.len());
        for (r, &i) in idx.iter().enumerate() {
            for c in 0..data.x.cols() {
                xb[(r, c)] = data.x[(i, c)];
            }
            yb.push(data.y[i]);
        }
        let ll = model.ngd_step(&xb, &yb, n, lr_ngd)?;
        ll_trace.push(ll);
        if lr_hyper > 0.0 && step % 2 == 1 {
            model.hyper_step(&xb, &yb, &mut adam, lr_hyper)?;
        }
    }
    Ok(TrainStats { ll_trace, seconds: t0.elapsed().as_secs_f64() })
}

/// Test metrics.
pub struct TestMetrics {
    /// mean negative predictive log-likelihood
    pub nll: f64,
    /// RMSE of the predictive mean (regression) / 0-1 error (classification)
    pub error: f64,
}

/// Evaluate predictive NLL and error on held-out data.
pub fn evaluate(model: &mut Svgp, data: &crate::data::Dataset) -> Result<TestMetrics> {
    let preds = model.predict(&data.x)?;
    let (nodes, weights) = gauss_hermite(20);
    let norm = std::f64::consts::PI.sqrt();
    let mut nll = 0.0;
    let mut err = 0.0;
    let classification = model.lik.name() == "bernoulli";
    for (p, &y) in preds.iter().zip(&data.y) {
        // log E_q[p(y|f)] via GH (log-sum-exp for stability)
        let c = (2.0 * p.var).sqrt();
        let mut max_lp = f64::NEG_INFINITY;
        let lps: Vec<f64> = nodes
            .iter()
            .map(|x| {
                let lp = model.lik.log_prob(y, p.mu + c * x);
                max_lp = max_lp.max(lp);
                lp
            })
            .collect();
        let s: f64 = lps.iter().zip(&weights).map(|(lp, w)| w / norm * (lp - max_lp).exp()).sum();
        nll -= max_lp + s.max(1e-300).ln();
        if classification {
            err += if (p.mu >= 0.0) != (y >= 0.0) { 1.0 } else { 0.0 };
        } else {
            err += (p.mu - y) * (p.mu - y);
        }
    }
    let n = data.len() as f64;
    Ok(TestMetrics {
        nll: nll / n,
        error: if classification { err / n } else { (err / n).sqrt() },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_regression;

    fn small_model(backend: Backend, m: usize, data: &crate::data::Dataset, rng: &mut Pcg64) -> Svgp {
        let z = data.kmeans_centers(m, 4, rng);
        Svgp::new(
            z,
            KernelType::Rbf,
            SvgpHyper { lengthscale: 0.15, outputscale: 1.0, jitter: 1e-4 },
            Box::new(Gaussian { noise: 0.05 }),
            backend,
        )
    }

    #[test]
    fn ngd_increases_data_fit() {
        let data = gaussian_regression(300, 2, 0.1, 1);
        let mut rng = Pcg64::seeded(2);
        let mut model = small_model(Backend::Cholesky, 24, &data, &mut rng);
        let stats = train(&mut model, &data, 25, 64, 0.5, 0.0, &mut rng).unwrap();
        let first = crate::util::mean(&stats.ll_trace[..5]);
        let last = crate::util::mean(&stats.ll_trace[stats.ll_trace.len() - 5..]);
        assert!(last > first, "expected LL to improve: {first} -> {last}");
    }

    #[test]
    fn ciq_and_cholesky_reach_similar_fits() {
        let data = gaussian_regression(250, 2, 0.1, 3);
        let mut rng = Pcg64::seeded(4);
        let mut chol = small_model(Backend::Cholesky, 20, &data, &mut rng);
        let mut rng2 = Pcg64::seeded(4);
        let mut ciq = small_model(
            Backend::Ciq(CiqOptions { tol: 1e-5, max_iters: 200, ..Default::default() }),
            20,
            &data,
            &mut rng2,
        );
        let mut rng_a = Pcg64::seeded(5);
        let mut rng_b = Pcg64::seeded(5);
        train(&mut chol, &data, 30, 64, 0.5, 0.0, &mut rng_a).unwrap();
        train(&mut ciq, &data, 30, 64, 0.5, 0.0, &mut rng_b).unwrap();
        let m_chol = evaluate(&mut chol, &data).unwrap();
        let m_ciq = evaluate(&mut ciq, &data).unwrap();
        // whitening differs by an orthogonal rotation; fits should agree
        assert!(
            (m_chol.nll - m_ciq.nll).abs() < 0.25,
            "NLL chol {} vs ciq {}",
            m_chol.nll,
            m_ciq.nll
        );
        assert!(!ciq.iteration_log.is_empty(), "CIQ should log msMINRES iterations");
    }

    #[test]
    fn gaussian_fit_beats_constant_predictor() {
        let data = gaussian_regression(400, 2, 0.15, 6);
        let mut rng = Pcg64::seeded(7);
        let (train_set, test_set) = data.split(0.8, &mut rng);
        let mut model = small_model(Backend::Cholesky, 32, &train_set, &mut rng);
        train(&mut model, &train_set, 40, 64, 0.5, 0.02, &mut rng).unwrap();
        let m = evaluate(&mut model, &test_set).unwrap();
        // y is standardized, so a constant predictor has RMSE ≈ 1
        assert!(m.error < 0.8, "SVGP RMSE {} should beat constant 1.0", m.error);
    }

    #[test]
    fn bernoulli_classification_learns() {
        let data = crate::data::binary_classification(400, 2, 0.05, 8);
        let mut rng = Pcg64::seeded(9);
        let z = data.kmeans_centers(24, 4, &mut rng);
        let mut model = Svgp::new(
            z,
            KernelType::Rbf,
            SvgpHyper { lengthscale: 0.2, outputscale: 1.5, jitter: 1e-4 },
            Box::new(Bernoulli),
            Backend::Cholesky,
        );
        train(&mut model, &data, 40, 64, 0.4, 0.0, &mut rng).unwrap();
        let m = evaluate(&mut model, &data).unwrap();
        assert!(m.error < 0.35, "0/1 error {} should beat chance", m.error);
    }

    #[test]
    fn stochastic_kl_matches_exact() {
        let data = gaussian_regression(200, 2, 0.1, 12);
        let mut rng = Pcg64::seeded(13);
        let mut model = small_model(Backend::Cholesky, 16, &data, &mut rng);
        train(&mut model, &data, 15, 64, 0.5, 0.0, &mut rng).unwrap();
        let exact = model.kl().unwrap();
        let est = model.kl_stochastic(60, 14).unwrap();
        assert!(
            (est - exact).abs() < 0.15 * exact.abs().max(1.0),
            "stochastic KL {est} vs exact {exact}"
        );
    }

    #[test]
    fn kl_zero_at_init_and_positive_after() {
        let data = gaussian_regression(100, 2, 0.1, 10);
        let mut rng = Pcg64::seeded(11);
        let mut model = small_model(Backend::Cholesky, 12, &data, &mut rng);
        let kl0 = model.kl().unwrap();
        assert!(kl0.abs() < 1e-8, "KL at init {kl0}");
        train(&mut model, &data, 10, 32, 0.5, 0.0, &mut rng).unwrap();
        let kl1 = model.kl().unwrap();
        assert!(kl1 > 0.0, "KL after training {kl1}");
    }
}

//! Observation likelihoods for SVGP: Gaussian (3droad), Student-T
//! (precipitation) and Bernoulli-logistic (covtype) — Sec. 5.1.

use crate::special::ln_gamma;

/// A factorized observation likelihood `p(y | f)`.
pub trait Likelihood: Sync + Send {
    /// `log p(y | f)`.
    fn log_prob(&self, y: f64, f: f64) -> f64;
    /// `∂ log p / ∂f`.
    fn dlogp_df(&self, y: f64, f: f64) -> f64;
    /// Mutable likelihood parameters as log-values (for hyper learning).
    fn log_params(&self) -> Vec<f64>;
    /// Set parameters from log-values.
    fn set_log_params(&mut self, p: &[f64]);
    /// Name for reports.
    fn name(&self) -> &'static str;
}

/// Gaussian: `y = f + ε`, `ε ~ N(0, σ²)`.
#[derive(Clone, Debug)]
pub struct Gaussian {
    /// observation variance σ²
    pub noise: f64,
}

impl Likelihood for Gaussian {
    fn log_prob(&self, y: f64, f: f64) -> f64 {
        let d = y - f;
        -0.5 * d * d / self.noise - 0.5 * (2.0 * std::f64::consts::PI * self.noise).ln()
    }
    fn dlogp_df(&self, y: f64, f: f64) -> f64 {
        (y - f) / self.noise
    }
    fn log_params(&self) -> Vec<f64> {
        vec![self.noise.ln()]
    }
    fn set_log_params(&mut self, p: &[f64]) {
        self.noise = p[0].exp().clamp(1e-6, 1e2);
    }
    fn name(&self) -> &'static str {
        "gaussian"
    }
}

/// Student-T with `ν` degrees of freedom and scale `s` (heavy-tailed noise).
#[derive(Clone, Debug)]
pub struct StudentT {
    /// degrees of freedom ν (> 2 keeps variance finite)
    pub nu: f64,
    /// scale s²
    pub scale2: f64,
}

impl Likelihood for StudentT {
    fn log_prob(&self, y: f64, f: f64) -> f64 {
        let d2 = (y - f) * (y - f);
        ln_gamma((self.nu + 1.0) / 2.0)
            - ln_gamma(self.nu / 2.0)
            - 0.5 * (self.nu * std::f64::consts::PI * self.scale2).ln()
            - 0.5 * (self.nu + 1.0) * (1.0 + d2 / (self.nu * self.scale2)).ln()
    }
    fn dlogp_df(&self, y: f64, f: f64) -> f64 {
        let d = y - f;
        (self.nu + 1.0) * d / (self.nu * self.scale2 + d * d)
    }
    fn log_params(&self) -> Vec<f64> {
        vec![self.nu.ln(), self.scale2.ln()]
    }
    fn set_log_params(&mut self, p: &[f64]) {
        self.nu = p[0].exp().clamp(2.1, 100.0);
        self.scale2 = p[1].exp().clamp(1e-6, 1e2);
    }
    fn name(&self) -> &'static str {
        "student_t"
    }
}

/// Bernoulli with logistic link; labels `y ∈ {−1, +1}`.
#[derive(Clone, Debug)]
pub struct Bernoulli;

impl Likelihood for Bernoulli {
    fn log_prob(&self, y: f64, f: f64) -> f64 {
        // log σ(y f) = −log(1 + e^{−y f}), numerically stable
        let z = y * f;
        if z > 0.0 {
            -((-z).exp().ln_1p())
        } else {
            z - (z.exp().ln_1p())
        }
    }
    fn dlogp_df(&self, y: f64, f: f64) -> f64 {
        // y σ(−y f)
        let z = y * f;
        y / (1.0 + z.exp())
    }
    fn log_params(&self) -> Vec<f64> {
        vec![]
    }
    fn set_log_params(&mut self, _p: &[f64]) {}
    fn name(&self) -> &'static str {
        "bernoulli"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_grad(lik: &dyn Likelihood, y: f64, f: f64) {
        let h = 1e-6;
        let fd = (lik.log_prob(y, f + h) - lik.log_prob(y, f - h)) / (2.0 * h);
        let an = lik.dlogp_df(y, f);
        assert!((fd - an).abs() < 1e-5, "{}: fd {fd} vs {an}", lik.name());
    }

    #[test]
    fn gradients_match_fd() {
        for &(y, f) in &[(0.5, 0.2), (-1.3, 0.9), (2.0, -2.0)] {
            check_grad(&Gaussian { noise: 0.3 }, y, f);
            check_grad(&StudentT { nu: 4.0, scale2: 0.5 }, y, f);
        }
        for &(y, f) in &[(1.0, 0.7), (-1.0, 0.7), (1.0, -3.0)] {
            check_grad(&Bernoulli, y, f);
        }
    }

    #[test]
    fn gaussian_normalizes() {
        // ∫ p(y|f) dy = 1 via simple quadrature
        let lik = Gaussian { noise: 0.4 };
        let mut acc = 0.0;
        let h = 0.01;
        let mut y = -8.0;
        while y < 8.0 {
            acc += lik.log_prob(y, 0.3).exp() * h;
            y += h;
        }
        assert!((acc - 1.0).abs() < 1e-3, "integral {acc}");
    }

    #[test]
    fn student_t_heavier_tail_than_gaussian() {
        let g = Gaussian { noise: 1.0 };
        let t = StudentT { nu: 3.0, scale2: 1.0 };
        assert!(t.log_prob(6.0, 0.0) > g.log_prob(6.0, 0.0));
    }

    #[test]
    fn bernoulli_symmetry_and_range() {
        let b = Bernoulli;
        for &f in &[-2.0, 0.0, 1.5] {
            let lp = b.log_prob(1.0, f);
            let lm = b.log_prob(-1.0, f);
            assert!(((lp.exp() + lm.exp()) - 1.0).abs() < 1e-12);
        }
    }
}

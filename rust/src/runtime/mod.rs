//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced once by
//! `python/compile/aot.py`) and execute them from the Rust request path.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes `HloModuleProto` with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md). Artifacts are
//! discovered by filename convention:
//!
//! * `kernel_mvm_n{n}_d{d}_r{r}_{kernel}.hlo.txt` — batched kernel MVM
//! * `ciq_sqrt_n{n}_d{d}_q{q}_j{j}_{kernel}.hlo.txt` — full CIQ pipeline
//!
//! Everything here is f32 (the artifacts' dtype); the f64 library API
//! converts at the boundary. That narrowing is **not** steered by the
//! service-wide [`Precision`](crate::linalg::Precision) policy: the dtype is
//! fixed when the artifact is AOT-compiled, long before any solve-time
//! policy exists, so each cast site below carries a `// precision:` note
//! naming this contract instead of routing through the enum (structlint
//! rule 7).
//!
//! The crate is dependency-free and builds fully offline, so the real `xla`
//! FFI bindings cannot be linked here; the in-module `xla` stub below keeps
//! this module compilable and fails fast at [`Runtime::cpu`]. See the
//! stub's docs for the swap-in recipe.

use crate::linalg::Matrix;
use crate::operators::LinearOp;
use crate::{Error, Result};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Inert stand-in for the `xla` FFI crate (PJRT bindings over
/// `libxla_extension.so`). Every entry point that would need the extension
/// reports an error instead — [`Runtime::cpu`] is the first such gate, so
/// callers (the `artifacts` subcommand, `examples/end_to_end.rs`, the
/// integration tests) degrade to their no-runtime skip paths. Linking the
/// real bindings is a two-line swap: delete this module and add the `xla`
/// crate to `[dependencies]` — the outer module's call sites match its API.
/// Public because [`Runtime::execute`] takes `&[xla::Literal]`, exactly as it
/// would with the real crate in scope.
#[allow(dead_code)]
pub mod xla {
    use std::fmt;
    use std::path::Path;

    /// Error surfaced by every stub entry point.
    pub struct XlaError;

    impl fmt::Display for XlaError {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "xla_extension not linked (dependency-free build)")
        }
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, XlaError> {
            Err(XlaError)
        }

        pub fn platform_name(&self) -> String {
            "unlinked".to_string()
        }

        pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
            Err(XlaError)
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
            Err(XlaError)
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
            Err(XlaError)
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, XlaError> {
            Err(XlaError)
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct Literal;

    impl Literal {
        pub fn vec1(_data: &[f32]) -> Literal {
            Literal
        }

        pub fn scalar(_v: f32) -> Literal {
            Literal
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
            Err(XlaError)
        }

        pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
            Err(XlaError)
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
            Err(XlaError)
        }
    }
}

/// Parsed artifact descriptor.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// `kernel_mvm` or `ciq_sqrt`.
    pub kind: String,
    /// kernel family name (`rbf`, `matern52`, …).
    pub kernel: String,
    /// data size `n`.
    pub n: usize,
    /// data dimension `d`.
    pub d: usize,
    /// RHS batch (kernel_mvm) — 0 if absent.
    pub r: usize,
    /// quadrature points (ciq_sqrt) — 0 if absent.
    pub q: usize,
    /// msMINRES iterations (ciq_sqrt) — 0 if absent.
    pub j: usize,
    /// file path.
    pub path: PathBuf,
}

/// Parse an artifact filename like `kernel_mvm_n256_d2_r8_rbf.hlo.txt`.
pub fn parse_artifact_name(path: &Path) -> Option<ArtifactMeta> {
    let stem = path.file_name()?.to_str()?.strip_suffix(".hlo.txt")?;
    let parts: Vec<&str> = stem.split('_').collect();
    // kind has one underscore (kernel_mvm / ciq_sqrt)
    if parts.len() < 4 {
        return None;
    }
    let kind = format!("{}_{}", parts[0], parts[1]);
    if kind != "kernel_mvm" && kind != "ciq_sqrt" {
        return None;
    }
    let mut meta = ArtifactMeta {
        kind,
        kernel: String::new(),
        n: 0,
        d: 0,
        r: 0,
        q: 0,
        j: 0,
        path: path.to_path_buf(),
    };
    for tok in &parts[2..] {
        if let Some(v) = tok.strip_prefix('n').and_then(|s| s.parse::<usize>().ok()) {
            meta.n = v;
        } else if let Some(v) = tok.strip_prefix('d').and_then(|s| s.parse::<usize>().ok()) {
            meta.d = v;
        } else if let Some(v) = tok.strip_prefix('r').and_then(|s| s.parse::<usize>().ok()) {
            meta.r = v;
        } else if let Some(v) = tok.strip_prefix('q').and_then(|s| s.parse::<usize>().ok()) {
            meta.q = v;
        } else if let Some(v) = tok.strip_prefix('j').and_then(|s| s.parse::<usize>().ok()) {
            meta.j = v;
        } else {
            meta.kernel = tok.to_string();
        }
    }
    Some(meta)
}

/// Scan a directory for artifacts.
pub fn discover_artifacts(dir: &Path) -> Vec<ArtifactMeta> {
    let mut out = Vec::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            if let Some(meta) = parse_artifact_name(&e.path()) {
                out.push(meta);
            }
        }
    }
    out.sort_by(|a, b| a.path.cmp(&b.path));
    out
}

/// A compiled PJRT executable plus its metadata.
///
/// Safety: the PJRT CPU client is internally synchronized for execution; we
/// additionally serialize all calls through a `Mutex`, so sharing across
/// threads is sound even though the FFI handle is a raw pointer.
pub struct Executable {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    /// artifact descriptor
    pub meta: ArtifactMeta,
}

// SAFETY: see the struct docs — the FFI handle is only reached through the
// `Mutex`, which serializes all cross-thread access.
unsafe impl Send for Executable {}
// SAFETY: as above.
unsafe impl Sync for Executable {}

/// PJRT runtime holding a CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

// SAFETY: same argument as for `Executable` — access is serialized by our
// wrappers.
unsafe impl Send for Runtime {}
// SAFETY: as above.
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Create a PJRT CPU runtime.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| Error::Runtime(format!("pjrt cpu: {e}")))?;
        Ok(Runtime { client })
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn load(&self, meta: &ArtifactMeta) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(&meta.path)
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", meta.path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {}: {e}", meta.path.display())))?;
        Ok(Executable { exe: Mutex::new(exe), meta: meta.clone() })
    }

    /// Execute with literal inputs; returns the flattened f32 output of the
    /// single-tuple result.
    pub fn execute(&self, exe: &Executable, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let guard = exe.exe.lock().unwrap();
        let result = guard
            .execute::<xla::Literal>(inputs)
            .map_err(|e| Error::Runtime(format!("execute: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal: {e}")))?;
        let out = lit
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("to_tuple1: {e}")))?;
        out.to_vec::<f32>().map_err(|e| Error::Runtime(format!("to_vec: {e}")))
    }
}

fn literal_matrix(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| Error::Runtime(format!("reshape: {e}")))
}

/// A kernel-MVM artifact exposed as a [`LinearOp`] — the kernel matrix is
/// computed tile-by-tile by the Pallas kernel inside the artifact.
pub struct XlaKernelMvm<'r> {
    rt: &'r Runtime,
    exe: Executable,
    /// lengthscale-scaled data, f32, row-major `n × d`
    xs: Vec<f32>,
    s2: f32,
    noise: f32,
}

impl<'r> XlaKernelMvm<'r> {
    /// Bind data + hyperparameters to a `kernel_mvm` artifact. `x` is the
    /// *unscaled* data; scaling by `1/lengthscale` happens here.
    pub fn new(
        rt: &'r Runtime,
        exe: Executable,
        x: &Matrix,
        lengthscale: f64,
        outputscale: f64,
        noise: f64,
    ) -> Result<XlaKernelMvm<'r>> {
        if exe.meta.kind != "kernel_mvm" {
            return Err(Error::Invalid(format!("artifact kind {} != kernel_mvm", exe.meta.kind)));
        }
        if x.rows() != exe.meta.n || x.cols() != exe.meta.d {
            return Err(Error::Shape(format!(
                "data {}x{} vs artifact {}x{}",
                x.rows(),
                x.cols(),
                exe.meta.n,
                exe.meta.d
            )));
        }
        // precision: the artifact is AOT-compiled f32 — data and
        // hyperparameters narrow once at this binding boundary (module docs).
        let xs: Vec<f32> = x.as_slice().iter().map(|&v| (v / lengthscale) as f32).collect();
        Ok(XlaKernelMvm { rt, exe, xs, s2: outputscale as f32, noise: noise as f32 })
    }

    /// The artifact's fixed RHS batch width.
    pub fn batch_width(&self) -> usize {
        self.exe.meta.r
    }

    fn run_batch(&self, b: &[f32]) -> Result<Vec<f32>> {
        let (n, d, r) = (self.exe.meta.n, self.exe.meta.d, self.exe.meta.r);
        let inputs = [
            literal_matrix(&self.xs, n, d)?,
            literal_matrix(b, n, r)?,
            xla::Literal::scalar(self.s2),
            xla::Literal::scalar(self.noise),
        ];
        self.rt.execute(&self.exe, &inputs)
    }
}

impl LinearOp for XlaKernelMvm<'_> {
    fn size(&self) -> usize {
        self.exe.meta.n
    }

    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let m = Matrix::from_vec(x.len(), 1, x.to_vec());
        let out = self.matmat(&m);
        out.as_slice().to_vec()
    }

    fn matmat(&self, x: &Matrix) -> Matrix {
        let (n, r) = (self.exe.meta.n, self.exe.meta.r);
        assert_eq!(x.rows(), n);
        let cols = x.cols();
        let mut out = Matrix::zeros(n, cols);
        // process `r` columns at a time, zero-padding the final batch
        let mut j0 = 0;
        while j0 < cols {
            let take = r.min(cols - j0);
            let mut batch = vec![0.0f32; n * r];
            // precision: the artifact consumes f32 right-hand sides (module
            // docs); results widen back to f64 below.
            for i in 0..n {
                for jj in 0..take {
                    batch[i * r + jj] = x[(i, j0 + jj)] as f32;
                }
            }
            let res = self.run_batch(&batch).expect("xla kernel mvm failed");
            for i in 0..n {
                for jj in 0..take {
                    out[(i, j0 + jj)] = res[i * r + jj] as f64;
                }
            }
            j0 += take;
        }
        out
    }
}

/// The full CIQ pipeline artifact: one PJRT call computes `K^{1/2}b`,
/// `K^{-1/2}b` and the msMINRES residual.
pub struct XlaCiq<'r> {
    rt: &'r Runtime,
    exe: Executable,
}

/// Output of [`XlaCiq::run`].
pub struct XlaCiqOutput {
    /// `K^{1/2} b`.
    pub sqrt: Vec<f64>,
    /// `K^{-1/2} b`.
    pub inv_sqrt: Vec<f64>,
    /// max relative msMINRES residual.
    pub residual: f64,
}

impl<'r> XlaCiq<'r> {
    /// Wrap a `ciq_sqrt` artifact.
    pub fn new(rt: &'r Runtime, exe: Executable) -> Result<XlaCiq<'r>> {
        if exe.meta.kind != "ciq_sqrt" {
            return Err(Error::Invalid(format!("artifact kind {} != ciq_sqrt", exe.meta.kind)));
        }
        Ok(XlaCiq { rt, exe })
    }

    /// Number of quadrature points the artifact was lowered with.
    pub fn q(&self) -> usize {
        self.exe.meta.q
    }

    /// Data size.
    pub fn n(&self) -> usize {
        self.exe.meta.n
    }

    /// Execute the pipeline.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        x: &Matrix,
        lengthscale: f64,
        outputscale: f64,
        noise: f64,
        b: &[f64],
        shifts: &[f64],
        weights: &[f64],
    ) -> Result<XlaCiqOutput> {
        let (n, d, q) = (self.exe.meta.n, self.exe.meta.d, self.exe.meta.q);
        if x.rows() != n || x.cols() != d || b.len() != n || shifts.len() != q || weights.len() != q {
            return Err(Error::Shape("ciq artifact input shape mismatch".into()));
        }
        // precision: the artifact is AOT-compiled f32 — every pipeline input
        // narrows at this boundary (module docs).
        let xs: Vec<f32> = x.as_slice().iter().map(|&v| (v / lengthscale) as f32).collect();
        let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let sf: Vec<f32> = shifts.iter().map(|&v| v as f32).collect();
        let wf: Vec<f32> = weights.iter().map(|&v| v as f32).collect();
        let inputs = [
            literal_matrix(&xs, n, d)?,
            xla::Literal::vec1(&bf),
            xla::Literal::vec1(&sf),
            xla::Literal::vec1(&wf),
            // precision: scalar hyperparameters narrow with the rest of the
            // artifact's f32 inputs (module docs).
            xla::Literal::scalar(outputscale as f32),
            xla::Literal::scalar(noise as f32),
        ];
        let out = self.rt.execute(&self.exe, &inputs)?;
        if out.len() != 2 * n + 1 {
            return Err(Error::Runtime(format!("ciq output len {} != {}", out.len(), 2 * n + 1)));
        }
        Ok(XlaCiqOutput {
            sqrt: out[..n].iter().map(|&v| v as f64).collect(),
            inv_sqrt: out[n..2 * n].iter().map(|&v| v as f64).collect(),
            residual: out[2 * n] as f64,
        })
    }
}

/// Default artifacts directory (`$CIQ_ARTIFACTS` or `./artifacts`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("CIQ_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_artifact_names() {
        let m = parse_artifact_name(Path::new("kernel_mvm_n256_d2_r8_rbf.hlo.txt")).unwrap();
        assert_eq!(m.kind, "kernel_mvm");
        assert_eq!((m.n, m.d, m.r), (256, 2, 8));
        assert_eq!(m.kernel, "rbf");
        let c = parse_artifact_name(Path::new("ciq_sqrt_n256_d2_q8_j64_matern52.hlo.txt")).unwrap();
        assert_eq!(c.kind, "ciq_sqrt");
        assert_eq!((c.n, c.q, c.j), (256, 8, 64));
        assert_eq!(c.kernel, "matern52");
        assert!(parse_artifact_name(Path::new("whatever.txt")).is_none());
        assert!(parse_artifact_name(Path::new("other_thing_n2.hlo.txt")).is_none());
    }
}

//! Batched dense Newton–Schulz square roots: the small-`N` tier of the
//! solve stack.
//!
//! The msMINRES/CIQ machinery (this crate's namesake) wins when `K` is
//! large and MVM-bound; for fleets of *small* posteriors the per-request
//! Krylov iteration is pure overhead. Following the batched-sqrt exemplars
//! (Lin & Maji's `matrix-sqrt`, its bcnn and FastDifferentiableMatSqrt
//! descendants), this module computes `K^{1/2}` and `K^{-1/2}` for a whole
//! **stack** of materialized small SPD operators with nothing but GEMMs:
//!
//! Trace-normalize each element: `norm_i = trace(A_i)`. For SPD `A`,
//! `trace(A) ≥ λ_max`, so every eigenvalue of `A_i / norm_i` lies in
//! `(0, 1]` — exactly the region where the coupled Newton–Schulz iteration
//!
//! ```text
//! Y_0 = A/norm,  Z_0 = I
//! T_k = ½ (3 I − Z_k Y_k),   Y_{k+1} = Y_k T_k,   Z_{k+1} = T_k Z_k
//! ```
//!
//! converges quadratically with `Y_k → (A/norm)^{1/2}` and
//! `Z_k → (A/norm)^{-1/2}`; un-normalizing gives `K^{1/2} = √norm · Y` and
//! `K^{-1/2} = Z / √norm`. Convergence is monitored per batch element
//! through the identity `Z_k Y_k = 3I − 2 T_k`: the scaled residual
//! `r_k = ‖Z_k Y_k − I‖_F / √n` is available from the product the
//! iteration computes anyway, so converged elements **exit early** (their
//! factors are finalized into the output stack and the remaining GEMM
//! passes skip them) while stragglers keep iterating. An element that
//! fails to reach `tol` within `max_iters` — a numerically singular `A`
//! has a zero eigenvalue the product map `p ← p(3−p)²/4` can never lift —
//! is reported with `converged = false`, and the coordinator routes its
//! requests through the msMINRES path instead (the guaranteed fallback;
//! see `rust/DESIGN.md` §6).
//!
//! The backward pass solves the Lyapunov equation
//! `dL/dY · Y + Y · dL/dY = dL/dA`-style sensitivity by the matching
//! coupled iteration from the exemplars
//! ([`newton_schulz_backward_stack_in`]).
//!
//! Everything here is allocation-free in the steady state: all scratch
//! (`Y`/`Z`/temp stacks, per-element norms and flags) is checked out of
//! the caller's [`SolveWorkspace`], the batched GEMM phases run through
//! [`crate::linalg::batched`]'s chunk-pool parallelism (one batch element
//! per disjoint output block), and results land in a caller-owned
//! [`DenseFactorStack`]. `rust/tests/alloc_regression.rs` pins the
//! zero-allocation claim with the counting global allocator.

use crate::linalg::gemm::{gemm_nn, gemm_tn};
use crate::linalg::SolveWorkspace;
use crate::util::threadpool::parallel_fill;

/// Iteration knobs for the forward Newton–Schulz solve.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseSqrtOptions {
    /// Iteration cap per batch element. Quadratic convergence makes ~20
    /// iterations enough for condition numbers into the 1e6 range; the
    /// default leaves headroom so `converged = false` genuinely means
    /// "numerically singular", not "impatient".
    pub max_iters: usize,
    /// Scaled-residual exit threshold on `‖Z_k Y_k − I‖_F / √n`.
    pub tol: f64,
}

impl Default for DenseSqrtOptions {
    fn default() -> DenseSqrtOptions {
        DenseSqrtOptions { max_iters: 40, tol: 1e-13 }
    }
}

/// Configuration of the coordinator's batched-dense tier
/// ([`crate::ciq::SolverPolicy::BatchedDense`]): which operators the tier
/// captures and how hard the Newton–Schulz iteration tries before handing
/// an operator back to the Krylov path.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchedDenseConfig {
    /// Operators with `size() ≤ n_threshold` are served by the dense tier;
    /// larger ones stay on per-operator Krylov shards. The default tracks
    /// the measured crossover of `perf_hotpath` §8 (`BENCH_batched_dense`).
    pub n_threshold: usize,
    /// Forward-iteration cap (see [`DenseSqrtOptions::max_iters`]).
    pub max_iters: usize,
    /// Forward residual tolerance (see [`DenseSqrtOptions::tol`]). The
    /// default sits near f64 roundoff so dense-tier answers match the
    /// Krylov path to ≤ 1e-6 even at high quadrature accuracy.
    pub tol: f64,
}

impl Default for BatchedDenseConfig {
    fn default() -> BatchedDenseConfig {
        BatchedDenseConfig { n_threshold: 256, max_iters: 40, tol: 1e-13 }
    }
}

impl BatchedDenseConfig {
    /// The forward-iteration options this tier runs under.
    pub fn sqrt_opts(&self) -> DenseSqrtOptions {
        DenseSqrtOptions { max_iters: self.max_iters, tol: self.tol }
    }
}

/// Output of one batched forward solve: `batch` pairs of `n×n` factors
/// plus per-element convergence diagnostics. Allocated once by the caller
/// ([`DenseFactorStack::new`]) and refilled in place on every
/// [`newton_schulz_stack_in`] call — the solve itself never allocates.
#[derive(Clone, Debug)]
pub struct DenseFactorStack {
    n: usize,
    batch: usize,
    /// `batch` row-major `n×n` matrices `≈ A_i^{1/2}` (stride `n·n`).
    pub sqrt: Vec<f64>,
    /// `batch` row-major `n×n` matrices `≈ A_i^{-1/2}`.
    pub invsqrt: Vec<f64>,
    /// Whether element `i` hit `tol` within `max_iters`. A `false` entry's
    /// factors are best-effort and must not be served — fall back to
    /// msMINRES.
    pub converged: Vec<bool>,
    /// Newton–Schulz updates element `i` performed before exit.
    pub iters: Vec<usize>,
    /// Final scaled residual `‖Z Y − I‖_F / √n` per element.
    pub residuals: Vec<f64>,
}

impl DenseFactorStack {
    /// A zeroed stack for `batch` elements of size `n` (the one allocation
    /// of the dense tier's lifecycle).
    pub fn new(n: usize, batch: usize) -> DenseFactorStack {
        DenseFactorStack {
            n,
            batch,
            sqrt: vec![0.0; batch * n * n],
            invsqrt: vec![0.0; batch * n * n],
            converged: vec![false; batch],
            iters: vec![0; batch],
            residuals: vec![f64::INFINITY; batch],
        }
    }

    /// Element size `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of batch elements.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Row-major `n×n` slice `≈ A_i^{1/2}`.
    pub fn sqrt_mat(&self, i: usize) -> &[f64] {
        let nn = self.n * self.n;
        &self.sqrt[i * nn..(i + 1) * nn]
    }

    /// Row-major `n×n` slice `≈ A_i^{-1/2}`.
    pub fn invsqrt_mat(&self, i: usize) -> &[f64] {
        let nn = self.n * self.n;
        &self.invsqrt[i * nn..(i + 1) * nn]
    }

    /// Whether every element converged.
    pub fn all_converged(&self) -> bool {
        self.converged.iter().all(|&c| c)
    }

    /// Clone element `i` out into a standalone per-operator cache unit.
    pub fn extract_pair(&self, i: usize) -> DenseFactorPair {
        DenseFactorPair {
            n: self.n,
            sqrt: self.sqrt_mat(i).to_vec(),
            invsqrt: self.invsqrt_mat(i).to_vec(),
            converged: self.converged[i],
            iters: self.iters[i],
            residual: self.residuals[i],
        }
    }
}

/// One operator's cached dense factors — what the coordinator stores per
/// operator version and applies with [`crate::linalg::batched::gemv_gather`]
/// on every size-class flush.
#[derive(Clone, Debug)]
pub struct DenseFactorPair {
    /// Factor dimension.
    pub n: usize,
    /// Row-major `n×n` `≈ K^{1/2}`.
    pub sqrt: Vec<f64>,
    /// Row-major `n×n` `≈ K^{-1/2}`.
    pub invsqrt: Vec<f64>,
    /// `false` marks the operator dense-incapable (serve via msMINRES).
    pub converged: bool,
    /// Forward iterations performed (feeds the backward pass).
    pub iters: usize,
    /// Final scaled residual.
    pub residual: f64,
}

/// Trace-normalized coupled Newton–Schulz over a stack of `batch`
/// row-major `n×n` SPD matrices (`a_stack`, stride `n·n`), writing
/// `A_i^{1/2}` / `A_i^{-1/2}` and per-element diagnostics into `out`.
///
/// Each iteration runs three batched GEMM passes (`T = Z·Y`, `Y·T`,
/// `T·Z`) parallelized across the batch dimension on the chunk pool, with
/// converged elements skipped in place; the residual check rides on the
/// `Z·Y` product the iteration needs anyway. All scratch comes from `ws`,
/// so a warmed workspace runs the whole solve without heap allocation.
///
/// Elements whose trace is non-positive or non-finite (not SPD) are marked
/// `converged = false` immediately; elements that exhaust `max_iters`
/// keep their best-effort factors but also report `converged = false`.
pub fn newton_schulz_stack_in(
    ws: &mut SolveWorkspace,
    n: usize,
    batch: usize,
    a_stack: &[f64],
    opts: &DenseSqrtOptions,
    out: &mut DenseFactorStack,
) {
    assert_eq!(a_stack.len(), batch * n * n, "newton_schulz_stack_in: A stack size");
    assert_eq!(out.n, n, "newton_schulz_stack_in: output stack dimension");
    assert_eq!(out.batch, batch, "newton_schulz_stack_in: output stack batch");
    if batch == 0 || n == 0 {
        return;
    }
    let nn = n * n;
    let sqrt_n = (n as f64).sqrt();
    let mut y = ws.take_vec(batch * nn);
    let mut z = ws.take_vec(batch * nn);
    let mut t = ws.take_vec(batch * nn);
    let mut y2 = ws.take_vec(batch * nn);
    let mut z2 = ws.take_vec(batch * nn);
    let mut norms = ws.take_vec(batch);
    // 0 = active, 1 = finalized (take_usize hands the buffer back zeroed).
    let mut state = ws.take_usize(batch);

    for i in 0..batch {
        let a = &a_stack[i * nn..(i + 1) * nn];
        let trace: f64 = (0..n).map(|r| a[r * n + r]).sum();
        out.iters[i] = 0;
        out.residuals[i] = f64::INFINITY;
        out.converged[i] = false;
        if !trace.is_finite() || trace <= 0.0 {
            // Not plausibly SPD: mark dense-incapable without iterating.
            out.sqrt[i * nn..(i + 1) * nn].fill(0.0);
            out.invsqrt[i * nn..(i + 1) * nn].fill(0.0);
            state[i] = 1;
            continue;
        }
        norms[i] = trace;
        let yi = &mut y[i * nn..(i + 1) * nn];
        for (dst, src) in yi.iter_mut().zip(a.iter()) {
            *dst = src / trace;
        }
        let zi = &mut z[i * nn..(i + 1) * nn];
        zi.fill(0.0);
        for r in 0..n {
            zi[r * n + r] = 1.0;
        }
    }

    let mut remaining = state.iter().filter(|&&s| s == 0).count();
    for iter in 0..opts.max_iters {
        if remaining == 0 {
            break;
        }
        // T ← Z·Y for every active element (one block per element; done
        // elements cost a flag check).
        parallel_fill(&mut t, nn, |start, block| {
            let i = start / nn;
            if state[i] != 0 {
                return;
            }
            block.fill(0.0);
            gemm_nn(n, n, n, &z[i * nn..(i + 1) * nn], &y[i * nn..(i + 1) * nn], block);
        });
        // Residual check + in-place transform T ← ³⁄₂I − ½T (serial: O(batch·n²)
        // against the O(batch·n³) GEMM phases).
        for i in 0..batch {
            if state[i] != 0 {
                continue;
            }
            let ti = &mut t[i * nn..(i + 1) * nn];
            let mut frob2 = 0.0;
            for r in 0..n {
                for c in 0..n {
                    let d = ti[r * n + c] - if r == c { 1.0 } else { 0.0 };
                    frob2 += d * d;
                }
            }
            let r = frob2.sqrt() / sqrt_n;
            out.residuals[i] = r;
            out.iters[i] = iter;
            if r <= opts.tol && r.is_finite() {
                let scale = norms[i].sqrt();
                let yi = &y[i * nn..(i + 1) * nn];
                let zi = &z[i * nn..(i + 1) * nn];
                for (dst, src) in out.sqrt[i * nn..(i + 1) * nn].iter_mut().zip(yi.iter()) {
                    *dst = src * scale;
                }
                for (dst, src) in out.invsqrt[i * nn..(i + 1) * nn].iter_mut().zip(zi.iter()) {
                    *dst = src / scale;
                }
                out.converged[i] = true;
                state[i] = 1;
                remaining -= 1;
                continue;
            }
            for v in ti.iter_mut() {
                *v = -0.5 * *v;
            }
            for r in 0..n {
                ti[r * n + r] += 1.5;
            }
        }
        if remaining == 0 {
            break;
        }
        // Y' ← Y·T and Z' ← T·Z for the stragglers.
        parallel_fill(&mut y2, nn, |start, block| {
            let i = start / nn;
            if state[i] != 0 {
                return;
            }
            block.fill(0.0);
            gemm_nn(n, n, n, &y[i * nn..(i + 1) * nn], &t[i * nn..(i + 1) * nn], block);
        });
        parallel_fill(&mut z2, nn, |start, block| {
            let i = start / nn;
            if state[i] != 0 {
                return;
            }
            block.fill(0.0);
            gemm_nn(n, n, n, &t[i * nn..(i + 1) * nn], &z[i * nn..(i + 1) * nn], block);
        });
        // Finalized elements' stale blocks swap along harmlessly — their
        // factors already live in `out` and every phase skips them.
        std::mem::swap(&mut y, &mut y2);
        std::mem::swap(&mut z, &mut z2);
    }

    // Stragglers at the cap: best-effort factors, converged = false.
    for i in 0..batch {
        if state[i] != 0 {
            continue;
        }
        let scale = norms[i].sqrt();
        out.iters[i] = opts.max_iters;
        for (dst, src) in
            out.sqrt[i * nn..(i + 1) * nn].iter_mut().zip(y[i * nn..(i + 1) * nn].iter())
        {
            *dst = src * scale;
        }
        for (dst, src) in
            out.invsqrt[i * nn..(i + 1) * nn].iter_mut().zip(z[i * nn..(i + 1) * nn].iter())
        {
            *dst = src / scale;
        }
    }

    ws.give_usize(state);
    ws.give_vec(norms);
    ws.give_vec(z2);
    ws.give_vec(y2);
    ws.give_vec(t);
    ws.give_vec(z);
    ws.give_vec(y);
}

/// Lyapunov-equation backward pass for the batched square root, after the
/// exemplars' `lyap_newton_schulz`: given the forward outputs
/// `Y_i ≈ A_i^{1/2}` (`sqrt_stack`) and upstream gradients
/// `dL/dY_i` (`grad_stack`), computes `dL/dA_i` into `out` by the coupled
/// iteration
///
/// ```text
/// a_0 = Y/‖Y‖_F,  q_0 = dL/dY / ‖Y‖_F
/// q_{k+1} = ½ [ q (3I − a²) − aᵀ (aᵀ q − q a) ]
/// a_{k+1} = ½ a (3I − a²)
/// dL/dA  = ½ q_final
/// ```
///
/// which drives `a → I` while `q` contracts to the solution of the
/// Lyapunov sensitivity equation `Y·dA + dA·Y = dY`. Iterations are
/// per-element `iters[i]` with a floor of 10: the backward fixed point
/// needs its own convergence budget even when the forward exited early.
///
/// Runs serially over the batch (this is the training path, not the
/// serving hot path); the six `n×n` scratch buffers come from `ws` and are
/// reused across elements.
pub fn newton_schulz_backward_stack_in(
    ws: &mut SolveWorkspace,
    n: usize,
    batch: usize,
    sqrt_stack: &[f64],
    grad_stack: &[f64],
    iters: &[usize],
    out: &mut [f64],
) {
    assert_eq!(sqrt_stack.len(), batch * n * n, "ns_backward: sqrt stack size");
    assert_eq!(grad_stack.len(), batch * n * n, "ns_backward: grad stack size");
    assert_eq!(iters.len(), batch, "ns_backward: iters length");
    assert_eq!(out.len(), batch * n * n, "ns_backward: output stack size");
    if batch == 0 || n == 0 {
        return;
    }
    let nn = n * n;
    let mut a = ws.take_vec(nn);
    let mut q = ws.take_vec(nn);
    let mut t3 = ws.take_vec(nn);
    let mut buf1 = ws.take_vec(nn);
    let mut buf2 = ws.take_vec(nn);
    let mut buf3 = ws.take_vec(nn);

    for i in 0..batch {
        let yi = &sqrt_stack[i * nn..(i + 1) * nn];
        let gi = &grad_stack[i * nn..(i + 1) * nn];
        let oi = &mut out[i * nn..(i + 1) * nn];
        let normz = yi.iter().map(|v| v * v).sum::<f64>().sqrt();
        if !normz.is_finite() || normz <= 0.0 {
            oi.fill(0.0);
            continue;
        }
        for (dst, src) in a.iter_mut().zip(yi.iter()) {
            *dst = src / normz;
        }
        for (dst, src) in q.iter_mut().zip(gi.iter()) {
            *dst = src / normz;
        }
        for _ in 0..iters[i].max(10) {
            // t3 ← 3I − a·a
            t3.fill(0.0);
            gemm_nn(n, n, n, &a, &a, &mut t3);
            for v in t3.iter_mut() {
                *v = -*v;
            }
            for r in 0..n {
                t3[r * n + r] += 3.0;
            }
            // buf1 ← q·t3
            buf1.fill(0.0);
            gemm_nn(n, n, n, &q, &t3, &mut buf1);
            // buf2 ← aᵀ·q − q·a
            buf2.fill(0.0);
            gemm_tn(n, n, n, &a, &q, &mut buf2);
            buf3.fill(0.0);
            gemm_nn(n, n, n, &q, &a, &mut buf3);
            for (d, s) in buf2.iter_mut().zip(buf3.iter()) {
                *d -= s;
            }
            // buf3 ← aᵀ·buf2
            buf3.fill(0.0);
            gemm_tn(n, n, n, &a, &buf2, &mut buf3);
            // q ← ½ (buf1 − buf3)
            for ((qv, t1), t2) in q.iter_mut().zip(buf1.iter()).zip(buf3.iter()) {
                *qv = 0.5 * (t1 - t2);
            }
            // a ← ½ a·t3
            buf1.fill(0.0);
            gemm_nn(n, n, n, &a, &t3, &mut buf1);
            for (av, s) in a.iter_mut().zip(buf1.iter()) {
                *av = 0.5 * s;
            }
        }
        for (dst, src) in oi.iter_mut().zip(q.iter()) {
            *dst = 0.5 * src;
        }
    }

    ws.give_vec(buf3);
    ws.give_vec(buf2);
    ws.give_vec(buf1);
    ws.give_vec(t3);
    ws.give_vec(q);
    ws.give_vec(a);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{eigen, Matrix};
    use crate::operators::{KernelOp, KernelType, LinearOp};
    use crate::rng::Pcg64;
    use crate::util::rel_err;

    /// `R Rᵀ + shift·I` — condition number steered by `shift`.
    fn random_spd(n: usize, shift: f64, rng: &mut Pcg64) -> Vec<f64> {
        let r: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += r[i * n + k] * r[j * n + k];
                }
                a[i * n + j] = s + if i == j { shift * n as f64 } else { 0.0 };
            }
        }
        a
    }

    /// Rank-deficient `B Bᵀ` with `B` of width `n−1`: has an exact zero
    /// eigenvalue Newton–Schulz can never lift.
    fn rank_deficient(n: usize, rng: &mut Pcg64) -> Vec<f64> {
        let k = n - 1;
        let b: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for l in 0..k {
                    s += b[i * k + l] * b[j * k + l];
                }
                a[i * n + j] = s;
            }
        }
        a
    }

    fn oracle_pair(n: usize, a: &[f64]) -> (Matrix, Matrix) {
        let m = Matrix::from_vec(n, n, a.to_vec());
        (eigen::spd_sqrt(&m).unwrap(), eigen::spd_inv_sqrt(&m).unwrap())
    }

    fn check_stack_against_oracle(n: usize, batch: usize, a_stack: &[f64], tol: f64) {
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, batch);
        newton_schulz_stack_in(
            &mut ws,
            n,
            batch,
            a_stack,
            &DenseSqrtOptions::default(),
            &mut out,
        );
        assert!(out.all_converged(), "stack n={n} batch={batch} failed to converge");
        for i in 0..batch {
            let (sq, isq) = oracle_pair(n, &a_stack[i * n * n..(i + 1) * n * n]);
            let e1 = rel_err(out.sqrt_mat(i), sq.as_slice());
            let e2 = rel_err(out.invsqrt_mat(i), isq.as_slice());
            assert!(e1 < tol, "sqrt element {i} (n={n}): rel err {e1:.3e}");
            assert!(e2 < tol, "invsqrt element {i} (n={n}): rel err {e2:.3e}");
        }
    }

    #[test]
    fn ns_matches_spectral_oracle_across_sizes_and_conditioning() {
        let mut rng = Pcg64::seeded(1234);
        // (n, shift): shift steers conditioning from benign to harsh.
        for &(n, shift) in &[(4usize, 2.0), (8, 0.5), (16, 0.1), (24, 1.0), (33, 0.02)] {
            let batch = 3;
            let mut stack = Vec::new();
            for _ in 0..batch {
                stack.extend(random_spd(n, shift, &mut rng));
            }
            check_stack_against_oracle(n, batch, &stack, 1e-8);
        }
    }

    #[test]
    fn ns_matches_oracle_on_kernel_matrices() {
        let mut rng = Pcg64::seeded(99);
        for &kind in &[KernelType::Rbf, KernelType::Matern32, KernelType::Matern52] {
            let n = 20;
            let x = Matrix::from_vec(n, 2, (0..n * 2).map(|_| rng.normal()).collect());
            let op = KernelOp::new(&x, kind, 0.9, 1.3, 1e-2);
            let dense = op.to_dense();
            check_stack_against_oracle(n, 1, dense.as_slice(), 1e-7);
        }
    }

    #[test]
    fn rank_deficient_element_fails_while_neighbors_converge() {
        let mut rng = Pcg64::seeded(7);
        let n = 12;
        let mut stack = random_spd(n, 1.0, &mut rng);
        stack.extend(rank_deficient(n, &mut rng));
        stack.extend(random_spd(n, 0.5, &mut rng));
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, 3);
        newton_schulz_stack_in(&mut ws, n, 3, &stack, &DenseSqrtOptions::default(), &mut out);
        assert!(out.converged[0], "well-conditioned element 0 must converge");
        assert!(
            !out.converged[1],
            "rank-deficient element must be flagged for Krylov fallback (residual {:.3e})",
            out.residuals[1]
        );
        assert!(out.converged[2], "well-conditioned element 2 must converge");
        // The flagged element still reports sane diagnostics.
        assert_eq!(out.iters[1], DenseSqrtOptions::default().max_iters);
        assert!(out.residuals[1] > 1e-8);
        // And the pair extraction carries the flag the coordinator keys on.
        assert!(!out.extract_pair(1).converged);
        assert!(out.extract_pair(0).converged);
    }

    #[test]
    fn non_spd_trace_is_flagged_without_iterating() {
        let n = 5;
        let mut stack = vec![0.0; n * n];
        for r in 0..n {
            stack[r * n + r] = -1.0;
        }
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, 1);
        newton_schulz_stack_in(&mut ws, n, 1, &stack, &DenseSqrtOptions::default(), &mut out);
        assert!(!out.converged[0]);
        assert_eq!(out.iters[0], 0);
    }

    #[test]
    fn factors_multiply_back_to_identity_and_operator() {
        let mut rng = Pcg64::seeded(42);
        let n = 18;
        let a = random_spd(n, 0.7, &mut rng);
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, 1);
        newton_schulz_stack_in(&mut ws, n, 1, &a, &DenseSqrtOptions::default(), &mut out);
        assert!(out.all_converged());
        let sq = Matrix::from_vec(n, n, out.sqrt_mat(0).to_vec());
        let isq = Matrix::from_vec(n, n, out.invsqrt_mat(0).to_vec());
        let prod = sq.matmul(&isq);
        let sq2 = sq.matmul(&sq);
        for r in 0..n {
            for c in 0..n {
                let id = if r == c { 1.0 } else { 0.0 };
                assert!((prod[(r, c)] - id).abs() < 1e-10, "K^1/2 · K^-1/2 ≠ I at ({r},{c})");
            }
        }
        assert!(rel_err(sq2.as_slice(), &a) < 1e-10, "(K^1/2)² ≠ K");
    }

    /// Finite-difference validation of the Lyapunov backward pass: for
    /// `L = Σ G ⊙ sqrt(A)`, compare `dL/dA` against
    /// `(L(A + εE) − L(A − εE)) / 2ε` along a random symmetric direction.
    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Pcg64::seeded(11);
        let n = 6;
        let a = random_spd(n, 1.5, &mut rng);
        let g: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        // Random symmetric perturbation direction.
        let mut e = vec![0.0; n * n];
        for r in 0..n {
            for c in r..n {
                let v = rng.normal();
                e[r * n + c] = v;
                e[c * n + r] = v;
            }
        }
        let sqrt_of = |m: &[f64]| -> Vec<f64> {
            let mut ws = SolveWorkspace::new();
            let mut out = DenseFactorStack::new(n, 1);
            newton_schulz_stack_in(&mut ws, n, 1, m, &DenseSqrtOptions::default(), &mut out);
            assert!(out.all_converged());
            out.sqrt_mat(0).to_vec()
        };
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, 1);
        newton_schulz_stack_in(&mut ws, n, 1, &a, &DenseSqrtOptions::default(), &mut out);
        assert!(out.all_converged());
        let mut grad = vec![0.0; n * n];
        newton_schulz_backward_stack_in(
            &mut ws,
            n,
            1,
            &out.sqrt,
            &g,
            &out.iters,
            &mut grad,
        );
        // Directional derivative from the backward pass vs central FD.
        let analytic: f64 = grad.iter().zip(e.iter()).map(|(x, y)| x * y).sum();
        let eps = 1e-5;
        let ap: Vec<f64> = a.iter().zip(e.iter()).map(|(x, y)| x + eps * y).collect();
        let am: Vec<f64> = a.iter().zip(e.iter()).map(|(x, y)| x - eps * y).collect();
        let lp: f64 = sqrt_of(&ap).iter().zip(g.iter()).map(|(x, y)| x * y).sum();
        let lm: f64 = sqrt_of(&am).iter().zip(g.iter()).map(|(x, y)| x * y).sum();
        let fd = (lp - lm) / (2.0 * eps);
        let denom = fd.abs().max(analytic.abs()).max(1e-12);
        assert!(
            (analytic - fd).abs() / denom < 1e-4,
            "Lyapunov backward vs finite differences: analytic {analytic:.8e}, fd {fd:.8e}"
        );
    }

    #[test]
    fn warmed_workspace_stops_growing() {
        let mut rng = Pcg64::seeded(3);
        let n = 10;
        let batch = 4;
        let mut stack = Vec::new();
        for _ in 0..batch {
            stack.extend(random_spd(n, 1.0, &mut rng));
        }
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, batch);
        newton_schulz_stack_in(&mut ws, n, batch, &stack, &DenseSqrtOptions::default(), &mut out);
        let grows = ws.grows();
        for _ in 0..3 {
            newton_schulz_stack_in(
                &mut ws,
                n,
                batch,
                &stack,
                &DenseSqrtOptions::default(),
                &mut out,
            );
        }
        assert_eq!(ws.grows(), grows, "warmed Newton–Schulz solve must not grow the workspace");
        assert!(out.all_converged());
    }
}

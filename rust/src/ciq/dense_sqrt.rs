//! Batched dense Newton–Schulz square roots: the small-`N` tier of the
//! solve stack.
//!
//! The msMINRES/CIQ machinery (this crate's namesake) wins when `K` is
//! large and MVM-bound; for fleets of *small* posteriors the per-request
//! Krylov iteration is pure overhead. Following the batched-sqrt exemplars
//! (Lin & Maji's `matrix-sqrt`, its bcnn and FastDifferentiableMatSqrt
//! descendants), this module computes `K^{1/2}` and `K^{-1/2}` for a whole
//! **stack** of materialized small SPD operators with nothing but GEMMs:
//!
//! Trace-normalize each element: `norm_i = trace(A_i)`. For SPD `A`,
//! `trace(A) ≥ λ_max`, so every eigenvalue of `A_i / norm_i` lies in
//! `(0, 1]` — exactly the region where the coupled Newton–Schulz iteration
//!
//! ```text
//! Y_0 = A/norm,  Z_0 = I
//! T_k = ½ (3 I − Z_k Y_k),   Y_{k+1} = Y_k T_k,   Z_{k+1} = T_k Z_k
//! ```
//!
//! converges quadratically with `Y_k → (A/norm)^{1/2}` and
//! `Z_k → (A/norm)^{-1/2}`; un-normalizing gives `K^{1/2} = √norm · Y` and
//! `K^{-1/2} = Z / √norm`. Convergence is monitored per batch element
//! through the identity `Z_k Y_k = 3I − 2 T_k`: the scaled residual
//! `r_k = ‖Z_k Y_k − I‖_F / √n` is available from the product the
//! iteration computes anyway, so converged elements **exit early** (their
//! factors are finalized into the output stack and the remaining GEMM
//! passes skip them) while stragglers keep iterating. An element that
//! fails to reach `tol` within `max_iters` — a numerically singular `A`
//! has a zero eigenvalue the product map `p ← p(3−p)²/4` can never lift —
//! is reported with `converged = false`, and the coordinator routes its
//! requests through the msMINRES path instead (the guaranteed fallback;
//! see `rust/DESIGN.md` §6).
//!
//! The backward pass solves the Lyapunov equation
//! `dL/dY · Y + Y · dL/dY = dL/dA`-style sensitivity by the matching
//! coupled iteration from the exemplars
//! ([`newton_schulz_backward_stack_in`]).
//!
//! Everything here is allocation-free in the steady state: all scratch
//! (`Y`/`Z`/temp stacks, per-element norms and flags) is checked out of
//! the caller's [`SolveWorkspace`], the batched GEMM phases run through
//! [`crate::linalg::batched`]'s chunk-pool parallelism (one batch element
//! per disjoint output block), and results land in a caller-owned
//! [`DenseFactorStack`]. `rust/tests/alloc_regression.rs` pins the
//! zero-allocation claim with the counting global allocator.

use crate::linalg::gemm::{gemm_nn, gemm_tn, NR};
use crate::linalg::{mixed, Precision, SolveWorkspace};
use crate::util::threadpool::parallel_fill;
use std::cell::RefCell;

/// f32 bulk-phase exit threshold on the scaled residual: past this point the
/// f32 iterates sit inside the f32 roundoff regime and further f32 sweeps
/// stop paying — the f64 polish takes over.
const MIXED_NS_FLOOR: f64 = 1e-3;
/// Anchored acceptance gate of the mixed path: the relative
/// `‖Y² − A/tr‖_F / ‖A/tr‖_F` the f64 polish must reach, else the stack
/// re-runs in pure f64. (`‖ZY − I‖` alone is *not* a certificate that `Y`
/// approximates `A^{1/2}` — the f32 phase perturbs which square root the
/// coupled iteration tracks, so acceptance re-anchors to `A` in f64.)
const MIXED_NS_GATE: f64 = 1e-10;
/// Fixed f64 re-anchored Newton sweeps after the f32 bulk phase. Each sweep
/// contracts the factor error quadratically (modulo an `O(η‖E‖)` commutator
/// term), so three sweeps take the ~1e-5 f32 handoff error to the f64 floor.
const MIXED_POLISH_SWEEPS: usize = 3;

std::thread_local! {
    /// Per-thread f32 panel-pack scratch for the mixed GEMM phases: the
    /// batch-parallel closures run on pool workers and cannot check pooled
    /// buffers out of the caller's workspace. Sized on first use per thread
    /// (same retention discipline as [`crate::linalg::gemm`]'s pack).
    static NS_PACK_F32: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with this thread's f32 pack scratch sized for inner dimension `k`.
fn with_ns_pack<R>(k: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    NS_PACK_F32.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < k * NR {
            buf.resize(k * NR, 0.0);
        }
        f(&mut buf[..k * NR])
    })
}

/// Iteration knobs for the forward Newton–Schulz solve.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseSqrtOptions {
    /// Iteration cap per batch element. Quadratic convergence makes ~20
    /// iterations enough for condition numbers into the 1e6 range; the
    /// default leaves headroom so `converged = false` genuinely means
    /// "numerically singular", not "impatient".
    pub max_iters: usize,
    /// Scaled-residual exit threshold on `‖Z_k Y_k − I‖_F / √n`.
    pub tol: f64,
    /// Arithmetic policy: pure f64, or [`Precision::Mixed`] — an f32 GEMM
    /// bulk phase followed by f64 re-anchored Newton polish with an f64
    /// acceptance gate; a stack that misses the gate is transparently re-run
    /// in pure f64 (`rust/DESIGN.md` §9). Under `Mixed`, `iters` counts f32
    /// sweeps plus polish sweeps.
    pub precision: Precision,
}

impl Default for DenseSqrtOptions {
    fn default() -> DenseSqrtOptions {
        DenseSqrtOptions { max_iters: 40, tol: 1e-13, precision: Precision::F64 }
    }
}

/// Configuration of the coordinator's batched-dense tier
/// ([`crate::ciq::SolverPolicy::BatchedDense`]): which operators the tier
/// captures and how hard the Newton–Schulz iteration tries before handing
/// an operator back to the Krylov path.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchedDenseConfig {
    /// Operators with `size() ≤ n_threshold` are served by the dense tier;
    /// larger ones stay on per-operator Krylov shards. The default tracks
    /// the measured crossover of `perf_hotpath` §8 (`BENCH_batched_dense`).
    pub n_threshold: usize,
    /// Forward-iteration cap (see [`DenseSqrtOptions::max_iters`]).
    pub max_iters: usize,
    /// Forward residual tolerance (see [`DenseSqrtOptions::tol`]). The
    /// default sits near f64 roundoff so dense-tier answers match the
    /// Krylov path to ≤ 1e-6 even at high quadrature accuracy.
    pub tol: f64,
    /// Arithmetic policy of the factor builds (see
    /// [`DenseSqrtOptions::precision`]). The coordinator mirrors the
    /// service-wide precision policy into this field.
    pub precision: Precision,
}

impl Default for BatchedDenseConfig {
    fn default() -> BatchedDenseConfig {
        BatchedDenseConfig {
            n_threshold: 256,
            max_iters: 40,
            tol: 1e-13,
            precision: Precision::F64,
        }
    }
}

impl BatchedDenseConfig {
    /// The forward-iteration options this tier runs under.
    pub fn sqrt_opts(&self) -> DenseSqrtOptions {
        DenseSqrtOptions { max_iters: self.max_iters, tol: self.tol, precision: self.precision }
    }
}

/// Output of one batched forward solve: `batch` pairs of `n×n` factors
/// plus per-element convergence diagnostics. Allocated once by the caller
/// ([`DenseFactorStack::new`]) and refilled in place on every
/// [`newton_schulz_stack_in`] call — the solve itself never allocates.
#[derive(Clone, Debug)]
pub struct DenseFactorStack {
    n: usize,
    batch: usize,
    /// `batch` row-major `n×n` matrices `≈ A_i^{1/2}` (stride `n·n`).
    pub sqrt: Vec<f64>,
    /// `batch` row-major `n×n` matrices `≈ A_i^{-1/2}`.
    pub invsqrt: Vec<f64>,
    /// Whether element `i` hit `tol` within `max_iters`. A `false` entry's
    /// factors are best-effort and must not be served — fall back to
    /// msMINRES.
    pub converged: Vec<bool>,
    /// Newton–Schulz updates element `i` performed before exit.
    pub iters: Vec<usize>,
    /// Final scaled residual `‖Z Y − I‖_F / √n` per element.
    pub residuals: Vec<f64>,
}

impl DenseFactorStack {
    /// A zeroed stack for `batch` elements of size `n` (the one allocation
    /// of the dense tier's lifecycle).
    pub fn new(n: usize, batch: usize) -> DenseFactorStack {
        DenseFactorStack {
            n,
            batch,
            sqrt: vec![0.0; batch * n * n],
            invsqrt: vec![0.0; batch * n * n],
            converged: vec![false; batch],
            iters: vec![0; batch],
            residuals: vec![f64::INFINITY; batch],
        }
    }

    /// Element size `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of batch elements.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Row-major `n×n` slice `≈ A_i^{1/2}`.
    pub fn sqrt_mat(&self, i: usize) -> &[f64] {
        let nn = self.n * self.n;
        &self.sqrt[i * nn..(i + 1) * nn]
    }

    /// Row-major `n×n` slice `≈ A_i^{-1/2}`.
    pub fn invsqrt_mat(&self, i: usize) -> &[f64] {
        let nn = self.n * self.n;
        &self.invsqrt[i * nn..(i + 1) * nn]
    }

    /// Whether every element converged.
    pub fn all_converged(&self) -> bool {
        self.converged.iter().all(|&c| c)
    }

    /// Clone element `i` out into a standalone per-operator cache unit.
    pub fn extract_pair(&self, i: usize) -> DenseFactorPair {
        DenseFactorPair {
            n: self.n,
            sqrt: self.sqrt_mat(i).to_vec(),
            invsqrt: self.invsqrt_mat(i).to_vec(),
            converged: self.converged[i],
            iters: self.iters[i],
            residual: self.residuals[i],
        }
    }
}

/// One operator's cached dense factors — what the coordinator stores per
/// operator version and applies with [`crate::linalg::batched::gemv_gather`]
/// on every size-class flush.
#[derive(Clone, Debug)]
pub struct DenseFactorPair {
    /// Factor dimension.
    pub n: usize,
    /// Row-major `n×n` `≈ K^{1/2}`.
    pub sqrt: Vec<f64>,
    /// Row-major `n×n` `≈ K^{-1/2}`.
    pub invsqrt: Vec<f64>,
    /// `false` marks the operator dense-incapable (serve via msMINRES).
    pub converged: bool,
    /// Forward iterations performed (feeds the backward pass).
    pub iters: usize,
    /// Final scaled residual.
    pub residual: f64,
}

/// Trace-normalized coupled Newton–Schulz over a stack of `batch`
/// row-major `n×n` SPD matrices (`a_stack`, stride `n·n`), writing
/// `A_i^{1/2}` / `A_i^{-1/2}` and per-element diagnostics into `out`.
///
/// Each iteration runs three batched GEMM passes (`T = Z·Y`, `Y·T`,
/// `T·Z`) parallelized across the batch dimension on the chunk pool, with
/// converged elements skipped in place; the residual check rides on the
/// `Z·Y` product the iteration needs anyway. All scratch comes from `ws`,
/// so a warmed workspace runs the whole solve without heap allocation.
///
/// Elements whose trace is non-positive or non-finite (not SPD) are marked
/// `converged = false` immediately; elements that exhaust `max_iters`
/// keep their best-effort factors but also report `converged = false`.
///
/// Under [`Precision::Mixed`] the bulk GEMM sweeps run on f32 stacks and a
/// fixed number of f64 re-anchored Newton sweeps polish the factors back to
/// f64 accuracy, gated by a final f64 residual check against `A` itself; a
/// stack that misses the gate (or stagnates in f32 — e.g. a rank-deficient
/// element) is re-run in pure f64, bit-identical to a [`Precision::F64`]
/// call. Trace normalization and all accept/reject decisions are always f64.
pub fn newton_schulz_stack_in(
    ws: &mut SolveWorkspace,
    n: usize,
    batch: usize,
    a_stack: &[f64],
    opts: &DenseSqrtOptions,
    out: &mut DenseFactorStack,
) {
    assert_eq!(a_stack.len(), batch * n * n, "newton_schulz_stack_in: A stack size");
    assert_eq!(out.n, n, "newton_schulz_stack_in: output stack dimension");
    assert_eq!(out.batch, batch, "newton_schulz_stack_in: output stack batch");
    if let Precision::Mixed(_) = opts.precision {
        if mixed_ns_stack_in(ws, n, batch, a_stack, opts, out) {
            return;
        }
        // gate miss or f32 stagnation: the rerun below reinitializes every
        // output field, so the result is bit-identical to a pure-f64 call.
    }
    ns_stack_f64_in(ws, n, batch, a_stack, opts, out);
}

/// The pure-f64 coupled Newton–Schulz engine (and the fallback target of the
/// mixed path).
fn ns_stack_f64_in(
    ws: &mut SolveWorkspace,
    n: usize,
    batch: usize,
    a_stack: &[f64],
    opts: &DenseSqrtOptions,
    out: &mut DenseFactorStack,
) {
    if batch == 0 || n == 0 {
        return;
    }
    let nn = n * n;
    let sqrt_n = (n as f64).sqrt();
    let mut y = ws.take_vec(batch * nn);
    let mut z = ws.take_vec(batch * nn);
    let mut t = ws.take_vec(batch * nn);
    let mut y2 = ws.take_vec(batch * nn);
    let mut z2 = ws.take_vec(batch * nn);
    let mut norms = ws.take_vec(batch);
    // 0 = active, 1 = finalized (take_usize hands the buffer back zeroed).
    let mut state = ws.take_usize(batch);

    for i in 0..batch {
        let a = &a_stack[i * nn..(i + 1) * nn];
        let trace: f64 = (0..n).map(|r| a[r * n + r]).sum();
        out.iters[i] = 0;
        out.residuals[i] = f64::INFINITY;
        out.converged[i] = false;
        if !trace.is_finite() || trace <= 0.0 {
            // Not plausibly SPD: mark dense-incapable without iterating.
            out.sqrt[i * nn..(i + 1) * nn].fill(0.0);
            out.invsqrt[i * nn..(i + 1) * nn].fill(0.0);
            state[i] = 1;
            continue;
        }
        norms[i] = trace;
        let yi = &mut y[i * nn..(i + 1) * nn];
        for (dst, src) in yi.iter_mut().zip(a.iter()) {
            *dst = src / trace;
        }
        let zi = &mut z[i * nn..(i + 1) * nn];
        zi.fill(0.0);
        for r in 0..n {
            zi[r * n + r] = 1.0;
        }
    }

    let mut remaining = state.iter().filter(|&&s| s == 0).count();
    for iter in 0..opts.max_iters {
        if remaining == 0 {
            break;
        }
        // T ← Z·Y for every active element (one block per element; done
        // elements cost a flag check).
        parallel_fill(&mut t, nn, |start, block| {
            let i = start / nn;
            if state[i] != 0 {
                return;
            }
            block.fill(0.0);
            gemm_nn(n, n, n, &z[i * nn..(i + 1) * nn], &y[i * nn..(i + 1) * nn], block);
        });
        // Residual check + in-place transform T ← ³⁄₂I − ½T (serial: O(batch·n²)
        // against the O(batch·n³) GEMM phases).
        for i in 0..batch {
            if state[i] != 0 {
                continue;
            }
            let ti = &mut t[i * nn..(i + 1) * nn];
            let mut frob2 = 0.0;
            for r in 0..n {
                for c in 0..n {
                    let d = ti[r * n + c] - if r == c { 1.0 } else { 0.0 };
                    frob2 += d * d;
                }
            }
            let r = frob2.sqrt() / sqrt_n;
            out.residuals[i] = r;
            out.iters[i] = iter;
            if r <= opts.tol && r.is_finite() {
                let scale = norms[i].sqrt();
                let yi = &y[i * nn..(i + 1) * nn];
                let zi = &z[i * nn..(i + 1) * nn];
                for (dst, src) in out.sqrt[i * nn..(i + 1) * nn].iter_mut().zip(yi.iter()) {
                    *dst = src * scale;
                }
                for (dst, src) in out.invsqrt[i * nn..(i + 1) * nn].iter_mut().zip(zi.iter()) {
                    *dst = src / scale;
                }
                out.converged[i] = true;
                state[i] = 1;
                remaining -= 1;
                continue;
            }
            for v in ti.iter_mut() {
                *v = -0.5 * *v;
            }
            for r in 0..n {
                ti[r * n + r] += 1.5;
            }
        }
        if remaining == 0 {
            break;
        }
        // Y' ← Y·T and Z' ← T·Z for the stragglers.
        parallel_fill(&mut y2, nn, |start, block| {
            let i = start / nn;
            if state[i] != 0 {
                return;
            }
            block.fill(0.0);
            gemm_nn(n, n, n, &y[i * nn..(i + 1) * nn], &t[i * nn..(i + 1) * nn], block);
        });
        parallel_fill(&mut z2, nn, |start, block| {
            let i = start / nn;
            if state[i] != 0 {
                return;
            }
            block.fill(0.0);
            gemm_nn(n, n, n, &t[i * nn..(i + 1) * nn], &z[i * nn..(i + 1) * nn], block);
        });
        // Finalized elements' stale blocks swap along harmlessly — their
        // factors already live in `out` and every phase skips them.
        std::mem::swap(&mut y, &mut y2);
        std::mem::swap(&mut z, &mut z2);
    }

    // Stragglers at the cap: best-effort factors, converged = false.
    for i in 0..batch {
        if state[i] != 0 {
            continue;
        }
        let scale = norms[i].sqrt();
        out.iters[i] = opts.max_iters;
        for (dst, src) in
            out.sqrt[i * nn..(i + 1) * nn].iter_mut().zip(y[i * nn..(i + 1) * nn].iter())
        {
            *dst = src * scale;
        }
        for (dst, src) in
            out.invsqrt[i * nn..(i + 1) * nn].iter_mut().zip(z[i * nn..(i + 1) * nn].iter())
        {
            *dst = src / scale;
        }
    }

    ws.give_usize(state);
    ws.give_vec(norms);
    ws.give_vec(z2);
    ws.give_vec(y2);
    ws.give_vec(t);
    ws.give_vec(z);
    ws.give_vec(y);
}

/// The mixed-precision engine: f32 coupled Newton–Schulz bulk phase down to
/// [`MIXED_NS_FLOOR`], then [`MIXED_POLISH_SWEEPS`] f64 Newton sweeps
/// re-anchored to `A` (`Y += ½(A/tr − Y²)Z`, `Z ← Z(2I − YZ)`), then a
/// final f64 gate on both `‖ZY − I‖_F/√n ≤ tol` and the anchored
/// [`MIXED_NS_GATE`]. Returns `false` when any serveable element stagnated
/// or missed the gate — the caller then re-runs the stack in pure f64.
fn mixed_ns_stack_in(
    ws: &mut SolveWorkspace,
    n: usize,
    batch: usize,
    a_stack: &[f64],
    opts: &DenseSqrtOptions,
    out: &mut DenseFactorStack,
) -> bool {
    if batch == 0 || n == 0 {
        return true;
    }
    let nn = n * n;
    let sqrt_n = (n as f64).sqrt();
    let mut y = ws.take_vec(batch * nn);
    let mut z = ws.take_vec(batch * nn);
    let mut t = ws.take_vec(batch * nn);
    let mut y2 = ws.take_vec(batch * nn);
    let mut z2 = ws.take_vec(batch * nn);
    let mut norms = ws.take_vec(batch);
    let mut mnorms = ws.take_vec(batch);
    // 0 = serveable, 1 = excluded at init (not plausibly SPD).
    let mut state = ws.take_usize(batch);

    // Trace normalization stays f64: the scale the factors are un-normalized
    // with never passes through f32.
    for i in 0..batch {
        let a = &a_stack[i * nn..(i + 1) * nn];
        let trace: f64 = (0..n).map(|r| a[r * n + r]).sum();
        out.iters[i] = 0;
        out.residuals[i] = f64::INFINITY;
        out.converged[i] = false;
        if !trace.is_finite() || trace <= 0.0 {
            out.sqrt[i * nn..(i + 1) * nn].fill(0.0);
            out.invsqrt[i * nn..(i + 1) * nn].fill(0.0);
            state[i] = 1;
            continue;
        }
        norms[i] = trace;
        mnorms[i] = a.iter().map(|v| v * v).sum::<f64>().sqrt() / trace;
        let yi = &mut y[i * nn..(i + 1) * nn];
        for (dst, src) in yi.iter_mut().zip(a.iter()) {
            *dst = src / trace;
        }
        let zi = &mut z[i * nn..(i + 1) * nn];
        zi.fill(0.0);
        for r in 0..n {
            zi[r * n + r] = 1.0;
        }
    }

    let mut y32 = ws.take_f32(batch * nn);
    let mut z32 = ws.take_f32(batch * nn);
    let mut t32 = ws.take_f32(batch * nn);
    mixed::downconvert(&y, &mut y32);
    mixed::downconvert(&z, &mut z32);
    // 0 = still refining in f32, 1 = at the f32 floor (or excluded).
    let mut pre = ws.take_usize(batch);
    for i in 0..batch {
        if state[i] != 0 {
            pre[i] = 1;
        }
    }
    let floor = opts.tol.max(MIXED_NS_FLOOR);
    let mut ok = true;
    for _ in 0..opts.max_iters {
        if pre.iter().all(|&p| p != 0) {
            break;
        }
        // T ← Z₃₂·Y₃₂ with f64 accumulation (one block per element).
        parallel_fill(&mut t, nn, |start, block| {
            let i = start / nn;
            if pre[i] != 0 {
                return;
            }
            block.fill(0.0);
            let (zi, yi) = (&z32[i * nn..(i + 1) * nn], &y32[i * nn..(i + 1) * nn]);
            with_ns_pack(n, |pack| mixed::gemm_nn(n, n, n, zi, yi, block, pack));
        });
        // f64 residual check + transform T ← ³⁄₂I − ½T, narrowed once.
        for i in 0..batch {
            if pre[i] != 0 {
                continue;
            }
            let ti = &mut t[i * nn..(i + 1) * nn];
            let mut frob2 = 0.0;
            for r in 0..n {
                for c in 0..n {
                    let d = ti[r * n + c] - if r == c { 1.0 } else { 0.0 };
                    frob2 += d * d;
                }
            }
            let r = frob2.sqrt() / sqrt_n;
            out.residuals[i] = r;
            out.iters[i] += 1;
            if !r.is_finite() {
                pre[i] = 1;
                ok = false;
                continue;
            }
            if r <= floor {
                pre[i] = 1;
                continue;
            }
            for v in ti.iter_mut() {
                *v = -0.5 * *v;
            }
            for r in 0..n {
                ti[r * n + r] += 1.5;
            }
            mixed::downconvert(ti, &mut t32[i * nn..(i + 1) * nn]);
        }
        if pre.iter().all(|&p| p != 0) {
            break;
        }
        // Y' ← Y₃₂·T₃₂ and Z' ← T₃₂·Z₃₂, narrowed back into the f32 stacks.
        parallel_fill(&mut y2, nn, |start, block| {
            let i = start / nn;
            if pre[i] != 0 {
                return;
            }
            block.fill(0.0);
            let (yi, ti) = (&y32[i * nn..(i + 1) * nn], &t32[i * nn..(i + 1) * nn]);
            with_ns_pack(n, |pack| mixed::gemm_nn(n, n, n, yi, ti, block, pack));
        });
        parallel_fill(&mut z2, nn, |start, block| {
            let i = start / nn;
            if pre[i] != 0 {
                return;
            }
            block.fill(0.0);
            let (ti, zi) = (&t32[i * nn..(i + 1) * nn], &z32[i * nn..(i + 1) * nn]);
            with_ns_pack(n, |pack| mixed::gemm_nn(n, n, n, ti, zi, block, pack));
        });
        for i in 0..batch {
            if pre[i] != 0 {
                continue;
            }
            mixed::downconvert(&y2[i * nn..(i + 1) * nn], &mut y32[i * nn..(i + 1) * nn]);
            mixed::downconvert(&z2[i * nn..(i + 1) * nn], &mut z32[i * nn..(i + 1) * nn]);
        }
    }
    // An element that never reached the floor stagnated in f32 (the classic
    // case: a zero eigenvalue the product map can never lift).
    for i in 0..batch {
        if state[i] == 0 && pre[i] == 0 {
            ok = false;
        }
    }

    if ok {
        for i in 0..batch {
            if state[i] != 0 {
                continue;
            }
            mixed::upconvert(&y32[i * nn..(i + 1) * nn], &mut y[i * nn..(i + 1) * nn]);
            mixed::upconvert(&z32[i * nn..(i + 1) * nn], &mut z[i * nn..(i + 1) * nn]);
        }
        for _ in 0..MIXED_POLISH_SWEEPS {
            // E ← A/tr − Y·Y (computed in t).
            parallel_fill(&mut t, nn, |start, block| {
                let i = start / nn;
                if state[i] != 0 {
                    return;
                }
                block.fill(0.0);
                gemm_nn(n, n, n, &y[i * nn..(i + 1) * nn], &y[i * nn..(i + 1) * nn], block);
            });
            for i in 0..batch {
                if state[i] != 0 {
                    continue;
                }
                let ti = &mut t[i * nn..(i + 1) * nn];
                let ai = &a_stack[i * nn..(i + 1) * nn];
                let inv = 1.0 / norms[i];
                for (tv, av) in ti.iter_mut().zip(ai.iter()) {
                    *tv = av * inv - *tv;
                }
            }
            // Y ← Y + ½ E·Z (Newton step for the sqrt, anchored to A).
            parallel_fill(&mut y2, nn, |start, block| {
                let i = start / nn;
                if state[i] != 0 {
                    return;
                }
                block.fill(0.0);
                gemm_nn(n, n, n, &t[i * nn..(i + 1) * nn], &z[i * nn..(i + 1) * nn], block);
            });
            for i in 0..batch {
                if state[i] != 0 {
                    continue;
                }
                let yi = &mut y[i * nn..(i + 1) * nn];
                for (yv, dv) in yi.iter_mut().zip(y2[i * nn..(i + 1) * nn].iter()) {
                    *yv += 0.5 * dv;
                }
            }
            // Z ← Z·(2I − Y·Z) (Newton step for the inverse of the new Y).
            parallel_fill(&mut t, nn, |start, block| {
                let i = start / nn;
                if state[i] != 0 {
                    return;
                }
                block.fill(0.0);
                gemm_nn(n, n, n, &y[i * nn..(i + 1) * nn], &z[i * nn..(i + 1) * nn], block);
            });
            for i in 0..batch {
                if state[i] != 0 {
                    continue;
                }
                let ti = &mut t[i * nn..(i + 1) * nn];
                for v in ti.iter_mut() {
                    *v = -*v;
                }
                for r in 0..n {
                    ti[r * n + r] += 2.0;
                }
            }
            parallel_fill(&mut z2, nn, |start, block| {
                let i = start / nn;
                if state[i] != 0 {
                    return;
                }
                block.fill(0.0);
                gemm_nn(n, n, n, &z[i * nn..(i + 1) * nn], &t[i * nn..(i + 1) * nn], block);
            });
            // Excluded elements' stale blocks swap along harmlessly — their
            // outputs were zeroed at init and every phase skips them.
            std::mem::swap(&mut z, &mut z2);
            for i in 0..batch {
                if state[i] == 0 {
                    out.iters[i] += 1;
                }
            }
        }
        // Final f64 acceptance gate: ZY against I *and* Y² against A.
        parallel_fill(&mut t, nn, |start, block| {
            let i = start / nn;
            if state[i] != 0 {
                return;
            }
            block.fill(0.0);
            gemm_nn(n, n, n, &z[i * nn..(i + 1) * nn], &y[i * nn..(i + 1) * nn], block);
        });
        parallel_fill(&mut y2, nn, |start, block| {
            let i = start / nn;
            if state[i] != 0 {
                return;
            }
            block.fill(0.0);
            gemm_nn(n, n, n, &y[i * nn..(i + 1) * nn], &y[i * nn..(i + 1) * nn], block);
        });
        let gate = opts.tol.max(MIXED_NS_GATE);
        for i in 0..batch {
            if state[i] != 0 {
                continue;
            }
            let ti = &t[i * nn..(i + 1) * nn];
            let mut frob2 = 0.0;
            for r in 0..n {
                for c in 0..n {
                    let d = ti[r * n + c] - if r == c { 1.0 } else { 0.0 };
                    frob2 += d * d;
                }
            }
            let r = frob2.sqrt() / sqrt_n;
            let ai = &a_stack[i * nn..(i + 1) * nn];
            let inv = 1.0 / norms[i];
            let mut e2 = 0.0;
            for (yv, av) in y2[i * nn..(i + 1) * nn].iter().zip(ai.iter()) {
                let d = av * inv - yv;
                e2 += d * d;
            }
            let ra = e2.sqrt() / mnorms[i];
            out.residuals[i] = r;
            if r.is_finite() && ra.is_finite() && r <= opts.tol && ra <= gate {
                let scale = norms[i].sqrt();
                let yi = &y[i * nn..(i + 1) * nn];
                let zi = &z[i * nn..(i + 1) * nn];
                for (dst, src) in out.sqrt[i * nn..(i + 1) * nn].iter_mut().zip(yi.iter()) {
                    *dst = src * scale;
                }
                for (dst, src) in out.invsqrt[i * nn..(i + 1) * nn].iter_mut().zip(zi.iter()) {
                    *dst = src / scale;
                }
                out.converged[i] = true;
            } else {
                ok = false;
            }
        }
    }

    ws.give_usize(pre);
    ws.give_f32(t32);
    ws.give_f32(z32);
    ws.give_f32(y32);
    ws.give_usize(state);
    ws.give_vec(mnorms);
    ws.give_vec(norms);
    ws.give_vec(z2);
    ws.give_vec(y2);
    ws.give_vec(t);
    ws.give_vec(z);
    ws.give_vec(y);
    ok
}

/// Lyapunov-equation backward pass for the batched square root, after the
/// exemplars' `lyap_newton_schulz`: given the forward outputs
/// `Y_i ≈ A_i^{1/2}` (`sqrt_stack`) and upstream gradients
/// `dL/dY_i` (`grad_stack`), computes `dL/dA_i` into `out` by the coupled
/// iteration
///
/// ```text
/// a_0 = Y/‖Y‖_F,  q_0 = dL/dY / ‖Y‖_F
/// q_{k+1} = ½ [ q (3I − a²) − aᵀ (aᵀ q − q a) ]
/// a_{k+1} = ½ a (3I − a²)
/// dL/dA  = ½ q_final
/// ```
///
/// which drives `a → I` while `q` contracts to the solution of the
/// Lyapunov sensitivity equation `Y·dA + dA·Y = dY`. Iterations are
/// per-element `iters[i]` with a floor of 10: the backward fixed point
/// needs its own convergence budget even when the forward exited early.
///
/// Runs serially over the batch (this is the training path, not the
/// serving hot path); the six `n×n` scratch buffers come from `ws` and are
/// reused across elements.
pub fn newton_schulz_backward_stack_in(
    ws: &mut SolveWorkspace,
    n: usize,
    batch: usize,
    sqrt_stack: &[f64],
    grad_stack: &[f64],
    iters: &[usize],
    out: &mut [f64],
) {
    assert_eq!(sqrt_stack.len(), batch * n * n, "ns_backward: sqrt stack size");
    assert_eq!(grad_stack.len(), batch * n * n, "ns_backward: grad stack size");
    assert_eq!(iters.len(), batch, "ns_backward: iters length");
    assert_eq!(out.len(), batch * n * n, "ns_backward: output stack size");
    if batch == 0 || n == 0 {
        return;
    }
    let nn = n * n;
    let mut a = ws.take_vec(nn);
    let mut q = ws.take_vec(nn);
    let mut t3 = ws.take_vec(nn);
    let mut buf1 = ws.take_vec(nn);
    let mut buf2 = ws.take_vec(nn);
    let mut buf3 = ws.take_vec(nn);

    for i in 0..batch {
        let yi = &sqrt_stack[i * nn..(i + 1) * nn];
        let gi = &grad_stack[i * nn..(i + 1) * nn];
        let oi = &mut out[i * nn..(i + 1) * nn];
        let normz = yi.iter().map(|v| v * v).sum::<f64>().sqrt();
        if !normz.is_finite() || normz <= 0.0 {
            oi.fill(0.0);
            continue;
        }
        for (dst, src) in a.iter_mut().zip(yi.iter()) {
            *dst = src / normz;
        }
        for (dst, src) in q.iter_mut().zip(gi.iter()) {
            *dst = src / normz;
        }
        for _ in 0..iters[i].max(10) {
            // t3 ← 3I − a·a
            t3.fill(0.0);
            gemm_nn(n, n, n, &a, &a, &mut t3);
            for v in t3.iter_mut() {
                *v = -*v;
            }
            for r in 0..n {
                t3[r * n + r] += 3.0;
            }
            // buf1 ← q·t3
            buf1.fill(0.0);
            gemm_nn(n, n, n, &q, &t3, &mut buf1);
            // buf2 ← aᵀ·q − q·a
            buf2.fill(0.0);
            gemm_tn(n, n, n, &a, &q, &mut buf2);
            buf3.fill(0.0);
            gemm_nn(n, n, n, &q, &a, &mut buf3);
            for (d, s) in buf2.iter_mut().zip(buf3.iter()) {
                *d -= s;
            }
            // buf3 ← aᵀ·buf2
            buf3.fill(0.0);
            gemm_tn(n, n, n, &a, &buf2, &mut buf3);
            // q ← ½ (buf1 − buf3)
            for ((qv, t1), t2) in q.iter_mut().zip(buf1.iter()).zip(buf3.iter()) {
                *qv = 0.5 * (t1 - t2);
            }
            // a ← ½ a·t3
            buf1.fill(0.0);
            gemm_nn(n, n, n, &a, &t3, &mut buf1);
            for (av, s) in a.iter_mut().zip(buf1.iter()) {
                *av = 0.5 * s;
            }
        }
        for (dst, src) in oi.iter_mut().zip(q.iter()) {
            *dst = 0.5 * src;
        }
    }

    ws.give_vec(buf3);
    ws.give_vec(buf2);
    ws.give_vec(buf1);
    ws.give_vec(t3);
    ws.give_vec(q);
    ws.give_vec(a);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{eigen, Matrix};
    use crate::operators::{KernelOp, KernelType, LinearOp};
    use crate::rng::Pcg64;
    use crate::util::rel_err;

    /// `R Rᵀ + shift·I` — condition number steered by `shift`.
    fn random_spd(n: usize, shift: f64, rng: &mut Pcg64) -> Vec<f64> {
        let r: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += r[i * n + k] * r[j * n + k];
                }
                a[i * n + j] = s + if i == j { shift * n as f64 } else { 0.0 };
            }
        }
        a
    }

    /// Rank-deficient `B Bᵀ` with `B` of width `n−1`: has an exact zero
    /// eigenvalue Newton–Schulz can never lift.
    fn rank_deficient(n: usize, rng: &mut Pcg64) -> Vec<f64> {
        let k = n - 1;
        let b: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for l in 0..k {
                    s += b[i * k + l] * b[j * k + l];
                }
                a[i * n + j] = s;
            }
        }
        a
    }

    fn oracle_pair(n: usize, a: &[f64]) -> (Matrix, Matrix) {
        let m = Matrix::from_vec(n, n, a.to_vec());
        (eigen::spd_sqrt(&m).unwrap(), eigen::spd_inv_sqrt(&m).unwrap())
    }

    fn check_stack_against_oracle(n: usize, batch: usize, a_stack: &[f64], tol: f64) {
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, batch);
        newton_schulz_stack_in(
            &mut ws,
            n,
            batch,
            a_stack,
            &DenseSqrtOptions::default(),
            &mut out,
        );
        assert!(out.all_converged(), "stack n={n} batch={batch} failed to converge");
        for i in 0..batch {
            let (sq, isq) = oracle_pair(n, &a_stack[i * n * n..(i + 1) * n * n]);
            let e1 = rel_err(out.sqrt_mat(i), sq.as_slice());
            let e2 = rel_err(out.invsqrt_mat(i), isq.as_slice());
            assert!(e1 < tol, "sqrt element {i} (n={n}): rel err {e1:.3e}");
            assert!(e2 < tol, "invsqrt element {i} (n={n}): rel err {e2:.3e}");
        }
    }

    #[test]
    fn ns_matches_spectral_oracle_across_sizes_and_conditioning() {
        let mut rng = Pcg64::seeded(1234);
        // (n, shift): shift steers conditioning from benign to harsh.
        for &(n, shift) in &[(4usize, 2.0), (8, 0.5), (16, 0.1), (24, 1.0), (33, 0.02)] {
            let batch = 3;
            let mut stack = Vec::new();
            for _ in 0..batch {
                stack.extend(random_spd(n, shift, &mut rng));
            }
            check_stack_against_oracle(n, batch, &stack, 1e-8);
        }
    }

    #[test]
    fn ns_matches_oracle_on_kernel_matrices() {
        let mut rng = Pcg64::seeded(99);
        for &kind in &[KernelType::Rbf, KernelType::Matern32, KernelType::Matern52] {
            let n = 20;
            let x = Matrix::from_vec(n, 2, (0..n * 2).map(|_| rng.normal()).collect());
            let op = KernelOp::new(&x, kind, 0.9, 1.3, 1e-2);
            let dense = op.to_dense();
            check_stack_against_oracle(n, 1, dense.as_slice(), 1e-7);
        }
    }

    #[test]
    fn rank_deficient_element_fails_while_neighbors_converge() {
        let mut rng = Pcg64::seeded(7);
        let n = 12;
        let mut stack = random_spd(n, 1.0, &mut rng);
        stack.extend(rank_deficient(n, &mut rng));
        stack.extend(random_spd(n, 0.5, &mut rng));
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, 3);
        newton_schulz_stack_in(&mut ws, n, 3, &stack, &DenseSqrtOptions::default(), &mut out);
        assert!(out.converged[0], "well-conditioned element 0 must converge");
        assert!(
            !out.converged[1],
            "rank-deficient element must be flagged for Krylov fallback (residual {:.3e})",
            out.residuals[1]
        );
        assert!(out.converged[2], "well-conditioned element 2 must converge");
        // The flagged element still reports sane diagnostics.
        assert_eq!(out.iters[1], DenseSqrtOptions::default().max_iters);
        assert!(out.residuals[1] > 1e-8);
        // And the pair extraction carries the flag the coordinator keys on.
        assert!(!out.extract_pair(1).converged);
        assert!(out.extract_pair(0).converged);
    }

    #[test]
    fn non_spd_trace_is_flagged_without_iterating() {
        let n = 5;
        let mut stack = vec![0.0; n * n];
        for r in 0..n {
            stack[r * n + r] = -1.0;
        }
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, 1);
        newton_schulz_stack_in(&mut ws, n, 1, &stack, &DenseSqrtOptions::default(), &mut out);
        assert!(!out.converged[0]);
        assert_eq!(out.iters[0], 0);
    }

    #[test]
    fn factors_multiply_back_to_identity_and_operator() {
        let mut rng = Pcg64::seeded(42);
        let n = 18;
        let a = random_spd(n, 0.7, &mut rng);
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, 1);
        newton_schulz_stack_in(&mut ws, n, 1, &a, &DenseSqrtOptions::default(), &mut out);
        assert!(out.all_converged());
        let sq = Matrix::from_vec(n, n, out.sqrt_mat(0).to_vec());
        let isq = Matrix::from_vec(n, n, out.invsqrt_mat(0).to_vec());
        let prod = sq.matmul(&isq);
        let sq2 = sq.matmul(&sq);
        for r in 0..n {
            for c in 0..n {
                let id = if r == c { 1.0 } else { 0.0 };
                assert!((prod[(r, c)] - id).abs() < 1e-10, "K^1/2 · K^-1/2 ≠ I at ({r},{c})");
            }
        }
        assert!(rel_err(sq2.as_slice(), &a) < 1e-10, "(K^1/2)² ≠ K");
    }

    #[test]
    fn mixed_stack_matches_oracle_at_f64_accuracy() {
        use crate::linalg::RefineConfig;
        let mut rng = Pcg64::seeded(77);
        let n = 16;
        let batch = 3;
        let mut stack = Vec::new();
        for _ in 0..batch {
            stack.extend(random_spd(n, 0.5, &mut rng));
        }
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, batch);
        let opts = DenseSqrtOptions {
            precision: Precision::Mixed(RefineConfig::default()),
            ..Default::default()
        };
        newton_schulz_stack_in(&mut ws, n, batch, &stack, &opts, &mut out);
        assert!(out.all_converged(), "mixed stack must converge: {:?}", out.residuals);
        for i in 0..batch {
            let (sq, isq) = oracle_pair(n, &stack[i * n * n..(i + 1) * n * n]);
            let e1 = rel_err(out.sqrt_mat(i), sq.as_slice());
            let e2 = rel_err(out.invsqrt_mat(i), isq.as_slice());
            assert!(e1 < 1e-8, "mixed sqrt element {i}: rel err {e1:.3e}");
            assert!(e2 < 1e-8, "mixed invsqrt element {i}: rel err {e2:.3e}");
            assert!(out.residuals[i] <= opts.tol, "final residual above tol");
        }
    }

    #[test]
    fn mixed_stack_falls_back_bit_identically_on_rank_deficiency() {
        use crate::linalg::RefineConfig;
        // The rank-deficient element stagnates in the f32 phase, so the whole
        // stack re-runs in pure f64 — every output must be bit-identical to a
        // Precision::F64 call.
        let mut rng = Pcg64::seeded(17);
        let n = 12;
        let mut stack = random_spd(n, 1.0, &mut rng);
        stack.extend(rank_deficient(n, &mut rng));
        let mut ws = SolveWorkspace::new();
        let mut f64_out = DenseFactorStack::new(n, 2);
        newton_schulz_stack_in(&mut ws, n, 2, &stack, &DenseSqrtOptions::default(), &mut f64_out);
        let opts = DenseSqrtOptions {
            precision: Precision::Mixed(RefineConfig::default()),
            ..Default::default()
        };
        let mut mixed_out = DenseFactorStack::new(n, 2);
        newton_schulz_stack_in(&mut ws, n, 2, &stack, &opts, &mut mixed_out);
        assert_eq!(mixed_out.sqrt, f64_out.sqrt, "fallback sqrt factors must be bit-identical");
        assert_eq!(mixed_out.invsqrt, f64_out.invsqrt);
        assert_eq!(mixed_out.converged, f64_out.converged);
        assert_eq!(mixed_out.iters, f64_out.iters);
        assert_eq!(
            mixed_out.residuals, f64_out.residuals,
            "fallback diagnostics must be bit-identical"
        );
    }

    /// Finite-difference validation of the Lyapunov backward pass: for
    /// `L = Σ G ⊙ sqrt(A)`, compare `dL/dA` against
    /// `(L(A + εE) − L(A − εE)) / 2ε` along a random symmetric direction.
    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Pcg64::seeded(11);
        let n = 6;
        let a = random_spd(n, 1.5, &mut rng);
        let g: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        // Random symmetric perturbation direction.
        let mut e = vec![0.0; n * n];
        for r in 0..n {
            for c in r..n {
                let v = rng.normal();
                e[r * n + c] = v;
                e[c * n + r] = v;
            }
        }
        let sqrt_of = |m: &[f64]| -> Vec<f64> {
            let mut ws = SolveWorkspace::new();
            let mut out = DenseFactorStack::new(n, 1);
            newton_schulz_stack_in(&mut ws, n, 1, m, &DenseSqrtOptions::default(), &mut out);
            assert!(out.all_converged());
            out.sqrt_mat(0).to_vec()
        };
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, 1);
        newton_schulz_stack_in(&mut ws, n, 1, &a, &DenseSqrtOptions::default(), &mut out);
        assert!(out.all_converged());
        let mut grad = vec![0.0; n * n];
        newton_schulz_backward_stack_in(
            &mut ws,
            n,
            1,
            &out.sqrt,
            &g,
            &out.iters,
            &mut grad,
        );
        // Directional derivative from the backward pass vs central FD.
        let analytic: f64 = grad.iter().zip(e.iter()).map(|(x, y)| x * y).sum();
        let eps = 1e-5;
        let ap: Vec<f64> = a.iter().zip(e.iter()).map(|(x, y)| x + eps * y).collect();
        let am: Vec<f64> = a.iter().zip(e.iter()).map(|(x, y)| x - eps * y).collect();
        let lp: f64 = sqrt_of(&ap).iter().zip(g.iter()).map(|(x, y)| x * y).sum();
        let lm: f64 = sqrt_of(&am).iter().zip(g.iter()).map(|(x, y)| x * y).sum();
        let fd = (lp - lm) / (2.0 * eps);
        let denom = fd.abs().max(analytic.abs()).max(1e-12);
        assert!(
            (analytic - fd).abs() / denom < 1e-4,
            "Lyapunov backward vs finite differences: analytic {analytic:.8e}, fd {fd:.8e}"
        );
    }

    #[test]
    fn warmed_workspace_stops_growing() {
        let mut rng = Pcg64::seeded(3);
        let n = 10;
        let batch = 4;
        let mut stack = Vec::new();
        for _ in 0..batch {
            stack.extend(random_spd(n, 1.0, &mut rng));
        }
        let mut ws = SolveWorkspace::new();
        let mut out = DenseFactorStack::new(n, batch);
        newton_schulz_stack_in(&mut ws, n, batch, &stack, &DenseSqrtOptions::default(), &mut out);
        let grows = ws.grows();
        for _ in 0..3 {
            newton_schulz_stack_in(
                &mut ws,
                n,
                batch,
                &stack,
                &DenseSqrtOptions::default(),
                &mut out,
            );
        }
        assert_eq!(ws.grows(), grows, "warmed Newton–Schulz solve must not grow the workspace");
        assert!(out.all_converged());
    }
}

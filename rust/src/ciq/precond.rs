//! Preconditioned msMINRES-CIQ (Appx. D).
//!
//! A single preconditioner `P ≈ K` accelerates *all* shifted solves at once:
//! run CIQ on the whitened operator `M = P^{-1/2} K P^{-1/2}`, whose
//! conditioning is `κ(P^{-1}K) ≪ κ(K)`. The results are equivalent to
//! `K^{±1/2} b` **up to an orthonormal rotation** (Eqs. S12/S13):
//!
//! * whitening: `R' b = P^{-1/2} M^{-1/2} b`, with `R'R'ᵀ = K^{-1}`;
//! * sampling:  `R b  = K P^{-1/2} M^{-1/2} b`, with `R Rᵀ = K`.
//!
//! Because our pivoted-Cholesky `P` is low-rank-plus-identity we have *exact*
//! `O(nr)` `P^{±1/2}` MVMs, so `M` is available directly as a composed
//! operator. (The paper reaches the same systems through a generalized
//! Lanczos recurrence that only needs `P^{-1}`; with exact `P^{-1/2}` the
//! two are algebraically identical — see `rust/DESIGN.md` for the argument,
//! and for how [`crate::ciq::SolverPolicy`] layers this under the serving
//! path.)

use super::{Ciq, CiqResult};
use crate::linalg::{Matrix, SolveWorkspace};
use crate::operators::LinearOp;
use crate::precond::PivotedCholesky;
use crate::Result;

/// The whitened operator `M = P^{-1/2} K P^{-1/2}`.
pub struct WhitenedOp<'a> {
    k: &'a dyn LinearOp,
    p: &'a PivotedCholesky,
}

impl<'a> WhitenedOp<'a> {
    /// Wrap `P^{-1/2} K P^{-1/2}`.
    pub fn new(k: &'a dyn LinearOp, p: &'a PivotedCholesky) -> Self {
        assert_eq!(k.size(), p.n());
        WhitenedOp { k, p }
    }
}

impl LinearOp for WhitenedOp<'_> {
    fn size(&self) -> usize {
        self.k.size()
    }
    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let a = self.p.invsqrt_mvm(x);
        let b = self.k.matvec(&a);
        self.p.invsqrt_mvm(&b)
    }
    /// Whole-block whitened MVM: both `P^{-1/2}` applications run blocked
    /// ([`PivotedCholesky::invsqrt_matmat`]) and the inner operator sees one
    /// `matmat`, so preconditioned block solves keep the panel-GEMM batch
    /// economics instead of degrading to per-column matvecs.
    fn matmat(&self, x: &Matrix) -> Matrix {
        let a = self.p.invsqrt_matmat(x);
        let b = self.k.matmat(&a);
        self.p.invsqrt_matmat(&b)
    }

    fn matvec_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        let n = self.size();
        let mut a = ws.take_vec(n);
        self.p.invsqrt_mvm_in(ws, x, &mut a);
        let mut b = ws.take_vec(n);
        self.k.matvec_in(ws, &a, &mut b);
        self.p.invsqrt_mvm_in(ws, &b, out);
        ws.give_vec(a);
        ws.give_vec(b);
    }

    /// Whole-block whitened MVM with every panel drawn from `ws` — the
    /// preconditioned leg of the zero-allocation steady state.
    fn matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        let n = self.size();
        let cols = x.cols();
        let mut a = ws.take_mat(n, cols);
        self.p.invsqrt_matmat_in(ws, x, &mut a);
        let mut b = ws.take_mat(n, cols);
        self.k.matmat_in(ws, &a, &mut b);
        self.p.invsqrt_matmat_in(ws, &b, out);
        ws.give_mat(a);
        ws.give_mat(b);
    }
}

impl Ciq {
    /// Preconditioned whitening: returns `R' b` with `R'R'ᵀ = K^{-1}`
    /// (rotation-equivalent to `K^{-1/2} b`).
    pub fn invsqrt_mvm_preconditioned(
        &self,
        op: &dyn LinearOp,
        precond: &PivotedCholesky,
        b: &[f64],
    ) -> Result<CiqResult> {
        let m = WhitenedOp::new(op, precond);
        let mut res = self.invsqrt_mvm(&m, b)?;
        res.solution = precond.invsqrt_mvm(&res.solution);
        Ok(res)
    }

    /// Preconditioned sampling: returns `R b` with `R Rᵀ = K`
    /// (rotation-equivalent to `K^{1/2} b`).
    pub fn sqrt_mvm_preconditioned(
        &self,
        op: &dyn LinearOp,
        precond: &PivotedCholesky,
        b: &[f64],
    ) -> Result<CiqResult> {
        let m = WhitenedOp::new(op, precond);
        let mut res = self.invsqrt_mvm(&m, b)?;
        let p_half = precond.invsqrt_mvm(&res.solution);
        res.solution = op.matvec(&p_half);
        Ok(res)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ciq::CiqOptions;
    use crate::linalg::Matrix;
    use crate::operators::{DenseOp, KernelOp, KernelType};
    use crate::rng::Pcg64;

    /// Empirical covariance check: applying the (rotated) sampling map to the
    /// columns of the identity must reproduce K: R Rᵀ = K exactly.
    #[test]
    fn rotated_sample_map_squares_to_k() {
        let mut rng = Pcg64::seeded(1);
        let n = 24;
        let x = Matrix::randn(n, 1, &mut rng);
        let op = KernelOp::new(&x, KernelType::Rbf, 0.6, 1.0, 1e-2);
        let pc = PivotedCholesky::new(&op, 8, 1e-2, 1e-12).unwrap();
        let solver = Ciq::new(CiqOptions { tol: 1e-10, q_points: 12, ..Default::default() });
        // build R as a dense matrix column by column
        let mut r = Matrix::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = solver.sqrt_mvm_preconditioned(&op, &pc, &e).unwrap().solution;
            for i in 0..n {
                r[(i, j)] = col[i];
            }
        }
        let rrt = r.matmul(&r.transpose());
        let k = op.to_dense();
        let err = rrt.max_abs_diff(&k);
        assert!(err < 1e-4, "R Rᵀ vs K max diff {err}");
    }

    #[test]
    fn rotated_whiten_map_squares_to_kinv() {
        let mut rng = Pcg64::seeded(2);
        let n = 20;
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64 * 0.5;
        }
        let op = DenseOp::new(k.clone());
        let pc = PivotedCholesky::new(&op, 6, 1.0, 1e-12).unwrap();
        let solver = Ciq::new(CiqOptions { tol: 1e-10, q_points: 12, ..Default::default() });
        let mut rp = Matrix::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = solver.invsqrt_mvm_preconditioned(&op, &pc, &e).unwrap().solution;
            for i in 0..n {
                rp[(i, j)] = col[i];
            }
        }
        // R' R'ᵀ = K^{-1}  ⇔  K R' R'ᵀ = I
        let prod = k.matmul(&rp.matmul(&rp.transpose()));
        let err = prod.max_abs_diff(&Matrix::eye(n));
        assert!(err < 1e-4, "K R'R'ᵀ vs I max diff {err}");
    }

    #[test]
    fn whitened_matmat_matches_per_column_matvec() {
        let mut rng = Pcg64::seeded(10);
        let n = 26;
        let x = Matrix::randn(n, 2, &mut rng);
        let op = KernelOp::new(&x, KernelType::Matern32, 0.8, 1.0, 1e-2);
        let pc = PivotedCholesky::new(&op, 6, 1e-2, 1e-12).unwrap();
        let m = WhitenedOp::new(&op, &pc);
        let b = Matrix::randn(n, 5, &mut rng);
        let blocked = m.matmat(&b);
        for j in 0..b.cols() {
            let single = m.matvec(&b.col(j));
            let err = crate::util::rel_err(&blocked.col(j), &single);
            assert!(err < 1e-10, "col {j}: {err}");
        }
    }

    #[test]
    fn blocked_preconditioned_solve_matches_single_vector() {
        use crate::ciq::{PrecondConfig, SolveKind, SolverPolicy};
        let mut rng = Pcg64::seeded(11);
        let n = 24;
        let x = Matrix::randn(n, 1, &mut rng);
        let op = KernelOp::new(&x, KernelType::Rbf, 0.7, 1.0, 1e-2);
        let solver = Ciq::new(CiqOptions { tol: 1e-10, q_points: 12, ..Default::default() });
        let cfg = PrecondConfig { rank: 8, sigma2: Some(1e-2), build_tol: 1e-14 };
        let ctx = solver.build_context(&op, &SolverPolicy::Preconditioned(cfg)).unwrap();
        let b = Matrix::randn(n, 4, &mut rng);
        for kind in [SolveKind::Sqrt, SolveKind::InvSqrt] {
            let blk = solver.solve_block(&op, &b, kind, &ctx).unwrap();
            for j in 0..b.cols() {
                let single = solver.solve(&op, &b.col(j), kind, &ctx).unwrap();
                let err = crate::util::rel_err(&blk.solution.col(j), &single.solution);
                assert!(err < 1e-6, "{kind:?} col {j}: {err}");
            }
        }
    }

    #[test]
    fn preconditioning_reduces_iterations() {
        // ill-conditioned kernel: tiny noise, smooth data
        let mut rng = Pcg64::seeded(3);
        let n = 150;
        let x = Matrix::randn(n, 1, &mut rng);
        let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 1e-4);
        let solver = Ciq::new(CiqOptions { tol: 1e-6, max_iters: 1000, ..Default::default() });
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let plain = solver.invsqrt_mvm(&op, &b).unwrap();
        let pc = PivotedCholesky::new(&op, 40, 1e-4, 1e-14).unwrap();
        let pre = solver.invsqrt_mvm_preconditioned(&op, &pc, &b).unwrap();
        assert!(
            pre.iterations < plain.iterations,
            "precond {} vs plain {}",
            pre.iterations,
            plain.iterations
        );
    }
}

//! msMINRES-CIQ (Alg. 1): `K^{1/2} b` and `K^{-1/2} b` through MVMs only.
//!
//! Pipeline: Lanczos estimates `(λ_min, λ_max)` (≈10 MVMs) → the Hale
//! quadrature rule produces `Q` weights/shifts → msMINRES computes all `Q`
//! shifted solves with `J` MVMs → the weighted combination gives
//! `K^{-1/2} b ≈ Σ_q w_q (t_q I + K)^{-1} b`, and one extra MVM gives
//! `K^{1/2} b = K · K^{-1/2} b`.
//!
//! Total cost `O((J + J_eig + 1) · ξ(K))` time and `O(QN)` memory
//! (Property 1); backward pass via Eq. (3) costs one more msMINRES call
//! ([`Ciq::backward`]).
//!
//! ## Spectral caching
//!
//! The `J_eig` Lanczos MVMs exist only to bracket the spectrum, and the
//! spectrum belongs to the *operator*, not the right-hand side. When many
//! solves target one operator (the sampling-service case —
//! [`crate::coordinator`]), estimate once via [`Ciq::solver_cache`] and pass
//! the resulting [`SolverCache`] (bounds + derived quadrature rule) to the
//! `*_with_bounds` entry points; every subsequent solve then costs `J` MVMs
//! flat, with zero re-estimation. The blocked entry points
//! ([`Ciq::invsqrt_mvm_block_with_bounds`] /
//! [`Ciq::sqrt_mvm_block_with_bounds`]) hand back the freshly built cache on
//! a cold call, so the first call doubles as cache population, and report the
//! matmat `column_work` actually performed by the compacted block solver
//! ([`crate::krylov::msminres::msminres_block`]).
//!
//! ## Solver policies
//!
//! The `*_with_bounds` family and the preconditioned entry points of
//! [`precond`] are unified behind a [`SolverPolicy`]: callers pick *how* an
//! operator should be approached (plain, cached bounds, or preconditioned —
//! Appx. D) and [`Ciq::build_context`] bakes every derived quantity (Lanczos
//! bounds, quadrature rule, optional pivoted-Cholesky factor) into a
//! [`SolverContext`]. [`Ciq::solve`] / [`Ciq::solve_block`] then execute any
//! [`SolveKind`] against that context with zero per-call estimation, so
//! callers (the coordinator above all) stop hand-threading caches and
//! preconditioners through four different entry points. Under
//! [`SolverPolicy::Preconditioned`] the solves run on the whitened operator
//! `M = P^{-1/2} K P^{-1/2}` and return the rotation-equivalent maps of
//! Eqs. S12/S13 (see `rust/DESIGN.md` for why that preserves sampling and
//! whitening semantics).
//!
//! ## Zero-allocation steady state
//!
//! [`Ciq::solve_block_in`] / [`Ciq::solve_in`] are the workspace twins of
//! the unified solves: every buffer comes from a caller-supplied
//! [`SolveWorkspace`] and the MVMs run through the operators'
//! `matvec_in`/`matmat_in` entry points, so a warmed workspace executes the
//! whole `krylov → ciq` stack without touching the heap (`rust/DESIGN.md`
//! §4). The owned entry points are wrappers over the same engines with a
//! transient workspace — results are bit-for-bit identical.

pub mod dense_sqrt;
pub mod precond;

pub use self::dense_sqrt::BatchedDenseConfig;
use self::precond::WhitenedOp;
use crate::krylov::msminres::{
    msminres, msminres_block, msminres_block_in, msminres_block_refined_in, msminres_in,
    MsMinresOptions,
};
use crate::krylov::{estimate_extreme_eigenvalues, EigenBounds};
use crate::linalg::{Matrix, Precision, SolveWorkspace};
use crate::operators::LinearOp;
use crate::precond::PivotedCholesky;
use crate::quadrature::{ciq_quadrature, QuadratureRule};
use crate::rng::Pcg64;
use crate::Result;
use std::sync::Arc;

/// Options for the CIQ solver.
#[derive(Clone, Debug)]
pub struct CiqOptions {
    /// Number of quadrature points `Q` (paper: 8 suffices for 1e-4).
    pub q_points: usize,
    /// msMINRES iteration cap `J`.
    pub max_iters: usize,
    /// msMINRES relative-residual tolerance.
    pub tol: f64,
    /// Lanczos iterations for eigenvalue estimation.
    pub lanczos_iters: usize,
    /// Seed for the Lanczos probe vector.
    pub seed: u64,
    /// Use the weighted (CIQ-aware) stopping criterion instead of max-shift.
    pub weighted_stop: bool,
    /// Arithmetic policy of the blocked solves: pure f64, or f32-storage
    /// kernels wrapped in f64 iterative refinement
    /// ([`crate::linalg::Precision::Mixed`], `rust/DESIGN.md` §9). Only the
    /// non-preconditioned block path honors `Mixed`; everything else runs
    /// f64 regardless.
    pub precision: Precision,
}

impl Default for CiqOptions {
    fn default() -> Self {
        CiqOptions {
            q_points: 8,
            max_iters: 400,
            tol: 1e-4,
            lanczos_iters: 15,
            seed: 0x51C2,
            weighted_stop: false,
            precision: Precision::F64,
        }
    }
}

/// Result of a CIQ solve.
#[derive(Clone, Debug)]
pub struct CiqResult {
    /// `≈ K^{±1/2} b`.
    pub solution: Vec<f64>,
    /// msMINRES iterations used (== MVM count of the solve phase).
    pub iterations: usize,
    /// Max relative residual across shifts at exit.
    pub residual: f64,
    /// Spectral bounds used for the quadrature rule.
    pub bounds: EigenBounds,
    /// Shifted solves `(t_q I + K)^{-1} b` (kept for the backward pass).
    pub shifted_solves: Vec<Vec<f64>>,
    /// The quadrature rule used.
    pub rule: QuadratureRule,
}

/// Per-operator spectral data computed once and reused across solves:
/// Lanczos bounds plus the quadrature rule derived from them. Costs
/// `lanczos_iters` MVMs to build; reusing it makes every later solve on the
/// same operator free of eigenvalue estimation.
#[derive(Clone, Debug)]
pub struct SolverCache {
    /// Lanczos spectral bounds of the operator.
    pub bounds: EigenBounds,
    /// Quadrature rule derived from the bounds (`Q` weights/shifts).
    pub rule: QuadratureRule,
}

/// Which square-root map a unified solve computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SolveKind {
    /// `K^{1/2} b` (sampling) — or its rotation `R b` with `R Rᵀ = K` under a
    /// preconditioned policy.
    Sqrt,
    /// `K^{-1/2} b` (whitening) — or its rotation `R' b` with `R'R'ᵀ = K^{-1}`
    /// under a preconditioned policy.
    InvSqrt,
}

/// Configuration of the pivoted-Cholesky preconditioner a
/// [`SolverPolicy::Preconditioned`] context builds.
#[derive(Clone, Debug, PartialEq)]
pub struct PrecondConfig {
    /// Rank budget of the partial pivoted Cholesky.
    pub rank: usize,
    /// σ² of `P = L̄L̄ᵀ + σ²I`. `None` derives it from the operator: the
    /// structural `lambda_min_bound` when one exists (kernel matrices:
    /// σ²_noise), else 1% of the mean diagonal.
    pub sigma2: Option<f64>,
    /// Early-stop tolerance on the residual diagonal of the factorization.
    pub build_tol: f64,
}

impl Default for PrecondConfig {
    fn default() -> Self {
        PrecondConfig { rank: 32, sigma2: None, build_tol: 1e-12 }
    }
}

/// How the solve stack approaches an operator. This is the knob the serving
/// path exposes end-to-end: the coordinator builds one [`SolverContext`] per
/// registered operator under the service's policy and every batch executes
/// through [`Ciq::solve_block`] against it.
#[derive(Clone, Debug, PartialEq)]
pub enum SolverPolicy {
    /// Estimate spectral bounds inline on every solve (no reuse). The
    /// baseline policy — what a context-free caller gets.
    Plain,
    /// Estimate bounds once per operator and reuse the cached bounds +
    /// quadrature rule for every subsequent solve.
    CachedBounds,
    /// Run msMINRES-CIQ on the whitened operator `M = P^{-1/2} K P^{-1/2}`
    /// with a pivoted-Cholesky `P ≈ K` (Appx. D): one preconditioner
    /// accelerates all `Q` shifted solves at once, at the price of returning
    /// the rotation-equivalent maps of Eqs. S12/S13 instead of `K^{±1/2}`.
    Preconditioned(PrecondConfig),
    /// Serve small operators (`size() ≤ n_threshold`) from cached dense
    /// `K^{±1/2}` factors computed by batched Newton–Schulz iteration
    /// ([`dense_sqrt`]): the coordinator shards such requests by size
    /// class and turns each flush into one batched GEMV. Operators above
    /// the threshold — and any operator whose iteration fails to converge
    /// — fall back to the cached-bounds msMINRES path, whose context is
    /// built alongside as the guarantee.
    BatchedDense(BatchedDenseConfig),
}

/// Everything a solve needs besides the operator and the right-hand sides:
/// the spectral cache of the operator the iterations actually run on (`K`
/// itself, or the whitened `M` under a preconditioned policy) plus the
/// preconditioner when one is in play. Built once per operator by
/// [`Ciq::build_context`] — this is the unit the coordinator's background
/// warmer populates off the request path.
#[derive(Clone)]
pub struct SolverContext {
    /// Bounds + quadrature rule of the solve operator (`K` or `M`).
    pub cache: SolverCache,
    /// The pivoted-Cholesky factor when the policy is preconditioned.
    pub precond: Option<Arc<PivotedCholesky>>,
    /// msMINRES options prebuilt from the rule (weights cloned once here,
    /// not once per solve) — what the workspace entry points run on.
    pub ms: MsMinresOptions,
    /// Resolved arithmetic policy for blocked solves through this context.
    /// Preconditioned contexts always resolve to [`Precision::F64`]: the
    /// whitened operator's MVM runs through `P^{-1/2}` triangular solves
    /// whose conditioning the f32 forward-error model does not cover.
    pub precision: Precision,
}

impl SolverContext {
    /// Whether solves through this context run on the whitened operator.
    pub fn is_preconditioned(&self) -> bool {
        self.precond.is_some()
    }
}

/// Result of a blocked CIQ solve.
#[derive(Clone, Debug)]
pub struct CiqBlockResult {
    /// `≈ K^{±1/2} B` (one column per right-hand side).
    pub solution: Matrix,
    /// msMINRES iterations per column.
    pub col_iterations: Vec<usize>,
    /// Per-shift relative residuals at exit (max over columns).
    pub residuals: Vec<f64>,
    /// Matmat column-work performed by the compacted block solver
    /// (Σ active width per iteration; ≤ `max(col_iterations) × columns`).
    pub column_work: usize,
    /// Freshly estimated spectral cache when the caller passed `None` (a
    /// cold call doubles as cache population); `None` on warm calls, which
    /// keeps the hot path free of rule clones.
    pub cache: Option<SolverCache>,
    /// Iterative-refinement sweeps spent when the solve ran under
    /// [`Precision::Mixed`] (0 on pure-f64 solves).
    pub refine_sweeps: usize,
    /// Whether a mixed solve stagnated and was re-run in pure f64. The
    /// returned numbers are then bit-identical to an f64 solve — this flag
    /// is the only trace the failed mixed attempt leaves.
    pub precision_fallback: bool,
}

/// Workspace-backed single-vector result of [`Ciq::solve_in`]: `solution`
/// belongs to the caller's workspace — hand it back with
/// [`crate::linalg::SolveWorkspace::give_vec`] once consumed.
#[derive(Debug)]
pub struct CiqVecSolve {
    /// `≈ K^{±1/2} b` (or its rotation under a preconditioned context).
    pub solution: Vec<f64>,
    /// msMINRES iterations used.
    pub iterations: usize,
    /// Max relative residual across shifts at exit.
    pub residual: f64,
}

/// Return a [`CiqBlockResult`] produced by [`Ciq::solve_block_in`] to its
/// workspace so the next solve reuses the buffers. (Results from the owned
/// entry points may also be handed in — that simply donates their capacity
/// to the pool.)
pub fn recycle_block_result(ws: &mut SolveWorkspace, res: CiqBlockResult) {
    ws.give_mat(res.solution);
    ws.give_usize(res.col_iterations);
    ws.give_vec(res.residuals);
}

/// Backward-pass payload: the vector–Jacobian product of Eq. (3) in factored
/// form, `∂/∂K ≈ -(1/2) Σ_q w_q (l_q r_qᵀ + r_q l_qᵀ)`.
pub struct CiqBackward {
    /// Per-quadrature-point `(w_q, l_q, r_q)` with
    /// `l_q = (t_qI+K)^{-1} v`, `r_q = (t_qI+K)^{-1} b`.
    pub terms: Vec<(f64, Vec<f64>, Vec<f64>)>,
}

impl CiqBackward {
    /// Materialize the dense gradient matrix (tests / small N only).
    pub fn to_dense(&self, n: usize) -> Matrix {
        let mut g = Matrix::zeros(n, n);
        for (w, l, r) in &self.terms {
            for i in 0..n {
                for j in 0..n {
                    g[(i, j)] += -0.5 * w * (l[i] * r[j] + r[i] * l[j]);
                }
            }
        }
        g
    }

    /// Contract with a symmetric direction `D`: `Σ_ij G_ij D_ij` — the
    /// directional derivative of `vᵀ K^{-1/2} b` along `dK = D`.
    pub fn contract(&self, d: &Matrix) -> f64 {
        let mut acc = 0.0;
        for (w, l, r) in &self.terms {
            // <-(w/2)(l rᵀ + r lᵀ), D> = -w · lᵀ D r  (D symmetric)
            let dr = d.matvec(r);
            acc += -w * crate::util::dot(l, &dr);
        }
        acc
    }
}

/// The msMINRES-CIQ solver.
pub struct Ciq {
    /// Options.
    pub opts: CiqOptions,
}

impl Ciq {
    /// Create a solver.
    pub fn new(opts: CiqOptions) -> Ciq {
        Ciq { opts }
    }

    /// Estimate spectral bounds of `op` with Lanczos.
    pub fn bounds(&self, op: &dyn LinearOp) -> Result<EigenBounds> {
        let mut rng = Pcg64::seeded(self.opts.seed);
        estimate_extreme_eigenvalues(op, self.opts.lanczos_iters, &mut rng)
    }

    /// Build the quadrature rule for `op` (estimating bounds if not given).
    pub fn rule(&self, op: &dyn LinearOp, bounds: Option<EigenBounds>) -> Result<(QuadratureRule, EigenBounds)> {
        // reject an impossible quadrature config before spending the Lanczos
        // MVMs — a deterministic failure should not cost estimation per call
        if self.opts.q_points == 0 {
            return Err(crate::Error::Invalid("need at least one quadrature point".into()));
        }
        let b = match bounds {
            Some(b) => b,
            None => self.bounds(op)?,
        };
        let rule = ciq_quadrature(self.opts.q_points, b.lambda_min, b.lambda_max)?;
        Ok((rule, b))
    }

    fn ms_opts(&self, rule: &QuadratureRule) -> MsMinresOptions {
        MsMinresOptions {
            max_iters: self.opts.max_iters,
            tol: self.opts.tol,
            weights: if self.opts.weighted_stop { Some(rule.weights.clone()) } else { None },
        }
    }

    /// `K^{-1/2} b` (whitening).
    pub fn invsqrt_mvm(&self, op: &dyn LinearOp, b: &[f64]) -> Result<CiqResult> {
        self.invsqrt_with_bounds(op, b, None)
    }

    /// `K^{-1/2} b` with caller-supplied spectral bounds (skips Lanczos —
    /// used when many solves share one operator).
    pub fn invsqrt_with_bounds(
        &self,
        op: &dyn LinearOp,
        b: &[f64],
        bounds: Option<EigenBounds>,
    ) -> Result<CiqResult> {
        let (rule, bnds) = self.rule(op, bounds)?;
        Ok(self.invsqrt_with_cache(op, b, &SolverCache { bounds: bnds, rule }))
    }

    /// `K^{-1/2} b` against a prebuilt cache: the cached quadrature rule is
    /// used outright — no estimation *and* no rule reconstruction. This is
    /// what [`Ciq::solve`] bottoms out in, mirroring the block path's reuse
    /// of [`SolverCache::rule`].
    fn invsqrt_with_cache(&self, op: &dyn LinearOp, b: &[f64], cache: &SolverCache) -> CiqResult {
        let ms = msminres(op, b, &cache.rule.shifts, &self.ms_opts(&cache.rule));
        let n = op.size();
        let mut sol = vec![0.0; n];
        for (w, c) in cache.rule.weights.iter().zip(&ms.solutions) {
            crate::util::axpy(*w, c, &mut sol);
        }
        CiqResult {
            solution: sol,
            iterations: ms.iterations,
            residual: ms.residuals.iter().cloned().fold(0.0, f64::max),
            bounds: cache.bounds,
            shifted_solves: ms.solutions,
            rule: cache.rule.clone(),
        }
    }

    /// `K^{1/2} b` (sampling): `K · (Σ_q w_q (t_qI+K)^{-1} b)`.
    pub fn sqrt_mvm(&self, op: &dyn LinearOp, b: &[f64]) -> Result<CiqResult> {
        self.sqrt_with_bounds(op, b, None)
    }

    /// `K^{1/2} b` with caller-supplied bounds.
    pub fn sqrt_with_bounds(
        &self,
        op: &dyn LinearOp,
        b: &[f64],
        bounds: Option<EigenBounds>,
    ) -> Result<CiqResult> {
        let mut res = self.invsqrt_with_bounds(op, b, bounds)?;
        res.solution = op.matvec(&res.solution);
        Ok(res)
    }

    /// Estimate bounds and derive the quadrature rule once, for reuse across
    /// many solves on the same operator (the `*_with_bounds` entry points).
    pub fn solver_cache(&self, op: &dyn LinearOp) -> Result<SolverCache> {
        let (rule, bounds) = self.rule(op, None)?;
        Ok(SolverCache { bounds, rule })
    }

    /// Build the full [`SolverContext`] for `op` under `policy`: Lanczos
    /// bounds + quadrature rule (of the whitened operator when the policy is
    /// preconditioned), plus the pivoted-Cholesky factor itself. This is the
    /// expensive, per-operator step — everything [`Ciq::solve`] /
    /// [`Ciq::solve_block`] do afterwards is estimation-free.
    pub fn build_context(&self, op: &dyn LinearOp, policy: &SolverPolicy) -> Result<SolverContext> {
        self.build_context_with_hint(op, policy, None).map(|(ctx, _)| ctx)
    }

    /// [`Ciq::build_context`] with an optional pivoted-Cholesky warm-start
    /// hint: the previous operator version's pivot order
    /// ([`PivotedCholesky::pivot_order`]), used by the coordinator when
    /// `replace_operator` installs a perturbed kernel. Returns the context
    /// plus the pivot-search passes the hint saved (0 for non-preconditioned
    /// policies).
    pub fn build_context_with_hint(
        &self,
        op: &dyn LinearOp,
        policy: &SolverPolicy,
        hint: Option<&[usize]>,
    ) -> Result<(SolverContext, usize)> {
        match policy {
            // BatchedDense builds the same Krylov context as CachedBounds:
            // it is both the fallback for non-convergent/oversized
            // operators and the reference the dense tier must match.
            SolverPolicy::Plain
            | SolverPolicy::CachedBounds
            | SolverPolicy::BatchedDense(_) => {
                let cache = self.solver_cache(op)?;
                let ms = self.ms_opts(&cache.rule);
                let precision = self.opts.precision;
                Ok((SolverContext { cache, precond: None, ms, precision }, 0))
            }
            SolverPolicy::Preconditioned(cfg) => {
                let sigma2 = match cfg.sigma2 {
                    Some(s) => s,
                    None => default_precond_sigma2(op),
                };
                let (pc, saved) =
                    PivotedCholesky::new_with_hint(op, cfg.rank, sigma2, cfg.build_tol, hint)?;
                let pc = Arc::new(pc);
                let m = WhitenedOp::new(op, pc.as_ref());
                let cache = self.solver_cache(&m)?;
                let ms = self.ms_opts(&cache.rule);
                // precision: the whitened path always runs f64 — see the
                // `SolverContext::precision` doc for why Mixed is not honored.
                let precision = Precision::F64;
                Ok((SolverContext { cache, precond: Some(pc), ms, precision }, saved))
            }
        }
    }

    /// Unified single-vector solve against a prebuilt context. Performs zero
    /// eigenvalue-estimation MVMs. Under a preconditioned context the result
    /// is the rotation-equivalent map (`R b` / `R' b` of Eqs. S12/S13) and
    /// `iterations` counts the msMINRES iterations on the *whitened*
    /// operator.
    pub fn solve(
        &self,
        op: &dyn LinearOp,
        b: &[f64],
        kind: SolveKind,
        ctx: &SolverContext,
    ) -> Result<CiqResult> {
        match &ctx.precond {
            None => {
                let mut res = self.invsqrt_with_cache(op, b, &ctx.cache);
                if kind == SolveKind::Sqrt {
                    res.solution = op.matvec(&res.solution);
                }
                Ok(res)
            }
            Some(pc) => {
                let m = WhitenedOp::new(op, pc.as_ref());
                let mut res = self.invsqrt_with_cache(&m, b, &ctx.cache);
                // rotate back out of the whitened space: R' b = P^{-1/2} M^{-1/2} b
                res.solution = pc.invsqrt_mvm(&res.solution);
                if kind == SolveKind::Sqrt {
                    // R b = K R' b, with R Rᵀ = K
                    res.solution = op.matvec(&res.solution);
                }
                Ok(res)
            }
        }
    }

    /// Unified blocked solve against a prebuilt context (the coordinator's
    /// per-batch entry point). Zero estimation MVMs; the preconditioned path
    /// keeps the panel-GEMM batch economics because [`WhitenedOp`] forwards
    /// whole blocks ([`WhitenedOp::matmat`] →
    /// [`PivotedCholesky::invsqrt_matmat`] + the operator's own `matmat`).
    ///
    /// Thin wrapper over [`Ciq::solve_block_in`] with a transient workspace
    /// — one engine, so the owned and workspace paths can never drift.
    pub fn solve_block(
        &self,
        op: &dyn LinearOp,
        b: &Matrix,
        kind: SolveKind,
        ctx: &SolverContext,
    ) -> Result<CiqBlockResult> {
        let mut ws = SolveWorkspace::new();
        self.solve_block_in(&mut ws, op, b, kind, ctx)
    }

    /// Workspace-backed blocked solve — the coordinator's steady-state hot
    /// path. Identical numerics to [`Ciq::solve_block`] (bit-for-bit), but
    /// every buffer — Krylov state, the weighted combination, rotation and
    /// square-root post-passes, and the returned `solution` /
    /// `col_iterations` / `residuals` — comes from `ws`, and the MVMs run
    /// through [`LinearOp::matmat_in`]. With a warmed workspace the whole
    /// call performs **zero** heap allocations. Recycle the result with
    /// [`recycle_block_result`] once consumed.
    pub fn solve_block_in(
        &self,
        ws: &mut SolveWorkspace,
        op: &dyn LinearOp,
        b: &Matrix,
        kind: SolveKind,
        ctx: &SolverContext,
    ) -> Result<CiqBlockResult> {
        let n = op.size();
        let r = b.cols();
        crate::trace!(crate::obs::trace::EventKind::SolveStart, r, n);
        let rule = &ctx.cache.rule;
        let nq = rule.shifts.len();
        // run on K, or on the whitened M under a preconditioned context; the
        // mixed-precision engine only engages on the plain path and only when
        // the operator actually ships f32 kernels — everything else is the
        // bit-identical f64 solve this method has always performed
        let (blk, refine_sweeps, precision_fallback) = match &ctx.precond {
            None => match ctx.precision {
                Precision::Mixed(cfg) if op.supports_mixed() => {
                    msminres_block_refined_in(ws, op, b, &rule.shifts, &ctx.ms, &cfg)
                }
                _ => (msminres_block_in(ws, op, b, &rule.shifts, &ctx.ms), 0, false),
            },
            Some(pc) => {
                let m = WhitenedOp::new(op, pc.as_ref());
                (msminres_block_in(ws, &m, b, &rule.shifts, &ctx.ms), 0, false)
            }
        };
        // weighted combination; transposed layout so each (column, shift)
        // pair is one contiguous axpy, then one transpose into n × r
        let mut tmp = ws.take_mat(r.max(1), n);
        for j in 0..r {
            let trow = tmp.row_mut(j);
            for (q, w) in rule.weights.iter().enumerate() {
                crate::util::axpy(*w, blk.solutions.row(j * nq + q), trow);
            }
        }
        let mut out = ws.take_mat(n, r);
        for i in 0..n {
            for j in 0..r {
                out[(i, j)] = tmp[(j, i)];
            }
        }
        ws.give_mat(tmp);
        let crate::krylov::msminres::MsMinresBlockSolve {
            solutions,
            col_iterations,
            residuals,
            column_work,
        } = blk;
        ws.give_mat(solutions);
        // rotation / square-root post-passes, all through `_in` MVMs
        let solution = match &ctx.precond {
            None => {
                if kind == SolveKind::Sqrt {
                    let mut s = ws.take_mat(n, r);
                    op.matmat_in(ws, &out, &mut s);
                    ws.give_mat(out);
                    s
                } else {
                    out
                }
            }
            Some(pc) => {
                // rotate back out of the whitened space (Eqs. S12/S13)
                let mut rot = ws.take_mat(n, r);
                pc.invsqrt_matmat_in(ws, &out, &mut rot);
                ws.give_mat(out);
                if kind == SolveKind::Sqrt {
                    let mut s = ws.take_mat(n, r);
                    op.matmat_in(ws, &rot, &mut s);
                    ws.give_mat(rot);
                    s
                } else {
                    rot
                }
            }
        };
        crate::trace!(
            crate::obs::trace::EventKind::SolveEnd,
            col_iterations.iter().copied().max().unwrap_or(0),
            column_work
        );
        Ok(CiqBlockResult {
            solution,
            col_iterations,
            residuals,
            column_work,
            cache: None,
            refine_sweeps,
            precision_fallback,
        })
    }

    /// Workspace-backed single-vector solve against a prebuilt context —
    /// the slim hot-path twin of [`Ciq::solve`] (the returned buffer
    /// belongs to `ws`). Unlike [`Ciq::solve_block`], `solve` cannot be a
    /// wrapper over this: its [`CiqResult`] carries the shifted solves and
    /// rule the backward pass needs, which the slim result deliberately
    /// drops. One contract difference follows: this entry point runs with
    /// the **context's** prebuilt msMINRES options (`ctx.ms` — cloned once
    /// per context, not per solve), while `solve` derives them from the
    /// serving `Ciq`'s own options; build the context with the same options
    /// that serve it (as the coordinator does) and the two are bit-for-bit
    /// identical.
    pub fn solve_in(
        &self,
        ws: &mut SolveWorkspace,
        op: &dyn LinearOp,
        b: &[f64],
        kind: SolveKind,
        ctx: &SolverContext,
    ) -> Result<CiqVecSolve> {
        let n = op.size();
        crate::trace!(crate::obs::trace::EventKind::SolveStart, 1, n);
        let rule = &ctx.cache.rule;
        let ms = match &ctx.precond {
            None => msminres_in(ws, op, b, &rule.shifts, &ctx.ms),
            Some(pc) => {
                let m = WhitenedOp::new(op, pc.as_ref());
                msminres_in(ws, &m, b, &rule.shifts, &ctx.ms)
            }
        };
        let mut sol = ws.take_vec(n);
        for (q, w) in rule.weights.iter().enumerate() {
            crate::util::axpy(*w, ms.solutions.row(q), &mut sol);
        }
        let iterations = ms.iterations;
        let residual = ms.residuals.iter().cloned().fold(0.0, f64::max);
        ms.recycle(ws);
        if let Some(pc) = &ctx.precond {
            // rotate back: R' b = P^{-1/2} M^{-1/2} b
            let mut rot = ws.take_vec(n);
            pc.invsqrt_mvm_in(ws, &sol, &mut rot);
            ws.give_vec(sol);
            sol = rot;
        }
        if kind == SolveKind::Sqrt {
            let mut s = ws.take_vec(n);
            op.matvec_in(ws, &sol, &mut s);
            ws.give_vec(sol);
            sol = s;
        }
        crate::trace!(crate::obs::trace::EventKind::SolveEnd, iterations, iterations);
        Ok(CiqVecSolve { solution: sol, iterations, residual })
    }

    /// Blocked whitening for `r` right-hand sides (columns of `b`): shares
    /// every iteration's MVMs as one `matmat`. Returns `(solutions, per-column
    /// iterations)`.
    pub fn invsqrt_mvm_block(&self, op: &dyn LinearOp, b: &Matrix) -> Result<(Matrix, Vec<usize>)> {
        let res = self.invsqrt_mvm_block_with_bounds(op, b, None)?;
        Ok((res.solution, res.col_iterations))
    }

    /// Blocked whitening with a caller-supplied spectral cache: when `cache`
    /// is `Some`, the solve performs **zero** eigenvalue-estimation MVMs.
    /// Pass `None` on first contact with an operator and keep the returned
    /// [`CiqBlockResult::cache`] for every solve after that.
    pub fn invsqrt_mvm_block_with_bounds(
        &self,
        op: &dyn LinearOp,
        b: &Matrix,
        cache: Option<&SolverCache>,
    ) -> Result<CiqBlockResult> {
        let fresh = match cache {
            Some(_) => None,
            None => Some(self.solver_cache(op)?),
        };
        let used: &SolverCache = cache.unwrap_or_else(|| fresh.as_ref().unwrap());
        let blk = msminres_block(op, b, &used.rule.shifts, &self.ms_opts(&used.rule));
        let n = op.size();
        let mut out = Matrix::zeros(n, b.cols());
        for (w, c) in used.rule.weights.iter().zip(&blk.solutions) {
            for i in 0..n {
                for j in 0..b.cols() {
                    out[(i, j)] += w * c[(i, j)];
                }
            }
        }
        Ok(CiqBlockResult {
            solution: out,
            col_iterations: blk.col_iterations,
            residuals: blk.residuals,
            column_work: blk.column_work,
            cache: fresh,
            refine_sweeps: 0,
            precision_fallback: false,
        })
    }

    /// Blocked sampling: `K^{1/2} B`.
    pub fn sqrt_mvm_block(&self, op: &dyn LinearOp, b: &Matrix) -> Result<(Matrix, Vec<usize>)> {
        let res = self.sqrt_mvm_block_with_bounds(op, b, None)?;
        Ok((res.solution, res.col_iterations))
    }

    /// Blocked sampling with a caller-supplied spectral cache (see
    /// [`Ciq::invsqrt_mvm_block_with_bounds`]).
    pub fn sqrt_mvm_block_with_bounds(
        &self,
        op: &dyn LinearOp,
        b: &Matrix,
        cache: Option<&SolverCache>,
    ) -> Result<CiqBlockResult> {
        let mut res = self.invsqrt_mvm_block_with_bounds(op, b, cache)?;
        res.solution = op.matmat(&res.solution);
        Ok(res)
    }

    /// Backward pass (Eq. 3): given the forward result for `K^{-1/2} b` and a
    /// back-propagated gradient `v`, compute the vector–Jacobian product
    /// `vᵀ (∂ K^{-1/2} b / ∂K)` in factored form. Costs one extra msMINRES
    /// call (the `r_q` solves are reused from the forward pass).
    pub fn backward(&self, op: &dyn LinearOp, forward: &CiqResult, v: &[f64]) -> Result<CiqBackward> {
        let mut ws = SolveWorkspace::new();
        self.backward_in(&mut ws, op, forward, v)
    }

    /// [`Ciq::backward`] with the extra msMINRES call's Krylov state drawn
    /// from `ws`. The returned [`CiqBackward`] owns its term vectors (it
    /// outlives the solve as an autograd payload), so the backward pass is
    /// workspace-assisted rather than fully allocation-free — it sits on the
    /// training path, not the serving steady state.
    pub fn backward_in(
        &self,
        ws: &mut SolveWorkspace,
        op: &dyn LinearOp,
        forward: &CiqResult,
        v: &[f64],
    ) -> Result<CiqBackward> {
        let rule = &forward.rule;
        let ms = msminres_in(ws, op, v, &rule.shifts, &self.ms_opts(rule));
        let terms = rule
            .weights
            .iter()
            .enumerate()
            .map(|(q, &w)| (w, ms.solutions.row(q).to_vec(), forward.shifted_solves[q].clone()))
            .collect();
        ms.recycle(ws);
        Ok(CiqBackward { terms })
    }
}

/// σ² used for a preconditioner when the caller does not pin one: the
/// operator's structural λ_min bound when available (kernel matrices expose
/// their noise term), else 1% of the mean diagonal — small enough that
/// `P ≈ K` stays tight, large enough that `P^{-1/2}` is well-posed.
fn default_precond_sigma2(op: &dyn LinearOp) -> f64 {
    if let Some(b) = op.lambda_min_bound() {
        if b > 0.0 {
            return b;
        }
    }
    let d = op.diagonal();
    let mean = d.iter().sum::<f64>() / (d.len().max(1) as f64);
    (mean.abs() * 1e-2).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigen::{spd_inv_sqrt, spd_sqrt};
    use crate::operators::DenseOp;
    use crate::util::rel_err;

    fn random_spd(n: usize, seed: u64, jitter: f64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += jitter;
        }
        k
    }

    #[test]
    fn sqrt_matches_eigendecomposition() {
        let n = 60;
        let k = random_spd(n, 1, n as f64 * 0.5);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(2);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { tol: 1e-8, q_points: 10, ..Default::default() });
        let res = solver.sqrt_mvm(&op, &b).unwrap();
        let exact = spd_sqrt(&k).unwrap().matvec(&b);
        let err = rel_err(&res.solution, &exact);
        assert!(err < 1e-5, "err={err}");
    }

    #[test]
    fn invsqrt_matches_eigendecomposition() {
        let n = 50;
        let k = random_spd(n, 3, n as f64 * 0.5);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(4);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { tol: 1e-8, q_points: 10, ..Default::default() });
        let res = solver.invsqrt_mvm(&op, &b).unwrap();
        let exact = spd_inv_sqrt(&k).unwrap().matvec(&b);
        let err = rel_err(&res.solution, &exact);
        assert!(err < 1e-5, "err={err}");
    }

    #[test]
    fn sqrt_then_sqrt_is_mvm() {
        // K^{1/2}(K^{1/2} b) ≈ K b
        let n = 40;
        let k = random_spd(n, 5, n as f64);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(6);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { tol: 1e-9, q_points: 12, ..Default::default() });
        let half = solver.sqrt_mvm(&op, &b).unwrap().solution;
        let full = solver.sqrt_mvm(&op, &half).unwrap().solution;
        let exact = k.matvec(&b);
        assert!(rel_err(&full, &exact) < 1e-4);
    }

    #[test]
    fn block_matches_single() {
        let n = 30;
        let k = random_spd(n, 7, n as f64 * 0.4);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(8);
        let b = Matrix::randn(n, 4, &mut rng);
        let solver = Ciq::new(CiqOptions { tol: 1e-8, ..Default::default() });
        let (block, _) = solver.invsqrt_mvm_block(&op, &b).unwrap();
        for j in 0..4 {
            let single = solver.invsqrt_mvm(&op, &b.col(j)).unwrap();
            let err = rel_err(&block.col(j), &single.solution);
            assert!(err < 1e-6, "col {j}: {err}");
        }
    }

    #[test]
    fn backward_matches_finite_difference() {
        let n = 12;
        let k = random_spd(n, 9, n as f64 * 0.6);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(10);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { tol: 1e-11, q_points: 14, ..Default::default() });
        let fwd = solver.invsqrt_mvm(&op, &b).unwrap();
        let bwd = solver.backward(&op, &fwd, &v).unwrap();
        let g = bwd.to_dense(n);
        // finite differences of f(K) = vᵀ K^{-1/2} b along symmetric directions
        let f = |kk: &Matrix| -> f64 {
            let m = spd_inv_sqrt(kk).unwrap();
            crate::util::dot(&v, &m.matvec(&b))
        };
        let h = 1e-5;
        for &(i, j) in &[(0usize, 0usize), (1, 3), (5, 2), (7, 7)] {
            let mut kp = k.clone();
            let mut km = k.clone();
            if i == j {
                kp[(i, i)] += h;
                km[(i, i)] -= h;
            } else {
                kp[(i, j)] += h;
                kp[(j, i)] += h;
                km[(i, j)] -= h;
                km[(j, i)] -= h;
            }
            let fd = (f(&kp) - f(&km)) / (2.0 * h);
            let analytic = if i == j { g[(i, i)] } else { g[(i, j)] + g[(j, i)] };
            assert!(
                (fd - analytic).abs() < 2e-3 * (1.0 + fd.abs()),
                "({i},{j}): fd={fd} analytic={analytic}"
            );
        }
    }

    #[test]
    fn contract_matches_dense_gradient() {
        let n = 10;
        let k = random_spd(n, 11, n as f64 * 0.7);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(12);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { tol: 1e-10, ..Default::default() });
        let fwd = solver.invsqrt_mvm(&op, &b).unwrap();
        let bwd = solver.backward(&op, &fwd, &v).unwrap();
        let mut d = Matrix::randn(n, n, &mut rng);
        d.symmetrize();
        let g = bwd.to_dense(n);
        let mut expect = 0.0;
        for i in 0..n {
            for j in 0..n {
                expect += g[(i, j)] * d[(i, j)];
            }
        }
        let got = bwd.contract(&d);
        assert!((got - expect).abs() < 1e-8 * (1.0 + expect.abs()));
    }

    #[test]
    fn cached_bounds_skip_lanczos_and_match() {
        use crate::operators::CountingOp;
        let n = 30;
        let k = random_spd(n, 15, n as f64 * 0.5);
        let op = CountingOp::new(DenseOp::new(k));
        let mut rng = Pcg64::seeded(16);
        let b = Matrix::randn(n, 3, &mut rng);
        let solver = Ciq::new(CiqOptions { tol: 1e-8, ..Default::default() });
        let cold = solver.invsqrt_mvm_block_with_bounds(&op, &b, None).unwrap();
        let mv_cold = op.matvec_count();
        assert!(mv_cold > 0, "cold solve must estimate the spectrum");
        assert!(cold.cache.is_some(), "cold solve must hand back the cache it built");
        let warm = solver.invsqrt_mvm_block_with_bounds(&op, &b, cold.cache.as_ref()).unwrap();
        assert_eq!(op.matvec_count(), mv_cold, "warm solve must skip Lanczos estimation");
        assert!(warm.cache.is_none(), "warm solve should not clone the cache back");
        assert!(warm.solution.max_abs_diff(&cold.solution) < 1e-12, "cached-bounds solve diverged");
    }

    #[test]
    fn policy_contexts_match_legacy_entry_points() {
        let n = 28;
        let k = random_spd(n, 21, n as f64 * 0.5);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(22);
        let b = Matrix::randn(n, 3, &mut rng);
        let solver = Ciq::new(CiqOptions { tol: 1e-9, ..Default::default() });
        // CachedBounds context must reproduce the *_with_bounds path exactly
        let ctx = solver.build_context(&op, &SolverPolicy::CachedBounds).unwrap();
        assert!(!ctx.is_preconditioned());
        let unified = solver.solve_block(&op, &b, SolveKind::InvSqrt, &ctx).unwrap();
        let legacy = solver.invsqrt_mvm_block_with_bounds(&op, &b, Some(&ctx.cache)).unwrap();
        assert!(unified.solution.max_abs_diff(&legacy.solution) < 1e-14);
        // single-vector agrees with the blocked column
        let single = solver.solve(&op, &b.col(0), SolveKind::InvSqrt, &ctx).unwrap();
        assert!(rel_err(&single.solution, &unified.solution.col(0)) < 1e-7);
        // sqrt kind matches too
        let us = solver.solve_block(&op, &b, SolveKind::Sqrt, &ctx).unwrap();
        let ls = solver.sqrt_mvm_block_with_bounds(&op, &b, Some(&ctx.cache)).unwrap();
        assert!(us.solution.max_abs_diff(&ls.solution) < 1e-14);
    }

    #[test]
    fn mixed_context_meets_f64_tolerance_and_preconditioned_stays_f64() {
        use crate::linalg::RefineConfig;
        let n = 40;
        let k = random_spd(n, 41, n as f64 * 0.5);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(42);
        let b = Matrix::randn(n, 3, &mut rng);
        let solver = Ciq::new(CiqOptions { tol: 1e-8, ..Default::default() });
        let ctx64 = solver.build_context(&op, &SolverPolicy::CachedBounds).unwrap();
        let base = solver.solve_block(&op, &b, SolveKind::InvSqrt, &ctx64).unwrap();
        assert_eq!(base.refine_sweeps, 0, "f64 contexts never refine");
        assert!(!base.precision_fallback);
        let mixed = Ciq::new(CiqOptions {
            tol: 1e-8,
            precision: Precision::Mixed(RefineConfig::default()),
            ..Default::default()
        });
        let ctxm = mixed.build_context(&op, &SolverPolicy::CachedBounds).unwrap();
        assert!(ctxm.precision.is_mixed());
        let res = mixed.solve_block(&op, &b, SolveKind::InvSqrt, &ctxm).unwrap();
        assert!(res.refine_sweeps >= 1, "tol below the f32 floor must take a sweep");
        assert!(!res.precision_fallback, "well-conditioned solve must not fall back");
        for &r in &res.residuals {
            assert!(r <= 1e-8, "refined residual {r} above the f64 tolerance");
        }
        assert!(res.solution.max_abs_diff(&base.solution) < 1e-6, "mixed drifted from f64");
        // a preconditioned context never honors Mixed
        let cfg = PrecondConfig { rank: 8, sigma2: Some(1.0), build_tol: 1e-14 };
        let ctxp = mixed.build_context(&op, &SolverPolicy::Preconditioned(cfg)).unwrap();
        assert_eq!(ctxp.precision, Precision::F64);
    }

    #[test]
    fn preconditioned_context_sample_map_squares_to_k() {
        // R Rᵀ = K for the blocked preconditioned sample map, by building R
        // from unit vectors through solve_block.
        let n = 22;
        let k = random_spd(n, 23, n as f64 * 0.4);
        let op = DenseOp::new(k.clone());
        let solver = Ciq::new(CiqOptions { tol: 1e-10, q_points: 12, ..Default::default() });
        let cfg = PrecondConfig { rank: 8, sigma2: Some(1.0), build_tol: 1e-14 };
        let ctx = solver.build_context(&op, &SolverPolicy::Preconditioned(cfg)).unwrap();
        assert!(ctx.is_preconditioned());
        let r_mat = solver.solve_block(&op, &Matrix::eye(n), SolveKind::Sqrt, &ctx).unwrap();
        let rrt = r_mat.solution.matmul(&r_mat.solution.transpose());
        let err = rrt.max_abs_diff(&k);
        assert!(err < 1e-4, "R Rᵀ vs K max diff {err}");
    }

    #[test]
    fn default_precond_sigma2_prefers_structural_bound() {
        let n = 12;
        let k = random_spd(n, 25, 0.0);
        let base = DenseOp::new(k);
        // the dense op exposes no structural bound, so sigma2 falls back to
        // 1% of the mean diagonal
        let d = base.diagonal();
        let mean = d.iter().sum::<f64>() / n as f64;
        let got = default_precond_sigma2(&base);
        assert!((got - mean * 1e-2).abs() < 1e-12 * (1.0 + mean));
        // a wrapper with a structural bound wins
        struct Bounded<'a>(&'a DenseOp);
        impl LinearOp for Bounded<'_> {
            fn size(&self) -> usize {
                self.0.size()
            }
            fn matvec(&self, x: &[f64]) -> Vec<f64> {
                self.0.matvec(x)
            }
            fn lambda_min_bound(&self) -> Option<f64> {
                Some(0.125)
            }
        }
        assert_eq!(default_precond_sigma2(&Bounded(&base)), 0.125);
    }

    #[test]
    fn workspace_solves_match_owned_api_bit_for_bit_and_stay_warm() {
        // solve_block_in / solve_in against a *reused* workspace must equal
        // the owned solve_block / solve exactly, under both a plain context
        // and a preconditioned one, for both solve kinds — and a warmed
        // workspace must stop growing.
        let n = 26;
        let k = random_spd(n, 31, n as f64 * 0.5);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(32);
        let b = Matrix::randn(n, 3, &mut rng);
        let solver = Ciq::new(CiqOptions { tol: 1e-9, ..Default::default() });
        let cfg = PrecondConfig { rank: 8, sigma2: Some(1.0), build_tol: 1e-14 };
        let ctx_plain = solver.build_context(&op, &SolverPolicy::CachedBounds).unwrap();
        let ctx_pre = solver.build_context(&op, &SolverPolicy::Preconditioned(cfg)).unwrap();
        let mut ws = SolveWorkspace::new();
        for ctx in [&ctx_plain, &ctx_pre] {
            for kind in [SolveKind::InvSqrt, SolveKind::Sqrt] {
                let owned = solver.solve_block(&op, &b, kind, ctx).unwrap();
                let res = solver.solve_block_in(&mut ws, &op, &b, kind, ctx).unwrap();
                assert_eq!(
                    res.solution.max_abs_diff(&owned.solution),
                    0.0,
                    "solve_block_in diverged ({kind:?}, precond={})",
                    ctx.is_preconditioned()
                );
                assert_eq!(res.col_iterations, owned.col_iterations);
                assert_eq!(res.residuals, owned.residuals);
                assert_eq!(res.column_work, owned.column_work);
                assert!(res.cache.is_none());
                let owned_v = solver.solve(&op, &b.col(0), kind, ctx).unwrap();
                let res_v = solver.solve_in(&mut ws, &op, &b.col(0), kind, ctx).unwrap();
                assert_eq!(res_v.solution, owned_v.solution, "solve_in diverged ({kind:?})");
                assert_eq!(res_v.iterations, owned_v.iterations);
                recycle_block_result(&mut ws, res);
                ws.give_vec(res_v.solution);
            }
        }
        // steady state: repeating the whole sweep allocates nothing new
        let grows = ws.grows();
        for ctx in [&ctx_plain, &ctx_pre] {
            for kind in [SolveKind::InvSqrt, SolveKind::Sqrt] {
                let res = solver.solve_block_in(&mut ws, &op, &b, kind, ctx).unwrap();
                recycle_block_result(&mut ws, res);
            }
        }
        assert_eq!(ws.grows(), grows, "warmed CIQ workspace must not re-allocate");
    }

    #[test]
    fn backward_in_matches_backward() {
        let n = 14;
        let k = random_spd(n, 33, n as f64 * 0.6);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(34);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { tol: 1e-10, ..Default::default() });
        let fwd = solver.invsqrt_mvm(&op, &b).unwrap();
        let owned = solver.backward(&op, &fwd, &v).unwrap();
        let mut ws = SolveWorkspace::new();
        let ws_res = solver.backward_in(&mut ws, &op, &fwd, &v).unwrap();
        assert_eq!(owned.terms.len(), ws_res.terms.len());
        for ((w1, l1, r1), (w2, l2, r2)) in owned.terms.iter().zip(&ws_res.terms) {
            assert_eq!(w1, w2);
            assert_eq!(l1, l2);
            assert_eq!(r1, r2);
        }
    }

    #[test]
    fn respects_iteration_budget() {
        let n = 80;
        let k = random_spd(n, 13, 0.01); // ill conditioned
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(14);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { max_iters: 9, tol: 1e-14, ..Default::default() });
        let res = solver.invsqrt_mvm(&op, &b).unwrap();
        assert!(res.iterations <= 9);
    }
}

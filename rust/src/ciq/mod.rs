//! msMINRES-CIQ (Alg. 1): `K^{1/2} b` and `K^{-1/2} b` through MVMs only.
//!
//! Pipeline: Lanczos estimates `(λ_min, λ_max)` (≈10 MVMs) → the Hale
//! quadrature rule produces `Q` weights/shifts → msMINRES computes all `Q`
//! shifted solves with `J` MVMs → the weighted combination gives
//! `K^{-1/2} b ≈ Σ_q w_q (t_q I + K)^{-1} b`, and one extra MVM gives
//! `K^{1/2} b = K · K^{-1/2} b`.
//!
//! Total cost `O((J + J_eig + 1) · ξ(K))` time and `O(QN)` memory
//! (Property 1); backward pass via Eq. (3) costs one more msMINRES call
//! ([`Ciq::backward`]).
//!
//! ## Spectral caching
//!
//! The `J_eig` Lanczos MVMs exist only to bracket the spectrum, and the
//! spectrum belongs to the *operator*, not the right-hand side. When many
//! solves target one operator (the sampling-service case —
//! [`crate::coordinator`]), estimate once via [`Ciq::solver_cache`] and pass
//! the resulting [`SolverCache`] (bounds + derived quadrature rule) to the
//! `*_with_bounds` entry points; every subsequent solve then costs `J` MVMs
//! flat, with zero re-estimation. The blocked entry points
//! ([`Ciq::invsqrt_mvm_block_with_bounds`] /
//! [`Ciq::sqrt_mvm_block_with_bounds`]) hand back the freshly built cache on
//! a cold call, so the first call doubles as cache population, and report the
//! matmat `column_work` actually performed by the compacted block solver
//! ([`crate::krylov::msminres::msminres_block`]).

pub mod precond;

use crate::krylov::msminres::{msminres, msminres_block, MsMinresOptions};
use crate::krylov::{estimate_extreme_eigenvalues, EigenBounds};
use crate::linalg::Matrix;
use crate::operators::LinearOp;
use crate::quadrature::{ciq_quadrature, QuadratureRule};
use crate::rng::Pcg64;
use crate::Result;

/// Options for the CIQ solver.
#[derive(Clone, Debug)]
pub struct CiqOptions {
    /// Number of quadrature points `Q` (paper: 8 suffices for 1e-4).
    pub q_points: usize,
    /// msMINRES iteration cap `J`.
    pub max_iters: usize,
    /// msMINRES relative-residual tolerance.
    pub tol: f64,
    /// Lanczos iterations for eigenvalue estimation.
    pub lanczos_iters: usize,
    /// Seed for the Lanczos probe vector.
    pub seed: u64,
    /// Use the weighted (CIQ-aware) stopping criterion instead of max-shift.
    pub weighted_stop: bool,
}

impl Default for CiqOptions {
    fn default() -> Self {
        CiqOptions {
            q_points: 8,
            max_iters: 400,
            tol: 1e-4,
            lanczos_iters: 15,
            seed: 0x51C2,
            weighted_stop: false,
        }
    }
}

/// Result of a CIQ solve.
#[derive(Clone, Debug)]
pub struct CiqResult {
    /// `≈ K^{±1/2} b`.
    pub solution: Vec<f64>,
    /// msMINRES iterations used (== MVM count of the solve phase).
    pub iterations: usize,
    /// Max relative residual across shifts at exit.
    pub residual: f64,
    /// Spectral bounds used for the quadrature rule.
    pub bounds: EigenBounds,
    /// Shifted solves `(t_q I + K)^{-1} b` (kept for the backward pass).
    pub shifted_solves: Vec<Vec<f64>>,
    /// The quadrature rule used.
    pub rule: QuadratureRule,
}

/// Per-operator spectral data computed once and reused across solves:
/// Lanczos bounds plus the quadrature rule derived from them. Costs
/// `lanczos_iters` MVMs to build; reusing it makes every later solve on the
/// same operator free of eigenvalue estimation.
#[derive(Clone, Debug)]
pub struct SolverCache {
    /// Lanczos spectral bounds of the operator.
    pub bounds: EigenBounds,
    /// Quadrature rule derived from the bounds (`Q` weights/shifts).
    pub rule: QuadratureRule,
}

/// Result of a blocked CIQ solve.
#[derive(Clone, Debug)]
pub struct CiqBlockResult {
    /// `≈ K^{±1/2} B` (one column per right-hand side).
    pub solution: Matrix,
    /// msMINRES iterations per column.
    pub col_iterations: Vec<usize>,
    /// Per-shift relative residuals at exit (max over columns).
    pub residuals: Vec<f64>,
    /// Matmat column-work performed by the compacted block solver
    /// (Σ active width per iteration; ≤ `max(col_iterations) × columns`).
    pub column_work: usize,
    /// Freshly estimated spectral cache when the caller passed `None` (a
    /// cold call doubles as cache population); `None` on warm calls, which
    /// keeps the hot path free of rule clones.
    pub cache: Option<SolverCache>,
}

/// Backward-pass payload: the vector–Jacobian product of Eq. (3) in factored
/// form, `∂/∂K ≈ -(1/2) Σ_q w_q (l_q r_qᵀ + r_q l_qᵀ)`.
pub struct CiqBackward {
    /// Per-quadrature-point `(w_q, l_q, r_q)` with
    /// `l_q = (t_qI+K)^{-1} v`, `r_q = (t_qI+K)^{-1} b`.
    pub terms: Vec<(f64, Vec<f64>, Vec<f64>)>,
}

impl CiqBackward {
    /// Materialize the dense gradient matrix (tests / small N only).
    pub fn to_dense(&self, n: usize) -> Matrix {
        let mut g = Matrix::zeros(n, n);
        for (w, l, r) in &self.terms {
            for i in 0..n {
                for j in 0..n {
                    g[(i, j)] += -0.5 * w * (l[i] * r[j] + r[i] * l[j]);
                }
            }
        }
        g
    }

    /// Contract with a symmetric direction `D`: `Σ_ij G_ij D_ij` — the
    /// directional derivative of `vᵀ K^{-1/2} b` along `dK = D`.
    pub fn contract(&self, d: &Matrix) -> f64 {
        let mut acc = 0.0;
        for (w, l, r) in &self.terms {
            // <-(w/2)(l rᵀ + r lᵀ), D> = -w · lᵀ D r  (D symmetric)
            let dr = d.matvec(r);
            acc += -w * crate::util::dot(l, &dr);
        }
        acc
    }
}

/// The msMINRES-CIQ solver.
pub struct Ciq {
    /// Options.
    pub opts: CiqOptions,
}

impl Ciq {
    /// Create a solver.
    pub fn new(opts: CiqOptions) -> Ciq {
        Ciq { opts }
    }

    /// Estimate spectral bounds of `op` with Lanczos.
    pub fn bounds(&self, op: &dyn LinearOp) -> Result<EigenBounds> {
        let mut rng = Pcg64::seeded(self.opts.seed);
        estimate_extreme_eigenvalues(op, self.opts.lanczos_iters, &mut rng)
    }

    /// Build the quadrature rule for `op` (estimating bounds if not given).
    pub fn rule(&self, op: &dyn LinearOp, bounds: Option<EigenBounds>) -> Result<(QuadratureRule, EigenBounds)> {
        // reject an impossible quadrature config before spending the Lanczos
        // MVMs — a deterministic failure should not cost estimation per call
        if self.opts.q_points == 0 {
            return Err(crate::Error::Invalid("need at least one quadrature point".into()));
        }
        let b = match bounds {
            Some(b) => b,
            None => self.bounds(op)?,
        };
        let rule = ciq_quadrature(self.opts.q_points, b.lambda_min, b.lambda_max)?;
        Ok((rule, b))
    }

    fn ms_opts(&self, rule: &QuadratureRule) -> MsMinresOptions {
        MsMinresOptions {
            max_iters: self.opts.max_iters,
            tol: self.opts.tol,
            weights: if self.opts.weighted_stop { Some(rule.weights.clone()) } else { None },
        }
    }

    /// `K^{-1/2} b` (whitening).
    pub fn invsqrt_mvm(&self, op: &dyn LinearOp, b: &[f64]) -> Result<CiqResult> {
        self.invsqrt_with_bounds(op, b, None)
    }

    /// `K^{-1/2} b` with caller-supplied spectral bounds (skips Lanczos —
    /// used when many solves share one operator).
    pub fn invsqrt_with_bounds(
        &self,
        op: &dyn LinearOp,
        b: &[f64],
        bounds: Option<EigenBounds>,
    ) -> Result<CiqResult> {
        let (rule, bnds) = self.rule(op, bounds)?;
        let ms = msminres(op, b, &rule.shifts, &self.ms_opts(&rule));
        let n = op.size();
        let mut sol = vec![0.0; n];
        for (w, c) in rule.weights.iter().zip(&ms.solutions) {
            crate::util::axpy(*w, c, &mut sol);
        }
        Ok(CiqResult {
            solution: sol,
            iterations: ms.iterations,
            residual: ms.residuals.iter().cloned().fold(0.0, f64::max),
            bounds: bnds,
            shifted_solves: ms.solutions,
            rule,
        })
    }

    /// `K^{1/2} b` (sampling): `K · (Σ_q w_q (t_qI+K)^{-1} b)`.
    pub fn sqrt_mvm(&self, op: &dyn LinearOp, b: &[f64]) -> Result<CiqResult> {
        self.sqrt_with_bounds(op, b, None)
    }

    /// `K^{1/2} b` with caller-supplied bounds.
    pub fn sqrt_with_bounds(
        &self,
        op: &dyn LinearOp,
        b: &[f64],
        bounds: Option<EigenBounds>,
    ) -> Result<CiqResult> {
        let mut res = self.invsqrt_with_bounds(op, b, bounds)?;
        res.solution = op.matvec(&res.solution);
        Ok(res)
    }

    /// Estimate bounds and derive the quadrature rule once, for reuse across
    /// many solves on the same operator (the `*_with_bounds` entry points).
    pub fn solver_cache(&self, op: &dyn LinearOp) -> Result<SolverCache> {
        let (rule, bounds) = self.rule(op, None)?;
        Ok(SolverCache { bounds, rule })
    }

    /// Blocked whitening for `r` right-hand sides (columns of `b`): shares
    /// every iteration's MVMs as one `matmat`. Returns `(solutions, per-column
    /// iterations)`.
    pub fn invsqrt_mvm_block(&self, op: &dyn LinearOp, b: &Matrix) -> Result<(Matrix, Vec<usize>)> {
        let res = self.invsqrt_mvm_block_with_bounds(op, b, None)?;
        Ok((res.solution, res.col_iterations))
    }

    /// Blocked whitening with a caller-supplied spectral cache: when `cache`
    /// is `Some`, the solve performs **zero** eigenvalue-estimation MVMs.
    /// Pass `None` on first contact with an operator and keep the returned
    /// [`CiqBlockResult::cache`] for every solve after that.
    pub fn invsqrt_mvm_block_with_bounds(
        &self,
        op: &dyn LinearOp,
        b: &Matrix,
        cache: Option<&SolverCache>,
    ) -> Result<CiqBlockResult> {
        let fresh = match cache {
            Some(_) => None,
            None => Some(self.solver_cache(op)?),
        };
        let used: &SolverCache = cache.unwrap_or_else(|| fresh.as_ref().unwrap());
        let blk = msminres_block(op, b, &used.rule.shifts, &self.ms_opts(&used.rule));
        let n = op.size();
        let mut out = Matrix::zeros(n, b.cols());
        for (w, c) in used.rule.weights.iter().zip(&blk.solutions) {
            for i in 0..n {
                for j in 0..b.cols() {
                    out[(i, j)] += w * c[(i, j)];
                }
            }
        }
        Ok(CiqBlockResult {
            solution: out,
            col_iterations: blk.col_iterations,
            residuals: blk.residuals,
            column_work: blk.column_work,
            cache: fresh,
        })
    }

    /// Blocked sampling: `K^{1/2} B`.
    pub fn sqrt_mvm_block(&self, op: &dyn LinearOp, b: &Matrix) -> Result<(Matrix, Vec<usize>)> {
        let res = self.sqrt_mvm_block_with_bounds(op, b, None)?;
        Ok((res.solution, res.col_iterations))
    }

    /// Blocked sampling with a caller-supplied spectral cache (see
    /// [`Ciq::invsqrt_mvm_block_with_bounds`]).
    pub fn sqrt_mvm_block_with_bounds(
        &self,
        op: &dyn LinearOp,
        b: &Matrix,
        cache: Option<&SolverCache>,
    ) -> Result<CiqBlockResult> {
        let mut res = self.invsqrt_mvm_block_with_bounds(op, b, cache)?;
        res.solution = op.matmat(&res.solution);
        Ok(res)
    }

    /// Backward pass (Eq. 3): given the forward result for `K^{-1/2} b` and a
    /// back-propagated gradient `v`, compute the vector–Jacobian product
    /// `vᵀ (∂ K^{-1/2} b / ∂K)` in factored form. Costs one extra msMINRES
    /// call (the `r_q` solves are reused from the forward pass).
    pub fn backward(&self, op: &dyn LinearOp, forward: &CiqResult, v: &[f64]) -> Result<CiqBackward> {
        let rule = &forward.rule;
        let ms = msminres(op, v, &rule.shifts, &self.ms_opts(rule));
        let terms = rule
            .weights
            .iter()
            .zip(ms.solutions.into_iter().zip(&forward.shifted_solves))
            .map(|(&w, (l, r))| (w, l, r.clone()))
            .collect();
        Ok(CiqBackward { terms })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigen::{spd_inv_sqrt, spd_sqrt};
    use crate::operators::DenseOp;
    use crate::util::rel_err;

    fn random_spd(n: usize, seed: u64, jitter: f64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, &mut rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += jitter;
        }
        k
    }

    #[test]
    fn sqrt_matches_eigendecomposition() {
        let n = 60;
        let k = random_spd(n, 1, n as f64 * 0.5);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(2);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { tol: 1e-8, q_points: 10, ..Default::default() });
        let res = solver.sqrt_mvm(&op, &b).unwrap();
        let exact = spd_sqrt(&k).unwrap().matvec(&b);
        let err = rel_err(&res.solution, &exact);
        assert!(err < 1e-5, "err={err}");
    }

    #[test]
    fn invsqrt_matches_eigendecomposition() {
        let n = 50;
        let k = random_spd(n, 3, n as f64 * 0.5);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(4);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { tol: 1e-8, q_points: 10, ..Default::default() });
        let res = solver.invsqrt_mvm(&op, &b).unwrap();
        let exact = spd_inv_sqrt(&k).unwrap().matvec(&b);
        let err = rel_err(&res.solution, &exact);
        assert!(err < 1e-5, "err={err}");
    }

    #[test]
    fn sqrt_then_sqrt_is_mvm() {
        // K^{1/2}(K^{1/2} b) ≈ K b
        let n = 40;
        let k = random_spd(n, 5, n as f64);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(6);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { tol: 1e-9, q_points: 12, ..Default::default() });
        let half = solver.sqrt_mvm(&op, &b).unwrap().solution;
        let full = solver.sqrt_mvm(&op, &half).unwrap().solution;
        let exact = k.matvec(&b);
        assert!(rel_err(&full, &exact) < 1e-4);
    }

    #[test]
    fn block_matches_single() {
        let n = 30;
        let k = random_spd(n, 7, n as f64 * 0.4);
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(8);
        let b = Matrix::randn(n, 4, &mut rng);
        let solver = Ciq::new(CiqOptions { tol: 1e-8, ..Default::default() });
        let (block, _) = solver.invsqrt_mvm_block(&op, &b).unwrap();
        for j in 0..4 {
            let single = solver.invsqrt_mvm(&op, &b.col(j)).unwrap();
            let err = rel_err(&block.col(j), &single.solution);
            assert!(err < 1e-6, "col {j}: {err}");
        }
    }

    #[test]
    fn backward_matches_finite_difference() {
        let n = 12;
        let k = random_spd(n, 9, n as f64 * 0.6);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(10);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { tol: 1e-11, q_points: 14, ..Default::default() });
        let fwd = solver.invsqrt_mvm(&op, &b).unwrap();
        let bwd = solver.backward(&op, &fwd, &v).unwrap();
        let g = bwd.to_dense(n);
        // finite differences of f(K) = vᵀ K^{-1/2} b along symmetric directions
        let f = |kk: &Matrix| -> f64 {
            let m = spd_inv_sqrt(kk).unwrap();
            crate::util::dot(&v, &m.matvec(&b))
        };
        let h = 1e-5;
        for &(i, j) in &[(0usize, 0usize), (1, 3), (5, 2), (7, 7)] {
            let mut kp = k.clone();
            let mut km = k.clone();
            if i == j {
                kp[(i, i)] += h;
                km[(i, i)] -= h;
            } else {
                kp[(i, j)] += h;
                kp[(j, i)] += h;
                km[(i, j)] -= h;
                km[(j, i)] -= h;
            }
            let fd = (f(&kp) - f(&km)) / (2.0 * h);
            let analytic = if i == j { g[(i, i)] } else { g[(i, j)] + g[(j, i)] };
            assert!(
                (fd - analytic).abs() < 2e-3 * (1.0 + fd.abs()),
                "({i},{j}): fd={fd} analytic={analytic}"
            );
        }
    }

    #[test]
    fn contract_matches_dense_gradient() {
        let n = 10;
        let k = random_spd(n, 11, n as f64 * 0.7);
        let op = DenseOp::new(k.clone());
        let mut rng = Pcg64::seeded(12);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { tol: 1e-10, ..Default::default() });
        let fwd = solver.invsqrt_mvm(&op, &b).unwrap();
        let bwd = solver.backward(&op, &fwd, &v).unwrap();
        let mut d = Matrix::randn(n, n, &mut rng);
        d.symmetrize();
        let g = bwd.to_dense(n);
        let mut expect = 0.0;
        for i in 0..n {
            for j in 0..n {
                expect += g[(i, j)] * d[(i, j)];
            }
        }
        let got = bwd.contract(&d);
        assert!((got - expect).abs() < 1e-8 * (1.0 + expect.abs()));
    }

    #[test]
    fn cached_bounds_skip_lanczos_and_match() {
        use crate::operators::CountingOp;
        let n = 30;
        let k = random_spd(n, 15, n as f64 * 0.5);
        let op = CountingOp::new(DenseOp::new(k));
        let mut rng = Pcg64::seeded(16);
        let b = Matrix::randn(n, 3, &mut rng);
        let solver = Ciq::new(CiqOptions { tol: 1e-8, ..Default::default() });
        let cold = solver.invsqrt_mvm_block_with_bounds(&op, &b, None).unwrap();
        let mv_cold = op.matvec_count();
        assert!(mv_cold > 0, "cold solve must estimate the spectrum");
        assert!(cold.cache.is_some(), "cold solve must hand back the cache it built");
        let warm = solver.invsqrt_mvm_block_with_bounds(&op, &b, cold.cache.as_ref()).unwrap();
        assert_eq!(op.matvec_count(), mv_cold, "warm solve must skip Lanczos estimation");
        assert!(warm.cache.is_none(), "warm solve should not clone the cache back");
        assert!(warm.solution.max_abs_diff(&cold.solution) < 1e-12, "cached-bounds solve diverged");
    }

    #[test]
    fn respects_iteration_budget() {
        let n = 80;
        let k = random_spd(n, 13, 0.01); // ill conditioned
        let op = DenseOp::new(k);
        let mut rng = Pcg64::seeded(14);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let solver = Ciq::new(CiqOptions { max_iters: 9, tol: 1e-14, ..Default::default() });
        let res = solver.invsqrt_mvm(&op, &b).unwrap();
        assert!(res.iterations <= 9);
    }
}

//! `ciq` — leader binary: CLI over the whole stack.
//!
//! Subcommands:
//! * `sample`  — draw `K^{1/2} ε` samples from a kernel operator (CIQ vs Cholesky)
//! * `whiten`  — whiten a random vector, report residual + iterations
//! * `serve`   — run the batching sampling service on synthetic traffic
//! * `svgp`    — train an SVGP on a synthetic dataset
//! * `bo`      — run Thompson-sampling Bayesian optimization
//! * `gibbs`   — image super-resolution Gibbs sampler
//! * `artifacts` — list + smoke-run the AOT artifacts through PJRT

use ciq::bo::{lander::Lander, run_bo, testfns::Hartmann6, BoConfig, Problem, Sampler};
use ciq::ciq::{Ciq, CiqOptions};
use ciq::coordinator::{ReqKind, SamplingService, ServiceConfig, SharedOp};
use ciq::data;
use ciq::gibbs::{reconstruct, write_pgm, GibbsConfig};
use ciq::linalg::Matrix;
use ciq::operators::{KernelOp, KernelType};
use ciq::rng::Pcg64;
use ciq::runtime::{artifacts_dir, discover_artifacts, Runtime, XlaCiq};
use ciq::svgp::{train, evaluate, Backend, Gaussian, Svgp, SvgpHyper};
use ciq::util::cli::Args;
use std::collections::HashMap;
use std::sync::Arc;

fn kernel_of(name: &str) -> KernelType {
    match name {
        "rbf" => KernelType::Rbf,
        "matern12" => KernelType::Matern12,
        "matern32" => KernelType::Matern32,
        _ => KernelType::Matern52,
    }
}

fn main() {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "sample" | "whiten" => cmd_sample(&args, cmd == "whiten"),
        "serve" => cmd_serve(&args),
        "svgp" => cmd_svgp(&args),
        "bo" => cmd_bo(&args),
        "gibbs" => cmd_gibbs(&args),
        "artifacts" => cmd_artifacts(&args),
        _ => {
            println!(
                "usage: ciq <sample|whiten|serve|svgp|bo|gibbs|artifacts> [--n N] [--q Q] [--tol T] ...\n\
                 see README.md for the full flag list"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_sample(args: &Args, whiten: bool) -> ciq::Result<()> {
    let n = args.get_or("n", 2000usize);
    let d = args.get_or("d", 3usize);
    let seed = args.get_or("seed", 0u64);
    let kind = kernel_of(args.get("kernel").unwrap_or("rbf"));
    let mut rng = Pcg64::seeded(seed);
    let x = Matrix::randn(n, d, &mut rng);
    let op = KernelOp::new(&x, kind, args.get_or("ell", 1.0), args.get_or("s2", 1.0), args.get_or("noise", 1e-2));
    let solver = Ciq::new(CiqOptions {
        q_points: args.get_or("q", 8usize),
        tol: args.get_or("tol", 1e-4),
        max_iters: args.get_or("max-iters", 400usize),
        ..Default::default()
    });
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let (res, secs) = ciq::util::timed(|| {
        if whiten {
            solver.invsqrt_mvm(&op, &b)
        } else {
            solver.sqrt_mvm(&op, &b)
        }
    });
    let res = res?;
    println!(
        "{} n={n} kernel={kind:?}: iters={} residual={:.2e} kappa≈{:.1e} time={secs:.3}s",
        if whiten { "whiten" } else { "sample" },
        res.iterations,
        res.residual,
        res.bounds.kappa()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> ciq::Result<()> {
    let n = args.get_or("n", 1000usize);
    let requests = args.get_or("requests", 64usize);
    let mut rng = Pcg64::seeded(args.get_or("seed", 0u64));
    let x = Matrix::randn(n, 2, &mut rng);
    let op: SharedOp = Arc::new(KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 1e-2));
    let mut ops = HashMap::new();
    ops.insert("default".to_string(), op);
    let svc = SamplingService::start(
        ServiceConfig {
            max_batch: args.get_or("max-batch", 16usize),
            workers: args.get_or("workers", 2usize),
            ..Default::default()
        },
        ops,
    );
    // clock: end-to-end demo wall-time printed at exit.
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = (0..requests)
        .map(|i| {
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            svc.submit("default", if i % 2 == 0 { ReqKind::Sample } else { ReqKind::Whiten }, b)
        })
        .collect();
    for t in tickets {
        t.wait()?;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("served {requests} requests on n={n} in {dt:.2}s ({:.1} req/s)", requests as f64 / dt);
    println!("metrics: {}", svc.metrics().summary());
    svc.shutdown();
    Ok(())
}

fn cmd_svgp(args: &Args) -> ciq::Result<()> {
    let n = args.get_or("n", 2000usize);
    let m = args.get_or("m", 128usize);
    let steps = args.get_or("steps", 60usize);
    let backend = if args.get("backend") == Some("cholesky") {
        Backend::Cholesky
    } else {
        Backend::Ciq(CiqOptions { tol: 1e-3, max_iters: 200, ..Default::default() })
    };
    let ds = data::gaussian_regression(n, 2, 0.1, args.get_or("seed", 0u64));
    let mut rng = Pcg64::seeded(1);
    let (train_set, test_set) = ds.split(0.8, &mut rng);
    let z = train_set.kmeans_centers(m, 6, &mut rng);
    let mut model = Svgp::new(z, KernelType::Rbf, SvgpHyper::default(), Box::new(Gaussian { noise: 0.05 }), backend);
    let stats = train(&mut model, &train_set, steps, args.get_or("batch", 128usize), 0.5, 0.02, &mut rng)?;
    let metrics = evaluate(&mut model, &test_set)?;
    println!(
        "svgp n={} m={m} steps={steps}: NLL={:.4} RMSE={:.4} time={:.1}s ({:.0}ms/step)",
        train_set.len(),
        metrics.nll,
        metrics.error,
        stats.seconds,
        1000.0 * stats.seconds / steps as f64
    );
    if !model.iteration_log.is_empty() {
        println!(
            "msMINRES iterations: mean={:.1} max={}",
            ciq::util::mean(&model.iteration_log.iter().map(|&v| v as f64).collect::<Vec<_>>()),
            model.iteration_log.iter().max().unwrap()
        );
    }
    Ok(())
}

fn cmd_bo(args: &Args) -> ciq::Result<()> {
    let problem_name = args.get("problem").unwrap_or("hartmann6");
    let sampler = match args.get("sampler").unwrap_or("ciq") {
        "cholesky" => Sampler::Cholesky,
        "rff" => Sampler::Rff,
        _ => Sampler::Ciq,
    };
    let cfg = BoConfig {
        candidates: args.get_or("candidates", 2000usize),
        evaluations: args.get_or("evals", 60usize),
        sampler,
        ..Default::default()
    };
    let hart = Hartmann6;
    let lander = Lander::default();
    let problem: &dyn Problem = if problem_name == "lander" { &lander } else { &hart };
    let trace = run_bo(problem, &cfg, args.get_or("seed", 0u64))?;
    println!(
        "bo {problem_name} sampler={sampler:?} T={}: best={:.4}{}",
        cfg.candidates,
        trace.best(),
        problem
            .optimum()
            .map(|o| format!(" regret={:.4}", trace.best() - o))
            .unwrap_or_default()
    );
    Ok(())
}

fn cmd_gibbs(args: &Args) -> ciq::Result<()> {
    let cfg = GibbsConfig {
        n: args.get_or("n", 48usize),
        samples: args.get_or("samples", 60usize),
        burn_in: args.get_or("burn-in", 20usize),
        ..Default::default()
    };
    let res = reconstruct(&cfg, args.get_or("seed", 0u64))?;
    println!(
        "gibbs {}x{} ({} dims): rmse={:.4} {:.2} samples/s mean_ciq_iters={:.0}",
        cfg.n,
        cfg.n,
        cfg.n * cfg.n,
        res.rmse,
        1.0 / res.seconds_per_sample.max(1e-9),
        res.mean_ciq_iters
    );
    if let Some(out) = args.get("out") {
        write_pgm(std::path::Path::new(out), &res.reconstruction, cfg.n)
            .map_err(|e| ciq::Error::Runtime(format!("write pgm: {e}")))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> ciq::Result<()> {
    let dir = artifacts_dir();
    let metas = discover_artifacts(&dir);
    if metas.is_empty() {
        println!("no artifacts in {} — run `make artifacts`", dir.display());
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    for meta in &metas {
        print!("{} ... ", meta.path.file_name().unwrap().to_string_lossy());
        let exe = rt.load(meta)?;
        if meta.kind == "ciq_sqrt" && args.has("run") {
            let mut rng = Pcg64::seeded(3);
            let x = Matrix::randn(meta.n, meta.d, &mut rng);
            let op = KernelOp::new(&x, KernelType::Rbf, 1.0, 1.0, 0.5);
            let solver = Ciq::new(CiqOptions { q_points: meta.q, ..Default::default() });
            let (rule, _) = solver.rule(&op, None)?;
            let b: Vec<f64> = (0..meta.n).map(|_| rng.normal()).collect();
            let xc = XlaCiq::new(&rt, exe)?;
            let out = xc.run(&x, 1.0, 1.0, 0.5, &b, &rule.shifts, &rule.weights)?;
            println!("ok (residual {:.1e})", out.residual);
        } else {
            println!("compiled ok");
        }
    }
    Ok(())
}

//! Row-major dense matrix with blocked, threaded matrix multiply. The
//! per-panel inner loops live in [`super::gemm`]; this file only decides how
//! to partition work across the persistent thread pool.

use super::gemm;
use super::workspace::SolveWorkspace;
use crate::rng::Pcg64;
use crate::util::threadpool::{num_threads, parallel_fill, parallel_map};
use std::ops::{Add, Index, IndexMut, Mul, Sub};

/// Dense row-major `f64` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a function of `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Build from an owned row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// Take back the row-major backing buffer (capacity preserved) — the
    /// [`super::workspace::SolveWorkspace`] recycling path.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Matrix of iid standard normals.
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg64) -> Matrix {
        Matrix { rows, cols, data: (0..rows * cols).map(|_| rng.normal()).collect() }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row-major backing slice.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable row-major backing slice.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j` (copied).
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `self * v` (matrix–vector).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.matvec_into(v, &mut out);
        out
    }

    /// `self * v` written into `out` — no allocation, same threading as
    /// [`Self::matvec`]. The zero-allocation solve path
    /// ([`crate::operators::LinearOp::matvec_in`]) bottoms out here.
    pub fn matvec_into(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.cols, "matvec dim mismatch");
        assert_eq!(out.len(), self.rows, "matvec out dim mismatch");
        parallel_fill(out, 256, |start, block| {
            for (k, o) in block.iter_mut().enumerate() {
                *o = gemm::dot_unrolled(self.row(start + k), v);
            }
        });
    }

    /// `selfᵀ * v` without forming the transpose: the `n = 1` case of
    /// [`Self::t_matmul`], routed through the same [`gemm::gemm_tn`]
    /// micro-kernel with the row reduction split into per-thread stripes —
    /// this sits on the Lanczos/msMINRES reorthogonalization path.
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows, "matvec_t dim mismatch");
        let (m, c) = (self.rows, self.cols);
        let stripes = num_threads().min(m.div_ceil(64).max(1));
        if stripes <= 1 || m * c < 32_768 {
            let mut out = vec![0.0; c];
            gemm::gemm_tn(m, c, 1, &self.data, v, &mut out);
            return out;
        }
        let rows_per = m.div_ceil(stripes);
        let partials: Vec<Vec<f64>> = parallel_map(stripes, |s| {
            let r0 = (s * rows_per).min(m);
            let r1 = ((s + 1) * rows_per).min(m);
            let mut acc = vec![0.0; c];
            if r1 > r0 {
                gemm::gemm_tn(r1 - r0, c, 1, &self.data[r0 * c..r1 * c], &v[r0..r1], &mut acc);
            }
            acc
        });
        let mut out = vec![0.0; c];
        for part in partials {
            for (o, p) in out.iter_mut().zip(&part) {
                *o += p;
            }
        }
        out
    }

    /// Blocked, threaded GEMM: `self * other`. Each thread owns a contiguous
    /// panel of output rows and runs the register-blocked
    /// [`gemm::gemm_nn`] micro-kernel over it.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// [`Self::matmul`] written into a pre-sized `out` — no allocation
    /// (the B-panel pack scratch inside [`gemm::gemm_nn`] is a reused
    /// thread-local), same threading.
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix) {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        assert_eq!(out.rows, self.rows, "matmul out rows mismatch");
        assert_eq!(out.cols, other.cols, "matmul out cols mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let data_out = out.as_mut_slice();
        data_out.fill(0.0);
        if m == 0 || k == 0 || n == 0 {
            return;
        }
        parallel_fill(data_out, 64 * n, |start_flat, block| {
            let row0 = start_flat / n;
            let nrows = block.len() / n;
            gemm::gemm_nn(nrows, k, n, &self.data[row0 * k..(row0 + nrows) * k], &other.data, block);
        });
    }

    /// `selfᵀ * other` without forming the transpose. The shared row
    /// reduction is split into stripes handled by [`gemm::gemm_tn`] on the
    /// thread pool, with per-stripe partial products summed at the end.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "t_matmul dim mismatch");
        let (p_rows, m, n) = (self.rows, self.cols, other.cols);
        if p_rows == 0 || m == 0 || n == 0 {
            return Matrix::zeros(m, n);
        }
        let stripes = num_threads().min(p_rows.div_ceil(64).max(1));
        if stripes <= 1 || p_rows * m * n < 65_536 {
            let mut out = Matrix::zeros(m, n);
            gemm::gemm_tn(p_rows, m, n, &self.data, &other.data, out.as_mut_slice());
            return out;
        }
        let rows_per = p_rows.div_ceil(stripes);
        let partials: Vec<Vec<f64>> = parallel_map(stripes, |s| {
            let r0 = (s * rows_per).min(p_rows);
            let r1 = ((s + 1) * rows_per).min(p_rows);
            let mut acc = vec![0.0; m * n];
            if r1 > r0 {
                gemm::gemm_tn(
                    r1 - r0,
                    m,
                    n,
                    &self.data[r0 * m..r1 * m],
                    &other.data[r0 * n..r1 * n],
                    &mut acc,
                );
            }
            acc
        });
        let mut flat = vec![0.0; m * n];
        for part in partials {
            for (o, p) in flat.iter_mut().zip(&part) {
                *o += p;
            }
        }
        Matrix::from_vec(m, n, flat)
    }

    /// `selfᵀ * other` into a pre-sized `out`, with the per-stripe partial
    /// products drawn from `ws` instead of fresh heap buffers — the
    /// zero-allocation analogue of [`Self::t_matmul`] (same stripe split,
    /// identical numerics: each stripe reduces its own rows, partials are
    /// summed in stripe order).
    pub fn t_matmul_in(&self, ws: &mut SolveWorkspace, other: &Matrix, out: &mut Matrix) {
        assert_eq!(self.rows, other.rows, "t_matmul dim mismatch");
        assert_eq!(out.rows, self.cols, "t_matmul out rows mismatch");
        assert_eq!(out.cols, other.cols, "t_matmul out cols mismatch");
        let (p_rows, m, n) = (self.rows, self.cols, other.cols);
        out.as_mut_slice().fill(0.0);
        if p_rows == 0 || m == 0 || n == 0 {
            return;
        }
        let stripes = num_threads().min(p_rows.div_ceil(64).max(1));
        if stripes <= 1 || p_rows * m * n < 65_536 {
            gemm::gemm_tn(p_rows, m, n, &self.data, &other.data, out.as_mut_slice());
            return;
        }
        let rows_per = p_rows.div_ceil(stripes);
        // one flat scratch holds every stripe's partial; blocks of exactly
        // m*n elements line up with the stripes
        let mut partials = ws.take_vec(stripes * m * n);
        parallel_fill(&mut partials, m * n, |start, block| {
            let s = start / (m * n);
            let r0 = (s * rows_per).min(p_rows);
            let r1 = ((s + 1) * rows_per).min(p_rows);
            if r1 > r0 {
                gemm::gemm_tn(r1 - r0, m, n, &self.data[r0 * m..r1 * m], &other.data[r0 * n..r1 * n], block);
            }
        });
        let flat = out.as_mut_slice();
        for s in 0..stripes {
            for (o, p) in flat.iter_mut().zip(&partials[s * m * n..(s + 1) * m * n]) {
                *o += p;
            }
        }
        ws.give_vec(partials);
    }

    /// `selfᵀ * v` into a pre-sized `out` without allocating. Serial
    /// [`gemm::gemm_tn`] — the in-place path serves skinny reductions
    /// (preconditioner factors), where striping has nothing to win.
    pub fn matvec_t_into(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows, "matvec_t dim mismatch");
        assert_eq!(out.len(), self.cols, "matvec_t out dim mismatch");
        out.fill(0.0);
        gemm::gemm_tn(self.rows, self.cols, 1, &self.data, v, out);
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |entry| difference against another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Symmetrize in place: `(A + Aᵀ)/2`.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let avg = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = avg;
                self[(j, i)] = avg;
            }
        }
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl Mul for &Matrix {
    type Output = Matrix;
    fn mul(self, rhs: &Matrix) -> Matrix {
        self.matmul(rhs)
    }
}

impl Add for &Matrix {
    type Output = Matrix;
    fn add(self, rhs: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }
}

impl Sub for &Matrix {
    type Output = Matrix;
    fn sub(self, rhs: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::seeded(1);
        let a = Matrix::randn(17, 23, &mut rng);
        let b = Matrix::randn(23, 11, &mut rng);
        let c = a.matmul(&b);
        for i in 0..17 {
            for j in 0..11 {
                let mut s = 0.0;
                for p in 0..23 {
                    s += a[(i, p)] * b[(p, j)];
                }
                assert!((c[(i, j)] - s).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn t_matmul_matches_transpose_matmul() {
        let mut rng = Pcg64::seeded(2);
        let a = Matrix::randn(9, 5, &mut rng);
        let b = Matrix::randn(9, 7, &mut rng);
        let c1 = a.t_matmul(&b);
        let c2 = a.transpose().matmul(&b);
        assert!(c1.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn matvec_consistency() {
        let mut rng = Pcg64::seeded(3);
        let a = Matrix::randn(30, 14, &mut rng);
        let v: Vec<f64> = (0..14).map(|_| rng.normal()).collect();
        let y = a.matvec(&v);
        let vm = Matrix::from_vec(14, 1, v.clone());
        let y2 = a.matmul(&vm);
        for i in 0..30 {
            assert!((y[i] - y2[(i, 0)]).abs() < 1e-12);
        }
        let w: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let z = a.matvec_t(&w);
        let z2 = a.transpose().matvec(&w);
        for j in 0..14 {
            assert!((z[j] - z2[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn striped_transpose_products_match_reference() {
        // big enough to cross the parallel-stripe thresholds in
        // matvec_t (m·c ≥ 32768) and t_matmul (p·m·n ≥ 65536)
        let mut rng = Pcg64::seeded(21);
        let a = Matrix::randn(601, 60, &mut rng);
        let w: Vec<f64> = (0..601).map(|_| rng.normal()).collect();
        let z = a.matvec_t(&w);
        let z_ref = a.transpose().matvec(&w);
        for (x, y) in z.iter().zip(&z_ref) {
            assert!((x - y).abs() < 1e-9);
        }
        let b = Matrix::randn(601, 23, &mut rng);
        let c = a.t_matmul(&b);
        let c_ref = a.transpose().matmul(&b);
        assert!(c.max_abs_diff(&c_ref) < 1e-9);
    }

    #[test]
    fn matmul_non_divisible_panel_sizes() {
        // shapes that exercise every micro-kernel tail (rows % 4, cols % 8)
        let mut rng = Pcg64::seeded(22);
        for &(m, k, n) in &[(66, 31, 9usize), (3, 70, 15), (129, 2, 8), (5, 5, 5)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let c = a.matmul(&b);
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0;
                    for p in 0..k {
                        s += a[(i, p)] * b[(p, j)];
                    }
                    assert!((c[(i, j)] - s).abs() < 1e-10, "({m},{k},{n}) at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn into_variants_match_allocating_versions() {
        let mut rng = Pcg64::seeded(31);
        let mut ws = SolveWorkspace::new();
        // small (serial) and large (striped) shapes
        for &(p, m, n) in &[(9usize, 5usize, 7usize), (601, 40, 23)] {
            let a = Matrix::randn(p, m, &mut rng);
            let b = Matrix::randn(p, n, &mut rng);
            let mut out = Matrix::zeros(m, n);
            a.t_matmul_in(&mut ws, &b, &mut out);
            assert!(out.max_abs_diff(&a.t_matmul(&b)) == 0.0, "t_matmul_in ({p},{m},{n})");
            let sq = Matrix::randn(m, m, &mut rng);
            let mut out2 = Matrix::zeros(m, n);
            sq.matmul_into(&a.t_matmul(&b), &mut out2);
            assert!(out2.max_abs_diff(&sq.matmul(&out)) == 0.0, "matmul_into ({m},{n})");
            let v: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
            let mut tv = vec![1.0; m]; // nonzero: _into must overwrite
            a.matvec_t_into(&v, &mut tv);
            let tref = a.matvec_t(&v);
            for (x, y) in tv.iter().zip(&tref) {
                assert!((x - y).abs() < 1e-9, "matvec_t_into ({p},{m})");
            }
            let w: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let mut mv = vec![1.0; p];
            a.matvec_into(&w, &mut mv);
            assert_eq!(mv, a.matvec(&w), "matvec_into ({p},{m})");
        }
        // into_vec round-trip preserves the buffer
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let v = m.into_vec();
        assert_eq!(v, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn eye_and_transpose() {
        let i = Matrix::eye(5);
        let mut rng = Pcg64::seeded(4);
        let a = Matrix::randn(5, 5, &mut rng);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-15);
        assert!(a.transpose().transpose().max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn add_sub_scale() {
        let mut rng = Pcg64::seeded(5);
        let a = Matrix::randn(4, 4, &mut rng);
        let b = Matrix::randn(4, 4, &mut rng);
        let c = &(&a + &b) - &b;
        assert!(c.max_abs_diff(&a) < 1e-12);
        let mut d = a.clone();
        d.scale(2.0);
        assert!((&d - &a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn symmetrize_produces_symmetric() {
        let mut rng = Pcg64::seeded(6);
        let mut a = Matrix::randn(6, 6, &mut rng);
        a.symmetrize();
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(a[(i, j)], a[(j, i)]);
            }
        }
    }
}

//! Cholesky factorization `K = L Lᵀ` and triangular solves.
//!
//! This is the `O(N³)` baseline the paper compares against (Sec. 2):
//! `L ε` draws samples from `N(0, K)` and `L^{-1} b` whitens `b`, each
//! equivalent to `K^{±1/2} b` up to an orthonormal rotation.

use crate::linalg::Matrix;
use crate::{Error, Result};

/// Lower-triangular Cholesky factor of an SPD matrix.
pub struct Cholesky {
    l: Matrix,
}

impl Cholesky {
    /// Factor `K = L Lᵀ`. Fails if `K` is not (numerically) positive definite.
    pub fn new(k: &Matrix) -> Result<Cholesky> {
        Self::with_jitter(k, 0.0)
    }

    /// Factor `K + jitter·I = L Lᵀ` (jitter emulates the diagonal fudge the
    /// baseline implementations need for ill-conditioned kernels).
    pub fn with_jitter(k: &Matrix, jitter: f64) -> Result<Cholesky> {
        let n = k.rows();
        if k.cols() != n {
            return Err(Error::Shape(format!("cholesky needs square, got {}x{}", n, k.cols())));
        }
        let mut l = Matrix::zeros(n, n);
        for j in 0..n {
            // diagonal
            let mut d = k[(j, j)] + jitter;
            for p in 0..j {
                d -= l[(j, p)] * l[(j, p)];
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(Error::Numerical(format!(
                    "cholesky failed at pivot {j}: d={d} (matrix not PD?)"
                )));
            }
            let dj = d.sqrt();
            l[(j, j)] = dj;
            // column below the diagonal — row-major friendly ordering
            for i in (j + 1)..n {
                let mut s = k[(i, j)] + if i == j { jitter } else { 0.0 };
                let (ri, rj) = (i * n, j * n);
                let li = &l.as_slice()[ri..ri + j];
                let lj = &l.as_slice()[rj..rj + j];
                for p in 0..j {
                    s -= li[p] * lj[p];
                }
                l[(i, j)] = s / dj;
            }
        }
        Ok(Cholesky { l })
    }

    /// The lower-triangular factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    /// Dimension.
    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// `log |K| = 2 Σ log L_ii`.
    pub fn logdet(&self) -> f64 {
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Forward substitution: solve `L y = b`.
    pub fn solve_l(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        for i in 0..n {
            let row = self.l.row(i);
            let mut s = y[i];
            for p in 0..i {
                s -= row[p] * y[p];
            }
            y[i] = s / row[i];
        }
        y
    }

    /// Back substitution: solve `Lᵀ x = b`.
    pub fn solve_lt(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        let mut x = b.to_vec();
        for i in (0..n).rev() {
            let mut s = x[i];
            for p in (i + 1)..n {
                s -= self.l[(p, i)] * x[p];
            }
            x[i] = s / self.l[(i, i)];
        }
        x
    }

    /// Full solve `K x = b` via `L Lᵀ x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_lt(&self.solve_l(b))
    }

    /// Sampling map: `L b` ~ `K^{1/2} b` up to rotation.
    pub fn sample_mvm(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        let mut out = vec![0.0; n];
        for i in 0..n {
            let row = self.l.row(i);
            let mut s = 0.0;
            for p in 0..=i {
                s += row[p] * b[p];
            }
            out[i] = s;
        }
        out
    }

    /// Whitening map: `L^{-1} b` ~ `K^{-1/2} b` up to rotation.
    pub fn whiten_mvm(&self, b: &[f64]) -> Vec<f64> {
        self.solve_l(b)
    }

    /// Solve against many right-hand sides (columns of `B`).
    pub fn solve_mat(&self, b: &Matrix) -> Matrix {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let mut out = Matrix::zeros(n, b.cols());
        for j in 0..b.cols() {
            let col = b.col(j);
            let x = self.solve(&col);
            for i in 0..n {
                out[(i, j)] = x[i];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, rng: &mut Pcg64) -> Matrix {
        let a = Matrix::randn(n, n, rng);
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64;
        }
        k
    }

    #[test]
    fn reconstructs_k() {
        let mut rng = Pcg64::seeded(1);
        let k = random_spd(20, &mut rng);
        let ch = Cholesky::new(&k).unwrap();
        let rec = ch.l().matmul(&ch.l().transpose());
        assert!(rec.max_abs_diff(&k) < 1e-9);
    }

    #[test]
    fn solve_matches_identity() {
        let mut rng = Pcg64::seeded(2);
        let k = random_spd(25, &mut rng);
        let ch = Cholesky::new(&k).unwrap();
        let b: Vec<f64> = (0..25).map(|_| rng.normal()).collect();
        let x = ch.solve(&b);
        let kb = k.matvec(&x);
        for (a, b) in kb.iter().zip(&b) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn whiten_then_sample_roundtrip() {
        let mut rng = Pcg64::seeded(3);
        let k = random_spd(15, &mut rng);
        let ch = Cholesky::new(&k).unwrap();
        let b: Vec<f64> = (0..15).map(|_| rng.normal()).collect();
        let w = ch.whiten_mvm(&b);
        let s = ch.sample_mvm(&w);
        for (a, b) in s.iter().zip(&b) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn logdet_matches_eig_free_check() {
        // For K = c I, logdet = n log c.
        let n = 10;
        let mut k = Matrix::eye(n);
        k.scale(3.0);
        let ch = Cholesky::new(&k).unwrap();
        assert!((ch.logdet() - n as f64 * 3.0f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let mut k = Matrix::eye(3);
        k[(2, 2)] = -1.0;
        assert!(Cholesky::new(&k).is_err());
    }

    #[test]
    fn whitened_covariance_is_identityish() {
        // cov(L^{-1} K L^{-T}) = I exactly: check L^{-1} K L^{-T} = I.
        let mut rng = Pcg64::seeded(4);
        let k = random_spd(12, &mut rng);
        let ch = Cholesky::new(&k).unwrap();
        // compute L^{-1} K L^{-T} column by column
        for j in 0..12 {
            let mut e = vec![0.0; 12];
            e[j] = 1.0;
            let col = ch.solve_lt(&e); // L^{-T} e_j
            let kcol = k.matvec(&col);
            let out = ch.solve_l(&kcol);
            for (i, &v) in out.iter().enumerate() {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((v - expect).abs() < 1e-8);
            }
        }
    }
}

//! Runtime-dispatched SIMD micro-kernels for the GEMM/MVM hot paths.
//!
//! The serving stack funnels every hot inner loop — KernelOp Gram panels,
//! msMINRES reorthogonalization, the batched Newton–Schulz tier — through
//! the register-tiled kernels in [`super::gemm`], which until this layer
//! relied on LLVM auto-vectorizing `chunks_exact` loops. This module makes
//! the vectorization *explicit*: hand-written `core::arch` kernels for the
//! three GEMM layouts (`gemm_nn` 4×8 FMA tile, `gemm_nt` contiguous-row
//! reductions, `gemm_tn` rank-1 updates), the unrolled dot product, and a
//! lane-parallel `ρ`/`dρ` panel evaluator built on a polynomial SIMD `exp`.
//!
//! ## Dispatch model
//!
//! * [`Backend`] enumerates the implemented instruction sets. AVX2+FMA and
//!   AVX-512F variants are compiled on `x86_64` and selected behind
//!   `is_x86_feature_detected!`; NEON is the `aarch64` baseline. The safe
//!   scalar kernels in [`super::gemm`] are the always-compiled fallback and
//!   the oracle the property tests compare against.
//! * Selection happens **once per process** ([`backend`] /
//!   [`table`]): the first dispatch resolves `CIQ_SIMD` + CPUID into a
//!   `&'static` [`KernelTable`] of plain function pointers cached in a
//!   `OnceLock`. Per-call feature detection would put an atomic load *and*
//!   a branch tree in front of kernels that are called millions of times
//!   per solve; a resolved fn-pointer table costs one predictable indirect
//!   call. [`resolutions`] exposes the resolve counter so tests can prove
//!   the "exactly once" claim (`pool_spawned_threads`-style).
//! * `CIQ_SIMD={auto,avx2,avx512,neon,scalar}` overrides auto-detection
//!   (unknown or unavailable values warn to stderr and fall back to
//!   `auto`). Tests and benches flip backends *in-process* with
//!   [`set_backend`] / [`clear_backend_override`], which bypass the cached
//!   choice without re-running resolution.
//!
//! ## Safety conventions
//!
//! All `#[target_feature]` kernels live in this file (a `structlint` rule
//! confines `core::arch` and `#[target_feature]` here). Every kernel is an
//! `unsafe fn` whose single obligation is "the named features are available
//! on the executing CPU"; the only callers are the safe `*_entry` wrappers
//! stored in a [`KernelTable`], and [`table_for`] refuses to hand out a
//! table whose backend [`Backend::available`] rejects — that check is the
//! discharge of the obligation. Raw-pointer arithmetic inside kernels is
//! justified per-kernel by slice bounds established in safe code.
//!
//! ## SIMD `exp` contract
//!
//! The panel evaluator needs one `exp` per matrix entry. The scalar
//! bit-twiddled [`crate::util::fastmath::fast_exp`] was benchmarked against
//! glibc and reverted (EXPERIMENTS.md §Perf iteration 2: glibc `exp` is
//! ~6 ns/call, the approximation 0.9–1.0×) — but vectorizing amortizes the
//! range reduction and polynomial over 4–8 lanes, which is different
//! economics. The vector `exp` here uses the same `2^n · 2^f` scheme and
//! hi/lo `ln 2` split as `fast_exp` with a **degree-11 Taylor** polynomial
//! on `|f| ≤ ln2/2` (truncation ≤ 7e-15), giving ≤ ~4 ULP relative error
//! over the kernel domain `x ∈ [-708, 0]` — property-tested against glibc
//! at 1e-13. Inputs below -708 flush to zero (glibc returns subnormals
//! there; kernels treat both as 0). The glibc path remains the fallback
//! (scalar backend, lane remainders) and the oracle.

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// An implemented instruction-set backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The safe, always-compiled kernels in [`super::gemm`] (plus the glibc
    /// `exp` path in the kernel operator). Fallback and oracle.
    Scalar,
    /// AVX2 + FMA (`x86_64`, 4 × f64 lanes).
    Avx2,
    /// AVX-512F (`x86_64`, 8 × f64 lanes).
    Avx512,
    /// NEON / AdvSIMD (`aarch64` baseline, 2 × f64 lanes).
    Neon,
}

impl Backend {
    /// All backends, scalar first, strongest last.
    pub fn all() -> [Backend; 4] {
        [Backend::Scalar, Backend::Avx2, Backend::Avx512, Backend::Neon]
    }

    /// Stable lowercase name (matches the `CIQ_SIMD` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Avx512 => "avx512",
            Backend::Neon => "neon",
        }
    }

    /// Whether this backend can run on the executing CPU. This is the
    /// runtime gate every `unsafe` kernel's feature contract rests on.
    pub fn available(self) -> bool {
        match self {
            Backend::Scalar => true,
            Backend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                        && std::arch::is_x86_feature_detected!("fma")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Backend::Avx512 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx512f")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Backend::Neon => {
                // NEON is baseline on aarch64: always present when this arm
                // is compiled for that target.
                cfg!(target_arch = "aarch64")
            }
        }
    }

    fn to_idx(self) -> u8 {
        match self {
            Backend::Scalar => 0,
            Backend::Avx2 => 1,
            Backend::Avx512 => 2,
            Backend::Neon => 3,
        }
    }

    fn from_idx(i: u8) -> Backend {
        match i {
            0 => Backend::Scalar,
            1 => Backend::Avx2,
            2 => Backend::Avx512,
            3 => Backend::Neon,
            _ => unreachable!("invalid backend index"),
        }
    }
}

/// Strongest available backend on this CPU (AVX-512F > AVX2 > NEON >
/// scalar).
pub fn best_available() -> Backend {
    for b in [Backend::Avx512, Backend::Avx2, Backend::Neon] {
        if b.available() {
            return b;
        }
    }
    Backend::Scalar
}

/// Parse a `CIQ_SIMD` spec into a backend. Pure (no env access) so the
/// parsing is unit-testable; unknown or unavailable specs warn to stderr
/// and fall back to auto-detection.
pub fn choose(spec: &str) -> Backend {
    let want = match spec.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => return best_available(),
        "scalar" => Backend::Scalar,
        "avx2" => Backend::Avx2,
        "avx512" => Backend::Avx512,
        "neon" => Backend::Neon,
        other => {
            eprintln!("ciq: unknown CIQ_SIMD value {other:?}; using auto detection");
            return best_available();
        }
    };
    if want.available() {
        want
    } else {
        eprintln!(
            "ciq: CIQ_SIMD={} requested but not available on this CPU; using auto detection",
            want.name()
        );
        best_available()
    }
}

/// Sentinel meaning "no in-process override"; real backends use
/// [`Backend::to_idx`] (0..=3).
const OVERRIDE_NONE: u8 = u8::MAX;

/// In-process backend override ([`set_backend`]); beats the cached
/// environment choice. `u8::MAX` = none.
static OVERRIDE: AtomicU8 = AtomicU8::new(OVERRIDE_NONE);

/// The once-per-process resolved backend (env + CPUID).
static CHOSEN: OnceLock<Backend> = OnceLock::new();

/// The resolved backend's kernel table (None for scalar), cached alongside
/// [`CHOSEN`] so the steady-state [`table`] call is one atomic load + one
/// `OnceLock` read — no repeated feature detection.
static RESOLVED_TABLE: OnceLock<Option<&'static KernelTable>> = OnceLock::new();

/// Process-lifetime count of [`CHOSEN`] resolutions. The `OnceLock`
/// guarantees ≤ 1; tests assert == 1 after heavy multi-threaded use.
static RESOLUTIONS: AtomicUsize = AtomicUsize::new(0);

fn resolve() -> Backend {
    // ordering: Relaxed — monotonic diagnostic counter, read only by tests
    // after the OnceLock has already synchronized the resolution itself.
    RESOLUTIONS.fetch_add(1, Ordering::Relaxed);
    match std::env::var("CIQ_SIMD") {
        Ok(spec) => choose(&spec),
        Err(_) => best_available(),
    }
}

/// Number of times dispatch resolution has run in this process (≤ 1 by
/// construction; exposed so tests can prove it, like
/// `pool_spawned_threads`).
pub fn resolutions() -> usize {
    // ordering: Relaxed — see `resolve`; a plain counter with no dependent
    // memory to publish.
    RESOLUTIONS.load(Ordering::Relaxed)
}

/// The backend the next kernel dispatch will use: the in-process override
/// if one is set, else the once-per-process `CIQ_SIMD`/CPUID resolution.
pub fn backend() -> Backend {
    // ordering: Relaxed — the override is one independent word; no other
    // memory is published through it, and the tests/benches that flip it
    // synchronize externally (they run the kernels on the flipping thread).
    let ov = OVERRIDE.load(Ordering::Relaxed);
    if ov != OVERRIDE_NONE {
        return Backend::from_idx(ov);
    }
    *CHOSEN.get_or_init(resolve)
}

/// Force a backend for this process (tests/benches), bypassing — not
/// re-running — the cached resolution. Fails if the backend cannot run on
/// this CPU, so a forced table never violates a kernel's feature contract.
pub fn set_backend(b: Backend) -> Result<(), String> {
    if !b.available() {
        return Err(format!("backend {} is not available on this CPU", b.name()));
    }
    // ordering: Relaxed — single-word flag; see `backend` for why no
    // stronger ordering is needed.
    OVERRIDE.store(b.to_idx(), Ordering::Relaxed);
    Ok(())
}

/// Drop the [`set_backend`] override, returning to the resolved choice.
pub fn clear_backend_override() {
    // ordering: Relaxed — single-word flag; see `backend`.
    OVERRIDE.store(OVERRIDE_NONE, Ordering::Relaxed);
}

/// The kernel table for the current [`backend`], or `None` when the scalar
/// fallback should run. This is the call sites' single entry point:
/// `if let Some(t) = simd::table() { (t.gemm_nn)(…) } else { scalar }`.
pub fn table() -> Option<&'static KernelTable> {
    // ordering: Relaxed — see `backend`.
    let ov = OVERRIDE.load(Ordering::Relaxed);
    if ov != OVERRIDE_NONE {
        return table_for(Backend::from_idx(ov));
    }
    *RESOLVED_TABLE.get_or_init(|| table_for(*CHOSEN.get_or_init(resolve)))
}

/// The kernel table for a specific backend, if it is compiled *and*
/// available on this CPU (`None` for scalar — callers fall back to
/// [`super::gemm`]). The availability check here is what discharges the
/// `unsafe` feature contract of every kernel reachable through the table.
pub fn table_for(b: Backend) -> Option<&'static KernelTable> {
    if !b.available() {
        return None;
    }
    match b {
        Backend::Scalar => None,
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => Some(&x86::AVX2_TABLE),
        #[cfg(target_arch = "x86_64")]
        Backend::Avx512 => Some(&x86::AVX512_TABLE),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => Some(&neon::NEON_TABLE),
        #[cfg(not(target_arch = "x86_64"))]
        Backend::Avx2 | Backend::Avx512 => None,
        #[cfg(not(target_arch = "aarch64"))]
        Backend::Neon => None,
    }
}

/// Resolved function pointers for one backend. All entries are *safe* fns
/// (thin wrappers whose bodies enter the `unsafe` feature-gated kernels):
/// `#[target_feature]` fns cannot coerce to safe fn pointers on the pinned
/// toolchain, and routing every entry through [`table_for`]'s availability
/// check keeps the unsafety confined to this module.
///
/// Contracts (validated by the dispatching wrappers in [`super::gemm`] /
/// the kernel operator, and re-checked with `debug_assert!` in the
/// kernels):
/// * `gemm_nn(m, k, n, a, b, c, pack)`: buffer sizes as in
///   [`super::gemm::gemm_nn_with_pack`]; `pack.len() ≥ k·NR` whenever
///   `n ≥ NR` (the wrapper grows it before dispatch).
/// * `gemm_nt` / `gemm_tn` / `dot`: same shapes as their
///   [`super::gemm`] counterparts.
/// * `rho_row(fam, outputscale, sqi, sq, row)`: in-place
///   `row[j] ← s²·ρ(√max(sqi + sq[j] − 2·row[j], 0))` with
///   `sq.len() == row.len()`.
/// * `grad_row(fam, outputscale, li, sqi, sq, pan, rv)`: returns the
///   row's `(Σ_j li·rv[j]·s²·dρ(r_j), Σ_j li·rv[j]·s²·ρ(r_j))` partial
///   sums, `r_j = √max(sqi + sq[j] − 2·pan[j], 0)`, equal-length slices.
pub struct KernelTable {
    /// Which backend these pointers implement (for logs/benches).
    pub backend: Backend,
    /// `C += A·B` micro-kernel driver (packed-B panels).
    pub gemm_nn: fn(usize, usize, usize, &[f64], &[f64], &mut [f64], &mut [f64]),
    /// `C += A·Bᵀ` (contiguous-row reductions).
    pub gemm_nt: fn(usize, usize, usize, &[f64], &[f64], &mut [f64]),
    /// `C += Aᵀ·B` (rank-1 updates).
    pub gemm_tn: fn(usize, usize, usize, &[f64], &[f64], &mut [f64]),
    /// Vectorized dot product.
    pub dot: fn(&[f64], &[f64]) -> f64,
    /// Lane-parallel kernel-panel evaluation (Gram values → `s²·ρ`).
    pub rho_row: fn(RhoFamily, f64, f64, &[f64], &mut [f64]),
    /// Lane-parallel gradient-panel contraction (one output row's partial
    /// `(d log ℓ, d log s²)` sums).
    pub grad_row: fn(RhoFamily, f64, f64, f64, &[f64], &[f64], &[f64]) -> (f64, f64),
}

/// Kernel correlation family — the SIMD-facing mirror of
/// `operators::KernelType`, which delegates its `ρ`/`dρ` scalar math here
/// so the scalar fallback, the lane remainders, and the vector kernels all
/// share one set of formulas.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RhoFamily {
    /// Squared-exponential `exp(-r²/2)`.
    Rbf,
    /// Matérn ν = 1/2: `exp(-r)`.
    Matern12,
    /// Matérn ν = 3/2: `(1+√3 r) exp(-√3 r)`.
    Matern32,
    /// Matérn ν = 5/2: `(1+√5 r+5r²/3) exp(-√5 r)`.
    Matern52,
}

impl RhoFamily {
    /// Correlation as a function of the scaled distance `r ≥ 0` (glibc
    /// `exp`; the scalar reference the vector kernels are tested against).
    #[inline]
    pub fn rho(self, r: f64) -> f64 {
        match self {
            RhoFamily::Rbf => (-0.5 * r * r).exp(),
            RhoFamily::Matern12 => (-r).exp(),
            RhoFamily::Matern32 => {
                let a = 3f64.sqrt() * r;
                (1.0 + a) * (-a).exp()
            }
            RhoFamily::Matern52 => {
                let a = 5f64.sqrt() * r;
                (1.0 + a + a * a / 3.0) * (-a).exp()
            }
        }
    }

    /// `d ρ / d log ℓ` as a function of scaled distance `r` (note
    /// `dr/d log ℓ = −r`), used for hyperparameter gradients.
    #[inline]
    pub fn drho_dlog_ell(self, r: f64) -> f64 {
        match self {
            RhoFamily::Rbf => r * r * (-0.5 * r * r).exp(),
            RhoFamily::Matern12 => r * (-r).exp(),
            RhoFamily::Matern32 => {
                let s = 3f64.sqrt();
                s * r * s * r * (-s * r).exp()
            }
            RhoFamily::Matern52 => {
                let s = 5f64.sqrt();
                let a = s * r;
                // dρ/dr = -(a/3)(1+a) e^{-a} · s ... computed analytically:
                // ρ(r) = (1+a+a²/3)e^{-a}, dρ/da = (1/3)a(1+a)·(-e^{-a}) + ...
                // dρ/da = -(a + a²)/3 · e^{-a} ... derive: d/da[(1+a+a²/3)e^{-a}]
                //       = (1+2a/3)e^{-a} - (1+a+a²/3)e^{-a} = -(a/3)(1+a)e^{-a}
                // dρ/dlogℓ = dρ/da · da/dlogℓ = -(a/3)(1+a)e^{-a} · (-a)
                a * a / 3.0 * (1.0 + a) * (-a).exp()
            }
        }
    }
}

/// Scalar reference for [`KernelTable::rho_row`] — bit-identical to the
/// pre-dispatch panel loop in the kernel operator (same op order per
/// element). Oracle for the SIMD property tests and the bench's "before"
/// side.
pub fn rho_row_scalar(fam: RhoFamily, outputscale: f64, sqi: f64, sq: &[f64], row: &mut [f64]) {
    debug_assert_eq!(sq.len(), row.len());
    for (v, &sj) in row.iter_mut().zip(sq) {
        let d2 = (sqi + sj - 2.0 * *v).max(0.0);
        *v = outputscale * fam.rho(d2.sqrt());
    }
}

/// Scalar reference for [`KernelTable::grad_row`] — bit-identical op order
/// to the pre-dispatch gradient loop (`lr = li·rv[j]·s²` in that exact
/// association). Oracle for the SIMD property tests.
pub fn grad_row_scalar(
    fam: RhoFamily,
    outputscale: f64,
    li: f64,
    sqi: f64,
    sq: &[f64],
    pan: &[f64],
    rv: &[f64],
) -> (f64, f64) {
    debug_assert_eq!(sq.len(), pan.len());
    debug_assert_eq!(sq.len(), rv.len());
    let mut d_ell = 0.0;
    let mut d_s2 = 0.0;
    for ((&xx, &sj), &rj) in pan.iter().zip(sq).zip(rv) {
        let rr = (sqi + sj - 2.0 * xx).max(0.0).sqrt();
        let lr = li * rj * outputscale;
        d_ell += lr * fam.drho_dlog_ell(rr);
        d_s2 += lr * fam.rho(rr);
    }
    (d_ell, d_s2)
}

/// Taylor coefficients `1/k!` for the degree-11 `e^r` polynomial on
/// `|r| ≤ ln2/2` (truncation `r¹²/12!` ≤ 7e-15 at the interval edge — the
/// accuracy step up from `fast_exp`'s degree-7 that keeps the vector path
/// inside the solver's 1e-10 test tolerances).
#[allow(dead_code)] // referenced only by the cfg(target_arch) kernel modules
const EXP_POLY: [f64; 12] = [
    1.0,
    1.0,
    1.0 / 2.0,
    1.0 / 6.0,
    1.0 / 24.0,
    1.0 / 120.0,
    1.0 / 720.0,
    1.0 / 5040.0,
    1.0 / 40320.0,
    1.0 / 362880.0,
    1.0 / 3628800.0,
    1.0 / 39916800.0,
];

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    //! AVX2+FMA and AVX-512F kernel variants. Every `unsafe fn` here has a
    //! single safety obligation — the features named in its
    //! `#[target_feature]` are available on the executing CPU — discharged
    //! by [`super::table_for`]'s `Backend::available` gate in front of the
    //! safe `*_entry` wrappers (the only callers).

    use super::{Backend, KernelTable, RhoFamily, EXP_POLY};
    use crate::linalg::gemm::{self, MR, NR};
    use crate::util::fastmath::{LN_2_HI, LN_2_LO, LOG2_E};
    use core::arch::x86_64::*;

    const ROUND_NEAREST: i32 = _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC;

    pub(super) static AVX2_TABLE: KernelTable = KernelTable {
        backend: Backend::Avx2,
        gemm_nn: gemm_nn_avx2_entry,
        gemm_nt: gemm_nt_avx2_entry,
        gemm_tn: gemm_tn_avx2_entry,
        dot: dot_avx2_entry,
        rho_row: rho_row_avx2_entry,
        grad_row: grad_row_avx2_entry,
    };

    // ---------------------------------------------------------------- AVX2

    /// Vector `e^x` (4 lanes), valid for `x ≤ 708`: `fast_exp`'s
    /// `2^n · 2^f` scheme with the hi/lo `ln 2` split and a degree-11
    /// Taylor polynomial (module docs: ≤ ~4 ULP on the kernel domain).
    /// Flushes `x < -708` to zero.
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    #[inline]
    pub(crate) unsafe fn exp_avx2(x: __m256d) -> __m256d {
        // SAFETY: register-only intrinsics (no memory access); avx2+fma
        // hold by this fn's own contract.
        unsafe {
            // clamp keeps n inside the i32 convert range for arbitrarily
            // negative inputs; the final mask zeroes the clamped lanes
            let xc = _mm256_max_pd(x, _mm256_set1_pd(-800.0));
            let n = _mm256_round_pd::<ROUND_NEAREST>(_mm256_mul_pd(xc, _mm256_set1_pd(LOG2_E)));
            // r = (x − n·ln2_hi) − n·ln2_lo, |r| ≤ ln2/2
            let r = _mm256_fnmadd_pd(n, _mm256_set1_pd(LN_2_HI), xc);
            let r = _mm256_fnmadd_pd(n, _mm256_set1_pd(LN_2_LO), r);
            let mut p = _mm256_set1_pd(EXP_POLY[11]);
            for idx in (0..11).rev() {
                p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(EXP_POLY[idx]));
            }
            // 2^n through the exponent bits (n ≥ −1022 after the −708 cut,
            // so the biased exponent stays normal)
            let n64 = _mm256_cvtepi32_epi64(_mm256_cvtpd_epi32(n));
            let bits = _mm256_slli_epi64::<52>(_mm256_add_epi64(n64, _mm256_set1_epi64x(1023)));
            let res = _mm256_mul_pd(p, _mm256_castsi256_pd(bits));
            // flush x < −708 to zero (glibc would return a subnormal)
            let keep = _mm256_cmp_pd::<_CMP_GE_OQ>(x, _mm256_set1_pd(-708.0));
            _mm256_and_pd(res, keep)
        }
    }

    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    #[inline]
    pub(crate) unsafe fn neg_avx2(v: __m256d) -> __m256d {
        // SAFETY: register-only intrinsic; features per the fn contract.
        unsafe { _mm256_xor_pd(v, _mm256_set1_pd(-0.0)) }
    }

    /// Horizontal sum of a 4-lane accumulator.
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    #[inline]
    pub(crate) unsafe fn hsum_avx2(v: __m256d) -> f64 {
        // SAFETY: register-only intrinsics; features per the fn contract.
        unsafe {
            let lo = _mm256_castpd256_pd128(v);
            let hi = _mm256_extractf128_pd::<1>(v);
            let s = _mm_add_pd(lo, hi);
            let s = _mm_add_sd(s, _mm_unpackhi_pd(s, s));
            _mm_cvtsd_f64(s)
        }
    }

    /// Vectorized dot with zip-truncation semantics (like the scalar
    /// kernel): two independent accumulators over 8-element chunks.
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len().min(b.len());
        // SAFETY: avx2+fma per the fn contract; every load reads at
        // p + lane < n ≤ min(a.len(), b.len()).
        unsafe {
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut acc0 = _mm256_setzero_pd();
            let mut acc1 = _mm256_setzero_pd();
            let mut p = 0;
            while p + 8 <= n {
                let a0 = _mm256_loadu_pd(ap.add(p));
                let b0 = _mm256_loadu_pd(bp.add(p));
                acc0 = _mm256_fmadd_pd(a0, b0, acc0);
                let a1 = _mm256_loadu_pd(ap.add(p + 4));
                let b1 = _mm256_loadu_pd(bp.add(p + 4));
                acc1 = _mm256_fmadd_pd(a1, b1, acc1);
                p += 8;
            }
            if p + 4 <= n {
                let a0 = _mm256_loadu_pd(ap.add(p));
                let b0 = _mm256_loadu_pd(bp.add(p));
                acc0 = _mm256_fmadd_pd(a0, b0, acc0);
                p += 4;
            }
            let mut s = hsum_avx2(_mm256_add_pd(acc0, acc1));
            while p < n {
                s += *ap.add(p) * *bp.add(p);
                p += 1;
            }
            s
        }
    }

    /// MR×NR register tile of [`gemm_nn_avx2`]: 8 ymm accumulators, two B
    /// loads + four broadcasts + eight FMAs per reduction step.
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn kernel_mrxnr_avx2(
        k: usize,
        n: usize,
        j: usize,
        a: &[f64],
        bpack: &[f64],
        c: &mut [f64],
    ) {
        debug_assert!(a.len() >= MR * k && bpack.len() >= k * NR);
        debug_assert!(j + NR <= n && c.len() >= (MR - 1) * n + j + NR);
        // SAFETY: avx2+fma per the fn contract. Loads read a at
        // mi·k + p < MR·k and bpack at p·NR + lane < k·NR; loads/stores on
        // c touch rows mi·n + j .. +NR with j + NR ≤ n and mi < MR — all
        // inside the slices the safe driver carved out (debug-asserted).
        unsafe {
            let ap = a.as_ptr();
            let bp = bpack.as_ptr();
            let mut acc00 = _mm256_setzero_pd();
            let mut acc01 = _mm256_setzero_pd();
            let mut acc10 = _mm256_setzero_pd();
            let mut acc11 = _mm256_setzero_pd();
            let mut acc20 = _mm256_setzero_pd();
            let mut acc21 = _mm256_setzero_pd();
            let mut acc30 = _mm256_setzero_pd();
            let mut acc31 = _mm256_setzero_pd();
            for p in 0..k {
                let b0 = _mm256_loadu_pd(bp.add(p * NR));
                let b1 = _mm256_loadu_pd(bp.add(p * NR + 4));
                let a0 = _mm256_set1_pd(*ap.add(p));
                acc00 = _mm256_fmadd_pd(a0, b0, acc00);
                acc01 = _mm256_fmadd_pd(a0, b1, acc01);
                let a1 = _mm256_set1_pd(*ap.add(k + p));
                acc10 = _mm256_fmadd_pd(a1, b0, acc10);
                acc11 = _mm256_fmadd_pd(a1, b1, acc11);
                let a2 = _mm256_set1_pd(*ap.add(2 * k + p));
                acc20 = _mm256_fmadd_pd(a2, b0, acc20);
                acc21 = _mm256_fmadd_pd(a2, b1, acc21);
                let a3 = _mm256_set1_pd(*ap.add(3 * k + p));
                acc30 = _mm256_fmadd_pd(a3, b0, acc30);
                acc31 = _mm256_fmadd_pd(a3, b1, acc31);
            }
            let cp = c.as_mut_ptr();
            let c0 = cp.add(j);
            _mm256_storeu_pd(c0, _mm256_add_pd(_mm256_loadu_pd(c0), acc00));
            let c0h = cp.add(j + 4);
            _mm256_storeu_pd(c0h, _mm256_add_pd(_mm256_loadu_pd(c0h), acc01));
            let c1 = cp.add(n + j);
            _mm256_storeu_pd(c1, _mm256_add_pd(_mm256_loadu_pd(c1), acc10));
            let c1h = cp.add(n + j + 4);
            _mm256_storeu_pd(c1h, _mm256_add_pd(_mm256_loadu_pd(c1h), acc11));
            let c2 = cp.add(2 * n + j);
            _mm256_storeu_pd(c2, _mm256_add_pd(_mm256_loadu_pd(c2), acc20));
            let c2h = cp.add(2 * n + j + 4);
            _mm256_storeu_pd(c2h, _mm256_add_pd(_mm256_loadu_pd(c2h), acc21));
            let c3 = cp.add(3 * n + j);
            _mm256_storeu_pd(c3, _mm256_add_pd(_mm256_loadu_pd(c3), acc30));
            let c3h = cp.add(3 * n + j + 4);
            _mm256_storeu_pd(c3h, _mm256_add_pd(_mm256_loadu_pd(c3h), acc31));
        }
    }

    /// 1×NR edge tile for the row remainder of [`gemm_nn_avx2`].
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn kernel_1xnr_avx2(j: usize, arow: &[f64], bpack: &[f64], crow: &mut [f64]) {
        debug_assert!(bpack.len() >= arow.len() * NR && j + NR <= crow.len());
        // SAFETY: avx2+fma per the fn contract; bpack loads read at
        // p·NR + lane < k·NR and the stores hit crow[j..j+NR] (both
        // debug-asserted, guaranteed by the driver).
        unsafe {
            let bp = bpack.as_ptr();
            let mut acc0 = _mm256_setzero_pd();
            let mut acc1 = _mm256_setzero_pd();
            for (p, &av) in arow.iter().enumerate() {
                let avv = _mm256_set1_pd(av);
                let b0 = _mm256_loadu_pd(bp.add(p * NR));
                let b1 = _mm256_loadu_pd(bp.add(p * NR + 4));
                acc0 = _mm256_fmadd_pd(avv, b0, acc0);
                acc1 = _mm256_fmadd_pd(avv, b1, acc1);
            }
            let cp = crow.as_mut_ptr().add(j);
            _mm256_storeu_pd(cp, _mm256_add_pd(_mm256_loadu_pd(cp), acc0));
            let cph = cp.add(4);
            _mm256_storeu_pd(cph, _mm256_add_pd(_mm256_loadu_pd(cph), acc1));
        }
    }

    /// Driver for the packed-panel `C += A·B`: identical structure to the
    /// scalar [`gemm::gemm_nn_with_pack`] (pack an NR-column B panel, sweep
    /// MR-row tiles, shared scalar column tail), with AVX2 register tiles.
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn gemm_nn_avx2(
        m: usize,
        k: usize,
        n: usize,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        pack: &mut [f64],
    ) {
        debug_assert!(a.len() == m * k && b.len() == k * n && c.len() == m * n);
        debug_assert!(n < NR || pack.len() >= k * NR);
        // SAFETY: avx2+fma per the fn contract, forwarded to the tile
        // kernels; the panel slicing matches the (bounds-checked) scalar
        // driver exactly.
        unsafe {
            let mut j = 0;
            while j + NR <= n {
                for p in 0..k {
                    pack[p * NR..(p + 1) * NR].copy_from_slice(&b[p * n + j..p * n + j + NR]);
                }
                let mut i = 0;
                while i + MR <= m {
                    let ar = &a[i * k..(i + MR) * k];
                    let cr = &mut c[i * n..(i + MR) * n];
                    kernel_mrxnr_avx2(k, n, j, ar, pack, cr);
                    i += MR;
                }
                while i < m {
                    let ar = &a[i * k..(i + 1) * k];
                    let cr = &mut c[i * n..(i + 1) * n];
                    kernel_1xnr_avx2(j, ar, pack, cr);
                    i += 1;
                }
                j += NR;
            }
            if j < n {
                gemm::gemm_nn_coltail(m, k, n, j, a, b, c);
            }
        }
    }

    /// Four simultaneous dots against one shared B row (the 4-row block of
    /// [`gemm_nt_avx2`]): each loaded `b` vector feeds four FMAs.
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot4_avx2(
        a0: &[f64],
        a1: &[f64],
        a2: &[f64],
        a3: &[f64],
        b: &[f64],
    ) -> (f64, f64, f64, f64) {
        let k = b.len();
        debug_assert!(a0.len() == k && a1.len() == k && a2.len() == k && a3.len() == k);
        // SAFETY: avx2+fma per the fn contract; all loads read at
        // p + lane < k = b.len() = a*.len() (debug-asserted, guaranteed by
        // the driver's row slicing).
        unsafe {
            let p0 = a0.as_ptr();
            let p1 = a1.as_ptr();
            let p2 = a2.as_ptr();
            let p3 = a3.as_ptr();
            let bp = b.as_ptr();
            let mut s0 = _mm256_setzero_pd();
            let mut s1 = _mm256_setzero_pd();
            let mut s2 = _mm256_setzero_pd();
            let mut s3 = _mm256_setzero_pd();
            let mut p = 0;
            while p + 4 <= k {
                let bv = _mm256_loadu_pd(bp.add(p));
                s0 = _mm256_fmadd_pd(_mm256_loadu_pd(p0.add(p)), bv, s0);
                s1 = _mm256_fmadd_pd(_mm256_loadu_pd(p1.add(p)), bv, s1);
                s2 = _mm256_fmadd_pd(_mm256_loadu_pd(p2.add(p)), bv, s2);
                s3 = _mm256_fmadd_pd(_mm256_loadu_pd(p3.add(p)), bv, s3);
                p += 4;
            }
            let mut r0 = hsum_avx2(s0);
            let mut r1 = hsum_avx2(s1);
            let mut r2 = hsum_avx2(s2);
            let mut r3 = hsum_avx2(s3);
            while p < k {
                let bv = *bp.add(p);
                r0 += *p0.add(p) * bv;
                r1 += *p1.add(p) * bv;
                r2 += *p2.add(p) * bv;
                r3 += *p3.add(p) * bv;
                p += 1;
            }
            (r0, r1, r2, r3)
        }
    }

    /// `C += A·Bᵀ`: contiguous-row reductions, four output rows sharing
    /// each loaded B row (same blocking as the scalar [`gemm::gemm_nt`]).
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn gemm_nt_avx2(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
        debug_assert!(a.len() == m * k && b.len() == n * k && c.len() == m * n);
        // SAFETY: avx2+fma per the fn contract, forwarded to the dot
        // kernels; row slicing is bounds-checked safe code.
        unsafe {
            let mut i = 0;
            while i + 4 <= m {
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                let a2 = &a[(i + 2) * k..(i + 3) * k];
                let a3 = &a[(i + 3) * k..(i + 4) * k];
                for j in 0..n {
                    let (s0, s1, s2, s3) = dot4_avx2(a0, a1, a2, a3, &b[j * k..(j + 1) * k]);
                    c[i * n + j] += s0;
                    c[(i + 1) * n + j] += s1;
                    c[(i + 2) * n + j] += s2;
                    c[(i + 3) * n + j] += s3;
                }
                i += 4;
            }
            while i < m {
                let arow = &a[i * k..(i + 1) * k];
                for j in 0..n {
                    c[i * n + j] += dot_avx2(arow, &b[j * k..(j + 1) * k]);
                }
                i += 1;
            }
        }
    }

    /// One 4-way rank-1 row update of [`gemm_tn_avx2`]:
    /// `crow += a0·b0 + a1·b1 + a2·b2 + a3·b3` over contiguous rows.
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn rank4_row_avx2(
        a0: f64,
        a1: f64,
        a2: f64,
        a3: f64,
        b0: &[f64],
        b1: &[f64],
        b2: &[f64],
        b3: &[f64],
        crow: &mut [f64],
    ) {
        let n = crow.len();
        debug_assert!(b0.len() == n && b1.len() == n && b2.len() == n && b3.len() == n);
        // SAFETY: avx2+fma per the fn contract; all loads/stores run at
        // j + lane < n = crow.len() = b*.len() (debug-asserted, guaranteed
        // by the driver's row slicing).
        unsafe {
            let v0 = _mm256_set1_pd(a0);
            let v1 = _mm256_set1_pd(a1);
            let v2 = _mm256_set1_pd(a2);
            let v3 = _mm256_set1_pd(a3);
            let q0 = b0.as_ptr();
            let q1 = b1.as_ptr();
            let q2 = b2.as_ptr();
            let q3 = b3.as_ptr();
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 4 <= n {
                let mut cv = _mm256_loadu_pd(cp.add(j));
                cv = _mm256_fmadd_pd(v0, _mm256_loadu_pd(q0.add(j)), cv);
                cv = _mm256_fmadd_pd(v1, _mm256_loadu_pd(q1.add(j)), cv);
                cv = _mm256_fmadd_pd(v2, _mm256_loadu_pd(q2.add(j)), cv);
                cv = _mm256_fmadd_pd(v3, _mm256_loadu_pd(q3.add(j)), cv);
                _mm256_storeu_pd(cp.add(j), cv);
                j += 4;
            }
            while j < n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                j += 1;
            }
        }
    }

    /// Single rank-1 row update for the p-row remainder of
    /// [`gemm_tn_avx2`].
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn rank1_row_avx2(av: f64, brow: &[f64], crow: &mut [f64]) {
        let n = crow.len();
        debug_assert!(brow.len() == n);
        // SAFETY: avx2+fma per the fn contract; loads/stores run at
        // j + lane < n = crow.len() = brow.len() (debug-asserted).
        unsafe {
            let vv = _mm256_set1_pd(av);
            let bp = brow.as_ptr();
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 4 <= n {
                let cv =
                    _mm256_fmadd_pd(vv, _mm256_loadu_pd(bp.add(j)), _mm256_loadu_pd(cp.add(j)));
                _mm256_storeu_pd(cp.add(j), cv);
                j += 4;
            }
            while j < n {
                crow[j] += av * brow[j];
                j += 1;
            }
        }
    }

    /// `C += Aᵀ·B`: 4-way unrolled rank-1 updates with vectorized
    /// contiguous inner rows, keeping the scalar kernel's zero-skip.
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn gemm_tn_avx2(
        p_rows: usize,
        m: usize,
        n: usize,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
    ) {
        debug_assert!(a.len() == p_rows * m && b.len() == p_rows * n && c.len() == m * n);
        // SAFETY: avx2+fma per the fn contract, forwarded to the row
        // kernels; row slicing is bounds-checked safe code.
        unsafe {
            let mut p = 0;
            while p + 4 <= p_rows {
                let b0 = &b[p * n..(p + 1) * n];
                let b1 = &b[(p + 1) * n..(p + 2) * n];
                let b2 = &b[(p + 2) * n..(p + 3) * n];
                let b3 = &b[(p + 3) * n..(p + 4) * n];
                for i in 0..m {
                    let a0 = a[p * m + i];
                    let a1 = a[(p + 1) * m + i];
                    let a2 = a[(p + 2) * m + i];
                    let a3 = a[(p + 3) * m + i];
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        continue;
                    }
                    let crow = &mut c[i * n..(i + 1) * n];
                    rank4_row_avx2(a0, a1, a2, a3, b0, b1, b2, b3, crow);
                }
                p += 4;
            }
            while p < p_rows {
                let brow = &b[p * n..(p + 1) * n];
                for i in 0..m {
                    let av = a[p * m + i];
                    if av == 0.0 {
                        continue;
                    }
                    rank1_row_avx2(av, brow, &mut c[i * n..(i + 1) * n]);
                }
                p += 1;
            }
        }
    }

    /// Lane-parallel `row[j] ← s²·ρ(√max(sqi + sq[j] − 2·row[j], 0))`.
    /// RBF skips the square root entirely (`ρ = e^{-d²/2}`); the Matérn
    /// families take one vector sqrt. Lane remainders use the scalar glibc
    /// path.
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn rho_row_avx2(
        fam: RhoFamily,
        outputscale: f64,
        sqi: f64,
        sq: &[f64],
        row: &mut [f64],
    ) {
        let n = row.len();
        debug_assert_eq!(sq.len(), n);
        let n4 = n - n % 4;
        // SAFETY: avx2+fma per the fn contract; loads/stores run at
        // j + lane < n4 ≤ min(sq.len(), row.len()).
        unsafe {
            let sp = sq.as_ptr();
            let rp = row.as_mut_ptr();
            let vsqi = _mm256_set1_pd(sqi);
            let vos = _mm256_set1_pd(outputscale);
            let vm2 = _mm256_set1_pd(-2.0);
            let vzero = _mm256_setzero_pd();
            let vone = _mm256_set1_pd(1.0);
            let mut j = 0;
            while j < n4 {
                let v = _mm256_loadu_pd(rp.add(j));
                let base = _mm256_add_pd(vsqi, _mm256_loadu_pd(sp.add(j)));
                let d2 = _mm256_max_pd(_mm256_fmadd_pd(vm2, v, base), vzero);
                let rho = match fam {
                    RhoFamily::Rbf => exp_avx2(_mm256_mul_pd(_mm256_set1_pd(-0.5), d2)),
                    RhoFamily::Matern12 => exp_avx2(neg_avx2(_mm256_sqrt_pd(d2))),
                    RhoFamily::Matern32 => {
                        let aa = _mm256_sqrt_pd(_mm256_mul_pd(_mm256_set1_pd(3.0), d2));
                        let e = exp_avx2(neg_avx2(aa));
                        _mm256_mul_pd(_mm256_add_pd(vone, aa), e)
                    }
                    RhoFamily::Matern52 => {
                        let aa = _mm256_sqrt_pd(_mm256_mul_pd(_mm256_set1_pd(5.0), d2));
                        let e = exp_avx2(neg_avx2(aa));
                        let lin = _mm256_add_pd(vone, aa);
                        let third = _mm256_set1_pd(1.0 / 3.0);
                        let a2t = _mm256_mul_pd(_mm256_mul_pd(aa, aa), third);
                        _mm256_mul_pd(_mm256_add_pd(lin, a2t), e)
                    }
                };
                _mm256_storeu_pd(rp.add(j), _mm256_mul_pd(vos, rho));
                j += 4;
            }
            for jj in n4..n {
                let d2 = (sqi + sq[jj] - 2.0 * row[jj]).max(0.0);
                row[jj] = outputscale * fam.rho(d2.sqrt());
            }
        }
    }

    /// Lane-parallel gradient-panel contraction: one output row's
    /// `(Σ lr·dρ, Σ lr·ρ)` partial sums, `lr = li·rv[j]·s²`.
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn grad_row_avx2(
        fam: RhoFamily,
        outputscale: f64,
        li: f64,
        sqi: f64,
        sq: &[f64],
        pan: &[f64],
        rv: &[f64],
    ) -> (f64, f64) {
        let n = pan.len();
        debug_assert!(sq.len() == n && rv.len() == n);
        let n4 = n - n % 4;
        let scale = li * outputscale;
        // SAFETY: avx2+fma per the fn contract; all loads run at
        // j + lane < n4 ≤ min(sq.len(), pan.len(), rv.len()).
        unsafe {
            let sp = sq.as_ptr();
            let pp = pan.as_ptr();
            let rp = rv.as_ptr();
            let vsqi = _mm256_set1_pd(sqi);
            let vm2 = _mm256_set1_pd(-2.0);
            let vzero = _mm256_setzero_pd();
            let vone = _mm256_set1_pd(1.0);
            let vscale = _mm256_set1_pd(scale);
            let mut ae = _mm256_setzero_pd();
            let mut as2 = _mm256_setzero_pd();
            let mut j = 0;
            while j < n4 {
                let xx = _mm256_loadu_pd(pp.add(j));
                let base = _mm256_add_pd(vsqi, _mm256_loadu_pd(sp.add(j)));
                let d2 = _mm256_max_pd(_mm256_fmadd_pd(vm2, xx, base), vzero);
                let (rho, drho) = match fam {
                    RhoFamily::Rbf => {
                        let e = exp_avx2(_mm256_mul_pd(_mm256_set1_pd(-0.5), d2));
                        (e, _mm256_mul_pd(d2, e))
                    }
                    RhoFamily::Matern12 => {
                        let aa = _mm256_sqrt_pd(d2);
                        let e = exp_avx2(neg_avx2(aa));
                        (e, _mm256_mul_pd(aa, e))
                    }
                    RhoFamily::Matern32 => {
                        let aa = _mm256_sqrt_pd(_mm256_mul_pd(_mm256_set1_pd(3.0), d2));
                        let e = exp_avx2(neg_avx2(aa));
                        let rho = _mm256_mul_pd(_mm256_add_pd(vone, aa), e);
                        (rho, _mm256_mul_pd(_mm256_mul_pd(aa, aa), e))
                    }
                    RhoFamily::Matern52 => {
                        let aa = _mm256_sqrt_pd(_mm256_mul_pd(_mm256_set1_pd(5.0), d2));
                        let e = exp_avx2(neg_avx2(aa));
                        let lin = _mm256_add_pd(vone, aa);
                        let third = _mm256_set1_pd(1.0 / 3.0);
                        let a2t = _mm256_mul_pd(_mm256_mul_pd(aa, aa), third);
                        let rho = _mm256_mul_pd(_mm256_add_pd(lin, a2t), e);
                        (rho, _mm256_mul_pd(_mm256_mul_pd(a2t, lin), e))
                    }
                };
                let lr = _mm256_mul_pd(vscale, _mm256_loadu_pd(rp.add(j)));
                ae = _mm256_fmadd_pd(lr, drho, ae);
                as2 = _mm256_fmadd_pd(lr, rho, as2);
                j += 4;
            }
            let mut d_ell = hsum_avx2(ae);
            let mut d_s2 = hsum_avx2(as2);
            for jj in n4..n {
                let rr = (sqi + sq[jj] - 2.0 * pan[jj]).max(0.0).sqrt();
                let lr = li * rv[jj] * outputscale;
                d_ell += lr * fam.drho_dlog_ell(rr);
                d_s2 += lr * fam.rho(rr);
            }
            (d_ell, d_s2)
        }
    }

    // Safe table entries. Every body's `unsafe` discharge is the same:
    // these fns are reachable only through AVX2_TABLE, which `table_for`
    // exposes only after `Backend::Avx2.available()` confirmed the avx2
    // and fma features on this CPU.

    fn gemm_nn_avx2_entry(
        m: usize,
        k: usize,
        n: usize,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        pack: &mut [f64],
    ) {
        // SAFETY: avx2+fma verified by `table_for` (see entry-block note).
        unsafe { gemm_nn_avx2(m, k, n, a, b, c, pack) }
    }

    fn gemm_nt_avx2_entry(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
        // SAFETY: avx2+fma verified by `table_for` (see entry-block note).
        unsafe { gemm_nt_avx2(m, k, n, a, b, c) }
    }

    fn gemm_tn_avx2_entry(p_rows: usize, m: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
        // SAFETY: avx2+fma verified by `table_for` (see entry-block note).
        unsafe { gemm_tn_avx2(p_rows, m, n, a, b, c) }
    }

    fn dot_avx2_entry(a: &[f64], b: &[f64]) -> f64 {
        // SAFETY: avx2+fma verified by `table_for` (see entry-block note).
        unsafe { dot_avx2(a, b) }
    }

    fn rho_row_avx2_entry(fam: RhoFamily, outputscale: f64, sqi: f64, sq: &[f64], row: &mut [f64]) {
        // SAFETY: avx2+fma verified by `table_for` (see entry-block note).
        unsafe { rho_row_avx2(fam, outputscale, sqi, sq, row) }
    }

    fn grad_row_avx2_entry(
        fam: RhoFamily,
        outputscale: f64,
        li: f64,
        sqi: f64,
        sq: &[f64],
        pan: &[f64],
        rv: &[f64],
    ) -> (f64, f64) {
        // SAFETY: avx2+fma verified by `table_for` (see entry-block note).
        unsafe { grad_row_avx2(fam, outputscale, li, sqi, sq, pan, rv) }
    }

    // ------------------------------------------------------------- AVX-512

    pub(super) static AVX512_TABLE: KernelTable = KernelTable {
        backend: Backend::Avx512,
        gemm_nn: gemm_nn_avx512_entry,
        gemm_nt: gemm_nt_avx512_entry,
        gemm_tn: gemm_tn_avx512_entry,
        dot: dot_avx512_entry,
        rho_row: rho_row_avx512_entry,
        grad_row: grad_row_avx512_entry,
    };

    /// 8-lane variant of [`exp_avx2`] (same scheme, same ULP contract);
    /// the underflow flush uses a zeroing merge mask instead of an AND.
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    #[inline]
    pub(crate) unsafe fn exp_avx512(x: __m512d) -> __m512d {
        // SAFETY: register-only intrinsics; avx512f holds by this fn's own
        // contract.
        unsafe {
            let xc = _mm512_max_pd(x, _mm512_set1_pd(-800.0));
            let scaled = _mm512_mul_pd(xc, _mm512_set1_pd(LOG2_E));
            let n = _mm512_roundscale_pd::<ROUND_NEAREST>(scaled);
            let r = _mm512_fnmadd_pd(n, _mm512_set1_pd(LN_2_HI), xc);
            let r = _mm512_fnmadd_pd(n, _mm512_set1_pd(LN_2_LO), r);
            let mut p = _mm512_set1_pd(EXP_POLY[11]);
            for idx in (0..11).rev() {
                p = _mm512_fmadd_pd(p, r, _mm512_set1_pd(EXP_POLY[idx]));
            }
            let n64 = _mm512_cvtepi32_epi64(_mm512_cvtpd_epi32(n));
            let bits = _mm512_slli_epi64::<52>(_mm512_add_epi64(n64, _mm512_set1_epi64(1023)));
            let res = _mm512_mul_pd(p, _mm512_castsi512_pd(bits));
            let keep = _mm512_cmp_pd_mask::<_CMP_GE_OQ>(x, _mm512_set1_pd(-708.0));
            _mm512_maskz_mov_pd(keep, res)
        }
    }

    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    #[inline]
    pub(crate) unsafe fn neg_avx512(v: __m512d) -> __m512d {
        // SAFETY: register-only intrinsic; avx512f per the fn contract.
        // (`xor_pd` would need AVX512DQ; an exact 0−v negation does not.)
        unsafe { _mm512_sub_pd(_mm512_setzero_pd(), v) }
    }

    /// 8-lane dot with zip-truncation semantics.
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn dot_avx512(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len().min(b.len());
        // SAFETY: avx512f per the fn contract; every load reads at
        // p + lane < n ≤ min(a.len(), b.len()).
        unsafe {
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut acc0 = _mm512_setzero_pd();
            let mut acc1 = _mm512_setzero_pd();
            let mut p = 0;
            while p + 16 <= n {
                let a0 = _mm512_loadu_pd(ap.add(p));
                let b0 = _mm512_loadu_pd(bp.add(p));
                acc0 = _mm512_fmadd_pd(a0, b0, acc0);
                let a1 = _mm512_loadu_pd(ap.add(p + 8));
                let b1 = _mm512_loadu_pd(bp.add(p + 8));
                acc1 = _mm512_fmadd_pd(a1, b1, acc1);
                p += 16;
            }
            if p + 8 <= n {
                let a0 = _mm512_loadu_pd(ap.add(p));
                let b0 = _mm512_loadu_pd(bp.add(p));
                acc0 = _mm512_fmadd_pd(a0, b0, acc0);
                p += 8;
            }
            let mut s = _mm512_reduce_add_pd(_mm512_add_pd(acc0, acc1));
            while p < n {
                s += *ap.add(p) * *bp.add(p);
                p += 1;
            }
            s
        }
    }

    /// MR×NR register tile, AVX-512: the whole NR=8 panel row is one zmm,
    /// so the reduction step is one load + four broadcasts + four FMAs.
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn kernel_mrxnr_avx512(
        k: usize,
        n: usize,
        j: usize,
        a: &[f64],
        bpack: &[f64],
        c: &mut [f64],
    ) {
        debug_assert!(a.len() >= MR * k && bpack.len() >= k * NR);
        debug_assert!(j + NR <= n && c.len() >= (MR - 1) * n + j + NR);
        // SAFETY: avx512f per the fn contract. Loads read a at
        // mi·k + p < MR·k and bpack at p·NR + lane < k·NR; loads/stores on
        // c touch rows mi·n + j .. +NR with j + NR ≤ n and mi < MR — all
        // inside the slices the safe driver carved out (debug-asserted).
        unsafe {
            let ap = a.as_ptr();
            let bp = bpack.as_ptr();
            let mut acc0 = _mm512_setzero_pd();
            let mut acc1 = _mm512_setzero_pd();
            let mut acc2 = _mm512_setzero_pd();
            let mut acc3 = _mm512_setzero_pd();
            for p in 0..k {
                let bv = _mm512_loadu_pd(bp.add(p * NR));
                acc0 = _mm512_fmadd_pd(_mm512_set1_pd(*ap.add(p)), bv, acc0);
                acc1 = _mm512_fmadd_pd(_mm512_set1_pd(*ap.add(k + p)), bv, acc1);
                acc2 = _mm512_fmadd_pd(_mm512_set1_pd(*ap.add(2 * k + p)), bv, acc2);
                acc3 = _mm512_fmadd_pd(_mm512_set1_pd(*ap.add(3 * k + p)), bv, acc3);
            }
            let cp = c.as_mut_ptr();
            let c0 = cp.add(j);
            _mm512_storeu_pd(c0, _mm512_add_pd(_mm512_loadu_pd(c0), acc0));
            let c1 = cp.add(n + j);
            _mm512_storeu_pd(c1, _mm512_add_pd(_mm512_loadu_pd(c1), acc1));
            let c2 = cp.add(2 * n + j);
            _mm512_storeu_pd(c2, _mm512_add_pd(_mm512_loadu_pd(c2), acc2));
            let c3 = cp.add(3 * n + j);
            _mm512_storeu_pd(c3, _mm512_add_pd(_mm512_loadu_pd(c3), acc3));
        }
    }

    /// 1×NR edge tile for the row remainder of [`gemm_nn_avx512`].
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn kernel_1xnr_avx512(j: usize, arow: &[f64], bpack: &[f64], crow: &mut [f64]) {
        debug_assert!(bpack.len() >= arow.len() * NR && j + NR <= crow.len());
        // SAFETY: avx512f per the fn contract; bpack loads read at
        // p·NR + lane < k·NR and the store hits crow[j..j+NR] (both
        // debug-asserted, guaranteed by the driver).
        unsafe {
            let bp = bpack.as_ptr();
            let mut acc = _mm512_setzero_pd();
            for (p, &av) in arow.iter().enumerate() {
                let bv = _mm512_loadu_pd(bp.add(p * NR));
                acc = _mm512_fmadd_pd(_mm512_set1_pd(av), bv, acc);
            }
            let cp = crow.as_mut_ptr().add(j);
            _mm512_storeu_pd(cp, _mm512_add_pd(_mm512_loadu_pd(cp), acc));
        }
    }

    /// AVX-512 driver for the packed-panel `C += A·B` (same structure as
    /// [`gemm_nn_avx2`]).
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn gemm_nn_avx512(
        m: usize,
        k: usize,
        n: usize,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        pack: &mut [f64],
    ) {
        debug_assert!(a.len() == m * k && b.len() == k * n && c.len() == m * n);
        debug_assert!(n < NR || pack.len() >= k * NR);
        // SAFETY: avx512f per the fn contract, forwarded to the tile
        // kernels; the panel slicing matches the (bounds-checked) scalar
        // driver exactly.
        unsafe {
            let mut j = 0;
            while j + NR <= n {
                for p in 0..k {
                    pack[p * NR..(p + 1) * NR].copy_from_slice(&b[p * n + j..p * n + j + NR]);
                }
                let mut i = 0;
                while i + MR <= m {
                    let ar = &a[i * k..(i + MR) * k];
                    let cr = &mut c[i * n..(i + MR) * n];
                    kernel_mrxnr_avx512(k, n, j, ar, pack, cr);
                    i += MR;
                }
                while i < m {
                    let ar = &a[i * k..(i + 1) * k];
                    let cr = &mut c[i * n..(i + 1) * n];
                    kernel_1xnr_avx512(j, ar, pack, cr);
                    i += 1;
                }
                j += NR;
            }
            if j < n {
                gemm::gemm_nn_coltail(m, k, n, j, a, b, c);
            }
        }
    }

    /// Four simultaneous 8-lane dots against one shared B row.
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn dot4_avx512(
        a0: &[f64],
        a1: &[f64],
        a2: &[f64],
        a3: &[f64],
        b: &[f64],
    ) -> (f64, f64, f64, f64) {
        let k = b.len();
        debug_assert!(a0.len() == k && a1.len() == k && a2.len() == k && a3.len() == k);
        // SAFETY: avx512f per the fn contract; all loads read at
        // p + lane < k = b.len() = a*.len() (debug-asserted, guaranteed by
        // the driver's row slicing).
        unsafe {
            let p0 = a0.as_ptr();
            let p1 = a1.as_ptr();
            let p2 = a2.as_ptr();
            let p3 = a3.as_ptr();
            let bp = b.as_ptr();
            let mut s0 = _mm512_setzero_pd();
            let mut s1 = _mm512_setzero_pd();
            let mut s2 = _mm512_setzero_pd();
            let mut s3 = _mm512_setzero_pd();
            let mut p = 0;
            while p + 8 <= k {
                let bv = _mm512_loadu_pd(bp.add(p));
                s0 = _mm512_fmadd_pd(_mm512_loadu_pd(p0.add(p)), bv, s0);
                s1 = _mm512_fmadd_pd(_mm512_loadu_pd(p1.add(p)), bv, s1);
                s2 = _mm512_fmadd_pd(_mm512_loadu_pd(p2.add(p)), bv, s2);
                s3 = _mm512_fmadd_pd(_mm512_loadu_pd(p3.add(p)), bv, s3);
                p += 8;
            }
            let mut r0 = _mm512_reduce_add_pd(s0);
            let mut r1 = _mm512_reduce_add_pd(s1);
            let mut r2 = _mm512_reduce_add_pd(s2);
            let mut r3 = _mm512_reduce_add_pd(s3);
            while p < k {
                let bv = *bp.add(p);
                r0 += *p0.add(p) * bv;
                r1 += *p1.add(p) * bv;
                r2 += *p2.add(p) * bv;
                r3 += *p3.add(p) * bv;
                p += 1;
            }
            (r0, r1, r2, r3)
        }
    }

    /// AVX-512 `C += A·Bᵀ` (same blocking as [`gemm_nt_avx2`]).
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn gemm_nt_avx512(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
        debug_assert!(a.len() == m * k && b.len() == n * k && c.len() == m * n);
        // SAFETY: avx512f per the fn contract, forwarded to the dot
        // kernels; row slicing is bounds-checked safe code.
        unsafe {
            let mut i = 0;
            while i + 4 <= m {
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                let a2 = &a[(i + 2) * k..(i + 3) * k];
                let a3 = &a[(i + 3) * k..(i + 4) * k];
                for j in 0..n {
                    let (s0, s1, s2, s3) = dot4_avx512(a0, a1, a2, a3, &b[j * k..(j + 1) * k]);
                    c[i * n + j] += s0;
                    c[(i + 1) * n + j] += s1;
                    c[(i + 2) * n + j] += s2;
                    c[(i + 3) * n + j] += s3;
                }
                i += 4;
            }
            while i < m {
                let arow = &a[i * k..(i + 1) * k];
                for j in 0..n {
                    c[i * n + j] += dot_avx512(arow, &b[j * k..(j + 1) * k]);
                }
                i += 1;
            }
        }
    }

    /// One 8-lane 4-way rank-1 row update of [`gemm_tn_avx512`].
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn rank4_row_avx512(
        a0: f64,
        a1: f64,
        a2: f64,
        a3: f64,
        b0: &[f64],
        b1: &[f64],
        b2: &[f64],
        b3: &[f64],
        crow: &mut [f64],
    ) {
        let n = crow.len();
        debug_assert!(b0.len() == n && b1.len() == n && b2.len() == n && b3.len() == n);
        // SAFETY: avx512f per the fn contract; all loads/stores run at
        // j + lane < n = crow.len() = b*.len() (debug-asserted, guaranteed
        // by the driver's row slicing).
        unsafe {
            let v0 = _mm512_set1_pd(a0);
            let v1 = _mm512_set1_pd(a1);
            let v2 = _mm512_set1_pd(a2);
            let v3 = _mm512_set1_pd(a3);
            let q0 = b0.as_ptr();
            let q1 = b1.as_ptr();
            let q2 = b2.as_ptr();
            let q3 = b3.as_ptr();
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 8 <= n {
                let mut cv = _mm512_loadu_pd(cp.add(j));
                cv = _mm512_fmadd_pd(v0, _mm512_loadu_pd(q0.add(j)), cv);
                cv = _mm512_fmadd_pd(v1, _mm512_loadu_pd(q1.add(j)), cv);
                cv = _mm512_fmadd_pd(v2, _mm512_loadu_pd(q2.add(j)), cv);
                cv = _mm512_fmadd_pd(v3, _mm512_loadu_pd(q3.add(j)), cv);
                _mm512_storeu_pd(cp.add(j), cv);
                j += 8;
            }
            while j < n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                j += 1;
            }
        }
    }

    /// Single 8-lane rank-1 row update for the p-row remainder.
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn rank1_row_avx512(av: f64, brow: &[f64], crow: &mut [f64]) {
        let n = crow.len();
        debug_assert!(brow.len() == n);
        // SAFETY: avx512f per the fn contract; loads/stores run at
        // j + lane < n = crow.len() = brow.len() (debug-asserted).
        unsafe {
            let vv = _mm512_set1_pd(av);
            let bp = brow.as_ptr();
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 8 <= n {
                let bv = _mm512_loadu_pd(bp.add(j));
                let cv = _mm512_fmadd_pd(vv, bv, _mm512_loadu_pd(cp.add(j)));
                _mm512_storeu_pd(cp.add(j), cv);
                j += 8;
            }
            while j < n {
                crow[j] += av * brow[j];
                j += 1;
            }
        }
    }

    /// AVX-512 `C += Aᵀ·B` (same blocking and zero-skip as
    /// [`gemm_tn_avx2`]).
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn gemm_tn_avx512(
        p_rows: usize,
        m: usize,
        n: usize,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
    ) {
        debug_assert!(a.len() == p_rows * m && b.len() == p_rows * n && c.len() == m * n);
        // SAFETY: avx512f per the fn contract, forwarded to the row
        // kernels; row slicing is bounds-checked safe code.
        unsafe {
            let mut p = 0;
            while p + 4 <= p_rows {
                let b0 = &b[p * n..(p + 1) * n];
                let b1 = &b[(p + 1) * n..(p + 2) * n];
                let b2 = &b[(p + 2) * n..(p + 3) * n];
                let b3 = &b[(p + 3) * n..(p + 4) * n];
                for i in 0..m {
                    let a0 = a[p * m + i];
                    let a1 = a[(p + 1) * m + i];
                    let a2 = a[(p + 2) * m + i];
                    let a3 = a[(p + 3) * m + i];
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        continue;
                    }
                    let crow = &mut c[i * n..(i + 1) * n];
                    rank4_row_avx512(a0, a1, a2, a3, b0, b1, b2, b3, crow);
                }
                p += 4;
            }
            while p < p_rows {
                let brow = &b[p * n..(p + 1) * n];
                for i in 0..m {
                    let av = a[p * m + i];
                    if av == 0.0 {
                        continue;
                    }
                    rank1_row_avx512(av, brow, &mut c[i * n..(i + 1) * n]);
                }
                p += 1;
            }
        }
    }

    /// 8-lane variant of [`rho_row_avx2`].
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn rho_row_avx512(
        fam: RhoFamily,
        outputscale: f64,
        sqi: f64,
        sq: &[f64],
        row: &mut [f64],
    ) {
        let n = row.len();
        debug_assert_eq!(sq.len(), n);
        let n8 = n - n % 8;
        // SAFETY: avx512f per the fn contract; loads/stores run at
        // j + lane < n8 ≤ min(sq.len(), row.len()).
        unsafe {
            let sp = sq.as_ptr();
            let rp = row.as_mut_ptr();
            let vsqi = _mm512_set1_pd(sqi);
            let vos = _mm512_set1_pd(outputscale);
            let vm2 = _mm512_set1_pd(-2.0);
            let vzero = _mm512_setzero_pd();
            let vone = _mm512_set1_pd(1.0);
            let mut j = 0;
            while j < n8 {
                let v = _mm512_loadu_pd(rp.add(j));
                let base = _mm512_add_pd(vsqi, _mm512_loadu_pd(sp.add(j)));
                let d2 = _mm512_max_pd(_mm512_fmadd_pd(vm2, v, base), vzero);
                let rho = match fam {
                    RhoFamily::Rbf => exp_avx512(_mm512_mul_pd(_mm512_set1_pd(-0.5), d2)),
                    RhoFamily::Matern12 => exp_avx512(neg_avx512(_mm512_sqrt_pd(d2))),
                    RhoFamily::Matern32 => {
                        let aa = _mm512_sqrt_pd(_mm512_mul_pd(_mm512_set1_pd(3.0), d2));
                        let e = exp_avx512(neg_avx512(aa));
                        _mm512_mul_pd(_mm512_add_pd(vone, aa), e)
                    }
                    RhoFamily::Matern52 => {
                        let aa = _mm512_sqrt_pd(_mm512_mul_pd(_mm512_set1_pd(5.0), d2));
                        let e = exp_avx512(neg_avx512(aa));
                        let lin = _mm512_add_pd(vone, aa);
                        let third = _mm512_set1_pd(1.0 / 3.0);
                        let a2t = _mm512_mul_pd(_mm512_mul_pd(aa, aa), third);
                        _mm512_mul_pd(_mm512_add_pd(lin, a2t), e)
                    }
                };
                _mm512_storeu_pd(rp.add(j), _mm512_mul_pd(vos, rho));
                j += 8;
            }
            for jj in n8..n {
                let d2 = (sqi + sq[jj] - 2.0 * row[jj]).max(0.0);
                row[jj] = outputscale * fam.rho(d2.sqrt());
            }
        }
    }

    /// 8-lane variant of [`grad_row_avx2`].
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn grad_row_avx512(
        fam: RhoFamily,
        outputscale: f64,
        li: f64,
        sqi: f64,
        sq: &[f64],
        pan: &[f64],
        rv: &[f64],
    ) -> (f64, f64) {
        let n = pan.len();
        debug_assert!(sq.len() == n && rv.len() == n);
        let n8 = n - n % 8;
        let scale = li * outputscale;
        // SAFETY: avx512f per the fn contract; all loads run at
        // j + lane < n8 ≤ min(sq.len(), pan.len(), rv.len()).
        unsafe {
            let sp = sq.as_ptr();
            let pp = pan.as_ptr();
            let rp = rv.as_ptr();
            let vsqi = _mm512_set1_pd(sqi);
            let vm2 = _mm512_set1_pd(-2.0);
            let vzero = _mm512_setzero_pd();
            let vone = _mm512_set1_pd(1.0);
            let vscale = _mm512_set1_pd(scale);
            let mut ae = _mm512_setzero_pd();
            let mut as2 = _mm512_setzero_pd();
            let mut j = 0;
            while j < n8 {
                let xx = _mm512_loadu_pd(pp.add(j));
                let base = _mm512_add_pd(vsqi, _mm512_loadu_pd(sp.add(j)));
                let d2 = _mm512_max_pd(_mm512_fmadd_pd(vm2, xx, base), vzero);
                let (rho, drho) = match fam {
                    RhoFamily::Rbf => {
                        let e = exp_avx512(_mm512_mul_pd(_mm512_set1_pd(-0.5), d2));
                        (e, _mm512_mul_pd(d2, e))
                    }
                    RhoFamily::Matern12 => {
                        let aa = _mm512_sqrt_pd(d2);
                        let e = exp_avx512(neg_avx512(aa));
                        (e, _mm512_mul_pd(aa, e))
                    }
                    RhoFamily::Matern32 => {
                        let aa = _mm512_sqrt_pd(_mm512_mul_pd(_mm512_set1_pd(3.0), d2));
                        let e = exp_avx512(neg_avx512(aa));
                        let rho = _mm512_mul_pd(_mm512_add_pd(vone, aa), e);
                        (rho, _mm512_mul_pd(_mm512_mul_pd(aa, aa), e))
                    }
                    RhoFamily::Matern52 => {
                        let aa = _mm512_sqrt_pd(_mm512_mul_pd(_mm512_set1_pd(5.0), d2));
                        let e = exp_avx512(neg_avx512(aa));
                        let lin = _mm512_add_pd(vone, aa);
                        let third = _mm512_set1_pd(1.0 / 3.0);
                        let a2t = _mm512_mul_pd(_mm512_mul_pd(aa, aa), third);
                        let rho = _mm512_mul_pd(_mm512_add_pd(lin, a2t), e);
                        (rho, _mm512_mul_pd(_mm512_mul_pd(a2t, lin), e))
                    }
                };
                let lr = _mm512_mul_pd(vscale, _mm512_loadu_pd(rp.add(j)));
                ae = _mm512_fmadd_pd(lr, drho, ae);
                as2 = _mm512_fmadd_pd(lr, rho, as2);
                j += 8;
            }
            let mut d_ell = _mm512_reduce_add_pd(ae);
            let mut d_s2 = _mm512_reduce_add_pd(as2);
            for jj in n8..n {
                let rr = (sqi + sq[jj] - 2.0 * pan[jj]).max(0.0).sqrt();
                let lr = li * rv[jj] * outputscale;
                d_ell += lr * fam.drho_dlog_ell(rr);
                d_s2 += lr * fam.rho(rr);
            }
            (d_ell, d_s2)
        }
    }

    // Safe AVX-512 table entries; same discharge as the AVX2 block, with
    // `Backend::Avx512.available()` confirming avx512f.

    fn gemm_nn_avx512_entry(
        m: usize,
        k: usize,
        n: usize,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        pack: &mut [f64],
    ) {
        // SAFETY: avx512f verified by `table_for` (see entry-block note).
        unsafe { gemm_nn_avx512(m, k, n, a, b, c, pack) }
    }

    fn gemm_nt_avx512_entry(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
        // SAFETY: avx512f verified by `table_for` (see entry-block note).
        unsafe { gemm_nt_avx512(m, k, n, a, b, c) }
    }

    fn gemm_tn_avx512_entry(
        p_rows: usize,
        m: usize,
        n: usize,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
    ) {
        // SAFETY: avx512f verified by `table_for` (see entry-block note).
        unsafe { gemm_tn_avx512(p_rows, m, n, a, b, c) }
    }

    fn dot_avx512_entry(a: &[f64], b: &[f64]) -> f64 {
        // SAFETY: avx512f verified by `table_for` (see entry-block note).
        unsafe { dot_avx512(a, b) }
    }

    fn rho_row_avx512_entry(
        fam: RhoFamily,
        outputscale: f64,
        sqi: f64,
        sq: &[f64],
        row: &mut [f64],
    ) {
        // SAFETY: avx512f verified by `table_for` (see entry-block note).
        unsafe { rho_row_avx512(fam, outputscale, sqi, sq, row) }
    }

    fn grad_row_avx512_entry(
        fam: RhoFamily,
        outputscale: f64,
        li: f64,
        sqi: f64,
        sq: &[f64],
        pan: &[f64],
        rv: &[f64],
    ) -> (f64, f64) {
        // SAFETY: avx512f verified by `table_for` (see entry-block note).
        unsafe { grad_row_avx512(fam, outputscale, li, sqi, sq, pan, rv) }
    }
}

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    //! NEON/AdvSIMD (2 × f64 lane) kernel variants — the `aarch64`
    //! baseline, so [`super::Backend::available`] is unconditionally true
    //! there; the `#[target_feature]`/`unsafe` structure still mirrors the
    //! x86 module so all backends share one safety convention.

    use super::{Backend, KernelTable, RhoFamily, EXP_POLY};
    use crate::linalg::gemm::{self, MR, NR};
    use crate::util::fastmath::{LN_2_HI, LN_2_LO, LOG2_E};
    use core::arch::aarch64::*;

    pub(super) static NEON_TABLE: KernelTable = KernelTable {
        backend: Backend::Neon,
        gemm_nn: gemm_nn_neon_entry,
        gemm_nt: gemm_nt_neon_entry,
        gemm_tn: gemm_tn_neon_entry,
        dot: dot_neon_entry,
        rho_row: rho_row_neon_entry,
        grad_row: grad_row_neon_entry,
    };

    /// 2-lane variant of the vector `e^x` (same scheme and ULP contract as
    /// the x86 versions; see the module docs).
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU (baseline on aarch64).
    #[target_feature(enable = "neon")]
    #[inline]
    pub(crate) unsafe fn exp_neon(x: float64x2_t) -> float64x2_t {
        // SAFETY: register-only intrinsics; neon holds by this fn's own
        // contract.
        unsafe {
            let xc = vmaxq_f64(x, vdupq_n_f64(-800.0));
            let n = vrndnq_f64(vmulq_f64(xc, vdupq_n_f64(LOG2_E)));
            // r = (x − n·ln2_hi) − n·ln2_lo (vfmsq: a − b·c)
            let r = vfmsq_f64(xc, n, vdupq_n_f64(LN_2_HI));
            let r = vfmsq_f64(r, n, vdupq_n_f64(LN_2_LO));
            let mut p = vdupq_n_f64(EXP_POLY[11]);
            for idx in (0..11).rev() {
                p = vfmaq_f64(vdupq_n_f64(EXP_POLY[idx]), p, r);
            }
            // n is integral, so the toward-zero convert is exact
            let n64 = vcvtq_s64_f64(n);
            let bits = vshlq_n_s64::<52>(vaddq_s64(n64, vdupq_n_s64(1023)));
            let res = vmulq_f64(p, vreinterpretq_f64_s64(bits));
            let keep = vcgeq_f64(x, vdupq_n_f64(-708.0));
            vreinterpretq_f64_u64(vandq_u64(vreinterpretq_u64_f64(res), keep))
        }
    }

    /// 2-lane dot with zip-truncation semantics.
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn dot_neon(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len().min(b.len());
        // SAFETY: neon per the fn contract; every load reads at
        // p + lane < n ≤ min(a.len(), b.len()).
        unsafe {
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut acc0 = vdupq_n_f64(0.0);
            let mut acc1 = vdupq_n_f64(0.0);
            let mut p = 0;
            while p + 4 <= n {
                acc0 = vfmaq_f64(acc0, vld1q_f64(ap.add(p)), vld1q_f64(bp.add(p)));
                acc1 = vfmaq_f64(acc1, vld1q_f64(ap.add(p + 2)), vld1q_f64(bp.add(p + 2)));
                p += 4;
            }
            if p + 2 <= n {
                acc0 = vfmaq_f64(acc0, vld1q_f64(ap.add(p)), vld1q_f64(bp.add(p)));
                p += 2;
            }
            let mut s = vaddvq_f64(vaddq_f64(acc0, acc1));
            while p < n {
                s += *ap.add(p) * *bp.add(p);
                p += 1;
            }
            s
        }
    }

    /// MR×NR register tile (MR·NR/2 = 16 q-register accumulators).
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn kernel_mrxnr_neon(
        k: usize,
        n: usize,
        j: usize,
        a: &[f64],
        bpack: &[f64],
        c: &mut [f64],
    ) {
        debug_assert!(a.len() >= MR * k && bpack.len() >= k * NR);
        debug_assert!(j + NR <= n && c.len() >= (MR - 1) * n + j + NR);
        // SAFETY: neon per the fn contract. Loads read a at mi·k + p <
        // MR·k and bpack at p·NR + lane < k·NR; loads/stores on c touch
        // rows mi·n + j .. +NR with j + NR ≤ n and mi < MR — all inside
        // the slices the safe driver carved out (debug-asserted).
        unsafe {
            let ap = a.as_ptr();
            let bp = bpack.as_ptr();
            let mut acc = [[vdupq_n_f64(0.0); 4]; MR];
            for p in 0..k {
                let bv = [
                    vld1q_f64(bp.add(p * NR)),
                    vld1q_f64(bp.add(p * NR + 2)),
                    vld1q_f64(bp.add(p * NR + 4)),
                    vld1q_f64(bp.add(p * NR + 6)),
                ];
                for (mi, arow) in acc.iter_mut().enumerate() {
                    let av = vdupq_n_f64(*ap.add(mi * k + p));
                    for (t, slot) in arow.iter_mut().enumerate() {
                        *slot = vfmaq_f64(*slot, av, bv[t]);
                    }
                }
            }
            let cp = c.as_mut_ptr();
            for (mi, arow) in acc.iter().enumerate() {
                let cr = cp.add(mi * n + j);
                for (t, slot) in arow.iter().enumerate() {
                    let cv = vaddq_f64(vld1q_f64(cr.add(2 * t)), *slot);
                    vst1q_f64(cr.add(2 * t), cv);
                }
            }
        }
    }

    /// 1×NR edge tile for the row remainder of [`gemm_nn_neon`].
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn kernel_1xnr_neon(j: usize, arow: &[f64], bpack: &[f64], crow: &mut [f64]) {
        debug_assert!(bpack.len() >= arow.len() * NR && j + NR <= crow.len());
        // SAFETY: neon per the fn contract; bpack loads read at
        // p·NR + lane < k·NR and the stores hit crow[j..j+NR] (both
        // debug-asserted, guaranteed by the driver).
        unsafe {
            let bp = bpack.as_ptr();
            let mut acc = [vdupq_n_f64(0.0); 4];
            for (p, &av) in arow.iter().enumerate() {
                let avv = vdupq_n_f64(av);
                for (t, slot) in acc.iter_mut().enumerate() {
                    *slot = vfmaq_f64(*slot, avv, vld1q_f64(bp.add(p * NR + 2 * t)));
                }
            }
            let cp = crow.as_mut_ptr().add(j);
            for (t, slot) in acc.iter().enumerate() {
                let cv = vaddq_f64(vld1q_f64(cp.add(2 * t)), *slot);
                vst1q_f64(cp.add(2 * t), cv);
            }
        }
    }

    /// NEON driver for the packed-panel `C += A·B` (same structure as the
    /// scalar and x86 drivers).
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn gemm_nn_neon(
        m: usize,
        k: usize,
        n: usize,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        pack: &mut [f64],
    ) {
        debug_assert!(a.len() == m * k && b.len() == k * n && c.len() == m * n);
        debug_assert!(n < NR || pack.len() >= k * NR);
        // SAFETY: neon per the fn contract, forwarded to the tile kernels;
        // the panel slicing matches the (bounds-checked) scalar driver.
        unsafe {
            let mut j = 0;
            while j + NR <= n {
                for p in 0..k {
                    pack[p * NR..(p + 1) * NR].copy_from_slice(&b[p * n + j..p * n + j + NR]);
                }
                let mut i = 0;
                while i + MR <= m {
                    let ar = &a[i * k..(i + MR) * k];
                    let cr = &mut c[i * n..(i + MR) * n];
                    kernel_mrxnr_neon(k, n, j, ar, pack, cr);
                    i += MR;
                }
                while i < m {
                    let ar = &a[i * k..(i + 1) * k];
                    let cr = &mut c[i * n..(i + 1) * n];
                    kernel_1xnr_neon(j, ar, pack, cr);
                    i += 1;
                }
                j += NR;
            }
            if j < n {
                gemm::gemm_nn_coltail(m, k, n, j, a, b, c);
            }
        }
    }

    /// Four simultaneous 2-lane dots against one shared B row.
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn dot4_neon(
        a0: &[f64],
        a1: &[f64],
        a2: &[f64],
        a3: &[f64],
        b: &[f64],
    ) -> (f64, f64, f64, f64) {
        let k = b.len();
        debug_assert!(a0.len() == k && a1.len() == k && a2.len() == k && a3.len() == k);
        // SAFETY: neon per the fn contract; all loads read at
        // p + lane < k = b.len() = a*.len() (debug-asserted).
        unsafe {
            let p0 = a0.as_ptr();
            let p1 = a1.as_ptr();
            let p2 = a2.as_ptr();
            let p3 = a3.as_ptr();
            let bp = b.as_ptr();
            let mut s0 = vdupq_n_f64(0.0);
            let mut s1 = vdupq_n_f64(0.0);
            let mut s2 = vdupq_n_f64(0.0);
            let mut s3 = vdupq_n_f64(0.0);
            let mut p = 0;
            while p + 2 <= k {
                let bv = vld1q_f64(bp.add(p));
                s0 = vfmaq_f64(s0, vld1q_f64(p0.add(p)), bv);
                s1 = vfmaq_f64(s1, vld1q_f64(p1.add(p)), bv);
                s2 = vfmaq_f64(s2, vld1q_f64(p2.add(p)), bv);
                s3 = vfmaq_f64(s3, vld1q_f64(p3.add(p)), bv);
                p += 2;
            }
            let mut r0 = vaddvq_f64(s0);
            let mut r1 = vaddvq_f64(s1);
            let mut r2 = vaddvq_f64(s2);
            let mut r3 = vaddvq_f64(s3);
            while p < k {
                let bv = *bp.add(p);
                r0 += *p0.add(p) * bv;
                r1 += *p1.add(p) * bv;
                r2 += *p2.add(p) * bv;
                r3 += *p3.add(p) * bv;
                p += 1;
            }
            (r0, r1, r2, r3)
        }
    }

    /// NEON `C += A·Bᵀ` (same blocking as the x86 variants).
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn gemm_nt_neon(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
        debug_assert!(a.len() == m * k && b.len() == n * k && c.len() == m * n);
        // SAFETY: neon per the fn contract, forwarded to the dot kernels;
        // row slicing is bounds-checked safe code.
        unsafe {
            let mut i = 0;
            while i + 4 <= m {
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                let a2 = &a[(i + 2) * k..(i + 3) * k];
                let a3 = &a[(i + 3) * k..(i + 4) * k];
                for j in 0..n {
                    let (s0, s1, s2, s3) = dot4_neon(a0, a1, a2, a3, &b[j * k..(j + 1) * k]);
                    c[i * n + j] += s0;
                    c[(i + 1) * n + j] += s1;
                    c[(i + 2) * n + j] += s2;
                    c[(i + 3) * n + j] += s3;
                }
                i += 4;
            }
            while i < m {
                let arow = &a[i * k..(i + 1) * k];
                for j in 0..n {
                    c[i * n + j] += dot_neon(arow, &b[j * k..(j + 1) * k]);
                }
                i += 1;
            }
        }
    }

    /// One 2-lane 4-way rank-1 row update of [`gemm_tn_neon`].
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn rank4_row_neon(
        a0: f64,
        a1: f64,
        a2: f64,
        a3: f64,
        b0: &[f64],
        b1: &[f64],
        b2: &[f64],
        b3: &[f64],
        crow: &mut [f64],
    ) {
        let n = crow.len();
        debug_assert!(b0.len() == n && b1.len() == n && b2.len() == n && b3.len() == n);
        // SAFETY: neon per the fn contract; all loads/stores run at
        // j + lane < n = crow.len() = b*.len() (debug-asserted).
        unsafe {
            let v0 = vdupq_n_f64(a0);
            let v1 = vdupq_n_f64(a1);
            let v2 = vdupq_n_f64(a2);
            let v3 = vdupq_n_f64(a3);
            let q0 = b0.as_ptr();
            let q1 = b1.as_ptr();
            let q2 = b2.as_ptr();
            let q3 = b3.as_ptr();
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 2 <= n {
                let mut cv = vld1q_f64(cp.add(j));
                cv = vfmaq_f64(cv, v0, vld1q_f64(q0.add(j)));
                cv = vfmaq_f64(cv, v1, vld1q_f64(q1.add(j)));
                cv = vfmaq_f64(cv, v2, vld1q_f64(q2.add(j)));
                cv = vfmaq_f64(cv, v3, vld1q_f64(q3.add(j)));
                vst1q_f64(cp.add(j), cv);
                j += 2;
            }
            while j < n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                j += 1;
            }
        }
    }

    /// Single 2-lane rank-1 row update for the p-row remainder.
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn rank1_row_neon(av: f64, brow: &[f64], crow: &mut [f64]) {
        let n = crow.len();
        debug_assert!(brow.len() == n);
        // SAFETY: neon per the fn contract; loads/stores run at
        // j + lane < n = crow.len() = brow.len() (debug-asserted).
        unsafe {
            let vv = vdupq_n_f64(av);
            let bp = brow.as_ptr();
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 2 <= n {
                let cv = vfmaq_f64(vld1q_f64(cp.add(j)), vv, vld1q_f64(bp.add(j)));
                vst1q_f64(cp.add(j), cv);
                j += 2;
            }
            while j < n {
                crow[j] += av * brow[j];
                j += 1;
            }
        }
    }

    /// NEON `C += Aᵀ·B` (same blocking and zero-skip as the x86 variants).
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn gemm_tn_neon(
        p_rows: usize,
        m: usize,
        n: usize,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
    ) {
        debug_assert!(a.len() == p_rows * m && b.len() == p_rows * n && c.len() == m * n);
        // SAFETY: neon per the fn contract, forwarded to the row kernels;
        // row slicing is bounds-checked safe code.
        unsafe {
            let mut p = 0;
            while p + 4 <= p_rows {
                let b0 = &b[p * n..(p + 1) * n];
                let b1 = &b[(p + 1) * n..(p + 2) * n];
                let b2 = &b[(p + 2) * n..(p + 3) * n];
                let b3 = &b[(p + 3) * n..(p + 4) * n];
                for i in 0..m {
                    let a0 = a[p * m + i];
                    let a1 = a[(p + 1) * m + i];
                    let a2 = a[(p + 2) * m + i];
                    let a3 = a[(p + 3) * m + i];
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        continue;
                    }
                    let crow = &mut c[i * n..(i + 1) * n];
                    rank4_row_neon(a0, a1, a2, a3, b0, b1, b2, b3, crow);
                }
                p += 4;
            }
            while p < p_rows {
                let brow = &b[p * n..(p + 1) * n];
                for i in 0..m {
                    let av = a[p * m + i];
                    if av == 0.0 {
                        continue;
                    }
                    rank1_row_neon(av, brow, &mut c[i * n..(i + 1) * n]);
                }
                p += 1;
            }
        }
    }

    /// 2-lane variant of the rho panel evaluator.
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn rho_row_neon(
        fam: RhoFamily,
        outputscale: f64,
        sqi: f64,
        sq: &[f64],
        row: &mut [f64],
    ) {
        let n = row.len();
        debug_assert_eq!(sq.len(), n);
        let n2 = n - n % 2;
        // SAFETY: neon per the fn contract; loads/stores run at
        // j + lane < n2 ≤ min(sq.len(), row.len()).
        unsafe {
            let sp = sq.as_ptr();
            let rp = row.as_mut_ptr();
            let vsqi = vdupq_n_f64(sqi);
            let vos = vdupq_n_f64(outputscale);
            let vm2 = vdupq_n_f64(-2.0);
            let vzero = vdupq_n_f64(0.0);
            let vone = vdupq_n_f64(1.0);
            let mut j = 0;
            while j < n2 {
                let v = vld1q_f64(rp.add(j));
                let base = vaddq_f64(vsqi, vld1q_f64(sp.add(j)));
                let d2 = vmaxq_f64(vfmaq_f64(base, vm2, v), vzero);
                let rho = match fam {
                    RhoFamily::Rbf => exp_neon(vmulq_f64(vdupq_n_f64(-0.5), d2)),
                    RhoFamily::Matern12 => exp_neon(vnegq_f64(vsqrtq_f64(d2))),
                    RhoFamily::Matern32 => {
                        let aa = vsqrtq_f64(vmulq_f64(vdupq_n_f64(3.0), d2));
                        let e = exp_neon(vnegq_f64(aa));
                        vmulq_f64(vaddq_f64(vone, aa), e)
                    }
                    RhoFamily::Matern52 => {
                        let aa = vsqrtq_f64(vmulq_f64(vdupq_n_f64(5.0), d2));
                        let e = exp_neon(vnegq_f64(aa));
                        let lin = vaddq_f64(vone, aa);
                        let third = vdupq_n_f64(1.0 / 3.0);
                        let a2t = vmulq_f64(vmulq_f64(aa, aa), third);
                        vmulq_f64(vaddq_f64(lin, a2t), e)
                    }
                };
                vst1q_f64(rp.add(j), vmulq_f64(vos, rho));
                j += 2;
            }
            for jj in n2..n {
                let d2 = (sqi + sq[jj] - 2.0 * row[jj]).max(0.0);
                row[jj] = outputscale * fam.rho(d2.sqrt());
            }
        }
    }

    /// 2-lane variant of the gradient-panel contraction.
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn grad_row_neon(
        fam: RhoFamily,
        outputscale: f64,
        li: f64,
        sqi: f64,
        sq: &[f64],
        pan: &[f64],
        rv: &[f64],
    ) -> (f64, f64) {
        let n = pan.len();
        debug_assert!(sq.len() == n && rv.len() == n);
        let n2 = n - n % 2;
        let scale = li * outputscale;
        // SAFETY: neon per the fn contract; all loads run at
        // j + lane < n2 ≤ min(sq.len(), pan.len(), rv.len()).
        unsafe {
            let sp = sq.as_ptr();
            let pp = pan.as_ptr();
            let rp = rv.as_ptr();
            let vsqi = vdupq_n_f64(sqi);
            let vm2 = vdupq_n_f64(-2.0);
            let vzero = vdupq_n_f64(0.0);
            let vone = vdupq_n_f64(1.0);
            let vscale = vdupq_n_f64(scale);
            let mut ae = vdupq_n_f64(0.0);
            let mut as2 = vdupq_n_f64(0.0);
            let mut j = 0;
            while j < n2 {
                let xx = vld1q_f64(pp.add(j));
                let base = vaddq_f64(vsqi, vld1q_f64(sp.add(j)));
                let d2 = vmaxq_f64(vfmaq_f64(base, vm2, xx), vzero);
                let (rho, drho) = match fam {
                    RhoFamily::Rbf => {
                        let e = exp_neon(vmulq_f64(vdupq_n_f64(-0.5), d2));
                        (e, vmulq_f64(d2, e))
                    }
                    RhoFamily::Matern12 => {
                        let aa = vsqrtq_f64(d2);
                        let e = exp_neon(vnegq_f64(aa));
                        (e, vmulq_f64(aa, e))
                    }
                    RhoFamily::Matern32 => {
                        let aa = vsqrtq_f64(vmulq_f64(vdupq_n_f64(3.0), d2));
                        let e = exp_neon(vnegq_f64(aa));
                        let rho = vmulq_f64(vaddq_f64(vone, aa), e);
                        (rho, vmulq_f64(vmulq_f64(aa, aa), e))
                    }
                    RhoFamily::Matern52 => {
                        let aa = vsqrtq_f64(vmulq_f64(vdupq_n_f64(5.0), d2));
                        let e = exp_neon(vnegq_f64(aa));
                        let lin = vaddq_f64(vone, aa);
                        let third = vdupq_n_f64(1.0 / 3.0);
                        let a2t = vmulq_f64(vmulq_f64(aa, aa), third);
                        let rho = vmulq_f64(vaddq_f64(lin, a2t), e);
                        (rho, vmulq_f64(vmulq_f64(a2t, lin), e))
                    }
                };
                let lr = vmulq_f64(vscale, vld1q_f64(rp.add(j)));
                ae = vfmaq_f64(ae, lr, drho);
                as2 = vfmaq_f64(as2, lr, rho);
                j += 2;
            }
            let mut d_ell = vaddvq_f64(ae);
            let mut d_s2 = vaddvq_f64(as2);
            for jj in n2..n {
                let rr = (sqi + sq[jj] - 2.0 * pan[jj]).max(0.0).sqrt();
                let lr = li * rv[jj] * outputscale;
                d_ell += lr * fam.drho_dlog_ell(rr);
                d_s2 += lr * fam.rho(rr);
            }
            (d_ell, d_s2)
        }
    }

    // Safe NEON table entries; the discharge matches the x86 blocks —
    // `table_for` only exposes NEON_TABLE when `Backend::Neon.available()`
    // holds (always, on aarch64).

    fn gemm_nn_neon_entry(
        m: usize,
        k: usize,
        n: usize,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        pack: &mut [f64],
    ) {
        // SAFETY: neon verified by `table_for` (see entry-block note).
        unsafe { gemm_nn_neon(m, k, n, a, b, c, pack) }
    }

    fn gemm_nt_neon_entry(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
        // SAFETY: neon verified by `table_for` (see entry-block note).
        unsafe { gemm_nt_neon(m, k, n, a, b, c) }
    }

    fn gemm_tn_neon_entry(p_rows: usize, m: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
        // SAFETY: neon verified by `table_for` (see entry-block note).
        unsafe { gemm_tn_neon(p_rows, m, n, a, b, c) }
    }

    fn dot_neon_entry(a: &[f64], b: &[f64]) -> f64 {
        // SAFETY: neon verified by `table_for` (see entry-block note).
        unsafe { dot_neon(a, b) }
    }

    fn rho_row_neon_entry(fam: RhoFamily, outputscale: f64, sqi: f64, sq: &[f64], row: &mut [f64]) {
        // SAFETY: neon verified by `table_for` (see entry-block note).
        unsafe { rho_row_neon(fam, outputscale, sqi, sq, row) }
    }

    fn grad_row_neon_entry(
        fam: RhoFamily,
        outputscale: f64,
        li: f64,
        sqi: f64,
        sq: &[f64],
        pan: &[f64],
        rv: &[f64],
    ) -> (f64, f64) {
        // SAFETY: neon verified by `table_for` (see entry-block note).
        unsafe { grad_row_neon(fam, outputscale, li, sqi, sq, pan, rv) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{self, NR};

    /// Deterministic LCG in [-1, 1] — the tests may not depend on wall
    /// clock or OS randomness.
    fn lcg(state: &mut u64) -> f64 {
        *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        ((*state >> 11) as f64 / (1u64 << 53) as f64) * 2.0 - 1.0
    }

    fn fill(state: &mut u64, buf: &mut [f64]) {
        for v in buf.iter_mut() {
            *v = lcg(state);
        }
    }

    /// Hybrid absolute+relative comparison (exp-dominated values span many
    /// orders of magnitude).
    fn approx(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + b.abs())
    }

    /// Every backend with a kernel table on this machine (empty on CPUs
    /// with no SIMD backend — the tests then trivially pass, and the
    /// forced-scalar CI lane covers the fallback path).
    fn tables() -> Vec<&'static KernelTable> {
        Backend::all().iter().filter_map(|&b| table_for(b)).collect()
    }

    /// GEMM shapes exercising full tiles plus every remainder class: row
    /// tails `m % MR`, packed-panel column tails `n % NR` (1..=7), and
    /// small dims 1..=15.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (2, 3, 5),
        (3, 5, 7),
        (4, 8, 8),
        (4, 8, 16),
        (5, 9, 17),
        (6, 1, 13),
        (7, 13, 15),
        (8, 16, 24),
        (9, 4, 11),
        (12, 33, 9),
        (13, 2, 31),
        (16, 15, 14),
    ];

    const FAMILIES: [RhoFamily; 4] = [
        RhoFamily::Rbf,
        RhoFamily::Matern12,
        RhoFamily::Matern32,
        RhoFamily::Matern52,
    ];

    #[test]
    fn choose_parses_specs() {
        assert_eq!(choose(""), best_available());
        assert_eq!(choose("auto"), best_available());
        assert_eq!(choose(" AUTO "), best_available());
        assert_eq!(choose("scalar"), Backend::Scalar);
        assert_eq!(choose("Scalar"), Backend::Scalar);
        assert_eq!(choose("definitely-not-an-isa"), best_available());
        for b in [Backend::Avx2, Backend::Avx512, Backend::Neon] {
            let got = choose(b.name());
            if b.available() {
                assert_eq!(got, b);
            } else {
                assert_eq!(got, best_available());
            }
        }
    }

    #[test]
    fn table_for_respects_availability() {
        assert!(table_for(Backend::Scalar).is_none());
        for &b in Backend::all().iter() {
            match table_for(b) {
                Some(t) => {
                    assert!(b.available());
                    assert_eq!(t.backend, b);
                }
                None => assert!(b == Backend::Scalar || !b.available()),
            }
        }
        assert!(best_available() == Backend::Scalar || table_for(best_available()).is_some());
    }

    /// The `pool_spawned_threads`-style proof: dispatch resolution runs at
    /// most once per process no matter how many threads race on `table()`.
    /// Also the `set_backend` round trip — one test owns the global
    /// override so parallel test threads can't interleave on it.
    #[test]
    fn dispatch_resolution_runs_once_and_override_round_trips() {
        let mut handles = Vec::new();
        for _ in 0..8 {
            handles.push(std::thread::spawn(|| {
                for _ in 0..200 {
                    let t = table();
                    if let Some(t) = t {
                        assert!(t.backend.available());
                    }
                    let _ = backend();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(resolutions(), 1, "resolution must run exactly once");

        let resolved = backend();
        set_backend(Backend::Scalar).unwrap();
        assert_eq!(backend(), Backend::Scalar);
        assert!(table().is_none());
        let best = best_available();
        set_backend(best).unwrap();
        assert_eq!(backend(), best);
        clear_backend_override();
        assert_eq!(backend(), resolved);
        for &b in Backend::all().iter() {
            if !b.available() {
                assert!(set_backend(b).is_err());
                assert_eq!(backend(), resolved, "failed set_backend must not stick");
            }
        }
        assert_eq!(resolutions(), 1, "overrides must not re-run resolution");
    }

    #[test]
    fn gemm_nn_matches_scalar_on_every_backend() {
        let mut st = 0x1234_5678_9abc_def0u64;
        for t in tables() {
            for &(m, k, n) in SHAPES {
                // +1 so the kernels run on unaligned slice starts
                let mut abuf = vec![0.0; m * k + 1];
                let mut bbuf = vec![0.0; k * n + 1];
                fill(&mut st, &mut abuf);
                fill(&mut st, &mut bbuf);
                let (a, b) = (&abuf[1..], &bbuf[1..]);
                let mut c_s = vec![0.25; m * n];
                let mut c_v = c_s.clone();
                let mut pack_s = vec![0.0; k * NR];
                let mut pack_v = vec![0.0; k * NR];
                gemm::gemm_nn_scalar(m, k, n, a, b, &mut c_s, &mut pack_s);
                (t.gemm_nn)(m, k, n, a, b, &mut c_v, &mut pack_v);
                for (x, y) in c_v.iter().zip(&c_s) {
                    assert!(
                        approx(*x, *y, 1e-12),
                        "gemm_nn {} ({m},{k},{n}): {x} vs {y}",
                        t.backend.name()
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_nt_matches_scalar_on_every_backend() {
        let mut st = 0x0dd0_1234_0000_0001u64;
        for t in tables() {
            for &(m, k, n) in SHAPES {
                let mut abuf = vec![0.0; m * k + 1];
                let mut bbuf = vec![0.0; n * k + 1];
                fill(&mut st, &mut abuf);
                fill(&mut st, &mut bbuf);
                let (a, b) = (&abuf[1..], &bbuf[1..]);
                let mut c_s = vec![-0.5; m * n];
                let mut c_v = c_s.clone();
                gemm::gemm_nt_scalar(m, k, n, a, b, &mut c_s);
                (t.gemm_nt)(m, k, n, a, b, &mut c_v);
                for (x, y) in c_v.iter().zip(&c_s) {
                    assert!(
                        approx(*x, *y, 1e-12),
                        "gemm_nt {} ({m},{k},{n}): {x} vs {y}",
                        t.backend.name()
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_tn_matches_scalar_on_every_backend() {
        let mut st = 0xbeef_0000_1111_2222u64;
        for t in tables() {
            for &(p_rows, m, n) in SHAPES {
                let mut abuf = vec![0.0; p_rows * m + 1];
                let mut bbuf = vec![0.0; p_rows * n + 1];
                fill(&mut st, &mut abuf);
                fill(&mut st, &mut bbuf);
                let mut a = abuf[1..].to_vec();
                // exercise the zero-skip branch too
                if !a.is_empty() {
                    a[0] = 0.0;
                    let last = a.len() - 1;
                    a[last] = 0.0;
                }
                let b = &bbuf[1..];
                let mut c_s = vec![1.5; m * n];
                let mut c_v = c_s.clone();
                gemm::gemm_tn_scalar(p_rows, m, n, &a, b, &mut c_s);
                (t.gemm_tn)(p_rows, m, n, &a, b, &mut c_v);
                for (x, y) in c_v.iter().zip(&c_s) {
                    assert!(
                        approx(*x, *y, 1e-12),
                        "gemm_tn {} ({p_rows},{m},{n}): {x} vs {y}",
                        t.backend.name()
                    );
                }
            }
        }
    }

    #[test]
    fn dot_matches_scalar_on_every_backend() {
        let mut st = 0x5151_5151_5151_5151u64;
        for t in tables() {
            for len in 0..=33usize {
                let mut abuf = vec![0.0; len + 1];
                let mut bbuf = vec![0.0; len + 1];
                fill(&mut st, &mut abuf);
                fill(&mut st, &mut bbuf);
                let (a, b) = (&abuf[1..], &bbuf[1..]);
                let want = gemm::dot_scalar(a, b);
                let got = (t.dot)(a, b);
                assert!(approx(got, want, 1e-13), "dot {} len {len}", t.backend.name());
            }
            // zip-truncation semantics: unequal lengths use the shorter
            let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
            let b = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
            assert_eq!((t.dot)(&a, &b), gemm::dot_scalar(&a, &b));
            assert_eq!((t.dot)(&b, &a), gemm::dot_scalar(&b, &a));
        }
    }

    #[test]
    fn rho_row_matches_scalar_on_every_backend() {
        let mut st = 0x0707_0707_0707_0707u64;
        for t in tables() {
            for fam in FAMILIES {
                for n in (1..=15).chain([64, 67]) {
                    for &sqi in &[0.0, 1.3, 37.0] {
                        let mut sq = vec![0.0; n];
                        let mut row = vec![0.0; n];
                        for j in 0..n {
                            // d² = sqi + sq[j] − 2·row[j] sometimes clamps
                            // at 0 (row > (sqi+sq)/2) and sometimes runs
                            // far into the exp tail (sq up to ~400)
                            sq[j] = (lcg(&mut st) + 1.0) * 200.0;
                            row[j] = lcg(&mut st) * (0.6 * (sqi + sq[j]));
                        }
                        let mut row_s = row.clone();
                        rho_row_scalar(fam, 1.7, sqi, &sq, &mut row_s);
                        (t.rho_row)(fam, 1.7, sqi, &sq, &mut row);
                        for (x, y) in row.iter().zip(&row_s) {
                            assert!(
                                approx(*x, *y, 1e-11),
                                "rho_row {} {fam:?} n={n}: {x} vs {y}",
                                t.backend.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn grad_row_matches_scalar_on_every_backend() {
        let mut st = 0xfeed_0000_0000_0001u64;
        for t in tables() {
            for fam in FAMILIES {
                for n in (1..=15).chain([64, 67]) {
                    let sqi = 2.5;
                    let li = -0.8;
                    let mut sq = vec![0.0; n];
                    let mut pan = vec![0.0; n];
                    let mut rv = vec![0.0; n];
                    for j in 0..n {
                        sq[j] = (lcg(&mut st) + 1.0) * 30.0;
                        pan[j] = lcg(&mut st) * (0.6 * (sqi + sq[j]));
                        rv[j] = lcg(&mut st);
                    }
                    let (we, ws) = grad_row_scalar(fam, 1.3, li, sqi, &sq, &pan, &rv);
                    let (ge, gs) = (t.grad_row)(fam, 1.3, li, sqi, &sq, &pan, &rv);
                    assert!(
                        approx(ge, we, 1e-10) && approx(gs, ws, 1e-10),
                        "grad_row {} {fam:?} n={n}: ({ge},{gs}) vs ({we},{ws})",
                        t.backend.name()
                    );
                }
            }
        }
    }

    /// The documented `exp` contract: ≤ ~4 ULP vs glibc over `[-708, 0]`
    /// (tested at 1e-13 relative) and flush-to-zero below -708. Driven
    /// through the RBF `rho_row` with `row = 0`, `sqi = 0`, `s² = 1`, which
    /// evaluates exactly `exp(-0.5·sq[j])` lane-parallel.
    #[test]
    fn vector_exp_matches_glibc_within_contract() {
        for t in tables() {
            let mut d2s = Vec::new();
            let mut x = 0.0f64;
            while x <= 1416.0 {
                d2s.push(x);
                x += 0.37;
            }
            d2s.push(1416.0); // exp(-708) itself must survive, not flush
            // pad to a lane multiple so no element takes the scalar tail
            while d2s.len() % 8 != 0 {
                d2s.push(1416.0);
            }
            let mut row = vec![0.0; d2s.len()];
            (t.rho_row)(RhoFamily::Rbf, 1.0, 0.0, &d2s, &mut row);
            for (j, &d2) in d2s.iter().enumerate() {
                let expect = (-0.5 * d2).exp();
                let rel = ((row[j] - expect) / expect).abs();
                assert!(
                    rel <= 1e-13,
                    "{} exp({}) rel err {rel:e}",
                    t.backend.name(),
                    -0.5 * d2
                );
            }
            let deep = [1420.0, 1500.0, 2000.0, 1.0e6, 2.0e9, 1.0e300, 4.0e300, 8.0e300];
            let mut row = vec![0.0; deep.len()];
            (t.rho_row)(RhoFamily::Rbf, 1.0, 0.0, &deep, &mut row);
            assert!(
                row.iter().all(|&v| v == 0.0),
                "{}: below-cutoff inputs must flush to zero, got {row:?}",
                t.backend.name()
            );
        }
    }
}

//! Symmetric eigendecomposition: Householder tridiagonalization (`tred2`)
//! followed by implicit-QL with shifts (`tql2`), ported from the classic
//! EISPACK routines.
//!
//! Used for (i) the *exact* `f(K)` oracle in tests (`spd_sqrt` /
//! `spd_inv_sqrt`), (ii) eigenvalues of the Lanczos tridiagonal matrix when
//! estimating `λ_min`, `λ_max` (Appx. B.2 of the paper), and (iii) the
//! randomized-SVD baseline.

use crate::linalg::Matrix;
use crate::{Error, Result};

/// Eigendecomposition `A = V diag(d) Vᵀ` of a symmetric matrix.
pub struct SymEig {
    /// Eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Eigenvectors as columns of `V`.
    pub vectors: Matrix,
}

/// Full symmetric eigendecomposition.
pub fn sym_eig(a: &Matrix) -> Result<SymEig> {
    let n = a.rows();
    if a.cols() != n {
        return Err(Error::Shape("sym_eig needs square".into()));
    }
    // Copy; v will be overwritten with the accumulated transformations.
    let mut v = a.clone();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    tred2(&mut v, &mut d, &mut e);
    tql2(&mut v, &mut d, &mut e)?;
    // sort ascending, permuting eigenvector columns
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
    let values: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
    let mut vectors = Matrix::zeros(n, n);
    for (newj, &oldj) in idx.iter().enumerate() {
        for i in 0..n {
            vectors[(i, newj)] = v[(i, oldj)];
        }
    }
    Ok(SymEig { values, vectors })
}

/// Eigenvalues of a symmetric tridiagonal matrix with diagonal `diag` and
/// off-diagonal `off` (`off.len() == diag.len() - 1`). Ascending order.
pub fn tridiag_eigenvalues(diag: &[f64], off: &[f64]) -> Result<Vec<f64>> {
    let n = diag.len();
    assert!(off.len() + 1 == n || (n == 0 && off.is_empty()));
    if n == 0 {
        return Ok(vec![]);
    }
    let mut d = diag.to_vec();
    let mut e = vec![0.0; n];
    e[1..n].copy_from_slice(off); // EISPACK convention: sub-diagonal in e[1..]
    tql_values(&mut d, &mut e)?;
    d.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(d)
}

/// Apply `f` to an SPD matrix through its eigendecomposition: `V f(d) Vᵀ`.
pub fn spd_matrix_function(a: &Matrix, f: impl Fn(f64) -> f64) -> Result<Matrix> {
    let eig = sym_eig(a)?;
    let n = a.rows();
    let mut scaled = eig.vectors.clone();
    for j in 0..n {
        let fj = f(eig.values[j]);
        for i in 0..n {
            scaled[(i, j)] *= fj;
        }
    }
    Ok(scaled.matmul(&eig.vectors.transpose()))
}

/// Exact principal square root `K^{1/2}` (test oracle).
pub fn spd_sqrt(a: &Matrix) -> Result<Matrix> {
    spd_matrix_function(a, |x| x.max(0.0).sqrt())
}

/// Exact inverse square root `K^{-1/2}` (test oracle).
pub fn spd_inv_sqrt(a: &Matrix) -> Result<Matrix> {
    spd_matrix_function(a, |x| 1.0 / x.max(1e-300).sqrt())
}

/// Householder reduction of `v` (symmetric) to tridiagonal form.
/// On exit `d` holds the diagonal, `e[1..]` the sub-diagonal, and `v` the
/// accumulated orthogonal transformation. (EISPACK `tred2`.)
fn tred2(v: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for j in 0..n {
        d[j] = v[(n - 1, j)];
    }
    for i in (1..n).rev() {
        // accumulate Householder vectors
        let mut scale = 0.0;
        let mut h = 0.0;
        for item in d.iter().take(i) {
            scale += item.abs();
        }
        if scale == 0.0 {
            e[i] = d[i - 1];
            for j in 0..i {
                d[j] = v[(i - 1, j)];
                v[(i, j)] = 0.0;
                v[(j, i)] = 0.0;
            }
        } else {
            for item in d.iter_mut().take(i) {
                *item /= scale;
                h += *item * *item;
            }
            let mut f = d[i - 1];
            let mut g = h.sqrt();
            if f > 0.0 {
                g = -g;
            }
            e[i] = scale * g;
            h -= f * g;
            d[i - 1] = f - g;
            for item in e.iter_mut().take(i) {
                *item = 0.0;
            }
            for j in 0..i {
                f = d[j];
                v[(j, i)] = f;
                g = e[j] + v[(j, j)] * f;
                for k in (j + 1)..i {
                    g += v[(k, j)] * d[k];
                    e[k] += v[(k, j)] * f;
                }
                e[j] = g;
            }
            f = 0.0;
            for j in 0..i {
                e[j] /= h;
                f += e[j] * d[j];
            }
            let hh = f / (h + h);
            for j in 0..i {
                e[j] -= hh * d[j];
            }
            for j in 0..i {
                f = d[j];
                g = e[j];
                for k in j..i {
                    v[(k, j)] -= f * e[k] + g * d[k];
                }
                d[j] = v[(i - 1, j)];
                v[(i, j)] = 0.0;
            }
        }
        d[i] = h;
    }
    // accumulate transformations
    for i in 0..n - 1 {
        v[(n - 1, i)] = v[(i, i)];
        v[(i, i)] = 1.0;
        let h = d[i + 1];
        if h != 0.0 {
            for k in 0..=i {
                d[k] = v[(k, i + 1)] / h;
            }
            for j in 0..=i {
                let mut g = 0.0;
                for k in 0..=i {
                    g += v[(k, i + 1)] * v[(k, j)];
                }
                for k in 0..=i {
                    v[(k, j)] -= g * d[k];
                }
            }
        }
        for k in 0..=i {
            v[(k, i + 1)] = 0.0;
        }
    }
    for j in 0..n {
        d[j] = v[(n - 1, j)];
        v[(n - 1, j)] = 0.0;
    }
    v[(n - 1, n - 1)] = 1.0;
    e[0] = 0.0;
}

/// Implicit-QL with eigenvector accumulation (EISPACK `tql2`).
fn tql2(v: &mut Matrix, d: &mut [f64], e: &mut [f64]) -> Result<()> {
    let n = d.len();
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    let mut f = 0.0f64;
    let mut tst1 = 0.0f64;
    let eps = f64::EPSILON;
    for l in 0..n {
        tst1 = tst1.max(d[l].abs() + e[l].abs());
        let mut m = l;
        while m < n {
            if e[m].abs() <= eps * tst1 {
                break;
            }
            m += 1;
        }
        if m > l {
            let mut iter = 0;
            loop {
                iter += 1;
                if iter > 50 {
                    return Err(Error::Numerical("tql2: too many iterations".into()));
                }
                // implicit shift
                let mut g = d[l];
                let mut p = (d[l + 1] - g) / (2.0 * e[l]);
                let mut r = (p * p + 1.0).sqrt();
                if p < 0.0 {
                    r = -r;
                }
                d[l] = e[l] / (p + r);
                d[l + 1] = e[l] * (p + r);
                let dl1 = d[l + 1];
                let mut h = g - d[l];
                for item in d.iter_mut().take(n).skip(l + 2) {
                    *item -= h;
                }
                f += h;
                // QL sweep
                p = d[m];
                let mut c = 1.0;
                let mut c2 = c;
                let mut c3 = c;
                let el1 = e[l + 1];
                let mut s = 0.0;
                let mut s2 = 0.0;
                for i in (l..m).rev() {
                    c3 = c2;
                    c2 = c;
                    s2 = s;
                    g = c * e[i];
                    h = c * p;
                    r = (p * p + e[i] * e[i]).sqrt();
                    e[i + 1] = s * r;
                    s = e[i] / r;
                    c = p / r;
                    p = c * d[i] - s * g;
                    d[i + 1] = h + s * (c * g + s * d[i]);
                    // accumulate eigenvectors
                    for k in 0..n {
                        h = v[(k, i + 1)];
                        v[(k, i + 1)] = s * v[(k, i)] + c * h;
                        v[(k, i)] = c * v[(k, i)] - s * h;
                    }
                }
                p = -s * s2 * c3 * el1 * e[l] / dl1;
                e[l] = s * p;
                d[l] = c * p;
                if e[l].abs() <= eps * tst1 {
                    break;
                }
            }
        }
        d[l] += f;
        e[l] = 0.0;
    }
    Ok(())
}

/// Eigenvalues-only implicit QL (no eigenvector accumulation) — cheap path
/// for the small Lanczos tridiagonal matrices.
fn tql_values(d: &mut [f64], e: &mut [f64]) -> Result<()> {
    let n = d.len();
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    let mut f = 0.0f64;
    let mut tst1 = 0.0f64;
    let eps = f64::EPSILON;
    for l in 0..n {
        tst1 = tst1.max(d[l].abs() + e[l].abs());
        let mut m = l;
        while m < n {
            if e[m].abs() <= eps * tst1 {
                break;
            }
            m += 1;
        }
        if m > l {
            let mut iter = 0;
            loop {
                iter += 1;
                if iter > 50 {
                    return Err(Error::Numerical("tql: too many iterations".into()));
                }
                let mut g = d[l];
                let mut p = (d[l + 1] - g) / (2.0 * e[l]);
                let mut r = (p * p + 1.0).sqrt();
                if p < 0.0 {
                    r = -r;
                }
                d[l] = e[l] / (p + r);
                d[l + 1] = e[l] * (p + r);
                let dl1 = d[l + 1];
                let mut h = g - d[l];
                for item in d.iter_mut().take(n).skip(l + 2) {
                    *item -= h;
                }
                f += h;
                p = d[m];
                let mut c = 1.0;
                let mut c2 = c;
                let mut c3 = c;
                let el1 = e[l + 1];
                let mut s = 0.0;
                let mut s2 = 0.0;
                for i in (l..m).rev() {
                    c3 = c2;
                    c2 = c;
                    s2 = s;
                    g = c * e[i];
                    h = c * p;
                    r = (p * p + e[i] * e[i]).sqrt();
                    e[i + 1] = s * r;
                    s = e[i] / r;
                    c = p / r;
                    p = c * d[i] - s * g;
                    d[i + 1] = h + s * (c * g + s * d[i]);
                }
                p = -s * s2 * c3 * el1 * e[l] / dl1;
                e[l] = s * p;
                d[l] = c * p;
                if e[l].abs() <= eps * tst1 {
                    break;
                }
            }
        }
        d[l] += f;
        e[l] = 0.0;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_sym(n: usize, rng: &mut Pcg64) -> Matrix {
        let mut a = Matrix::randn(n, n, rng);
        a.symmetrize();
        a
    }

    #[test]
    fn eig_reconstructs() {
        let mut rng = Pcg64::seeded(1);
        let a = random_sym(18, &mut rng);
        let eig = sym_eig(&a).unwrap();
        // A V = V diag(d)
        for j in 0..18 {
            let vj = eig.vectors.col(j);
            let av = a.matvec(&vj);
            for i in 0..18 {
                assert!(
                    (av[i] - eig.values[j] * vj[i]).abs() < 1e-8,
                    "eigpair {j} residual too large"
                );
            }
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Pcg64::seeded(2);
        let a = random_sym(15, &mut rng);
        let eig = sym_eig(&a).unwrap();
        let vtv = eig.vectors.t_matmul(&eig.vectors);
        assert!(vtv.max_abs_diff(&Matrix::eye(15)) < 1e-9);
    }

    #[test]
    fn sqrt_squares_back() {
        let mut rng = Pcg64::seeded(3);
        let b = Matrix::randn(12, 12, &mut rng);
        let mut k = b.matmul(&b.transpose());
        for i in 0..12 {
            k[(i, i)] += 12.0;
        }
        let s = spd_sqrt(&k).unwrap();
        let rec = s.matmul(&s);
        assert!(rec.max_abs_diff(&k) < 1e-7);
        let si = spd_inv_sqrt(&k).unwrap();
        let ident = s.matmul(&si);
        assert!(ident.max_abs_diff(&Matrix::eye(12)) < 1e-7);
    }

    #[test]
    fn tridiag_matches_dense() {
        let diag = [2.0, 3.0, 4.0, 5.0];
        let off = [1.0, 0.5, 0.25];
        let n = diag.len();
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            a[(i, i)] = diag[i];
        }
        for i in 0..n - 1 {
            a[(i, i + 1)] = off[i];
            a[(i + 1, i)] = off[i];
        }
        let ev1 = tridiag_eigenvalues(&diag, &off).unwrap();
        let ev2 = sym_eig(&a).unwrap().values;
        for (x, y) in ev1.iter().zip(&ev2) {
            assert!((x - y).abs() < 1e-10, "{x} vs {y}");
        }
    }

    #[test]
    fn known_eigenvalues_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let eig = sym_eig(&a).unwrap();
        assert!((eig.values[0] - 1.0).abs() < 1e-12);
        assert!((eig.values[1] - 3.0).abs() < 1e-12);
    }
}

//! Mixed-precision (f32-storage / f64-accumulate) kernel tier + the
//! `Precision` solve policy.
//!
//! The serving stack's hot MVMs are bandwidth-bound (DESIGN.md §7): at the
//! sizes the paper targets, every Lanczos step streams O(N²) kernel-panel
//! bytes and the FMA units wait on memory. Storing and streaming those
//! panels in `f32` halves the bytes per entry — and on AVX2 doubles the
//! lane count (8 × f32 vs 4 × f64) — while all *accumulation* stays in
//! `f64`, so a single pass loses at most ~`k·ε₃₂` of forward accuracy.
//! The solver then restores f64-grade residuals with iterative refinement
//! (`krylov::msminres_block_refined_in`): the residual `r = b − K_{f64}·x`
//! is always evaluated through the f64 operator, per the gating argument of
//! Simpson et al. (PAPERS.md) — never trust the low-precision recurrence's
//! own residual estimate.
//!
//! ## Layout of this module
//!
//! * [`Precision`] / [`RefineConfig`]: the solve-path policy knob carried on
//!   `CiqOptions` → `SolverContext` (plus the `CIQ_PRECISION` env override).
//! * [`MixedKernelTable`]: the f32-storage twin of
//!   [`super::simd::KernelTable`] — same four entry families
//!   (`gemm_nn`/`gemm_nt`/`gemm_tn`, `dot`, `rho_row`/`grad_row`), selected
//!   by the *same* backend resolution ([`super::simd::backend`], including
//!   the `CIQ_SIMD` override), so a forced backend forces both tiers.
//! * Safe dispatch wrappers ([`gemm_nn`] …) mirroring [`super::gemm`], with
//!   always-compiled scalar fallbacks that are also the property-test
//!   oracles. There is no "pre-dispatch bit-identical" contract here (the
//!   mixed tier is new); the contract is a documented forward-error bound
//!   against the f64 oracles instead.
//!
//! ## Numeric contract
//!
//! * All GEMM/dot accumulation is f64; `f32 × f32` products are exact in
//!   f64, so backend-vs-scalar differences are pure summation-order noise.
//! * `gemm_nt` (the Gram stage) rounds its output to f32 once per entry —
//!   it feeds `rho_row`, whose input is already f32.
//! * `rho_row`/`grad_row` compute the distance in f32 (matching the f32
//!   panel storage); AVX2 evaluates `ρ` with an 8-lane f32 `exp`
//!   (degree-7 Taylor, ≤ ~4 ULP-f32; flushes below −87), AVX-512/NEON
//!   widen to f64 lanes and reuse the f64 vector `exp`. The scalar
//!   fallback computes `ρ` through glibc f64 on the f32 distance. All
//!   variants agree to ~1e-5 relative (property-tested) — refinement
//!   absorbs the rest.
//!
//! Narrowing `as f32` casts are intentionally *confined* to this module:
//! structlint rule 7 requires a `// precision:` justification for any
//! truncating cast elsewhere in the shimmed/hot modules.

use super::simd::{self, Backend, RhoFamily};
use std::sync::OnceLock;

/// Arithmetic policy for a solve: pure f64, or f32-storage kernels wrapped
/// in f64 iterative refinement. Carried on `CiqOptions`/`SolverContext`;
/// `F64` keeps every code path bit-identical to the pre-mixed tree.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum Precision {
    /// Pure f64 (the default; bit-identical to pre-mixed behavior).
    #[default]
    F64,
    /// f32-storage kernels + outer f64 iterative refinement.
    Mixed(RefineConfig),
}

impl Precision {
    /// Whether this policy runs the mixed-precision kernel tier.
    pub fn is_mixed(self) -> bool {
        matches!(self, Precision::Mixed(_))
    }
}

/// Iterative-refinement loop parameters (see DESIGN.md §9). Each sweep
/// contracts the error by ~`κ·ε₃₂`; stagnation or the sweep cap triggers a
/// full fallback to the pure-f64 solve, so `Mixed` never returns a worse
/// residual than the tolerance the f64 path is held to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RefineConfig {
    /// Maximum refinement sweeps before falling back to pure f64.
    pub max_sweeps: usize,
    /// Floor for the inner (f32-operator) solve tolerance: asking the f32
    /// recurrence for residuals below ~ε₃₂ just burns iterations.
    pub inner_tol_floor: f64,
    /// A sweep must shrink the worst column residual by at least this
    /// factor, or the loop declares stagnation and falls back.
    pub stall_ratio: f64,
}

impl Default for RefineConfig {
    fn default() -> Self {
        RefineConfig { max_sweeps: 4, inner_tol_floor: 3e-6, stall_ratio: 0.5 }
    }
}

/// Parse a `CIQ_PRECISION` spec. Pure (no env access) so it is
/// unit-testable; `auto`/empty mean "no override", unknown values warn to
/// stderr and are ignored.
pub fn parse_precision(spec: &str) -> Option<Precision> {
    match spec.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => None,
        "f64" => Some(Precision::F64),
        "mixed" => Some(Precision::Mixed(RefineConfig::default())),
        other => {
            eprintln!("ciq: unknown CIQ_PRECISION value {other:?}; ignoring");
            None
        }
    }
}

/// The process-wide `CIQ_PRECISION` override, resolved once (the
/// service applies it to its config at startup; solves never re-read the
/// environment).
pub fn env_precision_override() -> Option<Precision> {
    static CACHE: OnceLock<Option<Precision>> = OnceLock::new();
    *CACHE.get_or_init(|| match std::env::var("CIQ_PRECISION") {
        Ok(spec) => parse_precision(&spec),
        Err(_) => None,
    })
}

/// Narrow an f64 slab into a same-length f32 slab (the one sanctioned bulk
/// truncation site; pooled `SolveWorkspace::take_f32` buffers are the
/// intended destination).
pub fn downconvert(src: &[f64], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s as f32;
    }
}

/// Widen an f32 slab back into an f64 slab (exact).
pub fn upconvert(src: &[f32], dst: &mut [f64]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = f64::from(s);
    }
}

/// Resolved mixed-precision function pointers for one backend — the
/// f32-storage twin of [`super::simd::KernelTable`]. All entries are safe
/// fns (thin wrappers over the `#[target_feature]` kernels), reachable only
/// through [`table_for`]'s availability gate.
///
/// Contracts (validated by the dispatching wrappers below):
/// * `gemm_nn(m, k, n, a, b, c, pack)`: `C(f64) += A(f32)·B(f32)`;
///   `pack.len() ≥ k·NR` whenever `n ≥ NR`.
/// * `gemm_nt(m, k, n, a, b, c)`: `C(f32) += A(f32)·B(f32)ᵀ`, accumulated
///   in f64 per entry and rounded once on store (the Gram stage).
/// * `gemm_tn(p, m, n, a, b, c)`: `C(f64) += A(f32)ᵀ·B(f32)`.
/// * `dot(a, b)`: f64 accumulation, zip-truncation semantics.
/// * `rho_row(fam, outputscale, sqi, sq, row)`: in-place
///   `row[j] ← s²·ρ(√max(sqi + sq[j] − 2·row[j], 0))` on f32 storage.
/// * `grad_row(fam, outputscale, li, sqi, sq, pan, rv)`: f32 panels, f64
///   residual column, f64 partial sums (same meaning as the f64 entry).
pub struct MixedKernelTable {
    /// Which backend these pointers implement (for logs/benches).
    pub backend: Backend,
    /// `C(f64) += A(f32)·B(f32)` micro-kernel driver (packed-B panels).
    pub gemm_nn: fn(usize, usize, usize, &[f32], &[f32], &mut [f64], &mut [f32]),
    /// `C(f32) += A(f32)·B(f32)ᵀ` (f64-accumulated contiguous-row dots).
    pub gemm_nt: fn(usize, usize, usize, &[f32], &[f32], &mut [f32]),
    /// `C(f64) += A(f32)ᵀ·B(f32)` (rank-1 updates).
    pub gemm_tn: fn(usize, usize, usize, &[f32], &[f32], &mut [f64]),
    /// f32-storage dot product with an f64 accumulator.
    pub dot: fn(&[f32], &[f32]) -> f64,
    /// Lane-parallel kernel-panel evaluation on f32 storage.
    pub rho_row: fn(RhoFamily, f64, f32, &[f32], &mut [f32]),
    /// Lane-parallel gradient-panel contraction (f32 panels, f64 sums).
    pub grad_row: fn(RhoFamily, f64, f64, f32, &[f32], &[f32], &[f64]) -> (f64, f64),
}

/// The mixed table for the *current* backend (same resolution as
/// [`super::simd::table`], including `CIQ_SIMD` and in-process overrides),
/// or `None` when the scalar mixed fallbacks should run.
pub fn table() -> Option<&'static MixedKernelTable> {
    table_for(simd::backend())
}

/// The mixed table for a specific backend, if compiled *and* available on
/// this CPU. As in the f64 tier, this availability check is the discharge
/// of every reachable kernel's `#[target_feature]` contract.
pub fn table_for(b: Backend) -> Option<&'static MixedKernelTable> {
    if !b.available() {
        return None;
    }
    match b {
        Backend::Scalar => None,
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => Some(&x86::AVX2_MIXED_TABLE),
        #[cfg(target_arch = "x86_64")]
        Backend::Avx512 => Some(&x86::AVX512_MIXED_TABLE),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => Some(&neon::NEON_MIXED_TABLE),
        #[cfg(not(target_arch = "x86_64"))]
        Backend::Avx2 | Backend::Avx512 => None,
        #[cfg(not(target_arch = "aarch64"))]
        Backend::Neon => None,
    }
}

// ------------------------------------------------------- dispatch wrappers

use super::gemm::NR;

/// `C(f64) += A(f32)·B(f32)` with a caller-owned f32 pack buffer (grown as
/// needed) — the mixed twin of [`super::gemm::gemm_nn_with_pack`].
pub fn gemm_nn(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f64],
    pack: &mut Vec<f32>,
) {
    assert_eq!(a.len(), m * k, "mixed gemm_nn: A buffer size");
    assert_eq!(b.len(), k * n, "mixed gemm_nn: B buffer size");
    assert_eq!(c.len(), m * n, "mixed gemm_nn: C buffer size");
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    // pack buffer only needed when at least one full NR panel exists
    if n >= NR && pack.len() < k * NR {
        pack.resize(k * NR, 0.0);
    }
    if let Some(t) = table() {
        return (t.gemm_nn)(m, k, n, a, b, c, pack);
    }
    gemm_nn_scalar(m, k, n, a, b, c);
}

/// `C(f32) += A(f32)·B(f32)ᵀ` (f64-accumulated) — the Gram-panel stage.
pub fn gemm_nt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "mixed gemm_nt: A buffer size");
    assert_eq!(b.len(), n * k, "mixed gemm_nt: B buffer size");
    assert_eq!(c.len(), m * n, "mixed gemm_nt: C buffer size");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if let Some(t) = table() {
        return (t.gemm_nt)(m, k, n, a, b, c);
    }
    gemm_nt_scalar(m, k, n, a, b, c);
}

/// `C(f64) += A(f32)ᵀ·B(f32)` (rank-1 updates, zero-skip preserved).
pub fn gemm_tn(p_rows: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f64]) {
    assert_eq!(a.len(), p_rows * m, "mixed gemm_tn: A buffer size");
    assert_eq!(b.len(), p_rows * n, "mixed gemm_tn: B buffer size");
    assert_eq!(c.len(), m * n, "mixed gemm_tn: C buffer size");
    if m == 0 || n == 0 {
        return;
    }
    if let Some(t) = table() {
        return (t.gemm_tn)(p_rows, m, n, a, b, c);
    }
    gemm_tn_scalar(p_rows, m, n, a, b, c);
}

/// f32-storage dot product with an f64 accumulator.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    if let Some(t) = table() {
        return (t.dot)(a, b);
    }
    dot_scalar(a, b)
}

/// Dispatching `rho_row` on f32 storage (see [`MixedKernelTable`]).
pub fn rho_row(fam: RhoFamily, outputscale: f64, sqi: f32, sq: &[f32], row: &mut [f32]) {
    if let Some(t) = table() {
        return (t.rho_row)(fam, outputscale, sqi, sq, row);
    }
    rho_row_scalar(fam, outputscale, sqi, sq, row);
}

/// Dispatching `grad_row` on f32 panels (see [`MixedKernelTable`]).
pub fn grad_row(
    fam: RhoFamily,
    outputscale: f64,
    li: f64,
    sqi: f32,
    sq: &[f32],
    pan: &[f32],
    rv: &[f64],
) -> (f64, f64) {
    if let Some(t) = table() {
        return (t.grad_row)(fam, outputscale, li, sqi, sq, pan, rv);
    }
    grad_row_scalar(fam, outputscale, li, sqi, sq, pan, rv)
}

// ------------------------------------------------------- scalar fallbacks

/// Scalar mixed `gemm_nn` (fallback + oracle): f64 accumulation over f32
/// storage in an i-p-j row-update order (no pack buffer needed).
pub fn gemm_nn_scalar(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f64]) {
    debug_assert!(a.len() == m * k && b.len() == k * n && c.len() == m * n);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let av = f64::from(a[i * k + p]);
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * f64::from(bv);
            }
        }
    }
}

/// Scalar mixed `gemm_nt` (fallback + oracle): each output entry is one
/// f64-accumulated dot, rounded to f32 exactly once on store.
pub fn gemm_nt_scalar(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert!(a.len() == m * k && b.len() == n * k && c.len() == m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let s = dot_scalar(arow, &b[j * k..(j + 1) * k]);
            let idx = i * n + j;
            c[idx] = (f64::from(c[idx]) + s) as f32;
        }
    }
}

/// Scalar mixed `gemm_tn` (fallback + oracle): rank-1 row updates with the
/// same zero-skip as the f64 kernel.
pub fn gemm_tn_scalar(p_rows: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f64]) {
    debug_assert!(a.len() == p_rows * m && b.len() == p_rows * n && c.len() == m * n);
    for p in 0..p_rows {
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let av = a[p * m + i];
            if av == 0.0 {
                continue;
            }
            let av = f64::from(av);
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * f64::from(bv);
            }
        }
    }
}

/// Scalar mixed dot (fallback + oracle): exact f64 products (24+24 < 53
/// significand bits), zip-truncation semantics like the f64 kernel.
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f64 {
    let mut s = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        s += f64::from(x) * f64::from(y);
    }
    s
}

/// Scalar mixed `rho_row` (fallback + oracle): the distance is computed in
/// f32 (matching the vector kernels' storage precision), `ρ` through glibc
/// f64, one f32 rounding on store.
pub fn rho_row_scalar(fam: RhoFamily, outputscale: f64, sqi: f32, sq: &[f32], row: &mut [f32]) {
    debug_assert_eq!(sq.len(), row.len());
    for (v, &sj) in row.iter_mut().zip(sq) {
        let d2 = (sqi + sj - 2.0 * *v).max(0.0);
        *v = (outputscale * fam.rho(f64::from(d2).sqrt())) as f32;
    }
}

/// Scalar mixed `grad_row` (fallback + oracle): f32 distances, f64 `ρ`/`dρ`
/// and f64 partial sums (`lr = li·rv[j]·s²` in the f64 kernel's exact
/// association).
pub fn grad_row_scalar(
    fam: RhoFamily,
    outputscale: f64,
    li: f64,
    sqi: f32,
    sq: &[f32],
    pan: &[f32],
    rv: &[f64],
) -> (f64, f64) {
    debug_assert_eq!(sq.len(), pan.len());
    debug_assert_eq!(sq.len(), rv.len());
    let mut d_ell = 0.0;
    let mut d_s2 = 0.0;
    for ((&xx, &sj), &rj) in pan.iter().zip(sq).zip(rv) {
        let rr = f64::from((sqi + sj - 2.0 * xx).max(0.0)).sqrt();
        let lr = li * rj * outputscale;
        d_ell += lr * fam.drho_dlog_ell(rr);
        d_s2 += lr * fam.rho(rr);
    }
    (d_ell, d_s2)
}

/// Shared scalar column tail for the vector `gemm_nn` drivers (columns
/// `j0..n` that don't fill an NR panel).
#[allow(dead_code)] // referenced only by the cfg(target_arch) kernel modules
pub(crate) fn gemm_nn_coltail(
    m: usize,
    k: usize,
    n: usize,
    j0: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f64],
) {
    for i in 0..m {
        for p in 0..k {
            let av = f64::from(a[i * k + p]);
            if av == 0.0 {
                continue;
            }
            for j in j0..n {
                c[i * n + j] += av * f64::from(b[p * n + j]);
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2+FMA and AVX-512F mixed-precision kernels. Same safety
    //! convention as `simd::x86`: every `unsafe fn`'s single obligation is
    //! "the named target features are available", discharged by
    //! [`super::table_for`]'s `Backend::available` gate in front of the
    //! safe `*_entry` wrappers (the only callers).
    //!
    //! AVX2 runs the `ρ` pipeline in 8 × f32 lanes with a dedicated f32
    //! `exp` (twice the lane count of the f64 tier); AVX-512 widens each
    //! 8 × f32 load to one 8 × f64 zmm and reuses the f64 vector `exp`, so
    //! its math error matches the scalar mixed oracle more closely.

    use super::super::gemm::{MR, NR};
    use super::super::simd::x86::{exp_avx512, hsum_avx2, neg_avx512};
    use super::{Backend, MixedKernelTable, RhoFamily};
    use core::arch::x86_64::*;

    const ROUND_NEAREST: i32 = _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC;

    /// Taylor coefficients `1/k!` for the degree-7 f32 `e^r` polynomial on
    /// `|r| ≤ ln2/2` (truncation `r⁸/8!` ≈ 5e-9 at the interval edge —
    /// far below f32 ε; total error ≤ ~4 ULP-f32).
    const EXP_POLY_F32: [f32; 8] = [
        1.0,
        1.0,
        1.0 / 2.0,
        1.0 / 6.0,
        1.0 / 24.0,
        1.0 / 120.0,
        1.0 / 720.0,
        1.0 / 5040.0,
    ];
    const LOG2_E_F32: f32 = std::f32::consts::LOG2_E;
    /// `ln 2` split: hi part exact in f32 (0x3F317000), lo the remainder.
    const LN_2_HI_F32: f32 = 0.693_359_375;
    const LN_2_LO_F32: f32 = -2.121_944_4e-4;

    pub(super) static AVX2_MIXED_TABLE: MixedKernelTable = MixedKernelTable {
        backend: Backend::Avx2,
        gemm_nn: gemm_nn_avx2_entry,
        gemm_nt: gemm_nt_avx2_entry,
        gemm_tn: gemm_tn_avx2_entry,
        dot: dot_avx2_entry,
        rho_row: rho_row_avx2_entry,
        grad_row: grad_row_avx2_entry,
    };

    // ---------------------------------------------------------------- AVX2

    /// 8-lane f32 `e^x`: the f64 vector `exp`'s `2^n · 2^f` scheme at f32
    /// width (degree-7 Taylor, f32 hi/lo `ln 2` split, exponent-bit
    /// scaling). Flushes `x < −87` to zero (f32 normal range ends near
    /// `e^{−87.3}`; the kernels treat subnormals and 0 alike).
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    #[inline]
    unsafe fn exp_ps_avx2(x: __m256) -> __m256 {
        // SAFETY: register-only intrinsics (no memory access); avx2+fma
        // hold by this fn's own contract.
        unsafe {
            // clamp keeps n in the convert range; the final mask zeroes
            // the clamped lanes anyway
            let xc = _mm256_max_ps(x, _mm256_set1_ps(-100.0));
            let n = _mm256_round_ps::<ROUND_NEAREST>(_mm256_mul_ps(xc, _mm256_set1_ps(LOG2_E_F32)));
            let r = _mm256_fnmadd_ps(n, _mm256_set1_ps(LN_2_HI_F32), xc);
            let r = _mm256_fnmadd_ps(n, _mm256_set1_ps(LN_2_LO_F32), r);
            let mut p = _mm256_set1_ps(EXP_POLY_F32[7]);
            for idx in (0..7).rev() {
                p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(EXP_POLY_F32[idx]));
            }
            // 2^n through the exponent bits (n ≥ −126 for x ≥ −87, so the
            // biased exponent stays normal)
            let n32 = _mm256_cvtps_epi32(n);
            let bits = _mm256_slli_epi32::<23>(_mm256_add_epi32(n32, _mm256_set1_epi32(127)));
            let res = _mm256_mul_ps(p, _mm256_castsi256_ps(bits));
            let keep = _mm256_cmp_ps::<_CMP_GE_OQ>(x, _mm256_set1_ps(-87.0));
            _mm256_and_ps(res, keep)
        }
    }

    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    #[inline]
    unsafe fn neg_ps_avx2(v: __m256) -> __m256 {
        // SAFETY: register-only intrinsic; features per the fn contract.
        unsafe { _mm256_xor_ps(v, _mm256_set1_ps(-0.0)) }
    }

    /// Load 8 f32 and widen to two 4 × f64 vectors (conversion is exact).
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU, and that `p..p+8` is in bounds.
    #[target_feature(enable = "avx2,fma")]
    #[inline]
    unsafe fn cvt8_avx2(p: *const f32) -> (__m256d, __m256d) {
        // SAFETY: one 32-byte load at `p` (in bounds per the fn contract);
        // the converts are register-only.
        unsafe {
            let v = _mm256_loadu_ps(p);
            let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(v));
            let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v));
            (lo, hi)
        }
    }

    /// f32-storage dot with f64 accumulators, zip-truncation semantics.
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len().min(b.len());
        // SAFETY: avx2+fma per the fn contract; every load reads at
        // p + lane < n ≤ min(a.len(), b.len()).
        unsafe {
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut acc0 = _mm256_setzero_pd();
            let mut acc1 = _mm256_setzero_pd();
            let mut p = 0;
            while p + 8 <= n {
                let (al, ah) = cvt8_avx2(ap.add(p));
                let (bl, bh) = cvt8_avx2(bp.add(p));
                acc0 = _mm256_fmadd_pd(al, bl, acc0);
                acc1 = _mm256_fmadd_pd(ah, bh, acc1);
                p += 8;
            }
            let mut s = hsum_avx2(_mm256_add_pd(acc0, acc1));
            while p < n {
                s += f64::from(*ap.add(p)) * f64::from(*bp.add(p));
                p += 1;
            }
            s
        }
    }

    /// MR×NR register tile of [`gemm_nn_avx2`]: identical accumulator
    /// layout to the f64 tier; the B panel is f32 and widened on load, the
    /// A broadcasts are widened scalars.
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn kernel_mrxnr_avx2(
        k: usize,
        n: usize,
        j: usize,
        a: &[f32],
        bpack: &[f32],
        c: &mut [f64],
    ) {
        debug_assert!(a.len() >= MR * k && bpack.len() >= k * NR);
        debug_assert!(j + NR <= n && c.len() >= (MR - 1) * n + j + NR);
        // SAFETY: avx2+fma per the fn contract. Loads read a at
        // mi·k + p < MR·k and bpack at p·NR + lane < k·NR; loads/stores on
        // c touch rows mi·n + j .. +NR with j + NR ≤ n and mi < MR — all
        // inside the slices the safe driver carved out (debug-asserted).
        unsafe {
            let ap = a.as_ptr();
            let bp = bpack.as_ptr();
            let mut acc00 = _mm256_setzero_pd();
            let mut acc01 = _mm256_setzero_pd();
            let mut acc10 = _mm256_setzero_pd();
            let mut acc11 = _mm256_setzero_pd();
            let mut acc20 = _mm256_setzero_pd();
            let mut acc21 = _mm256_setzero_pd();
            let mut acc30 = _mm256_setzero_pd();
            let mut acc31 = _mm256_setzero_pd();
            for p in 0..k {
                let (b0, b1) = cvt8_avx2(bp.add(p * NR));
                let a0 = _mm256_set1_pd(f64::from(*ap.add(p)));
                acc00 = _mm256_fmadd_pd(a0, b0, acc00);
                acc01 = _mm256_fmadd_pd(a0, b1, acc01);
                let a1 = _mm256_set1_pd(f64::from(*ap.add(k + p)));
                acc10 = _mm256_fmadd_pd(a1, b0, acc10);
                acc11 = _mm256_fmadd_pd(a1, b1, acc11);
                let a2 = _mm256_set1_pd(f64::from(*ap.add(2 * k + p)));
                acc20 = _mm256_fmadd_pd(a2, b0, acc20);
                acc21 = _mm256_fmadd_pd(a2, b1, acc21);
                let a3 = _mm256_set1_pd(f64::from(*ap.add(3 * k + p)));
                acc30 = _mm256_fmadd_pd(a3, b0, acc30);
                acc31 = _mm256_fmadd_pd(a3, b1, acc31);
            }
            let cp = c.as_mut_ptr();
            let c0 = cp.add(j);
            _mm256_storeu_pd(c0, _mm256_add_pd(_mm256_loadu_pd(c0), acc00));
            let c0h = cp.add(j + 4);
            _mm256_storeu_pd(c0h, _mm256_add_pd(_mm256_loadu_pd(c0h), acc01));
            let c1 = cp.add(n + j);
            _mm256_storeu_pd(c1, _mm256_add_pd(_mm256_loadu_pd(c1), acc10));
            let c1h = cp.add(n + j + 4);
            _mm256_storeu_pd(c1h, _mm256_add_pd(_mm256_loadu_pd(c1h), acc11));
            let c2 = cp.add(2 * n + j);
            _mm256_storeu_pd(c2, _mm256_add_pd(_mm256_loadu_pd(c2), acc20));
            let c2h = cp.add(2 * n + j + 4);
            _mm256_storeu_pd(c2h, _mm256_add_pd(_mm256_loadu_pd(c2h), acc21));
            let c3 = cp.add(3 * n + j);
            _mm256_storeu_pd(c3, _mm256_add_pd(_mm256_loadu_pd(c3), acc30));
            let c3h = cp.add(3 * n + j + 4);
            _mm256_storeu_pd(c3h, _mm256_add_pd(_mm256_loadu_pd(c3h), acc31));
        }
    }

    /// 1×NR edge tile for the row remainder of [`gemm_nn_avx2`].
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn kernel_1xnr_avx2(j: usize, arow: &[f32], bpack: &[f32], crow: &mut [f64]) {
        debug_assert!(bpack.len() >= arow.len() * NR && j + NR <= crow.len());
        // SAFETY: avx2+fma per the fn contract; bpack loads read at
        // p·NR + lane < k·NR and the stores hit crow[j..j+NR] (both
        // debug-asserted, guaranteed by the driver).
        unsafe {
            let bp = bpack.as_ptr();
            let mut acc0 = _mm256_setzero_pd();
            let mut acc1 = _mm256_setzero_pd();
            for (p, &av) in arow.iter().enumerate() {
                let avv = _mm256_set1_pd(f64::from(av));
                let (b0, b1) = cvt8_avx2(bp.add(p * NR));
                acc0 = _mm256_fmadd_pd(avv, b0, acc0);
                acc1 = _mm256_fmadd_pd(avv, b1, acc1);
            }
            let cp = crow.as_mut_ptr().add(j);
            _mm256_storeu_pd(cp, _mm256_add_pd(_mm256_loadu_pd(cp), acc0));
            let cph = cp.add(4);
            _mm256_storeu_pd(cph, _mm256_add_pd(_mm256_loadu_pd(cph), acc1));
        }
    }

    /// Driver for the packed-panel mixed `C += A·B` (same structure as the
    /// f64 drivers: pack an NR-column f32 B panel, sweep MR-row tiles,
    /// shared scalar column tail).
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn gemm_nn_avx2(
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f64],
        pack: &mut [f32],
    ) {
        debug_assert!(a.len() == m * k && b.len() == k * n && c.len() == m * n);
        debug_assert!(n < NR || pack.len() >= k * NR);
        // SAFETY: avx2+fma per the fn contract, forwarded to the tile
        // kernels; the panel slicing matches the (bounds-checked) f64
        // driver exactly.
        unsafe {
            let mut j = 0;
            while j + NR <= n {
                for p in 0..k {
                    pack[p * NR..(p + 1) * NR].copy_from_slice(&b[p * n + j..p * n + j + NR]);
                }
                let mut i = 0;
                while i + MR <= m {
                    let ar = &a[i * k..(i + MR) * k];
                    let cr = &mut c[i * n..(i + MR) * n];
                    kernel_mrxnr_avx2(k, n, j, ar, pack, cr);
                    i += MR;
                }
                while i < m {
                    let ar = &a[i * k..(i + 1) * k];
                    let cr = &mut c[i * n..(i + 1) * n];
                    kernel_1xnr_avx2(j, ar, pack, cr);
                    i += 1;
                }
                j += NR;
            }
            if j < n {
                super::gemm_nn_coltail(m, k, n, j, a, b, c);
            }
        }
    }

    /// Mixed `C += A·Bᵀ`: one f64-accumulated dot per entry, rounded to
    /// f32 once on store (the Gram stage runs at small k = input dim, so
    /// plain row dots are enough here).
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn gemm_nt_avx2(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        debug_assert!(a.len() == m * k && b.len() == n * k && c.len() == m * n);
        // SAFETY: avx2+fma per the fn contract, forwarded to the dot
        // kernel; row slicing is bounds-checked safe code.
        unsafe {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                for j in 0..n {
                    let s = dot_avx2(arow, &b[j * k..(j + 1) * k]);
                    let idx = i * n + j;
                    c[idx] = (f64::from(c[idx]) + s) as f32;
                }
            }
        }
    }

    /// Single rank-1 row update of [`gemm_tn_avx2`] (f32 B row widened on
    /// load, f64 C row).
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn rank1_row_avx2(av: f64, brow: &[f32], crow: &mut [f64]) {
        let n = crow.len();
        debug_assert!(brow.len() == n);
        // SAFETY: avx2+fma per the fn contract; loads/stores run at
        // j + lane < n = crow.len() = brow.len() (debug-asserted).
        unsafe {
            let vv = _mm256_set1_pd(av);
            let bp = brow.as_ptr();
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 4 <= n {
                let bv = _mm256_cvtps_pd(_mm_loadu_ps(bp.add(j)));
                let cv = _mm256_fmadd_pd(vv, bv, _mm256_loadu_pd(cp.add(j)));
                _mm256_storeu_pd(cp.add(j), cv);
                j += 4;
            }
            while j < n {
                crow[j] += av * f64::from(brow[j]);
                j += 1;
            }
        }
    }

    /// Mixed `C += Aᵀ·B`: rank-1 updates with the scalar kernel's
    /// zero-skip (exercised off the hot path; tested like the rest).
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn gemm_tn_avx2(
        p_rows: usize,
        m: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f64],
    ) {
        debug_assert!(a.len() == p_rows * m && b.len() == p_rows * n && c.len() == m * n);
        // SAFETY: avx2+fma per the fn contract, forwarded to the row
        // kernel; row slicing is bounds-checked safe code.
        unsafe {
            for p in 0..p_rows {
                let brow = &b[p * n..(p + 1) * n];
                for i in 0..m {
                    let av = a[p * m + i];
                    if av == 0.0 {
                        continue;
                    }
                    rank1_row_avx2(f64::from(av), brow, &mut c[i * n..(i + 1) * n]);
                }
            }
        }
    }

    /// 8 × f32-lane `row[j] ← s²·ρ(√max(sqi + sq[j] − 2·row[j], 0))` —
    /// twice the lane count of the f64 tier. Lane remainders use the
    /// scalar mixed path (f32 distance, glibc f64 `ρ`).
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn rho_row_avx2(
        fam: RhoFamily,
        outputscale: f64,
        sqi: f32,
        sq: &[f32],
        row: &mut [f32],
    ) {
        let n = row.len();
        debug_assert_eq!(sq.len(), n);
        let n8 = n - n % 8;
        // SAFETY: avx2+fma per the fn contract; loads/stores run at
        // j + lane < n8 ≤ min(sq.len(), row.len()).
        unsafe {
            let sp = sq.as_ptr();
            let rp = row.as_mut_ptr();
            let vsqi = _mm256_set1_ps(sqi);
            let vos = _mm256_set1_ps(outputscale as f32);
            let vm2 = _mm256_set1_ps(-2.0);
            let vzero = _mm256_setzero_ps();
            let vone = _mm256_set1_ps(1.0);
            let mut j = 0;
            while j < n8 {
                let v = _mm256_loadu_ps(rp.add(j));
                let base = _mm256_add_ps(vsqi, _mm256_loadu_ps(sp.add(j)));
                let d2 = _mm256_max_ps(_mm256_fmadd_ps(vm2, v, base), vzero);
                let rho = match fam {
                    RhoFamily::Rbf => exp_ps_avx2(_mm256_mul_ps(_mm256_set1_ps(-0.5), d2)),
                    RhoFamily::Matern12 => exp_ps_avx2(neg_ps_avx2(_mm256_sqrt_ps(d2))),
                    RhoFamily::Matern32 => {
                        let aa = _mm256_sqrt_ps(_mm256_mul_ps(_mm256_set1_ps(3.0), d2));
                        let e = exp_ps_avx2(neg_ps_avx2(aa));
                        _mm256_mul_ps(_mm256_add_ps(vone, aa), e)
                    }
                    RhoFamily::Matern52 => {
                        let aa = _mm256_sqrt_ps(_mm256_mul_ps(_mm256_set1_ps(5.0), d2));
                        let e = exp_ps_avx2(neg_ps_avx2(aa));
                        let lin = _mm256_add_ps(vone, aa);
                        let third = _mm256_set1_ps(1.0 / 3.0);
                        let a2t = _mm256_mul_ps(_mm256_mul_ps(aa, aa), third);
                        _mm256_mul_ps(_mm256_add_ps(lin, a2t), e)
                    }
                };
                _mm256_storeu_ps(rp.add(j), _mm256_mul_ps(vos, rho));
                j += 8;
            }
            for jj in n8..n {
                let d2 = (sqi + sq[jj] - 2.0 * row[jj]).max(0.0);
                row[jj] = (outputscale * fam.rho(f64::from(d2).sqrt())) as f32;
            }
        }
    }

    /// 8 × f32-lane gradient-panel contraction: `ρ`/`dρ` evaluated in f32
    /// lanes, widened once, then accumulated in f64 against the f64
    /// residual column (`lr = (li·s²)·rv[j]`).
    // SAFETY: caller must ensure the avx2 and fma target features are
    // available on the executing CPU.
    #[target_feature(enable = "avx2,fma")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn grad_row_avx2(
        fam: RhoFamily,
        outputscale: f64,
        li: f64,
        sqi: f32,
        sq: &[f32],
        pan: &[f32],
        rv: &[f64],
    ) -> (f64, f64) {
        let n = pan.len();
        debug_assert!(sq.len() == n && rv.len() == n);
        let n8 = n - n % 8;
        let scale = li * outputscale;
        // SAFETY: avx2+fma per the fn contract; all loads run at
        // j + lane < n8 ≤ min(sq.len(), pan.len(), rv.len()).
        unsafe {
            let sp = sq.as_ptr();
            let pp = pan.as_ptr();
            let rvp = rv.as_ptr();
            let vscale = _mm256_set1_pd(scale);
            let vsqi = _mm256_set1_ps(sqi);
            let vm2 = _mm256_set1_ps(-2.0);
            let vzero = _mm256_setzero_ps();
            let vone = _mm256_set1_ps(1.0);
            let mut aell0 = _mm256_setzero_pd();
            let mut aell1 = _mm256_setzero_pd();
            let mut as20 = _mm256_setzero_pd();
            let mut as21 = _mm256_setzero_pd();
            let mut j = 0;
            while j < n8 {
                let x = _mm256_loadu_ps(pp.add(j));
                let base = _mm256_add_ps(vsqi, _mm256_loadu_ps(sp.add(j)));
                let d2 = _mm256_max_ps(_mm256_fmadd_ps(vm2, x, base), vzero);
                // (ρ, dρ/dlogℓ) per family, f32 lanes (dρ formulas match
                // RhoFamily::drho_dlog_ell: Rbf d2·e, M12 a·e, M32 a²·e,
                // M52 (a²/3)(1+a)·e)
                let (rho_ps, drho_ps) = match fam {
                    RhoFamily::Rbf => {
                        let e = exp_ps_avx2(_mm256_mul_ps(_mm256_set1_ps(-0.5), d2));
                        (e, _mm256_mul_ps(d2, e))
                    }
                    RhoFamily::Matern12 => {
                        let aa = _mm256_sqrt_ps(d2);
                        let e = exp_ps_avx2(neg_ps_avx2(aa));
                        (e, _mm256_mul_ps(aa, e))
                    }
                    RhoFamily::Matern32 => {
                        let aa = _mm256_sqrt_ps(_mm256_mul_ps(_mm256_set1_ps(3.0), d2));
                        let e = exp_ps_avx2(neg_ps_avx2(aa));
                        let rho = _mm256_mul_ps(_mm256_add_ps(vone, aa), e);
                        (rho, _mm256_mul_ps(_mm256_mul_ps(aa, aa), e))
                    }
                    RhoFamily::Matern52 => {
                        let aa = _mm256_sqrt_ps(_mm256_mul_ps(_mm256_set1_ps(5.0), d2));
                        let e = exp_ps_avx2(neg_ps_avx2(aa));
                        let lin = _mm256_add_ps(vone, aa);
                        let third = _mm256_set1_ps(1.0 / 3.0);
                        let a2t = _mm256_mul_ps(_mm256_mul_ps(aa, aa), third);
                        let rho = _mm256_mul_ps(_mm256_add_ps(lin, a2t), e);
                        (rho, _mm256_mul_ps(_mm256_mul_ps(a2t, lin), e))
                    }
                };
                let rl = _mm256_cvtps_pd(_mm256_castps256_ps128(rho_ps));
                let rh = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(rho_ps));
                let dl = _mm256_cvtps_pd(_mm256_castps256_ps128(drho_ps));
                let dh = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(drho_ps));
                let lr0 = _mm256_mul_pd(vscale, _mm256_loadu_pd(rvp.add(j)));
                let lr1 = _mm256_mul_pd(vscale, _mm256_loadu_pd(rvp.add(j + 4)));
                aell0 = _mm256_fmadd_pd(lr0, dl, aell0);
                aell1 = _mm256_fmadd_pd(lr1, dh, aell1);
                as20 = _mm256_fmadd_pd(lr0, rl, as20);
                as21 = _mm256_fmadd_pd(lr1, rh, as21);
                j += 8;
            }
            let mut d_ell = hsum_avx2(_mm256_add_pd(aell0, aell1));
            let mut d_s2 = hsum_avx2(_mm256_add_pd(as20, as21));
            for jj in n8..n {
                let rr = f64::from((sqi + sq[jj] - 2.0 * pan[jj]).max(0.0)).sqrt();
                let lr = scale * rv[jj];
                d_ell += lr * fam.drho_dlog_ell(rr);
                d_s2 += lr * fam.rho(rr);
            }
            (d_ell, d_s2)
        }
    }

    // Safe table entries. Every body's `unsafe` discharge is the same:
    // these fns are reachable only through AVX2_MIXED_TABLE, which
    // `table_for` exposes only after `Backend::Avx2.available()` confirmed
    // the avx2 and fma features on this CPU.

    fn gemm_nn_avx2_entry(
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f64],
        pack: &mut [f32],
    ) {
        // SAFETY: avx2+fma verified by `table_for` (see entry-block note).
        unsafe { gemm_nn_avx2(m, k, n, a, b, c, pack) }
    }

    fn gemm_nt_avx2_entry(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        // SAFETY: avx2+fma verified by `table_for` (see entry-block note).
        unsafe { gemm_nt_avx2(m, k, n, a, b, c) }
    }

    fn gemm_tn_avx2_entry(p_rows: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f64]) {
        // SAFETY: avx2+fma verified by `table_for` (see entry-block note).
        unsafe { gemm_tn_avx2(p_rows, m, n, a, b, c) }
    }

    fn dot_avx2_entry(a: &[f32], b: &[f32]) -> f64 {
        // SAFETY: avx2+fma verified by `table_for` (see entry-block note).
        unsafe { dot_avx2(a, b) }
    }

    fn rho_row_avx2_entry(fam: RhoFamily, outputscale: f64, sqi: f32, sq: &[f32], row: &mut [f32]) {
        // SAFETY: avx2+fma verified by `table_for` (see entry-block note).
        unsafe { rho_row_avx2(fam, outputscale, sqi, sq, row) }
    }

    fn grad_row_avx2_entry(
        fam: RhoFamily,
        outputscale: f64,
        li: f64,
        sqi: f32,
        sq: &[f32],
        pan: &[f32],
        rv: &[f64],
    ) -> (f64, f64) {
        // SAFETY: avx2+fma verified by `table_for` (see entry-block note).
        unsafe { grad_row_avx2(fam, outputscale, li, sqi, sq, pan, rv) }
    }

    // ------------------------------------------------------------- AVX-512

    pub(super) static AVX512_MIXED_TABLE: MixedKernelTable = MixedKernelTable {
        backend: Backend::Avx512,
        gemm_nn: gemm_nn_avx512_entry,
        gemm_nt: gemm_nt_avx512_entry,
        gemm_tn: gemm_tn_avx512_entry,
        dot: dot_avx512_entry,
        rho_row: rho_row_avx512_entry,
        grad_row: grad_row_avx512_entry,
    };

    /// Load 8 f32 and widen to one 8 × f64 zmm (exact; the whole NR=8
    /// panel row in one register — the mixed tier's AVX-512 advantage is
    /// halved *loads*, not extra lanes).
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU, and that `p..p+8` is in bounds.
    #[target_feature(enable = "avx512f")]
    #[inline]
    unsafe fn cvt8_avx512(p: *const f32) -> __m512d {
        // SAFETY: one 32-byte load at `p` (in bounds per the fn contract);
        // the convert is register-only.
        unsafe { _mm512_cvtps_pd(_mm256_loadu_ps(p)) }
    }

    /// 8-lane f32-storage dot with f64 accumulators.
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn dot_avx512(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len().min(b.len());
        // SAFETY: avx512f per the fn contract; every load reads at
        // p + lane < n ≤ min(a.len(), b.len()).
        unsafe {
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut acc0 = _mm512_setzero_pd();
            let mut acc1 = _mm512_setzero_pd();
            let mut p = 0;
            while p + 16 <= n {
                acc0 = _mm512_fmadd_pd(cvt8_avx512(ap.add(p)), cvt8_avx512(bp.add(p)), acc0);
                let a1 = cvt8_avx512(ap.add(p + 8));
                let b1 = cvt8_avx512(bp.add(p + 8));
                acc1 = _mm512_fmadd_pd(a1, b1, acc1);
                p += 16;
            }
            if p + 8 <= n {
                acc0 = _mm512_fmadd_pd(cvt8_avx512(ap.add(p)), cvt8_avx512(bp.add(p)), acc0);
                p += 8;
            }
            let mut s = _mm512_reduce_add_pd(_mm512_add_pd(acc0, acc1));
            while p < n {
                s += f64::from(*ap.add(p)) * f64::from(*bp.add(p));
                p += 1;
            }
            s
        }
    }

    /// MR×NR register tile, AVX-512 mixed: one widened zmm per packed B
    /// row, four broadcast-FMA accumulators (mirrors the f64 tile).
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn kernel_mrxnr_avx512(
        k: usize,
        n: usize,
        j: usize,
        a: &[f32],
        bpack: &[f32],
        c: &mut [f64],
    ) {
        debug_assert!(a.len() >= MR * k && bpack.len() >= k * NR);
        debug_assert!(j + NR <= n && c.len() >= (MR - 1) * n + j + NR);
        // SAFETY: avx512f per the fn contract. Loads read a at
        // mi·k + p < MR·k and bpack at p·NR + lane < k·NR; loads/stores on
        // c touch rows mi·n + j .. +NR with j + NR ≤ n and mi < MR — all
        // inside the slices the safe driver carved out (debug-asserted).
        unsafe {
            let ap = a.as_ptr();
            let bp = bpack.as_ptr();
            let mut acc0 = _mm512_setzero_pd();
            let mut acc1 = _mm512_setzero_pd();
            let mut acc2 = _mm512_setzero_pd();
            let mut acc3 = _mm512_setzero_pd();
            for p in 0..k {
                let bv = cvt8_avx512(bp.add(p * NR));
                acc0 = _mm512_fmadd_pd(_mm512_set1_pd(f64::from(*ap.add(p))), bv, acc0);
                acc1 = _mm512_fmadd_pd(_mm512_set1_pd(f64::from(*ap.add(k + p))), bv, acc1);
                acc2 = _mm512_fmadd_pd(_mm512_set1_pd(f64::from(*ap.add(2 * k + p))), bv, acc2);
                acc3 = _mm512_fmadd_pd(_mm512_set1_pd(f64::from(*ap.add(3 * k + p))), bv, acc3);
            }
            let cp = c.as_mut_ptr();
            let c0 = cp.add(j);
            _mm512_storeu_pd(c0, _mm512_add_pd(_mm512_loadu_pd(c0), acc0));
            let c1 = cp.add(n + j);
            _mm512_storeu_pd(c1, _mm512_add_pd(_mm512_loadu_pd(c1), acc1));
            let c2 = cp.add(2 * n + j);
            _mm512_storeu_pd(c2, _mm512_add_pd(_mm512_loadu_pd(c2), acc2));
            let c3 = cp.add(3 * n + j);
            _mm512_storeu_pd(c3, _mm512_add_pd(_mm512_loadu_pd(c3), acc3));
        }
    }

    /// 1×NR edge tile for the row remainder of [`gemm_nn_avx512`].
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn kernel_1xnr_avx512(j: usize, arow: &[f32], bpack: &[f32], crow: &mut [f64]) {
        debug_assert!(bpack.len() >= arow.len() * NR && j + NR <= crow.len());
        // SAFETY: avx512f per the fn contract; bpack loads read at
        // p·NR + lane < k·NR and the store hits crow[j..j+NR] (both
        // debug-asserted, guaranteed by the driver).
        unsafe {
            let bp = bpack.as_ptr();
            let mut acc = _mm512_setzero_pd();
            for (p, &av) in arow.iter().enumerate() {
                let bv = cvt8_avx512(bp.add(p * NR));
                acc = _mm512_fmadd_pd(_mm512_set1_pd(f64::from(av)), bv, acc);
            }
            let cp = crow.as_mut_ptr().add(j);
            _mm512_storeu_pd(cp, _mm512_add_pd(_mm512_loadu_pd(cp), acc));
        }
    }

    /// AVX-512 driver for the packed-panel mixed `C += A·B`.
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn gemm_nn_avx512(
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f64],
        pack: &mut [f32],
    ) {
        debug_assert!(a.len() == m * k && b.len() == k * n && c.len() == m * n);
        debug_assert!(n < NR || pack.len() >= k * NR);
        // SAFETY: avx512f per the fn contract, forwarded to the tile
        // kernels; the panel slicing matches the (bounds-checked) f64
        // driver exactly.
        unsafe {
            let mut j = 0;
            while j + NR <= n {
                for p in 0..k {
                    pack[p * NR..(p + 1) * NR].copy_from_slice(&b[p * n + j..p * n + j + NR]);
                }
                let mut i = 0;
                while i + MR <= m {
                    let ar = &a[i * k..(i + MR) * k];
                    let cr = &mut c[i * n..(i + MR) * n];
                    kernel_mrxnr_avx512(k, n, j, ar, pack, cr);
                    i += MR;
                }
                while i < m {
                    let ar = &a[i * k..(i + 1) * k];
                    let cr = &mut c[i * n..(i + 1) * n];
                    kernel_1xnr_avx512(j, ar, pack, cr);
                    i += 1;
                }
                j += NR;
            }
            if j < n {
                super::gemm_nn_coltail(m, k, n, j, a, b, c);
            }
        }
    }

    /// Mixed `C += A·Bᵀ`, AVX-512 (per-entry f64 dots, one f32 rounding).
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn gemm_nt_avx512(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        debug_assert!(a.len() == m * k && b.len() == n * k && c.len() == m * n);
        // SAFETY: avx512f per the fn contract, forwarded to the dot
        // kernel; row slicing is bounds-checked safe code.
        unsafe {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                for j in 0..n {
                    let s = dot_avx512(arow, &b[j * k..(j + 1) * k]);
                    let idx = i * n + j;
                    c[idx] = (f64::from(c[idx]) + s) as f32;
                }
            }
        }
    }

    /// Single rank-1 row update of [`gemm_tn_avx512`].
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn rank1_row_avx512(av: f64, brow: &[f32], crow: &mut [f64]) {
        let n = crow.len();
        debug_assert!(brow.len() == n);
        // SAFETY: avx512f per the fn contract; loads/stores run at
        // j + lane < n = crow.len() = brow.len() (debug-asserted).
        unsafe {
            let vv = _mm512_set1_pd(av);
            let bp = brow.as_ptr();
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 8 <= n {
                let cv = _mm512_fmadd_pd(vv, cvt8_avx512(bp.add(j)), _mm512_loadu_pd(cp.add(j)));
                _mm512_storeu_pd(cp.add(j), cv);
                j += 8;
            }
            while j < n {
                crow[j] += av * f64::from(brow[j]);
                j += 1;
            }
        }
    }

    /// Mixed `C += Aᵀ·B`, AVX-512 (rank-1 updates, zero-skip preserved).
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn gemm_tn_avx512(
        p_rows: usize,
        m: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f64],
    ) {
        debug_assert!(a.len() == p_rows * m && b.len() == p_rows * n && c.len() == m * n);
        // SAFETY: avx512f per the fn contract, forwarded to the row
        // kernel; row slicing is bounds-checked safe code.
        unsafe {
            for p in 0..p_rows {
                let brow = &b[p * n..(p + 1) * n];
                for i in 0..m {
                    let av = a[p * m + i];
                    if av == 0.0 {
                        continue;
                    }
                    rank1_row_avx512(f64::from(av), brow, &mut c[i * n..(i + 1) * n]);
                }
            }
        }
    }

    /// AVX-512 mixed `rho_row`: widen 8 f32 to f64 lanes, run the f64-lane
    /// family math + vector `exp`, narrow once on store — same lane count
    /// as the f64 tier at half the panel bandwidth.
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    unsafe fn rho_row_avx512(
        fam: RhoFamily,
        outputscale: f64,
        sqi: f32,
        sq: &[f32],
        row: &mut [f32],
    ) {
        let n = row.len();
        debug_assert_eq!(sq.len(), n);
        let n8 = n - n % 8;
        // SAFETY: avx512f per the fn contract; loads/stores run at
        // j + lane < n8 ≤ min(sq.len(), row.len()).
        unsafe {
            let sp = sq.as_ptr();
            let rp = row.as_mut_ptr();
            let vsqi = _mm512_set1_pd(f64::from(sqi));
            let vos = _mm512_set1_pd(outputscale);
            let vm2 = _mm512_set1_pd(-2.0);
            let vzero = _mm512_setzero_pd();
            let vone = _mm512_set1_pd(1.0);
            let mut j = 0;
            while j < n8 {
                let v = cvt8_avx512(rp.add(j));
                let base = _mm512_add_pd(vsqi, cvt8_avx512(sp.add(j)));
                let d2 = _mm512_max_pd(_mm512_fmadd_pd(vm2, v, base), vzero);
                let rho = match fam {
                    RhoFamily::Rbf => exp_avx512(_mm512_mul_pd(_mm512_set1_pd(-0.5), d2)),
                    RhoFamily::Matern12 => exp_avx512(neg_avx512(_mm512_sqrt_pd(d2))),
                    RhoFamily::Matern32 => {
                        let aa = _mm512_sqrt_pd(_mm512_mul_pd(_mm512_set1_pd(3.0), d2));
                        let e = exp_avx512(neg_avx512(aa));
                        _mm512_mul_pd(_mm512_add_pd(vone, aa), e)
                    }
                    RhoFamily::Matern52 => {
                        let aa = _mm512_sqrt_pd(_mm512_mul_pd(_mm512_set1_pd(5.0), d2));
                        let e = exp_avx512(neg_avx512(aa));
                        let lin = _mm512_add_pd(vone, aa);
                        let third = _mm512_set1_pd(1.0 / 3.0);
                        let a2t = _mm512_mul_pd(_mm512_mul_pd(aa, aa), third);
                        _mm512_mul_pd(_mm512_add_pd(lin, a2t), e)
                    }
                };
                _mm256_storeu_ps(rp.add(j), _mm512_cvtpd_ps(_mm512_mul_pd(vos, rho)));
                j += 8;
            }
            for jj in n8..n {
                let d2 = (sqi + sq[jj] - 2.0 * row[jj]).max(0.0);
                row[jj] = (outputscale * fam.rho(f64::from(d2).sqrt())) as f32;
            }
        }
    }

    /// AVX-512 mixed gradient-panel contraction (widened f64 lanes, f64
    /// accumulators against the f64 residual column).
    // SAFETY: caller must ensure the avx512f target feature is available
    // on the executing CPU.
    #[target_feature(enable = "avx512f")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn grad_row_avx512(
        fam: RhoFamily,
        outputscale: f64,
        li: f64,
        sqi: f32,
        sq: &[f32],
        pan: &[f32],
        rv: &[f64],
    ) -> (f64, f64) {
        let n = pan.len();
        debug_assert!(sq.len() == n && rv.len() == n);
        let n8 = n - n % 8;
        let scale = li * outputscale;
        // SAFETY: avx512f per the fn contract; all loads run at
        // j + lane < n8 ≤ min(sq.len(), pan.len(), rv.len()).
        unsafe {
            let sp = sq.as_ptr();
            let pp = pan.as_ptr();
            let rvp = rv.as_ptr();
            let vscale = _mm512_set1_pd(scale);
            let vsqi = _mm512_set1_pd(f64::from(sqi));
            let vm2 = _mm512_set1_pd(-2.0);
            let vzero = _mm512_setzero_pd();
            let vone = _mm512_set1_pd(1.0);
            let mut aell = _mm512_setzero_pd();
            let mut as2 = _mm512_setzero_pd();
            let mut j = 0;
            while j < n8 {
                let x = cvt8_avx512(pp.add(j));
                let base = _mm512_add_pd(vsqi, cvt8_avx512(sp.add(j)));
                let d2 = _mm512_max_pd(_mm512_fmadd_pd(vm2, x, base), vzero);
                let (rho, drho) = match fam {
                    RhoFamily::Rbf => {
                        let e = exp_avx512(_mm512_mul_pd(_mm512_set1_pd(-0.5), d2));
                        (e, _mm512_mul_pd(d2, e))
                    }
                    RhoFamily::Matern12 => {
                        let aa = _mm512_sqrt_pd(d2);
                        let e = exp_avx512(neg_avx512(aa));
                        (e, _mm512_mul_pd(aa, e))
                    }
                    RhoFamily::Matern32 => {
                        let aa = _mm512_sqrt_pd(_mm512_mul_pd(_mm512_set1_pd(3.0), d2));
                        let e = exp_avx512(neg_avx512(aa));
                        let rho = _mm512_mul_pd(_mm512_add_pd(vone, aa), e);
                        (rho, _mm512_mul_pd(_mm512_mul_pd(aa, aa), e))
                    }
                    RhoFamily::Matern52 => {
                        let aa = _mm512_sqrt_pd(_mm512_mul_pd(_mm512_set1_pd(5.0), d2));
                        let e = exp_avx512(neg_avx512(aa));
                        let lin = _mm512_add_pd(vone, aa);
                        let third = _mm512_set1_pd(1.0 / 3.0);
                        let a2t = _mm512_mul_pd(_mm512_mul_pd(aa, aa), third);
                        let rho = _mm512_mul_pd(_mm512_add_pd(lin, a2t), e);
                        (rho, _mm512_mul_pd(_mm512_mul_pd(a2t, lin), e))
                    }
                };
                let lr = _mm512_mul_pd(vscale, _mm512_loadu_pd(rvp.add(j)));
                aell = _mm512_fmadd_pd(lr, drho, aell);
                as2 = _mm512_fmadd_pd(lr, rho, as2);
                j += 8;
            }
            let mut d_ell = _mm512_reduce_add_pd(aell);
            let mut d_s2 = _mm512_reduce_add_pd(as2);
            for jj in n8..n {
                let rr = f64::from((sqi + sq[jj] - 2.0 * pan[jj]).max(0.0)).sqrt();
                let lr = scale * rv[jj];
                d_ell += lr * fam.drho_dlog_ell(rr);
                d_s2 += lr * fam.rho(rr);
            }
            (d_ell, d_s2)
        }
    }

    // Safe table entries — reachable only through AVX512_MIXED_TABLE,
    // which `table_for` exposes only after `Backend::Avx512.available()`
    // confirmed the avx512f feature on this CPU.

    fn gemm_nn_avx512_entry(
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f64],
        pack: &mut [f32],
    ) {
        // SAFETY: avx512f verified by `table_for` (see entry-block note).
        unsafe { gemm_nn_avx512(m, k, n, a, b, c, pack) }
    }

    fn gemm_nt_avx512_entry(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        // SAFETY: avx512f verified by `table_for` (see entry-block note).
        unsafe { gemm_nt_avx512(m, k, n, a, b, c) }
    }

    fn gemm_tn_avx512_entry(
        p_rows: usize,
        m: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f64],
    ) {
        // SAFETY: avx512f verified by `table_for` (see entry-block note).
        unsafe { gemm_tn_avx512(p_rows, m, n, a, b, c) }
    }

    fn dot_avx512_entry(a: &[f32], b: &[f32]) -> f64 {
        // SAFETY: avx512f verified by `table_for` (see entry-block note).
        unsafe { dot_avx512(a, b) }
    }

    fn rho_row_avx512_entry(
        fam: RhoFamily,
        outputscale: f64,
        sqi: f32,
        sq: &[f32],
        row: &mut [f32],
    ) {
        // SAFETY: avx512f verified by `table_for` (see entry-block note).
        unsafe { rho_row_avx512(fam, outputscale, sqi, sq, row) }
    }

    fn grad_row_avx512_entry(
        fam: RhoFamily,
        outputscale: f64,
        li: f64,
        sqi: f32,
        sq: &[f32],
        pan: &[f32],
        rv: &[f64],
    ) -> (f64, f64) {
        // SAFETY: avx512f verified by `table_for` (see entry-block note).
        unsafe { grad_row_avx512(fam, outputscale, li, sqi, sq, pan, rv) }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON/AdvSIMD mixed-precision kernels (2 × f64 lanes over widened
    //! 4 × f32 loads). Same safety convention as the x86 module; NEON is
    //! baseline on `aarch64`, so the availability gate is unconditional
    //! there.

    use super::super::gemm::{MR, NR};
    use super::super::simd::neon::exp_neon;
    use super::{Backend, MixedKernelTable, RhoFamily};
    use core::arch::aarch64::*;

    pub(super) static NEON_MIXED_TABLE: MixedKernelTable = MixedKernelTable {
        backend: Backend::Neon,
        gemm_nn: gemm_nn_neon_entry,
        gemm_nt: gemm_nt_neon_entry,
        gemm_tn: gemm_tn_neon_entry,
        dot: dot_neon_entry,
        rho_row: rho_row_neon_entry,
        grad_row: grad_row_neon_entry,
    };

    /// Load 4 f32 and widen to two 2 × f64 vectors (exact).
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU, and that `p..p+4` is in bounds.
    #[target_feature(enable = "neon")]
    #[inline]
    unsafe fn cvt4_neon(p: *const f32) -> (float64x2_t, float64x2_t) {
        // SAFETY: one 16-byte load at `p` (in bounds per the fn contract);
        // the converts are register-only.
        unsafe {
            let v = vld1q_f32(p);
            (vcvt_f64_f32(vget_low_f32(v)), vcvt_high_f64_f32(v))
        }
    }

    /// `(ρ, dρ/dlogℓ)` on two f64 lanes (shared by the `rho_row` /
    /// `grad_row` halves; formulas match `RhoFamily`).
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    #[inline]
    unsafe fn rho_drho_neon(fam: RhoFamily, d2: float64x2_t) -> (float64x2_t, float64x2_t) {
        // SAFETY: register-only intrinsics; neon per the fn contract.
        unsafe {
            let vone = vdupq_n_f64(1.0);
            match fam {
                RhoFamily::Rbf => {
                    let e = exp_neon(vmulq_f64(vdupq_n_f64(-0.5), d2));
                    (e, vmulq_f64(d2, e))
                }
                RhoFamily::Matern12 => {
                    let aa = vsqrtq_f64(d2);
                    let e = exp_neon(vnegq_f64(aa));
                    (e, vmulq_f64(aa, e))
                }
                RhoFamily::Matern32 => {
                    let aa = vsqrtq_f64(vmulq_f64(vdupq_n_f64(3.0), d2));
                    let e = exp_neon(vnegq_f64(aa));
                    (vmulq_f64(vaddq_f64(vone, aa), e), vmulq_f64(vmulq_f64(aa, aa), e))
                }
                RhoFamily::Matern52 => {
                    let aa = vsqrtq_f64(vmulq_f64(vdupq_n_f64(5.0), d2));
                    let e = exp_neon(vnegq_f64(aa));
                    let lin = vaddq_f64(vone, aa);
                    let a2t = vmulq_f64(vmulq_f64(aa, aa), vdupq_n_f64(1.0 / 3.0));
                    let rho = vmulq_f64(vaddq_f64(lin, a2t), e);
                    (rho, vmulq_f64(vmulq_f64(a2t, lin), e))
                }
            }
        }
    }

    /// f32-storage dot with f64 accumulators, zip-truncation semantics.
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len().min(b.len());
        // SAFETY: neon per the fn contract; every load reads at
        // p + lane < n ≤ min(a.len(), b.len()).
        unsafe {
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut acc0 = vdupq_n_f64(0.0);
            let mut acc1 = vdupq_n_f64(0.0);
            let mut p = 0;
            while p + 4 <= n {
                let (al, ah) = cvt4_neon(ap.add(p));
                let (bl, bh) = cvt4_neon(bp.add(p));
                acc0 = vfmaq_f64(acc0, al, bl);
                acc1 = vfmaq_f64(acc1, ah, bh);
                p += 4;
            }
            let mut s = vaddvq_f64(vaddq_f64(acc0, acc1));
            while p < n {
                s += f64::from(*ap.add(p)) * f64::from(*bp.add(p));
                p += 1;
            }
            s
        }
    }

    /// MR×NR register tile (widened f32 B panel, f64 accumulators).
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn kernel_mrxnr_neon(
        k: usize,
        n: usize,
        j: usize,
        a: &[f32],
        bpack: &[f32],
        c: &mut [f64],
    ) {
        debug_assert!(a.len() >= MR * k && bpack.len() >= k * NR);
        debug_assert!(j + NR <= n && c.len() >= (MR - 1) * n + j + NR);
        // SAFETY: neon per the fn contract. Loads read a at mi·k + p <
        // MR·k and bpack at p·NR + lane < k·NR; loads/stores on c touch
        // rows mi·n + j .. +NR with j + NR ≤ n and mi < MR — all inside
        // the slices the safe driver carved out (debug-asserted).
        unsafe {
            let ap = a.as_ptr();
            let bp = bpack.as_ptr();
            let mut acc = [[vdupq_n_f64(0.0); 4]; MR];
            for p in 0..k {
                let (b0, b1) = cvt4_neon(bp.add(p * NR));
                let (b2, b3) = cvt4_neon(bp.add(p * NR + 4));
                let bv = [b0, b1, b2, b3];
                for (mi, arow) in acc.iter_mut().enumerate() {
                    let av = vdupq_n_f64(f64::from(*ap.add(mi * k + p)));
                    for (t, slot) in arow.iter_mut().enumerate() {
                        *slot = vfmaq_f64(*slot, av, bv[t]);
                    }
                }
            }
            let cp = c.as_mut_ptr();
            for (mi, arow) in acc.iter().enumerate() {
                let cr = cp.add(mi * n + j);
                for (t, slot) in arow.iter().enumerate() {
                    let cv = vaddq_f64(vld1q_f64(cr.add(2 * t)), *slot);
                    vst1q_f64(cr.add(2 * t), cv);
                }
            }
        }
    }

    /// 1×NR edge tile for the row remainder of [`gemm_nn_neon`].
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn kernel_1xnr_neon(j: usize, arow: &[f32], bpack: &[f32], crow: &mut [f64]) {
        debug_assert!(bpack.len() >= arow.len() * NR && j + NR <= crow.len());
        // SAFETY: neon per the fn contract; bpack loads read at
        // p·NR + lane < k·NR and the stores hit crow[j..j+NR] (both
        // debug-asserted, guaranteed by the driver).
        unsafe {
            let bp = bpack.as_ptr();
            let mut acc = [vdupq_n_f64(0.0); 4];
            for (p, &av) in arow.iter().enumerate() {
                let avv = vdupq_n_f64(f64::from(av));
                let (b0, b1) = cvt4_neon(bp.add(p * NR));
                let (b2, b3) = cvt4_neon(bp.add(p * NR + 4));
                let bv = [b0, b1, b2, b3];
                for (t, slot) in acc.iter_mut().enumerate() {
                    *slot = vfmaq_f64(*slot, avv, bv[t]);
                }
            }
            let cp = crow.as_mut_ptr().add(j);
            for (t, slot) in acc.iter().enumerate() {
                let cv = vaddq_f64(vld1q_f64(cp.add(2 * t)), *slot);
                vst1q_f64(cp.add(2 * t), cv);
            }
        }
    }

    /// NEON driver for the packed-panel mixed `C += A·B`.
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn gemm_nn_neon(
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f64],
        pack: &mut [f32],
    ) {
        debug_assert!(a.len() == m * k && b.len() == k * n && c.len() == m * n);
        debug_assert!(n < NR || pack.len() >= k * NR);
        // SAFETY: neon per the fn contract, forwarded to the tile kernels;
        // the panel slicing matches the (bounds-checked) f64 driver.
        unsafe {
            let mut j = 0;
            while j + NR <= n {
                for p in 0..k {
                    pack[p * NR..(p + 1) * NR].copy_from_slice(&b[p * n + j..p * n + j + NR]);
                }
                let mut i = 0;
                while i + MR <= m {
                    let ar = &a[i * k..(i + MR) * k];
                    let cr = &mut c[i * n..(i + MR) * n];
                    kernel_mrxnr_neon(k, n, j, ar, pack, cr);
                    i += MR;
                }
                while i < m {
                    let ar = &a[i * k..(i + 1) * k];
                    let cr = &mut c[i * n..(i + 1) * n];
                    kernel_1xnr_neon(j, ar, pack, cr);
                    i += 1;
                }
                j += NR;
            }
            if j < n {
                super::gemm_nn_coltail(m, k, n, j, a, b, c);
            }
        }
    }

    /// Mixed `C += A·Bᵀ`, NEON (per-entry f64 dots, one f32 rounding).
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn gemm_nt_neon(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        debug_assert!(a.len() == m * k && b.len() == n * k && c.len() == m * n);
        // SAFETY: neon per the fn contract, forwarded to the dot kernel;
        // row slicing is bounds-checked safe code.
        unsafe {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                for j in 0..n {
                    let s = dot_neon(arow, &b[j * k..(j + 1) * k]);
                    let idx = i * n + j;
                    c[idx] = (f64::from(c[idx]) + s) as f32;
                }
            }
        }
    }

    /// Single rank-1 row update of [`gemm_tn_neon`].
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn rank1_row_neon(av: f64, brow: &[f32], crow: &mut [f64]) {
        let n = crow.len();
        debug_assert!(brow.len() == n);
        // SAFETY: neon per the fn contract; loads/stores run at
        // j + lane < n = crow.len() = brow.len() (debug-asserted).
        unsafe {
            let vv = vdupq_n_f64(av);
            let bp = brow.as_ptr();
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 4 <= n {
                let (bl, bh) = cvt4_neon(bp.add(j));
                vst1q_f64(cp.add(j), vfmaq_f64(vld1q_f64(cp.add(j)), vv, bl));
                vst1q_f64(cp.add(j + 2), vfmaq_f64(vld1q_f64(cp.add(j + 2)), vv, bh));
                j += 4;
            }
            while j < n {
                crow[j] += av * f64::from(brow[j]);
                j += 1;
            }
        }
    }

    /// Mixed `C += Aᵀ·B`, NEON (rank-1 updates, zero-skip preserved).
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn gemm_tn_neon(p_rows: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f64]) {
        debug_assert!(a.len() == p_rows * m && b.len() == p_rows * n && c.len() == m * n);
        // SAFETY: neon per the fn contract, forwarded to the row kernel;
        // row slicing is bounds-checked safe code.
        unsafe {
            for p in 0..p_rows {
                let brow = &b[p * n..(p + 1) * n];
                for i in 0..m {
                    let av = a[p * m + i];
                    if av == 0.0 {
                        continue;
                    }
                    rank1_row_neon(f64::from(av), brow, &mut c[i * n..(i + 1) * n]);
                }
            }
        }
    }

    /// NEON mixed `rho_row`: widen 4 f32 to two f64 lane pairs, run the
    /// f64 family math + vector `exp`, narrow once on store.
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    unsafe fn rho_row_neon(
        fam: RhoFamily,
        outputscale: f64,
        sqi: f32,
        sq: &[f32],
        row: &mut [f32],
    ) {
        let n = row.len();
        debug_assert_eq!(sq.len(), n);
        let n4 = n - n % 4;
        // SAFETY: neon per the fn contract; loads/stores run at
        // j + lane < n4 ≤ min(sq.len(), row.len()).
        unsafe {
            let sp = sq.as_ptr();
            let rp = row.as_mut_ptr();
            let vsqi = vdupq_n_f64(f64::from(sqi));
            let vos = vdupq_n_f64(outputscale);
            let vm2 = vdupq_n_f64(-2.0);
            let vzero = vdupq_n_f64(0.0);
            let mut j = 0;
            while j < n4 {
                let (v0, v1) = cvt4_neon(rp.add(j));
                let (s0, s1) = cvt4_neon(sp.add(j));
                let d2l = vmaxq_f64(vfmaq_f64(vaddq_f64(vsqi, s0), vm2, v0), vzero);
                let d2h = vmaxq_f64(vfmaq_f64(vaddq_f64(vsqi, s1), vm2, v1), vzero);
                let (rl, _) = rho_drho_neon(fam, d2l);
                let (rh, _) = rho_drho_neon(fam, d2h);
                let lo = vcvt_f32_f64(vmulq_f64(vos, rl));
                let hi = vcvt_f32_f64(vmulq_f64(vos, rh));
                vst1q_f32(rp.add(j), vcombine_f32(lo, hi));
                j += 4;
            }
            for jj in n4..n {
                let d2 = (sqi + sq[jj] - 2.0 * row[jj]).max(0.0);
                row[jj] = (outputscale * fam.rho(f64::from(d2).sqrt())) as f32;
            }
        }
    }

    /// NEON mixed gradient-panel contraction (widened f64 lanes, f64
    /// accumulators against the f64 residual column).
    // SAFETY: caller must ensure the neon target feature is available on
    // the executing CPU.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn grad_row_neon(
        fam: RhoFamily,
        outputscale: f64,
        li: f64,
        sqi: f32,
        sq: &[f32],
        pan: &[f32],
        rv: &[f64],
    ) -> (f64, f64) {
        let n = pan.len();
        debug_assert!(sq.len() == n && rv.len() == n);
        let n4 = n - n % 4;
        let scale = li * outputscale;
        // SAFETY: neon per the fn contract; all loads run at
        // j + lane < n4 ≤ min(sq.len(), pan.len(), rv.len()).
        unsafe {
            let sp = sq.as_ptr();
            let pp = pan.as_ptr();
            let rvp = rv.as_ptr();
            let vscale = vdupq_n_f64(scale);
            let vsqi = vdupq_n_f64(f64::from(sqi));
            let vm2 = vdupq_n_f64(-2.0);
            let vzero = vdupq_n_f64(0.0);
            let mut aell0 = vdupq_n_f64(0.0);
            let mut aell1 = vdupq_n_f64(0.0);
            let mut as20 = vdupq_n_f64(0.0);
            let mut as21 = vdupq_n_f64(0.0);
            let mut j = 0;
            while j < n4 {
                let (x0, x1) = cvt4_neon(pp.add(j));
                let (s0, s1) = cvt4_neon(sp.add(j));
                let d2l = vmaxq_f64(vfmaq_f64(vaddq_f64(vsqi, s0), vm2, x0), vzero);
                let d2h = vmaxq_f64(vfmaq_f64(vaddq_f64(vsqi, s1), vm2, x1), vzero);
                let (rl, dl) = rho_drho_neon(fam, d2l);
                let (rh, dh) = rho_drho_neon(fam, d2h);
                let lr0 = vmulq_f64(vscale, vld1q_f64(rvp.add(j)));
                let lr1 = vmulq_f64(vscale, vld1q_f64(rvp.add(j + 2)));
                aell0 = vfmaq_f64(aell0, lr0, dl);
                aell1 = vfmaq_f64(aell1, lr1, dh);
                as20 = vfmaq_f64(as20, lr0, rl);
                as21 = vfmaq_f64(as21, lr1, rh);
                j += 4;
            }
            let mut d_ell = vaddvq_f64(vaddq_f64(aell0, aell1));
            let mut d_s2 = vaddvq_f64(vaddq_f64(as20, as21));
            for jj in n4..n {
                let rr = f64::from((sqi + sq[jj] - 2.0 * pan[jj]).max(0.0)).sqrt();
                let lr = scale * rv[jj];
                d_ell += lr * fam.drho_dlog_ell(rr);
                d_s2 += lr * fam.rho(rr);
            }
            (d_ell, d_s2)
        }
    }

    // Safe table entries — reachable only through NEON_MIXED_TABLE, which
    // `table_for` exposes only on aarch64 (NEON is baseline there).

    fn gemm_nn_neon_entry(
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        c: &mut [f64],
        pack: &mut [f32],
    ) {
        // SAFETY: neon verified by `table_for` (baseline on aarch64).
        unsafe { gemm_nn_neon(m, k, n, a, b, c, pack) }
    }

    fn gemm_nt_neon_entry(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        // SAFETY: neon verified by `table_for` (baseline on aarch64).
        unsafe { gemm_nt_neon(m, k, n, a, b, c) }
    }

    fn gemm_tn_neon_entry(p_rows: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f64]) {
        // SAFETY: neon verified by `table_for` (baseline on aarch64).
        unsafe { gemm_tn_neon(p_rows, m, n, a, b, c) }
    }

    fn dot_neon_entry(a: &[f32], b: &[f32]) -> f64 {
        // SAFETY: neon verified by `table_for` (baseline on aarch64).
        unsafe { dot_neon(a, b) }
    }

    fn rho_row_neon_entry(fam: RhoFamily, outputscale: f64, sqi: f32, sq: &[f32], row: &mut [f32]) {
        // SAFETY: neon verified by `table_for` (baseline on aarch64).
        unsafe { rho_row_neon(fam, outputscale, sqi, sq, row) }
    }

    fn grad_row_neon_entry(
        fam: RhoFamily,
        outputscale: f64,
        li: f64,
        sqi: f32,
        sq: &[f32],
        pan: &[f32],
        rv: &[f64],
    ) -> (f64, f64) {
        // SAFETY: neon verified by `table_for` (baseline on aarch64).
        unsafe { grad_row_neon(fam, outputscale, li, sqi, sq, pan, rv) }
    }
}

#[cfg(test)]
mod tests {
    use super::super::simd::{Backend, RhoFamily};
    use super::*;

    /// Deterministic LCG in [-1, 1) — keeps every backend comparison
    /// reproducible without touching the global RNG or process state.
    fn lcg(state: &mut u64) -> f64 {
        *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        ((*state >> 11) as f64 / (1u64 << 53) as f64) * 2.0 - 1.0
    }

    fn fill(v: &mut [f32], state: &mut u64) {
        for x in v.iter_mut() {
            *x = lcg(state) as f32;
        }
    }

    /// Hybrid absolute/relative tolerance, matching the simd.rs tests.
    fn approx(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + b.abs())
    }

    /// Every mixed table the host can actually run (scalar fallback is
    /// exercised separately through the `*_scalar` fns). Deliberately
    /// avoids `set_backend`: the global override is owned by one simd.rs
    /// test, and lib tests run concurrently.
    fn mixed_tables() -> Vec<&'static MixedKernelTable> {
        Backend::all().iter().filter_map(|&b| table_for(b)).collect()
    }

    /// Shapes covering 1×1, exact MR×NR multiples, row/column remainders,
    /// and panel tails on every lane width (4/8/16).
    const SHAPES: &[(usize, usize, usize)] =
        &[(1, 1, 1), (4, 4, 4), (5, 3, 9), (8, 8, 8), (9, 17, 6), (12, 8, 12), (3, 2, 13), (16, 24, 32)];

    const FAMILIES: [RhoFamily; 4] =
        [RhoFamily::Rbf, RhoFamily::Matern12, RhoFamily::Matern32, RhoFamily::Matern52];

    fn naive_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += f64::from(a[i * k + p]) * f64::from(b[p * n + j]);
                }
            }
        }
        c
    }

    #[test]
    fn parse_precision_specs() {
        assert_eq!(parse_precision(""), None);
        assert_eq!(parse_precision("auto"), None);
        assert_eq!(parse_precision("f64"), Some(Precision::F64));
        assert_eq!(parse_precision("F64"), Some(Precision::F64));
        assert_eq!(parse_precision("mixed"), Some(Precision::Mixed(RefineConfig::default())));
        assert_eq!(parse_precision("bogus"), None);
    }

    #[test]
    fn precision_default_is_f64() {
        assert_eq!(Precision::default(), Precision::F64);
        assert!(!Precision::F64.is_mixed());
        assert!(Precision::Mixed(RefineConfig::default()).is_mixed());
        let cfg = RefineConfig::default();
        assert!(cfg.max_sweeps >= 1 && cfg.inner_tol_floor > 0.0 && cfg.stall_ratio < 1.0);
    }

    #[test]
    fn convert_roundtrip_is_exact_for_f32_values() {
        let mut state = 0x5EED_u64;
        let src_f32: Vec<f32> = (0..97).map(|_| lcg(&mut state) as f32).collect();
        let mut wide = vec![0.0f64; src_f32.len()];
        upconvert(&src_f32, &mut wide);
        let mut narrow = vec![0.0f32; src_f32.len()];
        downconvert(&wide, &mut narrow);
        // f32 → f64 → f32 is lossless; only the initial f64 → f32 rounds.
        assert_eq!(narrow, src_f32);
    }

    #[test]
    fn scalar_mixed_gemms_match_naive_oracle() {
        let mut state = 0xA11CE_u64;
        for &(m, k, n) in SHAPES {
            let mut a = vec![0.0f32; m * k];
            let mut b = vec![0.0f32; k * n];
            fill(&mut a, &mut state);
            fill(&mut b, &mut state);
            // nn: f64 accumulation over exact f32 products ⇒ the only
            // divergence from the oracle is summation order (~1e-12).
            let mut c = vec![0.0; m * n];
            gemm_nn_scalar(m, k, n, &a, &b, &mut c);
            let oracle = naive_nn(m, k, n, &a, &b);
            for (got, want) in c.iter().zip(oracle.iter()) {
                assert!(approx(*got, *want, 1e-12), "nn {m}x{k}x{n}: {got} vs {want}");
            }
            // tn: A is k×m (transposed), same accumulation argument.
            let mut at = vec![0.0f32; k * m];
            for i in 0..k {
                for j in 0..m {
                    at[i * m + j] = a[j * k + i];
                }
            }
            let mut ct = vec![0.0; m * n];
            gemm_tn_scalar(k, m, n, &at, &b, &mut ct);
            for (got, want) in ct.iter().zip(oracle.iter()) {
                assert!(approx(*got, *want, 1e-12), "tn {m}x{k}x{n}: {got} vs {want}");
            }
            // nt: output rounds to f32 once, so compare at f32 precision.
            let mut bt = vec![0.0f32; n * k];
            for i in 0..n {
                for j in 0..k {
                    bt[i * k + j] = b[j * n + i];
                }
            }
            let mut cnt = vec![0.0f32; m * n];
            gemm_nt_scalar(m, k, n, &a, &bt, &mut cnt);
            for (got, want) in cnt.iter().zip(oracle.iter()) {
                assert!(approx(f64::from(*got), *want, 1e-6), "nt {m}x{k}x{n}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn scalar_mixed_dot_matches_f64() {
        let mut state = 0xD07_u64;
        for n in [0usize, 1, 3, 4, 7, 8, 15, 64, 129] {
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            fill(&mut a, &mut state);
            fill(&mut b, &mut state);
            let want: f64 =
                a.iter().zip(b.iter()).map(|(&x, &y)| f64::from(x) * f64::from(y)).sum();
            assert!(approx(dot_scalar(&a, &b), want, 1e-12), "dot n={n}");
            // Zip semantics: trailing elements of the longer slice ignored.
            let longer = vec![1.0f32; n + 3];
            assert!(approx(dot_scalar(&a, &longer[..n.min(longer.len())]), dot_scalar(&a, &longer), 1e-15));
        }
    }

    #[test]
    fn dispatched_gemms_match_scalar_mixed() {
        // GEMM/dot kernels do pure f64 accumulation over exact widened
        // products, so backends differ from the scalar-mixed reference
        // only in summation order (1e-12 hybrid); gemm_nt additionally
        // rounds its output to f32 once per entry on each side (1e-6).
        let mut state = 0xBAC_u64;
        for table in mixed_tables() {
            for &(m, k, n) in SHAPES {
                let mut a = vec![0.0f32; m * k];
                let mut b = vec![0.0f32; k * n];
                fill(&mut a, &mut state);
                fill(&mut b, &mut state);

                let mut want = vec![0.0; m * n];
                gemm_nn_scalar(m, k, n, &a, &b, &mut want);
                let mut got = vec![0.0; m * n];
                let mut pack = vec![0.0f32; k * NR];
                (table.gemm_nn)(m, k, n, &a, &b, &mut got, &mut pack);
                for (g, w) in got.iter().zip(want.iter()) {
                    assert!(approx(*g, *w, 1e-12), "{:?} nn {m}x{k}x{n}", table.backend);
                }

                let mut at = vec![0.0f32; k * m];
                for i in 0..k {
                    for j in 0..m {
                        at[i * m + j] = a[j * k + i];
                    }
                }
                let mut got_tn = vec![0.0; m * n];
                (table.gemm_tn)(k, m, n, &at, &b, &mut got_tn);
                for (g, w) in got_tn.iter().zip(want.iter()) {
                    assert!(approx(*g, *w, 1e-12), "{:?} tn {m}x{k}x{n}", table.backend);
                }

                let mut bt = vec![0.0f32; n * k];
                for i in 0..n {
                    for j in 0..k {
                        bt[i * k + j] = b[j * n + i];
                    }
                }
                let mut want_nt = vec![0.0f32; m * n];
                gemm_nt_scalar(m, k, n, &a, &bt, &mut want_nt);
                let mut got_nt = vec![0.0f32; m * n];
                (table.gemm_nt)(m, k, n, &a, &bt, &mut got_nt);
                for (g, w) in got_nt.iter().zip(want_nt.iter()) {
                    assert!(
                        approx(f64::from(*g), f64::from(*w), 1e-6),
                        "{:?} nt {m}x{k}x{n}",
                        table.backend
                    );
                }

                let want_dot = dot_scalar(&a, &b[..a.len().min(b.len())]);
                let got_dot = (table.dot)(&a, &b[..a.len().min(b.len())]);
                assert!(approx(got_dot, want_dot, 1e-12), "{:?} dot", table.backend);
            }
        }
    }

    /// Build an n-point ρ-row problem in f32: squared norms, one panel of
    /// inner products, and a residual column.
    fn rho_inputs(n: usize, state: &mut u64) -> (f32, Vec<f32>, Vec<f32>, Vec<f64>) {
        let d = 3;
        let xi: Vec<f64> = (0..d).map(|_| lcg(state)).collect();
        let sqi = xi.iter().map(|v| v * v).sum::<f64>() as f32;
        let mut sq = vec![0.0f32; n];
        let mut row = vec![0.0f32; n];
        let mut rv = vec![0.0f64; n];
        for j in 0..n {
            let xj: Vec<f64> = (0..d).map(|_| lcg(state)).collect();
            sq[j] = xj.iter().map(|v| v * v).sum::<f64>() as f32;
            row[j] = xi.iter().zip(xj.iter()).map(|(a, b)| a * b).sum::<f64>() as f32;
            rv[j] = lcg(state);
        }
        (sqi, sq, row, rv)
    }

    #[test]
    fn dispatched_rho_row_matches_scalar_mixed_and_f64() {
        // Backend ρ rows use a vector exp (f32 degree-7 on AVX2, widened
        // f64 elsewhere) against the scalar-mixed glibc reference: 2e-5
        // hybrid covers the f32-lane path. Against the pure-f64 oracle
        // the f32 distance inputs dominate: 5e-4 hybrid.
        let mut state = 0x0_5EED_u64;
        for table in mixed_tables() {
            for fam in FAMILIES {
                for n in [1usize, 4, 7, 8, 15, 33, 64] {
                    let (sqi, sq, row0, _) = rho_inputs(n, &mut state);
                    let outputscale = 1.7;

                    let mut want = row0.clone();
                    rho_row_scalar(fam, outputscale, sqi, &sq, &mut want);
                    let mut got = row0.clone();
                    (table.rho_row)(fam, outputscale, sqi, &sq, &mut got);
                    for (g, w) in got.iter().zip(want.iter()) {
                        assert!(
                            approx(f64::from(*g), f64::from(*w), 2e-5),
                            "{:?} {fam:?} rho n={n}: {g} vs {w}",
                            table.backend
                        );
                    }
                    for (j, g) in got.iter().enumerate() {
                        let d2 = (f64::from(sqi) + f64::from(sq[j]) - 2.0 * f64::from(row0[j]))
                            .max(0.0);
                        let oracle = outputscale * fam.rho(d2.sqrt());
                        assert!(
                            approx(f64::from(*g), oracle, 5e-4),
                            "{:?} {fam:?} rho-vs-f64 n={n}",
                            table.backend
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dispatched_grad_row_matches_scalar_mixed_and_f64() {
        // grad_row reduces n f32-derived terms into two f64 sums; the
        // f32 distance error accumulates across terms, hence 5e-4 hybrid
        // for both comparisons.
        let mut state = 0x6_4AD_u64;
        for table in mixed_tables() {
            for fam in FAMILIES {
                for n in [1usize, 4, 7, 8, 15, 33, 64] {
                    let (sqi, sq, pan, rv) = rho_inputs(n, &mut state);
                    let (outputscale, li) = (1.3, 0.8);

                    let (we, ws) = grad_row_scalar(fam, outputscale, li, sqi, &sq, &pan, &rv);
                    let (ge, gs) = (table.grad_row)(fam, outputscale, li, sqi, &sq, &pan, &rv);
                    assert!(approx(ge, we, 5e-4), "{:?} {fam:?} d_ell n={n}", table.backend);
                    assert!(approx(gs, ws, 5e-4), "{:?} {fam:?} d_s2 n={n}", table.backend);

                    let (mut oe, mut os) = (0.0, 0.0);
                    for j in 0..n {
                        let d2 = (f64::from(sqi) + f64::from(sq[j]) - 2.0 * f64::from(pan[j]))
                            .max(0.0);
                        let rr = d2.sqrt();
                        let lr = li * outputscale * rv[j];
                        oe += lr * fam.drho_dlog_ell(rr);
                        os += lr * fam.rho(rr);
                    }
                    assert!(approx(ge, oe, 5e-4), "{:?} {fam:?} d_ell-vs-f64", table.backend);
                    assert!(approx(gs, os, 5e-4), "{:?} {fam:?} d_s2-vs-f64", table.backend);
                }
            }
        }
    }

    #[test]
    fn dispatch_wrappers_fall_back_to_scalar() {
        // The safe wrappers must produce identical results whether or not
        // a SIMD table resolved (scalar path exercised on every host by
        // comparing against the oracle direct calls).
        let mut state = 0xFA11_u64;
        let (m, k, n) = (5, 7, 11);
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        fill(&mut a, &mut state);
        fill(&mut b, &mut state);
        let mut c = vec![0.0; m * n];
        let mut pack = Vec::new();
        gemm_nn(m, k, n, &a, &b, &mut c, &mut pack);
        let mut want = vec![0.0; m * n];
        gemm_nn_scalar(m, k, n, &a, &b, &mut want);
        for (g, w) in c.iter().zip(want.iter()) {
            assert!(approx(*g, *w, 1e-12));
        }
        assert!(approx(dot(&a, &b[..a.len()]), dot_scalar(&a, &b[..a.len()]), 1e-12));
    }
}






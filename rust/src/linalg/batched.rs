//! Strided **batched** GEMM/GEMV entry points for fleets of small
//! operators.
//!
//! The panel micro-kernels in [`crate::linalg::gemm`] were built for one
//! large operand; the batched-dense Newton–Schulz tier
//! (`crate::ciq::dense_sqrt`) instead multiplies *stacks* of small
//! matrices — hundreds of `N ≤ 256` covariance factors per flush. A naive
//! per-element loop would serialize on one core and re-enter the dispatch
//! machinery per element, so the entries here flip the parallel axis:
//! **threads split the batch dimension** (each element's output block is
//! disjoint, so [`parallel_fill`] hands them out with no locking), while
//! each element runs the serial register-tiled kernels (which in turn pick
//! up the runtime-dispatched SIMD variants of [`crate::linalg::simd`] with
//! no changes here — one resolved function-pointer table serves every batch
//! element). B-panel packing happens inside [`gemm_nn`] through its
//! thread-local scratch, which each pool worker reuses across every batch
//! element it claims — the pack cost is paid once per thread, not once per
//! element, and the scratch grows to the largest `k·NR` the worker has seen
//! across size classes (regression-proved in `tests/alloc_regression.rs`).
//!
//! All entries **accumulate** (`C += A·B`) like the rest of the `gemm`
//! family and allocate nothing: callers own every buffer (typically checked
//! out of a [`crate::linalg::SolveWorkspace`]), so the batched tier keeps
//! the zero-allocation steady-state contract of `rust/DESIGN.md` §4.

use crate::linalg::gemm::gemm_nn;
use crate::util::threadpool::parallel_fill;

/// Batched `C_i += A_i · B_i` over a stack of `batch` independent products:
/// `a` holds `batch` row-major `m×k` matrices contiguously (stride `m·k`),
/// `b` holds `batch` `k×n` matrices (stride `k·n`), `c` holds `batch` `m×n`
/// accumulators (stride `m·n`). Parallelized across the batch dimension on
/// the persistent chunk pool; each element runs the serial panel kernels.
pub fn gemm_nn_batched(
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
) {
    assert_eq!(a.len(), batch * m * k, "gemm_nn_batched: A stack size");
    assert_eq!(b.len(), batch * k * n, "gemm_nn_batched: B stack size");
    assert_eq!(c.len(), batch * m * n, "gemm_nn_batched: C stack size");
    if batch == 0 || m == 0 || n == 0 {
        return;
    }
    let (sa, sb, sc) = (m * k, k * n, m * n);
    parallel_fill(c, sc, |start, block| {
        let i = start / sc;
        gemm_nn(m, k, n, &a[i * sa..(i + 1) * sa], &b[i * sb..(i + 1) * sb], block);
    });
}

/// Batched `y_i += M_i · x_i` over a stack of `batch` square `n×n` matrices
/// (stride `n·n`) and `batch` length-`n` vectors (stride `n`): the
/// steady-state *apply* of the batched-dense tier — one call turns a whole
/// flush of cached-factor requests into GEMV work split across the pool.
pub fn gemv_nn_batched(batch: usize, n: usize, mats: &[f64], xs: &[f64], ys: &mut [f64]) {
    assert_eq!(mats.len(), batch * n * n, "gemv_nn_batched: matrix stack size");
    assert_eq!(xs.len(), batch * n, "gemv_nn_batched: x stack size");
    assert_eq!(ys.len(), batch * n, "gemv_nn_batched: y stack size");
    if batch == 0 || n == 0 {
        return;
    }
    parallel_fill(ys, n, |start, block| {
        let i = start / n;
        let m = &mats[i * n * n..(i + 1) * n * n];
        let x = &xs[i * n..(i + 1) * n];
        gemv_serial(n, m, x, block);
    });
}

/// Gather variant of [`gemv_nn_batched`]: element `i` multiplies by
/// `mats[i]` (a borrowed `n×n` matrix that need not be contiguous with its
/// neighbors). The coordinator's size-class flush uses this to apply each
/// request's *own* cached operator factor in one batched call, even though
/// the factors live in per-operator caches.
pub fn gemv_gather(n: usize, mats: &[&[f64]], xs: &[f64], ys: &mut [f64]) {
    let batch = mats.len();
    assert_eq!(xs.len(), batch * n, "gemv_gather: x stack size");
    assert_eq!(ys.len(), batch * n, "gemv_gather: y stack size");
    if batch == 0 || n == 0 {
        return;
    }
    for m in mats {
        assert_eq!(m.len(), n * n, "gemv_gather: matrix size");
    }
    parallel_fill(ys, n, |start, block| {
        let i = start / n;
        gemv_serial(n, mats[i], &xs[i * n..(i + 1) * n], block);
    });
}

/// Serial `y += M·x` on one row-major `n×n` element (unrolled dot per row
/// via the shared kernel helper).
fn gemv_serial(n: usize, m: &[f64], x: &[f64], y: &mut [f64]) {
    for (r, yr) in y.iter_mut().enumerate() {
        *yr += crate::linalg::gemm::dot_unrolled(&m[r * n..(r + 1) * n], x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::rng::Pcg64;

    fn stack(rng: &mut Pcg64, batch: usize, rows: usize, cols: usize) -> Vec<f64> {
        (0..batch * rows * cols).map(|_| rng.normal()).collect()
    }

    #[test]
    fn batched_gemm_matches_per_element_matmul() {
        let (batch, m, k, n) = (7, 5, 9, 6);
        let mut rng = Pcg64::seeded(11);
        let a = stack(&mut rng, batch, m, k);
        let b = stack(&mut rng, batch, k, n);
        let mut c = vec![0.0; batch * m * n];
        // seed C with junk to prove accumulation semantics
        for (i, v) in c.iter_mut().enumerate() {
            *v = (i % 3) as f64;
        }
        let seed = c.clone();
        gemm_nn_batched(batch, m, k, n, &a, &b, &mut c);
        for i in 0..batch {
            let am = Matrix::from_vec(m, k, a[i * m * k..(i + 1) * m * k].to_vec());
            let bm = Matrix::from_vec(k, n, b[i * k * n..(i + 1) * k * n].to_vec());
            let exact = am.matmul(&bm);
            for r in 0..m {
                for cidx in 0..n {
                    let got = c[i * m * n + r * n + cidx];
                    let want = seed[i * m * n + r * n + cidx] + exact[(r, cidx)];
                    assert!(
                        (got - want).abs() < 1e-12,
                        "element {i} ({r},{cidx}): {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn batched_gemv_matches_matrix_matvec() {
        let (batch, n) = (9, 13);
        let mut rng = Pcg64::seeded(12);
        let mats = stack(&mut rng, batch, n, n);
        let xs = stack(&mut rng, batch, n, 1);
        let mut ys = vec![0.0; batch * n];
        gemv_nn_batched(batch, n, &mats, &xs, &mut ys);
        let refs: Vec<&[f64]> = (0..batch).map(|i| &mats[i * n * n..(i + 1) * n * n]).collect();
        let mut ys2 = vec![0.0; batch * n];
        gemv_gather(n, &refs, &xs, &mut ys2);
        for i in 0..batch {
            let m = Matrix::from_vec(n, n, mats[i * n * n..(i + 1) * n * n].to_vec());
            let want = m.matvec(&xs[i * n..(i + 1) * n]);
            for r in 0..n {
                assert!((ys[i * n + r] - want[r]).abs() < 1e-12, "strided gemv element {i}");
                assert!((ys2[i * n + r] - want[r]).abs() < 1e-12, "gather gemv element {i}");
            }
        }
    }

    #[test]
    fn empty_batch_and_degenerate_dims_are_noops() {
        gemm_nn_batched(0, 4, 4, 4, &[], &[], &mut []);
        gemv_nn_batched(0, 4, &[], &[], &mut []);
        gemv_gather(4, &[], &[], &mut []);
        let mut c = vec![1.0; 0];
        gemm_nn_batched(3, 0, 5, 0, &[], &vec![0.0; 0], &mut c);
    }
}

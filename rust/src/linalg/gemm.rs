//! Register-blocked GEMM micro-kernels on contiguous row-major panels.
//!
//! This is the single inner-loop engine shared by [`super::Matrix::matmul`],
//! the kernel operator's panel MVM
//! ([`crate::operators::KernelOp`]), and the transpose products on the
//! Lanczos/msMINRES reorthogonalization path. Three layouts cover every
//! caller:
//!
//! * [`gemm_nn`]: `C += A·B` — packed `NR`-column B panels, a 4×8
//!   register-tile inner kernel whose hot loop is `chunks_exact`-shaped so
//!   it auto-vectorizes.
//! * [`gemm_nt`]: `C += A·Bᵀ` — both operands row-major, the reduction runs
//!   along contiguous rows (the Gram-panel case `X_i · X_jᵀ`).
//! * [`gemm_tn`]: `C += Aᵀ·B` — 4-way unrolled rank-1 updates with
//!   contiguous inner loops (the `VᵀW` reorthogonalization case).
//!
//! All kernels *accumulate* into `C` (callers zero it when they need a plain
//! product) and are pure serial building blocks (threading lives in the
//! callers, over disjoint output panels).
//!
//! Each public entry point dispatches through [`super::simd::table`]: when a
//! runtime-detected SIMD backend is active, the call forwards to the
//! explicit `core::arch` variant of the same layout; otherwise (scalar
//! backend, or no SIMD support compiled/detected) the safe `*_scalar`
//! kernels below run — they are the always-compiled fallback *and* the
//! oracle the SIMD property tests compare against, and with
//! `CIQ_SIMD=scalar` their results are bit-identical to the pre-dispatch
//! code. This file itself stays `unsafe`-free; all intrinsics live in
//! [`super::simd`].

use super::simd;

/// Register-tile rows of the [`gemm_nn`] micro-kernel.
pub const MR: usize = 4;
/// Register-tile columns of the [`gemm_nn`] micro-kernel.
pub const NR: usize = 8;

/// Dot product: dispatches to the active SIMD backend, falling back to the
/// 4-way unrolled `chunks_exact`-vectorizable scalar loop.
#[inline]
pub fn dot_unrolled(a: &[f64], b: &[f64]) -> f64 {
    if let Some(t) = simd::table() {
        return (t.dot)(a, b);
    }
    dot_scalar(a, b)
}

/// The scalar dot kernel (pre-dispatch `dot_unrolled` body, bit-identical).
#[inline]
pub(crate) fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let ra = ca.remainder();
    let rb = cb.remainder();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for (x, y) in ca.zip(cb) {
        s0 += x[0] * y[0];
        s1 += x[1] * y[1];
        s2 += x[2] * y[2];
        s3 += x[3] * y[3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for (x, y) in ra.iter().zip(rb) {
        s += x * y;
    }
    s
}

std::thread_local! {
    // Per-thread B-panel pack scratch for [`gemm_nn`]: grows to the largest
    // k·NR this thread has seen, then every later call is allocation-free —
    // part of the zero-allocation steady-state contract of the solve stack
    // (regression-proved across size classes in tests/alloc_regression.rs).
    // Deliberately retained for the thread's lifetime (8·k_max·NR bytes per
    // pool worker): the pre-thread-local code allocated this buffer on
    // *every* call, so retention trades a small, bounded per-thread floor
    // for the removal of per-call heap traffic.
    static PACK: std::cell::RefCell<Vec<f64>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Current length of this thread's [`gemm_nn`] pack scratch — observability
/// for the growth-bound regression tests (the documented contract: grows to
/// the largest `k·NR` seen on this thread, never shrinks, never exceeds it).
pub fn thread_pack_len() -> usize {
    PACK.with(|p| p.borrow().len())
}

/// `C += A · B` with `A: m×k`, `B: k×n`, `C: m×n`, all contiguous
/// row-major. B is packed one `NR`-column panel at a time so the micro-
/// kernel streams it from a dense buffer (a reused thread-local, so warm
/// calls never touch the heap).
pub fn gemm_nn(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    PACK.with(|p| gemm_nn_with_pack(m, k, n, a, b, c, &mut p.borrow_mut()));
}

/// [`gemm_nn`] with a caller-owned pack scratch buffer (resized as needed),
/// so tight per-tile loops — the kernel operator calls this once per
/// `(row-block, j-tile)` — don't pay a heap allocation per call.
pub fn gemm_nn_with_pack(
    m: usize,
    k: usize,
    n: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    pack: &mut Vec<f64>,
) {
    assert_eq!(a.len(), m * k, "gemm_nn: A buffer size");
    assert_eq!(b.len(), k * n, "gemm_nn: B buffer size");
    assert_eq!(c.len(), m * n, "gemm_nn: C buffer size");
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    // pack buffer only needed when at least one full NR panel exists
    if n >= NR && pack.len() < k * NR {
        pack.resize(k * NR, 0.0);
    }
    if let Some(t) = simd::table() {
        return (t.gemm_nn)(m, k, n, a, b, c, pack);
    }
    gemm_nn_scalar(m, k, n, a, b, c, pack);
}

/// The scalar [`gemm_nn`] driver (pre-dispatch body, bit-identical).
/// Preconditions (validated by [`gemm_nn_with_pack`]): buffer sizes match,
/// no zero dimension, `pack.len() ≥ k·NR` whenever `n ≥ NR`.
pub(crate) fn gemm_nn_scalar(
    m: usize,
    k: usize,
    n: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    bpack: &mut [f64],
) {
    let mut j = 0;
    while j + NR <= n {
        // pack the B panel: k rows × NR contiguous columns
        for p in 0..k {
            bpack[p * NR..(p + 1) * NR].copy_from_slice(&b[p * n + j..p * n + j + NR]);
        }
        let mut i = 0;
        while i + MR <= m {
            kernel_mrxnr(k, n, j, &a[i * k..(i + MR) * k], bpack, &mut c[i * n..(i + MR) * n]);
            i += MR;
        }
        while i < m {
            kernel_1xnr(n, j, &a[i * k..(i + 1) * k], bpack, &mut c[i * n..(i + 1) * n]);
            i += 1;
        }
        j += NR;
    }
    if j < n {
        gemm_nn_coltail(m, k, n, j, a, b, c);
    }
}

/// Column tail of [`gemm_nn`]: plain rank-1 accumulation over the `< NR`
/// columns right of `j`. Shared by the scalar driver and every SIMD driver
/// (the tail is too narrow for a packed panel either way).
pub(crate) fn gemm_nn_coltail(
    m: usize,
    k: usize,
    n: usize,
    j: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for jj in j..n {
                crow[jj] += av * brow[jj];
            }
        }
    }
}

/// MR×NR register tile: `C[0..MR][j..j+NR] += A-rows · packed-B-panel`.
#[inline]
fn kernel_mrxnr(k: usize, n: usize, j: usize, a: &[f64], bpack: &[f64], c: &mut [f64]) {
    let mut acc = [[0.0f64; NR]; MR];
    for p in 0..k {
        let bp = &bpack[p * NR..(p + 1) * NR];
        let a0 = a[p];
        let a1 = a[k + p];
        let a2 = a[2 * k + p];
        let a3 = a[3 * k + p];
        for t in 0..NR {
            let bv = bp[t];
            acc[0][t] += a0 * bv;
            acc[1][t] += a1 * bv;
            acc[2][t] += a2 * bv;
            acc[3][t] += a3 * bv;
        }
    }
    for (mi, accrow) in acc.iter().enumerate() {
        let crow = &mut c[mi * n + j..mi * n + j + NR];
        for t in 0..NR {
            crow[t] += accrow[t];
        }
    }
}

/// 1×NR edge tile for the row remainder of [`gemm_nn`].
#[inline]
fn kernel_1xnr(n: usize, j: usize, arow: &[f64], bpack: &[f64], crow: &mut [f64]) {
    let mut acc = [0.0f64; NR];
    for (p, &av) in arow.iter().enumerate() {
        let bp = &bpack[p * NR..(p + 1) * NR];
        for t in 0..NR {
            acc[t] += av * bp[t];
        }
    }
    let cj = &mut crow[j..j + NR];
    for t in 0..NR {
        cj[t] += acc[t];
    }
}

/// `C += A · Bᵀ` with `A: m×k`, `B: n×k`, `C: m×n`, all contiguous
/// row-major — the reduction axis is the contiguous one for both operands
/// (the Gram-panel layout). 4×4 register tiles of simultaneous dots.
pub fn gemm_nt(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    assert_eq!(a.len(), m * k, "gemm_nt: A buffer size");
    assert_eq!(b.len(), n * k, "gemm_nt: B buffer size");
    assert_eq!(c.len(), m * n, "gemm_nt: C buffer size");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        return;
    }
    if let Some(t) = simd::table() {
        return (t.gemm_nt)(m, k, n, a, b, c);
    }
    gemm_nt_scalar(m, k, n, a, b, c);
}

/// The scalar [`gemm_nt`] driver (pre-dispatch body, bit-identical).
pub(crate) fn gemm_nt_scalar(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    const TB: usize = 4;
    let mut i = 0;
    while i + TB <= m {
        let mut j = 0;
        while j + TB <= n {
            let mut acc = [[0.0f64; TB]; TB];
            for p in 0..k {
                let ar = [a[i * k + p], a[(i + 1) * k + p], a[(i + 2) * k + p], a[(i + 3) * k + p]];
                let br = [b[j * k + p], b[(j + 1) * k + p], b[(j + 2) * k + p], b[(j + 3) * k + p]];
                for (mi, &av) in ar.iter().enumerate() {
                    for (nj, &bv) in br.iter().enumerate() {
                        acc[mi][nj] += av * bv;
                    }
                }
            }
            for (mi, accrow) in acc.iter().enumerate() {
                let crow = &mut c[(i + mi) * n + j..(i + mi) * n + j + TB];
                for (nj, &v) in accrow.iter().enumerate() {
                    crow[nj] += v;
                }
            }
            j += TB;
        }
        while j < n {
            let brow = &b[j * k..(j + 1) * k];
            for mi in 0..TB {
                c[(i + mi) * n + j] += dot_scalar(&a[(i + mi) * k..(i + mi + 1) * k], brow);
            }
            j += 1;
        }
        i += TB;
    }
    while i < m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            c[i * n + j] += dot_scalar(arow, &b[j * k..(j + 1) * k]);
        }
        i += 1;
    }
}

/// `C += Aᵀ · B` with `A: p×m`, `B: p×n`, `C: m×n`, all contiguous
/// row-major, computed as 4-way unrolled rank-1 updates whose inner loops
/// stream contiguous rows of `B` and `C`.
pub fn gemm_tn(p_rows: usize, m: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    assert_eq!(a.len(), p_rows * m, "gemm_tn: A buffer size");
    assert_eq!(b.len(), p_rows * n, "gemm_tn: B buffer size");
    assert_eq!(c.len(), m * n, "gemm_tn: C buffer size");
    if m == 0 || n == 0 {
        return;
    }
    if let Some(t) = simd::table() {
        return (t.gemm_tn)(p_rows, m, n, a, b, c);
    }
    gemm_tn_scalar(p_rows, m, n, a, b, c);
}

/// The scalar [`gemm_tn`] driver (pre-dispatch body, bit-identical).
pub(crate) fn gemm_tn_scalar(
    p_rows: usize,
    m: usize,
    n: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
) {
    let mut p = 0;
    while p + 4 <= p_rows {
        let b0 = &b[p * n..(p + 1) * n];
        let b1 = &b[(p + 1) * n..(p + 2) * n];
        let b2 = &b[(p + 2) * n..(p + 3) * n];
        let b3 = &b[(p + 3) * n..(p + 4) * n];
        for i in 0..m {
            let a0 = a[p * m + i];
            let a1 = a[(p + 1) * m + i];
            let a2 = a[(p + 2) * m + i];
            let a3 = a[(p + 3) * m + i];
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
        }
        p += 4;
    }
    while p < p_rows {
        let bp = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let av = a[p * m + i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * bp[j];
            }
        }
        p += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn randv(n: usize, rng: &mut Pcg64) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    fn naive_nn(m: usize, k: usize, n: usize, a: &[f64], b: &[f64]) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn max_diff(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn gemm_nn_matches_naive_over_shapes() {
        let mut rng = Pcg64::seeded(11);
        for &(m, k, n) in &[
            (1, 1, 1),
            (4, 4, 8),
            (5, 3, 9),
            (7, 16, 8),
            (13, 5, 21),
            (16, 32, 17),
            (33, 7, 1),
            (2, 9, 40),
        ] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let want = naive_nn(m, k, n, &a, &b);
            let mut c = randv(m * n, &mut rng); // nonzero: kernels accumulate
            let base = c.clone();
            gemm_nn(m, k, n, &a, &b, &mut c);
            let want_acc: Vec<f64> = want.iter().zip(&base).map(|(w, b0)| w + b0).collect();
            assert!(max_diff(&c, &want_acc) < 1e-11, "gemm_nn {m}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_nt_matches_naive_over_shapes() {
        let mut rng = Pcg64::seeded(12);
        for &(m, k, n) in &[(1, 1, 1), (4, 4, 4), (5, 3, 9), (9, 17, 6), (12, 8, 12), (3, 2, 13)] {
            let a = randv(m * k, &mut rng);
            let bt = randv(n * k, &mut rng); // B is n×k, used as Bᵀ
            // naive: c[i][j] = dot(a_row_i, b_row_j)
            let mut want = vec![0.0; m * n];
            for i in 0..m {
                for j in 0..n {
                    for p in 0..k {
                        want[i * n + j] += a[i * k + p] * bt[j * k + p];
                    }
                }
            }
            let mut c = vec![0.0; m * n];
            gemm_nt(m, k, n, &a, &bt, &mut c);
            assert!(max_diff(&c, &want) < 1e-11, "gemm_nt {m}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_tn_matches_naive_over_shapes() {
        let mut rng = Pcg64::seeded(13);
        for &(p, m, n) in &[(1, 1, 1), (4, 4, 4), (9, 5, 7), (17, 3, 11), (8, 16, 2), (5, 1, 30)] {
            let a = randv(p * m, &mut rng); // p×m
            let b = randv(p * n, &mut rng); // p×n
            let mut want = vec![0.0; m * n];
            for pp in 0..p {
                for i in 0..m {
                    for j in 0..n {
                        want[i * n + j] += a[pp * m + i] * b[pp * n + j];
                    }
                }
            }
            let mut c = vec![0.0; m * n];
            gemm_tn(p, m, n, &a, &b, &mut c);
            assert!(max_diff(&c, &want) < 1e-11, "gemm_tn {p}x{m}x{n}");
        }
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let mut rng = Pcg64::seeded(14);
        for len in [0usize, 1, 3, 4, 5, 8, 17, 64, 100] {
            let a = randv(len, &mut rng);
            let b = randv(len, &mut rng);
            let want: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot_unrolled(&a, &b) - want).abs() < 1e-11, "len={len}");
        }
    }

    #[test]
    fn zero_dims_are_noops() {
        let mut c = vec![0.0; 0];
        gemm_nn(0, 3, 0, &[], &[0.0; 0], &mut c);
        gemm_nt(0, 2, 0, &[], &[], &mut c);
        gemm_tn(0, 0, 0, &[], &[], &mut c);
        let mut c2 = vec![1.0; 6];
        // k = 0: C must be left untouched
        gemm_nn(2, 0, 3, &[], &[], &mut c2);
        gemm_nt(2, 0, 3, &[], &[], &mut c2);
        assert!(c2.iter().all(|&x| x == 1.0));
    }

    /// The documented thread-local PACK contract: the scratch grows to the
    /// largest `k·NR` this thread has seen and exactly that — never smaller
    /// (which would mean per-call reallocation) and never beyond it. Runs
    /// on a dedicated thread so other tests' gemm calls can't interfere.
    #[test]
    fn thread_pack_grows_to_running_max_k_and_stays() {
        std::thread::spawn(|| {
            assert_eq!(thread_pack_len(), 0);
            let mut max_k = 0usize;
            for &k in &[3usize, 17, 9, 64, 5, 64, 33, 2] {
                max_k = max_k.max(k);
                let a = vec![1.0; 2 * k];
                let b = vec![1.0; k * NR];
                let mut c = vec![0.0; 2 * NR];
                gemm_nn(2, k, NR, &a, &b, &mut c);
                assert_eq!(thread_pack_len(), max_k * NR, "after k={k}");
            }
            // narrow products (n < NR) must not grow the pack at all
            let a = vec![1.0; 2 * 1000];
            let b = vec![1.0; 1000 * 3];
            let mut c = vec![0.0; 2 * 3];
            gemm_nn(2, 1000, 3, &a, &b, &mut c);
            assert_eq!(thread_pack_len(), max_k * NR, "n < NR grew the pack");
        })
        .join()
        .unwrap();
    }
}

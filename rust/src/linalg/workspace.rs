//! `SolveWorkspace` — a growable, checkpointable scratch arena for the
//! solve stack (krylov → ciq → coordinator).
//!
//! The paper's promise is that `K^{±1/2} b` costs ~100 MVMs, so at serving
//! scale the MVM kernel should be the *only* cost — yet a heap-allocating
//! solver puts the allocator on the hot path once per O(N) buffer per solve
//! (Q shift recurrences × several O(N)/O(N·r) buffers for msMINRES alone).
//! The workspace turns that steady-state traffic into buffer *reuse*:
//!
//! * [`SolveWorkspace::take_vec`] / [`SolveWorkspace::take_mat`] /
//!   [`SolveWorkspace::take_usize`] hand out owned, zeroed buffers drawn
//!   from a free list — a fresh heap allocation (**a grow**) happens only
//!   when no pooled buffer is large enough, i.e. during first-touch warm-up
//!   or after a workload-shape change.
//! * [`SolveWorkspace::give_vec`] / [`SolveWorkspace::give_mat`] /
//!   [`SolveWorkspace::give_usize`] return buffers for the next solve.
//!   Matrices and vectors share one `f64` pool (a matrix is checked in as
//!   its backing buffer), so a shrinking block solve can recycle its old
//!   wide panel as the next narrower one.
//! * A warmed workspace running the same solve shape performs **zero** heap
//!   allocations — the property the `alloc_regression` integration tests
//!   pin with a counting global allocator
//!   ([`crate::util::allocs::CountingAllocator`]).
//!
//! Buffers are handed out as plain owned `Vec`/[`Matrix`] values rather
//! than borrows of one slab: the borrow checker then imposes no artificial
//! lifetime coupling between scratch buffers, a leaked buffer degrades to a
//! one-time re-grow instead of unsafety, and the operator layer can take
//! further scratch from the same workspace mid-solve
//! ([`crate::operators::LinearOp::matmat_in`]).
//!
//! ## Checkpoints
//!
//! [`SolveWorkspace::checkpoint`] snapshots the number of outstanding
//! checkouts; [`SolveWorkspace::leaked_since`] reports how many buffers a
//! region failed to give back. Solver entry points use this in debug builds
//! to prove they are leak-free — a leak is not unsafe, but every leaked
//! buffer is a grow (= a heap allocation) on the next identical solve.
//!
//! ## Pools of workspaces
//!
//! [`WorkspacePool`] is the coordinator-facing layer: a lazily-grown set of
//! workspaces checked out per batch flush (at most one per concurrent batch
//! worker) and returned afterwards, with [`WorkspacePool::prune`] dropping
//! pooled buffers when operator churn invalidates the steady-state shapes.
//! [`WorkspacePool::checkin`] drains each workspace's telemetry so
//! `Metrics::workspace_{checkouts,grows,bytes_high_water}` reflect live
//! traffic.

use super::Matrix;
use std::sync::Mutex;

/// Telemetry drained from a workspace by [`SolveWorkspace::drain_stats`]:
/// `checkouts`/`grows` are deltas since the last drain,
/// `bytes_high_water` is the workspace's lifetime peak of owned bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WsStats {
    /// Buffer checkouts since the last drain.
    pub checkouts: u64,
    /// Checkouts that had to heap-allocate since the last drain.
    pub grows: u64,
    /// Peak bytes of buffer capacity this workspace has ever owned.
    pub bytes_high_water: u64,
}

/// Best-fit lookup: index of the smallest pooled buffer with capacity ≥ `n`.
/// Best-fit (rather than first/last-fit) makes the pool's capacity-multiset
/// evolution a function of the request sequence alone, so a warmed workspace
/// replaying an identical solve provably never grows.
fn best_fit<T>(free: &[Vec<T>], n: usize) -> Option<usize> {
    let mut best: Option<(usize, usize)> = None;
    for (i, b) in free.iter().enumerate() {
        let c = b.capacity();
        if c >= n {
            match best {
                Some((_, bc)) if bc <= c => {}
                _ => best = Some((i, c)),
            }
        }
    }
    best.map(|(i, _)| i)
}

/// Snapshot of a workspace's outstanding-checkout count
/// (see [`SolveWorkspace::checkpoint`]).
#[derive(Clone, Copy, Debug)]
pub struct WsCheckpoint {
    outstanding: i64,
}

/// A growable pool of reusable scratch buffers for the solve stack.
#[derive(Default)]
pub struct SolveWorkspace {
    /// Free `f64` buffers (matrices check in/out through here too).
    free: Vec<Vec<f64>>,
    /// Free `f32` buffers — the mixed-precision slabs (downconverted kernel
    /// inputs, f32 Newton–Schulz stacks; see `linalg::mixed`). A separate
    /// pool rather than reinterpreted `f64` storage so the type system, not
    /// a transmute, guarantees no pool ever hands out the wrong element
    /// width.
    free_f32: Vec<Vec<f32>>,
    /// Free `usize` buffers (iteration counters, active-column index lists).
    free_usize: Vec<Vec<usize>>,
    /// Lifetime checkouts.
    checkouts: u64,
    /// Lifetime checkouts that heap-allocated.
    grows: u64,
    /// Counters already reported through [`Self::drain_stats`].
    reported_checkouts: u64,
    reported_grows: u64,
    /// Current / peak bytes of capacity owned (free + checked out).
    bytes_owned: u64,
    bytes_high_water: u64,
    /// Checked-out-minus-returned buffer count (can go negative if a caller
    /// donates an external buffer; only deltas between checkpoints matter).
    outstanding: i64,
}

impl SolveWorkspace {
    /// An empty workspace; every buffer it ever owns comes from growth.
    pub fn new() -> SolveWorkspace {
        SolveWorkspace::default()
    }

    /// Check out a zero-filled `f64` buffer of length `n`. Reuses the
    /// **smallest** pooled buffer whose capacity fits (best-fit: a small
    /// request can never waste a large buffer another take needs, so a
    /// repeated solve's take sequence is satisfiable from exactly the
    /// buffers its first run grew); grows (one heap allocation) only when
    /// none fits.
    pub fn take_vec(&mut self, n: usize) -> Vec<f64> {
        self.checkouts += 1;
        self.outstanding += 1;
        let mut v = match best_fit(&self.free, n) {
            Some(i) => self.free.swap_remove(i),
            None => {
                let v = Vec::with_capacity(n);
                self.grew(v.capacity() as u64 * 8);
                v
            }
        };
        v.clear();
        v.resize(n, 0.0);
        v
    }

    /// Return an `f64` buffer to the pool.
    pub fn give_vec(&mut self, v: Vec<f64>) {
        self.outstanding -= 1;
        self.free.push(v);
    }

    /// Check out a zeroed `rows × cols` matrix backed by the `f64` pool.
    pub fn take_mat(&mut self, rows: usize, cols: usize) -> Matrix {
        let data = self.take_vec(rows * cols);
        Matrix::from_vec(rows, cols, data)
    }

    /// Return a matrix's backing buffer to the `f64` pool.
    pub fn give_mat(&mut self, m: Matrix) {
        self.give_vec(m.into_vec());
    }

    /// Check out a zero-filled `f32` buffer of length `n` (best-fit, like
    /// [`Self::take_vec`]). The mixed-precision tier draws its downconverted
    /// slabs and refinement scratch from here, so a warmed mixed solve is as
    /// allocation-free as an f64 one.
    pub fn take_f32(&mut self, n: usize) -> Vec<f32> {
        self.checkouts += 1;
        self.outstanding += 1;
        let mut v = match best_fit(&self.free_f32, n) {
            Some(i) => self.free_f32.swap_remove(i),
            None => {
                let v = Vec::with_capacity(n);
                self.grew(v.capacity() as u64 * 4);
                v
            }
        };
        v.clear();
        v.resize(n, 0.0);
        v
    }

    /// Return an `f32` buffer to the pool.
    pub fn give_f32(&mut self, v: Vec<f32>) {
        self.outstanding -= 1;
        self.free_f32.push(v);
    }

    /// Check out a zero-filled `usize` buffer of length `n` (best-fit, like
    /// [`Self::take_vec`]).
    pub fn take_usize(&mut self, n: usize) -> Vec<usize> {
        self.checkouts += 1;
        self.outstanding += 1;
        let mut v = match best_fit(&self.free_usize, n) {
            Some(i) => self.free_usize.swap_remove(i),
            None => {
                let v = Vec::with_capacity(n);
                self.grew(v.capacity() as u64 * 8);
                v
            }
        };
        v.clear();
        v.resize(n, 0);
        v
    }

    /// Return a `usize` buffer to the pool.
    pub fn give_usize(&mut self, v: Vec<usize>) {
        self.outstanding -= 1;
        self.free_usize.push(v);
    }

    fn grew(&mut self, bytes: u64) {
        self.grows += 1;
        self.bytes_owned += bytes;
        self.bytes_high_water = self.bytes_high_water.max(self.bytes_owned);
    }

    /// Snapshot the outstanding-checkout count.
    pub fn checkpoint(&self) -> WsCheckpoint {
        WsCheckpoint { outstanding: self.outstanding }
    }

    /// Buffers checked out since `cp` that were never given back. Zero for a
    /// leak-free region; each leak costs one grow on the next warm solve.
    pub fn leaked_since(&self, cp: &WsCheckpoint) -> i64 {
        self.outstanding - cp.outstanding
    }

    /// Lifetime checkouts.
    pub fn checkouts(&self) -> u64 {
        self.checkouts
    }

    /// Lifetime checkouts that heap-allocated. A warmed workspace running a
    /// fixed solve shape stops advancing this — the zero-allocation
    /// steady-state invariant.
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// Peak bytes of buffer capacity ever owned.
    pub fn bytes_high_water(&self) -> u64 {
        self.bytes_high_water
    }

    /// Free buffers currently pooled (telemetry / tests).
    pub fn pooled_buffers(&self) -> usize {
        self.free.len() + self.free_f32.len() + self.free_usize.len()
    }

    /// Drop every pooled buffer (outstanding checkouts are unaffected).
    /// The next solves re-grow from scratch — used when the workload shape
    /// changes for good (operator deregistration).
    pub fn clear(&mut self) {
        let freed: u64 = self.free.iter().map(|v| v.capacity() as u64 * 8).sum::<u64>()
            + self.free_f32.iter().map(|v| v.capacity() as u64 * 4).sum::<u64>()
            + self.free_usize.iter().map(|v| v.capacity() as u64 * 8).sum::<u64>();
        self.bytes_owned = self.bytes_owned.saturating_sub(freed);
        self.free.clear();
        self.free_f32.clear();
        self.free_usize.clear();
    }

    /// Drain telemetry: `(checkouts, grows)` as deltas since the previous
    /// drain plus the lifetime `bytes_high_water`.
    pub fn drain_stats(&mut self) -> WsStats {
        let stats = WsStats {
            checkouts: self.checkouts - self.reported_checkouts,
            grows: self.grows - self.reported_grows,
            bytes_high_water: self.bytes_high_water,
        };
        self.reported_checkouts = self.checkouts;
        self.reported_grows = self.grows;
        stats
    }
}

/// A lazily-grown pool of [`SolveWorkspace`]s shared by the coordinator's
/// batch workers: one workspace is checked out per batch flush and returned
/// afterwards, so at most `workers` workspaces ever exist and each worker's
/// steady-state flush runs entirely on warmed buffers.
#[derive(Default)]
pub struct WorkspacePool {
    free: Mutex<Vec<SolveWorkspace>>,
}

impl WorkspacePool {
    /// An empty pool; workspaces are created on first checkout.
    pub fn new() -> WorkspacePool {
        WorkspacePool::default()
    }

    /// Check out a workspace (a pooled one when available, else fresh).
    pub fn checkout(&self) -> SolveWorkspace {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a workspace, draining its telemetry for the caller to record.
    pub fn checkin(&self, mut ws: SolveWorkspace) -> WsStats {
        let stats = ws.drain_stats();
        self.free.lock().unwrap().push(ws);
        stats
    }

    /// Drop the pooled buffers of every idle workspace (checked-out ones are
    /// untouched and return normally). Called on operator deregistration so
    /// workspace scratch sized for a retired operator does not linger. (The
    /// GEMM layer's per-thread pack/panel thread-locals are out of scope:
    /// they are retained for the worker threads' lifetime by design — see
    /// `linalg::gemm` — and are bounded by `8·k_max·NR` bytes per thread.)
    pub fn prune(&self) {
        for ws in self.free.lock().unwrap().iter_mut() {
            ws.clear();
        }
    }

    /// Idle workspaces currently pooled.
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warm_takes_stop_growing() {
        let mut ws = SolveWorkspace::new();
        // warm-up: three distinct sizes
        let a = ws.take_vec(100);
        let b = ws.take_vec(50);
        let m = ws.take_mat(10, 7);
        assert_eq!(ws.grows(), 3);
        ws.give_vec(a);
        ws.give_vec(b);
        ws.give_mat(m);
        // steady state: identical shape, zero growth
        for _ in 0..10 {
            let a = ws.take_vec(100);
            let b = ws.take_vec(50);
            let m = ws.take_mat(10, 7);
            assert!(a.iter().all(|&x| x == 0.0));
            assert_eq!(m.rows(), 10);
            ws.give_vec(a);
            ws.give_vec(b);
            ws.give_mat(m);
        }
        assert_eq!(ws.grows(), 3, "warmed workspace must not re-allocate");
        assert_eq!(ws.checkouts(), 33);
        assert!(ws.bytes_high_water() >= (100 + 50 + 70) * 8);
    }

    #[test]
    fn buffers_are_zeroed_on_reuse() {
        let mut ws = SolveWorkspace::new();
        let mut v = ws.take_vec(8);
        v.iter_mut().for_each(|x| *x = 7.0);
        ws.give_vec(v);
        let v = ws.take_vec(8);
        assert!(v.iter().all(|&x| x == 0.0), "recycled buffer must be zeroed");
        ws.give_vec(v);
        let mut m = ws.take_mat(2, 4);
        m[(1, 3)] = 3.0;
        ws.give_mat(m);
        let m = ws.take_mat(4, 2);
        assert!(m.as_slice().iter().all(|&x| x == 0.0));
        ws.give_mat(m);
    }

    #[test]
    fn smaller_requests_reuse_bigger_buffers() {
        let mut ws = SolveWorkspace::new();
        let big = ws.take_vec(1000);
        ws.give_vec(big);
        let small = ws.take_vec(10);
        assert_eq!(ws.grows(), 1, "a big pooled buffer must serve a smaller request");
        assert_eq!(small.len(), 10);
        ws.give_vec(small);
    }

    #[test]
    fn best_fit_never_wastes_a_big_buffer_on_a_small_request() {
        // Regression for the last-fit policy: a small take must not consume
        // a large pooled buffer that a later take in the same solve needs —
        // that would force a grow on a warmed workspace, whatever the free
        // list's order.
        let mut ws = SolveWorkspace::new();
        let a = ws.take_vec(100);
        let b = ws.take_vec(50);
        ws.give_vec(a); // free order: [100, 50]
        ws.give_vec(b);
        let small = ws.take_vec(40);
        let big = ws.take_vec(80);
        assert_eq!(ws.grows(), 2, "best-fit must serve both takes from the pool");
        // reversed free order: give-back sequence flips the list
        ws.give_vec(big); // free order: [100, 50] again after both returns
        ws.give_vec(small);
        let small = ws.take_vec(40);
        let big = ws.take_vec(80);
        assert_eq!(ws.grows(), 2, "order of the free list must not matter");
        ws.give_vec(small);
        ws.give_vec(big);
    }

    #[test]
    fn checkpoint_detects_leaks() {
        let mut ws = SolveWorkspace::new();
        let cp = ws.checkpoint();
        let a = ws.take_vec(4);
        let b = ws.take_vec(4);
        ws.give_vec(a);
        assert_eq!(ws.leaked_since(&cp), 1);
        ws.give_vec(b);
        assert_eq!(ws.leaked_since(&cp), 0);
    }

    #[test]
    fn f32_pool_is_independent_and_stays_warm() {
        let mut ws = SolveWorkspace::new();
        // an f64 buffer in the pool must never satisfy an f32 take (and
        // vice versa): separate pools, separate element widths
        let v64 = ws.take_vec(64);
        ws.give_vec(v64);
        let mut s = ws.take_f32(64);
        assert_eq!(ws.grows(), 2, "f32 take must not be served from the f64 pool");
        assert!(s.iter().all(|&x| x == 0.0));
        s.iter_mut().for_each(|x| *x = 7.0);
        ws.give_f32(s);
        let s = ws.take_f32(48);
        assert_eq!(ws.grows(), 2, "warmed f32 pool must serve a smaller request");
        assert!(s.iter().all(|&x| x == 0.0), "recycled f32 buffer must be zeroed");
        ws.give_f32(s);
        assert!(ws.bytes_high_water() >= 64 * 8 + 64 * 4);
        ws.clear();
        assert_eq!(ws.pooled_buffers(), 0, "clear must drop the f32 pool too");
    }

    #[test]
    fn usize_pool_is_independent() {
        let mut ws = SolveWorkspace::new();
        let u = ws.take_usize(16);
        assert!(u.iter().all(|&x| x == 0));
        ws.give_usize(u);
        let grows = ws.grows();
        let u = ws.take_usize(16);
        assert_eq!(ws.grows(), grows);
        ws.give_usize(u);
    }

    #[test]
    fn clear_drops_pooled_buffers_and_stats_drain() {
        let mut ws = SolveWorkspace::new();
        let v = ws.take_vec(64);
        ws.give_vec(v);
        assert_eq!(ws.pooled_buffers(), 1);
        let s = ws.drain_stats();
        assert_eq!(s.checkouts, 1);
        assert_eq!(s.grows, 1);
        assert!(s.bytes_high_water >= 64 * 8);
        // second drain reports only the delta
        let s2 = ws.drain_stats();
        assert_eq!(s2.checkouts, 0);
        assert_eq!(s2.grows, 0);
        ws.clear();
        assert_eq!(ws.pooled_buffers(), 0);
        let v = ws.take_vec(64);
        assert_eq!(ws.drain_stats().grows, 1, "cleared workspace must re-grow");
        ws.give_vec(v);
    }

    #[test]
    fn workspace_pool_recycles_and_prunes() {
        let pool = WorkspacePool::new();
        let mut ws = pool.checkout();
        let v = ws.take_vec(32);
        ws.give_vec(v);
        let stats = pool.checkin(ws);
        assert_eq!(stats.checkouts, 1);
        assert_eq!(stats.grows, 1);
        assert_eq!(pool.pooled(), 1);
        // the recycled workspace serves the same shape without growing
        let mut ws = pool.checkout();
        let v = ws.take_vec(32);
        ws.give_vec(v);
        let stats = pool.checkin(ws);
        assert_eq!(stats.checkouts, 1);
        assert_eq!(stats.grows, 0, "pooled workspace must stay warm across checkins");
        pool.prune();
        let mut ws = pool.checkout();
        assert_eq!(ws.pooled_buffers(), 0, "prune must drop pooled buffers");
        let v = ws.take_vec(32);
        ws.give_vec(v);
        assert_eq!(pool.checkin(ws).grows, 1);
    }
}

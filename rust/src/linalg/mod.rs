//! Dense linear algebra, from scratch.
//!
//! Provides everything the reproduction needs without external BLAS/LAPACK:
//! a row-major [`Matrix`] with blocked & threaded GEMM built on the
//! register-blocked panel micro-kernels in [`gemm`] (shared with the kernel
//! operator's panel MVM), strided batched GEMM/GEMV over stacks of small
//! matrices ([`batched`], the engine under the dense Newton–Schulz tier),
//! Cholesky factorization with triangular solves
//! ([`chol`]), a symmetric eigendecomposition (Householder
//! tridiagonalization + implicit-QL, [`eigen`]) used as the *exact*
//! `K^{1/2}` oracle in tests and inside the randomized-SVD baseline, the
//! [`workspace`] buffer pool behind the solve stack's zero-allocation
//! steady state (`rust/DESIGN.md` §4), and the runtime-dispatched SIMD
//! micro-kernel engine ([`simd`], `rust/DESIGN.md` §7) that the [`gemm`]
//! entry points route through on CPUs with AVX2/AVX-512/NEON, plus its
//! mixed-precision tier ([`mixed`], `rust/DESIGN.md` §9): f32-storage /
//! f64-accumulate kernel variants behind the [`mixed::Precision`] solve
//! policy with f64 iterative refinement upstairs.

mod matrix;
pub mod batched;
pub mod chol;
pub mod eigen;
pub mod gemm;
pub mod mixed;
pub mod simd;
pub mod workspace;

pub use chol::Cholesky;
pub use matrix::Matrix;
pub use mixed::{Precision, RefineConfig};
pub use workspace::{SolveWorkspace, WorkspacePool, WsStats};

//! Dense linear algebra, from scratch.
//!
//! Provides everything the reproduction needs without external BLAS/LAPACK:
//! a row-major [`Matrix`] with blocked & threaded GEMM built on the
//! register-blocked panel micro-kernels in [`gemm`] (shared with the kernel
//! operator's panel MVM), Cholesky factorization with triangular solves
//! ([`chol`]), a symmetric eigendecomposition (Householder
//! tridiagonalization + implicit-QL, [`eigen`]) used as the *exact*
//! `K^{1/2}` oracle in tests and inside the randomized-SVD baseline.

mod matrix;
pub mod chol;
pub mod eigen;
pub mod gemm;

pub use chol::Cholesky;
pub use matrix::Matrix;

//! # `ciq` — Fast Matrix Square Roots with msMINRES-CIQ
//!
//! A from-scratch reproduction of *"Fast Matrix Square Roots with Applications
//! to Gaussian Processes and Bayesian Optimization"* (Pleiss, Jankowiak,
//! Eriksson, Damle, Gardner — NeurIPS 2020) as a three-layer Rust + JAX +
//! Pallas stack.
//!
//! The headline operation is computing `K^{1/2} b` (sampling) and
//! `K^{-1/2} b` (whitening) for a symmetric positive-definite operator `K`
//! using only matrix–vector products (MVMs):
//!
//! 1. **Contour Integral Quadrature (CIQ)** expresses `K^{-1/2}` as a short
//!    weighted sum of shifted inverses `Σ_q w_q (t_q I + K)^{-1}` via the
//!    Hale–Higham–Trefethen conformal-map quadrature ([`quadrature`]).
//! 2. **msMINRES** ([`krylov::msminres`]) computes *all* `Q` shifted solves
//!    simultaneously from a single Krylov subspace — `J` MVMs total,
//!    `O(QN)` extra memory.
//! 3. The [`ciq`] module glues the two together (Alg. 1 of the paper), adds
//!    the efficient backward pass (Eq. 3) and single-preconditioner support
//!    (Appx. D).
//!
//! On top of the core algorithm the crate ships every substrate and
//! application the paper evaluates: dense linear algebra ([`linalg`]),
//! kernel/image linear operators with `O(N)`-memory partitioned MVMs
//! ([`operators`]), pivoted-Cholesky preconditioning ([`precond`]),
//! Cholesky/RFF/randomized-SVD baselines ([`baselines`]), exact GPs ([`gp`]),
//! whitened stochastic variational GPs with `O(M²)` natural-gradient updates
//! ([`svgp`]), Thompson-sampling Bayesian optimization ([`bo`]), a Gibbs
//! sampler for image super-resolution ([`gibbs`]), a PJRT runtime that
//! executes AOT-compiled JAX/Pallas artifacts ([`runtime`]), a
//! dependency-free async executor with a hierarchical timer wheel ([`exec`]),
//! a batching sampling-service coordinator ([`coordinator`]) whose
//! dispatcher runs on it, and a flight-recorder observability layer
//! ([`obs`]: lock-free histograms, structured solve traces, exportable
//! service snapshots).
//!
//! ## Quickstart
//!
//! (Compiled but not executed as a doctest: rustdoc's temp binaries do not
//! inherit the workspace rpath to `libxla_extension.so`; the identical flow
//! runs in `examples/quickstart.rs` and the unit tests.)
//!
//! ```no_run
//! use ciq::operators::{DenseOp, LinearOp};
//! use ciq::ciq::{Ciq, CiqOptions};
//! use ciq::rng::Pcg64;
//!
//! // A small random SPD matrix K = A Aᵀ + I.
//! let mut rng = Pcg64::seeded(7);
//! let n = 64;
//! let a = ciq::linalg::Matrix::randn(n, n, &mut rng);
//! let mut k = &a * &a.transpose();
//! for i in 0..n { k[(i, i)] += (n as f64) * 0.5; }
//! let op = DenseOp::new(k);
//!
//! // Draw a sample with covariance K:  y = K^{1/2} eps.
//! let eps: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
//! let solver = Ciq::new(CiqOptions::default());
//! let y = solver.sqrt_mvm(&op, &eps).unwrap().solution;
//! assert_eq!(y.len(), n);
//! ```

// Every `unsafe` operation must sit in an explicit `unsafe {}` block with
// its own `// SAFETY:` justification (enforced structurally by
// `tools/structlint.rs`), even inside `unsafe fn`.
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(unused_unsafe)]

pub mod util;
pub mod obs;
pub mod exec;
pub mod rng;
pub mod linalg;
pub mod special;
pub mod operators;
pub mod krylov;
pub mod quadrature;
pub mod ciq;
pub mod precond;
pub mod baselines;
pub mod data;
pub mod gp;
pub mod svgp;
pub mod bo;
pub mod gibbs;
pub mod runtime;
pub mod coordinator;

/// Crate-wide error type.
///
/// `Clone` matters operationally: a failed batch in the coordinator fans the
/// *same* error out to every request in the batch, preserving the original
/// error kind per request.
#[derive(Clone, Debug)]
pub enum Error {
    /// Shape/size mismatch between operands.
    Shape(String),
    /// A numerical routine failed to converge or hit an invalid state.
    Numerical(String),
    /// Invalid argument.
    Invalid(String),
    /// Runtime (PJRT / artifact) failure.
    Runtime(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Numerical(m) => write!(f, "numerical failure: {m}"),
            Error::Invalid(m) => write!(f, "invalid argument: {m}"),
            Error::Runtime(m) => write!(f, "runtime failure: {m}"),
        }
    }
}

impl std::error::Error for Error {}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

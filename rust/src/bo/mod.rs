//! Thompson-sampling Bayesian optimization (Sec. 5.2 / Fig. 4).
//!
//! The BO loop keeps an exact-GP surrogate over all evaluations, draws
//! posterior samples at a `T`-point Sobol candidate set, and queries the
//! minimizers. Samplers: Cholesky (`O(T³)` — infeasible at large `T`),
//! msMINRES-CIQ (`O(T²)`), Random Fourier Features (approximate). The
//! paper's claim: larger `T` → lower regret, and only CIQ makes
//! `T ≥ 50,000` tractable with an exact GP.

pub mod testfns;
pub mod lander;

use crate::baselines::RandomFourierFeatures;
use crate::ciq::CiqOptions;
use crate::gp::{ExactGp, GpHyper};
use crate::linalg::Matrix;
use crate::operators::KernelType;
use crate::rng::{Pcg64, Sobol};
use crate::Result;

/// A minimization problem over `[0,1]^d` (scaled domain).
pub trait Problem: Sync {
    /// Dimension.
    fn dim(&self) -> usize;
    /// Evaluate the objective (lower is better).
    fn eval(&self, x: &[f64]) -> f64;
    /// Known optimum (for regret curves), if any.
    fn optimum(&self) -> Option<f64> {
        None
    }
    /// Name for reports.
    fn name(&self) -> &str;
}

/// Posterior sampling backend for Thompson sampling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sampler {
    /// dense Cholesky at the candidate set (baseline)
    Cholesky,
    /// msMINRES-CIQ (this paper)
    Ciq,
    /// random Fourier features (approximate baseline)
    Rff,
}

/// BO configuration.
#[derive(Clone, Debug)]
pub struct BoConfig {
    /// Thompson candidate-set size `T`.
    pub candidates: usize,
    /// Total evaluations (including init).
    pub evaluations: usize,
    /// Initial design size.
    pub init: usize,
    /// Parallel queries per iteration (paper: 5).
    pub batch: usize,
    /// Sampler backend.
    pub sampler: Sampler,
    /// CIQ options.
    pub ciq: CiqOptions,
    /// RFF feature count (paper: 1000).
    pub rff_features: usize,
    /// Adam steps for hyper refits.
    pub fit_steps: usize,
}

impl Default for BoConfig {
    fn default() -> Self {
        BoConfig {
            candidates: 1000,
            evaluations: 50,
            init: 10,
            batch: 5,
            sampler: Sampler::Ciq,
            ciq: CiqOptions { tol: 1e-4, max_iters: 200, ..Default::default() },
            rff_features: 1000,
            fit_steps: 20,
        }
    }
}

/// Result of a BO run.
pub struct BoTrace {
    /// best objective value after each evaluation
    pub best_so_far: Vec<f64>,
    /// all queried points
    pub queries: Matrix,
    /// all observed values
    pub values: Vec<f64>,
}

impl BoTrace {
    /// Final best value.
    pub fn best(&self) -> f64 {
        *self.best_so_far.last().unwrap()
    }

    /// Regret trace against a known optimum.
    pub fn regret(&self, opt: f64) -> Vec<f64> {
        self.best_so_far.iter().map(|v| (v - opt).max(0.0)).collect()
    }
}

/// Run Thompson-sampling BO on `problem`.
pub fn run_bo(problem: &dyn Problem, cfg: &BoConfig, seed: u64) -> Result<BoTrace> {
    let d = problem.dim();
    let mut rng = Pcg64::seeded(seed);

    // initial space-filling design
    let mut sobol = Sobol::new(d);
    let mut xs: Vec<Vec<f64>> = sobol.sample(cfg.init);
    // jitter the deterministic design per replicate
    for p in &mut xs {
        for v in p.iter_mut() {
            *v = (*v + rng.uniform() * 0.05).min(1.0 - 1e-9);
        }
    }
    let mut values: Vec<f64> = xs.iter().map(|p| problem.eval(p)).collect();

    let mut best_so_far = Vec::with_capacity(cfg.evaluations);
    let mut best = f64::INFINITY;
    for &v in &values {
        best = best.min(v);
        best_so_far.push(best);
    }

    while values.len() < cfg.evaluations {
        // surrogate over standardized values
        let n = values.len();
        let mut x_train = Matrix::zeros(n, d);
        for (i, p) in xs.iter().enumerate() {
            for j in 0..d {
                x_train[(i, j)] = p[j];
            }
        }
        let ymean = crate::util::mean(&values);
        let ystd = crate::util::std_dev(&values).max(1e-9);
        let y_std: Vec<f64> = values.iter().map(|v| (v - ymean) / ystd).collect();
        let mut gp = ExactGp::new(
            x_train,
            y_std,
            KernelType::Matern52,
            GpHyper { lengthscale: 0.3, outputscale: 1.0, noise: 1e-4 },
        );
        gp.fit_hypers(cfg.fit_steps, 0.1)?;

        // candidate set
        let mut sob = Sobol::new(d);
        let cand_vecs = sob.sample(cfg.candidates);
        let mut cands = Matrix::zeros(cfg.candidates, d);
        for (i, p) in cand_vecs.iter().enumerate() {
            for j in 0..d {
                // random shift per iteration to decorrelate candidate sets
                cands[(i, j)] = (p[j] + rng.uniform() * 1e-3).min(1.0 - 1e-9);
            }
        }

        // draw `batch` Thompson samples and take each minimizer
        let mut batch_pts: Vec<Vec<f64>> = Vec::new();
        for _ in 0..cfg.batch.min(cfg.evaluations - values.len()) {
            let sample = match cfg.sampler {
                Sampler::Ciq => gp.sample_posterior_ciq(&cands, &cfg.ciq, &mut rng)?,
                Sampler::Cholesky => gp.sample_posterior_cholesky(&cands, &mut rng)?,
                Sampler::Rff => {
                    let rff = RandomFourierFeatures::new(
                        d,
                        cfg.rff_features,
                        gp.hyper.lengthscale,
                        gp.hyper.outputscale,
                        &mut rng,
                    );
                    rff.posterior_sample(&gp.x, &gp.y, gp.hyper.noise.max(1e-6), &cands, &mut rng)?
                }
            };
            let (mut arg, mut best_s) = (0usize, f64::INFINITY);
            for (i, &v) in sample.iter().enumerate() {
                if v < best_s {
                    best_s = v;
                    arg = i;
                }
            }
            batch_pts.push(cands.row(arg).to_vec());
        }

        for p in batch_pts {
            let v = problem.eval(&p);
            xs.push(p);
            values.push(v);
            best = best.min(v);
            best_so_far.push(best);
        }
    }

    let mut queries = Matrix::zeros(xs.len(), d);
    for (i, p) in xs.iter().enumerate() {
        for j in 0..d {
            queries[(i, j)] = p[j];
        }
    }
    Ok(BoTrace { best_so_far, queries, values })
}

#[cfg(test)]
mod tests {
    use super::testfns::{Branin2, Hartmann6};
    use super::*;

    #[test]
    fn bo_beats_random_search_on_branin() {
        let problem = Branin2;
        let cfg = BoConfig {
            candidates: 256,
            evaluations: 30,
            init: 6,
            batch: 2,
            sampler: Sampler::Ciq,
            fit_steps: 10,
            ..Default::default()
        };
        let trace = run_bo(&problem, &cfg, 7).unwrap();
        assert_eq!(trace.best_so_far.len(), 30);
        // monotone best-so-far
        for w in trace.best_so_far.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
        // random search baseline with the same budget
        let mut rng = Pcg64::seeded(7);
        let mut rs_best = f64::INFINITY;
        for _ in 0..30 {
            let p: Vec<f64> = (0..2).map(|_| rng.uniform()).collect();
            rs_best = rs_best.min(problem.eval(&p));
        }
        assert!(
            trace.best() <= rs_best + 0.5,
            "BO {} should be no worse than random {}",
            trace.best(),
            rs_best
        );
        // and it should get reasonably close to the optimum (0.3979)
        assert!(trace.best() < 3.0, "best {}", trace.best());
    }

    #[test]
    fn samplers_all_run_on_hartmann() {
        let problem = Hartmann6;
        for sampler in [Sampler::Cholesky, Sampler::Ciq, Sampler::Rff] {
            let cfg = BoConfig {
                candidates: 128,
                evaluations: 14,
                init: 8,
                batch: 3,
                sampler,
                fit_steps: 5,
                ..Default::default()
            };
            let trace = run_bo(&problem, &cfg, 3).unwrap();
            assert_eq!(trace.best_so_far.len(), 14);
            assert!(trace.best() < 0.0, "{sampler:?} best {}", trace.best());
        }
    }
}

//! From-scratch 2-D lunar-lander controller-tuning problem (the paper's
//! Fig. 4 right uses OpenAI gym's `LunarLander-v2`; we build the physics
//! ourselves — DESIGN.md §Substitutions).
//!
//! Dynamics: a point-mass lander with orientation falls under gravity over
//! flat terrain; actions each step are {nothing, left thruster, right
//! thruster, main engine}. The 12-parameter heuristic controller family
//! follows Eriksson et al. [21]: PD-style gains mapping state to target
//! angle/hover plus firing thresholds. Reward = landing bonus − crash
//! penalty − fuel − distance, averaged over a fixed set of random initial
//! conditions. The objective is the *negated* mean reward (minimization).

use super::Problem;
use crate::rng::Pcg64;

/// Lander state.
#[derive(Clone, Copy, Debug)]
struct State {
    x: f64,
    y: f64,
    vx: f64,
    vy: f64,
    angle: f64,
    vangle: f64,
    fuel: f64,
}

const DT: f64 = 0.05;
const GRAVITY: f64 = -1.0;
const MAIN_THRUST: f64 = 2.2;
const SIDE_TORQUE: f64 = 1.2;
const SIDE_THRUST: f64 = 0.18;
const MAX_STEPS: usize = 400;

/// One simulated episode under a 12-parameter controller.
/// Returns the episode reward (higher is better).
fn episode(params: &[f64; 12], seed: u64) -> f64 {
    let mut rng = Pcg64::seeded(seed);
    let mut s = State {
        x: rng.uniform_in(-0.6, 0.6),
        y: rng.uniform_in(1.2, 1.6),
        vx: rng.uniform_in(-0.3, 0.3),
        vy: rng.uniform_in(-0.4, 0.0),
        angle: rng.uniform_in(-0.2, 0.2),
        vangle: rng.uniform_in(-0.1, 0.1),
        fuel: 0.0,
    };
    let p = params;
    for _ in 0..MAX_STEPS {
        // --- controller (12 parameters, Eriksson et al. heuristic family) ---
        let mut angle_targ = s.x * p[0] + s.vx * p[1];
        angle_targ = angle_targ.clamp(-p[2], p[2]);
        let hover_targ = p[3] * s.x.abs() + p[4];
        let angle_todo = (angle_targ - s.angle) * p[5] - s.vangle * p[6];
        let hover_todo = (hover_targ - s.y) * p[7] - s.vy * p[8];

        // action selection
        let mut main_on = false;
        let mut side: f64 = 0.0;
        if hover_todo > angle_todo.abs() && hover_todo > p[9] {
            main_on = true;
        } else if angle_todo < -p[10] {
            side = -1.0;
        } else if angle_todo > p[11] {
            side = 1.0;
        }

        // --- physics ---
        let mut ax = 0.0;
        let mut ay = GRAVITY;
        if main_on {
            ax += MAIN_THRUST * (-s.angle.sin());
            ay += MAIN_THRUST * s.angle.cos();
            s.fuel += 0.3 * DT;
        }
        if side != 0.0 {
            s.vangle += side * SIDE_TORQUE * DT;
            ax += side * SIDE_THRUST * s.angle.cos();
            s.fuel += 0.03 * DT;
        }
        s.vx += ax * DT;
        s.vy += ay * DT;
        s.x += s.vx * DT;
        s.y += s.vy * DT;
        s.angle += s.vangle * DT;

        // touchdown / crash
        if s.y <= 0.0 {
            let gentle = s.vy.abs() < 0.5 && s.vx.abs() < 0.5 && s.angle.abs() < 0.35;
            let on_pad = s.x.abs() < 0.3;
            let mut r = -s.fuel - s.x.abs();
            if gentle && on_pad {
                r += 100.0;
            } else if gentle {
                r += 30.0;
            } else {
                r -= 100.0; // crash
            }
            return r;
        }
        // drifted away
        if s.x.abs() > 2.5 || s.y > 3.0 {
            return -100.0 - s.fuel;
        }
    }
    // ran out of time hovering
    -50.0 - s.fuel
}

/// The 12-D controller-tuning problem: parameters live in `[0,1]^12` and are
/// affinely mapped to physical gain ranges; objective = −(mean reward over
/// `episodes` fixed seeds).
pub struct Lander {
    /// number of fixed evaluation episodes (paper uses 50)
    pub episodes: usize,
}

impl Default for Lander {
    fn default() -> Self {
        Lander { episodes: 20 }
    }
}

/// gain ranges for the 12 parameters
const RANGES: [(f64, f64); 12] = [
    (0.0, 2.0),  // x -> target angle
    (0.0, 2.0),  // vx -> target angle
    (0.1, 1.0),  // angle clamp
    (0.0, 1.0),  // |x| -> hover target
    (0.0, 0.5),  // hover bias
    (0.1, 8.0),  // angle P gain
    (0.0, 4.0),  // angle D gain
    (0.1, 8.0),  // hover P gain
    (0.0, 8.0),  // hover D gain
    (0.0, 1.0),  // main-engine threshold
    (0.0, 0.6),  // left threshold
    (0.0, 0.6),  // right threshold
];

impl Problem for Lander {
    fn dim(&self) -> usize {
        12
    }

    fn eval(&self, z: &[f64]) -> f64 {
        let mut p = [0.0f64; 12];
        for i in 0..12 {
            let (lo, hi) = RANGES[i];
            p[i] = lo + (hi - lo) * z[i].clamp(0.0, 1.0);
        }
        let mut total = 0.0;
        for e in 0..self.episodes {
            total += episode(&p, 1000 + e as u64);
        }
        -(total / self.episodes as f64)
    }

    fn name(&self) -> &str {
        "lander12"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_objective() {
        let l = Lander { episodes: 5 };
        let z = [0.5; 12];
        assert_eq!(l.eval(&z), l.eval(&z));
    }

    #[test]
    fn objective_discriminates_controllers() {
        let l = Lander { episodes: 10 };
        // zero gains: free fall → crashes (bad)
        let freefall = l.eval(&[0.0; 12]);
        // a hand-tuned reasonable controller
        let decent = l.eval(&[0.3, 0.5, 0.5, 0.3, 0.4, 0.6, 0.4, 0.6, 0.4, 0.05, 0.1, 0.1]);
        assert!(
            decent < freefall,
            "tuned controller ({decent}) should beat free fall ({freefall})"
        );
    }

    #[test]
    fn a_good_controller_lands_sometimes() {
        // search a small random sample for a controller that achieves
        // positive average reward (objective < 0) — ensures the problem is
        // solvable, not degenerate
        let l = Lander { episodes: 10 };
        let mut rng = Pcg64::seeded(9);
        let mut best = f64::INFINITY;
        for _ in 0..60 {
            let z: Vec<f64> = (0..12).map(|_| rng.uniform()).collect();
            best = best.min(l.eval(&z));
        }
        assert!(best < 60.0, "even random search should find non-crashing controllers, best={best}");
    }

    #[test]
    fn episode_terminates_and_is_bounded() {
        let p = [1.0f64; 12];
        for seed in 0..5 {
            let r = episode(&p, seed);
            assert!((-300.0..=150.0).contains(&r), "reward {r} out of bounds");
        }
    }
}

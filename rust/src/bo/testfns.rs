//! Standard global-optimization test problems (scaled to `[0,1]^d`).

use super::Problem;

/// Hartmann-6: 6 local minima, global optimum −3.32237 (the paper's Fig. 4
/// left / Fig. 2 posterior-covariance test case).
pub struct Hartmann6;

const H6_A: [[f64; 6]; 4] = [
    [10.0, 3.0, 17.0, 3.5, 1.7, 8.0],
    [0.05, 10.0, 17.0, 0.1, 8.0, 14.0],
    [3.0, 3.5, 1.7, 10.0, 17.0, 8.0],
    [17.0, 8.0, 0.05, 10.0, 0.1, 14.0],
];
const H6_C: [f64; 4] = [1.0, 1.2, 3.0, 3.2];
const H6_P: [[f64; 6]; 4] = [
    [0.1312, 0.1696, 0.5569, 0.0124, 0.8283, 0.5886],
    [0.2329, 0.4135, 0.8307, 0.3736, 0.1004, 0.9991],
    [0.2348, 0.1451, 0.3522, 0.2883, 0.3047, 0.6650],
    [0.4047, 0.8828, 0.8732, 0.5743, 0.1091, 0.0381],
];

impl Problem for Hartmann6 {
    fn dim(&self) -> usize {
        6
    }
    fn eval(&self, x: &[f64]) -> f64 {
        let mut outer = 0.0;
        for i in 0..4 {
            let mut inner = 0.0;
            for j in 0..6 {
                let d = x[j] - H6_P[i][j];
                inner += H6_A[i][j] * d * d;
            }
            outer += H6_C[i] * (-inner).exp();
        }
        -outer
    }
    fn optimum(&self) -> Option<f64> {
        Some(-3.32237)
    }
    fn name(&self) -> &str {
        "hartmann6"
    }
}

/// Branin (2-D), rescaled to `[0,1]²`; optimum ≈ 0.397887.
pub struct Branin2;

impl Problem for Branin2 {
    fn dim(&self) -> usize {
        2
    }
    fn eval(&self, z: &[f64]) -> f64 {
        let x = 15.0 * z[0] - 5.0;
        let y = 15.0 * z[1];
        let a = 1.0;
        let b = 5.1 / (4.0 * std::f64::consts::PI.powi(2));
        let c = 5.0 / std::f64::consts::PI;
        let r = 6.0;
        let s = 10.0;
        let t = 1.0 / (8.0 * std::f64::consts::PI);
        a * (y - b * x * x + c * x - r).powi(2) + s * (1.0 - t) * x.cos() + s
    }
    fn optimum(&self) -> Option<f64> {
        Some(0.397887)
    }
    fn name(&self) -> &str {
        "branin2"
    }
}

/// Ackley in `d` dims on `[0,1]^d` (mapped to `[-5,5]^d`); optimum 0 at center.
pub struct Ackley {
    /// dimension
    pub d: usize,
}

impl Problem for Ackley {
    fn dim(&self) -> usize {
        self.d
    }
    fn eval(&self, z: &[f64]) -> f64 {
        let x: Vec<f64> = z.iter().map(|v| 10.0 * v - 5.0).collect();
        let n = self.d as f64;
        let s1: f64 = x.iter().map(|v| v * v).sum::<f64>() / n;
        let s2: f64 = x.iter().map(|v| (2.0 * std::f64::consts::PI * v).cos()).sum::<f64>() / n;
        -20.0 * (-0.2 * s1.sqrt()).exp() - s2.exp() + 20.0 + std::f64::consts::E
    }
    fn optimum(&self) -> Option<f64> {
        Some(0.0)
    }
    fn name(&self) -> &str {
        "ackley"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hartmann_known_optimum() {
        // global minimizer (Surjanovic & Bingham)
        let xopt = [0.20169, 0.150011, 0.476874, 0.275332, 0.311652, 0.6573];
        let v = Hartmann6.eval(&xopt);
        assert!((v - (-3.32237)).abs() < 1e-4, "hartmann at optimum = {v}");
        // any other point is worse
        assert!(Hartmann6.eval(&[0.5; 6]) > v);
    }

    #[test]
    fn branin_known_optimum() {
        // one of the three minimizers: (pi, 2.275) → scaled
        let z = [(std::f64::consts::PI + 5.0) / 15.0, 2.275 / 15.0];
        let v = Branin2.eval(&z);
        assert!((v - 0.397887).abs() < 1e-4, "branin at optimum = {v}");
    }

    #[test]
    fn ackley_optimum_at_center() {
        let a = Ackley { d: 4 };
        let v = a.eval(&[0.5; 4]);
        assert!(v.abs() < 1e-9, "ackley at center = {v}");
        assert!(a.eval(&[0.9; 4]) > 1.0);
    }
}

//! Linear operators accessed only through matrix–vector multiplication.
//!
//! This is the paper's central abstraction: every Krylov routine in the crate
//! touches `K` exclusively via [`LinearOp::matvec`] / [`LinearOp::matmat`],
//! so `K` never needs to be materialized. Kernel operators perform their
//! MVMs in row blocks (map-reduce style, Sec. 3.2 / refs [11, 79]) giving
//! `O(N)` memory, and are threaded.

mod counting;
mod dense;
pub mod kernel;
pub mod image;
mod composed;

pub use composed::{DiagOp, LowRankPlusDiagOp, ScaledOp, ShiftedOp, SubtractLowRankOp, SumOp};
pub use counting::CountingOp;
pub use dense::DenseOp;
pub use kernel::{cross_kernel, KernelOp, KernelType};

use crate::linalg::{Matrix, SolveWorkspace};

/// A symmetric linear operator `K ∈ R^{n×n}` accessed through MVMs.
pub trait LinearOp: Sync {
    /// Dimension `n`.
    fn size(&self) -> usize;

    /// `y = K x`.
    fn matvec(&self, x: &[f64]) -> Vec<f64>;

    /// `out = K x` with any scratch drawn from `ws` — the zero-allocation
    /// solve path ([`crate::krylov::msminres::msminres_in`] and friends).
    /// Default routes through [`Self::matvec`] (one transient allocation);
    /// structured operators override with a genuinely in-place compute.
    fn matvec_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        let _ = ws;
        assert_eq!(out.len(), self.size(), "matvec_in out dim mismatch");
        out.copy_from_slice(&self.matvec(x));
    }

    /// `out = K X` for a block of right-hand sides, scratch drawn from `ws`.
    /// Same contract as [`Self::matvec_in`]: the default allocates once via
    /// [`Self::matmat`]; overrides write straight into `out` so a warmed
    /// workspace-backed block solve performs zero heap allocations.
    fn matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        let _ = ws;
        assert_eq!(out.rows(), self.size(), "matmat_in out rows mismatch");
        assert_eq!(out.cols(), x.cols(), "matmat_in out cols mismatch");
        let y = self.matmat(x);
        out.as_mut_slice().copy_from_slice(y.as_slice());
    }

    /// `Y = K X` for a block of right-hand sides (columns of `x`).
    ///
    /// Default implementation loops over columns; structured operators
    /// override this with a fused blocked implementation (this is where the
    /// coordinator's RHS batching pays off).
    fn matmat(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.rows(), self.size(), "matmat dim mismatch");
        let mut out = Matrix::zeros(self.size(), x.cols());
        for j in 0..x.cols() {
            let col = x.col(j);
            let y = self.matvec(&col);
            for i in 0..self.size() {
                out[(i, j)] = y[i];
            }
        }
        out
    }

    /// Diagonal of the operator (needed by pivoted-Cholesky preconditioning
    /// and Jacobi preconditioners). Default: probe with unit vectors (O(n²));
    /// structured operators override.
    fn diagonal(&self) -> Vec<f64> {
        let n = self.size();
        let mut d = vec![0.0; n];
        let mut e = vec![0.0; n];
        for (i, di) in d.iter_mut().enumerate() {
            e[i] = 1.0;
            *di = self.matvec(&e)[i];
            e[i] = 0.0;
        }
        d
    }

    /// Column `j` of the operator (pivoted Cholesky needs explicit columns).
    /// Default: probe with a unit vector.
    fn column(&self, j: usize) -> Vec<f64> {
        let n = self.size();
        let mut e = vec![0.0; n];
        e[j] = 1.0;
        self.matvec(&e)
    }

    /// A guaranteed lower bound on λ_min, when the operator's structure
    /// provides one (e.g. `K = PSD + σ²I ⇒ λ_min ≥ σ²`). Lanczos *over*-
    /// estimates λ_min on clustered spectra, which would make the CIQ
    /// quadrature interval miss the bottom of the spectrum; a structural
    /// bound is always safe because the quadrature error only degrades
    /// logarithmically with over-estimated κ (Lemma 1).
    fn lambda_min_bound(&self) -> Option<f64> {
        None
    }

    /// Materialize as a dense matrix (tests / small-N baselines only).
    fn to_dense(&self) -> Matrix {
        let n = self.size();
        let mut m = Matrix::zeros(n, n);
        for j in 0..n {
            let col = self.column(j);
            for i in 0..n {
                m[(i, j)] = col[i];
            }
        }
        m
    }

    /// Whether this operator has a genuine f32-storage MVM behind
    /// [`Self::matmat_mixed_in`]. The refined solve path
    /// (`rust/DESIGN.md` §9) only engages `Precision::Mixed` when this
    /// returns `true`; otherwise it silently runs pure f64.
    fn supports_mixed(&self) -> bool {
        false
    }

    /// `out ≈ K X` computed with f32-storage kernels (f64 accumulation),
    /// scratch drawn from `ws`. Only meaningful when
    /// [`Self::supports_mixed`] is `true`; the default delegates to the
    /// exact [`Self::matmat_in`] so callers never get garbage from an
    /// operator that lacks a mixed path.
    fn matmat_mixed_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        self.matmat_in(ws, x, out)
    }
}

/// Adapter presenting an operator's *mixed-precision* MVM as its primary
/// `matmat_in`, so the unmodified msMINRES recurrence can run against the
/// f32 kernels while the refinement loop above it keeps the exact f64
/// `matmat_in` for true residuals (`rust/DESIGN.md` §9).
pub struct MixedOp<'a, T: LinearOp + ?Sized>(pub &'a T);

impl<T: LinearOp + ?Sized> LinearOp for MixedOp<'_, T> {
    fn size(&self) -> usize {
        self.0.size()
    }
    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        self.0.matvec(x)
    }
    fn matvec_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        self.0.matvec_in(ws, x, out)
    }
    fn matmat(&self, x: &Matrix) -> Matrix {
        self.0.matmat(x)
    }
    fn matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        self.0.matmat_mixed_in(ws, x, out)
    }
    fn diagonal(&self) -> Vec<f64> {
        self.0.diagonal()
    }
    fn column(&self, j: usize) -> Vec<f64> {
        self.0.column(j)
    }
    fn lambda_min_bound(&self) -> Option<f64> {
        self.0.lambda_min_bound()
    }
    fn supports_mixed(&self) -> bool {
        self.0.supports_mixed()
    }
    fn matmat_mixed_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        self.0.matmat_mixed_in(ws, x, out)
    }
}

impl<T: LinearOp + ?Sized> LinearOp for &T {
    fn size(&self) -> usize {
        (**self).size()
    }
    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        (**self).matvec(x)
    }
    fn matvec_in(&self, ws: &mut SolveWorkspace, x: &[f64], out: &mut [f64]) {
        (**self).matvec_in(ws, x, out)
    }
    fn matmat(&self, x: &Matrix) -> Matrix {
        (**self).matmat(x)
    }
    fn matmat_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        (**self).matmat_in(ws, x, out)
    }
    fn diagonal(&self) -> Vec<f64> {
        (**self).diagonal()
    }
    fn column(&self, j: usize) -> Vec<f64> {
        (**self).column(j)
    }
    fn lambda_min_bound(&self) -> Option<f64> {
        (**self).lambda_min_bound()
    }
    fn to_dense(&self) -> Matrix {
        (**self).to_dense()
    }
    fn supports_mixed(&self) -> bool {
        (**self).supports_mixed()
    }
    fn matmat_mixed_in(&self, ws: &mut SolveWorkspace, x: &Matrix, out: &mut Matrix) {
        (**self).matmat_mixed_in(ws, x, out)
    }
}
